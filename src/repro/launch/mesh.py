"""Production mesh construction.

A function (not a module-level constant) so importing never touches jax
device state. Single pod: 256 v5e chips as (data=16, model=16). Multi-pod:
2 pods = 512 chips as (pod=2, data=16, model=16) — the pod axis carries
pure data parallelism over DCN.
"""
from __future__ import annotations

import jax


def make_mesh(shape, axes) -> jax.sharding.Mesh:
    # jax.sharding.AxisType landed after 0.4.x; Auto is the default there
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh() -> jax.sharding.Mesh:
    """Degenerate 1-device mesh for CPU smoke runs."""
    return make_mesh((1, 1), ("data", "model"))


def make_zero_mesh(ndp: int = 1, *, model: int = 1,
                   devices=None) -> jax.sharding.Mesh:
    """``(data=ndp, model=...)`` mesh over the first ``ndp * model`` local
    devices — the DP/ZeRO domain of the sharded RLHF engines. Unlike
    :func:`make_mesh` this takes an explicit device subset, so one forced
    multi-device CPU process can host the ``ndp=1`` baseline and the
    ``ndp=8`` sharded run side by side (the CI validation topology)."""
    import numpy as np
    devices = list(devices if devices is not None else jax.devices())
    n = ndp * model
    assert len(devices) >= n, (len(devices), n)
    arr = np.array(devices[:n]).reshape(ndp, model)
    return jax.sharding.Mesh(arr, ("data", "model"))


# TPU v5e hardware constants for the roofline (per chip).
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # B/s
ICI_BW_PER_LINK = 50e9        # B/s per link (~45-50 GB/s on v5e)
ICI_LINKS = 4                 # 2D torus on v5e: 4 links/chip
DCN_BW = 25e9                 # B/s per host NIC (pod axis)
