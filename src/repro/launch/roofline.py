import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Roofline derivation per (arch x shape) on the single-pod mesh.

Three terms (seconds, per step):
  compute    = HLO flops (trip-count corrected) / (chips * 197 TF/s)
  memory     = HLO byte-traffic proxy / (chips * 819 GB/s)
  collective = per-type collective bytes / (chips * links * 50 GB/s)
               (pod-axis DCN collectives would use 25 GB/s — single-pod here)

MODEL_FLOPS = 6 * N_active * tokens (train; x1/3 for pure forward) gives the
useful-work ratio. Emits JSON consumed by EXPERIMENTS.md §Roofline.

Usage: PYTHONPATH=src python -m repro.launch.roofline [--out roofline.json]
"""

import argparse
import json
import time

import jax

from repro.configs import ASSIGNED_ARCHS, SHAPES, get_config
from repro.launch import hlo_analysis
from repro.launch.dryrun import build_lowerable
from repro.launch.mesh import (HBM_BW, ICI_BW_PER_LINK, ICI_LINKS,
                               PEAK_FLOPS_BF16, make_production_mesh)

CHIPS = 256


def model_flops(cfg, shape) -> float:
    n = cfg.active_param_count()
    tokens = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def analyze_one(arch: str, shape_name: str, mesh=None, strat=None) -> dict:
    mesh = mesh or make_production_mesh()
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    fn, args, in_sh, out_sh, donate = build_lowerable(arch, shape_name, mesh,
                                                      strat)
    compiled = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                       donate_argnums=donate).lower(*args).compile()
    summ = hlo_analysis.analyze(compiled.as_text())
    mem = compiled.memory_analysis()

    # per-device quantities from the partitioned module
    t_compute = summ.flops / PEAK_FLOPS_BF16
    t_memory = summ.hbm_bytes / HBM_BW
    t_coll = summ.total_collective_bytes / (ICI_BW_PER_LINK * ICI_LINKS)
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    hlo_global_flops = summ.flops * CHIPS
    rec = {
        "arch": arch, "shape": shape_name,
        "compute_s": round(t_compute, 4),
        "memory_s": round(t_memory, 4),
        "collective_s": round(t_coll, 4),
        "dominant": dominant.replace("_s", ""),
        "model_flops": mf,
        "hlo_flops_global": hlo_global_flops,
        "useful_ratio": round(mf / hlo_global_flops, 3)
        if hlo_global_flops else None,
        "collective_bytes_per_device": {k: int(v)
                                        for k, v in summ.coll_bytes.items()},
        "hbm_bytes_per_device": int(summ.hbm_bytes),
        "device_mem_gib": round(
            (mem.argument_size_in_bytes + mem.temp_size_in_bytes
             - mem.alias_size_in_bytes) / 2**30, 2),
    }
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="roofline.json")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    args = ap.parse_args()
    mesh = make_production_mesh()
    combos = ([(args.arch, args.shape)] if args.arch else
              [(a, s) for a in ASSIGNED_ARCHS for s in SHAPES])
    records = []
    for arch, shape in combos:
        t0 = time.time()
        try:
            rec = analyze_one(arch, shape, mesh)
            rec["analysis_s"] = round(time.time() - t0, 1)
            print(f"[roofline] {arch:25s} {shape:12s} "
                  f"C {rec['compute_s']:8.3f}s M {rec['memory_s']:8.3f}s "
                  f"X {rec['collective_s']:8.3f}s -> {rec['dominant']:10s} "
                  f"useful {rec['useful_ratio']}", flush=True)
        except Exception as e:
            rec = {"arch": arch, "shape": shape, "error": str(e)[:300]}
            print(f"[roofline] {arch:25s} {shape:12s} FAIL {e}", flush=True)
        records.append(rec)
        with open(args.out, "w") as f:
            json.dump(records, f, indent=1)


if __name__ == "__main__":
    main()
