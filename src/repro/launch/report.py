"""Render a run's telemetry JSONL (``repro.obs.RunTelemetry.write_jsonl``)
as a per-phase table and an ASCII memory timeline.

Everything printed here is read straight off the file — phase wall times,
measured/simulated bytes and PCIe traffic all rode the spans when the run
recorded them, so the report involves zero recomputation (and can be run
on another machine, long after the run).

Usage:
  PYTHONPATH=src python -m repro.launch.report RUN.jsonl [--width 64]
  PYTHONPATH=src python -m repro.launch.report RUN.jsonl --metrics
"""
from __future__ import annotations

import argparse
import json
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_MIB = 2 ** 20


def load(path: str) -> Tuple[dict, List[dict], List[dict], List[dict]]:
    """Split a telemetry JSONL into (meta, spans+instants, samples,
    metrics)."""
    meta: dict = {}
    events: List[dict] = []
    samples: List[dict] = []
    metrics: List[dict] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            t = rec.get("type")
            if t == "meta":
                meta = rec
            elif t == "sample":
                samples.append(rec)
            elif t == "metric":
                metrics.append(rec)
            elif t in ("span", "instant"):
                events.append(rec)
    return meta, events, samples, metrics


def phase_table(events: List[dict]) -> str:
    """Aggregate the ``cat == "phase"`` spans per phase name, preserving
    first-seen order (the canonical phase sequence)."""
    rows: Dict[str, dict] = {}
    order: List[str] = []
    for ev in events:
        if ev.get("type") != "span" or ev.get("cat") != "phase":
            continue
        name, args = ev["name"], ev.get("args", {})
        if name not in rows:
            order.append(name)
            rows[name] = {"n": 0, "wall_us": 0.0, "live": 0, "peak": 0,
                          "host": 0, "pcie": 0.0, "sim": None, "delta": None}
        r = rows[name]
        r["n"] += 1
        r["wall_us"] += ev.get("dur_us", 0.0)
        r["live"] = max(r["live"], args.get("measured_bytes", 0))
        r["peak"] = max(r["peak"], args.get("measured_peak_bytes", 0))
        r["host"] = max(r["host"], args.get("host_bytes", 0))
        r["pcie"] += args.get("pcie_bytes", 0)
        if "sim_peak_bytes" in args:
            r["sim"] = args["sim_peak_bytes"]
            r["delta"] = args.get("sim_delta_bytes")
    if not rows:
        return "(no phase spans in file)"
    hdr = (f"{'phase':16s} {'n':>3s} {'wall ms':>9s} {'live MiB':>9s} "
           f"{'peak MiB':>9s} {'host MiB':>9s} {'PCIe MiB':>9s} "
           f"{'sim MiB':>9s} {'delta MiB':>10s}")
    out = [hdr, "-" * len(hdr)]
    for name in order:
        r = rows[name]
        sim = f"{r['sim']/_MIB:9.2f}" if r["sim"] is not None else f"{'-':>9s}"
        dl = (f"{r['delta']/_MIB:+10.2f}" if r["delta"] is not None
              else f"{'-':>10s}")
        out.append(f"{name:16s} {r['n']:3d} {r['wall_us']/1e3:9.1f} "
                   f"{r['live']/_MIB:9.2f} {r['peak']/_MIB:9.2f} "
                   f"{r['host']/_MIB:9.2f} {r['pcie']/_MIB:9.2f} {sim} {dl}")
    return "\n".join(out)


def timeline(samples: List[dict], *, track: str = "memory",
             key: str = "device_mib", width: int = 64,
             height: int = 10) -> str:
    """ASCII area chart of one counter-track series over the run."""
    pts = [(s["ts_us"], s["values"][key]) for s in samples
           if s.get("track") == track and key in s.get("values", {})]
    if len(pts) < 2:
        return f"(no '{track}/{key}' samples in file)"
    pts.sort()
    t_lo, t_hi = pts[0][0], pts[-1][0]
    v_hi = max(v for _, v in pts) or 1.0
    # bucket samples into `width` columns, keep each column's max
    cols: List[Optional[float]] = [None] * width
    for t, v in pts:
        c = min(int((t - t_lo) / max(t_hi - t_lo, 1) * (width - 1)),
                width - 1)
        cols[c] = v if cols[c] is None else max(cols[c], v)
    last = 0.0
    for i, c in enumerate(cols):          # carry last value through gaps
        last = last if c is None else c
        cols[i] = last
    grid = []
    for row in range(height, 0, -1):
        thr = v_hi * (row - 0.5) / height
        line = "".join("█" if v >= thr else " " for v in cols)
        label = f"{v_hi * row / height:8.1f} |" if row in (1, height) \
            else f"{'':8s} |"
        grid.append(label + line)
    grid.append(f"{'':8s} +" + "-" * width)
    grid.append(f"{'':10s}0 ms{'':{max(width - 18, 1)}s}"
                f"{(t_hi - t_lo)/1e3:8.1f} ms")
    return "\n".join(grid)


def attribution_table(events: List[dict], *, key: str = "attrib") -> str:
    """Owner x phase matrix in MiB, read from the LAST span of each phase
    name (the steady-state iteration). ``key="attrib"`` renders measured
    per-owner bytes plus the unattributed residue row;
    ``key="attrib_sim_delta"`` renders the signed measured-minus-sim
    per-owner deltas instead."""
    cols: Dict[str, dict] = {}
    order: List[str] = []
    for ev in events:
        if ev.get("type") != "span" or ev.get("cat") != "phase":
            continue
        args = ev.get("args", {})
        if key not in args:
            continue
        name = ev["name"]
        if name not in cols:
            order.append(name)
        tab = dict(args[key])
        if key == "attrib":
            tab["(unattributed)"] = args.get("attrib_unattributed", 0)
        cols[name] = tab
    if not cols:
        return f"(no per-owner '{key}' tables in file)"
    # rows sorted by the owner's largest (absolute) cell, residue last
    peak: Dict[str, int] = {}
    for tab in cols.values():
        for k, v in tab.items():
            peak[k] = max(peak.get(k, 0), abs(int(v)))
    names = sorted((k for k in peak if k != "(unattributed)"),
                   key=lambda k: -peak[k])
    if "(unattributed)" in peak:
        names.append("(unattributed)")
    w = max(9, *(len(p) for p in order))
    signed = key != "attrib"
    hdr = f"{'owner':18s} " + " ".join(f"{p:>{w}s}" for p in order)
    out = [hdr, "-" * len(hdr)]
    for k in names:
        cells = []
        for p in order:
            v = cols[p].get(k)
            if v is None:
                cells.append(f"{'-':>{w}s}")
            elif signed:
                cells.append(f"{v / _MIB:>+{w}.2f}")
            else:
                cells.append(f"{v / _MIB:>{w}.2f}")
        out.append(f"{k:18s} " + " ".join(cells))
    return "\n".join(out)


def flight_summary(dump: dict) -> str:
    """Human rendering of one flight-recorder dump bundle
    (``repro.obs.flight`` schema ``flight-recorder/v1``)."""
    cap = dump.get("capacity_bytes") or 0
    live = dump.get("live_bytes", 0)
    head = f"flight recorder dump — trigger: {dump.get('trigger', '?')}" \
           f" (source: {dump.get('source') or '?'}"
    if dump.get("phase"):
        head += f", phase: {dump['phase']}"
    out = [head + ")",
           f"  live {live / _MIB:.2f} MiB / capacity {cap / _MIB:.2f} MiB"
           f" (watermark {dump.get('watermark', 0):.0%})"]
    if dump.get("error"):
        out.append(f"  error: {dump['error'][:200]}")
    owners = dump.get("owners", {})
    if owners:
        out.append("  owners:")
        ranked = dump.get("owners_ranked") or \
            sorted(owners, key=owners.get, reverse=True)
        for k in ranked:
            out.append(f"    {k:20s} {owners[k] / _MIB:9.2f} MiB "
                       f"{owners[k] / max(live, 1):6.1%}")
    un = dump.get("unattributed", 0)
    out.append(f"    {'(unattributed)':20s} {un / _MIB:9.2f} MiB "
               f"{un / max(live, 1):6.1%}")
    tb = dump.get("top_buffers", [])
    if tb:
        out.append(f"  top {len(tb)} live buffers:")
        for b in tb:
            line = (f"    {b.get('nbytes', 0) / _MIB:9.2f} MiB "
                    f"{str(b.get('dtype', '?')):>10s} "
                    f"{str(b.get('shape', '?')):16s} "
                    f"{b.get('owner', '?')}")
            if b.get("path"):
                line += f" @{b['path']}"
            out.append(line)
    ph = dump.get("phase_history", [])
    if ph:
        out.append(f"  phase history ({len(ph)} boundaries, oldest first):")
        for p in ph[-10:]:
            out.append(f"    {str(p.get('phase', '?')):16s} "
                       f"live {p.get('live_bytes', 0) / _MIB:9.2f} MiB  "
                       f"host {(p.get('host_bytes') or 0) / _MIB:9.2f} MiB")
    out.append(f"  ring: {len(dump.get('ring', []))} context events")
    return "\n".join(out)


def trend_table(path: str, *, last: int = 20) -> str:
    """Cross-run trajectory of one bench's gated metrics, read from a
    ``benchmarks/history/HISTORY_<name>.jsonl`` file (one line per run,
    appended by ``benchmarks.run``)."""
    rows: List[dict] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                rows.append(json.loads(line))
    if not rows:
        return "(empty history file)"
    rows = rows[-last:]
    keys: List[str] = []
    for r in rows:
        for k in r.get("gated", {}):
            if k not in keys:
                keys.append(k)
    w = {k: max(len(k), 10) for k in keys}
    hdr = f"{'when':>16s} {'sha':>9s} " + \
        " ".join(f"{k:>{w[k]}s}" for k in keys)
    out = [f"bench history: {rows[-1].get('bench', '?')} "
           f"(last {len(rows)} runs)", hdr, "-" * len(hdr)]
    for r in rows:
        cells = []
        for k in keys:
            v = r.get("gated", {}).get(k)
            if v is None:
                cells.append(f"{'-':>{w[k]}s}")
            elif isinstance(v, (int, float)):
                cells.append(f"{v:>{w[k]}.4g}")
            else:
                cells.append(f"{str(v)[:w[k]]:>{w[k]}s}")
        out.append(f"{str(r.get('iso', ''))[:16]:>16s} "
                   f"{str(r.get('sha', '-')):>9s} " + " ".join(cells))
    return "\n".join(out)


def metric_lines(metrics: List[dict]) -> str:
    out = []
    for m in metrics:
        lab = ",".join(f"{k}={v}" for k, v in sorted(
            m.get("labels", {}).items()))
        name = m["name"] + (f"{{{lab}}}" if lab else "")
        if m["kind"] == "histogram":
            mean = m["sum"] / m["count"] if m["count"] else 0.0
            out.append(f"  {name:48s} n={m['count']} mean={mean:.6g} "
                       f"max={m['max']:.6g}")
        else:
            peak = f" peak={m['peak']:.6g}" if "peak" in m else ""
            out.append(f"  {name:48s} {m['value']:.6g}{peak}")
    return "\n".join(out) if out else "  (no metrics in file)"


def render(path: str, *, width: int = 64, show_metrics: bool = False) -> str:
    meta, events, samples, metrics = load(path)
    run_meta = {k: v for k, v in meta.items()
                if k not in ("type", "t0_wall", "written")}
    out = [f"telemetry report: {path}"]
    if run_meta:
        out.append("  " + " ".join(f"{k}={v}" for k, v in
                                   sorted(run_meta.items())))
    n_off = sum(1 for e in events if e.get("cat") == "offload")
    n_srv = sum(1 for e in events if e.get("cat") == "serving")
    out += ["", phase_table(events)]
    attr = attribution_table(events)
    if not attr.startswith("(no"):
        out += ["", "per-owner attribution (MiB, last span per phase):",
                attr]
        sd = attribution_table(events, key="attrib_sim_delta")
        if not sd.startswith("(no"):
            out += ["", "per-owner sim delta (measured - sim, MiB):", sd]
    out += ["", "live device memory (MiB) over the run:",
            timeline(samples, width=width)]
    host = [s for s in samples if s.get("track") == "memory"
            and s.get("values", {}).get("host_mib")]
    if host:
        out += ["", "host (parked) memory (MiB) over the run:",
                timeline(samples, key="host_mib", width=width)]
    if n_off or n_srv:
        out += ["", f"other spans: {n_off} offload, {n_srv} serving"]
    if show_metrics:
        out += ["", "metrics snapshot:", metric_lines(metrics)]
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("jsonl", nargs="?",
                    help="run telemetry JSONL "
                         "(RunTelemetry.write_jsonl output)")
    ap.add_argument("--width", type=int, default=64,
                    help="timeline width in columns")
    ap.add_argument("--metrics", action="store_true",
                    help="also print the final metrics snapshot")
    ap.add_argument("--trend", metavar="HISTORY_JSONL",
                    help="render a benchmarks/history/HISTORY_<name>.jsonl "
                         "cross-run trajectory")
    ap.add_argument("--flight", metavar="DUMP_JSON",
                    help="render a flight-recorder dump bundle")
    args = ap.parse_args()
    shown = False
    if args.trend:
        print(trend_table(args.trend))
        shown = True
    if args.flight:
        with open(args.flight) as f:
            print(flight_summary(json.load(f)))
        shown = True
    if args.jsonl:
        if shown:
            print()
        print(render(args.jsonl, width=args.width,
                     show_metrics=args.metrics))
    elif not shown:
        ap.error("nothing to render: give a run JSONL, --trend, "
                 "or --flight")


if __name__ == "__main__":
    main()
