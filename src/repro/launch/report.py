"""Render a run's telemetry JSONL (``repro.obs.RunTelemetry.write_jsonl``)
as a per-phase table and an ASCII memory timeline.

Everything printed here is read straight off the file — phase wall times,
measured/simulated bytes and PCIe traffic all rode the spans when the run
recorded them, so the report involves zero recomputation (and can be run
on another machine, long after the run).

Usage:
  PYTHONPATH=src python -m repro.launch.report RUN.jsonl [--width 64]
  PYTHONPATH=src python -m repro.launch.report RUN.jsonl --metrics
"""
from __future__ import annotations

import argparse
import json
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_MIB = 2 ** 20


def load(path: str) -> Tuple[dict, List[dict], List[dict], List[dict]]:
    """Split a telemetry JSONL into (meta, spans+instants, samples,
    metrics)."""
    meta: dict = {}
    events: List[dict] = []
    samples: List[dict] = []
    metrics: List[dict] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            t = rec.get("type")
            if t == "meta":
                meta = rec
            elif t == "sample":
                samples.append(rec)
            elif t == "metric":
                metrics.append(rec)
            elif t in ("span", "instant"):
                events.append(rec)
    return meta, events, samples, metrics


def phase_table(events: List[dict]) -> str:
    """Aggregate the ``cat == "phase"`` spans per phase name, preserving
    first-seen order (the canonical phase sequence)."""
    rows: Dict[str, dict] = {}
    order: List[str] = []
    for ev in events:
        if ev.get("type") != "span" or ev.get("cat") != "phase":
            continue
        name, args = ev["name"], ev.get("args", {})
        if name not in rows:
            order.append(name)
            rows[name] = {"n": 0, "wall_us": 0.0, "live": 0, "peak": 0,
                          "host": 0, "pcie": 0.0, "sim": None, "delta": None}
        r = rows[name]
        r["n"] += 1
        r["wall_us"] += ev.get("dur_us", 0.0)
        r["live"] = max(r["live"], args.get("measured_bytes", 0))
        r["peak"] = max(r["peak"], args.get("measured_peak_bytes", 0))
        r["host"] = max(r["host"], args.get("host_bytes", 0))
        r["pcie"] += args.get("pcie_bytes", 0)
        if "sim_peak_bytes" in args:
            r["sim"] = args["sim_peak_bytes"]
            r["delta"] = args.get("sim_delta_bytes")
    if not rows:
        return "(no phase spans in file)"
    hdr = (f"{'phase':16s} {'n':>3s} {'wall ms':>9s} {'live MiB':>9s} "
           f"{'peak MiB':>9s} {'host MiB':>9s} {'PCIe MiB':>9s} "
           f"{'sim MiB':>9s} {'delta MiB':>10s}")
    out = [hdr, "-" * len(hdr)]
    for name in order:
        r = rows[name]
        sim = f"{r['sim']/_MIB:9.2f}" if r["sim"] is not None else f"{'-':>9s}"
        dl = (f"{r['delta']/_MIB:+10.2f}" if r["delta"] is not None
              else f"{'-':>10s}")
        out.append(f"{name:16s} {r['n']:3d} {r['wall_us']/1e3:9.1f} "
                   f"{r['live']/_MIB:9.2f} {r['peak']/_MIB:9.2f} "
                   f"{r['host']/_MIB:9.2f} {r['pcie']/_MIB:9.2f} {sim} {dl}")
    return "\n".join(out)


def timeline(samples: List[dict], *, track: str = "memory",
             key: str = "device_mib", width: int = 64,
             height: int = 10) -> str:
    """ASCII area chart of one counter-track series over the run."""
    pts = [(s["ts_us"], s["values"][key]) for s in samples
           if s.get("track") == track and key in s.get("values", {})]
    if len(pts) < 2:
        return f"(no '{track}/{key}' samples in file)"
    pts.sort()
    t_lo, t_hi = pts[0][0], pts[-1][0]
    v_hi = max(v for _, v in pts) or 1.0
    # bucket samples into `width` columns, keep each column's max
    cols: List[Optional[float]] = [None] * width
    for t, v in pts:
        c = min(int((t - t_lo) / max(t_hi - t_lo, 1) * (width - 1)),
                width - 1)
        cols[c] = v if cols[c] is None else max(cols[c], v)
    last = 0.0
    for i, c in enumerate(cols):          # carry last value through gaps
        last = last if c is None else c
        cols[i] = last
    grid = []
    for row in range(height, 0, -1):
        thr = v_hi * (row - 0.5) / height
        line = "".join("█" if v >= thr else " " for v in cols)
        label = f"{v_hi * row / height:8.1f} |" if row in (1, height) \
            else f"{'':8s} |"
        grid.append(label + line)
    grid.append(f"{'':8s} +" + "-" * width)
    grid.append(f"{'':10s}0 ms{'':{max(width - 18, 1)}s}"
                f"{(t_hi - t_lo)/1e3:8.1f} ms")
    return "\n".join(grid)


def metric_lines(metrics: List[dict]) -> str:
    out = []
    for m in metrics:
        lab = ",".join(f"{k}={v}" for k, v in sorted(
            m.get("labels", {}).items()))
        name = m["name"] + (f"{{{lab}}}" if lab else "")
        if m["kind"] == "histogram":
            mean = m["sum"] / m["count"] if m["count"] else 0.0
            out.append(f"  {name:48s} n={m['count']} mean={mean:.6g} "
                       f"max={m['max']:.6g}")
        else:
            peak = f" peak={m['peak']:.6g}" if "peak" in m else ""
            out.append(f"  {name:48s} {m['value']:.6g}{peak}")
    return "\n".join(out) if out else "  (no metrics in file)"


def render(path: str, *, width: int = 64, show_metrics: bool = False) -> str:
    meta, events, samples, metrics = load(path)
    run_meta = {k: v for k, v in meta.items()
                if k not in ("type", "t0_wall", "written")}
    out = [f"telemetry report: {path}"]
    if run_meta:
        out.append("  " + " ".join(f"{k}={v}" for k, v in
                                   sorted(run_meta.items())))
    n_off = sum(1 for e in events if e.get("cat") == "offload")
    n_srv = sum(1 for e in events if e.get("cat") == "serving")
    out += ["", phase_table(events), "",
            "live device memory (MiB) over the run:",
            timeline(samples, width=width)]
    host = [s for s in samples if s.get("track") == "memory"
            and s.get("values", {}).get("host_mib")]
    if host:
        out += ["", "host (parked) memory (MiB) over the run:",
                timeline(samples, key="host_mib", width=width)]
    if n_off or n_srv:
        out += ["", f"other spans: {n_off} offload, {n_srv} serving"]
    if show_metrics:
        out += ["", "metrics snapshot:", metric_lines(metrics)]
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("jsonl", help="run telemetry JSONL "
                                  "(RunTelemetry.write_jsonl output)")
    ap.add_argument("--width", type=int, default=64,
                    help="timeline width in columns")
    ap.add_argument("--metrics", action="store_true",
                    help="also print the final metrics snapshot")
    args = ap.parse_args()
    print(render(args.jsonl, width=args.width, show_metrics=args.metrics))


if __name__ == "__main__":
    main()
