"""Serving launcher: batched generation with the fixed-capacity donated KV
cache (prefill + decode loop), reporting per-phase live-memory — the
inference side of the paper's study as a runnable service loop.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch llama3_2_3b --smoke \
      --batch 8 --prompt-len 32 --gen 64 --requests 4
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import ByteTokenizer, PromptDataset, \
    synthetic_instruction_prompts
from repro.models import Model
from repro.obs import MetricsRegistry
from repro.rlhf import Rollout, live_device_bytes


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=64)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write a metrics-registry JSONL snapshot here")
    args = ap.parse_args()
    reg = MetricsRegistry()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    n = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"[serve] {cfg.name}: {n/1e6:.2f}M params, "
          f"live {live_device_bytes()/2**20:.1f} MiB")
    reg.gauge("serve_params_m", "model size in M params").set(n / 1e6)
    reg.gauge("serve_live_device_bytes",
              "live HBM bytes (peak via gauge peak)").set(live_device_bytes())

    rollout = Rollout(model, cfg, capacity=args.prompt_len + args.gen,
                      temperature=args.temperature, top_k=50)
    prompts = PromptDataset(
        synthetic_instruction_prompts(args.batch * args.requests,
                                      seed=args.seed), args.prompt_len)
    it = prompts.batches(args.batch, seed=args.seed)
    tok = ByteTokenizer()
    key = jax.random.PRNGKey(args.seed + 1)
    for r in range(args.requests):
        key, k = jax.random.split(key)
        batch = jnp.asarray(next(it)) % cfg.vocab_size
        t0 = time.time()
        res = rollout.generate(params, {"tokens": batch}, args.gen, k)
        dt = time.time() - t0
        tput = args.batch * args.gen / dt
        print(f"[serve] request {r}: {dt*1e3:7.1f} ms "
              f"({tput:7.1f} tok/s) live {live_device_bytes()/2**20:8.1f} MiB")
        reg.counter("serve_requests_total", "generate calls served").inc()
        reg.counter("serve_tokens_total", "tokens generated").inc(
            args.batch * args.gen)
        reg.histogram("serve_request_latency_s",
                      "wall time per generate call").observe(dt)
        reg.gauge("serve_tokens_per_s", "throughput of last request").set(tput)
        reg.gauge("serve_live_device_bytes",
                  "live HBM bytes (peak via gauge peak)").set(
            live_device_bytes())
        if cfg.vocab_size >= 259 and r == 0:
            print("  sample:", tok.decode(
                np.asarray(res.tokens[0])[args.prompt_len:])[:60])
    if args.metrics_out:
        reg.write_jsonl(args.metrics_out)
        print(f"[serve] metrics -> {args.metrics_out}")


if __name__ == "__main__":
    main()
