import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production mesh with real shardings but ShapeDtypeStruct inputs (no
allocation). Prints memory_analysis / cost_analysis and the collective
schedule; emits a JSON record per combination for EXPERIMENTS.md §Dry-run
and the roofline (§Roofline).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3_2_3b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] --out results.json
"""

import argparse
import json
import re
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ASSIGNED_ARCHS, SHAPES, get_config
from repro.launch.mesh import make_production_mesh
from repro.models import Model
from repro.sharding import (ShardedContext, ShardingStrategy, batch_pspecs,
                            cache_pspecs, opt_shardings, to_named,
                            validate_tp)
from repro.steps import (cache_specs, decode_window, input_specs,
                         make_decode_step, make_prefill_step, make_train_step,
                         sds)

_COLL_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?(?:\.\d+)?\s*\(")
_SHAPED = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> dict:
    """Per-collective byte totals from optimized (per-device) HLO text.
    all-gather / all-reduce / all-to-all / permute: result bytes;
    reduce-scatter: first-operand bytes (the large buffer that moves)."""
    out = {}
    for line in hlo_text.splitlines():
        m = re.search(
            r"=\s*([a-z0-9]+)\[([0-9,]*)\][^=]*?"
            r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
            r"collective-permute)(?:-start)?", line)
        if not m:
            continue
        dtype, dims, op = m.group(1), m.group(2), m.group(3)
        if op == "reduce-scatter":
            # operand shape: first shaped arg inside parens
            rhs = line.split(op, 1)[1]
            ops_ = _SHAPED.findall(rhs)
            if ops_:
                dtype, dims = ops_[0]
        # tuple results print as (bf16[..], ..): fall back to per-line sum
        out[op] = out.get(op, 0) + _shape_bytes(dtype, dims)
    return out


def build_lowerable(arch: str, shape_name: str, mesh,
                    strat: ShardingStrategy = None):
    """Returns (fn, args, in_shardings, out_shardings) ready to lower."""
    from repro.sharding.ctx import set_current_mesh, set_segment_param_specs
    set_current_mesh(mesh)
    set_segment_param_specs(None)
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    strat = strat or ShardingStrategy()
    # eager Megatron divisibility check (DESIGN.md §9): fail here with the
    # offending dims named, not as an XLA shape error deep inside lower()
    validate_tp(cfg, strat.ntp)
    # the same context the RLHF trainer threads: param/opt specs come from
    # its TreePlans, so the launch path and the runtime engines cannot
    # disagree about what a ZeRO stage means
    sctx = ShardedContext(mesh, strat)
    model = Model(cfg)
    window = decode_window(cfg, shape)

    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pspecs = sctx.plan_params(cfg, params_shape).param_specs
    bspecs = batch_pspecs(cfg, shape, mesh)
    batch = input_specs(cfg, shape)

    if shape.kind == "train":
        # (hillclimb C, refuted on this backend: per-layer param-slice
        # constraints via ctx.set_segment_param_specs did not convert the
        # grad all-reduce into reduce-scatter — GSPMD keeps AR+slice. The
        # mechanism stays available in sharding.ctx for TPU/Shardy runs.)
        step = make_train_step(model, cfg, kind="ppo")
        opt = step.optimizer
        opt_specs = sctx.plan_params(cfg, params_shape, opt).opt_specs
        opt_shape = jax.eval_shape(opt.init, params_shape)
        state_shape = {"params": params_shape, "opt": opt_shape,
                       "step": sds((), jnp.int32)}
        state_specs = {"params": pspecs, "opt": opt_specs, "step": P()}
        metric_keys = ("ppo_loss", "kl", "clip_frac", "loss", "grad_norm")
        if cfg.mtp_depth:
            metric_keys = metric_keys + ("mtp_loss",)
        out_specs = (state_specs, {k: P() for k in metric_keys})
        # optimizer state may target the host memory kind
        # (strat.offload_optimizer — the runtime face of cpu_offload)
        in_state_sh = {"params": to_named(mesh, pspecs),
                       "opt": opt_shardings(mesh, opt_specs, strat),
                       "step": NamedSharding(mesh, P())}
        in_sh = (in_state_sh,
                 to_named(mesh, {k: bspecs[k] for k in batch}))
        return (step, (state_shape, batch), in_sh, to_named(mesh, out_specs),
                (0,))  # donate the train state

    if shape.kind == "prefill":
        cap = shape.seq_len
        step = make_prefill_step(model, cfg, capacity=cap, window=window)
        cspecs = _cache_pspec_tree(model, cfg, shape, mesh, strat)
        out_specs = (P(_bspec(shape, mesh)), cspecs)
        in_sh = (to_named(mesh, pspecs),
                 to_named(mesh, {k: bspecs[k] for k in batch}))
        return (step, (params_shape, batch), in_sh, to_named(mesh, out_specs),
                ())

    # decode / long_decode
    step = make_decode_step(model, cfg, window=window)
    cshapes = cache_specs(model, cfg, shape)
    cspecs = _cache_pspec_tree(model, cfg, shape, mesh, strat)
    b = _bspec(shape, mesh)
    in_sh = (to_named(mesh, pspecs), to_named(mesh, cspecs),
             NamedSharding(mesh, P(b)), NamedSharding(mesh, P(b)))
    out_specs = (P(b, None), cspecs)
    args = (params_shape, cshapes, batch["token"], batch["position"])
    return step, args, in_sh, to_named(mesh, out_specs), (1,)  # donate caches


def _bspec(shape, mesh):
    from repro.sharding.rules import dp_axes, _axsize
    dp = dp_axes(mesh)
    if shape.global_batch % _axsize(mesh, dp) == 0 and _axsize(mesh, dp) > 1:
        return dp if len(dp) > 1 else dp[0]
    return None


def _cache_pspec_tree(model, cfg, shape, mesh, strat):
    from repro.steps import cache_capacity
    cshapes = cache_specs(model, cfg, shape)
    seg_specs = cache_pspecs(model, cfg, mesh, shape.global_batch, strat,
                             cshapes["segments"])
    specs = {"segments": seg_specs, "cross_kv": None}
    if cshapes["cross_kv"] is not None:
        b = _bspec(shape, mesh)
        mp = "model" if "model" in mesh.axis_names else None
        kvh = cfg.num_kv_heads
        tp = mp if (mp and kvh % mesh.shape[mp] == 0) else None
        specs["cross_kv"] = jax.tree.map(
            lambda x: P(None, b, None, tp, None), cshapes["cross_kv"])
    return specs


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            strat: ShardingStrategy = None, verbose: bool = True,
            mesh=None) -> dict:
    if mesh is None:
        mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_desc = "x".join(str(s) for s in dict(mesh.shape).values())
    t0 = time.time()
    fn, args, in_sh, out_sh, donate = build_lowerable(arch, shape_name, mesh,
                                                      strat)
    lowered = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                      donate_argnums=donate).lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):       # jax 0.4.x: one dict per device
        ca = ca[0] if ca else {}
    coll = collective_bytes(compiled.as_text())
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_desc,
        "ok": True,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "bytes_per_device": {
            "arguments": mem.argument_size_in_bytes,
            "outputs": mem.output_size_in_bytes,
            "temps": mem.temp_size_in_bytes,
            "aliased": mem.alias_size_in_bytes,
        },
        "flops_per_device": ca.get("flops", 0.0),
        "bytes_accessed_per_device": ca.get("bytes accessed", 0.0),
        "collective_bytes_per_device": coll,
    }
    if verbose:
        print(json.dumps(rec, indent=2))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--zero-stage", type=int, default=3, choices=(1, 2, 3),
                    help="ZeRO stage for the sharding strategy (paper R2)")
    ap.add_argument("--ndp", type=int, default=0,
                    help="with --ntp: data-parallel size of an explicit "
                         "(data=ndp, model=ntp) zero mesh instead of the "
                         "production mesh")
    ap.add_argument("--ntp", type=int, default=0,
                    help="declared TP degree: builds the mesh via "
                         "launch.mesh.make_zero_mesh(ndp, model=ntp), sets "
                         "ShardingStrategy.ntp, and eagerly validates the "
                         "Megatron divisibility contract (DESIGN.md §9)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    mesh = None
    if args.ndp or args.ntp:
        from repro.launch.mesh import make_zero_mesh
        ndp, ntp = max(args.ndp, 1), max(args.ntp, 1)
        strat = ShardingStrategy(zero_stage=args.zero_stage, ntp=ntp)
        mesh = make_zero_mesh(ndp, model=ntp)
    else:
        strat = ShardingStrategy(zero_stage=args.zero_stage)

    combos = []
    if args.all:
        combos = [(a, s) for a in ASSIGNED_ARCHS for s in SHAPES]
    else:
        # default to the smallest assigned arch / shortest shape so a bare
        # `--ndp 2 --ntp 2` invocation has something to compile
        combos = [(args.arch or ASSIGNED_ARCHS[0], args.shape or "train_4k")]

    records = []
    for arch, shape in combos:
        try:
            rec = run_one(arch, shape, multi_pod=args.multi_pod,
                          strat=strat, verbose=not args.all, mesh=mesh)
            status = "OK"
        except Exception as e:
            rec = {"arch": arch, "shape": shape,
                   "mesh": ("x".join(str(s) for s in dict(mesh.shape).values())
                            if mesh is not None else
                            ("2x16x16" if args.multi_pod else "16x16")),
                   "ok": False, "error": f"{type(e).__name__}: {e}",
                   "trace": traceback.format_exc()[-2000:]}
            status = f"FAIL {type(e).__name__}"
        records.append(rec)
        print(f"[dryrun] {arch:25s} {shape:12s} "
              f"{rec['mesh']:8s} {status}", flush=True)
        if args.out:
            with open(args.out, "w") as f:
                json.dump(records, f, indent=1)
    n_ok = sum(r["ok"] for r in records)
    print(f"[dryrun] {n_ok}/{len(records)} combinations compiled")
    if n_ok < len(records):
        sys.exit(1)


if __name__ == "__main__":
    main()
