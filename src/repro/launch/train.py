"""Training launcher: LM pretraining/SFT or RLHF PPO for any registered
architecture on the host devices (CPU smoke / single TPU host) — the
multi-device production configuration is exercised via dryrun.py.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch llama3_2_3b --smoke \
      --mode lm --steps 50 --batch 8 --seq 128
  PYTHONPATH=src python -m repro.launch.train --arch opt_1_3b --smoke \
      --mode rlhf --steps 20 --batch 8
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save
from repro.configs import get_config
from repro.data import PromptDataset, SyntheticTextDataset, \
    synthetic_instruction_prompts
from repro.models import Model
from repro.rlhf import RLHFConfig, RLHFTrainer
from repro.rlhf.reward import make_target_token_reward
from repro.steps import init_train_state, make_train_step


def train_lm(cfg, args):
    model = Model(cfg)
    step_fn = make_train_step(model, cfg, kind="lm", lr=args.lr)
    state = init_train_state(model, cfg, jax.random.PRNGKey(args.seed),
                             step_fn.optimizer)
    n_params = sum(int(np.prod(p.shape)) for p in
                   jax.tree.leaves(state["params"]))
    print(f"[train] {cfg.name}: {n_params/1e6:.2f}M params")
    data = SyntheticTextDataset(cfg.vocab_size, args.seq, seed=args.seed)
    jit_step = jax.jit(step_fn, donate_argnums=(0,))
    it = data.batches(args.batch)
    t0 = time.time()
    for step in range(args.steps):
        toks = jnp.asarray(next(it))
        batch = {"tokens": toks, "loss_mask": jnp.ones_like(toks, jnp.float32)}
        state, metrics = jit_step(state, batch)
        if step % max(1, args.steps // 10) == 0 or step == args.steps - 1:
            print(f"  step {step:4d} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"({time.time()-t0:.1f}s)")
    if args.ckpt_dir:
        path = save(args.ckpt_dir, args.steps, state["params"])
        print(f"[train] saved {path}")
    return state


def train_rlhf(cfg, args):
    rl = RLHFConfig(prompt_len=args.seq // 2, gen_len=args.seq // 2,
                    lr=args.lr, critic_lr=args.lr * 3,
                    kl_coef=0.05, memory_policy=args.memory_policy)
    trainer = RLHFTrainer(cfg, cfg, rl, jax.random.PRNGKey(args.seed),
                          reward_fn=make_target_token_reward(7))
    prompts = PromptDataset(synthetic_instruction_prompts(256),
                            rl.prompt_len)
    it = prompts.batches(args.batch, seed=args.seed)
    key = jax.random.PRNGKey(args.seed + 1)
    t0 = time.time()
    for step in range(args.steps):
        key, k = jax.random.split(key)
        batch = jnp.asarray(next(it)) % cfg.vocab_size
        m = trainer.train_step(batch, k)
        if step % max(1, args.steps // 10) == 0 or step == args.steps - 1:
            print(f"  step {step:4d} reward {m['mean_reward']:+.4f} "
                  f"kl {m['kl']:.4f} vf {m['vf_loss']:.4f} "
                  f"({time.time()-t0:.1f}s)")
    print(f"[train] phase-memory records: {len(trainer.memory.records)} "
          f"(policy={args.memory_policy})")
    return trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--mode", choices=("lm", "rlhf"), default="lm")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced CPU-sized variant")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--memory-policy", default="after_inference",
                    choices=("none", "after_inference", "after_training",
                             "after_all"))
    args = ap.parse_args()
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    if args.mode == "lm":
        train_lm(cfg, args)
    else:
        train_rlhf(cfg, args)


if __name__ == "__main__":
    main()
