"""Trip-count-aware analysis of optimized (post-SPMD, per-device) HLO text.

``compiled.cost_analysis()`` counts each while-loop body ONCE, which
under-reports looped work by the trip count (layers scan x microbatches).
This module re-derives the roofline terms from the HLO text itself:

  * a first pass builds a global symbol table %name -> result shape (this
    dump format does not inline operand types);
  * computations are parsed into blocks; while ops carry
    ``known_trip_count`` in their backend_config — multipliers propagate
    ENTRY -> called computations (body/cond x trip, fusions/calls x 1);
  * flops: every ``dot`` contributes 2 * |result| * K (K = contracted dims
    of the lhs operand, looked up in the symbol table) x multiplier;
  * collective bytes per op type (all-gather / all-reduce / all-to-all /
    collective-permute: result bytes; reduce-scatter: operand bytes);
  * HBM-traffic proxy: op output bytes outside fusion bodies (+ fusion
    operand bytes) x multiplier — an upper bound on bytes moved.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1, "token": 0,
}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_COMP_HEADER = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\([^)]*\)\s*->")
_OPCODE_RE = re.compile(r"\)?\s*([a-z][\w\-]*)\s*\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CALL_SINGLE = re.compile(
    r"(?:to_apply|condition|body|calls)=%([\w.\-]+)")
_CALL_BRACED = re.compile(
    r"(?:calls|branch_computations)=\{([^}]*)\}")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_COLLS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
          "collective-permute")

# opcodes that imply real HBM traffic on TPU (elementwise chains fuse):
_TRAFFIC_OPS = frozenset((
    "fusion", "dot", "convolution", "reduce", "reduce-window", "scatter",
    "gather", "dynamic-slice", "dynamic-update-slice", "copy", "transpose",
    "concatenate", "pad", "slice", "reverse", "sort", "select-and-scatter",
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
))


def _shapes_bytes(shapes) -> int:
    total = 0
    for dt, dims in shapes:
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


@dataclass
class OpInfo:
    name: str
    opcode: str
    out_bytes: int
    out_shapes: list
    operand_names: List[str]
    calls: List[str]
    trip: int = 1
    flops: float = 0.0


@dataclass
class Computation:
    name: str
    ops: List[OpInfo] = field(default_factory=list)
    is_entry: bool = False


def parse_module(text: str):
    comps: Dict[str, Computation] = {}
    symbols: Dict[str, list] = {}          # name -> out shape list
    cur: Optional[Computation] = None
    entry = ""
    for raw in text.splitlines():
        s = raw.strip()
        if not s:
            continue
        # computation headers sit at column 0 and end with "{" — param
        # lists may contain nested parens (tuple types), so no paren regex
        if (raw.startswith("%") or raw.startswith("ENTRY")) and \
                s.endswith("{") and "->" in s:
            is_entry = raw.startswith("ENTRY")
            name = s.split("(", 1)[0].replace("ENTRY", "").strip().lstrip("%")
            cur = Computation(name, is_entry=is_entry)
            comps[name] = cur
            if is_entry:
                entry = name
            continue
        if s.startswith("}"):
            cur = None
            continue
        dm = _DEF_RE.match(s)
        if not dm or cur is None:
            continue
        name, rhs = dm.group(1), dm.group(2)
        om = _OPCODE_RE.search(rhs)
        if not om:
            continue
        opcode = om.group(1)
        head = rhs[:om.start(1)]
        out_shapes = _SHAPE_RE.findall(head)
        symbols[name] = out_shapes
        # operand names: inside the first (...) after the opcode
        depth = 0
        i = om.end(1)
        start = rhs.find("(", i - 1)
        j = start
        while j < len(rhs):
            if rhs[j] == "(":
                depth += 1
            elif rhs[j] == ")":
                depth -= 1
                if depth == 0:
                    break
            j += 1
        operand_str = rhs[start + 1:j] if start >= 0 else ""
        operands = _OPERAND_RE.findall(operand_str)
        attrs = rhs[j + 1:] if j < len(rhs) else ""
        calls = [m.group(1) for m in _CALL_SINGLE.finditer(attrs)]
        for m in _CALL_BRACED.finditer(attrs):
            for nm in m.group(1).split(","):
                nm = nm.strip().lstrip("%")
                if nm and nm not in calls:
                    calls.append(nm)
        trip = 1
        tm = _TRIP.search(attrs)
        if tm:
            trip = int(tm.group(1))
        op = OpInfo(name, opcode, _shapes_bytes(out_shapes), out_shapes,
                    operands, calls, trip)
        if opcode == "dot":
            mm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", attrs)
            op.flops = (mm, operands)      # resolved in second pass
        cur.ops.append(op)
    # second pass: resolve dot flops via the symbol table
    for comp in comps.values():
        for op in comp.ops:
            if op.opcode == "dot" and isinstance(op.flops, tuple):
                mm, operands = op.flops
                op.flops = 0.0
                if mm and operands:
                    lhs = symbols.get(operands[0])
                    if lhs:
                        dims = [int(x) for x in lhs[0][1].split(",") if x]
                        k = 1
                        for d in (int(x) for x in mm.group(1).split(",") if x):
                            if d < len(dims):
                                k *= dims[d]
                        out_elems = 1
                        if op.out_shapes:
                            for x in op.out_shapes[0][1].split(","):
                                if x:
                                    out_elems *= int(x)
                        op.flops = 2.0 * out_elems * k
    return comps, symbols, entry


@dataclass
class HLOSummary:
    flops: float = 0.0
    coll_bytes: Dict[str, float] = field(default_factory=dict)
    hbm_bytes: float = 0.0

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.coll_bytes.values())


def analyze(text: str) -> HLOSummary:
    comps, symbols, entry = parse_module(text)
    if not entry and comps:
        entry = max(comps, key=lambda k: len(comps[k].ops))
    fused = set()
    for c in comps.values():
        for op in c.ops:
            if op.opcode == "fusion":
                fused.update(op.calls)
    summary = HLOSummary()
    stack = []

    def operand_bytes(op: OpInfo) -> int:
        return sum(_shapes_bytes(symbols.get(n, [])) for n in
                   op.operand_names)

    def visit(name: str, mult: float):
        comp = comps.get(name)
        if comp is None or name in stack:
            return
        stack.append(name)
        in_fusion = name in fused
        for op in comp.ops:
            summary.flops += (op.flops or 0.0) * mult
            base = op.opcode.replace("-start", "").replace("-done", "")
            if base in _COLLS:
                nb = op.out_bytes
                if base == "reduce-scatter":
                    ob = operand_bytes(op)
                    nb = ob or nb
                elif base == "all-reduce":
                    nb *= 2      # ring cost: reduce-scatter + all-gather
                summary.coll_bytes[base] = summary.coll_bytes.get(base, 0) \
                    + nb * mult
            if not in_fusion and op.opcode in _TRAFFIC_OPS:
                nb = op.out_bytes
                if op.opcode in ("fusion", "dot", "convolution"):
                    nb += operand_bytes(op)
                summary.hbm_bytes += nb * mult
            child_mult = mult * (op.trip if op.opcode == "while" else 1)
            for callee in op.calls:
                visit(callee, child_mult)
        stack.pop()

    visit(entry, 1.0)
    return summary
