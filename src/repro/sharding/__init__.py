from repro.sharding.rules import (
    ShardingStrategy, batch_pspecs, cache_pspecs, dp_axes, opt_shardings,
    param_pspecs, to_named, zero_opt_pspecs,
)

__all__ = ["ShardingStrategy", "batch_pspecs", "cache_pspecs", "dp_axes",
           "opt_shardings", "param_pspecs", "to_named", "zero_opt_pspecs"]
