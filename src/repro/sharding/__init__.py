"""One public surface over the two sharding faces (see each module's
docstring): ``context`` — out-of-jit ShardedContext/TreePlan spec trees —
and ``ctx`` — the ambient-mesh GSPMD constraint hints model code uses
in-jit. Both resolve axis names from ``rules`` (DP_AXIS_NAMES/MODEL_AXIS),
so hints and explicit specs always agree about the mesh."""
from repro.sharding import ctx
from repro.sharding.context import (ShardedContext, TreePlan, delete_tree,
                                    tree_per_device_bytes)
from repro.sharding.ctx import (constrain, constrain_spec, current_mesh,
                                resolve_entry, set_current_mesh, use_mesh)
from repro.sharding.rules import (DP_AXIS_NAMES, MODEL_AXIS, TP_COL_SITES,
                                  TP_ROW_SITES, ShardingStrategy, SpecMesh,
                                  adapter_pspecs, batch_pspecs, cache_pspecs,
                                  dp_axes, opt_shardings, param_pspecs,
                                  spec_device_fraction, to_named,
                                  validate_tp, zero_opt_pspecs)

__all__ = ["DP_AXIS_NAMES", "MODEL_AXIS", "ShardedContext",
           "ShardingStrategy", "SpecMesh", "TP_COL_SITES", "TP_ROW_SITES",
           "TreePlan",
           "adapter_pspecs", "batch_pspecs", "cache_pspecs", "constrain",
           "constrain_spec", "ctx", "current_mesh", "delete_tree",
           "dp_axes",
           "opt_shardings", "param_pspecs", "resolve_entry",
           "set_current_mesh", "spec_device_fraction", "to_named",
           "tree_per_device_bytes", "use_mesh", "validate_tp",
           "zero_opt_pspecs"]
