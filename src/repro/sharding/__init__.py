from repro.sharding.context import (ShardedContext, TreePlan, delete_tree,
                                    tree_per_device_bytes)
from repro.sharding.rules import (ShardingStrategy, SpecMesh, adapter_pspecs,
                                  batch_pspecs, cache_pspecs, dp_axes,
                                  opt_shardings, param_pspecs,
                                  spec_device_fraction, to_named,
                                  zero_opt_pspecs)

__all__ = ["ShardedContext", "ShardingStrategy", "SpecMesh", "TreePlan",
           "adapter_pspecs", "batch_pspecs", "cache_pspecs", "delete_tree",
           "dp_axes",
           "opt_shardings", "param_pspecs", "spec_device_fraction",
           "to_named", "tree_per_device_bytes", "zero_opt_pspecs"]
