"""Parameter / activation / cache sharding rules.

Mesh axes: ``(data, model)`` single-pod, ``(pod, data, model)`` multi-pod.
``pod`` and ``data`` together form the data-parallel (and ZeRO/FSDP) domain;
``model`` carries tensor parallelism and expert parallelism.

ZeRO stages map onto pjit as (see DESIGN.md §2):
  * stage 1/2 — parameters replicated across the DP domain (TP still applies);
    optimizer state sharded over DP. XLA derives reduce-scatter/all-gather
    from the spec mismatch (the 1-vs-2 distinction is a *schedule* property
    modelled in the allocator-trace layer, not a pjit spec).
  * stage 3 — parameters also sharded over DP (FSDP): per-layer all-gathers.

Every rule checks divisibility (pjit requires in/out dims divide the axis)
and falls back to the next-best dim or replication — e.g. granite's 24 heads
/ 40 experts on a 16-way model axis shard the fused head dim / d_expert dim
instead.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig


# Canonical mesh axis names. ``DP_AXIS_NAMES`` together form the
# data-parallel (ZeRO/FSDP) domain; ``MODEL_AXIS`` carries tensor/expert
# parallelism. ``sharding.ctx`` resolves its constraint-hint entries
# ("dp" / "model") from these same names, so GSPMD hints and explicit
# TreePlan specs can never disagree about which axis is which.
DP_AXIS_NAMES = ("pod", "data")
MODEL_AXIS = "model"


def dp_axes(mesh: Mesh):
    return tuple(a for a in DP_AXIS_NAMES if a in mesh.axis_names)


def _axsize(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


class SpecMesh:
    """Devices-free stand-in for spec construction and byte accounting.

    The rules in this module only read ``mesh.shape`` (a name -> size
    mapping) and ``mesh.axis_names`` — so PartitionSpecs (and the
    per-device byte fractions ``core.strategies`` traces from them) can be
    built without any jax device state, e.g. for an 8-way DP domain on a
    1-device test process. Real ``jax.sharding.Mesh`` objects satisfy the
    same protocol."""

    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(self.shape)


@dataclass(frozen=True)
class ShardingStrategy:
    """The paper's §2.2 memory-management strategy knobs, pjit edition."""
    zero_stage: int = 3          # 0 | 1 | 2 | 3  (0 = fully replicated DP)
    tensor_parallel: bool = True
    expert_parallel: bool = True
    # Declared TP degree: the size the "model" mesh axis must have when this
    # strategy runs (1 = hints-only TP, the pre-TP-runtime behaviour). The
    # runtime (ShardedContext.create(model=ntp)) sets it from the mesh; the
    # traced simulator builds a SpecMesh with a matching model axis. A
    # strategy with ntp > 1 refuses meshes whose model axis disagrees, so
    # specs and devices can never silently diverge.
    ntp: int = 1
    # TP layout recipe. "megatron" is the column/row-parallel split
    # (DESIGN.md §9): QKV/up-projections column-parallel (output dim over
    # "model"), down/out-projections row-parallel (input dim over "model"),
    # embeddings/lm-head vocab-parallel. The only mode today; the knob
    # exists so alternate layouts (e.g. sequence-parallel-only) get a name
    # instead of a boolean explosion.
    tp_mode: str = "megatron"
    # ZeRO-3 all-gather granularity (DESIGN.md §3.7): "layer" gathers one
    # scanned layer period per scan iteration inside the forward/backward
    # (the FSDP discipline — transient peak is ONE layer period), "tree"
    # gathers the whole parameter tree up front (transient peak is the
    # full replicated model). Bit-identical to each other and to ndp=1;
    # only the transient HBM peak differs. Ignored below stage 3.
    gather_mode: str = "layer"   # "layer" | "tree"
    # host-offloaded optimizer state: realized as real device placement by
    # opt_shardings() (host memory kind) on backends that support memory
    # kinds — the same axis MemoryStrategy.cpu_offload models analytically
    # and repro.offload swaps at runtime, so the three can't disagree
    offload_optimizer: bool = False
    remat: Optional[str] = None       # override cfg.remat if set

    def __post_init__(self):
        if self.ntp < 1:
            raise ValueError(f"ntp must be >= 1, got {self.ntp}")
        if self.tp_mode != "megatron":
            raise ValueError(f"unknown tp_mode {self.tp_mode!r} "
                             "(supported: 'megatron')")
        if self.ntp > 1 and not self.tensor_parallel:
            raise ValueError("ntp > 1 requires tensor_parallel=True")


# Megatron site classification for the LoRA adapter rules: COLUMN-parallel
# base matmuls shard their OUTPUT dim over "model" (x stays replicated on
# the model axis going in), ROW-parallel ones shard their INPUT dim (x
# arrives model-sharded, the matmul ends in an all-reduce). Mirrors the
# per-name entries in param_pspecs below.
TP_COL_SITES = ("wq", "wk", "wv", "w_in", "w_gate", "in_proj",
                "q_up", "kv_up", "proj")
TP_ROW_SITES = ("wo", "w_out", "out_proj")


def validate_tp(cfg: ModelConfig, ntp: int) -> None:
    """Eagerly reject a (config, TP degree) pair the Megatron layout cannot
    shard: heads, FFN width and vocab must all divide ``ntp``. Raising here
    — at launch/mesh-construction time — replaces the XLA shape-mismatch
    error a bad combination would otherwise surface deep inside jit."""
    if ntp <= 1:
        return
    bad = []
    if cfg.num_heads % ntp:
        bad.append(f"num_heads={cfg.num_heads}")
    if cfg.d_ff and cfg.d_ff % ntp:
        bad.append(f"d_ff={cfg.d_ff}")
    if cfg.vocab_size % ntp:
        bad.append(f"vocab_size={cfg.vocab_size}")
    if bad:
        raise ValueError(
            f"config {cfg.name!r} cannot run tensor-parallel at ntp={ntp}: "
            f"{', '.join(bad)} must be divisible by ntp. Pick a TP degree "
            f"dividing all of (num_heads, d_ff, vocab_size) or adjust the "
            f"config.")


def _check_tp_mesh(mesh, strat: ShardingStrategy) -> None:
    if strat.ntp > 1:
        size = dict(mesh.shape).get("model")
        assert size == strat.ntp, \
            (f"strategy declares ntp={strat.ntp} but the mesh's 'model' "
             f"axis is {size} ({tuple(mesh.axis_names)})")


def _div(mesh, dim: int, axes) -> bool:
    return dim % _axsize(mesh, axes) == 0 and _axsize(mesh, axes) > 1


def param_pspecs(cfg: ModelConfig, mesh: Mesh,
                 strat: ShardingStrategy, params_shape) -> dict:
    """PartitionSpec pytree matching ``params_shape`` (a ShapeDtypeStruct
    pytree from jax.eval_shape of model.init)."""
    _check_tp_mesh(mesh, strat)
    dp = dp_axes(mesh)
    mp = "model" if (strat.tensor_parallel and "model" in mesh.axis_names) else None
    fsdp = dp if strat.zero_stage >= 3 else None

    def fs(dim: int):
        return fsdp if (fsdp and dim % _axsize(mesh, fsdp) == 0) else None

    def tp(dim: int):
        return mp if (mp and dim % _axsize(mesh, mp) == 0) else None

    def spec_for(path: Tuple[str, ...], leaf) -> P:
        shape = leaf.shape
        # mtp_extra stacks MTP modules for depths 2..k on a leading axis,
        # exactly like scanned segments — strip it and apply name rules
        stacked = any(k.startswith("segment") or k in ("encoder", "mtp_extra")
                      for k in path)
        lead = (None,) if stacked else ()
        if stacked:
            shape = shape[1:]
        name = path[-1]
        parent = path[-2] if len(path) >= 2 else ""

        def mk(*entries):
            return P(*(lead + entries))

        if name == "embed":
            return mk(tp(shape[0]), fs(shape[1]))
        if name == "lm_head":
            return mk(fs(shape[0]), tp(shape[1]))
        if name in ("final_norm", "encoder_norm", "norm1", "norm2", "norm_x",
                    "q_norm", "kv_norm", "norm_h", "norm_e"):
            return mk(*([None] * len(shape)))
        if name == "scale":
            return mk(*([None] * len(shape)))
        # attention -----------------------------------------------------
        if name in ("wq", "wk", "wv"):
            return mk(fs(shape[0]), tp(shape[1]))
        if name == "wo":
            return mk(tp(shape[0]), fs(shape[1]))
        if name in ("bq", "bk", "bv"):
            return mk(tp(shape[0]))
        # MLA -----------------------------------------------------------
        if name in ("q_down", "kv_down"):
            return mk(fs(shape[0]), None)
        if name in ("q_up", "kv_up"):
            return mk(fs(shape[0]), tp(shape[1]))
        # MLP -----------------------------------------------------------
        if name in ("w_in", "w_gate") and len(shape) == 2:
            return mk(fs(shape[0]), tp(shape[1]))
        if name == "w_out" and len(shape) == 2:
            return mk(tp(shape[0]), fs(shape[1]))
        # MoE experts [E, D, F] / [E, F, D] -------------------------------
        if name in ("w_in", "w_gate") and len(shape) == 3:
            ep = mp if (strat.expert_parallel and mp and _div(mesh, shape[0], mp)) else None
            if ep:
                return mk(ep, fs(shape[1]), None)
            return mk(None, fs(shape[1]), tp(shape[2]))
        if name == "w_out" and len(shape) == 3:
            ep = mp if (strat.expert_parallel and mp and _div(mesh, shape[0], mp)) else None
            if ep:
                return mk(ep, None, fs(shape[2]))
            return mk(None, tp(shape[1]), fs(shape[2]))
        if name == "router":
            return mk(fs(shape[0]), None)
        # Mamba ----------------------------------------------------------
        if name == "in_proj":
            return mk(fs(shape[0]), tp(shape[1]))
        if name == "out_proj":
            return mk(tp(shape[0]), fs(shape[1]))
        if name in ("conv_w", "conv_b"):
            return mk(*([None] * (len(shape) - 1)), tp(shape[-1]))
        if name in ("dt_bias", "A_log", "D", "norm"):
            return mk(*([None] * len(shape)))
        # heads / misc -----------------------------------------------------
        if parent == "value_head" or name in ("w", "b"):
            return mk(*([None] * len(shape)))
        if name == "proj":  # mtp projection [2D, D]
            return mk(fs(shape[0]), tp(shape[1]))
        return mk(*([None] * len(shape)))

    flat = jax.tree_util.tree_flatten_with_path(params_shape)[0]
    paths = [tuple(str(getattr(k, "key", k)) for k in kp) for kp, _ in flat]
    leaves = [spec_for(p, l) for p, (_, l) in zip(paths, flat)]
    treedef = jax.tree_util.tree_structure(params_shape)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def zero_opt_pspecs(param_specs, params_shape, mesh: Mesh,
                    strat: ShardingStrategy):
    """ZeRO-1/2: optimizer state sharded over the DP domain even when the
    parameters themselves are replicated there. For each leaf, shard the
    largest dim that (a) is unsharded in the param spec and (b) divides the
    DP size. ZeRO-3 states just mirror the (already DP-sharded) param spec."""
    dp = dp_axes(mesh)
    n = _axsize(mesh, dp)

    def respec(spec: P, leaf) -> P:
        if strat.zero_stage >= 3 or strat.zero_stage < 1 or n == 1:
            return spec
        entries = list(spec) + [None] * (len(leaf.shape) - len(spec))
        best, best_dim = None, 0
        for i, (e, d) in enumerate(zip(entries, leaf.shape)):
            if e is None and d % n == 0 and d > best_dim:
                best, best_dim = i, d
        if best is not None:
            entries[best] = dp if len(dp) > 1 else dp[0]
        return P(*entries)

    return jax.tree.map(respec, param_specs, params_shape,
                        is_leaf=lambda x: isinstance(x, P))


def adapter_pspecs(mesh: Mesh, strat: ShardingStrategy, adapter_shape) -> dict:
    """PartitionSpec pytree for a hydra LoRA adapter tree (see
    ``models.lora.init_adapter``: {"lora": {... {"a", "b"} sites}, optional
    "value_head"}). The RLHF sharding contract (DESIGN.md §2):

      * ``a`` factors ``[*lead, d_in, r]`` shard ``d_in`` over the DP/FSDP
        domain at ZeRO-3 (the rank dim is tiny and stays whole);
      * ``b`` factors ``[*lead, r, d_out]`` shard ``d_out`` likewise;
      * value heads / biases are replicated (scalar-output leaves);
      * below ZeRO-3 the DP entries drop — the per-role trees are
        paper-small, so only the FSDP stage bothers cutting them over DP.

    Under TP (a mesh with a "model" axis and ``strat.tensor_parallel``)
    each factor additionally partitions CONSISTENTLY with its base matmul
    (DESIGN.md §9), so the hydra merge ``base + A @ B`` is shard-local and
    the merged tree lands in exactly the base layout:

      * column-parallel sites (``TP_COL_SITES``: base output dim over
        "model") put "model" on ``b``'s ``d_out`` — each model shard holds
        the full ``A`` and its own columns of ``B``/``base``;
      * row-parallel sites (``TP_ROW_SITES``: base input dim over "model")
        put "model" on ``a``'s ``d_in`` — each shard holds its rows of
        ``A``/``base`` and the full ``B``.

    A dim takes the TP entry *or* the FSDP entry, TP first (the base rule
    never stacks both on one dim either). Divisibility falls back per-leaf,
    same as :func:`param_pspecs`."""
    _check_tp_mesh(mesh, strat)
    dp = dp_axes(mesh)
    fsdp = dp if strat.zero_stage >= 3 else None
    mp = "model" if (strat.tensor_parallel and "model" in mesh.axis_names) \
        else None

    def fs(dim: int):
        return fsdp if (fsdp and dim % _axsize(mesh, fsdp) == 0) else None

    def tp(dim: int):
        return mp if (mp and dim % _axsize(mesh, mp) == 0) else None

    def spec_for(path: Tuple[str, ...], leaf) -> P:
        shape = leaf.shape
        name = path[-1]
        site = path[-2] if len(path) >= 2 else ""
        if "value_head" in path or len(shape) < 2:
            return P(*([None] * len(shape)))
        lead = (None,) * (len(shape) - 2)
        row_par = site in TP_ROW_SITES
        if name == "a":
            e = (tp(shape[-2]) or fs(shape[-2])) if row_par else fs(shape[-2])
            return P(*lead, e, None)
        if name == "b":
            e = fs(shape[-1]) if row_par else (tp(shape[-1]) or fs(shape[-1]))
            return P(*lead, None, e)
        return P(*([None] * len(shape)))

    flat = jax.tree_util.tree_flatten_with_path(adapter_shape)[0]
    paths = [tuple(str(getattr(k, "key", k)) for k in kp) for kp, _ in flat]
    leaves = [spec_for(p, l) for p, (_, l) in zip(paths, flat)]
    treedef = jax.tree_util.tree_structure(adapter_shape)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def spec_device_fraction(spec: P, leaf, mesh: Mesh) -> float:
    """Per-device fraction of ``leaf``'s bytes under ``spec``: 1/(product of
    the mesh axes the spec actually uses). The traced alternative to the
    closed-form ``1/ndp`` of ``MemoryStrategy.scale``."""
    n = 1
    for entry in spec:
        if entry is not None:
            n *= _axsize(mesh, entry)
    return 1.0 / n


# ---------------------------------------------------------------------------
# Activations / batches / caches
# ---------------------------------------------------------------------------
def batch_pspecs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh) -> dict:
    """Specs for the step-function input batch (see launch.steps for the
    matching ShapeDtypeStructs)."""
    dp = dp_axes(mesh)
    B = shape.global_batch
    bspec = dp if B % _axsize(mesh, dp) == 0 else None
    if isinstance(bspec, tuple) and len(bspec) == 1:
        bspec = bspec[0]
    tok2 = P(bspec, None)
    specs = {"tokens": tok2}
    if shape.kind == "train":
        specs.update({"loss_mask": tok2, "advantages": tok2,
                      "old_logp": tok2, "ref_logp": tok2, "returns": tok2})
    if cfg.input_mode == "embeddings":
        specs["prefix_embeds"] = P(bspec, None, None)
    if cfg.input_mode == "encdec":
        specs["frame_embeds"] = P(bspec, None, None)
    return specs


def cache_pspecs(model, cfg: ModelConfig, mesh: Mesh, batch: int,
                 strat: ShardingStrategy, cache_shapes) -> list:
    """Decode-cache specs. Batch shards over DP when divisible; for the
    long-context batch=1 case the sequence (capacity) dim of attention
    caches shards over DP instead (sequence-parallel KV)."""
    dp = dp_axes(mesh)
    ndp = _axsize(mesh, dp)
    dpa = dp if len(dp) > 1 else dp[0]
    mp = "model" if (strat.tensor_parallel and "model" in mesh.axis_names) else None
    batch_ok = batch % ndp == 0 and ndp > 1

    def spec_for(path, leaf) -> P:
        shape = leaf.shape  # leading dim = n_groups (stacked)
        name = path[-1]
        b = dpa if batch_ok else None
        def dim_ax(i, ax):
            return ax if (ax and shape[i] % _axsize(mesh, ax if isinstance(ax, tuple) else (ax,)) == 0) else None
        if name in ("k", "v"):          # [G, B, cap, K, hd]
            seq = None if batch_ok else dim_ax(2, dpa)
            kh = dim_ax(3, mp)
            hd = dim_ax(4, mp) if kh is None else None   # kv<TP: shard head_dim
            return P(None, b, seq, kh, hd)
        if name in ("c_kv", "k_rope"):  # [G, B, cap, r] — shard the latent dim
            seq = None if batch_ok else dim_ax(2, dpa)
            return P(None, b, seq, dim_ax(3, mp))
        if name == "pos":               # [G, B, cap]
            seq = None if batch_ok else dim_ax(2, dpa)
            return P(None, b, seq)
        if name == "conv_state":        # [G, B, W-1, C]
            return P(None, b, None, dim_ax(3, mp))
        if name == "ssm_state":         # [G, B, H, P, N]
            return P(None, b, dim_ax(2, mp), None, None)
        return P(*([None] * len(shape)))

    flat = jax.tree_util.tree_flatten_with_path(cache_shapes)[0]
    paths = [tuple(str(getattr(k, "key", k)) for k in kp) for kp, _ in flat]
    leaves = [spec_for(p, l) for p, (_, l) in zip(paths, flat)]
    treedef = jax.tree_util.tree_structure(cache_shapes)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def to_named(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def opt_shardings(mesh: Mesh, opt_spec_tree, strat: ShardingStrategy):
    """NamedShardings for the optimizer state. With
    ``strat.offload_optimizer`` the shardings target the host memory kind
    (when the backend exposes one — see ``kernels.compat``): the optimizer
    moments live on host as *committed device placement*, which is what
    ``MemoryStrategy.scale(tag="opt") == 0`` has been modelling at the
    trace level. Backends without memory kinds fall back to plain device
    shardings; the dynamic alternative there is the runtime parking lot
    (``repro.offload``, ``offload="optimizer"``)."""
    named = to_named(mesh, opt_spec_tree)
    if not strat.offload_optimizer:
        return named
    from repro.kernels.compat import host_memory_kind
    kind = host_memory_kind()
    if kind is None:
        return named
    return jax.tree.map(lambda s: s.with_memory_kind(kind), named,
                        is_leaf=lambda x: isinstance(x, NamedSharding))
