"""Mesh context + activation sharding constraints.

Model code calls ``constrain(x, "dp", None, "model")`` at key activation
points; when no mesh is active (CPU smoke tests) it is a no-op. Entries:
``"dp"`` resolves to the data-parallel axes (``rules.DP_AXIS_NAMES`` —
("pod","data") on the multi-pod mesh), ``"model"`` (``rules.MODEL_AXIS``)
to tensor parallelism. Any entry whose dim is not divisible by the axis
size is dropped (replicated) — this is what lets the same model code lower
on 1-device CPU, 256- and 512-chip meshes.

Division of labour with ``sharding.context`` (one public surface, both
re-exported from ``repro.sharding``): *this* module is the in-jit,
tree-free face — an ambient mesh plus per-activation GSPMD hints that
model code sprinkles without threading a plan around; ``context`` is the
out-of-jit face — ``ShardedContext``/``TreePlan`` build and commit whole
spec trees for params/opt/grads. Both resolve axis names from
``sharding.rules`` (``DP_AXIS_NAMES``/``MODEL_AXIS``), so a ``constrain``
hint and an explicit ``TreePlan`` spec always mean the same devices. The
runtime threads the two together by running its jitted programs under
``use_mesh(shard.mesh)``.
"""
from __future__ import annotations

from contextlib import contextmanager
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.sharding.rules import DP_AXIS_NAMES, MODEL_AXIS

_MESH: Optional[Mesh] = None


def set_current_mesh(mesh: Optional[Mesh]) -> None:
    global _MESH
    _MESH = mesh


def current_mesh() -> Optional[Mesh]:
    return _MESH


@contextmanager
def use_mesh(mesh: Optional[Mesh]):
    """Scoped ambient mesh. ``use_mesh(None)`` is the explicit "no mesh"
    scope (constraints become no-ops) — the unsharded trainer path uses it
    so a leaked global can never bleed into an ndp=1 baseline."""
    global _MESH
    prev = _MESH
    _MESH = mesh
    try:
        yield mesh
    finally:
        _MESH = prev


def _axsize(mesh, axes) -> int:
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def resolve_entry(mesh: Mesh, entry, dim: int):
    """Resolve a hint entry against ``mesh``: "dp" -> the DP axis-name
    subset present (``rules.DP_AXIS_NAMES``), "model" (``rules.MODEL_AXIS``)
    or any literal axis name -> itself; non-divisible or absent -> None
    (replicate). The same names ``ShardedContext`` specs use."""
    if entry is None:
        return None
    if entry == "dp":
        axes = tuple(a for a in DP_AXIS_NAMES if a in mesh.axis_names)
        if not axes:
            return None
        if dim % _axsize(mesh, axes) == 0:
            return axes if len(axes) > 1 else axes[0]
        # try data alone
        if "data" in axes and dim % mesh.shape["data"] == 0:
            return "data"
        return None
    if entry == MODEL_AXIS and MODEL_AXIS not in mesh.axis_names:
        return None
    if entry not in mesh.axis_names:
        return None
    return entry if dim % _axsize(mesh, entry) == 0 else None


def constrain(x: jax.Array, *entries):
    if _MESH is None or x is None:
        return x
    mesh = _MESH
    assert len(entries) == x.ndim, (entries, x.shape)
    spec = P(*(resolve_entry(mesh, e, d) for e, d in zip(entries, x.shape)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# --- per-layer param-slice specs (grad reduce-scatter, §Perf hillclimb C) --
# with_sharding_constraint is its own transpose: constraining the per-layer
# parameter slice inside the scan makes its cotangent (the layer's weight
# gradient) carry the same sharding, so the partitioner reduce-scatters the
# per-layer dW instead of all-reducing it in full.
_SEGMENT_SPECS = None


def set_segment_param_specs(specs) -> None:
    global _SEGMENT_SPECS
    _SEGMENT_SPECS = specs


def segment_param_specs():
    return _SEGMENT_SPECS


def constrain_spec(x, spec):
    """Constrain ``x`` to ``spec``: a bare PartitionSpec resolves against
    the ambient mesh (no-op when none is active); a NamedSharding carries
    its own mesh — the form ``TreePlan.layer_specs`` uses so the per-layer
    ZeRO-3 gather inside the scan body needs no mesh context."""
    if spec is None:
        return x
    if isinstance(spec, NamedSharding):
        return jax.lax.with_sharding_constraint(x, spec)
    if _MESH is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(_MESH, spec))
