"""ShardedContext: the mesh + rule set that makes ZeRO execution real for
the RLHF engines (DESIGN.md §3).

``sharding.rules`` builds PartitionSpecs; this module owns their *runtime*
application for the RLHF trainer: a :class:`ShardedContext` wraps a mesh
and a :class:`~repro.sharding.rules.ShardingStrategy` and hands out
:class:`TreePlan` objects — one per parameter tree (full model trees and
hydra LoRA adapters alike) — that know

  * the **state specs** the tree is stored under between steps (ZeRO-3
    shards params over the DP domain; 1/2 keep them replicated),
  * the **optimizer-state specs** (sharded over DP from ZeRO-1 up, via
    ``zero_opt_pspecs`` + the optimizer's ``init_specs``),
  * the **compute specs** — the state specs with the DP entries stripped
    (tensor-parallel entries survive): what a forward/backward gathers to.

TP composes orthogonally (DESIGN.md §9): with ``strat.ntp > 1`` every
spec set above carries the Megatron column/row "model" entries from
``rules.param_pspecs``/``adapter_pspecs``, and every gather in this module
— ``gather``, ``gather_copy``, the per-layer ``layer_specs`` — moves ONLY
the DP dimension. TP entries are never gathered: the model-sharded layout
IS the compute layout, at every ZeRO stage and in both gather modes.

The execution contract (validated bit-level on forced multi-device CPU,
see ``benchmarks/zero_smoke.py``): step functions gather parameters to the
compute specs *before* any matmul, run the loss/gradient computation on
the gathered (DP-replicated) values, clip on the replicated gradients, and
only then re-shard gradients onto the optimizer layout — a slice, not a
reduction, so every ZeRO stage reproduces the single-device arithmetic to
the last ulp while persistent state lives at 1/ndp per device.

The gather itself comes in two granularities
(``ShardingStrategy.gather_mode``, DESIGN.md §3.7):

  * ``"tree"``  — the whole parameter tree is constrained to the compute
    specs before the forward; the transient HBM peak is the full
    replicated model (what PR 4 shipped);
  * ``"layer"`` — scanned (stacked) leaves stay ZeRO-sharded at the step
    boundary and each ``jax.lax.scan`` iteration constrains only its own
    sliced layer period to the DP-stripped specs (``TreePlan.layer_specs``
    threaded into the scan body by ``Model._stack_fwd``). The gathered
    slice dies when the iteration exits (under remat, the backward
    re-gathers per layer from the saved *sharded* slice), so the
    transient peak is ONE layer period — exactly the ``layer_slice``
    schedule the allocator simulator has always charged ZeRO-3 for.
    Non-stacked leaves (embeddings, lm head, norms, value heads) still
    gather whole: they are touched at both ends of every forward.

Both modes run the same replicated arithmetic inside the scan body, so
they are bit-identical to each other and to the single device.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.sharding.rules import (ShardingStrategy, adapter_pspecs,
                                  param_pspecs, zero_opt_pspecs)

_IS_SPEC = lambda x: isinstance(x, P)


def _constrain(tree, spec_tree, mesh):
    """with_sharding_constraint over a (tree, spec tree) pair — usable
    inside jit; the constraint is its own transpose, so gradients of a
    gathered tree re-shard automatically."""
    return jax.tree.map(
        lambda x, s: jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, s)),
        tree, spec_tree, is_leaf=lambda x: _IS_SPEC(x))


def _place(tree, spec_tree, mesh):
    """Committed device placement (outside jit): ``jax.device_put`` each
    leaf onto its NamedSharding. Re-placing an already-conforming leaf is
    a no-op (same buffers), so this is safe to call idempotently."""
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
        tree, spec_tree, is_leaf=lambda x: _IS_SPEC(x))


def delete_tree(tree) -> None:
    """Deterministically delete every device buffer in ``tree`` (phase
    boundary hygiene for owned copies — see ``TreePlan.gather_copy``)."""
    jax.tree.map(
        lambda x: x.delete()
        if hasattr(x, "delete") and not x.is_deleted() else None, tree)


def tree_per_device_bytes(tree) -> int:
    """Max-over-devices resident bytes of ``tree`` — the number that OOMs.
    Replicated leaves count full size (every device holds a copy); ZeRO-3
    leaves count 1/ndp. Host-committed (numpy) leaves count zero."""
    per: dict = {}
    for leaf in jax.tree.leaves(tree):
        shards = getattr(leaf, "addressable_shards", None)
        if shards is None:
            continue
        for s in shards:
            per[s.device] = per.get(s.device, 0) + s.data.nbytes
    return max(per.values()) if per else 0


@dataclass(frozen=True)
class TreePlan:
    """Sharding plan for one parameter tree (+ its optimizer state)."""
    mesh: Mesh
    strat: ShardingStrategy
    param_specs: Any               # state placement (ZeRO-3: DP-sharded)
    compute_specs: Any             # DP entries stripped (gather target)
    opt_specs: Optional[Any] = None
    # param-shaped layout of the optimizer shards (``zero_opt_pspecs``):
    # the *uniform* sharding every update-program operand — gradients
    # included — is eagerly placed on, so the elementwise optimizer math
    # is partitioned identically for params, grads, and moments. Mixed
    # layouts make XLA fuse (FMA) differently per operand and cost a ulp
    # (DESIGN.md §3).
    update_specs: Optional[Any] = None
    # per-layer gather mode (``ShardingStrategy.gather_mode == "layer"``
    # at ZeRO-3, DESIGN.md §3.7): ``layer_param_specs`` is the full-tree
    # gather target where stacked (scanned) leaves KEEP their sharded
    # state specs and only non-stacked leaves go to compute specs;
    # ``layer_specs`` is the per-segment list of NamedSharding trees for
    # one *sliced* layer period (DP stripped) that ``Model._stack_fwd``
    # constrains inside the scan body — the actual per-iteration
    # all-gather. Both None in "tree" mode / below stage 3.
    layer_param_specs: Optional[Any] = None
    layer_specs: Optional[Any] = None

    @property
    def gather_mode(self) -> str:
        return "layer" if self.layer_param_specs is not None else "tree"

    # ----------------------------------------------------------- in-jit
    def gather(self, params):
        """Constrain ``params`` to the gather target — the per-step
        all-gather of ZeRO-3 (a no-op below stage 3). In layer mode the
        stacked leaves stay sharded here; the per-layer gather happens
        inside the scan body (``layer_specs``)."""
        if self.layer_param_specs is not None:
            return _constrain(params, self.layer_param_specs, self.mesh)
        return _constrain(params, self.compute_specs, self.mesh)

    def place_grads(self, grads):
        """Eager re-shard of DP-identical gradients onto the update layout
        — a committed ``device_put`` slice between the grad and update
        programs, so the layout change can never exert sharding pressure
        on either graph (the bit-identity contract)."""
        if self.update_specs is None:
            return grads
        return _place(grads, self.update_specs, self.mesh)

    def place_update_params(self, params):
        """Params on the update layout: at ZeRO-3 these are the state
        buffers themselves; below, a transient 1/ndp slice copy so the
        update program sees uniformly-sharded operands."""
        if self.update_specs is None:
            return params
        return _place(params, self.update_specs, self.mesh)

    def constrain_update(self, params):
        """Pin param-shaped values to the uniform update layout (a
        same-layout constraint — never a reshard, so codegen-neutral)."""
        if self.update_specs is None:
            return params
        return _constrain(params, self.update_specs, self.mesh)

    def constrain_opt(self, opt):
        if self.opt_specs is None:
            return opt
        return _constrain(opt, self.opt_specs, self.mesh)

    # ------------------------------------------------------ out-of-jit
    def place_params(self, params):
        return _place(params, self.param_specs, self.mesh)

    def place_opt(self, opt):
        if self.opt_specs is None:
            return opt
        return _place(opt, self.opt_specs, self.mesh)

    def place_state(self, state):
        """Place a ``{"params", "opt", "step"}`` train state."""
        out = dict(state)
        out["params"] = self.place_params(state["params"])
        if "opt" in state:
            out["opt"] = self.place_opt(state["opt"])
        return out

    def gather_copy(self, params):
        """Materialize a DP-gathered copy of ``params`` (committed
        ``device_put`` onto the compute shardings) for rollout / merged
        generation. Under TP the copies stay model-sharded — only the DP
        dimension is gathered, so the per-device cost of a rollout copy is
        1/ntp of the tree (the trainer attributes it to the ``tp_gather``
        owner instead of ``zero_gather``). Returns ``(tree, owned)``:

          * ``owned=False`` (below ZeRO-3): the compute specs equal the
            state specs, so the returned tree is the SAME buffers as the
            live state — the caller must NOT delete it;
          * ``owned=True`` (ZeRO-3): every leaf is a fresh buffer the
            caller owns and should ``delete_tree`` at the phase boundary.
            Leaves whose sharding is unchanged (replicated norms, value
            heads) are explicitly copied rather than aliased, so deleting
            the returned tree can never free live state.
        """
        if self.compute_specs is self.param_specs or self.strat.zero_stage < 3:
            return params, False

        def copy_leaf(x, s):
            ns = NamedSharding(self.mesh, s)
            if getattr(x, "sharding", None) is not None and \
                    x.sharding.is_equivalent_to(ns, x.ndim):
                # device_put would be a no-op sharing buffers with the
                # live state; force a real copy so ownership is uniform
                return jnp.copy(x)
            return jax.device_put(x, ns)

        gathered = jax.tree.map(copy_leaf, params, self.compute_specs,
                                is_leaf=lambda x: _IS_SPEC(x))
        # telemetry: real bytes materialized by this gather (the rollout /
        # merged-generation copies) — counted on the process-global
        # registry so the frozen plan needs no telemetry handle threaded
        from repro.obs.metrics import global_registry
        global_registry().counter(
            "sharding_gather_copy_bytes_total",
            "bytes materialized by TreePlan.gather_copy (ZeRO-3 rollout "
            "gathers)").inc(
            sum(getattr(x, "nbytes", 0) for x in jax.tree.leaves(gathered)))
        return gathered, True

    def gathered_bytes(self, params) -> int:
        """Global bytes this plan all-gathers per step at ZeRO-3: the
        leaves whose state spec differs from the compute target. Tree and
        layer gather modes move the same total per step — layer mode just
        stages it one scan period at a time (DESIGN.md §3.7) — so one
        figure serves both; the RLHF trainer multiplies it into the
        ``sharding_step_gathered_bytes_total`` counter per update."""
        if self.strat.zero_stage < 3 or \
                self.compute_specs is self.param_specs:
            return 0
        total = 0

        def add(x, s, c):
            nonlocal total
            if s != c:
                total += getattr(x, "nbytes", 0)

        jax.tree.map(add, params, self.param_specs, self.compute_specs)
        return total

    # (per-device byte *accounting* lives in core.strategies —
    # ``traced_zero_scales`` / ``_tree_fraction`` — so the simulator and
    # the runtime read one implementation)


class ShardedContext:
    """Mesh + ZeRO strategy, threaded through trainer / engine / steps."""

    def __init__(self, mesh: Mesh, strat: Optional[ShardingStrategy] = None):
        self.mesh = mesh
        self.strat = strat or ShardingStrategy()

    @classmethod
    def create(cls, ndp: int = 1, *, zero_stage: int = 3, model: int = 1,
               gather_mode: str = "layer",
               devices=None) -> "ShardedContext":
        """Build a ``(data=ndp, model=ntp)`` mesh from the first
        ``ndp * model`` local devices (so an 8-device process can host both
        the ndp=1 baseline and the ndp=8 sharded run). ``model`` is the TP
        degree: the strategy records it as ``ntp`` so every spec the
        context emits partitions over dp x tp (DESIGN.md §9). Callers with
        a concrete ModelConfig should run ``rules.validate_tp(cfg, model)``
        first for the friendly divisibility error."""
        from repro.launch.mesh import make_zero_mesh
        assert gather_mode in ("layer", "tree"), gather_mode
        mesh = make_zero_mesh(ndp, model=model, devices=devices)
        # model == 1 keeps tensor_parallel off so the size-1 "model" axis
        # never decorates specs — the pre-TP (pure-ZeRO) spec trees, and
        # their bit-identity contract, are byte-for-byte unchanged
        return cls(mesh, ShardingStrategy(zero_stage=zero_stage,
                                          tensor_parallel=model > 1,
                                          ntp=model,
                                          gather_mode=gather_mode))

    @property
    def ndp(self) -> int:
        from repro.sharding.rules import _axsize, dp_axes
        return _axsize(self.mesh, dp_axes(self.mesh))

    @property
    def ntp(self) -> int:
        """Runtime TP degree — the mesh's "model" axis size (1 without)."""
        return dict(self.mesh.shape).get("model", 1)

    @property
    def zero_stage(self) -> int:
        return self.strat.zero_stage

    # ------------------------------------------------------------- plans
    def _plan(self, pspecs, shapes, optimizer, *,
              layerwise: bool = False) -> TreePlan:
        strat = self.strat
        opt_specs = update_specs = None
        if optimizer is not None:
            base = zero_opt_pspecs(pspecs, shapes, self.mesh, strat)
            opt_specs = optimizer.init_specs(base, shapes)
            # optimizers with element-crossing reductions (adafactor)
            # override the param-shaped update layout (DESIGN.md §3.3)
            upd = getattr(optimizer, "update_pspecs", None)
            update_specs = upd(base, shapes) if upd is not None else base
        compute = jax.tree.map(
            lambda s: _strip_dp(s, self.mesh), pspecs,
            is_leaf=_IS_SPEC) if strat.zero_stage >= 3 else pspecs
        layer_full = layer_slices = None
        if layerwise and strat.zero_stage >= 3 and \
                strat.gather_mode == "layer":
            layer_full, layer_slices = _layer_specs(pspecs, self.mesh)
        return TreePlan(self.mesh, strat, pspecs, compute,
                        opt_specs, update_specs,
                        layer_param_specs=layer_full,
                        layer_specs=layer_slices)

    def plan_params(self, cfg, params_shape, optimizer=None) -> TreePlan:
        """Plan for a full model tree (``rules.param_pspecs``).

        Per-layer gathers require every stacked leaf to be touched ONLY
        inside the scan body. Encoder-decoder models break that premise:
        ``Model._cross_kvs`` vmaps over the stacked decoder cross-attn
        weights before the scan, which under layer specs would all-gather
        them in-graph (a bit-identity hazard per DESIGN.md §3 rule 2) and
        re-materialize the whole stacked set at once. Those configs fall
        back to whole-tree gathers."""
        pspecs = param_pspecs(cfg, self.mesh, self.strat, params_shape)
        layerwise = getattr(cfg, "input_mode", "tokens") != "encdec"
        return self._plan(pspecs, params_shape, optimizer,
                          layerwise=layerwise)

    def plan_adapter(self, adapter_shape, optimizer=None) -> TreePlan:
        """Plan for a hydra LoRA adapter tree (``rules.adapter_pspecs``).
        Adapters always gather whole-tree: the per-role trees are
        paper-small, so the per-layer discipline buys nothing there."""
        pspecs = adapter_pspecs(self.mesh, self.strat, adapter_shape)
        return self._plan(pspecs, adapter_shape, optimizer)


def _layer_specs(pspecs, mesh):
    """Split a full-tree spec dict into the layer-gather pair
    ``(layer_param_specs, layer_specs)`` — see :class:`TreePlan`.

    Stacked decoder segments (top-level ``segment{i}`` keys — the trees
    ``jax.lax.scan`` slices per iteration) keep their sharded state specs
    in the full-tree target and contribute one *sliced* spec tree each
    (leading scan entry dropped, DP stripped, wrapped as NamedShardings so
    the scan body can constrain without a mesh context). Everything else
    — embeddings, lm head, final norm, value heads and the MTP head —
    gathers whole via DP-stripped compute specs. (Encoder-decoder
    configs never reach here: ``plan_params`` falls back to whole-tree
    gathers because ``_cross_kvs`` touches stacked decoder weights
    outside the scan.)"""
    if not isinstance(pspecs, dict):
        return None, None
    seg_keys = sorted((k for k in pspecs if k.startswith("segment")),
                      key=lambda k: int(k[len("segment"):]))
    if not seg_keys:
        return None, None
    full = {}
    for k, sub in pspecs.items():
        if k in seg_keys:
            full[k] = sub            # stays ZeRO-sharded at the boundary
        else:
            full[k] = jax.tree.map(lambda s: _strip_dp(s, mesh), sub,
                                   is_leaf=_IS_SPEC)

    real_mesh = isinstance(mesh, Mesh)   # SpecMesh (devices-free) keeps
    # bare PartitionSpecs — spec-level tests and traced accounting only

    def slice_spec(s: P):
        sp = _strip_dp(P(*tuple(s)[1:]), mesh)
        return NamedSharding(mesh, sp) if real_mesh else sp

    slices = [jax.tree.map(slice_spec, pspecs[k], is_leaf=_IS_SPEC)
              for k in seg_keys]
    return full, slices


def _strip_dp(spec: P, mesh) -> P:
    """Remove DP/FSDP axes from a spec, keeping tensor-parallel entries —
    the compute layout a ZeRO-3 gather targets."""
    from repro.sharding.rules import dp_axes
    dp = set(dp_axes(mesh))

    def keep(entry):
        if entry is None:
            return None
        es = entry if isinstance(entry, tuple) else (entry,)
        kept = tuple(e for e in es if e not in dp)
        if not kept:
            return None
        return kept if len(kept) > 1 else kept[0]

    return P(*(keep(e) for e in spec))
