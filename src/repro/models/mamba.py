"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) block in pure JAX.

Full-sequence path uses the chunked SSD algorithm (quadratic within a chunk
on the MXU, linear across chunks); decode is the O(1)-state recurrence. The
chunk-scan hot loop also exists as a Pallas TPU kernel
(repro.kernels.ssd_scan) validated against :func:`ssd_chunked` here.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SSMConfig
from repro.models.layers import _init, rms_norm
from repro.sharding import ctx


def segsum(x: jax.Array) -> jax.Array:
    """x [..., L] -> [..., L, L] where out[i,j] = sum_{j<k<=i} x[k], -inf above
    the diagonal (diagonal itself is 0)."""
    L = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool))
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(x, a, b, c, chunk: int,
                initial_state: Optional[jax.Array] = None,
                use_kernel: bool = False):
    """Chunked SSD.

    x [B,S,H,P] (pre-multiplied by dt), a [B,S,H] (= dt * A, log-decay
    increments, <= 0), b/c [B,S,N] (single group shared across heads).
    Returns (y [B,S,H,P], final_state [B,H,P,N]).
    """
    if use_kernel:
        from repro.kernels import ops as kops
        return kops.ssd_scan(x, a, b, c, chunk, initial_state)
    B, S, H, P = x.shape
    N = b.shape[-1]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    xc = x.reshape(B, nc, chunk, H, P)
    bc = b.reshape(B, nc, chunk, N)
    cc = c.reshape(B, nc, chunk, N)
    ac = a.reshape(B, nc, chunk, H).transpose(0, 3, 1, 2)     # [B,H,nc,L]
    ac = ac.astype(jnp.float32)
    a_cum = jnp.cumsum(ac, axis=-1)

    # 1. intra-chunk (diagonal blocks)
    L = jnp.exp(segsum(ac))                                    # [B,H,nc,l,l]
    y_diag = jnp.einsum("bcln,bcsn,bhcls,bcshp->bclhp",
                        cc, bc, L.astype(x.dtype), xc)

    # 2. per-chunk final states
    decay_states = jnp.exp(a_cum[..., -1:] - a_cum)            # [B,H,nc,l]
    states = jnp.einsum("bcln,bhcl,bclhp->bchpn",
                        bc, decay_states.astype(x.dtype), xc)

    # 3. inter-chunk recurrence (matmul form over the chunk axis)
    if initial_state is None:
        initial_state = jnp.zeros((B, H, P, N), x.dtype)
    chunk_decay = a_cum[..., -1]                               # [B,H,nc]
    padded = jnp.pad(chunk_decay, ((0, 0), (0, 0), (1, 0)))
    decay_chunk = jnp.exp(segsum(padded))                      # [B,H,nc+1,nc+1]
    states_cat = jnp.concatenate([initial_state[:, None], states], axis=1)
    new_states = jnp.einsum("bhzc,bchpn->bzhpn",
                            decay_chunk.astype(x.dtype), states_cat)
    prev_states, final_state = new_states[:, :-1], new_states[:, -1]

    # 4. state -> output within each chunk
    state_decay_out = jnp.exp(a_cum)                           # [B,H,nc,l]
    y_off = jnp.einsum("bcln,bchpn,bhcl->bclhp",
                       cc, prev_states, state_decay_out.astype(x.dtype))
    y = (y_diag + y_off).reshape(B, S, H, P)
    return y, final_state


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------
def init_mamba(key, cfg: ModelConfig, dtype) -> dict:
    s: SSMConfig = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    nh = s.n_heads(d)
    conv_dim = di + 2 * s.d_state
    ks = jax.random.split(key, 4)
    dt = jnp.exp(jax.random.uniform(ks[2], (nh,)) *
                 (math.log(0.1) - math.log(0.001)) + math.log(0.001))
    return {
        "in_proj": _init(ks[0], (d, 2 * di + 2 * s.d_state + nh), dtype=dtype),
        "conv_w": _init(ks[1], (s.d_conv, conv_dim), scale=0.2, dtype=dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "dt_bias": (dt + jnp.log(-jnp.expm1(-dt))).astype(jnp.float32),
        "A_log": jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32)),
        "D": jnp.ones((nh,), jnp.float32),
        "norm": jnp.ones((di,), dtype),
        "out_proj": _init(ks[3], (di, d),
                          scale=0.02 / math.sqrt(2 * cfg.num_layers), dtype=dtype),
    }


def _causal_conv(xbc: jax.Array, w: jax.Array, bias: jax.Array,
                 prepend: Optional[jax.Array] = None):
    """Depthwise causal conv. xbc [B,S,C], w [W,C]. Returns (y, tail) where
    tail is the last W-1 inputs (the decode conv state)."""
    W = w.shape[0]
    if prepend is None:
        prepend = jnp.zeros((xbc.shape[0], W - 1, xbc.shape[2]), xbc.dtype)
    full = jnp.concatenate([prepend, xbc], axis=1)             # [B,S+W-1,C]
    y = sum(full[:, i:i + xbc.shape[1]] * w[i] for i in range(W))
    tail = full[:, -(W - 1):] if W > 1 else full[:, :0]
    return y + bias, tail


def _split_zxbcdt(z_xbc_dt, di: int, n: int, nh: int):
    z = z_xbc_dt[..., :di]
    xbc = z_xbc_dt[..., di:2 * di + 2 * n]
    dt = z_xbc_dt[..., 2 * di + 2 * n:]
    return z, xbc, dt


def mamba_fwd(params, x, cfg: ModelConfig, *, return_state: bool = False,
              use_kernel: bool = False):
    """Full-sequence forward. x [B,S,D] -> y [B,S,D] (and optionally the
    decode cache {conv_state, ssm_state})."""
    s: SSMConfig = cfg.ssm
    d = cfg.d_model
    di, n, nh, p = s.d_inner(d), s.d_state, s.n_heads(d), s.head_dim
    B, S, _ = x.shape
    z, xbc, dt = _split_zxbcdt(x @ params["in_proj"], di, n, nh)
    xbc, conv_tail = _causal_conv(xbc, params["conv_w"], params["conv_b"])
    xbc = jax.nn.silu(xbc)
    xs = xbc[..., :di].reshape(B, S, nh, p)
    xs = ctx.constrain(xs, "dp", None, "model", None)   # heads over TP
    b_mat = xbc[..., di:di + n]
    c_mat = xbc[..., di + n:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])                              # [nh]
    y, final_state = ssd_chunked(
        xs * dt.astype(xs.dtype)[..., None], dt * A, b_mat, c_mat,
        min(s.chunk_size, S), use_kernel=use_kernel)
    y = y + params["D"].astype(y.dtype)[None, None, :, None] * xs
    y = y.reshape(B, S, di)
    y = rms_norm(y * jax.nn.silu(z), {"scale": params["norm"]}, cfg.norm_eps)
    out = y @ params["out_proj"]
    if return_state:
        return out, {"conv_state": conv_tail, "ssm_state": final_state}
    return out


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    s: SSMConfig = cfg.ssm
    d = cfg.d_model
    di, n, nh, p = s.d_inner(d), s.d_state, s.n_heads(d), s.head_dim
    return {
        "conv_state": jnp.zeros((batch, s.d_conv - 1, di + 2 * n), dtype),
        "ssm_state": jnp.zeros((batch, nh, p, n), dtype),
    }


def mamba_decode(params, x, cache, cfg: ModelConfig):
    """One-token recurrent step. x [B,1,D] -> (y [B,1,D], new_cache)."""
    s: SSMConfig = cfg.ssm
    d = cfg.d_model
    di, n, nh, p = s.d_inner(d), s.d_state, s.n_heads(d), s.head_dim
    B = x.shape[0]
    z, xbc_t, dt = _split_zxbcdt(x[:, 0] @ params["in_proj"], di, n, nh)
    window = jnp.concatenate([cache["conv_state"], xbc_t[:, None]], axis=1)
    conv = jnp.einsum("bwc,wc->bc", window, params["conv_w"]) + params["conv_b"]
    conv = jax.nn.silu(conv)
    new_conv_state = window[:, 1:]
    xs = conv[..., :di].reshape(B, nh, p)
    b_vec = conv[..., di:di + n]
    c_vec = conv[..., di + n:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])   # [B,nh]
    A = -jnp.exp(params["A_log"])
    dA = jnp.exp(dt * A)                                        # [B,nh]
    upd = (dt.astype(xs.dtype)[..., None] * xs)[..., None] * b_vec[:, None, None, :]
    new_state = cache["ssm_state"] * dA[..., None, None].astype(xs.dtype) + upd
    y = jnp.einsum("bhpn,bn->bhp", new_state, c_vec)
    y = y + params["D"].astype(y.dtype)[None, :, None] * xs
    y = y.reshape(B, di)
    y = rms_norm(y * jax.nn.silu(z), {"scale": params["norm"]}, cfg.norm_eps)
    out = (y @ params["out_proj"])[:, None]
    return out, {"conv_state": new_conv_state, "ssm_state": new_state}
