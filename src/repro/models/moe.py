"""Mixture-of-Experts FFN with shard-local sort-based dispatch.

TPU-native adaptation (DESIGN.md §2): a GShard one-hot dispatch tensor is
O(T*E*C); a *global* sort/gather forces XLA to all-gather every token to
every device. Instead we expose the data-parallel sharding to the routing
math: tokens [T, D] are viewed as [n_dp_shards, T_local, D] (the leading
axis laid out on the dp mesh axes), routing/sort/scatter are vmapped over
that axis so they stay shard-local, and the only cross-device movement is
the (dp-sharded tokens) -> (model-sharded experts) all-to-all implied by the
expert matmul sharding. Experts run as one batched MXU matmul
[s, E, C_local, D] x [E, D, F].

Capacity dropping is per shard: C_local = ceil(T_local * k / E * cf).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig
from repro.models.layers import _init, init_mlp, mlp_fwd
from repro.sharding import ctx


def init_moe(key, cfg: ModelConfig, dtype) -> dict:
    e: MoEConfig = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    p = {
        "router": _init(ks[0], (d, e.num_experts), scale=0.02, dtype=jnp.float32),
        "w_in": _init(ks[1], (e.num_experts, d, e.d_expert), dtype=dtype),
        "w_gate": _init(ks[2], (e.num_experts, d, e.d_expert), dtype=dtype),
        "w_out": _init(ks[3], (e.num_experts, e.d_expert, d),
                       scale=0.02 / math.sqrt(2 * cfg.num_layers), dtype=dtype),
    }
    if e.num_shared_experts:
        p["shared"] = init_mlp(ks[4], d, e.num_shared_experts * e.d_expert,
                               True, cfg.num_layers, dtype)
    return p


def router_topk(logits: jax.Array, k: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """logits [T, E] fp32 -> (gates [T,k], idx [T,k], aux_loss scalar)."""
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance loss: E * sum_e fraction_e * prob_e
    E = logits.shape[-1]
    me = probs.mean(0)                                       # mean prob per expert
    ce = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(1.0)
    ce = ce / idx.size
    aux = E * jnp.sum(me * ce)
    return gates, idx, aux


def _num_dp_shards(T: int) -> int:
    mesh = ctx.current_mesh()
    if mesh is None:
        return 1
    n = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            n *= mesh.shape[a]
    return n if (n > 1 and T % n == 0) else 1


def _route_local(xf, router_w, k: int, C: int):
    """Shard-local routing. xf [Tl, D] -> (gathered [E*C+1 rows worth of
    indices], ...). Returns (dest [Tl*k], src_token [Tl*k], gate [Tl*k],
    keep [Tl*k], aux)."""
    E = router_w.shape[-1]
    logits = xf.astype(jnp.float32) @ router_w
    gates, idx, aux = router_topk(logits, k)
    Tl = xf.shape[0]
    token_idx = jnp.repeat(jnp.arange(Tl), k)
    expert_idx = idx.reshape(-1)
    gate_flat = gates.reshape(-1)
    order = jnp.argsort(expert_idx)
    sorted_expert = expert_idx[order]
    sorted_token = token_idx[order]
    sorted_gate = gate_flat[order]
    counts = jnp.zeros((E,), jnp.int32).at[expert_idx].add(1)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(Tl * k) - starts[sorted_expert]
    keep = pos < C
    dest = jnp.where(keep, sorted_expert * C + pos, E * C)   # OOB rows dropped
    return dest, sorted_token, sorted_gate, keep, aux


def moe_fwd(params, x, cfg: ModelConfig, *,
            capacity_factor: Optional[float] = None):
    """x [B, S, D] -> (y [B, S, D], aux_loss).

    Uses the shard_map expert-parallel path (explicit all-to-all) whenever
    the token grid tiles the mesh; falls back to the single-shard sort-based
    dispatch for small/decode shapes and meshless CPU runs."""
    e: MoEConfig = cfg.moe
    if capacity_factor is None:
        capacity_factor = e.capacity_factor
    B, S, D = x.shape
    from repro.models import moe_shard_map as msm
    if msm.usable(cfg, B, S):
        y, aux = msm.moe_fwd_shard_map(params, x, cfg,
                                       capacity_factor=capacity_factor)
        if e.num_shared_experts:
            y = y + mlp_fwd(params["shared"], x, True)
        return y, aux
    T = B * S
    ndp = _num_dp_shards(T)
    Tl = T // ndp
    k, E = e.top_k, e.num_experts
    C = int(math.ceil(Tl * k / E * capacity_factor))
    C = max(4, -(-C // 4) * 4)

    xs = x.reshape(ndp, Tl, D)
    xs = ctx.constrain(xs, "dp", None, None)
    dest, src, gate, keep, aux = jax.vmap(
        lambda xf: _route_local(xf, params["router"], k, C))(xs)

    sidx = jnp.arange(ndp)[:, None]
    # dispatch: batched gather, row dim sharded over model (rows are
    # expert-sorted, so this pre-stages the all-to-all locality)
    xk = jnp.take_along_axis(xs, src[..., None], axis=1)     # [s, Tl*k, D]
    xk = ctx.constrain(xk, "dp", None, None)
    gathered = jnp.zeros((ndp, E * C, D), x.dtype).at[
        sidx, dest].set(xk, mode="drop")
    ge = gathered.reshape(ndp, E, C, D)
    # dp-sharded on s; expert-parallel on E when divisible (else C over model)
    if _expert_parallel_ok(E):
        ge = ctx.constrain(ge, "dp", "model", None, None)
    else:
        ge = ctx.constrain(ge, "dp", None, "model", None)
    h = jnp.einsum("secd,edf->secf", ge, params["w_in"])
    g = jnp.einsum("secd,edf->secf", ge, params["w_gate"])
    out_e = jnp.einsum("secf,efd->secd", jax.nn.silu(g) * h, params["w_out"])
    out_rows = out_e.reshape(ndp, E * C, D)

    contrib = jnp.take_along_axis(
        out_rows, jnp.minimum(dest, E * C - 1)[..., None], axis=1)
    contrib = ctx.constrain(contrib, "dp", None, None)
    contrib = contrib * (gate * keep).astype(x.dtype)[..., None]
    y = jnp.zeros((ndp, Tl, D), x.dtype).at[sidx, src].add(contrib)
    y = ctx.constrain(y, "dp", None, None).reshape(B, S, D)

    if e.num_shared_experts:
        y = y + mlp_fwd(params["shared"], x, True)
    return y, aux.mean() * e.router_aux_coef


def _expert_parallel_ok(E: int) -> bool:
    mesh = ctx.current_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return False
    return E % mesh.shape["model"] == 0
