"""Core transformer layers: RMSNorm, RoPE, GQA attention (+QKV bias,
sliding window, KV cache), DeepSeek MLA, and (gated) MLPs.

All functions are pure; parameters are plain dicts of jnp arrays. Projection
weights are stored with *fused* head dims (``[d_model, heads*head_dim]``) so
that tensor-parallel sharding over the ``model`` mesh axis stays divisible
even when the head count is not (e.g. granite's 24 heads on a 16-way axis) —
see DESIGN.md §6.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import MLAConfig, ModelConfig
from repro.models.lora import lora_delta


def _init(key, shape, scale=0.02, dtype=jnp.float32):
    return (scale * jax.random.normal(key, shape, dtype=jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------
def init_norm(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype=dtype)}


def rms_norm(x: jax.Array, params: dict, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * params["scale"].astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings (NeoX-style half rotation)
# ---------------------------------------------------------------------------
def rope_tables(positions: jax.Array, dim: int, theta: float):
    """positions [..., S] -> (sin, cos) of shape [..., S, dim/2], fp32."""
    inv_freq = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    angles = positions.astype(jnp.float32)[..., None] * inv_freq
    return jnp.sin(angles), jnp.cos(angles)


def apply_rope(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """x [B, S, H, D]; sin/cos [B, S, D/2]."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    sin = sin[:, :, None, :]
    cos = cos[:, :, None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(dtype)


# ---------------------------------------------------------------------------
# Masked softmax attention core (shared by full-seq and decode paths)
# ---------------------------------------------------------------------------
def sdpa(q, k, v, mask, use_kernel: bool = False):
    """q [B,Sq,H,D], k/v [B,Sk,K,D] with H % K == 0; mask [B,1|H,Sq,Sk] bool.
    Softmax in fp32. (Kernel routing happens in attn_core / decode paths,
    where masks are structural.)"""
    B, Sq, H, D = q.shape
    K = k.shape[2]
    group = H // K
    qg = q.reshape(B, Sq, K, group, D)
    logits = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32)
    logits = logits / math.sqrt(D)
    m = mask[:, :, None] if mask.shape[1] == 1 else mask.reshape(B, K, group, Sq, -1)
    logits = jnp.where(m, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(B, Sq, H, v.shape[-1])   # v head dim may differ (MLA)


FLASH_MIN_ELEMS = 1 << 20   # use flash path when Sq*Sk exceeds this (mutable)


def attn_core(q, k, v, *, causal: bool, window: int = 0,
              use_kernel: bool = False):
    """Structural-mask attention. ``use_kernel`` routes to the Pallas flash
    kernel (interpret mode on CPU); otherwise large score matrices take the
    XLA flash twin and small ones the dense softmax."""
    Sq, Sk = q.shape[1], k.shape[1]
    if use_kernel and Sq > 1:
        from repro.kernels import ops as kops
        return kops.flash_attention(q, k, v, causal=causal, window=window)
    if Sq > 1 and Sq * Sk >= FLASH_MIN_ELEMS:
        from repro.models.flash import flash_sdpa
        return flash_sdpa(q, k, v, causal, window, min(1024, max(Sk, 16)))
    mask = causal_mask(Sq, Sk, window=window) if causal else \
        jnp.ones((1, 1, Sq, Sk), bool)
    mask = jnp.broadcast_to(mask, (q.shape[0], 1, Sq, Sk))
    return sdpa(q, k, v, mask)


def causal_mask(Sq: int, Sk: int, q_offset: int = 0, window: int = 0) -> jax.Array:
    """[1, 1, Sq, Sk] causal (optionally sliding-window) mask."""
    qi = jnp.arange(Sq)[:, None] + q_offset
    kj = jnp.arange(Sk)[None, :]
    m = kj <= qi
    if window:
        m &= kj > qi - window
    return m[None, None]


# ---------------------------------------------------------------------------
# GQA attention layer
# ---------------------------------------------------------------------------
def init_attention(key, cfg: ModelConfig, dtype, cross: bool = False) -> dict:
    d, h, kvh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads
    hd = cfg.resolved_head_dim()
    ks = jax.random.split(key, 4)
    p = {
        "wq": _init(ks[0], (d, h * hd), dtype=dtype),
        "wk": _init(ks[1], (d, kvh * hd), dtype=dtype),
        "wv": _init(ks[2], (d, kvh * hd), dtype=dtype),
        "wo": _init(ks[3], (h * hd, d), scale=0.02 / math.sqrt(2 * cfg.num_layers),
                    dtype=dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dtype)
        p["bk"] = jnp.zeros((kvh * hd,), dtype)
        p["bv"] = jnp.zeros((kvh * hd,), dtype)
    return p


def _project_qkv(params, x, cfg: ModelConfig, kv_x=None, adapter=None):
    B, S, _ = x.shape
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim()
    kv_x = x if kv_x is None else kv_x
    Skv = kv_x.shape[1]
    ad = adapter or {}
    q = x @ params["wq"] + lora_delta(x, ad.get("wq"))
    k = kv_x @ params["wk"] + lora_delta(kv_x, ad.get("wk"))
    v = kv_x @ params["wv"] + lora_delta(kv_x, ad.get("wv"))
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    return (q.reshape(B, S, h, hd), k.reshape(B, Skv, kvh, hd),
            v.reshape(B, Skv, kvh, hd))


def _fill_cache(init_cache, entries, positions):
    """Write the last min(S, capacity) per-position entries into a rolling
    cache. ``entries``: dict name -> [B,S,...] tensors; positions [B,S]."""
    B, S = positions.shape
    any_buf = next(iter(init_cache.values()))
    cap = any_buf.shape[1]
    n = min(S, cap)
    slots = (positions[:, -n:] % cap).astype(jnp.int32)
    bi = jnp.arange(B)[:, None]
    new = {k: init_cache[k].at[bi, slots].set(v[:, -n:])
           for k, v in entries.items()}
    new["pos"] = init_cache["pos"].at[bi, slots].set(
        positions[:, -n:].astype(jnp.int32))
    return new


def attention_fwd(params, x, positions, cfg: ModelConfig, *,
                  window: int = 0, use_kernel: bool = False,
                  init_cache: Optional[dict] = None, adapter=None):
    """Full-sequence (train / prefill) self-attention. With ``init_cache``
    also returns the filled rolling KV cache (single-pass prefill)."""
    q, k, v = _project_qkv(params, x, cfg, adapter=adapter)
    sin, cos = rope_tables(positions, cfg.resolved_head_dim(), cfg.rope_theta)
    q = apply_rope(q, sin, cos)
    k = apply_rope(k, sin, cos)
    out = attn_core(q, k, v, causal=True, window=window,
                    use_kernel=use_kernel)
    out = out.reshape(x.shape[0], x.shape[1], -1)
    out = out @ params["wo"] + lora_delta(out, (adapter or {}).get("wo"))
    if init_cache is None:
        return out
    return out, _fill_cache(init_cache, {"k": k, "v": v}, positions)


def cross_attention_fwd(params, x, enc_kv, cfg: ModelConfig) -> jax.Array:
    """Cross-attention: k/v precomputed from encoder output ([B,Se,K,hd] x2)."""
    B, S, _ = x.shape
    h, hd = cfg.num_heads, cfg.resolved_head_dim()
    q = (x @ params["wq"])
    if cfg.qkv_bias:
        q = q + params["bq"]
    q = q.reshape(B, S, h, hd)
    k, v = enc_kv
    out = attn_core(q, k, v, causal=False)
    return out.reshape(B, S, -1) @ params["wo"]


def encode_cross_kv(params, enc_out, cfg: ModelConfig):
    B, Se, _ = enc_out.shape
    kvh, hd = cfg.num_kv_heads, cfg.resolved_head_dim()
    k = enc_out @ params["wk"]
    v = enc_out @ params["wv"]
    if cfg.qkv_bias:
        k, v = k + params["bk"], v + params["bv"]
    return k.reshape(B, Se, kvh, hd), v.reshape(B, Se, kvh, hd)


# --- KV cache ---------------------------------------------------------------
def init_kv_cache(cfg: ModelConfig, batch: int, capacity: int, dtype) -> dict:
    kvh, hd = cfg.num_kv_heads, cfg.resolved_head_dim()
    return {
        "k": jnp.zeros((batch, capacity, kvh, hd), dtype),
        "v": jnp.zeros((batch, capacity, kvh, hd), dtype),
        # absolute position stored in each slot; -1 = empty
        "pos": jnp.full((batch, capacity), -1, jnp.int32),
    }


def attention_decode(params, x, position, cache, cfg: ModelConfig, *,
                     window: int = 0, use_kernel: bool = False,
                     adapter=None):
    """One-token decode. x [B,1,D], position [B] absolute. Rolling buffer:
    slot = position % capacity (capacity == window for the long-context
    path). Returns (out [B,1,D], new_cache)."""
    B = x.shape[0]
    cap = cache["k"].shape[1]
    q, k, v = _project_qkv(params, x, cfg, adapter=adapter)
    sin, cos = rope_tables(position[:, None], cfg.resolved_head_dim(),
                           cfg.rope_theta)
    q = apply_rope(q, sin, cos)
    k = apply_rope(k, sin, cos)
    slot = (position % cap).astype(jnp.int32)
    bidx = jnp.arange(B)
    new_k = cache["k"].at[bidx, slot].set(k[:, 0])
    new_v = cache["v"].at[bidx, slot].set(v[:, 0])
    new_pos = cache["pos"].at[bidx, slot].set(position.astype(jnp.int32))
    if use_kernel:
        # Pallas flash-decode kernel: data-driven masking from the cache's
        # per-slot positions (rolling buffer + window handled in-kernel)
        from repro.kernels import ops as kops
        out = kops.decode_attention(q[:, 0], new_k, new_v, new_pos, position,
                                    window=window)[:, None]
    else:
        valid = new_pos >= 0
        valid &= new_pos <= position[:, None]
        if window:
            valid &= new_pos > (position[:, None] - window)
        mask = valid[:, None, None, :]  # [B,1,1,cap]
        out = sdpa(q, new_k, new_v, mask)
    out = out.reshape(B, 1, -1)
    out = out @ params["wo"] + lora_delta(out, (adapter or {}).get("wo"))
    return out, {"k": new_k, "v": new_v, "pos": new_pos}


def attention_decode_multi(params, x, positions, cache, cfg: ModelConfig, *,
                           window: int = 0, adapter=None):
    """T-token decode (speculative-decode verify). x [B,T,D], positions
    [B,T] absolute (consecutive per row; -1 rows write a dead entry that
    stays masked). All T K/V entries are scattered first, then every query
    attends over the updated rolling buffer with per-query position masks —
    so draft token j IS context for draft token j+1, exactly as if the T
    tokens had been decoded sequentially. Returns (out [B,T,D], new_cache)."""
    B, T = x.shape[:2]
    cap = cache["k"].shape[1]
    q, k, v = _project_qkv(params, x, cfg, adapter=adapter)
    sin, cos = rope_tables(positions, cfg.resolved_head_dim(), cfg.rope_theta)
    q = apply_rope(q, sin, cos)
    k = apply_rope(k, sin, cos)
    slots = (positions % cap).astype(jnp.int32)              # [B, T]
    bi = jnp.arange(B)[:, None]
    new_k = cache["k"].at[bi, slots].set(k)
    new_v = cache["v"].at[bi, slots].set(v)
    new_pos = cache["pos"].at[bi, slots].set(positions.astype(jnp.int32))
    valid = (new_pos[:, None, :] >= 0) \
        & (new_pos[:, None, :] <= positions[:, :, None])     # [B, T, cap]
    if window:
        valid &= new_pos[:, None, :] > (positions[:, :, None] - window)
    out = sdpa(q, new_k, new_v, valid[:, None])              # mask [B,1,T,cap]
    out = out.reshape(B, T, -1)
    out = out @ params["wo"] + lora_delta(out, (adapter or {}).get("wo"))
    return out, {"k": new_k, "v": new_v, "pos": new_pos}


# ---------------------------------------------------------------------------
# DeepSeek-V3 Multi-head Latent Attention (MLA)
# ---------------------------------------------------------------------------
def init_mla(key, cfg: ModelConfig, dtype) -> dict:
    m: MLAConfig = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 5)
    return {
        "q_down": _init(ks[0], (d, m.q_lora_rank), dtype=dtype),
        "q_norm": init_norm(m.q_lora_rank, dtype),
        "q_up": _init(ks[1], (m.q_lora_rank, h * qk_hd), dtype=dtype),
        "kv_down": _init(ks[2], (d, m.kv_lora_rank + m.qk_rope_head_dim), dtype=dtype),
        "kv_norm": init_norm(m.kv_lora_rank, dtype),
        "kv_up": _init(ks[3], (m.kv_lora_rank, h * (m.qk_nope_head_dim + m.v_head_dim)),
                       dtype=dtype),
        "wo": _init(ks[4], (h * m.v_head_dim, d),
                    scale=0.02 / math.sqrt(2 * cfg.num_layers), dtype=dtype),
    }


def _mla_q(params, x, positions, cfg):
    m: MLAConfig = cfg.mla
    B, S, _ = x.shape
    h = cfg.num_heads
    qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
    ql = rms_norm(x @ params["q_down"], params["q_norm"], cfg.norm_eps)
    q = (ql @ params["q_up"]).reshape(B, S, h, qk_hd)
    q_nope, q_rope = q[..., :m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    sin, cos = rope_tables(positions, m.qk_rope_head_dim, cfg.rope_theta)
    q_rope = apply_rope(q_rope, sin, cos)
    return q_nope, q_rope


def _mla_kv_from_latent(params, c_kv, cfg):
    """Expand latent [B,S,r] into per-head K_nope and V."""
    m: MLAConfig = cfg.mla
    B, S, _ = c_kv.shape
    h = cfg.num_heads
    kv = (c_kv @ params["kv_up"]).reshape(B, S, h, m.qk_nope_head_dim + m.v_head_dim)
    return kv[..., :m.qk_nope_head_dim], kv[..., m.qk_nope_head_dim:]


def mla_fwd(params, x, positions, cfg: ModelConfig, *, window: int = 0,
            init_cache: Optional[dict] = None):
    m: MLAConfig = cfg.mla
    B, S, _ = x.shape
    q_nope, q_rope = _mla_q(params, x, positions, cfg)
    down = x @ params["kv_down"]
    c_kv = rms_norm(down[..., :m.kv_lora_rank], params["kv_norm"], cfg.norm_eps)
    k_rope = down[..., m.kv_lora_rank:][:, :, None, :]  # single shared rope head
    sin, cos = rope_tables(positions, m.qk_rope_head_dim, cfg.rope_theta)
    k_rope = apply_rope(k_rope, sin, cos)
    k_nope, v = _mla_kv_from_latent(params, c_kv, cfg)
    q = jnp.concatenate([q_nope, q_rope], -1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, k_nope.shape[:3] + (m.qk_rope_head_dim,))], -1)
    out = attn_core(q, k, v, causal=True, window=window)
    out = out.reshape(B, S, -1) @ params["wo"]
    if init_cache is None:
        return out
    filled = _fill_cache(init_cache, {"c_kv": c_kv, "k_rope": k_rope[:, :, 0]},
                         positions)
    return out, filled


def init_mla_cache(cfg: ModelConfig, batch: int, capacity: int, dtype) -> dict:
    m: MLAConfig = cfg.mla
    return {
        "c_kv": jnp.zeros((batch, capacity, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, capacity, m.qk_rope_head_dim), dtype),
        "pos": jnp.full((batch, capacity), -1, jnp.int32),
    }


def mla_decode(params, x, position, cache, cfg: ModelConfig, *,
               window: int = 0, absorbed: bool = True):
    """MLA decode: the cache stores only the compressed latent + rope key —
    the paper-relevant memory saving (kv_lora_rank + rope_dim per token
    instead of 2*H*hd).

    ``absorbed=True`` (default, §Perf hillclimb B): attention runs *in the
    latent space* — q_nope is absorbed through kv_up's K half and the
    context is re-expanded through its V half only once per step, so the
    cache is never expanded to per-head K/V. Per-step matmul flops drop
    from O(cap * r * H * (nope+v)) to O(cap * r * H), ~128x for
    DeepSeek-V3 (identical math; validated against absorbed=False in
    tests)."""
    m: MLAConfig = cfg.mla
    B = x.shape[0]
    H = cfg.num_heads
    cap = cache["c_kv"].shape[1]
    q_nope, q_rope = _mla_q(params, x, position[:, None], cfg)
    down = x @ params["kv_down"]
    c_kv_t = rms_norm(down[..., :m.kv_lora_rank], params["kv_norm"], cfg.norm_eps)
    k_rope_t = down[..., m.kv_lora_rank:][:, :, None, :]
    sin, cos = rope_tables(position[:, None], m.qk_rope_head_dim, cfg.rope_theta)
    k_rope_t = apply_rope(k_rope_t, sin, cos)
    slot = (position % cap).astype(jnp.int32)
    bidx = jnp.arange(B)
    new_ckv = cache["c_kv"].at[bidx, slot].set(c_kv_t[:, 0])
    new_krope = cache["k_rope"].at[bidx, slot].set(k_rope_t[:, 0, 0])
    new_pos = cache["pos"].at[bidx, slot].set(position.astype(jnp.int32))
    valid = (new_pos >= 0) & (new_pos <= position[:, None])
    if window:
        valid &= new_pos > (position[:, None] - window)
    new_cache = {"c_kv": new_ckv, "k_rope": new_krope, "pos": new_pos}

    if not absorbed:
        k_nope, v = _mla_kv_from_latent(params, new_ckv, cfg)   # [B,cap,H,*]
        k = jnp.concatenate([
            k_nope,
            jnp.broadcast_to(new_krope[:, :, None, :],
                             k_nope.shape[:3] + (m.qk_rope_head_dim,)),
        ], -1)
        q = jnp.concatenate([q_nope, q_rope], -1)
        out = sdpa(q, k, v, valid[:, None, None, :])
        out = out.reshape(B, 1, -1) @ params["wo"]
        return out, new_cache

    kv_up = params["kv_up"].reshape(m.kv_lora_rank, H,
                                    m.qk_nope_head_dim + m.v_head_dim)
    w_uk = kv_up[..., :m.qk_nope_head_dim]             # [r, H, nope]
    w_uv = kv_up[..., m.qk_nope_head_dim:]             # [r, H, v]
    # absorb q through the K-expansion: scores live in latent space
    q_lat = jnp.einsum("bhn,rhn->bhr", q_nope[:, 0], w_uk)
    s = jnp.einsum("bhr,bcr->bhc", q_lat.astype(jnp.float32),
                   new_ckv.astype(jnp.float32))
    s = s + jnp.einsum("bhp,bcp->bhc", q_rope[:, 0].astype(jnp.float32),
                       new_krope.astype(jnp.float32))
    s = s / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    s = jnp.where(valid[:, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    ctx_lat = jnp.einsum("bhc,bcr->bhr", p.astype(new_ckv.dtype), new_ckv)
    out = jnp.einsum("bhr,rhv->bhv", ctx_lat, w_uv)    # [B, H, v]
    out = out.reshape(B, 1, H * m.v_head_dim) @ params["wo"]
    return out, new_cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------
def init_mlp(key, d: int, d_ff: int, gated: bool, num_layers: int, dtype) -> dict:
    ks = jax.random.split(key, 3)
    p = {
        "w_in": _init(ks[0], (d, d_ff), dtype=dtype),
        "w_out": _init(ks[1], (d_ff, d), scale=0.02 / math.sqrt(2 * num_layers),
                       dtype=dtype),
    }
    if gated:
        p["w_gate"] = _init(ks[2], (d, d_ff), dtype=dtype)
    return p


def mlp_fwd(params, x, gated: bool, adapter=None) -> jax.Array:
    ad = adapter or {}
    h = x @ params["w_in"] + lora_delta(x, ad.get("w_in"))
    if gated:
        h = jax.nn.silu(x @ params["w_gate"]
                        + lora_delta(x, ad.get("w_gate"))) * h
    else:
        h = jax.nn.gelu(h)
    return h @ params["w_out"] + lora_delta(h, ad.get("w_out"))
