"""Expert-parallel MoE via shard_map with explicit all-to-all dispatch.

GSPMD auto-sharding cannot partition a data-dependent scatter across the
expert axis (it falls back to replication — observed 1.6 TB/device temps on
deepseek-v3 train). This module owns the communication pattern explicitly:

  device grid = (dp = pod x data, model = M shards x E_loc experts each)

  per device (t = T / (ndp * M) local tokens):
    1. route local tokens (top-k over all E experts)
    2. bucket assignments by destination model-shard; capacity-drop into a
       send buffer [M, cap, D] (+ int payload carrying local-expert ids)
    3. all_to_all over the model axis              <- the MoE dispatch
    4. locally sort received rows by expert, run the [E_loc, C, D] x
       [E_loc, D, F] batched MXU matmul
    5. scatter results back into the recv layout, all_to_all back
    6. combine into the original token order with gate weights

  Every buffer is O(t * k * cf) per device; the sorts are over t*k elems.

Experts are zero-padded to a multiple of M when E % M != 0 (granite's 40
experts on a 16-way axis -> 48 padded; dead experts receive no rows). The
FSDP all-gather of expert weights happens outside (pjit inserts it because
the shard_map in_spec asks for dims the params shard over dp).
"""
from __future__ import annotations

import functools
import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, MoEConfig
from repro.sharding import ctx

try:
    from jax import shard_map as _shard_map  # jax >= 0.7 name
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map

import inspect as _inspect

# the "don't verify replication" kwarg was renamed check_rep -> check_vma
_SHARD_MAP_NO_CHECK = (
    {"check_vma": False}
    if "check_vma" in _inspect.signature(_shard_map).parameters
    else {"check_rep": False})


def _dp_axes(mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def usable(cfg: ModelConfig, B: int, S: int) -> bool:
    """shard_map path applies when tokens tile the (dp, model) grid."""
    mesh = ctx.current_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return False
    M = mesh.shape["model"]
    ndp = 1
    for a in _dp_axes(mesh):
        ndp *= mesh.shape[a]
    if M <= 1:
        return False
    if B % ndp or S % M:
        return False
    t = (B // ndp) * (S // M)
    return t * cfg.moe.top_k >= 4 * M


def moe_fwd_shard_map(params, x, cfg: ModelConfig, *,
                      capacity_factor: float = 1.25):
    """x [B, S, D] -> (y [B, S, D], aux). Requires usable(cfg, B, S)."""
    mesh = ctx.current_mesh()
    e: MoEConfig = cfg.moe
    B, S, D = x.shape
    M = mesh.shape["model"]
    dp = _dp_axes(mesh)
    ndp = 1
    for a in dp:
        ndp *= mesh.shape[a]
    E = e.num_experts
    E_pad = M * (-(-E // M))
    k = e.top_k
    t = (B // ndp) * (S // M)
    cap = max(4, -(-int(math.ceil(t * k / M * capacity_factor)) // 4) * 4)
    C2 = max(4, -(-int(math.ceil(t * k / (E_pad // M) * capacity_factor)) // 4) * 4)
    E_loc = E_pad // M

    w_in, w_gate, w_out = params["w_in"], params["w_gate"], params["w_out"]
    if E_pad != E:
        padg = ((0, E_pad - E), (0, 0), (0, 0))
        w_in, w_gate, w_out = (jnp.pad(w, padg) for w in (w_in, w_gate, w_out))

    dpspec = dp if len(dp) > 1 else dp[0]

    def local(x_loc, router_w, w_in_l, w_gate_l, w_out_l):
        # x_loc [B/ndp, S/M, D] -> flat [t, D]
        xt = x_loc.reshape(t, D)
        logits = xt.astype(jnp.float32) @ router_w
        probs = jax.nn.softmax(logits, axis=-1)
        gates, eids = jax.lax.top_k(probs, k)
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
        # load-balance aux (global via pmean)
        me = probs.mean(0)
        cexp = jnp.zeros((E,), jnp.float32).at[eids.reshape(-1)].add(1.0) / (t * k)
        aux = E * jnp.sum(jax.lax.pmean(me, ("model",) + dp)
                          * jax.lax.pmean(cexp, ("model",) + dp))

        token_idx = jnp.repeat(jnp.arange(t), k)
        eid_flat = eids.reshape(-1)
        gate_flat = gates.reshape(-1)
        dshard = eid_flat // E_loc
        eloc = eid_flat % E_loc

        # ---- bucket by destination shard, capacity `cap` per shard
        order = jnp.argsort(dshard)
        ds_s, tok_s, el_s, gate_s = (dshard[order], token_idx[order],
                                     eloc[order], gate_flat[order])
        counts = jnp.zeros((M,), jnp.int32).at[dshard].add(1)
        starts = jnp.cumsum(counts) - counts
        pos = jnp.arange(t * k) - starts[ds_s]
        keep = pos < cap
        slot = jnp.where(keep, ds_s * cap + pos, M * cap)
        send = jnp.zeros((M * cap, D), x.dtype).at[slot].set(
            jnp.take(xt, tok_s, axis=0), mode="drop")
        payload = jnp.full((M * cap,), E_loc, jnp.int32).at[slot].set(
            el_s, mode="drop")

        # ---- dispatch all-to-all over the model axis
        recv = jax.lax.all_to_all(send.reshape(M, cap, D), "model",
                                  split_axis=0, concat_axis=0, tiled=False)
        pl_recv = jax.lax.all_to_all(payload.reshape(M, cap), "model",
                                     split_axis=0, concat_axis=0, tiled=False)
        rows = recv.reshape(M * cap, D)
        peid = pl_recv.reshape(M * cap)                 # E_loc = invalid

        # ---- local expert dispatch (second bucket sort)
        order2 = jnp.argsort(peid)
        pe_s = peid[order2]
        counts2 = jnp.zeros((E_loc + 1,), jnp.int32).at[peid].add(1)
        starts2 = jnp.cumsum(counts2) - counts2
        pos2_s = jnp.arange(M * cap) - starts2[pe_s]
        keep2_s = (pos2_s < C2) & (pe_s < E_loc)
        slot2_s = jnp.where(keep2_s, pe_s * C2 + pos2_s, E_loc * C2)
        ebuf = jnp.zeros((E_loc * C2, D), x.dtype).at[slot2_s].set(
            jnp.take(rows, order2, axis=0), mode="drop")
        eb = ebuf.reshape(E_loc, C2, D)
        h = jnp.einsum("ecd,edf->ecf", eb, w_in_l)
        g = jnp.einsum("ecd,edf->ecf", eb, w_gate_l)
        out_e = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * h, w_out_l)
        out_rows = out_e.reshape(E_loc * C2, D)

        # ---- un-sort back into recv layout
        back = jnp.zeros((M * cap, D), x.dtype).at[order2].set(
            jnp.take(out_rows, jnp.minimum(slot2_s, E_loc * C2 - 1), axis=0)
            * keep2_s[:, None].astype(x.dtype), mode="drop")

        # ---- return all-to-all
        ret = jax.lax.all_to_all(back.reshape(M, cap, D), "model",
                                 split_axis=0, concat_axis=0, tiled=False)
        res_rows = ret.reshape(M * cap, D)

        # ---- combine in original token order
        contrib = jnp.take(res_rows, jnp.minimum(slot, M * cap - 1), axis=0)
        contrib = contrib * (gate_s * keep).astype(x.dtype)[:, None]
        y = jnp.zeros((t, D), x.dtype).at[tok_s].add(contrib)
        return y.reshape(x_loc.shape), aux

    fn = _shard_map(
        local, mesh=mesh,
        in_specs=(P(dpspec, "model", None), P(None, None),
                  P("model", None, None), P("model", None, None),
                  P("model", None, None)),
        out_specs=(P(dpspec, "model", None), P()),
        **_SHARD_MAP_NO_CHECK)
    y, aux = fn(x, params["router"], w_in, w_gate, w_out)
    return y, aux * e.router_aux_coef
