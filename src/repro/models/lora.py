"""LoRA adapter trees over the base parameter pytree (Hydra-RLHF style).

The shared-base "hydra" RLHF engine keeps ONE frozen trunk and gives every
role (actor / critic / reward) a small adapter: low-rank A/B factors on the
2-D projection weights, plus a value head for the scalar-output roles. This
module owns the adapter pytree itself:

  * :func:`init_adapter`      — build an adapter mirroring a base tree
    (A ~ N(0, 0.02), B = 0, so the initial delta is exactly zero);
  * :func:`lora_delta`        — the *unmerged* application ``(x @ A) @ B``
    used at matmul sites during training forwards (never materializes the
    [d_in, d_out] product);
  * :func:`merge_adapter` / :func:`unmerge_adapter` — fold ``A @ B`` into
    the base weights for rollout-speed generation and back out again;
  * :func:`merged_leaves`     — the arrays a merge freshly created (the
    ones that are safe to ``.delete()`` at a phase boundary — non-adapted
    leaves of a merged tree alias the frozen base and must survive);
  * :func:`adapter_param_count` / :func:`trainable_fraction` — exact
    trainable-parameter accounting from the real trees (replaces the old
    analytic estimate in ``core.strategies``).

Adapted sites: attention projections (``wq/wk/wv/wo`` — only when all four
are present, so MLA mixers and cross-attention blocks are left alone) and
dense-MLP projections (``w_in/w_gate/w_out`` — only in dicts without a
``router``, so MoE expert banks are left alone). Segment-stacked leaves
``[G, d_in, d_out]`` get stacked factors ``[G, d_in, r]`` / ``[G, r, d_out]``
that slice correctly under ``jax.lax.scan``. Adapter leaves are stored in
float32 (they are the master/trainable copy); deltas are cast to the
activation dtype at apply time.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

ATTN_SITES = ("wq", "wk", "wv", "wo")
MLP_SITES = ("w_in", "w_gate", "w_out")


def _is_site(node: dict) -> bool:
    """An adapter site leaf-pair {"a": A, "b": B}."""
    return isinstance(node, dict) and set(node) == {"a", "b"}


def _adaptable_names(parent: Dict[str, Any], path_names) -> List[str]:
    """Which keys of ``parent`` get LoRA factors."""
    if "cross" in path_names:
        return []
    if all(k in parent for k in ATTN_SITES):
        return [k for k in ATTN_SITES]
    if "router" not in parent and any(k in parent for k in MLP_SITES):
        return [k for k in MLP_SITES if k in parent]
    return []


def init_adapter(key, base_params, rank: int, *,
                 with_value: bool = False, d_model: int = 0,
                 scale: float = 0.02) -> Dict[str, Any]:
    """Adapter tree for ``base_params``: {"lora": {...}, ["value_head"]}.

    ``rank=0`` yields an empty lora tree (the adapter is only a value head,
    or nothing at all) — the forward then reduces to the plain base pass.
    ``base_params`` may be ShapeDtypeStructs (eval_shape-safe counting).
    """
    counter = [0]

    def rec(node, path_names):
        if not isinstance(node, dict):
            return None
        out: Dict[str, Any] = {}
        names = _adaptable_names(node, path_names) if rank > 0 else []
        for name in names:
            w = node[name]
            if len(w.shape) < 2:
                continue
            *lead, d_in, d_out = w.shape
            counter[0] += 1
            ka, _ = jax.random.split(jax.random.fold_in(key, counter[0]))
            out[name] = {
                "a": scale * jax.random.normal(
                    ka, (*lead, d_in, rank), jnp.float32),
                "b": jnp.zeros((*lead, rank, d_out), jnp.float32),
            }
        for k, v in node.items():
            if k in out or not isinstance(v, dict):
                continue
            sub = rec(v, path_names + (k,))
            if sub:
                out[k] = sub
        return out

    adapter: Dict[str, Any] = {"lora": rec(base_params, ()) or {}}
    if with_value:
        assert d_model > 0, "with_value adapters need d_model"
        kv = jax.random.fold_in(key, 0)
        adapter["value_head"] = {
            "w": 0.02 * jax.random.normal(kv, (d_model, 1), jnp.float32),
            "b": jnp.zeros((1,), jnp.float32),
        }
    return adapter


def lora_delta(x: jax.Array, ab: Optional[dict]) -> jax.Array:
    """Unmerged low-rank delta ``(x @ A) @ B`` in the activation dtype.
    ``ab`` may be None / absent — returns 0 so call sites stay branch-free."""
    if not ab:
        return jnp.zeros((), x.dtype)
    return (x @ ab["a"].astype(x.dtype)) @ ab["b"].astype(x.dtype)


def _merge(base, lora, sign: float):
    if _is_site(lora):
        return (base + sign * (lora["a"] @ lora["b"]).astype(base.dtype)
                ).astype(base.dtype)
    if isinstance(base, dict):
        return {k: _merge(v, lora[k], sign) if k in lora else v
                for k, v in base.items()}
    return base


def merge_adapter(base_params, lora_tree) -> Any:
    """base + A@B at every adapted site. Non-adapted leaves are returned
    *by reference* (they alias the frozen base — do not delete them)."""
    return _merge(base_params, lora_tree or {}, +1.0)


def unmerge_adapter(merged_params, lora_tree) -> Any:
    """Inverse of :func:`merge_adapter` (up to fp round-off)."""
    return _merge(merged_params, lora_tree or {}, -1.0)


def merged_leaves(merged_params, lora_tree) -> List[jax.Array]:
    """The arrays :func:`merge_adapter` freshly allocated — i.e. the leaves
    at adapted sites. Safe to ``.delete()`` at a phase boundary."""
    out: List[jax.Array] = []

    def rec(node, lora):
        if _is_site(lora):
            out.append(node)
            return
        if isinstance(node, dict):
            for k, sub in lora.items():
                if k in node:
                    rec(node[k], sub)

    rec(merged_params, lora_tree or {})
    return out


def delete_merged(merged_params, lora_tree) -> None:
    """Phase-boundary hygiene: ``.delete()`` exactly the arrays
    :func:`merge_adapter` freshly allocated, leaving the aliased frozen
    base untouched. No-op on leaves without buffers (tracers, structs)."""
    for leaf in merged_leaves(merged_params, lora_tree):
        if hasattr(leaf, "delete") and not leaf.is_deleted():
            leaf.delete()


def adapted_subtree(params, lora_tree) -> Any:
    """The sub-pytree of ``params`` at the adapted sites — exactly the
    leaves :func:`merge_adapter` replaces. This is the swappable unit the
    offload subsystem parks while a merged copy serves rollout (the
    non-adapted leaves, which the merged tree aliases, must stay put)."""
    if _is_site(lora_tree):
        return params
    return {k: adapted_subtree(params[k], sub)
            for k, sub in (lora_tree or {}).items() if k in params}


def with_adapted_leaves(params, lora_tree, subtree) -> Any:
    """Rebuild ``params`` with the adapted-site leaves replaced by
    ``subtree`` (an :func:`adapted_subtree`-shaped tree); all other leaves
    are returned by reference."""
    if _is_site(lora_tree):
        return subtree
    if not isinstance(params, dict):
        return params
    lora_tree = lora_tree or {}
    return {k: with_adapted_leaves(v, lora_tree[k], subtree[k])
            if k in lora_tree else v for k, v in params.items()}


def adapter_param_count(adapter) -> int:
    """Total trainable parameters in an adapter (lora factors + value head)."""
    import numpy as np
    return int(sum(np.prod(l.shape) for l in jax.tree.leaves(adapter)))


def trainable_fraction(base_params, adapter) -> float:
    """Exact trainable fraction: adapter params / base params. This is what
    LoRA scales the grad and optimizer-state footprint by."""
    import numpy as np
    n_base = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(base_params))
    return adapter_param_count(adapter) / max(n_base, 1)
