"""Composable decoder (and encoder-decoder) LM over the layer-kind zoo.

A network is a list of *segments*; each segment is ``n_groups`` repetitions
of a layer *period* (e.g. Jamba's ``(M,M,M,M,A,M,M,M)``), scanned with
``jax.lax.scan`` over stacked parameters so the HLO stays small even for
80-layer models. ``first_k_dense`` (DeepSeek) becomes its own leading
segment. Supports forward (train), prefill (build caches) and one-token
decode, with full / sliding-window attention, MoE FFNs, MLA, Mamba2 and
cross-attention for enc-dec models.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN, MAMBA, MLA, ModelConfig
from repro.models import layers as L
from repro.models import lora as LORA
from repro.models import mamba as M
from repro.models import moe as MOE
from repro.sharding import ctx


@dataclass(frozen=True)
class Segment:
    kinds: Tuple[str, ...]
    moe_flags: Tuple[bool, ...]
    n_groups: int


def build_segments(cfg: ModelConfig) -> List[Segment]:
    per = len(cfg.period)
    segs: List[Segment] = []
    k = cfg.first_k_dense
    if k:
        assert k % per == 0, (cfg.name, k, per)
        segs.append(Segment(cfg.period, (False,) * per, k // per))
    rest = cfg.num_layers - k
    assert rest % per == 0, (cfg.name, rest, per)
    if rest:
        segs.append(Segment(cfg.period, cfg.moe_period, rest // per))
    return segs


def _stack_groups(groups: List[Any]):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *groups)


def _model_size() -> int:
    mesh = ctx.current_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return 1
    return mesh.shape["model"]


class Model:
    """cfg-driven LM. ``with_value=True`` adds a scalar value head (critic /
    reward models in the RLHF pipeline share this class)."""

    def __init__(self, cfg: ModelConfig, with_value: bool = False):
        self.cfg = cfg
        self.with_value = with_value
        self.segments = build_segments(cfg)
        self.is_encdec = cfg.input_mode == "encdec"

    # ------------------------------------------------------------------ init
    def _init_slot(self, key, kind: str, is_moe: bool, cross: bool, dtype):
        cfg = self.cfg
        ks = jax.random.split(key, 6)
        slot: Dict[str, Any] = {"norm1": L.init_norm(cfg.d_model, dtype)}
        if kind == ATTN:
            slot["mixer"] = L.init_attention(ks[0], cfg, dtype)
        elif kind == MLA:
            slot["mixer"] = L.init_mla(ks[0], cfg, dtype)
        elif kind == MAMBA:
            slot["mixer"] = M.init_mamba(ks[0], cfg, dtype)
        else:
            raise ValueError(kind)
        if cross:
            slot["norm_x"] = L.init_norm(cfg.d_model, dtype)
            slot["cross"] = L.init_attention(ks[1], cfg, dtype)
        if is_moe and cfg.moe is not None:
            slot["norm2"] = L.init_norm(cfg.d_model, dtype)
            slot["ffn"] = MOE.init_moe(ks[2], cfg, dtype)
        elif cfg.d_ff:
            slot["norm2"] = L.init_norm(cfg.d_model, dtype)
            slot["ffn"] = L.init_mlp(ks[2], cfg.d_model, cfg.d_ff,
                                     cfg.mlp_gated, cfg.num_layers, dtype)
        return slot

    def _init_group(self, key, seg: Segment, cross: bool, dtype):
        ks = jax.random.split(key, len(seg.kinds))
        return {f"slot{i}": self._init_slot(ks[i], kind, seg.moe_flags[i], cross, dtype)
                for i, kind in enumerate(seg.kinds)}

    def init(self, key) -> dict:
        cfg = self.cfg
        dtype = jnp.dtype(cfg.param_dtype)
        n_seg = len(self.segments)
        ks = jax.random.split(key, n_seg + 6)
        params: Dict[str, Any] = {
            "embed": L._init(ks[0], (cfg.vocab_size, cfg.d_model), dtype=dtype),
            "final_norm": L.init_norm(cfg.d_model, dtype),
        }
        cross = self.is_encdec
        for si, seg in enumerate(self.segments):
            gks = jax.random.split(ks[1 + si], seg.n_groups)
            groups = [self._init_group(gks[g], seg, cross, dtype)
                      for g in range(seg.n_groups)]
            params[f"segment{si}"] = _stack_groups(groups)
        if not cfg.tie_embeddings:
            params["lm_head"] = L._init(ks[n_seg + 1], (cfg.d_model, cfg.vocab_size),
                                        dtype=dtype)
        if self.with_value:
            params["value_head"] = {
                "w": L._init(ks[n_seg + 2], (cfg.d_model, 1), dtype=jnp.float32),
                "b": jnp.zeros((1,), jnp.float32),
            }
        if cfg.encoder_layers:
            eseg = Segment((ATTN,), (False,), cfg.encoder_layers)
            gks = jax.random.split(ks[n_seg + 3], cfg.encoder_layers)
            groups = [self._init_group(gks[g], eseg, False, dtype)
                      for g in range(cfg.encoder_layers)]
            params["encoder"] = _stack_groups(groups)
            params["encoder_norm"] = L.init_norm(cfg.d_model, dtype)
        if cfg.mtp_depth:
            mseg = self.segments[-1]

            def mtp_module(pk, lk):
                return {
                    "proj": L._init(pk, (2 * cfg.d_model, cfg.d_model),
                                    dtype=dtype),
                    "norm_h": L.init_norm(cfg.d_model, dtype),
                    "norm_e": L.init_norm(cfg.d_model, dtype),
                    "layer": self._init_group(
                        lk, Segment(mseg.kinds[:1], mseg.moe_flags[:1], 1),
                        False, dtype),
                }

            params["mtp"] = mtp_module(ks[n_seg + 4], ks[n_seg + 5])
            if cfg.mtp_depth > 1:
                # depths 2..k stack on a leading axis ("mtp_extra") so the
                # depth-1 tree — and therefore every existing checkpoint —
                # is byte-identical; keys fork off the depth-1 stream
                extras = [
                    mtp_module(
                        jax.random.fold_in(ks[n_seg + 4], 1 + j),
                        jax.random.fold_in(ks[n_seg + 5], 1 + j))
                    for j in range(cfg.mtp_depth - 1)]
                params["mtp_extra"] = _stack_groups(extras)
        return params

    # -------------------------------------------------------- lora adapters
    def init_adapter(self, key, params, rank: int, *,
                     with_value: bool = False) -> dict:
        """Per-role LoRA adapter over ``params`` (see models/lora.py)."""
        return LORA.init_adapter(key, params, rank, with_value=with_value,
                                 d_model=self.cfg.d_model)

    def merge_adapter(self, params, adapter) -> dict:
        """Fold A·B into the base weights (rollout-speed generation). The
        returned tree aliases the base at non-adapted leaves — delete only
        ``lora.merged_leaves(merged, adapter["lora"])`` afterwards."""
        return LORA.merge_adapter(params, (adapter or {}).get("lora"))

    def unmerge_adapter(self, params, adapter) -> dict:
        """Subtract A·B back out of a merged tree (fp round-off applies)."""
        return LORA.unmerge_adapter(params, (adapter or {}).get("lora"))

    # ------------------------------------------------------------ embeddings
    def embed(self, params, tokens):
        return jnp.take(params["embed"], tokens, axis=0)

    def unembed(self, params, h):
        h = L.rms_norm(h, params["final_norm"], self.cfg.norm_eps)
        w = params["embed"].T if self.cfg.tie_embeddings else params["lm_head"]
        logits = h @ w
        if logits.ndim == 3:
            # vocab-parallel when V divides TP; else shard the seq dim
            if logits.shape[-1] % max(_model_size(), 1) == 0:
                logits = ctx.constrain(logits, "dp", None, "model")
            else:
                logits = ctx.constrain(logits, "dp", "model", None)
        return logits

    # -------------------------------------------------------------- full seq
    def _slot_fwd(self, slot, h, positions, kind, has_ffn, is_moe, *,
                  window, cross_kv=None, init_cache=None, adapter=None):
        """One layer. If ``init_cache`` is given (prefill), also fills and
        returns the slot's decode cache in the same pass. ``adapter`` is the
        slot's LoRA subtree (unmerged A·B applied at the matmul sites)."""
        cfg = self.cfg
        cache = {}
        ad = adapter or {}
        # (§Perf hillclimb C, refuted: per-slot Megatron-SP constraints were
        # tried here — GSPMD already picks its schedule and the extra
        # constraints cost +5..23% memory-term on jamba/llama; reverted.
        # The group-boundary seq-parallel constraint in _stack_fwd stays.)
        x = L.rms_norm(h, slot["norm1"], cfg.norm_eps)
        if kind == ATTN:
            y = L.attention_fwd(slot["mixer"], x, positions, cfg,
                                window=window, init_cache=init_cache,
                                adapter=ad.get("mixer"))
            if init_cache is not None:
                y, cache = y
            h = h + y
        elif kind == MLA:
            y = L.mla_fwd(slot["mixer"], x, positions, cfg,
                          window=window, init_cache=init_cache)
            if init_cache is not None:
                y, cache = y
            h = h + y
        elif kind == MAMBA:
            if init_cache is not None:
                y, cache = M.mamba_fwd(slot["mixer"], x, cfg, return_state=True)
            else:
                y = M.mamba_fwd(slot["mixer"], x, cfg)
            h = h + y
        if cross_kv is not None:
            xx = L.rms_norm(h, slot["norm_x"], cfg.norm_eps)
            h = h + L.cross_attention_fwd(slot["cross"], xx, cross_kv, cfg)
        aux = jnp.zeros((), jnp.float32)
        if has_ffn:
            x2 = L.rms_norm(h, slot["norm2"], cfg.norm_eps)
            if is_moe:
                y, aux = MOE.moe_fwd(slot["ffn"], x2, cfg)
            else:
                y = L.mlp_fwd(slot["ffn"], x2, cfg.mlp_gated,
                              adapter=ad.get("ffn"))
            h = h + y
        return h, aux, cache

    def _seg_has_ffn(self, seg: Segment, i: int) -> bool:
        return (seg.moe_flags[i] and self.cfg.moe is not None) or self.cfg.d_ff > 0

    def _stack_fwd(self, params, h, positions, *, window=0, cross_kv=None,
                   init_caches=None, adapter=None, layer_specs=None):
        """Run all segments. Returns (h, aux, filled_caches_per_segment).
        ``adapter`` is a LoRA tree mirroring the segment structure; its
        stacked factors ride the scan alongside the stacked weights.

        ``layer_specs`` (a per-segment list of sliced-layer sharding
        trees, ``sharding.TreePlan.layer_specs``) turns the scan body into
        the per-layer ZeRO-3/FSDP all-gather: each iteration constrains
        only its own sliced layer period to the DP-stripped compute
        layout, so the gathered weights live for ONE layer instead of the
        whole tree (DESIGN.md §3.7). Falls back to the ambient
        ``ctx.segment_param_specs()`` (the per-layer grad reduce-scatter
        hook) when None."""
        cfg = self.cfg
        lora = (adapter or {}).get("lora")
        aux_total = jnp.zeros((), jnp.float32)
        all_caches = []
        seg_specs = layer_specs if layer_specs is not None \
            else ctx.segment_param_specs()
        for si, seg in enumerate(self.segments):
            def group_fwd(carry, xs, seg=seg, si=si):
                hh, aux = carry
                # sequence parallelism at layer boundaries: the remat-saved
                # residual stream shards over ("dp", "model") — 16x smaller
                # checkpoint footprint; XLA all-gathers into the mixers.
                hh = ctx.constrain(hh, "dp", "model", None)
                gp, ckv, ic, ad = xs
                if seg_specs is not None:
                    gp = jax.tree.map(ctx.constrain_spec, gp, seg_specs[si])
                caches = {}
                for i, kind in enumerate(seg.kinds):
                    is_moe = seg.moe_flags[i] and cfg.moe is not None
                    hh, a, c = self._slot_fwd(
                        gp[f"slot{i}"], hh, positions, kind,
                        self._seg_has_ffn(seg, i), is_moe,
                        window=window,
                        cross_kv=None if ckv is None else ckv[i],
                        init_cache=None if ic is None else ic[f"slot{i}"],
                        adapter=None if ad is None else ad.get(f"slot{i}"))
                    caches[f"slot{i}"] = c
                    aux = aux + a
                if cfg.remat == "offload":
                    # name the carried residual so the offload-aware
                    # checkpoint policy can spill it to host (see
                    # repro.offload.policies)
                    from jax.ad_checkpoint import checkpoint_name
                    hh = checkpoint_name(hh, "residual")
                return (hh, aux), caches

            body = group_fwd
            if cfg.remat == "full":
                body = jax.checkpoint(group_fwd)
            elif cfg.remat in ("dots", "offload"):
                from repro.offload.policies import remat_policy_for
                body = jax.checkpoint(group_fwd,
                                      policy=remat_policy_for(cfg.remat))
            xs = (params[f"segment{si}"],
                  cross_kv[si] if cross_kv is not None else None,
                  init_caches[si] if init_caches is not None else None,
                  lora.get(f"segment{si}") if lora else None)
            (h, aux_total), caches = jax.lax.scan(
                body, (h, aux_total), xs)
            all_caches.append(caches)
        return h, aux_total, all_caches

    # ---------------------------------------------------------------- encode
    def encode(self, params, frame_embeds):
        """Bidirectional encoder over precomputed frame embeddings."""
        cfg = self.cfg
        h = frame_embeds
        Se = h.shape[1]
        positions = jnp.broadcast_to(jnp.arange(Se), h.shape[:2])

        def group_fwd(hh, gp):
            hh = ctx.constrain(hh, "dp", "model", None)
            x = L.rms_norm(hh, gp["slot0"]["norm1"], cfg.norm_eps)
            q, k, v = L._project_qkv(gp["slot0"]["mixer"], x, cfg)
            sin, cos = L.rope_tables(positions, cfg.resolved_head_dim(),
                                     cfg.rope_theta)
            q, k = L.apply_rope(q, sin, cos), L.apply_rope(k, sin, cos)
            o = L.attn_core(q, k, v, causal=False).reshape(hh.shape[0], Se, -1)
            hh = hh + o @ gp["slot0"]["mixer"]["wo"]
            x2 = L.rms_norm(hh, gp["slot0"]["norm2"], cfg.norm_eps)
            hh = hh + L.mlp_fwd(gp["slot0"]["ffn"], x2, cfg.mlp_gated)
            return hh, None

        body = jax.checkpoint(group_fwd) if cfg.remat != "none" else group_fwd
        h, _ = jax.lax.scan(body, h, params["encoder"])
        return L.rms_norm(h, params["encoder_norm"], cfg.norm_eps)

    def _cross_kvs(self, params, enc_out):
        """Per-decoder-layer cross K/V, stacked per segment.
        Sharded (batch over dp, kv heads — or head_dim — over model)."""
        def con(x):  # [G, B, Se, K, hd]
            kh = "model" if x.shape[3] % _model_size() == 0 else None
            hd = None if kh else "model"
            return ctx.constrain(x, None, "dp", None, kh, hd)
        out = []
        for si, seg in enumerate(self.segments):
            def per_group(gp):
                return tuple(
                    L.encode_cross_kv(gp[f"slot{i}"]["cross"], enc_out, self.cfg)
                    for i in range(len(seg.kinds)))
            kvs = jax.vmap(per_group, in_axes=0)(params[f"segment{si}"])
            out.append(jax.tree.map(con, kvs))
        return out

    # --------------------------------------------------------------- forward
    def _prepare_inputs(self, params, batch):
        cfg = self.cfg
        cross_kv = None
        if cfg.input_mode == "tokens":
            h = self.embed(params, batch["tokens"])
        elif cfg.input_mode == "embeddings":
            tok = self.embed(params, batch["tokens"])
            h = jnp.concatenate([batch["prefix_embeds"].astype(tok.dtype), tok], 1)
        elif cfg.input_mode == "encdec":
            h = self.embed(params, batch["tokens"])
            enc_out = self.encode(params, batch["frame_embeds"].astype(h.dtype))
            cross_kv = self._cross_kvs(params, enc_out)
        else:
            raise ValueError(cfg.input_mode)
        S = h.shape[1]
        positions = jnp.broadcast_to(jnp.arange(S), h.shape[:2])
        return h, positions, cross_kv

    def forward(self, params, batch, *, window: int = 0, adapter=None,
                layer_specs=None):
        """Full-sequence forward -> (logits [B,S,V], aux_loss, h_final).
        ``adapter`` (optional LoRA tree) is applied unmerged;
        ``layer_specs`` enables the per-layer ZeRO-3 gather in the scan
        body (see ``_stack_fwd``)."""
        h, positions, cross_kv = self._prepare_inputs(params, batch)
        # cross_kv from _cross_kvs is already per-segment stacked; pass as xs
        h, aux, _ = self._stack_fwd(params, h, positions, window=window,
                                    cross_kv=cross_kv, adapter=adapter,
                                    layer_specs=layer_specs)
        return self.unembed(params, h), aux, h

    def forward_value(self, params, batch, adapter=None, layer_specs=None):
        """[B,S] per-token scalar values (critic / reward). With an
        ``adapter`` carrying a value head, the head comes from the adapter —
        the hydra engine's critic/reward share a headless base trunk."""
        h, positions, cross_kv = self._prepare_inputs(params, batch)
        h, _, _ = self._stack_fwd(params, h, positions, cross_kv=cross_kv,
                                  adapter=adapter, layer_specs=layer_specs)
        h = L.rms_norm(h, params["final_norm"], self.cfg.norm_eps)
        vh = (adapter or {}).get("value_head") or params["value_head"]
        return (h.astype(jnp.float32) @ vh["w"] + vh["b"])[..., 0]

    def _mtp_modules(self, params) -> list:
        """Depth-ordered MTP modules: ``params["mtp"]`` is depth 1; extras
        (depths 2..k) are unstacked off ``params["mtp_extra"]``'s lead axis."""
        modules = [params["mtp"]]
        extra = params.get("mtp_extra")
        if extra is not None:
            n = jax.tree.leaves(extra)[0].shape[0]
            modules += [jax.tree.map(lambda x, j=j: x[j], extra)
                        for j in range(n)]
        return modules

    def _mtp_module_fwd(self, module, h_prev, e_next, positions, *, window=0):
        """One MTP module: combine h^{d-1} with emb(t_{i+d}) and run the
        module's transformer layer. Returns h^d (pre-final-norm)."""
        cfg = self.cfg
        h_in = jnp.concatenate([
            L.rms_norm(h_prev, module["norm_h"], cfg.norm_eps),
            L.rms_norm(e_next, module["norm_e"], cfg.norm_eps)], -1)
        hh = h_in @ module["proj"]
        seg = self.segments[-1]
        kind = seg.kinds[0]
        is_moe = seg.moe_flags[0] and cfg.moe is not None
        hh, _, _ = self._slot_fwd(module["layer"]["slot0"], hh, positions,
                                  kind, self._seg_has_ffn(seg, 0), is_moe,
                                  window=window)
        return hh

    def mtp_logits(self, params, h, tokens):
        """DeepSeek multi-token prediction: predict t_{i+2} from h_i and
        emb(t_{i+1}). Runs on the full (shifted, end-padded) sequence so the
        token grid keeps tiling the mesh (the MoE shard_map path applies);
        returns logits [B, S, V] where index i scores tokens[:, i+2]
        (the last two positions are padding — mask them in the loss)."""
        shifted = jnp.pad(tokens[:, 1:], ((0, 0), (0, 1)))
        S = h.shape[1]
        positions = jnp.broadcast_to(jnp.arange(S), h.shape[:2])
        hh = self._mtp_module_fwd(params["mtp"], h,
                                  self.embed(params, shifted), positions)
        return self.unembed(params, hh)

    def mtp_chain_logits(self, params, h, tokens, *, window: int = 0):
        """Depth-k chained MTP (arXiv:2412.19437): module d consumes
        h^{d-1} and emb(tokens shifted by d) and predicts t_{i+d+1}.
        Returns a list of logits [B, S, V], one per depth — entry d-1's
        index i scores tokens[:, i+d+1] (``steps.mtp_loss(offset=d+1)``).

        Depth 1 is bit-identical to :meth:`mtp_logits`. ``window=1`` trains
        the chain under the identity attention mask (each position sees
        only itself), which is exactly the function :meth:`mtp_draft`
        evaluates at decode time — use it to train draft-consistent heads."""
        S = h.shape[1]
        positions = jnp.broadcast_to(jnp.arange(S), h.shape[:2])
        out = []
        h_prev = h
        for d, module in enumerate(self._mtp_modules(params), start=1):
            shifted = jnp.pad(tokens[:, d:], ((0, 0), (0, d)))
            h_prev = self._mtp_module_fwd(module, h_prev,
                                          self.embed(params, shifted),
                                          positions, window=window)
            out.append(self.unembed(params, h_prev))
        return out

    def mtp_draft(self, params, h_last, first_tok, k_draft: int):
        """Draft ``k_draft`` greedy tokens from the MTP chain in one shot.

        ``h_last`` [B, D] is the trunk hidden state at position i (the one
        whose logits produced ``first_tok`` = t_{i+1}); the chain then
        predicts t_{i+2}, t_{i+3}, ... Each module runs at a single
        position, where attention degenerates to v(x) — position- and
        RoPE-independent, equal to the ``window=1`` train-time chain — so
        drafts are a deterministic function of (h_last, first_tok). Depths
        beyond the trained ``mtp_depth`` reuse the deepest module. Draft
        quality only moves the accept rate; verification guarantees
        greedy-exact output regardless. Returns drafts [B, k_draft] int32."""
        modules = self._mtp_modules(params)
        h = h_last[:, None]                               # [B, 1, D]
        tok = first_tok
        positions = jnp.zeros(h.shape[:2], jnp.int32)
        drafts = []
        for d in range(k_draft):
            module = modules[min(d, len(modules) - 1)]
            e = self.embed(params, tok[:, None])
            h = self._mtp_module_fwd(module, h, e, positions)
            lg = self.unembed(params, h)[:, 0].astype(jnp.float32)
            tok = jnp.argmax(lg, -1).astype(jnp.int32)
            drafts.append(tok)
        return jnp.stack(drafts, axis=1)

    # ------------------------------------------------------------- kv caches
    def init_cache(self, batch: int, capacity: int, dtype) -> list:
        """Per-segment stacked decode caches."""
        cfg = self.cfg
        caches = []
        for seg in self.segments:
            slot_caches = {}
            for i, kind in enumerate(seg.kinds):
                if kind == ATTN:
                    c = L.init_kv_cache(cfg, batch, capacity, dtype)
                elif kind == MLA:
                    c = L.init_mla_cache(cfg, batch, capacity, dtype)
                else:
                    c = M.init_mamba_cache(cfg, batch, dtype)
                slot_caches[f"slot{i}"] = jax.tree.map(
                    lambda x: jnp.broadcast_to(x, (seg.n_groups,) + x.shape), c)
            caches.append(slot_caches)
        return caches

    def prefill(self, params, batch, capacity: int, *, window: int = 0,
                adapter=None, lengths=None, return_h: bool = False):
        """Process a prompt, returning (last-position logits [B,V], caches).

        caches = {"segments": [...], "cross_kv": [...]|None}. Attention /
        MLA caches hold the last ``min(S, capacity)`` positions of a rolling
        buffer; Mamba slots hold (conv_state, ssm_state). Single pass.

        ``lengths`` [B] (optional) marks per-row valid-token counts under
        right-padding (the compile-bucket ladder pads prompts to a capture
        length): logits come from position ``lengths-1`` and padded cache
        entries are invalidated post-hoc (their "pos" set to -1) — exact
        because causal attention makes right-padding invisible to earlier
        positions. Token-input attention/MLA models only (Mamba states
        cannot be masked after the fact). ``return_h=True`` additionally
        returns the pre-final-norm trunk hidden at the logits position
        [B, D] — the state the MTP draft head chains from."""
        h, positions, cross_kv = self._prepare_inputs(params, batch)
        B = h.shape[0]
        init_caches = self.init_cache(B, capacity, h.dtype)
        h_out, aux, filled = self._stack_fwd(
            params, h, positions, window=window, cross_kv=cross_kv,
            init_caches=init_caches, adapter=adapter)
        if lengths is None:
            h_last = h_out[:, -1]
        else:
            assert self.cfg.input_mode == "tokens", \
                "bucketed (lengths-masked) prefill needs token inputs"
            assert all(k in (ATTN, MLA) for seg in self.segments
                       for k in seg.kinds), \
                "bucketed prefill cannot mask Mamba states post-hoc"
            idx = jnp.maximum(lengths - 1, 0).astype(jnp.int32)
            h_last = jnp.take_along_axis(
                h_out, jnp.broadcast_to(idx[:, None, None],
                                        (B, 1, h_out.shape[-1])), 1)[:, 0]
            lens = lengths[None, :, None]          # vs pos leaves [G, B, cap]
            filled = [
                {k: dict(c, pos=jnp.where(c["pos"] < lens, c["pos"], -1))
                 for k, c in seg.items()}
                for seg in filled]
        logits = self.unembed(params, h_last[:, None])[:, 0]
        caches = {"segments": filled, "cross_kv": cross_kv}
        if return_h:
            return logits, caches, h_last
        return logits, caches

    # ------------------------------------------------------- paged kv caches
    def supports_paged(self) -> bool:
        """The paged backend covers token-input, attention-only nets (the
        serving/RLHF configs). MLA/Mamba states are not paged (yet)."""
        return (self.cfg.input_mode == "tokens"
                and all(k == ATTN for seg in self.segments for k in seg.kinds))

    def init_paged_pools(self, num_pages: int, page_size: int, dtype) -> list:
        """Per-segment stacked paged KV pools ([n_groups, P, ps, kvh, hd]
        per attention slot). The block table is shared across layers; each
        layer owns its physical pool."""
        from repro import paged as PG
        assert self.supports_paged(), \
            f"paged cache needs attention-only token models, got {self.cfg.name}"
        pools = []
        for seg in self.segments:
            slot_pools = {}
            for i in range(len(seg.kinds)):
                c = PG.init_pool(self.cfg, num_pages, page_size, dtype)
                slot_pools[f"slot{i}"] = jax.tree.map(
                    lambda x: jnp.broadcast_to(x, (seg.n_groups,) + x.shape), c)
            pools.append(slot_pools)
        return pools

    def paged_prefill(self, params, batch, pools, block_tables, lengths, *,
                      adapter=None, return_h: bool = False):
        """Prefill into paged pools: dense single-pass prompt compute, then
        the per-layer K/V scattered to the sequences' pages (gather/scatter
        prefill). batch["tokens"] [B, S]; block_tables [B, nb] int32;
        lengths [B] valid-token counts — logits come from position
        ``lengths-1``, so bucket-padded prompts are exact. Returns
        (last-valid-position logits [B, V], pools[, h_last])."""
        from repro import paged as PG
        S = batch["tokens"].shape[1]
        logits, caches, h_last = self.prefill(params, batch, S,
                                              adapter=adapter,
                                              lengths=lengths, return_h=True)
        new_pools = []
        for si, seg in enumerate(self.segments):
            slot_pools = {}
            for i in range(len(seg.kinds)):
                filled = caches["segments"][si][f"slot{i}"]   # k/v [G,B,S,..]
                scatter = jax.vmap(PG.scatter_prefill,
                                   in_axes=(0, 0, 0, None, None))
                slot_pools[f"slot{i}"] = scatter(
                    pools[si][f"slot{i}"], filled["k"], filled["v"],
                    block_tables, lengths)
            new_pools.append(slot_pools)
        if return_h:
            return logits, new_pools, h_last
        return logits, new_pools

    def paged_prefill_suffix(self, params, batch, pools, block_tables,
                             start, lengths, *, adapter=None,
                             return_h: bool = False):
        """Prefill only the *suffix* of a prompt whose prefix KV already
        sits in the pool (cross-request prefix cache hit). batch["tokens"]
        [B, Sb] holds ``prompt[start:]`` right-padded to the suffix bucket;
        ``start`` [B] is the per-row count of cached prefix tokens and
        ``lengths`` [B] the full prompt length. Suffix K/V is appended to
        the pool first and attention gathers from the pool (the
        :meth:`paged_decode_multi` layout), so a query at position p sees
        cached prefix entries (idx < start) and earlier suffix entries
        through one and the same mask — a cold run (start = 0) and a warm
        run compute the identical per-position function. Returns
        (last-prompt-position logits [B, V], pools[, h_last])."""
        tokens = batch["tokens"]
        B, Sb = tokens.shape
        j = jnp.arange(Sb, dtype=jnp.int32)[None, :]
        pos = start[:, None].astype(jnp.int32) + j
        positions = jnp.where(pos < lengths[:, None], pos, -1)
        logits_all, h_all, new_pools = self.paged_decode_multi(
            params, pools, tokens, positions, block_tables, adapter=adapter)
        idx = jnp.clip(lengths - start - 1, 0, Sb - 1).astype(jnp.int32)
        logits = jnp.take_along_axis(
            logits_all, jnp.broadcast_to(idx[:, None, None],
                                         (B, 1, logits_all.shape[-1])),
            1)[:, 0]
        if return_h:
            h_last = jnp.take_along_axis(
                h_all, jnp.broadcast_to(idx[:, None, None],
                                        (B, 1, h_all.shape[-1])), 1)[:, 0]
            return logits, new_pools, h_last
        return logits, new_pools

    def paged_decode_step(self, params, pools, token, position, block_tables,
                          *, use_kernel: bool = False, adapter=None):
        """One-token decode over paged pools. token/position [B] (position
        is the logical index being written); block_tables [B, nb].
        Returns (logits [B, V], pools)."""
        from repro.paged.attention import paged_attention_decode
        cfg = self.cfg
        lora = (adapter or {}).get("lora")
        h = self.embed(params, token[:, None])
        new_pools = []
        for si, seg in enumerate(self.segments):
            def group_dec(hh, xs, seg=seg):
                gp, pool, ad = xs
                new_pool = {}
                for i in range(len(seg.kinds)):
                    slot = gp[f"slot{i}"]
                    sad = (ad or {}).get(f"slot{i}") or {}
                    x = L.rms_norm(hh, slot["norm1"], cfg.norm_eps)
                    y, np_ = paged_attention_decode(
                        slot["mixer"], x, position, pool[f"slot{i}"],
                        block_tables, cfg, use_kernel=use_kernel,
                        adapter=sad.get("mixer"))
                    hh = hh + y
                    new_pool[f"slot{i}"] = np_
                    if self._seg_has_ffn(seg, i):
                        x2 = L.rms_norm(hh, slot["norm2"], cfg.norm_eps)
                        is_moe = seg.moe_flags[i] and cfg.moe is not None
                        if is_moe:
                            y2, _ = MOE.moe_fwd(slot["ffn"], x2, cfg)
                        else:
                            y2 = L.mlp_fwd(slot["ffn"], x2, cfg.mlp_gated,
                                           adapter=sad.get("ffn"))
                        hh = hh + y2
                return hh, new_pool

            xs = (params[f"segment{si}"], pools[si],
                  lora.get(f"segment{si}") if lora else None)
            h, seg_pool = jax.lax.scan(group_dec, h, xs)
            new_pools.append(seg_pool)
        logits = self.unembed(params, h)[:, 0]
        return logits, new_pools

    def decode_step(self, params, caches, token, position, *, window: int = 0,
                    adapter=None):
        """token [B] int32, position [B] int32 -> (logits [B,V], caches)."""
        cfg = self.cfg
        lora = (adapter or {}).get("lora")
        h = self.embed(params, token[:, None])
        cross_kv = caches.get("cross_kv")
        new_segments = []
        for si, seg in enumerate(self.segments):
            def group_dec(hh, xs, seg=seg):
                gp, cache, ckv, ad = xs
                new_cache = {}
                for i, kind in enumerate(seg.kinds):
                    slot = gp[f"slot{i}"]
                    sad = (ad or {}).get(f"slot{i}") or {}
                    x = L.rms_norm(hh, slot["norm1"], cfg.norm_eps)
                    if kind == ATTN:
                        y, nc = L.attention_decode(slot["mixer"], x, position,
                                                   cache[f"slot{i}"], cfg,
                                                   window=window,
                                                   adapter=sad.get("mixer"))
                    elif kind == MLA:
                        y, nc = L.mla_decode(slot["mixer"], x, position,
                                             cache[f"slot{i}"], cfg,
                                             window=window)
                    else:
                        y, nc = M.mamba_decode(slot["mixer"], x,
                                               cache[f"slot{i}"], cfg)
                    hh = hh + y
                    new_cache[f"slot{i}"] = nc
                    if ckv is not None:
                        xx = L.rms_norm(hh, slot["norm_x"], cfg.norm_eps)
                        hh = hh + L.cross_attention_fwd(slot["cross"], xx,
                                                        ckv[i], cfg)
                    if self._seg_has_ffn(seg, i):
                        x2 = L.rms_norm(hh, slot["norm2"], cfg.norm_eps)
                        is_moe = seg.moe_flags[i] and cfg.moe is not None
                        if is_moe:
                            y2, _ = MOE.moe_fwd(slot["ffn"], x2, cfg)
                        else:
                            y2 = L.mlp_fwd(slot["ffn"], x2, cfg.mlp_gated,
                                           adapter=sad.get("ffn"))
                        hh = hh + y2
                return hh, new_cache

            xs = (params[f"segment{si}"], caches["segments"][si],
                  cross_kv[si] if cross_kv is not None else None,
                  lora.get(f"segment{si}") if lora else None)
            h, seg_cache = jax.lax.scan(group_dec, h, xs)
            new_segments.append(seg_cache)
        logits = self.unembed(params, h)[:, 0]
        new_caches = dict(caches)
        new_caches["segments"] = new_segments
        return logits, new_caches

    # ------------------------------------------------- speculative decoding
    def supports_spec_decode(self) -> bool:
        """The draft/verify path covers token-input attention-only nets
        (rolling-pos dense caches and paged pools both self-heal rejected
        drafts by position masking; Mamba/MLA states cannot roll back)."""
        return (self.cfg.input_mode == "tokens" and self.cfg.mtp_depth > 0
                and all(k == ATTN for seg in self.segments
                        for k in seg.kinds))

    def decode_multi(self, params, caches, tokens, positions, *,
                     window: int = 0, adapter=None):
        """T-token verify forward over the dense rolling cache. tokens
        [B, T] int32, positions [B, T] absolute (consecutive per row; a -1
        row writes only dead entries). Returns (logits [B, T, V],
        h [B, T, D] pre-final-norm trunk states, caches) — logits[:, j]
        scores the token at position ``positions[:, j] + 1``. Rejected-draft
        cache entries need no rollback: their stored positions exceed any
        later query position, so the mask hides them until the rolling
        buffer overwrites them (attention-only models)."""
        cfg = self.cfg
        assert all(k == ATTN for seg in self.segments for k in seg.kinds), \
            "decode_multi needs attention-only models"
        lora = (adapter or {}).get("lora")
        h = self.embed(params, tokens)
        new_segments = []
        for si, seg in enumerate(self.segments):
            def group_dec(hh, xs, seg=seg):
                gp, cache, ad = xs
                new_cache = {}
                for i in range(len(seg.kinds)):
                    slot = gp[f"slot{i}"]
                    sad = (ad or {}).get(f"slot{i}") or {}
                    x = L.rms_norm(hh, slot["norm1"], cfg.norm_eps)
                    y, nc = L.attention_decode_multi(
                        slot["mixer"], x, positions, cache[f"slot{i}"], cfg,
                        window=window, adapter=sad.get("mixer"))
                    hh = hh + y
                    new_cache[f"slot{i}"] = nc
                    if self._seg_has_ffn(seg, i):
                        x2 = L.rms_norm(hh, slot["norm2"], cfg.norm_eps)
                        is_moe = seg.moe_flags[i] and cfg.moe is not None
                        if is_moe:
                            y2, _ = MOE.moe_fwd(slot["ffn"], x2, cfg)
                        else:
                            y2 = L.mlp_fwd(slot["ffn"], x2, cfg.mlp_gated,
                                           adapter=sad.get("ffn"))
                        hh = hh + y2
                return hh, new_cache

            xs = (params[f"segment{si}"], caches["segments"][si],
                  lora.get(f"segment{si}") if lora else None)
            h, seg_cache = jax.lax.scan(group_dec, h, xs)
            new_segments.append(seg_cache)
        logits = self.unembed(params, h)
        new_caches = dict(caches)
        new_caches["segments"] = new_segments
        return logits, h, new_caches

    def paged_decode_multi(self, params, pools, tokens, positions,
                           block_tables, *, adapter=None):
        """T-token verify forward over paged pools (the paged twin of
        :meth:`decode_multi`). tokens/positions [B, T]; position -1 entries
        are dropped writes (idle or finished rows). The page manager must
        have grown each live row by T logical tokens first
        (``PageManager.append_tokens``); after acceptance the caller
        truncates back (``PageManager.truncate``). Returns (logits
        [B, T, V], h [B, T, D], pools)."""
        from repro.paged.attention import paged_attention_decode_multi
        cfg = self.cfg
        lora = (adapter or {}).get("lora")
        h = self.embed(params, tokens)
        new_pools = []
        for si, seg in enumerate(self.segments):
            def group_dec(hh, xs, seg=seg):
                gp, pool, ad = xs
                new_pool = {}
                for i in range(len(seg.kinds)):
                    slot = gp[f"slot{i}"]
                    sad = (ad or {}).get(f"slot{i}") or {}
                    x = L.rms_norm(hh, slot["norm1"], cfg.norm_eps)
                    y, np_ = paged_attention_decode_multi(
                        slot["mixer"], x, positions, pool[f"slot{i}"],
                        block_tables, cfg, adapter=sad.get("mixer"))
                    hh = hh + y
                    new_pool[f"slot{i}"] = np_
                    if self._seg_has_ffn(seg, i):
                        x2 = L.rms_norm(hh, slot["norm2"], cfg.norm_eps)
                        is_moe = seg.moe_flags[i] and cfg.moe is not None
                        if is_moe:
                            y2, _ = MOE.moe_fwd(slot["ffn"], x2, cfg)
                        else:
                            y2 = L.mlp_fwd(slot["ffn"], x2, cfg.mlp_gated,
                                           adapter=sad.get("ffn"))
                        hh = hh + y2
                return hh, new_pool

            xs = (params[f"segment{si}"], pools[si],
                  lora.get(f"segment{si}") if lora else None)
            h, seg_pool = jax.lax.scan(group_dec, h, xs)
            new_pools.append(seg_pool)
        logits = self.unembed(params, h)
        return logits, h, new_pools
