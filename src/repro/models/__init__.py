from repro.models.transformer import Model, Segment, build_segments

__all__ = ["Model", "Segment", "build_segments"]
