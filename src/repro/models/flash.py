"""Memory-efficient (flash) attention in pure JAX with a custom VJP.

Forward: online-softmax scan over KV chunks — never materializes the
[Sq, Sk] score matrix; residuals are only (q, k, v, out, lse). Backward:
recomputes scores chunk-by-chunk. fp32 accumulation throughout.

This is the XLA-level twin of the Pallas TPU kernel in
``repro.kernels.flash_attention`` (same blocking strategy; the kernel owns
the VMEM tiling). The dry-run and CPU tests run this path; kernels/ tests
assert both agree with the naive oracle.

Masking is structural: ``causal`` and ``window`` (sliding) are static; the
chunk loop uses absolute indices so padded KV positions are masked out.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _chunk_mask(q_idx, k_idx, causal: bool, window: int, kv_len: int):
    """[Sq, Ck] bool validity. k_idx may exceed kv_len-1 (padding)."""
    m = k_idx[None, :] < kv_len
    if causal:
        m &= k_idx[None, :] <= q_idx[:, None]
        if window:
            m &= k_idx[None, :] > q_idx[:, None] - window
    return m


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_sdpa(q, k, v, causal: bool = True, window: int = 0,
               chunk: int = 1024, q_offset: int = 0):
    """q [B,Sq,H,D], k/v [B,Sk,K,Dk/Dv], H % K == 0. Returns [B,Sq,H,Dv]."""
    out, _ = _flash_fwd_res(q, k, v, causal, window, chunk, q_offset)
    return out


def _pad_kv(k, v, chunk):
    Sk = k.shape[1]
    pad = (-Sk) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return k, v, Sk


def _flash_fwd_res(q, k, v, causal, window, chunk, q_offset):
    B, Sq, H, D = q.shape
    K = k.shape[2]
    Dv = v.shape[-1]
    G = H // K
    scale = 1.0 / math.sqrt(D)
    k, v, Sk = _pad_kv(k, v, chunk)
    nc = k.shape[1] // chunk
    qg = (q * scale).reshape(B, Sq, K, G, D)
    q_idx = jnp.arange(Sq) + q_offset
    kc = k.reshape(B, nc, chunk, K, D).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nc, chunk, K, Dv).transpose(1, 0, 2, 3, 4)

    def body(carry, xs):
        acc, m, l = carry
        kb, vb, ci = xs
        s = jnp.einsum("bqkgd,bckd->bqkgc", qg, kb).astype(jnp.float32)
        k_idx = ci * chunk + jnp.arange(chunk)
        mask = _chunk_mask(q_idx, k_idx, causal, window, Sk)
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bqkgc,bckd->bqkgd", p.astype(vb.dtype), vb).astype(jnp.float32)
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((B, Sq, K, G, Dv), jnp.float32)
    m0 = jnp.full((B, Sq, K, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, K, G), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0),
                                  (kc, vc, jnp.arange(nc)))
    l_safe = jnp.maximum(l, 1e-30)
    out = (acc / l_safe[..., None]).reshape(B, Sq, H, Dv).astype(q.dtype)
    lse = m + jnp.log(l_safe)                                   # [B,Sq,K,G]
    return out, lse


def _fwd(q, k, v, causal, window, chunk, q_offset):
    out, lse = _flash_fwd_res(q, k, v, causal, window, chunk, q_offset)
    return out, (q, k, v, out, lse)


def _bwd(causal, window, chunk, q_offset, res, dout):
    q, k, v, out, lse = res
    B, Sq, H, D = q.shape
    K = k.shape[2]
    Dv = v.shape[-1]
    G = H // K
    scale = 1.0 / math.sqrt(D)
    kp, vp, Sk = _pad_kv(k, v, chunk)
    nc = kp.shape[1] // chunk
    qg = (q * scale).reshape(B, Sq, K, G, D)
    dog = dout.reshape(B, Sq, K, G, Dv)
    og = out.reshape(B, Sq, K, G, Dv)
    delta = jnp.sum(dog.astype(jnp.float32) * og.astype(jnp.float32), -1)
    q_idx = jnp.arange(Sq) + q_offset
    kc = kp.reshape(B, nc, chunk, K, D).transpose(1, 0, 2, 3, 4)
    vc = vp.reshape(B, nc, chunk, K, Dv).transpose(1, 0, 2, 3, 4)

    def body(dq_acc, xs):
        kb, vb, ci = xs
        s = jnp.einsum("bqkgd,bckd->bqkgc", qg, kb).astype(jnp.float32)
        k_idx = ci * chunk + jnp.arange(chunk)
        mask = _chunk_mask(q_idx, k_idx, causal, window, Sk)
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        p = jnp.exp(s - lse[..., None])                         # [B,q,K,G,c]
        dv_b = jnp.einsum("bqkgc,bqkgd->bckd", p.astype(dout.dtype), dog)
        dp = jnp.einsum("bqkgd,bckd->bqkgc", dog, vb).astype(jnp.float32)
        ds = p * (dp - delta[..., None])                        # fp32
        ds = ds.astype(q.dtype)
        dq_b = jnp.einsum("bqkgc,bckd->bqkgd", ds, kb)
        dk_b = jnp.einsum("bqkgc,bqkgd->bckd", ds, qg)
        return dq_acc + dq_b.astype(jnp.float32), (dk_b, dv_b)

    dq0 = jnp.zeros((B, Sq, K, G, D), jnp.float32)
    dq, (dk_c, dv_c) = jax.lax.scan(body, dq0, (kc, vc, jnp.arange(nc)))
    dq = (dq * scale).reshape(B, Sq, H, D).astype(q.dtype)
    dk = dk_c.transpose(1, 0, 2, 3, 4).reshape(B, nc * chunk, K, D)[:, :Sk]
    dv = dv_c.transpose(1, 0, 2, 3, 4).reshape(B, nc * chunk, K, Dv)[:, :Sk]
    return dq, dk.astype(k.dtype), dv.astype(v.dtype)


flash_sdpa.defvjp(_fwd, _bwd)
