"""Checkpointing: pytree <-> npz with path-keyed leaves. Sharding-aware:
arrays are gathered to host on save and re-placed with the provided
shardings on restore (per-leaf NamedSharding tree optional)."""
from __future__ import annotations

import json
import os
import re
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for kp, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in kp)
        out[key] = np.asarray(leaf)
    return out


def save(ckpt_dir: str, step: int, tree: Any) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    tmp = path + ".tmp.npz"
    flat = _flatten(tree)
    np.savez(tmp, **flat)
    os.replace(tmp, path)
    return path


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for f in os.listdir(ckpt_dir)
             if (m := re.match(r"step_(\d+)\.npz$", f))]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like: Any,
            shardings: Any = None) -> Any:
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    data = np.load(path)
    flat_like = jax.tree_util.tree_flatten_with_path(like)[0]
    treedef = jax.tree_util.tree_structure(like)
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(flat_like))
    leaves = []
    for (kp, leaf), sh in zip(flat_like, shard_leaves):
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in kp)
        arr = data[key]
        assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        arr = arr.astype(leaf.dtype)
        leaves.append(jax.device_put(arr, sh) if sh is not None
                      else jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves)
