"""Checkpointing: pytree <-> npz with path-keyed leaves. Sharding-aware:
arrays are gathered to host on save and re-placed with the provided
shardings on restore (per-leaf NamedSharding tree optional).

``restore(..., memory_kind=...)`` targets a memory kind instead of the
device default — with an active offload plan, trees that would be parked
immediately after resume restore straight into host memory
(``kernels.compat.host_memory_kind()``) and never transit HBM; feed them
to ``OffloadExecutor.adopt_parked``. On backends without memory kinds the
leaves stay as host numpy arrays (the parking lot's fallback
representation), which ``adopt_parked`` accepts unchanged."""
from __future__ import annotations

import json
import os
import re
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for kp, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in kp)
        out[key] = np.asarray(leaf)
    return out


def save(ckpt_dir: str, step: int, tree: Any) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    tmp = path + ".tmp.npz"
    flat = _flatten(tree)
    np.savez(tmp, **flat)
    os.replace(tmp, path)
    return path


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for f in os.listdir(ckpt_dir)
             if (m := re.match(r"step_(\d+)\.npz$", f))]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like: Any, shardings: Any = None,
            *, memory_kind: str = None) -> Any:
    """Load step ``step`` shaped/typed like ``like``. ``memory_kind``
    (e.g. ``compat.host_memory_kind()``) retargets placement: leaves land
    in that memory space — or stay as host numpy arrays when the backend
    has no such kind — instead of spiking HBM on the way to a parking
    lot."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    data = np.load(path)
    flat_like = jax.tree_util.tree_flatten_with_path(like)[0]
    treedef = jax.tree_util.tree_structure(like)
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(flat_like))
    kind_ok = False
    if memory_kind is not None:
        from repro.kernels import compat
        kind_ok = memory_kind in (compat.host_memory_kind(),
                                  compat.device_memory_kind())
    leaves = []
    for (kp, leaf), sh in zip(flat_like, shard_leaves):
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in kp)
        arr = data[key]
        assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        arr = arr.astype(leaf.dtype)
        if memory_kind is not None:
            if not kind_ok:         # no such space: stay host-resident
                leaves.append(arr)
                continue
            if sh is not None:
                sh = sh.with_memory_kind(memory_kind)
            else:
                sh = jax.sharding.SingleDeviceSharding(
                    jax.devices()[0], memory_kind=memory_kind)
        leaves.append(jax.device_put(arr, sh) if sh is not None
                      else jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves)
