"""The paper's own study models (DeepSpeed-Chat / ColossalChat workloads).

OPT-1.3b / OPT-350m (actor-ref / critic-reward pair), GPT2-xl / GPT2-medium,
and Llama-2-7b from Appendix C. These drive the fragmentation study and the
Table-1/Table-2 reproduction benchmarks.
"""
from repro.configs.base import ATTN, ModelConfig, register

OPT_1_3B = register(ModelConfig(
    name="opt_1_3b", family="dense",
    num_layers=24, d_model=2048, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=50272, period=(ATTN,),
    qkv_bias=True, mlp_gated=False, tie_embeddings=True,
    remat="none", source="[hf:facebook/opt-1.3b]",
))

OPT_350M = register(ModelConfig(
    name="opt_350m", family="dense",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=16,
    d_ff=4096, vocab_size=50272, period=(ATTN,),
    qkv_bias=True, mlp_gated=False, tie_embeddings=True,
    remat="none", source="[hf:facebook/opt-350m]",
))

GPT2_XL = register(ModelConfig(
    name="gpt2_xl", family="dense",
    num_layers=48, d_model=1600, num_heads=25, num_kv_heads=25,
    d_ff=6400, vocab_size=50257, period=(ATTN,),
    qkv_bias=True, mlp_gated=False, tie_embeddings=True,
    remat="none", source="[hf:gpt2-xl]",
))

GPT2_MEDIUM = register(ModelConfig(
    name="gpt2_medium", family="dense",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=16,
    d_ff=4096, vocab_size=50257, period=(ATTN,),
    qkv_bias=True, mlp_gated=False, tie_embeddings=True,
    remat="none", source="[hf:gpt2-medium]",
))

OPT_6_7B = register(ModelConfig(
    name="opt_6_7b", family="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=32,
    d_ff=16384, vocab_size=50272, period=(ATTN,),
    qkv_bias=True, mlp_gated=False, tie_embeddings=True,
    remat="none", source="[hf:facebook/opt-6.7b]",
))

LLAMA2_7B = register(ModelConfig(
    name="llama2_7b", family="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=32,
    d_ff=11008, vocab_size=32000, period=(ATTN,),
    source="[hf:meta-llama/Llama-2-7b]",
))
