"""internvl2-2b [vlm] — InternViT + InternLM2 [arXiv:2404.16821].

Backbone only: the ViT/projector frontend is the allowed stub —
``input_specs()`` supplies precomputed patch embeddings prepended to the
token embeddings (``num_prefix_embeddings``).
"""
from repro.configs.base import ATTN, ModelConfig, register

CONFIG = register(ModelConfig(
    name="internvl2_2b",
    family="vlm",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=92553,
    period=(ATTN,),
    input_mode="embeddings",
    num_prefix_embeddings=256,    # one 448x448 tile -> 256 patch tokens
    rope_theta=1_000_000.0,
    source="[arXiv:2404.16821]",
))
