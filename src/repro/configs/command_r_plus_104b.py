"""command-r-plus-104b [dense] — GQA, no-bias [hf:CohereForAI/c4ai-command-r-v01]."""
from repro.configs.base import ATTN, ModelConfig, register

CONFIG = register(ModelConfig(
    name="command_r_plus_104b",
    family="dense",
    num_layers=64,
    d_model=12288,
    num_heads=96,
    num_kv_heads=8,
    d_ff=33792,
    vocab_size=256000,
    period=(ATTN,),
    qkv_bias=False,
    rope_theta=75_000_000.0,
    tie_embeddings=True,
    # >=100B on a 256-chip v5e pod: bf16 Adam moments (DESIGN.md §6)
    optimizer="adamw_bf16",
    microbatches=2,           # same trade as qwen1_5_110b (§Perf C)
    source="[hf:CohereForAI/c4ai-command-r-v01]",
))
