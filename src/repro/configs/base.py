"""Config system: model configs, input shapes, and the architecture registry.

Every assigned architecture lives in its own ``configs/<id>.py`` file and
registers a full-size :class:`ModelConfig` plus a reduced smoke variant
(2 layers, d_model <= 512, <= 4 experts) used by CPU tests.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field, replace
from typing import Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Layer kinds making up a repeating "period" of the network.
# ---------------------------------------------------------------------------
ATTN = "attn"     # (sliding-window capable) GQA/MHA self-attention block
MLA = "mla"       # DeepSeek multi-head latent attention block
MAMBA = "mamba"   # Mamba2 SSD block


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int                 # per-expert FFN hidden size
    num_shared_experts: int = 0   # DeepSeek-style always-on shared experts
    router_aux_coef: float = 0.01
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V3 multi-head latent attention dims."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 SSD dims."""
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk_size: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int                # query heads (0 for attention-free)
    num_kv_heads: int
    d_ff: int                     # dense FFN hidden (0 if every layer is MoE/SSM)
    vocab_size: int
    # --- layer pattern ------------------------------------------------------
    # The network is `num_layers / len(period)` repetitions of `period`.
    period: Tuple[str, ...] = (ATTN,)
    moe_period: Tuple[bool, ...] = (False,)   # which period slots are MoE FFNs
    first_k_dense: int = 0                    # leading layers forced dense FFN
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    # --- attention details --------------------------------------------------
    head_dim: int = 0                         # 0 => d_model // num_heads
    qkv_bias: bool = False
    mlp_gated: bool = True                    # swiglu (3 mats) vs gelu (2 mats)
    rope_theta: float = 10000.0
    sliding_window: int = 0                   # 0 = full attention at train time
    # decode-time window used only for the long_500k sub-quadratic path:
    long_context_window: int = 8192
    # --- structure ----------------------------------------------------------
    encoder_layers: int = 0                   # >0 => encoder-decoder
    input_mode: str = "tokens"                # tokens | embeddings | encdec
    num_prefix_embeddings: int = 0            # VLM patch / audio frame stub len
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    mtp_depth: int = 0                        # DeepSeek multi-token prediction
    # --- numerics / memory defaults (see DESIGN.md §6) ----------------------
    param_dtype: str = "bfloat16"
    optimizer: str = "adamw"                  # adamw | adamw_bf16 | adafactor
    remat: str = "full"                       # none | dots | full | offload
    microbatches: int = 1                     # gradient-accumulation steps
    source: str = ""                          # citation bracket from the pool

    # -- derived -------------------------------------------------------------
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        if self.num_heads:
            return self.d_model // self.num_heads
        return 0

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer kind for all num_layers (decoder side)."""
        reps = -(-self.num_layers // len(self.period))
        return tuple((self.period * reps)[: self.num_layers])

    def layer_is_moe(self) -> Tuple[bool, ...]:
        reps = -(-self.num_layers // len(self.moe_period))
        flags = list((self.moe_period * reps)[: self.num_layers])
        for i in range(min(self.first_k_dense, self.num_layers)):
            flags[i] = False
        return tuple(flags)

    def num_period_groups(self) -> int:
        assert self.num_layers % len(self.period) == 0, (
            f"{self.name}: num_layers {self.num_layers} not divisible by "
            f"period {len(self.period)}")
        return self.num_layers // len(self.period)

    def param_count(self) -> int:
        """Analytic parameter count (used by rooflines / 6ND)."""
        d, v = self.d_model, self.vocab_size
        hd = self.resolved_head_dim()
        total = v * d  # embeddings
        if not self.tie_embeddings:
            total += v * d
        kinds = self.layer_kinds()
        is_moe = self.layer_is_moe()
        for kind, moe_l in zip(kinds, is_moe):
            total += 2 * d  # two norms
            if kind == ATTN:
                q = d * self.num_heads * hd
                kv = 2 * d * self.num_kv_heads * hd
                o = self.num_heads * hd * d
                total += q + kv + o
                if self.qkv_bias:
                    total += (self.num_heads + 2 * self.num_kv_heads) * hd
            elif kind == MLA:
                m = self.mla
                qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
                total += d * m.q_lora_rank + m.q_lora_rank * self.num_heads * qk_hd
                total += d * (m.kv_lora_rank + m.qk_rope_head_dim)
                total += m.kv_lora_rank * self.num_heads * (m.qk_nope_head_dim + m.v_head_dim)
                total += self.num_heads * m.v_head_dim * d
            elif kind == MAMBA:
                s = self.ssm
                di = s.d_inner(d)
                nh = s.n_heads(d)
                total += d * (2 * di + 2 * s.d_state * 0 + nh)  # in_proj(zx)+dt
                total += d * 2 * s.d_state * 2                  # B,C projections
                total += s.d_conv * (di + 2 * s.d_state)        # conv
                total += di * d                                 # out_proj
                total += 2 * nh                                 # A_log, D
            if moe_l and self.moe is not None:
                e = self.moe
                per = 3 * d * e.d_expert
                total += (e.num_experts + e.num_shared_experts) * per
                total += d * e.num_experts  # router
            elif kind != MAMBA:
                total += (3 if self.mlp_gated else 2) * d * self.d_ff
        # encoder stack (attention + dense FFN, full attention, no cache)
        for _ in range(self.encoder_layers):
            q = d * self.num_heads * hd
            kv = 2 * d * self.num_kv_heads * hd
            o = self.num_heads * hd * d
            total += q + kv + o + (3 if self.mlp_gated else 2) * d * self.d_ff + 2 * d
        total += d  # final norm
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: routed top-k + shared only)."""
        if self.moe is None:
            return self.param_count()
        e = self.moe
        dense_equiv = replace(
            self, moe=MoEConfig(
                num_experts=e.top_k, top_k=e.top_k, d_expert=e.d_expert,
                num_shared_experts=e.num_shared_experts))
        return dense_equiv.param_count()

    def smoke(self) -> "ModelConfig":
        """Reduced same-family variant for CPU smoke tests."""
        per = len(self.period)
        n_layers = per if per >= 2 else 2
        nh = min(self.num_heads, 4) or 0
        nkv = min(self.num_kv_heads, nh) or 0
        if self.num_heads and self.num_kv_heads:
            # keep GQA grouping valid
            while nh % max(nkv, 1):
                nkv -= 1
        kw = dict(
            name=self.name + "-smoke",
            num_layers=n_layers,
            d_model=256,
            num_heads=nh,
            num_kv_heads=nkv,
            d_ff=512 if self.d_ff else 0,
            vocab_size=512,
            head_dim=64 if self.num_heads else 0,
            first_k_dense=min(self.first_k_dense, 1),
            encoder_layers=2 if self.encoder_layers else 0,
            num_prefix_embeddings=8 if self.num_prefix_embeddings else 0,
            long_context_window=64,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            remat="none",
            optimizer="adamw",
            microbatches=1,
            param_dtype="float32",
        )
        if self.moe is not None:
            kw["moe"] = MoEConfig(
                num_experts=4, top_k=2, d_expert=128,
                num_shared_experts=min(self.moe.num_shared_experts, 1),
                capacity_factor=8.0)   # drop-free: exact decode==forward
        if self.mla is not None:
            kw["mla"] = MLAConfig(q_lora_rank=64, kv_lora_rank=32,
                                  qk_nope_head_dim=32, qk_rope_head_dim=16,
                                  v_head_dim=32)
        if self.ssm is not None:
            kw["ssm"] = SSMConfig(d_state=16, d_conv=4, expand=2,
                                  head_dim=32, chunk_size=32)
        # mtp_depth is inherited as-is: depth-k smoke configs exercise the
        # chained draft path (speculative decode) at CPU scale
        return replace(self, **kw)


# ---------------------------------------------------------------------------
# Input shapes (assigned).
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode | long_decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "long_decode"),
}

ASSIGNED_ARCHS = (
    "llama3_2_3b",
    "command_r_plus_104b",
    "mamba2_370m",
    "qwen1_5_110b",
    "granite_moe_3b_a800m",
    "internvl2_2b",
    "qwen1_5_4b",
    "deepseek_v3_671b",
    "jamba_v0_1_52b",
    "seamless_m4t_large_v2",
)
PAPER_ARCHS = ("opt_1_3b", "opt_350m", "gpt2_xl", "gpt2_medium", "llama2_7b")

_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    key = name.replace("-", "_").replace(".", "_")
    if key not in _REGISTRY:
        try:
            importlib.import_module(f"repro.configs.{key}")
        except ImportError as e:
            raise KeyError(f"unknown architecture {name!r}") from e
    return _REGISTRY[key]


def list_archs() -> Sequence[str]:
    for key in ASSIGNED_ARCHS + PAPER_ARCHS:
        get_config(key)
    return tuple(_REGISTRY)
