"""mamba2-370m [ssm] — SSD (state-space duality) [arXiv:2405.21060]."""
from repro.configs.base import MAMBA, ModelConfig, SSMConfig, register

CONFIG = register(ModelConfig(
    name="mamba2_370m",
    family="ssm",
    num_layers=48,
    d_model=1024,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    period=(MAMBA,),
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk_size=256),
    tie_embeddings=True,
    source="[arXiv:2405.21060]",
))
