"""granite-moe-3b-a800m [moe] — MoE 40e top-8 per the assigned structured
field (the bracket note says 32 experts; we follow the structured field,
see DESIGN.md §6) [hf:ibm-granite/granite-3.0-1b-a400m-base]."""
from repro.configs.base import ATTN, ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    name="granite_moe_3b_a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    d_ff=0,                       # every FFN is MoE
    vocab_size=49155,
    period=(ATTN,),
    moe_period=(True,),
    moe=MoEConfig(num_experts=40, top_k=8, d_expert=512),
    tie_embeddings=True,
    source="[hf:ibm-granite/granite-3.0-1b-a400m-base]",
))
