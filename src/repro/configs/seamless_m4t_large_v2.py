"""seamless-m4t-large-v2 [audio] — enc-dec, multimodal [arXiv:2308.11596].

Backbone only: the mel-spectrogram + conformer feature extractor is the
allowed stub — ``input_specs()`` supplies precomputed frame embeddings for
the 24-layer text/unit encoder; the 24-layer decoder is fully implemented
(self-attn with KV cache + cross-attn to encoder output).
"""
from repro.configs.base import ATTN, ModelConfig, register

CONFIG = register(ModelConfig(
    name="seamless_m4t_large_v2",
    family="audio",
    num_layers=24,                # decoder layers
    encoder_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    period=(ATTN,),
    input_mode="encdec",
    num_prefix_embeddings=1024,   # frame-embedding sequence length stub
    mlp_gated=False,              # classic transformer FFN
    source="[arXiv:2308.11596]",
))
