"""jamba-v0.1-52b [hybrid] — Mamba+attn 1:7 interleave, MoE 16e top-2
[arXiv:2403.19887].

Period of 8 layers: attention at offset 4 (attn_layer_period=8,
attn_layer_offset=4), MoE every other layer (expert_layer_period=2,
expert_layer_offset=1) — matching the Jamba-v0.1 card.
"""
from repro.configs.base import ATTN, MAMBA, ModelConfig, MoEConfig, SSMConfig, register

CONFIG = register(ModelConfig(
    name="jamba_v0_1_52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    period=(MAMBA, MAMBA, MAMBA, MAMBA, ATTN, MAMBA, MAMBA, MAMBA),
    moe_period=(False, True, False, True, False, True, False, True),
    moe=MoEConfig(num_experts=16, top_k=2, d_expert=14336),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=64, chunk_size=256),
    microbatches=2,
    source="[arXiv:2403.19887]",
))
