"""qwen1.5-4b [dense] — QKV bias, MHA (kv == heads) [hf:Qwen/Qwen1.5-0.5B]."""
from repro.configs.base import ATTN, ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen1_5_4b",
    family="dense",
    num_layers=40,
    d_model=2560,
    num_heads=20,
    num_kv_heads=20,
    d_ff=6912,
    vocab_size=151936,
    period=(ATTN,),
    qkv_bias=True,
    rope_theta=1_000_000.0,
    source="[hf:Qwen/Qwen1.5-0.5B]",
))
