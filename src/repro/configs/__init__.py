from repro.configs.base import (
    ASSIGNED_ARCHS, ATTN, MAMBA, MLA, MLAConfig, ModelConfig, MoEConfig,
    PAPER_ARCHS, SHAPES, SSMConfig, ShapeConfig, get_config, list_archs,
    register,
)

# paper models register on import so that get_config("opt_1_3b") etc. work
from repro.configs import paper_models as _paper_models  # noqa: F401

__all__ = [
    "ASSIGNED_ARCHS", "ATTN", "MAMBA", "MLA", "MLAConfig", "ModelConfig",
    "MoEConfig", "PAPER_ARCHS", "SHAPES", "SSMConfig", "ShapeConfig",
    "get_config", "list_archs", "register",
]
