"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed top-8, first 3 layers
dense, MTP [arXiv:2412.19437]."""
from repro.configs.base import MLA, MLAConfig, ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    name="deepseek_v3_671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,             # MLA: per-head KV reconstructed from latent
    d_ff=18432,                   # dense FFN width of the first_k_dense layers
    vocab_size=129280,
    period=(MLA,),
    moe_period=(True,),
    first_k_dense=3,
    moe=MoEConfig(num_experts=256, top_k=8, d_expert=2048,
                  num_shared_experts=1, router_aux_coef=0.001),
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    mtp_depth=1,
    # 671B on 256 v5e chips: factored second moment only (DESIGN.md §6)
    optimizer="adafactor",
    microbatches=4,           # §Perf hillclimb A: M -20%, X -31% vs mb=8
    source="[arXiv:2412.19437]",
))
