"""qwen1.5-110b [dense] — QKV bias [hf:Qwen/Qwen1.5-0.5B]."""
from repro.configs.base import ATTN, ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen1_5_110b",
    family="dense",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=49152,
    vocab_size=152064,
    period=(ATTN,),
    qkv_bias=True,
    rope_theta=1_000_000.0,
    optimizer="adamw_bf16",   # >=100B, see DESIGN.md §6
    microbatches=2,           # §Perf hillclimb C: X -49%, M -26% vs mb=4
    source="[hf:Qwen/Qwen1.5-0.5B]",
))
