"""The RLHF PPO trainer, with the paper's phase-boundary memory management
as a first-class feature — in two engine layouts:

  * ``engine="separate"`` — the four-model seed path (actor, critic,
    reference, reward as full parameter trees, two full optimizer states):
    the configuration the paper profiles.
  * ``engine="hydra"``    — the shared-base engine (``rlhf.engine``): ONE
    frozen trunk, per-role LoRA adapters + value heads, adapter-only
    optimizer states. Reference logp is the plain base forward (the ref
    copy disappears); rollout generates from merged weights re-merged at
    phase boundaries.

``PhaseMemoryManager`` is the JAX/TPU-native analogue of the paper's
``empty_cache()`` insertion (§3.3): at each phase boundary it deterministically
drops dead device buffers (explicit ``.delete()`` of phase-local arrays),
triggers host GC, and reports live device bytes — so the memory timeline of
a real run is observable, phase by phase, exactly like the paper's profiler
(App. B). On TPU, buffer *placement* churn is already avoided by design
(static shapes + donation — see rollout.py); what remains at boundaries is
reference hygiene, which this manager enforces.

``RLHFConfig.offload`` adds the runtime half of the paper's
phase-exclusivity story (``repro.offload``): role state is parked to host
between the phases that touch it and async-fetched back at the boundary —
``"optimizer"`` swaps the moments, ``"roles"`` adds the per-role
params/adapters, ``"all"`` also parks the hydra trunk's adapted leaves
while merged weights serve rollout. Parking is bit-exact, so every offload
level reproduces the ``"none"`` losses to the last ulp.
"""
from __future__ import annotations

import dataclasses
import gc
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import Model
from repro.rlhf.engine import ModelEngine
from repro.rlhf.ppo import gae, kl_shaped_rewards, whiten
from repro.rlhf.rollout import Rollout
from repro.steps import (init_lora_train_state, init_train_state,
                         make_lora_train_step, make_train_step, _prefix_len)

MEMORY_POLICIES = ("none", "after_inference", "after_training", "after_all")


def _jit_step(step):
    """Jit a train step unless the builder already jitted it internally
    (ZeRO steps are two programs with an eager grad re-shard between —
    see ``steps.make_train_step(shard=...)``)."""
    if getattr(step, "prejitted", False):
        return step
    return jax.jit(step, donate_argnums=(0,))


def live_device_bytes() -> int:
    """Live *device* bytes: arrays parked in the host memory kind by the
    offload subsystem don't count (numpy fallback copies never did) — they
    are accounted by :func:`live_host_bytes` instead."""
    from repro.kernels import compat
    host_kind = compat.host_memory_kind()
    total = 0
    for a in jax.live_arrays():
        if host_kind is not None and \
                getattr(a.sharding, "memory_kind", None) == host_kind:
            continue
        total += getattr(a, "nbytes", 0)
    return total


def live_host_bytes() -> int:
    """Live bytes of jax arrays placed in the *host* memory kind — the
    other half of :func:`live_device_bytes`, so offloaded state (parked
    role trees, remat-offloaded residuals) no longer vanishes from all
    accounting. Note the committed-numpy fallback transport parks plain
    ``np.ndarray`` copies that are not jax arrays; those are accounted by
    ``HostParkingLot.parked_bytes()`` and the two figures are merged with
    ``max`` (never summed — memory-kind parks appear in both) by
    ``PhaseMemoryManager._record``."""
    from repro.kernels import compat
    host_kind = compat.host_memory_kind()
    if host_kind is None:
        return 0
    return sum(getattr(a, "nbytes", 0) for a in jax.live_arrays()
               if getattr(a.sharding, "memory_kind", None) == host_kind)


def per_device_live_bytes(memory: str = "device") -> int:
    """Max-over-devices live bytes — the per-device HBM figure ZeRO cuts.
    Replicated arrays cost full size on every device; ZeRO-3-sharded trees
    cost 1/ndp. Equal to :func:`live_device_bytes` on one device.

    ``memory="host"`` counts host-memory-kind arrays instead (their
    shards live in each device's pinned host segment), so parked state is
    accounted per device by the same shard walk rather than vanishing."""
    assert memory in ("device", "host"), memory
    from repro.kernels import compat
    host_kind = compat.host_memory_kind()
    per: Dict[Any, int] = {}
    for a in jax.live_arrays():
        on_host = host_kind is not None and \
            getattr(a.sharding, "memory_kind", None) == host_kind
        if on_host != (memory == "host"):
            continue
        shards = getattr(a, "addressable_shards", None)
        if not shards:
            per[None] = per.get(None, 0) + getattr(a, "nbytes", 0)
        else:
            for s in shards:
                per[s.device] = per.get(s.device, 0) + s.data.nbytes
    return max(per.values()) if per else 0


@dataclass
class PhaseMemoryManager:
    """Phase-boundary memory hygiene + per-phase live-memory profiling.

    With an ``offload`` executor attached (``rl.offload != "none"``), each
    boundary also runs the offload schedule: park the trees the next phase
    doesn't touch *before* the live-bytes record (so eviction shows in the
    curve), async-fetch the next phase's trees after it — mirroring the
    park -> empty_cache -> record -> fetch order of the allocator
    simulator's boundary model.

    With a ``telemetry`` bundle attached (``obs.RunTelemetry``), every
    boundary additionally closes one tracer span per canonical runtime
    phase — carrying the measured live/host/PCIe bytes of the record it
    just took (zero recomputation) plus, when the trainer attached
    ``sim_phase_bytes``, the traced allocator-simulator's predicted bytes
    for that phase and the sim-vs-measured delta — and feeds the metrics
    registry (``rlhf_phase_*``). Phase spans tile the iteration exactly:
    each span runs from the previous boundary (or ``iteration_start``) to
    this one."""
    # none | after_inference | after_training | after_all
    policy: str = "after_inference"
    records: List[dict] = field(default_factory=list)
    offload: Optional[Any] = None      # offload.OffloadExecutor
    telemetry: Optional[Any] = None    # obs.RunTelemetry
    # obs.MemoryAttributor: when attached, every record classifies the
    # live set by owner in ONE walk — the record's live_bytes IS the
    # snapshot total, so the per-owner table on a phase span sums (with
    # the unattributed residue) to measured_bytes exactly
    attributor: Optional[Any] = None
    # runtime phase -> {"sim_bytes", "sim_peak_bytes"} from the traced
    # simulator (attached lazily by RLHFTrainer when sim_delta is on)
    sim_phase_bytes: Dict[str, dict] = field(default_factory=dict)

    def __post_init__(self):
        if self.policy not in MEMORY_POLICIES:
            raise ValueError(
                f"unknown memory policy {self.policy!r}; "
                f"expected one of {MEMORY_POLICIES}")
        self._phase_t0: Optional[float] = None   # tracer µs of phase start
        self._phase_peak = 0                     # mid-phase sample peak
        self._pcie_mark = 0                      # lot traffic at phase start
        self._iter_n = 0
        self._last_snap = None                   # most recent attribution

    def _record(self, phase: str, kind: str, **extra) -> dict:
        snap = None
        if self.attributor is not None:
            snap = self.attributor.snapshot()
            self._last_snap = snap
            live = snap.total_bytes
            # device and host totals come from the snapshot's single walk
            host = snap.host_unattributed + sum(snap.host_owners.values())
            if self.telemetry is not None:
                # the classification pass is telemetry work: charge it to
                # self-time so the <=2% overhead gate covers attribution
                self.telemetry.tracer.self_time_s += snap.walk_s
        else:
            live = live_device_bytes()
            host = live_host_bytes()
        # host-side accounting: memory-kind parks are live jax arrays
        # (live_host_bytes) AND lot entries; numpy-fallback parks are lot
        # entries only — max() merges without double counting
        if self.offload is not None:
            host = max(host, self.offload.lot.parked_bytes())
        rec = {"phase": phase, "kind": kind,
               "live_bytes": live,
               "live_bytes_per_device": (per_device_live_bytes()
                                         if jax.device_count() > 1 else live),
               "host_bytes": host,
               "t": time.time()}
        if snap is not None:
            rec["attrib"] = snap.table()
            rec["attrib_unattributed"] = snap.unattributed
        rec.update(extra)
        self.records.append(rec)
        return rec

    def _snapshot_for_dump(self):
        """Lazy snapshot source for the flight recorder: reuse the one the
        triggering record just took (same live set) instead of re-walking."""
        if self._last_snap is not None:
            return self._last_snap
        if self.attributor is not None:
            return self.attributor.snapshot()
        return None

    def _flight(self):
        return getattr(self.telemetry, "flight", None) \
            if self.telemetry is not None else None

    # ----------------------------------------------------------- telemetry
    def _pcie_total(self) -> int:
        if self.offload is None:
            return 0
        st = self.offload.lot.stats
        return st.bytes_parked_total + st.bytes_fetched_total

    def iteration_start(self):
        """Open the per-iteration parent span (telemetry only)."""
        if self.telemetry is None:
            return
        tr = self.telemetry.tracer
        tr.begin("ppo_iteration", cat="iteration", n=self._iter_n)
        self._phase_t0 = tr.now_us()
        self._phase_peak = 0
        self._pcie_mark = self._pcie_total()

    def iteration_end(self, **args):
        if self.telemetry is None:
            return
        self.telemetry.tracer.end(**args)
        self.telemetry.registry.counter(
            "rlhf_iterations_total", "completed PPO iterations").inc()
        self._iter_n += 1
        self._phase_t0 = None

    def _emit_phase_span(self, phase: str, kind: str, rec: dict):
        tel = self.telemetry
        tr = tel.tracer
        now = tr.now_us()
        t0 = self._phase_t0 if self._phase_t0 is not None else now
        pcie_now = self._pcie_total()
        args = {"kind": kind,
                "measured_bytes": rec["live_bytes"],
                "measured_peak_bytes": max(rec["live_bytes"],
                                           self._phase_peak),
                "measured_bytes_per_device": rec["live_bytes_per_device"],
                "host_bytes": rec["host_bytes"],
                "pcie_bytes": pcie_now - self._pcie_mark}
        if "attrib" in rec:
            args["attrib"] = rec["attrib"]
            args["attrib_unattributed"] = rec["attrib_unattributed"]
        sim = self.sim_phase_bytes.get(phase)
        if sim is not None:
            args.update(sim)
            args["sim_delta_bytes"] = rec["live_bytes"] - sim["sim_bytes"]
            # per-owner sim deltas: measured owner table vs the simulator's
            # per-state ledger at this phase's boundary record. Restricted
            # to the sim's group names — both sides use the same taxonomy
            sim_owners = sim.get("sim_owner_bytes")
            if sim_owners and "attrib" in rec:
                args["attrib_sim_delta"] = {
                    k: rec["attrib"].get(k, 0) - v
                    for k, v in sim_owners.items()}
        tr.complete(phase, "phase", t0, now - t0, **args)
        tr.sample("memory", {"device_mib": rec["live_bytes"] / 2**20,
                             "host_mib": rec["host_bytes"] / 2**20},
                  ts_us=now)
        reg = tel.registry
        reg.counter("rlhf_phase_total", "phase boundaries crossed").inc(
            phase=phase)
        reg.gauge("rlhf_phase_live_bytes",
                  "live device bytes at phase end").set(
            rec["live_bytes"], phase=phase)
        reg.gauge("rlhf_phase_host_bytes",
                  "host-resident bytes at phase end").set(
            rec["host_bytes"], phase=phase)
        reg.histogram("rlhf_phase_seconds", "wall time per phase").observe(
            (now - t0) / 1e6, phase=phase)
        for owner, b in rec.get("attrib", {}).items():
            reg.gauge("rlhf_owner_live_bytes",
                      "live device bytes by owner at phase end").set(
                b, owner=owner, phase=phase)
        self._phase_t0 = now
        self._phase_peak = 0
        self._pcie_mark = pcie_now

    def sample(self, phase: str, kind: str = "inference"):
        """Mid-phase measurement point (no hygiene): used where the live
        set changes inside a phase — e.g. hydra rollout decode, where the
        trunk's adapted leaves are parked while merged weights serve."""
        rec = self._record(phase, kind, sample=True)
        self._phase_peak = max(self._phase_peak, rec["live_bytes"])
        if self.telemetry is not None:
            tr = self.telemetry.tracer
            extra = {k: rec[k] for k in ("attrib", "attrib_unattributed")
                     if k in rec}
            tr.instant(f"{phase}:sample", cat="phase",
                       measured_bytes=rec["live_bytes"],
                       host_bytes=rec["host_bytes"], **extra)
            tr.sample("memory", {"device_mib": rec["live_bytes"] / 2**20,
                                 "host_mib": rec["host_bytes"] / 2**20})
        fl = self._flight()
        if fl is not None:
            fl.note("sample", phase=phase, live_bytes=rec["live_bytes"],
                    host_bytes=rec["host_bytes"])
            fl.check(rec["live_bytes"], snapshot_fn=self._snapshot_for_dump,
                     phase=phase, source="rlhf")

    def boundary(self, phase: str, kind: str, *drop):
        for tree in drop:
            jax.tree.map(
                lambda x: x.delete()
                if hasattr(x, "delete") and not x.is_deleted() else None,
                tree)
        if self.offload is not None:
            self.offload.park_for_boundary(phase)
        if (self.policy == "after_all"
                or (self.policy == "after_inference" and kind == "inference")
                or (self.policy == "after_training" and kind == "training")):
            gc.collect()
        rec = self._record(phase, kind)
        if self.telemetry is not None:
            self._emit_phase_span(phase, kind, rec)
        fl = self._flight()
        if fl is not None:
            # checked before the fetch: the record is the post-hygiene,
            # pre-fetch trough — the same point the simulator records
            fl.note("phase", phase=phase, kind=kind,
                    live_bytes=rec["live_bytes"],
                    host_bytes=rec["host_bytes"])
            fl.check(rec["live_bytes"], snapshot_fn=self._snapshot_for_dump,
                     phase=phase, source="rlhf")
        if self.offload is not None:
            self.offload.fetch_for_boundary(phase)


@dataclass
class RLHFConfig:
    prompt_len: int = 32
    gen_len: int = 32
    kl_coef: float = 0.1
    gamma: float = 1.0
    lam: float = 0.95
    ppo_epochs: int = 1
    lr: float = 1e-5
    critic_lr: float = 1e-5
    temperature: float = 1.0
    top_k: int = 50
    whiten_advantages: bool = True
    memory_policy: str = "after_inference"
    engine: str = "separate"        # separate | hydra
    lora_rank: int = 128            # hydra adapter rank (paper grid: 128)
    # runtime host-offload level (repro.offload): none | optimizer | roles
    # | all — which role state is parked to host between the phases that
    # touch it ("all" also parks the hydra trunk's adapted leaves while
    # merged weights serve rollout)
    offload: str = "none"
    # DP batch sharding of the scoring/training batches under a mesh
    # (DESIGN.md §3.6):
    #   "throughput" (default) — shard the batch over the DP axis when it
    #     divides; batch-dim loss reductions then run as per-device
    #     partials + a cross-device sum, which changes reduction ORDER vs
    #     the replicated batch — a documented ~ulp drift, accepted for
    #     the ndp-times-smaller per-device activations. A non-divisible
    #     batch falls back to replication WITH a warning (never silent).
    #   "strict" — sharded semantics are required: a batch that does not
    #     divide the DP size raises instead of silently replicating.
    # The bit-identity validation harness (zero_smoke, test_zero_rlhf)
    # deliberately uses non-divisible batches so state shards but batches
    # replicate and the arithmetic stays exactly single-device.
    batch_shard: str = "throughput"
    # fast decode path (DESIGN.md "Fast decode path"): MTP self-speculative
    # greedy rollout — bit-identical tokens/logps to vanilla greedy, fewer
    # decode dispatches. Forces temperature=0 / top_k=0 for the rollout.
    spec_decode: bool = False
    spec_k: int = 2
    # compile-bucket ladder for ragged prompt lengths (None = off)
    capture_buckets: Optional[Sequence[int]] = None


class RLHFTrainer:
    """PPO over (actor, critic, reference, reward). The reward model is any
    callable ``(tokens, mask) -> [B] float`` — a learned value-head model or
    a programmatic reward for the examples.

    With ``rl.engine == "hydra"`` the four roles share one frozen trunk
    (``critic_cfg`` is ignored — the critic/reward heads ride the actor
    trunk) and only adapter leaves train.

    ``shard`` (a ``sharding.ShardedContext``) makes the whole pipeline
    mesh-aware: params, grads, and optimizer state partition over the DP
    axis per ``shard.strat.zero_stage`` on *both* engines — the hydra path
    shards the frozen trunk with ZeRO-3 and the per-role adapters by rule,
    the separate path shards all four role trees. Rollout and merged-weight
    generation run under the same mesh from a gathered compute copy, and
    ``offload`` composes: the parking lot round-trips sharded leaves
    sharding-intact, so ``offload != "none"`` still parks exactly the
    per-device ZeRO shards. Every stage reproduces the unsharded losses
    bit-for-bit (the gather-compute/slice-update contract of
    ``steps.make_train_step`` — DESIGN.md §3).
    """

    def __init__(self, actor_cfg: ModelConfig, critic_cfg: ModelConfig,
                 rl: RLHFConfig, key, reward_fn: Optional[Callable] = None,
                 shard=None, telemetry=None):
        assert rl.engine in ("separate", "hydra"), rl.engine
        if rl.batch_shard not in ("strict", "throughput"):
            raise ValueError(
                f"unknown batch_shard {rl.batch_shard!r}; "
                "expected 'strict' or 'throughput'")
        self.rl = rl
        self.actor_cfg, self.critic_cfg = actor_cfg, critic_cfg
        self.reward_fn = reward_fn
        self.shard = shard
        # ambient mesh for the scoring/rollout programs: only a TP context
        # (ntp > 1) activates it, so the in-jit "model" constraint hints
        # resolve — pure-DP runs keep the historical mesh-free traces and
        # their bitwise contract intact (DESIGN.md §3 vs §9)
        self._tp_mesh = shard.mesh if shard is not None and \
            getattr(shard, "ntp", 1) > 1 else None
        self.telemetry = telemetry          # obs.RunTelemetry | None
        self._sim_attached = False
        self._gather_step_bytes: Optional[int] = None
        self.memory = PhaseMemoryManager(policy=rl.memory_policy,
                                         telemetry=telemetry)
        if rl.engine == "hydra":
            self._init_hydra(actor_cfg, rl, key)
        else:
            self._init_separate(actor_cfg, critic_cfg, rl, key)
        self.rollout = Rollout(
            self.actor, actor_cfg, capacity=rl.prompt_len + rl.gen_len,
            temperature=0.0 if rl.spec_decode else rl.temperature,
            top_k=0 if rl.spec_decode else rl.top_k,
            spec_decode=rl.spec_decode, spec_k=rl.spec_k,
            capture_buckets=rl.capture_buckets, mesh=self._tp_mesh)
        self.offload = self.offload_lot = None
        if rl.offload != "none":
            self._init_offload(rl)
        # phase-scoped buffer trees the attribution engine reads through
        # (merged rollout weights, rollout outputs, experience) — set and
        # cleared by _gen/make_experience/train_step
        self._live_buffers: Dict[str, Any] = {}
        self._compiled_recorded: set = set()
        if telemetry is not None:
            self._init_attribution(telemetry)

    # --------------------------------------------------------- attribution
    def _init_attribution(self, telemetry) -> None:
        """Create (or adopt) the run's MemoryAttributor and register this
        trainer's owner trees. Registration order is priority order on
        aliased arrays: the hydra trunk goes FIRST so the reference (which
        IS the base) and the merged-rollout leaves that alias non-adapted
        trunk arrays attribute to ``base_params``; the ``merged_rollout``
        owner then claims only the freshly merged copies."""
        from repro.obs import MemoryAttributor
        at = telemetry.attribution
        if at is None:
            at = telemetry.attribution = MemoryAttributor()
        if self.rl.engine == "hydra":
            at.register("base_params", lambda: self.base_params)
            at.register("reward_params", lambda: self.reward_adapter)
        else:
            at.register("ref_params", lambda: self.ref_params)
            at.register("reward_params", lambda: self.reward_params)
        at.register("actor_params", lambda: self.actor_state["params"])
        at.register("actor_opt", lambda: self.actor_state["opt"])
        at.register("critic_params", lambda: self.critic_state["params"])
        at.register("critic_opt", lambda: self.critic_state["opt"])
        # the ZeRO-3 rollout gather copies register BEFORE merged_rollout:
        # the merged tree's non-adapted leaves alias the gathered trunk,
        # and they are gather traffic, not freshly merged weights.
        # Under TP (shard.ntp > 1) the same copies are DP-gathered but stay
        # model-sharded at 1/ntp per device — a different animal in an OOM
        # report, so they get their own ``tp_gather`` owner (the _gen paths
        # pick the key by ntp; exactly one of the two is ever populated)
        at.register("zero_gather",
                    lambda: self._live_buffers.get("zero_gather"))
        at.register("tp_gather",
                    lambda: self._live_buffers.get("tp_gather"))
        at.register("merged_rollout",
                    lambda: self._live_buffers.get("merged_rollout"))
        at.register("rollout_buffers",
                    lambda: self._live_buffers.get("rollout"))
        at.register("experience",
                    lambda: self._live_buffers.get("experience"))
        self.memory.attributor = at

    # ------------------------------------------------------------- sharding
    @property
    def _gather_key(self) -> str:
        """Attribution owner of the rollout gather copies: ``zero_gather``
        in pure DP, ``tp_gather`` when the mesh has a model axis (the
        copies are DP-gathered but TP-resident at 1/ntp per device)."""
        return "tp_gather" if self._tp_mesh is not None else "zero_gather"

    def per_device_state_bytes(self) -> int:
        """Max-over-devices bytes of the persistent role state (params +
        optimizer moments) — the figure the ZeRO stages cut. Replicated
        trees cost full size per device; ZeRO-3 trees cost 1/ndp."""
        from repro.sharding import tree_per_device_bytes
        return tree_per_device_bytes(list(self._persistent_trees().values()))

    def _shard_batch(self, tree):
        """DP batch sharding per ``rl.batch_shard`` (DESIGN.md §3.6): place
        every batch-leading array in ``tree`` onto the data axis. Applied
        to the scoring batch and the training experience — the phases
        whose activations dominate — not to rollout (generation runs from
        the gathered compute copy on its own schedule). Reduction-order
        drift under a sharded batch is documented and accepted in
        throughput mode; strict mode refuses to fall back."""
        if self.shard is None or self.shard.ndp <= 1:
            return tree
        leaves = [x for x in jax.tree.leaves(tree)
                  if getattr(x, "ndim", 0) >= 1]
        if not leaves:
            return tree
        B = leaves[0].shape[0]
        ndp = self.shard.ndp
        if B % ndp != 0:
            if self.rl.batch_shard == "strict":
                raise ValueError(
                    f"batch_shard='strict': global batch {B} does not "
                    f"divide the DP size {ndp} — the batch would silently "
                    "replicate. Pad the batch or use "
                    "batch_shard='throughput'.")
            if not getattr(self, "_batch_shard_warned", False):
                self._batch_shard_warned = True
                import warnings
                warnings.warn(
                    f"RLHF batch {B} does not divide ndp={ndp}: "
                    "replicating the batch over the DP axis (state still "
                    "shards; see RLHFConfig.batch_shard)", stacklevel=3)
            return tree
        from jax.sharding import NamedSharding
        from jax.sharding import PartitionSpec as P
        from repro.sharding import dp_axes
        mesh = self.shard.mesh
        dp = dp_axes(mesh)
        dp = dp if len(dp) > 1 else dp[0]

        def place(x):
            if getattr(x, "ndim", 0) < 1 or x.shape[0] != B:
                return x
            spec = P(dp, *([None] * (x.ndim - 1)))
            return jax.device_put(x, NamedSharding(mesh, spec))

        return jax.tree.map(place, tree)

    def _persistent_trees(self) -> Dict[str, Any]:
        out = {"actor_params": self.actor_state["params"],
               "actor_opt": self.actor_state["opt"],
               "critic_params": self.critic_state["params"],
               "critic_opt": self.critic_state["opt"]}
        if self.rl.engine == "hydra":
            out["base_params"] = self.base_params
            out["reward_params"] = self.reward_adapter
        else:
            out["ref_params"] = self.ref_params
            out["reward_params"] = self.reward_params
        return out

    # --------------------------------------------------------------- offload
    def _init_offload(self, rl: RLHFConfig):
        """Runtime host-offload: compile the phase plan, bind it to a
        parking lot over the trainer's state accessors, and do the initial
        placement (everything the first phase doesn't touch goes to host)."""
        from repro.offload import HostParkingLot, OffloadExecutor, OffloadPlan
        states = self._offload_states()
        # a programmatic reward_fn means score_reward never touches the
        # reward model: park it once at start instead of swapping it
        # host<->device every iteration
        unused = ("reward_params",) if self.reward_fn is not None else ()
        plan = OffloadPlan.compile(rl.offload, engine=rl.engine,
                                   states=states, frozen_unused=unused)
        self.offload_lot = HostParkingLot()
        self.offload = OffloadExecutor(plan, self.offload_lot, states,
                                       telemetry=self.telemetry)
        self.memory.offload = self.offload
        self.offload.start()

    def _offload_states(self) -> Dict[str, Any]:
        """name -> (get, set) accessors over the trainer's live trees. The
        setters repoint every alias (train-state dicts, engine adapter
        views) so parked device buffers have no surviving references."""

        def state_slot(state_attr, slot, alias=None):
            def get():
                return getattr(self, state_attr)[slot]

            def set_(v):
                getattr(self, state_attr)[slot] = v
                if alias is not None:
                    self.engine.adapters[alias] = v
            return (get, set_)

        if self.rl.engine == "separate":
            def attr(name):
                return (lambda: getattr(self, name),
                        lambda v: setattr(self, name, v))
            return {
                "actor_params": state_slot("actor_state", "params"),
                "actor_opt": state_slot("actor_state", "opt"),
                "critic_params": state_slot("critic_state", "params"),
                "critic_opt": state_slot("critic_state", "opt"),
                "ref_params": attr("ref_params"),
                "reward_params": attr("reward_params"),
            }

        # hydra: the swappable unit of the trunk is its *adapted-site*
        # subtree — exactly the leaves merge_adapter replaces; the merged
        # rollout copy aliases everything else, which must stay put
        from repro.models import lora as LORA
        lora_sites = self.engine.lora_sites()

        def get_base():
            return LORA.adapted_subtree(self.base_params, lora_sites)

        def set_base(subtree):
            new = LORA.with_adapted_leaves(self.base_params, lora_sites,
                                           subtree)
            self.base_params = new
            self.engine.base_params = new
            self.ref_params = new          # reference IS the base

        def reward_acc():
            def get():
                return self.reward_adapter

            def set_(v):
                self.reward_adapter = v
                self.engine.adapters["reward"] = v
            return (get, set_)

        return {
            "base_params": (get_base, set_base),
            "actor_params": state_slot("actor_state", "params",
                                       alias="actor"),
            "actor_opt": state_slot("actor_state", "opt"),
            "critic_params": state_slot("critic_state", "params",
                                        alias="critic"),
            "critic_opt": state_slot("critic_state", "opt"),
            "reward_params": reward_acc(),
        }

    # -------------------------------------------------------------- separate
    def _init_separate(self, actor_cfg, critic_cfg, rl, key):
        self.engine = None
        self.actor = Model(actor_cfg)
        self.critic = Model(critic_cfg, with_value=True)
        self.reward_model = Model(critic_cfg, with_value=True)
        self.ref = Model(actor_cfg)
        ks = jax.random.split(key, 2)

        # ZeRO plans (one per role tree) when a ShardedContext is threaded
        self.actor_plan = self.critic_plan = None
        if self.shard is not None:
            from repro.optim import make_optimizer
            a_shapes = jax.eval_shape(self.actor.init, ks[0])
            c_shapes = jax.eval_shape(self.critic.init, ks[1])
            self.actor_plan = self.shard.plan_params(
                actor_cfg, a_shapes, make_optimizer(actor_cfg.optimizer))
            self.critic_plan = self.shard.plan_params(
                critic_cfg, c_shapes, make_optimizer(critic_cfg.optimizer))

        self.actor_step = make_train_step(self.actor, actor_cfg, kind="ppo",
                                          lr=rl.lr, kl_coef=rl.kl_coef,
                                          shard=self.actor_plan)
        self.critic_step = make_train_step(self.critic, critic_cfg,
                                           kind="critic", lr=rl.critic_lr,
                                           shard=self.critic_plan)
        self.actor_state = init_train_state(self.actor, actor_cfg, ks[0],
                                            self.actor_step.optimizer)
        self.critic_state = init_train_state(self.critic, critic_cfg, ks[1],
                                             self.critic_step.optimizer)
        if self.actor_plan is not None:
            # commit the ZeRO placement (params + opt sharded over DP per
            # stage) — init values are unchanged, only their layout
            self.actor_state = self.actor_plan.place_state(self.actor_state)
            self.critic_state = self.critic_plan.place_state(
                self.critic_state)
        # reference = frozen copy of the (SFT) actor init; reward = frozen
        # copy of the critic init (same value-head structure — the reward
        # model is "a critic that stopped learning at preference time")
        self.ref_params = jax.tree.map(jnp.copy, self.actor_state["params"])
        self.reward_params = jax.tree.map(jnp.copy,
                                          self.critic_state["params"])

        ga = lambda p: p if self.actor_plan is None \
            else self.actor_plan.gather(p)
        gc_ = lambda p: p if self.critic_plan is None \
            else self.critic_plan.gather(p)
        # per-layer ZeRO-3 gather specs for the scoring forwards (None in
        # tree mode / unsharded — DESIGN.md §3.7)
        ls_a = getattr(self.actor_plan, "layer_specs", None)
        ls_c = getattr(self.critic_plan, "layer_specs", None)
        self._jit_actor_step = _jit_step(self.actor_step)
        self._jit_critic_step = _jit_step(self.critic_step)
        self._jit_logp = jax.jit(
            lambda p, b: self._token_logp(ga(p), b, ls_a))
        self._jit_values = jax.jit(
            lambda p, b: self.critic.forward_value(gc_(p), b,
                                                   layer_specs=ls_c))
        self._jit_reward = jax.jit(
            lambda p, b: self.reward_model.forward_value(gc_(p), b,
                                                         layer_specs=ls_c))

        # engine-bound callables: make_experience / train_step are the same
        # straight-line code for both engines over these seven.
        # Rollout generates from a gathered compute copy of the ZeRO-3
        # actor shards (below stage 3 gather_copy returns the live
        # buffers, owned=False); an owned copy is deleted deterministically
        # when the rollout phase ends — never left to the GC.
        def _gen(prompts, key):
            from repro.sharding import delete_tree
            p, owned = self.actor_state["params"], False
            if self.actor_plan is not None:
                p, owned = self.actor_plan.gather_copy(p)
                self._live_buffers[self._gather_key] = {"actor": p}
            try:
                return self.rollout.generate(p, {"tokens": prompts},
                                             self.rl.gen_len, key)
            finally:
                self._live_buffers.pop(self._gather_key, None)
                if owned:
                    delete_tree(p)

        self._gen = _gen
        self._old_logp = lambda b: self._jit_logp(
            self.actor_state["params"], b)
        self._ref_logp = lambda b: self._jit_logp(self.ref_params, b)
        self._values = lambda b: self._jit_values(
            self.critic_state["params"], b)
        self._reward_scores = lambda b: self._jit_reward(
            self.reward_params, b)

        def _actor_update(exp):
            self.actor_state, m = self._jit_actor_step(self.actor_state, exp)
            return m

        def _critic_update(cbatch):
            self.critic_state, m = self._jit_critic_step(self.critic_state,
                                                         cbatch)
            return m

        self._actor_update, self._critic_update = _actor_update, _critic_update

    # ----------------------------------------------------------------- hydra
    def _init_hydra(self, cfg: ModelConfig, rl: RLHFConfig, key):
        self.engine = ModelEngine(cfg, key, rank=rl.lora_rank,
                                  shard=self.shard)
        self.actor = self.engine.model          # shared headless trunk
        self.critic = self.reward_model = self.ref = self.actor
        self.base_params = self.engine.base_params
        base_plan = self.engine.base_plan
        a_plan = self.engine.adapter_plans.get("actor")
        c_plan = self.engine.adapter_plans.get("critic")

        self.actor_step = make_lora_train_step(self.actor, cfg, kind="ppo",
                                               lr=rl.lr, kl_coef=rl.kl_coef,
                                               shard=a_plan,
                                               base_shard=base_plan)
        self.critic_step = make_lora_train_step(self.actor, cfg,
                                                kind="critic",
                                                lr=rl.critic_lr,
                                                shard=c_plan,
                                                base_shard=base_plan)
        self.actor_state = init_lora_train_state(
            self.engine.adapters["actor"], self.actor_step.optimizer)
        self.critic_state = init_lora_train_state(
            self.engine.adapters["critic"], self.critic_step.optimizer)
        if a_plan is not None:
            self.actor_state = a_plan.place_state(self.actor_state)
            self.critic_state = c_plan.place_state(self.critic_state)
            self.engine.adapters["actor"] = self.actor_state["params"]
            self.engine.adapters["critic"] = self.critic_state["params"]
        # frozen roles: reference IS the base (no copy at all); reward is
        # the frozen reward adapter over the same base (seeded from the
        # critic adapter init inside ModelEngine)
        self.ref_params = self.base_params
        self.reward_adapter = self.engine.adapters["reward"]

        gb = lambda p: p if base_plan is None else base_plan.gather(p)
        gad = lambda plan: (lambda ad: ad if plan is None
                            else plan.gather(ad))
        ga, gc_ = gad(a_plan), gad(c_plan)
        rw_plan = self.engine.adapter_plans.get("reward")
        grw = gad(rw_plan)
        # per-layer ZeRO-3 gather of the frozen trunk (DESIGN.md §3.7)
        ls_b = getattr(base_plan, "layer_specs", None)
        self._jit_actor_step = _jit_step(self.actor_step)
        self._jit_critic_step = _jit_step(self.critic_step)
        self._jit_logp = jax.jit(
            lambda p, ad, b: self._token_logp_adapter(gb(p), ga(ad), b,
                                                      ls_b))
        self._jit_ref_logp = jax.jit(
            lambda p, b: self._token_logp_ref(gb(p), b, ls_b))
        self._jit_values = jax.jit(
            lambda p, ad, b: self.engine.values(gb(p), gc_(ad), b,
                                                layer_specs=ls_b))
        self._jit_reward = jax.jit(
            lambda p, ad, b: self.engine.values(gb(p), grw(ad), b,
                                                layer_specs=ls_b))

        # engine-bound callables (hydra flavor: the frozen trunk threads
        # through every call; rollout merges A·B into it once per phase).
        # The merge happens here rather than inside Rollout.generate so the
        # offload scheduler can park the trunk's now-redundant adapted
        # leaves for the duration of generation (offload="all"). Under a
        # mesh, the merge runs on gathered compute copies of the ZeRO-3
        # trunk shards (and the actor adapter) — merged generation and the
        # paged decode path both execute under the same mesh.
        def _gen(prompts, key):
            from repro.models.lora import delete_merged
            from repro.sharding import delete_tree
            adapter, owned_a = self.actor_state["params"], False
            base, owned_b = self.base_params, False
            if base_plan is not None:
                base, owned_b = base_plan.gather_copy(self.base_params)
                adapter, owned_a = a_plan.gather_copy(
                    self.actor_state["params"])
                # the gather copies are live Python-held trees for the
                # whole generation — own them in the attribution table
                # (the merged tree's non-adapted leaves alias ``base``)
                self._live_buffers[self._gather_key] = {
                    "base": base, "adapter": adapter}
            merged = self.actor.merge_adapter(base, adapter)
            # visible to the attribution engine for the duration of the
            # phase (the mid-phase rollout_decode sample sees it); the
            # non-adapted leaves alias the live trunk and attribute to
            # base_params (it registered first)
            self._live_buffers["merged_rollout"] = merged
            if self.offload is not None:
                self.offload.rollout_merged()
            try:
                ro = self.rollout.generate(merged, {"tokens": prompts},
                                           self.rl.gen_len, key)
                # live set changes inside this phase (merged weights serve,
                # trunk possibly parked): record it before the merged
                # leaves die at the boundary
                self.memory.sample("rollout_decode")
                return ro
            finally:
                # deterministic phase-boundary hygiene. Order matters:
                # delete_merged reads the adapter tree's structure first,
                # then the owned ZeRO-3 gather copies are dropped (below
                # stage 3 owned=False — merged aliases the LIVE base, and
                # only the freshly-merged leaves may die).
                delete_merged(merged, adapter.get("lora"))
                self._live_buffers.pop("merged_rollout", None)
                self._live_buffers.pop(self._gather_key, None)
                if owned_a:
                    delete_tree(adapter)
                if owned_b:
                    delete_tree(base)

        self._gen = _gen
        self._old_logp = lambda b: self._jit_logp(
            self.base_params, self.actor_state["params"], b)
        # reference logp IS the plain base forward — no ref replica
        self._ref_logp = lambda b: self._jit_ref_logp(self.base_params, b)
        self._values = lambda b: self._jit_values(
            self.base_params, self.critic_state["params"], b)
        self._reward_scores = lambda b: self._jit_reward(
            self.base_params, self.reward_adapter, b)

        # The donated step consumes the previous adapter arrays, so the
        # engine's adapter view is re-pointed at the updated train state —
        # engine.adapters always reads the live trained values.
        def _actor_update(exp):
            self.actor_state, m = self._jit_actor_step(
                self.actor_state, self.base_params, exp)
            self.engine.adapters["actor"] = self.actor_state["params"]
            return m

        def _critic_update(cbatch):
            self.critic_state, m = self._jit_critic_step(
                self.critic_state, self.base_params, cbatch)
            self.engine.adapters["critic"] = self.critic_state["params"]
            return m

        self._actor_update, self._critic_update = _actor_update, _critic_update

    # ------------------------------------------------------------------
    def _token_logp(self, params, batch, layer_specs=None):
        from repro.steps import _action_logp
        logits, _, _ = self.actor.forward(params, batch,
                                          layer_specs=layer_specs)
        return _action_logp(logits, batch["tokens"],
                            _prefix_len(self.actor_cfg))

    def _token_logp_adapter(self, params, adapter, batch, layer_specs=None):
        from repro.steps import _action_logp
        logits = self.engine.logits(params, adapter, batch,
                                    layer_specs=layer_specs)
        return _action_logp(logits, batch["tokens"],
                            _prefix_len(self.actor_cfg))

    def _token_logp_ref(self, params, batch, layer_specs=None):
        from repro.steps import _action_logp
        return _action_logp(
            self.engine.ref_logits(params, batch, layer_specs=layer_specs),
            batch["tokens"], _prefix_len(self.actor_cfg))

    # ----------------------------------------------------------- telemetry
    def _attach_sim_predictions(self, batch_size: int) -> None:
        """Run the traced allocator simulator once for THIS run's exact
        shape (engine, batch, lengths, offload level) and attach its
        per-phase predicted bytes to the memory manager, so every phase
        span carries a sim-vs-measured delta. One-time setup (lazy, at the
        first train_step); failures degrade to spans without predictions
        rather than killing the run."""
        try:
            from repro.core import (MemoryStrategy, build_rlhf_phases,
                                    run_iteration)
            from repro.models import layers as _L
            # build_rlhf_phases raises the flash threshold for its traces;
            # restore it so telemetry can never perturb the run's numerics
            flash_min = _L.FLASH_MIN_ELEMS
            try:
                ph, persist = build_rlhf_phases(
                    self.actor_cfg, self.critic_cfg, batch=batch_size,
                    prompt_len=self.rl.prompt_len, gen_len=self.rl.gen_len,
                    engine=self.rl.engine, lora_rank=self.rl.lora_rank,
                    grad_ckpt=(self.actor_cfg.remat == "full"),
                    ppo_epochs=self.rl.ppo_epochs, min_bytes=2048)
            finally:
                _L.FLASH_MIN_ELEMS = flash_min
            strat = MemoryStrategy(
                "None", offload=self.rl.offload,
                grad_ckpt=(self.actor_cfg.remat == "full"))
            ndp = ntp = 1
            if self.shard is not None:
                # predict the run's REAL dp x tp layout: per-group
                # fractions traced from the same spec trees the runtime
                # placed its state with (core.strategies.traced_strategy)
                from repro.core.strategies import traced_strategy
                ndp, ntp = self.shard.ndp, self.shard.ntp
                strat = dataclasses.replace(
                    strat, zero_stage=self.shard.zero_stage,
                    gather_mode=self.shard.strat.gather_mode, ntp=ntp)
                strat = traced_strategy(
                    strat, self.actor_cfg, self.critic_cfg, ndp=ndp,
                    engine=self.rl.engine, lora_rank=self.rl.lora_rank)
            r = run_iteration(
                ph, persist, strat,
                "none", ndp=ndp, ntp=ntp, trainable_fraction=1.0,
                capacity=None)
            sim: Dict[str, dict] = {}
            for rec in r.phase_records:
                name = "rollout" if rec.name.startswith("rollout") \
                    else rec.name
                cur = sim.setdefault(name, {"sim_bytes": 0,
                                            "sim_peak_bytes": 0})
                cur["sim_bytes"] = rec.allocated_end
                cur["sim_peak_bytes"] = max(cur["sim_peak_bytes"],
                                            rec.alloc_peak)
                # the simulator's per-state ledger at this boundary — the
                # sim side of the per-owner measured-vs-sim diff (for a
                # collapsed rollout, the last sub-phase record wins, same
                # as sim_bytes)
                if rec.state_bytes_end:
                    cur["sim_owner_bytes"] = dict(rec.state_bytes_end)
            self.memory.sim_phase_bytes = sim
        except Exception as e:                        # pragma: no cover
            import warnings
            warnings.warn(f"telemetry: simulator prediction unavailable "
                          f"({e!r}); phase spans carry measured bytes only",
                          stacklevel=2)

    def _maybe_record_compiled(self, program: str, fn, *args) -> None:
        """Per-jitted-program compiled-memory accounting: feed XLA's
        ``memory_analysis()`` temp/arg/output bytes for ``program`` into
        the metrics registry, once. Lowering only traces (never executes),
        so like the simulator replay this is one-time setup excluded from
        the tracer's self-time. Pre-jitted ZeRO steps (two programs with
        an eager re-shard between) expose no ``.lower`` and are skipped."""
        if self.telemetry is None or program in self._compiled_recorded:
            return
        self._compiled_recorded.add(program)
        if not hasattr(fn, "lower"):
            return
        from repro.obs import record_compiled_memory
        record_compiled_memory(self.telemetry.registry, program, fn, *args)

    def _record_compiled_programs(self, batch) -> None:
        """Compiled-memory stats for the four scoring programs (lazy, at
        the first make_experience — the args are the real batch)."""
        if self.telemetry is None or "score_old_logp" in \
                self._compiled_recorded:
            return
        rec = self._maybe_record_compiled
        try:
            if self.rl.engine == "hydra":
                rec("score_old_logp", self._jit_logp, self.base_params,
                    self.actor_state["params"], batch)
                rec("score_ref", self._jit_ref_logp, self.base_params, batch)
                rec("score_values", self._jit_values, self.base_params,
                    self.critic_state["params"], batch)
                if self.reward_fn is None:
                    rec("score_reward", self._jit_reward, self.base_params,
                        self.reward_adapter, batch)
            else:
                rec("score_old_logp", self._jit_logp,
                    self.actor_state["params"], batch)
                rec("score_ref", self._jit_logp, self.ref_params, batch)
                rec("score_values", self._jit_values,
                    self.critic_state["params"], batch)
                if self.reward_fn is None:
                    rec("score_reward", self._jit_reward,
                        self.reward_params, batch)
        except Exception:                             # pragma: no cover
            pass

    def _role_gather_bytes(self) -> Dict[str, int]:
        """Analytic ZeRO-3 all-gather bytes per update program (cached):
        what the in-jit tree/layer gathers move each time the actor /
        critic step runs — Python can't observe in-scan collectives, so
        the counter is fed from the plan (DESIGN.md §4)."""
        if self._gather_step_bytes is None:
            ga = gc_ = 0
            if self.rl.engine == "hydra":
                bp = self.engine.base_plan
                trunk = 0 if bp is None else \
                    bp.gathered_bytes(self.base_params)

                def role_bytes(role):
                    pl = self.engine.adapter_plans.get(role)
                    ad = self.engine.adapters[role]
                    return trunk + (0 if pl is None
                                    else pl.gathered_bytes(ad))

                ga, gc_ = role_bytes("actor"), role_bytes("critic")
            else:
                if self.actor_plan is not None:
                    ga = self.actor_plan.gathered_bytes(
                        self.actor_state["params"])
                if self.critic_plan is not None:
                    gc_ = self.critic_plan.gathered_bytes(
                        self.critic_state["params"])
            self._gather_step_bytes = {"train_actor": ga, "train_critic": gc_}
        return self._gather_step_bytes

    def _count_gather(self, program: str) -> None:
        if self.telemetry is None:
            return
        b = self._role_gather_bytes().get(program, 0)
        if b:
            self.telemetry.registry.counter(
                "sharding_step_gathered_bytes_total",
                "bytes all-gathered by ZeRO-3 per update program "
                "(analytic, from the TreePlan)").inc(b, program=program)

    def make_experience(self, prompts: jax.Array, key) -> Dict[str, Any]:
        """Phases 1-5: rollout + the four scoring inferences -> experience.
        Straight-line over the engine-bound callables from ``_init_*``, in
        the canonical order of ``core.phases.RLHF_PHASE_SEQUENCE`` (the
        order the offload plan prefetches against). Under TP the whole
        sequence runs with the mesh ambient (``ctx.use_mesh``) so the
        scoring programs trace with their "model" constraint hints live."""
        from repro.sharding import ctx as _sctx
        with _sctx.use_mesh(self._tp_mesh):
            return self._make_experience_inner(prompts, key)

    def _make_experience_inner(self, prompts: jax.Array, key):
        mm = self.memory
        ro = self._gen(prompts, key)
        self._live_buffers["rollout"] = {
            "tokens": ro.tokens, "logp": ro.logp, "mask": ro.mask}
        mm.boundary("rollout", "inference")

        batch = self._shard_batch({"tokens": ro.tokens})
        self._record_compiled_programs(batch)
        if self.reward_fn is not None:
            terminal = self.reward_fn(ro.tokens, ro.mask)
        else:
            rm = self._reward_scores(batch)
            idx = jnp.maximum(ro.mask.sum(-1).astype(jnp.int32) - 1, 0)
            terminal = jnp.take_along_axis(rm, idx[:, None], 1)[:, 0]
        mm.boundary("score_reward", "inference")
        ref_logp = self._ref_logp(batch)
        mm.boundary("score_ref", "inference")
        values = self._values(batch) * ro.mask
        mm.boundary("score_values", "inference")
        old_logp = self._old_logp(batch)
        mm.boundary("score_old_logp", "inference")

        rewards = kl_shaped_rewards(old_logp, ref_logp, terminal, ro.mask,
                                    kl_coef=self.rl.kl_coef)
        adv, returns = gae(rewards, values, ro.mask,
                           gamma=self.rl.gamma, lam=self.rl.lam)
        if self.rl.whiten_advantages:
            adv = whiten(adv, ro.mask)
        exp = self._shard_batch({
            "tokens": ro.tokens, "loss_mask": ro.mask,
            "advantages": adv, "old_logp": old_logp * ro.mask,
            "ref_logp": ref_logp * ro.mask, "returns": returns,
            "old_values": values,
        })
        exp["mean_reward"] = terminal.mean()
        return exp

    def train_step(self, prompts: jax.Array, key) -> Dict[str, float]:
        """One full PPO iteration (all seven phases). A caught XLA
        ``RESOURCE_EXHAUSTED`` is captured by the flight recorder (owner
        table + top buffers at the moment of death) and re-raised — the
        recorder observes, it never swallows."""
        try:
            return self._train_step_inner(prompts, key)
        except Exception as e:
            fl = self.memory._flight()
            if fl is not None and fl.is_oom(e):
                at = self.memory.attributor
                fl.record_oom(
                    e, snapshot_fn=(at.snapshot if at is not None else None),
                    live_bytes=live_device_bytes(), source="rlhf")
            raise

    def _train_step_inner(self, prompts: jax.Array, key) -> Dict[str, float]:
        if self.telemetry is not None:
            if self.telemetry.sim_delta and not self._sim_attached:
                self._sim_attached = True
                self._attach_sim_predictions(int(prompts.shape[0]))
            self.memory.iteration_start()
        exp = self.make_experience(prompts, key)
        # the copy (same arrays) keeps popped members attributed to the
        # experience owner for the rest of the iteration
        self._live_buffers["experience"] = dict(exp)
        mean_reward = float(exp.pop("mean_reward"))
        old_values = exp.pop("old_values")
        if self.rl.engine == "hydra":
            self._maybe_record_compiled("train_actor", self._jit_actor_step,
                                        self.actor_state, self.base_params,
                                        exp)
        else:
            self._maybe_record_compiled("train_actor", self._jit_actor_step,
                                        self.actor_state, exp)
        metrics = {}
        for _ in range(self.rl.ppo_epochs):
            m = self._actor_update(exp)
            metrics.update({k: float(v) for k, v in m.items()})
            self._count_gather("train_actor")
        self.memory.boundary("train_actor", "training")
        cbatch = dict(exp, old_values=old_values)
        if self.rl.engine == "hydra":
            self._maybe_record_compiled("train_critic", self._jit_critic_step,
                                        self.critic_state, self.base_params,
                                        cbatch)
        else:
            self._maybe_record_compiled("train_critic", self._jit_critic_step,
                                        self.critic_state, cbatch)
        for _ in range(self.rl.ppo_epochs):
            mc = self._critic_update(cbatch)
            metrics.update({k: float(v) for k, v in mc.items()})
            self._count_gather("train_critic")
        self.memory.boundary("train_critic", "training", exp, cbatch)
        self._live_buffers.pop("rollout", None)
        self._live_buffers.pop("experience", None)
        metrics["mean_reward"] = mean_reward
        if self.telemetry is not None:
            self.memory.iteration_end(mean_reward=mean_reward)
        return metrics
