"""GRPO (group-relative policy optimization) — critic-free RLHF.

For each prompt, sample a group of G responses; the advantage of response i
is its reward standardized within the group. Removes the critic and reward
*value* model from the memory picture entirely (two of the paper's four
models) — the memory-minimal member of the framework's RLHF family, and a
natural beyond-paper data point for the §Paper-claims study.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import Model
from repro.rlhf.rollout import Rollout
from repro.rlhf.trainer import PhaseMemoryManager
from repro.steps import init_train_state, make_train_step, _prefix_len


@dataclasses.dataclass
class GRPOConfig:
    prompt_len: int = 8
    gen_len: int = 16
    group_size: int = 8
    kl_coef: float = 0.02
    lr: float = 1e-3
    temperature: float = 1.0
    top_k: int = 0
    memory_policy: str = "after_inference"
    rollout_backend: str = "dense"   # "paged": G samples fork ONE shared
    page_size: int = 16              # prompt prefill (CoW page sharing)


class GRPOTrainer:
    """Two models only: actor + frozen reference. reward_fn is programmatic
    (verifiable rewards) or any callable (tokens, mask) -> [B]."""

    def __init__(self, actor_cfg: ModelConfig, rl: GRPOConfig, key,
                 reward_fn: Callable):
        self.rl = rl
        self.actor_cfg = actor_cfg
        self.actor = Model(actor_cfg)
        self.reward_fn = reward_fn
        self.actor_step = make_train_step(self.actor, actor_cfg, kind="ppo",
                                          lr=rl.lr, kl_coef=rl.kl_coef)
        self.actor_state = init_train_state(self.actor, actor_cfg, key,
                                            self.actor_step.optimizer)
        self.ref_params = jax.tree.map(jnp.copy, self.actor_state["params"])
        self.rollout = Rollout(self.actor, actor_cfg,
                               capacity=rl.prompt_len + rl.gen_len,
                               temperature=rl.temperature, top_k=rl.top_k,
                               backend=rl.rollout_backend,
                               page_size=rl.page_size)
        self.memory = PhaseMemoryManager(policy=rl.memory_policy)
        self._jit_step = jax.jit(self.actor_step, donate_argnums=(0,))
        self._jit_logp = jax.jit(self._token_logp)

    def _token_logp(self, params, batch):
        from repro.steps import _action_logp
        logits, _, _ = self.actor.forward(params, batch)
        return _action_logp(logits, batch["tokens"],
                            _prefix_len(self.actor_cfg))

    def train_step(self, prompts: jax.Array, key) -> Dict[str, float]:
        """prompts [B, P]; each prompt is expanded to a group of G. On the
        paged rollout backend the G samples fork one shared prompt prefill
        (CoW page sharing) — same sampled stream as the dense repeat, with
        the prompt prefilled once per unique prompt."""
        G = self.rl.group_size
        B = prompts.shape[0]
        ro = self.rollout.generate(self.actor_state["params"],
                                   {"tokens": prompts}, self.rl.gen_len, key,
                                   group_size=G)          # [B*G, ...]
        self.memory.boundary("rollout", "inference")

        batch = {"tokens": ro.tokens}
        old_logp = self._jit_logp(self.actor_state["params"], batch)
        ref_logp = self._jit_logp(self.ref_params, batch)
        self.memory.boundary("score", "inference")

        rewards = self.reward_fn(ro.tokens, ro.mask)       # [B*G]
        rg = rewards.reshape(B, G)
        adv_seq = (rg - rg.mean(axis=1, keepdims=True)) / (
            rg.std(axis=1, keepdims=True) + 1e-6)
        adv = adv_seq.reshape(B * G)[:, None] * ro.mask    # token-broadcast

        exp = {"tokens": ro.tokens, "loss_mask": ro.mask,
               "advantages": adv, "old_logp": old_logp * ro.mask,
               "ref_logp": ref_logp * ro.mask,
               "returns": jnp.zeros_like(ro.mask)}
        self.actor_state, m = self._jit_step(self.actor_state, exp)
        self.memory.boundary("train_actor", "training", exp)
        out = {k: float(v) for k, v in m.items()}
        out["mean_reward"] = float(rewards.mean())
        return out
