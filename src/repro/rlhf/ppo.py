"""PPO math: per-token rewards (KL-shaped), GAE, advantage whitening."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def kl_shaped_rewards(logp, ref_logp, terminal_reward, mask, *,
                      kl_coef: float = 0.1, clip_reward: float = 5.0):
    """Per-token reward: -kl_coef * (logp - ref_logp), plus the sequence
    reward on the final generated token. All [B, S]."""
    kl = logp - ref_logp
    rewards = -kl_coef * kl * mask
    # index of last valid token per row
    idx = jnp.maximum(mask.sum(-1).astype(jnp.int32) - 1, 0)
    last_pos = jnp.clip(idx, 0, mask.shape[1] - 1)
    terminal = jnp.clip(terminal_reward, -clip_reward, clip_reward)
    rewards = rewards.at[jnp.arange(rewards.shape[0]), last_pos].add(terminal)
    return rewards


def gae(rewards, values, mask, *, gamma: float = 1.0, lam: float = 0.95):
    """Generalized advantage estimation over the generated region.
    rewards/values/mask [B, S] -> (advantages, returns) [B, S]."""
    B, S = rewards.shape

    def step(carry, xs):
        adv_next, val_next = carry
        r, v, m = xs
        delta = r + gamma * val_next * m - v
        adv = delta + gamma * lam * adv_next * m
        return (adv, v), adv

    xs = (rewards.T, values.T, mask.T)
    xs = jax.tree.map(lambda x: x[::-1], xs)
    (_, _), adv_rev = jax.lax.scan(step, (jnp.zeros(B), jnp.zeros(B)), xs)
    advantages = adv_rev[::-1].T * mask
    returns = advantages + values
    return advantages, returns


def whiten(x, mask, *, eps: float = 1e-8):
    n = jnp.maximum(mask.sum(), 1.0)
    mean = jnp.sum(x * mask) / n
    var = jnp.sum(jnp.square(x - mean) * mask) / n
    return (x - mean) * jax.lax.rsqrt(var + eps) * mask
