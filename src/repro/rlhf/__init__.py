from repro.rlhf.engine import ModelEngine
from repro.rlhf.experience import ExperienceBuffer
from repro.rlhf.ppo import gae, kl_shaped_rewards, whiten
from repro.rlhf.rollout import Rollout, RolloutResult, sample_token
from repro.rlhf.trainer import (MEMORY_POLICIES, PhaseMemoryManager,
                                RLHFConfig, RLHFTrainer, live_device_bytes,
                                live_host_bytes, per_device_live_bytes)

__all__ = ["ModelEngine", "ExperienceBuffer", "gae", "kl_shaped_rewards",
           "whiten", "Rollout", "RolloutResult", "sample_token",
           "MEMORY_POLICIES", "PhaseMemoryManager", "RLHFConfig",
           "RLHFTrainer", "live_device_bytes", "live_host_bytes",
           "per_device_live_bytes"]
