"""Experience buffer: accumulates rollout batches and serves PPO
minibatches (multiple PPO epochs over shuffled experience)."""
from __future__ import annotations

from typing import Dict, Iterator, List

import jax
import jax.numpy as jnp
import numpy as np


class ExperienceBuffer:
    def __init__(self):
        self._batches: List[Dict[str, jax.Array]] = []

    def add(self, batch: Dict[str, jax.Array]):
        self._batches.append(batch)

    def __len__(self):
        return sum(int(b["tokens"].shape[0]) for b in self._batches)

    def minibatches(self, size: int, key, epochs: int = 1
                    ) -> Iterator[Dict[str, jax.Array]]:
        if not self._batches:
            return
        cat = {k: jnp.concatenate([b[k] for b in self._batches])
               for k in self._batches[0]}
        n = cat["tokens"].shape[0]
        for e in range(epochs):
            perm = jax.random.permutation(jax.random.fold_in(key, e), n)
            for i in range(0, n - size + 1, size):
                idx = perm[i:i + size]
                yield {k: jnp.take(v, idx, axis=0) for k, v in cat.items()}

    def clear(self):
        """Phase-boundary hygiene: drop references so device buffers die
        (the trainer's PhaseMemoryManager then collects)."""
        self._batches.clear()
