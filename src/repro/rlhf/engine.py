"""Shared-base "hydra" RLHF engine: one frozen trunk, per-role adapters.

The paper's §2.1 accounting blames the four full model replicas (actor,
critic, reference, reward) plus two full optimizer states for most of the
persistent RLHF footprint. Hydra-RLHF (arXiv:2309.00754) and PERL
(arXiv:2403.10704) show the replicas can share one frozen trunk with
per-role LoRA adapters at near-zero quality cost. :class:`ModelEngine`
realizes that here:

  * **base**      — ONE frozen parameter tree (the SFT checkpoint);
  * **actor**     — base ⊕ actor LoRA adapter (trained);
  * **reference** — the plain base forward. The frozen ref *copy* of the
    four-model pipeline disappears entirely: at init the actor adapter's
    delta is zero, so ``ref ≡ actor-at-init`` exactly, the same invariant
    the separate path builds with ``jnp.copy``;
  * **critic**    — base ⊕ critic adapter + value head (trained);
  * **reward**    — base ⊕ reward adapter + value head (frozen; seeded
    from the critic adapter init, mirroring the separate path's seeding).

Optimizer state and gradients exist only for adapter leaves (see
``steps.make_lora_train_step``), so the persistent footprint drops from
``~4 x params + 2 x opt(params)`` to
``params + Σ_role adapters + 2 x opt(adapters)``.

Rollout-speed generation uses ``merge_adapter`` (fold A·B into the trunk
once per iteration) rather than paying the unmerged per-matmul delta on
every decode step; the merged leaves are dropped at the phase boundary and
re-merged from the frozen base next iteration, so merge error never
accumulates.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ATTN, ModelConfig
from repro.models import Model
from repro.models import lora as LORA


def _tree_bytes(tree) -> int:
    return int(sum(np.prod(l.shape) * jnp.dtype(l.dtype).itemsize
                   for l in jax.tree.leaves(tree)))


class ModelEngine:
    """One frozen base tree + per-role AdapterSets (LoRA factors on every
    adapted 2-D projection, plus value heads for critic/reward)."""

    VALUE_ROLES = frozenset({"critic", "reward"})

    def __init__(self, cfg: ModelConfig, key, *, rank: int = 128,
                 roles=("actor", "critic", "reward"), shard=None):
        assert cfg.input_mode == "tokens", \
            f"hydra engine needs token-input models, got {cfg.input_mode}"
        assert all(k == ATTN for k in cfg.layer_kinds()), \
            f"hydra engine covers attention-only trunks, got {cfg.name}"
        assert cfg.moe is None, "hydra engine covers dense FFNs"
        self.cfg = cfg
        self.rank = rank
        self.model = Model(cfg)                 # headless shared trunk
        kb, *krs = jax.random.split(key, 1 + len(roles))
        self.base_params = self.model.init(kb)  # frozen
        self.adapters: Dict[str, Any] = {}
        for role, kr in zip(roles, krs):
            if role == "reward" and "critic" in self.adapters:
                # seeded from the critic init (documented parity with the
                # separate path's reward <- copy(critic init))
                self.adapters[role] = jax.tree.map(
                    jnp.copy, self.adapters["critic"])
                continue
            self.adapters[role] = self.model.init_adapter(
                kr, self.base_params, rank,
                with_value=role in self.VALUE_ROLES)
        # ZeRO placement (sharding.ShardedContext): the frozen trunk shards
        # over the DP/FSDP domain per zero_stage; per-role adapters are
        # replicated-or-sharded by rule (rules.adapter_pspecs). Under TP
        # (strat.ntp > 1) both trees additionally carry the Megatron
        # "model" entries — adapter factors partition consistently with
        # their base matmul (column sites shard B's d_out, row sites A's
        # d_in), so merge_adapter's base + A@B stays shard-local and the
        # hydra merge is exact at every dp x tp layout (DESIGN.md §9).
        # Init values are unchanged — only the committed layout moves.
        self.shard = shard
        self.base_plan = None
        self.adapter_plans: Dict[str, Any] = {}
        if shard is not None:
            from repro.optim import make_optimizer
            opt = make_optimizer(cfg.optimizer)
            self.base_plan = shard.plan_params(cfg, self.base_params)
            self.base_params = self.base_plan.place_params(self.base_params)
            for role, ad in self.adapters.items():
                plan = shard.plan_adapter(ad, opt)
                self.adapter_plans[role] = plan
                self.adapters[role] = plan.place_params(ad)

    # ------------------------------------------------------ role forwards
    # The trunk is an explicit argument (not read off ``self``) so jitted
    # callers pass it as a real input — closing over it would bake the
    # largest tree in the system into the executable as a constant.
    def logits(self, base_params, adapter, batch, layer_specs=None):
        """Role-switched forward: base ⊕ adapter -> [B,S,V] logits.
        ``layer_specs`` (the base plan's) turns the trunk's ZeRO-3 gather
        per-layer inside the scan body (DESIGN.md §3.7)."""
        return self.model.forward(base_params, batch, adapter=adapter,
                                  layer_specs=layer_specs)[0]

    def ref_logits(self, base_params, batch, layer_specs=None):
        """Reference forward IS the plain base pass — no ref copy exists."""
        return self.model.forward(base_params, batch,
                                  layer_specs=layer_specs)[0]

    def values(self, base_params, adapter, batch, layer_specs=None):
        """Critic/reward forward: base ⊕ adapter + adapter's value head."""
        return self.model.forward_value(base_params, batch, adapter=adapter,
                                        layer_specs=layer_specs)

    # Rollout-speed generation folds A·B into the trunk and drops the
    # merged leaves at the phase boundary — that lifecycle lives in
    # ``Rollout.generate(..., adapter=...)`` via ``Model.merge_adapter`` and
    # ``lora.delete_merged``.

    def lora_sites(self):
        """Structure-only copy of the adapter site tree (every leaf True).
        The offload subsystem traverses it to find the trunk's swappable
        adapted-site leaves (``lora.adapted_subtree``) — the site layout is
        shared by every role, so the actor's adapter defines it."""
        return jax.tree.map(lambda _: True, self.adapters["actor"]["lora"])

    # ---------------------------------------------------------- accounting
    def base_param_count(self) -> int:
        return int(sum(np.prod(l.shape)
                       for l in jax.tree.leaves(self.base_params)))

    def adapter_param_count(self, role: str) -> int:
        return LORA.adapter_param_count(self.adapters[role])

    def trainable_fraction(self, role: str = "actor") -> float:
        return LORA.trainable_fraction(self.base_params, self.adapters[role])

    def memory_accounting(self) -> Dict[str, Dict[str, int]]:
        """Per-role {params, opt, grad} bytes for the hydra layout, plus the
        separate-path equivalents on the same config. Optimizer-state bytes
        are EXACT for ``cfg.optimizer`` (``eval_shape`` over the real
        ``opt.init`` tree — adamw fp32/bf16 moments and adafactor's
        factored second moment all come out right); grads are transient,
        one copy of the trainables in the accumulation dtype of
        ``steps._accumulated_grads``."""
        from repro.optim import make_optimizer
        opt = make_optimizer(self.cfg.optimizer)
        opt_bytes = lambda tree: _tree_bytes(jax.eval_shape(opt.init, tree))
        grad_item = 4 if self.cfg.optimizer == "adamw" else 2
        base_b = _tree_bytes(self.base_params)
        out: Dict[str, Dict[str, int]] = {
            "base": {"params": base_b, "opt": 0, "grad": 0}}
        for role, ad in self.adapters.items():
            trained = role != "reward"
            out[role] = {
                "params": _tree_bytes(ad),
                "opt": opt_bytes(ad) if trained else 0,
                "grad": (grad_item * LORA.adapter_param_count(ad)
                         if trained else 0)}
        trained_full = {"params": base_b, "opt": opt_bytes(self.base_params),
                        "grad": grad_item * self.base_param_count()}
        sep = {"actor": dict(trained_full), "critic": dict(trained_full),
               "ref": {"params": base_b, "opt": 0, "grad": 0},
               "reward": {"params": base_b, "opt": 0, "grad": 0}}
        return {"hydra": out, "separate": sep}
