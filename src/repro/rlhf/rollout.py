"""Rollout: prompt prefill + sampled decoding with a fixed-capacity donated
KV cache.

Design note (paper App. B): ColossalChat's original ``generate()`` grew its
buffers per step, which the paper found pathological. Here the cache is
allocated once at ``capacity`` and every decode step donates it back —
in-place on TPU, zero allocator churn. This is the JAX-native fix the
framework adopts as default.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import Model


@dataclass
class RolloutResult:
    tokens: jax.Array        # [B, S_total] prompt + generated (padded)
    logp: jax.Array          # [B, S_total] sampled-token logprobs (0 on prompt)
    mask: jax.Array          # [B, S_total] 1.0 on generated tokens
    prompt_len: int


def sample_token(key, logits, *, temperature: float = 1.0, top_k: int = 0):
    logits = logits.astype(jnp.float32)
    if top_k:
        v, _ = jax.lax.top_k(logits, top_k)
        logits = jnp.where(logits < v[..., -1:], -1e30, logits)
    if temperature <= 0.0:
        tok = jnp.argmax(logits, -1)
    else:
        tok = jax.random.categorical(key, logits / temperature)
    logp = jax.nn.log_softmax(logits, -1)
    return tok, jnp.take_along_axis(logp, tok[..., None], -1)[..., 0]


class Rollout:
    def __init__(self, model: Model, cfg: ModelConfig, *, capacity: int,
                 temperature: float = 1.0, top_k: int = 0,
                 eos_id: Optional[int] = None, window: int = 0,
                 donate: bool = True, backend: str = "dense",
                 page_size: int = 16):
        assert backend in ("dense", "paged"), backend
        self.model, self.cfg = model, cfg
        self.capacity = capacity
        self.temperature, self.top_k = temperature, top_k
        self.eos_id = eos_id
        self.window = window
        self.backend = backend
        self.page_size = page_size
        self.page_manager = None        # populated per generate() when paged

        if backend == "paged":
            assert model.supports_paged(), \
                "paged rollout needs an attention-only token model"
            assert window == 0, "paged rollout is full-attention"

            def prefill_paged(params, batch, pools, bt, lens):
                return model.paged_prefill(params, batch, pools, bt, lens)

            def decode_paged(params, pools, token, position, bt, key, done):
                logits, pools = model.paged_decode_step(params, pools, token,
                                                        position, bt)
                tok, logp = sample_token(key, logits,
                                         temperature=temperature, top_k=top_k)
                tok = jnp.where(done, 0, tok).astype(jnp.int32)
                logp = jnp.where(done, 0.0, logp)
                return tok, logp, pools

            self._prefill = jax.jit(prefill_paged, donate_argnums=(2,))
            self._decode = jax.jit(decode_paged, donate_argnums=(1,))
            return

        def prefill(params, batch):
            return model.prefill(params, batch, capacity, window=window)

        def decode(params, caches, token, position, key, done):
            logits, caches = model.decode_step(params, caches, token,
                                               position, window=window)
            tok, logp = sample_token(key, logits,
                                     temperature=temperature, top_k=top_k)
            tok = jnp.where(done, 0, tok).astype(jnp.int32)
            logp = jnp.where(done, 0.0, logp)
            return tok, logp, caches

        self._prefill = jax.jit(prefill)
        self._decode = jax.jit(decode, donate_argnums=(1,))

    def generate(self, params, batch, max_new_tokens: int, key,
                 adapter=None):
        """batch: prompt inputs (see Model input modes). Python loop over
        steps — the realistic serving pattern, and the phase the paper's
        §3.1 traces.

        With ``adapter`` (hydra engine), generation runs from *merged*
        weights — A·B folded into the trunk once, so every decode step pays
        zero adapter overhead — and the merged leaves are deleted at the
        phase boundary (the base leaves they alias survive). The merge is
        redone from the frozen base next call, so fp error never
        accumulates."""
        if adapter is not None:
            from repro.models.lora import delete_merged
            merged = self.model.merge_adapter(params, adapter)
            try:
                return self.generate(merged, batch, max_new_tokens, key)
            finally:
                delete_merged(merged, adapter.get("lora"))
        if self.backend == "paged":
            return self._generate_paged(params, batch, max_new_tokens, key)
        tokens = batch["tokens"]
        B, P = tokens.shape
        prefix = (self.cfg.num_prefix_embeddings
                  if self.cfg.input_mode == "embeddings" else 0)
        logits, caches = self._prefill(params, batch)
        tok, logp0 = sample_token(jax.random.fold_in(key, 0), logits,
                                  temperature=self.temperature,
                                  top_k=self.top_k)
        tok = tok.astype(jnp.int32)
        done = jnp.zeros((B,), bool)
        out_toks = [tok]
        out_logp = [logp0]
        for t in range(1, max_new_tokens):
            pos = jnp.full((B,), prefix + P + t - 1, jnp.int32)
            k = jax.random.fold_in(key, t)
            tok, lp, caches = self._decode(params, caches, tok, pos, k, done)
            if self.eos_id is not None:
                done = done | (out_toks[-1] == self.eos_id)
            out_toks.append(tok)
            out_logp.append(lp)
        return self._finalize(tokens, out_toks, out_logp, caches)

    def _finalize(self, tokens, out_toks, out_logp, caches) -> RolloutResult:
        """Shared generation epilogue: stack outputs, mask everything after
        (and including the pad after) EOS, free the caches deterministically
        (phase-boundary hygiene)."""
        B, P = tokens.shape
        gen = jnp.stack(out_toks, axis=1)                  # [B, N]
        gen_logp = jnp.stack(out_logp, axis=1)
        full = jnp.concatenate([tokens, gen], axis=1)
        logp = jnp.concatenate([jnp.zeros((B, P)), gen_logp], axis=1)
        mask = jnp.concatenate(
            [jnp.zeros((B, P)), jnp.ones((B, gen.shape[1]))], axis=1)
        if self.eos_id is not None:
            eos = jnp.cumsum((full == self.eos_id) &
                             (mask > 0), axis=1)
            keep = (eos - ((full == self.eos_id) & (mask > 0))) == 0
            mask = mask * keep
            logp = logp * keep
        jax.tree.map(lambda x: x.delete() if hasattr(x, "delete") else None,
                     caches)
        return RolloutResult(tokens=full, logp=logp, mask=mask, prompt_len=P)

    def _generate_paged(self, params, batch, max_new_tokens: int, key):
        """Paged generation phase: identical sampling stream to the dense
        path (same logits, same keys), but KV lives in a page pool that
        grows by one page per sequence only when a page boundary is
        crossed. ``self.page_manager`` afterwards holds the alloc/free
        event stream for the memory simulator."""
        from repro.paged import PageManager, pool_token_bytes

        tokens = batch["tokens"]
        B, P = tokens.shape
        ps = self.page_size
        nb = -(-(P + max_new_tokens) // ps)
        dtype = jax.tree.leaves(params)[0].dtype
        pm = PageManager(
            B * nb, ps,
            bytes_per_token=pool_token_bytes(self.cfg, dtype)
            * self.cfg.num_layers)
        for b in range(B):
            pm.allocate(b, P)
        pools = self.model.init_paged_pools(B * nb, ps, dtype)
        seq_ids = list(range(B))
        bt = jnp.asarray(pm.block_table_array(seq_ids, nb))
        logits, pools = self._prefill(params, batch, pools, bt,
                                      jnp.full((B,), P, jnp.int32))
        tok, logp0 = sample_token(jax.random.fold_in(key, 0), logits,
                                  temperature=self.temperature,
                                  top_k=self.top_k)
        tok = tok.astype(jnp.int32)
        done = jnp.zeros((B,), bool)
        out_toks = [tok]
        out_logp = [logp0]
        for t in range(1, max_new_tokens):
            for b in range(B):
                pm.append_token(b)          # page for index P + t - 1
            bt = jnp.asarray(pm.block_table_array(seq_ids, nb))
            pos = jnp.full((B,), P + t - 1, jnp.int32)
            k = jax.random.fold_in(key, t)
            tok, lp, pools = self._decode(params, pools, tok, pos, bt, k,
                                          done)
            if self.eos_id is not None:
                done = done | (out_toks[-1] == self.eos_id)
            out_toks.append(tok)
            out_logp.append(lp)
        for b in range(B):
            pm.free_seq(b)
        self.page_manager = pm
        return self._finalize(tokens, out_toks, out_logp, pools)
