"""Rollout: prompt prefill + sampled decoding with a fixed-capacity donated
KV cache.

Design note (paper App. B): ColossalChat's original ``generate()`` grew its
buffers per step, which the paper found pathological. Here the cache is
allocated once at ``capacity`` and every decode step donates it back —
in-place on TPU, zero allocator churn. This is the JAX-native fix the
framework adopts as default.

Two decode-speed features from DESIGN.md "Fast decode path" plug in here:

  * ``capture_buckets`` — prompts pad to a compile-bucket ladder rung and
    the padding is masked exactly via per-row ``lengths``, so PPO batches
    with ragged prompt lengths stop recompiling the prefill per length.
  * ``spec_decode`` — MTP self-speculative greedy decoding: draft
    ``spec_k`` tokens from the model's MTP chain, verify them in ONE
    batched forward, accept the greedy-consistent prefix. Emitted tokens
    and logprobs are bit-identical to vanilla greedy decoding (every token
    is the verify forward's own fp32 argmax; logp is the same
    ``log_softmax`` gather) — only wall-clock changes. Greedy-only
    (``temperature == 0``, ``top_k == 0``, no EOS early-exit: the vanilla
    path feeds zeroed post-EOS tokens through the cache, which speculation
    cannot reproduce).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import Model


@dataclass
class RolloutResult:
    tokens: jax.Array        # [B, S_total] prompt + generated (padded)
    logp: jax.Array          # [B, S_total] sampled-token logprobs (0 on prompt)
    mask: jax.Array          # [B, S_total] 1.0 on generated tokens
    prompt_len: int


def sample_token(key, logits, *, temperature: float = 1.0, top_k: int = 0):
    logits = logits.astype(jnp.float32)
    if top_k:
        v, _ = jax.lax.top_k(logits, top_k)
        logits = jnp.where(logits < v[..., -1:], -1e30, logits)
    if temperature <= 0.0:
        tok = jnp.argmax(logits, -1)
    else:
        tok = jax.random.categorical(key, logits / temperature)
    logp = jax.nn.log_softmax(logits, -1)
    return tok, jnp.take_along_axis(logp, tok[..., None], -1)[..., 0]


def spec_verify_step(model: Model, spec_k: int, verify_fn, params, h_last,
                     tok, pos, live):
    """Shared draft/verify/accept core for self-speculative greedy decode
    (jitted inside backend-specific wrappers here and in the serving
    scheduler). ``verify_fn(seq [B,T], positions [B,T])`` runs the
    T = spec_k + 1 token forward and returns (logits [B,T,V], h [B,T,D],
    state); rows with ``live = False`` get position -1 (dead writes).

    Greedy-exactness: logits[:, j] is the same function of the context a
    sequential decode would compute at that position (drafts j' <= j are
    context for query j), so ``argmax(fp32 logits[:, j])`` IS the vanilla
    greedy token once tokens 0..j-1 of the run are accepted, and the
    gathered ``log_softmax`` matches ``sample_token``'s logp at top_k=0.
    The accepted prefix therefore yields ``n_acc + 1`` vanilla-exact
    (token, logp) pairs per step.

    Returns (greedy [B, k+1], logp [B, k+1], n_acc [B],
    h_new [B, D] — trunk state at each row's last accepted position —
    and the backend cache state)."""
    B = tok.shape[0]
    drafts = model.mtp_draft(params, h_last, tok, spec_k)        # [B, k]
    seq = jnp.concatenate([tok[:, None], drafts], axis=1)        # [B, k+1]
    positions = pos[:, None] + jnp.arange(spec_k + 1, dtype=jnp.int32)[None]
    positions = jnp.where(live[:, None], positions, -1)
    logits, h, state = verify_fn(seq, positions)
    lg32 = logits.astype(jnp.float32)
    greedy = jnp.argmax(lg32, -1).astype(jnp.int32)
    logp = jnp.take_along_axis(jax.nn.log_softmax(lg32, -1),
                               greedy[..., None], -1)[..., 0]
    acc = jnp.cumprod((greedy[:, :-1] == drafts).astype(jnp.int32), axis=1)
    n_acc = acc.sum(axis=1).astype(jnp.int32)                    # [B]
    h_new = h[jnp.arange(B), n_acc]                              # [B, D]
    return greedy, logp, n_acc, h_new, state


def place_kv_tp(tree, mesh):
    """Commit eagerly-built KV state (paged pools, dense caches) TP-sharded:
    the kv-head axis (dim -2 of each ``[..., kv_heads, head_dim]`` leaf)
    partitions over the model axis, mirroring ``rules.cache_pspecs``; when
    kv heads don't divide, head_dim is tried, else the leaf replicates —
    per device the KV footprint drops to ~1/ntp. No-op without a mesh, so
    the pure-DP layout (and its byte accounting) is unchanged."""
    if mesh is None:
        return tree
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P
    from repro.sharding.ctx import resolve_entry

    def place(x):
        entries = [None] * x.ndim
        if x.ndim >= 2:
            e = resolve_entry(mesh, "model", x.shape[-2])
            if e is not None:
                entries[-2] = e
            else:
                entries[-1] = resolve_entry(mesh, "model", x.shape[-1])
        return jax.device_put(x, NamedSharding(mesh, P(*entries)))

    return jax.tree.map(place, tree)


class Rollout:
    def __init__(self, model: Model, cfg: ModelConfig, *, capacity: int,
                 temperature: float = 1.0, top_k: int = 0,
                 eos_id: Optional[int] = None, window: int = 0,
                 donate: bool = True, backend: str = "dense",
                 page_size: int = 16,
                 capture_buckets: Optional[Sequence[int]] = None,
                 spec_decode: bool = False, spec_k: int = 2,
                 mesh=None):
        assert backend in ("dense", "paged"), backend
        self.model, self.cfg = model, cfg
        self.capacity = capacity
        self.temperature, self.top_k = temperature, top_k
        self.eos_id = eos_id
        self.window = window
        self.backend = backend
        self.page_size = page_size
        self.page_manager = None        # populated per generate() when paged
        # TP mesh (DESIGN.md §9): generation runs under ``ctx.use_mesh`` so
        # the model's "model"-axis constraint hints bake into the prefill /
        # decode programs, and paged KV pools are committed sharded over
        # the kv-head axis. None (the default, and every pure-DP caller)
        # keeps the historical mesh-free trace.
        self.mesh = mesh

        from repro.serving.buckets import BucketLadder, CompileCache
        self.compile_cache = CompileCache()
        self.prefill_ladder = (BucketLadder(capture_buckets)
                               if capture_buckets else None)
        self.spec_decode, self.spec_k = spec_decode, spec_k
        if spec_decode:
            assert model.supports_spec_decode(), \
                "spec decode needs a token-input attention-only model " \
                "with mtp_depth > 0"
            assert temperature <= 0.0 and top_k == 0, \
                "spec decode is greedy-only (temperature=0, top_k=0)"
            assert eos_id is None, \
                "spec decode has no EOS early-exit (vanilla feeds zeroed " \
                "post-EOS tokens through the cache); mask EOS downstream"
            assert window == 0, "spec decode is full-attention"
        # the verify forward transiently writes up to spec_k positions past
        # the last needed one; pad the rolling cache so those writes can
        # never wrap onto live prompt entries
        cap_eff = capacity + (spec_k if spec_decode else 0)
        self._cap_eff = cap_eff
        # the lengths-masked prefill needs token inputs and attention kinds;
        # plain traffic on exotic models keeps the legacy path
        self._rich = spec_decode or self.prefill_ladder is not None

        if backend == "paged":
            assert model.supports_paged(), \
                "paged rollout needs an attention-only token model"
            assert window == 0, "paged rollout is full-attention"

            def prefill_paged(params, batch, pools, bt, lens):
                return model.paged_prefill(params, batch, pools, bt, lens,
                                           return_h=True)

            def decode_paged(params, pools, token, position, bt, key, done):
                logits, pools = model.paged_decode_step(params, pools, token,
                                                        position, bt)
                tok, logp = sample_token(key, logits,
                                         temperature=temperature, top_k=top_k)
                tok = jnp.where(done, 0, tok).astype(jnp.int32)
                logp = jnp.where(done, 0.0, logp)
                return tok, logp, pools

            self._prefill = jax.jit(prefill_paged, donate_argnums=(2,))
            self._decode = jax.jit(decode_paged, donate_argnums=(1,))
            if spec_decode:
                def spec_paged(params, pools, h_last, tok, pos, bt, live):
                    return spec_verify_step(
                        model, spec_k,
                        lambda seq, positions: model.paged_decode_multi(
                            params, pools, seq, positions, bt),
                        params, h_last, tok, pos, live)

                self._spec = jax.jit(spec_paged, donate_argnums=(1,))
            return

        if self._rich:
            def prefill(params, batch, lens):
                return model.prefill(params, batch, cap_eff, window=window,
                                     lengths=lens, return_h=True)
        else:
            def prefill(params, batch):
                return model.prefill(params, batch, capacity, window=window)

        def decode(params, caches, token, position, key, done):
            logits, caches = model.decode_step(params, caches, token,
                                               position, window=window)
            tok, logp = sample_token(key, logits,
                                     temperature=temperature, top_k=top_k)
            tok = jnp.where(done, 0, tok).astype(jnp.int32)
            logp = jnp.where(done, 0.0, logp)
            return tok, logp, caches

        self._prefill = jax.jit(prefill)
        self._decode = jax.jit(decode, donate_argnums=(1,))
        if spec_decode:
            def spec_dense(params, caches, h_last, tok, pos, live):
                return spec_verify_step(
                    model, spec_k,
                    lambda seq, positions: model.decode_multi(
                        params, caches, seq, positions),
                    params, h_last, tok, pos, live)

            self._spec = jax.jit(spec_dense, donate_argnums=(1,))

    def _place_pools(self, pools):
        return place_kv_tp(pools, self.mesh)

    # -- bucketed prefill helpers -------------------------------------------
    def _bucketed_prompt(self, tokens):
        """Pad [B, P] prompts up to their capture bucket; returns the
        padded batch, per-row lengths, and the bucket for key accounting."""
        B, P = tokens.shape
        Sb = self.prefill_ladder.fit(P) if self.prefill_ladder else P
        if Sb != P:
            tokens = jnp.pad(tokens, ((0, 0), (0, Sb - P)))
        return {"tokens": tokens}, jnp.full((B,), P, jnp.int32), Sb

    def warmup(self, params, batch_size: int,
               max_prompt_len: Optional[int] = None) -> None:
        """Pre-compile the bucketed dense prefill for every ladder rung (the
        ragged dimension of PPO traffic). Decode/spec shapes are fixed per
        (batch, capacity) and compile once on first use; the paged pool
        shape is likewise fixed by ``capacity``, so no paged warmup is
        needed. Marks the compile cache warmed either way."""
        if self.prefill_ladder is not None and self.backend == "dense" \
                and self._rich:
            from repro.sharding import ctx as _sctx
            with _sctx.use_mesh(self.mesh):
                for Sb in self.prefill_ladder.up_to(
                        max_prompt_len or self.capacity):
                    batch = {"tokens": jnp.zeros((batch_size, Sb),
                                                 jnp.int32)}
                    lens = jnp.zeros((batch_size,), jnp.int32)
                    self._prefill(params, batch, lens)
                    self.compile_cache.warm(("prefill", self.backend, Sb))
        self.compile_cache.finish_warmup()

    def generate(self, params, batch, max_new_tokens: int, key,
                 adapter=None, group_size: int = 1):
        """batch: prompt inputs (see Model input modes). Python loop over
        steps — the realistic serving pattern, and the phase the paper's
        §3.1 traces.

        With ``adapter`` (hydra engine), generation runs from *merged*
        weights — A·B folded into the trunk once, so every decode step pays
        zero adapter overhead — and the merged leaves are deleted at the
        phase boundary (the base leaves they alias survive). The merge is
        redone from the frozen base next call, so fp error never
        accumulates. Spec decode drafts and verifies from the same merged
        tree (MTP modules included), so hydra output stays greedy-exact.

        ``group_size = G > 1`` (GRPO / best-of-N) expands every prompt to
        a group of G samples, returning ``[B*G, ...]`` results ordered as
        ``jnp.repeat`` would produce. On the paged backend the group
        *forks one shared prompt prefill*: the prompt is prefilled once
        per unique prompt and the G samples share its pages copy-on-write
        — same sampling stream as the repeat path (the prefill logits are
        replicated row-wise before sampling), at 1/G of the prefill
        compute and shared prompt KV."""
        from repro.sharding import ctx as _sctx
        with _sctx.use_mesh(self.mesh):
            return self._generate_inner(params, batch, max_new_tokens, key,
                                        adapter=adapter,
                                        group_size=group_size)

    def _generate_inner(self, params, batch, max_new_tokens: int, key,
                        adapter=None, group_size: int = 1):
        if adapter is not None:
            from repro.models.lora import delete_merged
            merged = self.model.merge_adapter(params, adapter)
            try:
                return self.generate(merged, batch, max_new_tokens, key,
                                     group_size=group_size)
            finally:
                delete_merged(merged, adapter.get("lora"))
        if group_size > 1 and not (self.backend == "paged"
                                   and not self.spec_decode):
            # dense/spec paths have no page sharing to exploit: expand up
            # front (identical results, G times the prefill)
            batch = dict(batch, tokens=jnp.repeat(batch["tokens"],
                                                  group_size, axis=0))
            group_size = 1
        if self.spec_decode:
            return self._generate_spec(params, batch, max_new_tokens, key)
        if self.backend == "paged":
            return self._generate_paged(params, batch, max_new_tokens, key,
                                        group_size=group_size)
        tokens = batch["tokens"]
        B, P = tokens.shape
        prefix = (self.cfg.num_prefix_embeddings
                  if self.cfg.input_mode == "embeddings" else 0)
        if self._rich:
            pbatch, lens, Sb = self._bucketed_prompt(tokens)
            self.compile_cache.lookup(("prefill", "dense", Sb))
            logits, caches, _h = self._prefill(params, pbatch, lens)
        else:
            logits, caches = self._prefill(params, batch)
        tok, logp0 = sample_token(jax.random.fold_in(key, 0), logits,
                                  temperature=self.temperature,
                                  top_k=self.top_k)
        tok = tok.astype(jnp.int32)
        done = jnp.zeros((B,), bool)
        out_toks = [tok]
        out_logp = [logp0]
        for t in range(1, max_new_tokens):
            pos = jnp.full((B,), prefix + P + t - 1, jnp.int32)
            k = jax.random.fold_in(key, t)
            tok, lp, caches = self._decode(params, caches, tok, pos, k, done)
            if self.eos_id is not None:
                done = done | (out_toks[-1] == self.eos_id)
            out_toks.append(tok)
            out_logp.append(lp)
        return self._finalize(tokens, out_toks, out_logp, caches)

    def _finalize(self, tokens, out_toks, out_logp, caches) -> RolloutResult:
        """Shared generation epilogue: stack outputs, mask everything after
        (and including the pad after) EOS, free the caches deterministically
        (phase-boundary hygiene). Accepts per-step lists or pre-stacked
        [B, N] arrays."""
        B, P = tokens.shape
        gen = jnp.stack(out_toks, axis=1) if isinstance(out_toks, list) \
            else out_toks                                  # [B, N]
        gen_logp = jnp.stack(out_logp, axis=1) if isinstance(out_logp, list) \
            else out_logp
        full = jnp.concatenate([tokens, gen], axis=1)
        logp = jnp.concatenate([jnp.zeros((B, P)), gen_logp], axis=1)
        mask = jnp.concatenate(
            [jnp.zeros((B, P)), jnp.ones((B, gen.shape[1]))], axis=1)
        if self.eos_id is not None:
            eos = jnp.cumsum((full == self.eos_id) &
                             (mask > 0), axis=1)
            keep = (eos - ((full == self.eos_id) & (mask > 0))) == 0
            mask = mask * keep
            logp = logp * keep
        jax.tree.map(lambda x: x.delete() if hasattr(x, "delete") else None,
                     caches)
        return RolloutResult(tokens=full, logp=logp, mask=mask, prompt_len=P)

    def _generate_paged(self, params, batch, max_new_tokens: int, key,
                        group_size: int = 1):
        """Paged generation phase: identical sampling stream to the dense
        path (same logits, same keys), but KV lives in a page pool that
        grows by one page per sequence only when a page boundary is
        crossed. ``self.page_manager`` afterwards holds the alloc/free
        event stream for the memory simulator.

        With ``group_size = G > 1`` each prompt row is prefilled ONCE and
        forked into G sequences sharing the prompt pages copy-on-write
        (``PageManager.fork``); the prefill logits are replicated to the
        ``B*G`` sampling rows, so the emitted stream is exactly what
        ``jnp.repeat(prompts, G)`` through the unshared path would give."""
        from repro.paged import PageManager, pool_token_bytes

        tokens = batch["tokens"]
        B, P = tokens.shape
        G = group_size
        BG = B * G
        ps = self.page_size
        nb = -(-(P + max_new_tokens) // ps)
        dtype = jax.tree.leaves(params)[0].dtype
        if G == 1:
            num_pages = B * nb
        else:
            # shared prompt pages once per unique prompt, plus each group
            # member's own growth: pages past the shared full-page prefix
            # (a partial prompt page is CoW-copied on first append)
            num_pages = B * (-(-P // ps)) + BG * (nb - P // ps)
        pm = PageManager(
            num_pages, ps,
            bytes_per_token=pool_token_bytes(self.cfg, dtype)
            * self.cfg.num_layers)
        for b in range(B):
            pm.allocate(b * G, P)           # group parent row
        pools = self._place_pools(
            self.model.init_paged_pools(num_pages, ps, dtype))
        bt = jnp.asarray(pm.block_table_array(
            [b * G for b in range(B)], nb))
        pbatch, lens, Sb = self._bucketed_prompt(tokens)
        self.compile_cache.lookup(("prefill", "paged", Sb))
        logits, pools, _h = self._prefill(params, pbatch, pools, bt, lens)
        if G > 1:
            for b in range(B):
                for g in range(1, G):
                    pm.fork(b * G, b * G + g)
            logits = jnp.repeat(logits, G, axis=0)
            tokens = jnp.repeat(tokens, G, axis=0)
        seq_ids = list(range(BG))
        tok, logp0 = sample_token(jax.random.fold_in(key, 0), logits,
                                  temperature=self.temperature,
                                  top_k=self.top_k)
        tok = tok.astype(jnp.int32)
        done = jnp.zeros((BG,), bool)
        out_toks = [tok]
        out_logp = [logp0]
        for t in range(1, max_new_tokens):
            copies = []
            for b in seq_ids:
                copies += pm.append_token(b)   # page for index P + t - 1
            if copies:
                # CoW of a shared partial prompt page: mirror the copies
                # on every layer pool before the decode writes past them
                from repro.paged import copy_pages
                src = [s for s, _ in copies]
                dst = [d for _, d in copies]
                pools = [
                    {k2: jax.vmap(copy_pages, in_axes=(0, None, None))(
                        pool, src, dst) for k2, pool in seg.items()}
                    for seg in pools]
            bt = jnp.asarray(pm.block_table_array(seq_ids, nb))
            pos = jnp.full((BG,), P + t - 1, jnp.int32)
            k = jax.random.fold_in(key, t)
            tok, lp, pools = self._decode(params, pools, tok, pos, bt, k,
                                          done)
            if self.eos_id is not None:
                done = done | (out_toks[-1] == self.eos_id)
            out_toks.append(tok)
            out_logp.append(lp)
        for b in seq_ids:
            pm.free_seq(b)
        self.page_manager = pm
        return self._finalize(tokens, out_toks, out_logp, pools)

    def _generate_spec(self, params, batch, max_new_tokens: int, key):
        """Self-speculative greedy generation (dense or paged backend).

        Per step: draft ``spec_k`` tokens per row from the MTP chain, run
        ONE (spec_k+1)-token verify forward, accept the greedy-consistent
        prefix — ``n_acc + 1`` tokens and logps, all bit-identical to the
        vanilla greedy stream. Rows that reach ``max_new_tokens`` early get
        position -1 (dead writes) until the batch drains; emission counts
        are per-row host state, so rows advance at their own accept rate."""
        tokens = batch["tokens"]
        B, P = tokens.shape
        k1 = self.spec_k + 1
        stats = self.spec_stats = {"steps": 0, "drafted": 0, "accepted": 0}
        pbatch, lens, Sb = self._bucketed_prompt(tokens)
        self.compile_cache.lookup(("prefill", self.backend, Sb))
        pm = None
        if self.backend == "paged":
            from repro.paged import PageManager, pool_token_bytes
            ps = self.page_size
            # pool sized by capacity (not P + max_new): one pool shape per
            # Rollout, so ragged PPO batches never recompile the decode
            nb = -(-self._cap_eff // ps)
            dtype = jax.tree.leaves(params)[0].dtype
            pm = PageManager(
                B * nb, ps,
                bytes_per_token=pool_token_bytes(self.cfg, dtype)
                * self.cfg.num_layers)
            for b in range(B):
                pm.allocate(b, P)
            pools = self._place_pools(
                self.model.init_paged_pools(B * nb, ps, dtype))
            seq_ids = list(range(B))
            bt = jnp.asarray(pm.block_table_array(seq_ids, nb))
            logits, state, h_last = self._prefill(params, pbatch, pools, bt,
                                                  lens)
        else:
            logits, state, h_last = self._prefill(params, pbatch, lens)
        tok0, logp0 = sample_token(jax.random.fold_in(key, 0), logits,
                                   temperature=self.temperature,
                                   top_k=self.top_k)
        gen = np.zeros((B, max_new_tokens), np.int32)
        gen_lp = np.zeros((B, max_new_tokens), np.float32)
        gen[:, 0] = np.asarray(tok0)
        gen_lp[:, 0] = np.asarray(logp0)
        n_em = np.ones(B, np.int64)         # tokens emitted per row
        last_tok = np.asarray(tok0, np.int32).copy()
        while (n_em < max_new_tokens).any():
            live = n_em < max_new_tokens
            pos = P + n_em - 1              # position of each row's last_tok
            pos_in = np.where(live, pos, -1).astype(np.int32)
            if pm is not None:
                for b in np.nonzero(live)[0]:
                    pm.append_tokens(int(b), k1)
                bt = jnp.asarray(pm.block_table_array(seq_ids, nb))
                self.compile_cache.lookup(("spec", "paged", B, k1))
                greedy, lp, n_acc, h_last, state = self._spec(
                    params, state, h_last, jnp.asarray(last_tok),
                    jnp.asarray(pos_in), bt, jnp.asarray(live))
            else:
                self.compile_cache.lookup(("spec", "dense", B, k1))
                greedy, lp, n_acc, h_last, state = self._spec(
                    params, state, h_last, jnp.asarray(last_tok),
                    jnp.asarray(pos_in), jnp.asarray(live))
            greedy = np.asarray(greedy)
            lp_np = np.asarray(lp)
            n_acc_np = np.asarray(n_acc)
            stats["steps"] += 1
            stats["drafted"] += self.spec_k * int(live.sum())
            stats["accepted"] += int(n_acc_np[live].sum())
            for b in np.nonzero(live)[0]:
                take = min(int(n_acc_np[b]) + 1,
                           max_new_tokens - int(n_em[b]))
                e = int(n_em[b])
                gen[b, e:e + take] = greedy[b, :take]
                gen_lp[b, e:e + take] = lp_np[b, :take]
                n_em[b] += take
                last_tok[b] = greedy[b, take - 1]
                if pm is not None:
                    # drop page claims for rejected/untaken draft positions;
                    # logical length == position of the row's last token
                    pm.truncate(int(b), P + int(n_em[b]) - 1)
        if pm is not None:
            for b in range(B):
                pm.free_seq(b)
            self.page_manager = pm
        return self._finalize(tokens, jnp.asarray(gen),
                              jnp.asarray(gen_lp), state)
