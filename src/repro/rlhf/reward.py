"""Reward models: a learned value-head scorer and programmatic rewards for
the runnable examples (verifiable-reward style)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import Model


def sequence_reward(model: Model, params, tokens, mask):
    """Score = value-head output at the last generated token. [B]."""
    v = model.forward_value(params, {"tokens": tokens})
    idx = jnp.maximum(mask.sum(-1).astype(jnp.int32) - 1, 0)
    return jnp.take_along_axis(v, idx[:, None], 1)[:, 0]


def make_target_token_reward(target_id: int):
    """Programmatic reward for examples: fraction of generated tokens equal
    to ``target_id`` — trivially verifiable, so PPO improvement is visible
    within a few steps on CPU."""
    def fn(tokens, mask):
        hit = (tokens == target_id).astype(jnp.float32) * mask
        return hit.sum(-1) / jnp.maximum(mask.sum(-1), 1.0)
    return fn


def make_even_token_reward():
    """Reward even token ids (another verifiable pretext task)."""
    def fn(tokens, mask):
        hit = (tokens % 2 == 0).astype(jnp.float32) * mask
        return hit.sum(-1) / jnp.maximum(mask.sum(-1), 1.0)
    return fn
