"""OffloadPlan / OffloadExecutor: the phase schedule of the offload
subsystem.

``OffloadPlan`` is compiled from the canonical PPO phase sequence in
``core.phases`` (``RLHF_PHASE_SEQUENCE`` collapsed to the seven runtime
phases) and the same per-state touch map the allocator simulator replays
(``phase_state_touches``) — so the analytic live-HBM curve and the runtime
one are two views of one schedule and cannot drift apart.

``OffloadExecutor`` binds a plan to a :class:`~repro.offload.host_store.
HostParkingLot` and a registry of *state accessors* — ``name -> (get,
set)`` closures owned by the trainer, since role trees live in train-state
dicts that donation rewrites every step. At each
``PhaseMemoryManager.boundary()`` the executor:

  1. **parks** every managed tree the next phase doesn't touch (before the
     boundary's gc/record, so the eviction is visible in the live-bytes
     curve);
  2. **fetches** the next phase's parked trees — ``jax.device_put`` is
     asynchronous, so the host->device copies overlap the boundary's host
     work and the next phase's dispatch (the double-buffering).

The one mid-phase event is hydra rollout: once ``merge_adapter`` has
folded A·B into a rollout copy of the trunk, the trunk's adapted leaves
are redundant until scoring — ``rollout_merged()`` parks them (the
``offload="all"`` preset), and the rollout boundary fetches them back.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Mapping, Tuple

from repro.core.phases import (RUNTIME_RLHF_PHASE_SEQUENCE,
                               runtime_state_touches)
from repro.core.strategies import OFFLOAD_LEVELS, offload_managed_states
from repro.offload.host_store import HostParkingLot

# one PPO iteration as the trainer bounds it — derived in core.phases from
# the canonical trace-level sequence (rollout prefill+decode collapsed)
RUNTIME_PHASE_SEQUENCE = RUNTIME_RLHF_PHASE_SEQUENCE

StateAccessor = Tuple[Callable[[], Any], Callable[[Any], None]]


@dataclass(frozen=True)
class OffloadPlan:
    """Which state trees must be device-resident during which phase, and
    which of them the chosen level swaps."""
    level: str
    engine: str
    sequence: Tuple[str, ...]
    required: Mapping[str, frozenset]     # phase -> state names it touches
    managed: frozenset                    # states the level parks off-phase

    @classmethod
    def compile(cls, level: str, *, engine: str = "hydra",
                states=None, frozen_unused=()) -> "OffloadPlan":
        """Compile the plan for an offload level. ``states`` (optional)
        restricts the plan to the state names the caller actually
        registers (e.g. no ``ref_params`` tree exists under hydra).
        ``frozen_unused`` names states the run never touches at all (e.g.
        ``reward_params`` when a programmatic ``reward_fn`` replaces the
        reward model): they park at ``start()`` and are never fetched,
        instead of round-tripping over PCIe every iteration."""
        assert level in OFFLOAD_LEVELS, level
        touches = runtime_state_touches(engine)
        if states is not None:
            touches = {k: v for k, v in touches.items() if k in set(states)}
        touches.update({n: frozenset() for n in frozen_unused
                        if n in touches})
        required = {
            ph: frozenset(n for n, phs in touches.items() if ph in phs)
            for ph in RUNTIME_PHASE_SEQUENCE}
        managed = frozenset(offload_managed_states(level, touches))
        return cls(level=level, engine=engine,
                   sequence=RUNTIME_PHASE_SEQUENCE, required=required,
                   managed=managed)

    def next_phase(self, phase: str) -> str:
        i = self.sequence.index(phase)
        return self.sequence[(i + 1) % len(self.sequence)]

    def resident_for(self, phase: str) -> frozenset:
        """Managed states that must be on device during ``phase``."""
        return self.managed & self.required[phase]

    def evict_before(self, phase: str) -> frozenset:
        """Managed states ``phase`` does not touch (park candidates)."""
        return self.managed - self.required[phase]


class OffloadExecutor:
    """Drives a plan against the trainer's live state at phase boundaries.

    ``states`` maps each plan state name to ``(get, set)`` closures; ``set``
    must repoint *every* alias the trainer holds (train-state dict, engine
    adapter view, ...) so no reference to a parked device buffer survives.
    """

    def __init__(self, plan: OffloadPlan, lot: HostParkingLot,
                 states: Dict[str, StateAccessor], *, telemetry=None):
        missing = plan.managed - set(states)
        assert not missing, f"no accessor for managed states {missing}"
        self.plan = plan
        self.lot = lot
        self.states = states
        self.telemetry = telemetry          # obs.RunTelemetry | None

    # ------------------------------------------------------------ telemetry
    def _emit(self, name: str, t0_us, parked0: int, fetched0: int,
              hits0: int) -> None:
        """One offload span + the PCIe traffic counters, measured as lot-
        stats deltas across the park/fetch window (zero recomputation)."""
        tel = self.telemetry
        st = self.lot.stats
        parked = st.bytes_parked_total - parked0
        fetched = st.bytes_fetched_total - fetched0
        tr = tel.tracer
        tr.complete(name, "offload", t0_us, tr.now_us() - t0_us,
                    parked_bytes=parked, fetched_bytes=fetched,
                    prefetch_hits=st.n_prefetch_hits - hits0,
                    host_bytes=st.parked_bytes)
        reg = tel.registry
        if parked:
            reg.counter("offload_parked_bytes_total",
                        "cumulative device->host park traffic").inc(parked)
        if fetched:
            reg.counter("offload_fetched_bytes_total",
                        "cumulative host->device fetch traffic").inc(fetched)
        reg.gauge("offload_host_bytes",
                  "bytes currently parked on host").set(st.parked_bytes)
        # flight-recorder context: a forensic dump replays the recent
        # park/fetch traffic leading up to the breach
        fl = getattr(tel, "flight", None)
        if fl is not None:
            fl.note("offload", op=name, parked_bytes=parked,
                    fetched_bytes=fetched, host_bytes=st.parked_bytes)

    def _marks(self):
        st = self.lot.stats
        return (st.bytes_parked_total, st.bytes_fetched_total,
                st.n_prefetch_hits)

    # ------------------------------------------------------------ lifecycle
    def start(self) -> None:
        """Initial placement: park everything the first phase (rollout)
        doesn't touch. Called once at trainer init — and the point where
        ``adopt_parked`` checkpoint restores land for free."""
        self._park_except(self.plan.sequence[0])

    def park_for_boundary(self, completed: str) -> None:
        """Boundary half 1 (before the live-bytes record): evict managed
        trees the next phase doesn't touch."""
        if self.telemetry is None:
            self._park_except(self.plan.next_phase(completed))
            return
        t0, marks = self.telemetry.tracer.now_us(), self._marks()
        self._park_except(self.plan.next_phase(completed))
        self._emit(f"park:{completed}", t0, *marks)

    def fetch_for_boundary(self, completed: str) -> None:
        """Boundary half 2 (after the record): bring the next phase's
        parked trees back. All host->device copies are *prefetched* first
        — issued back-to-back so they overlap one another and (via JAX's
        async dispatch) whatever the device is still running from the
        completed phase — then installed as prefetch hits. A deeper
        horizon would park/fetch a phase early and hold double residency
        for a whole phase; this keeps the overlap without the extra live
        bytes."""
        nxt = self.plan.next_phase(completed)
        names = [n for n in sorted(self.plan.resident_for(nxt))
                 if n in self.lot]
        t0 = marks = None
        if self.telemetry is not None:
            t0, marks = self.telemetry.tracer.now_us(), self._marks()
        for name in names:
            self.lot.prefetch(name)
        for name in names:
            self.states[name][1](self.lot.fetch(name))
        if self.telemetry is not None:
            self._emit(f"fetch:{nxt}", t0, *marks)

    def rollout_merged(self) -> None:
        """Hydra mid-rollout hook: the merged rollout weights now carry the
        adapted leaves, so the trunk's own copies are phase-dead — park
        them (level "all"; no-op otherwise). Their fetch rides the rollout
        boundary like any other state."""
        if "base_params" in self.plan.managed and \
                "base_params" not in self.lot:
            get, set_ = self.states["base_params"]
            self.lot.park("base_params", get())
            set_(self.lot.peek("base_params"))

    def adopt_parked(self, name: str, host_tree) -> None:
        """Install a host-resident restore (``checkpoint.store.restore(...,
        memory_kind=...)``) directly into the lot — resume without the
        transient HBM spike of trees that would immediately be parked."""
        if name in self.lot:
            self.lot.discard(name)    # replace a stale parked copy
        self.lot.adopt(name, host_tree)
        self.states[name][1](self.lot.peek(name))

    # ------------------------------------------------------------- internals
    def _park_except(self, phase: str) -> None:
        for name in sorted(self.plan.evict_before(phase)):
            if name not in self.lot:
                get, set_ = self.states[name]
                self.lot.park(name, get())
                # leave the host view installed: accidental use stays
                # correct (jit coerces), and the fetch repoints it
                set_(self.lot.peek(name))
