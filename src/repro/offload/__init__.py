# Phase-aware host-offload subsystem: runtime HBM<->host swapping of RLHF
# role state (host_store + scheduler) and offload-aware remat (policies).
# The schedule is compiled from core.phases so the allocator simulator and
# the runtime agree; byte movement gates on the memory-kind capability
# probe in kernels.compat.
from repro.offload.host_store import HostParkingLot, LotStats, tree_nbytes
from repro.offload.policies import offload_remat_policy, remat_policy_for
from repro.offload.scheduler import (RUNTIME_PHASE_SEQUENCE, OffloadExecutor,
                                     OffloadPlan)

__all__ = ["HostParkingLot", "LotStats", "tree_nbytes",
           "offload_remat_policy", "remat_policy_for",
           "RUNTIME_PHASE_SEQUENCE", "OffloadExecutor", "OffloadPlan"]
