"""HostParkingLot: runtime HBM <-> host swapping of whole pytrees.

The paper's core finding is that most RLHF state is *phase-exclusive*:
each of the seven PPO phases touches one role's trees and leaves the rest
idle on HBM. The parking lot is the byte-moving half of the offload
subsystem (the phase schedule lives in ``offload.scheduler``): it parks a
named pytree to host memory, frees the device copy, and fetches it back —
bit-identical — when its phase comes around again.

Two transports, selected by the capability probe in ``kernels.compat``:

  * **memory kinds** (TPU/GPU runtimes exposing "pinned_host"): leaves move
    with ``jax.device_put`` onto the same sharding re-targeted at the host
    memory kind — layout-preserving, async, DMA-able back in;
  * **committed-numpy fallback** (CPU, old runtimes): leaves are copied to
    host ``numpy`` arrays and the device buffers deleted. Round trips are
    still bit-identical (``np.asarray`` of a bf16 array keeps the raw
    bits via ml_dtypes).

Fetches are double-buffered by construction: ``jax.device_put`` back to
device is asynchronous, so a fetch issued at a phase boundary overlaps the
host-side setup (and, with ``prefetch``, the tail of the previous phase's
device compute). Parks block by default — eviction is the point of a
boundary — but ``block=False`` defers the source ``delete`` to ``drain()``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.kernels import compat


def tree_nbytes(tree) -> int:
    return int(sum(getattr(l, "nbytes", 0) for l in jax.tree.leaves(tree)))


def _is_device_array(leaf) -> bool:
    return hasattr(leaf, "delete") and hasattr(leaf, "sharding")


def _delete(leaf) -> None:
    if hasattr(leaf, "delete") and not leaf.is_deleted():
        leaf.delete()


@dataclass
class LotStats:
    parked_bytes: int = 0           # currently host-resident
    peak_parked_bytes: int = 0
    bytes_parked_total: int = 0     # cumulative device->host traffic
    bytes_fetched_total: int = 0    # cumulative host->device traffic
    n_park: int = 0
    n_fetch: int = 0
    n_prefetch_hits: int = 0


@dataclass
class _Entry:
    host_leaves: List[Any]
    treedef: Any
    nbytes: int
    # pending device->host transfer: device sources to delete once the
    # host copy is known materialized (async park)
    pending_sources: Optional[List[Any]] = None
    # per-leaf device shardings captured at park time (numpy fallback):
    # fetches restore them, so a parked ZeRO shard comes back as the same
    # 1/ndp per-device slice — offload and zero_stage compose
    shardings: Optional[List[Any]] = None


class HostParkingLot:
    """Named pytree parking between device HBM and host memory.

    ``use_memory_kinds=None`` (default) auto-selects from the compat probe;
    ``False`` forces the numpy fallback (useful for tests / determinism
    studies on memory-kind backends).
    """

    def __init__(self, *, use_memory_kinds: Optional[bool] = None):
        if use_memory_kinds is None:
            use_memory_kinds = compat.supports_host_offload()
        self.host_kind = compat.host_memory_kind() if use_memory_kinds else None
        self.device_kind = compat.device_memory_kind()
        self._entries: Dict[str, _Entry] = {}
        self._prefetched: Dict[str, List[Any]] = {}
        self.stats = LotStats()
        # (op, name) stream — "park" | "prefetch" | "fetch_hit" | "fetch"
        self.events: List[Tuple[str, str]] = []

    # ------------------------------------------------------------- transport
    def _to_host(self, leaf):
        if not _is_device_array(leaf):
            return leaf
        if self.host_kind is not None:
            return jax.device_put(
                leaf, leaf.sharding.with_memory_kind(self.host_kind))
        return np.asarray(leaf)     # committed copy; blocks

    def _to_device(self, leaf, sharding=None):
        if self.host_kind is not None and _is_device_array(leaf):
            return jax.device_put(
                leaf, leaf.sharding.with_memory_kind(self.device_kind))
        if sharding is not None:
            return jax.device_put(leaf, sharding)
        return jax.device_put(leaf)

    @staticmethod
    def _sharding_of(leaf):
        """Multi-device sharding to restore on fetch (single-device /
        non-array leaves need none — the default placement is right)."""
        sh = getattr(leaf, "sharding", None)
        if sh is not None and len(getattr(sh, "device_set", ())) > 1:
            return sh
        return None

    # ---------------------------------------------------------------- public
    def __contains__(self, name: str) -> bool:
        return name in self._entries

    def names(self):
        return tuple(self._entries)

    def parked_bytes(self) -> int:
        return self.stats.parked_bytes

    def park(self, name: str, tree, *, block: bool = True) -> None:
        """Move ``tree`` to host under ``name`` and free its device bytes.
        With ``block=False`` the device sources survive until ``drain()``
        (or the next access) so the copy can overlap in-flight compute."""
        assert name not in self._entries, f"{name!r} already parked"
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        host = [self._to_host(l) for l in leaves]
        shardings = [self._sharding_of(l) for l in leaves] \
            if self.host_kind is None else None
        sources = [l for l in leaves if _is_device_array(l)]
        nbytes = tree_nbytes(tree)
        entry = _Entry(host, treedef, nbytes,
                       pending_sources=None if block else sources,
                       shardings=shardings)
        if block:
            self._complete_park(entry, sources)
        self._entries[name] = entry
        st = self.stats
        st.n_park += 1
        st.bytes_parked_total += nbytes
        st.parked_bytes += nbytes
        st.peak_parked_bytes = max(st.peak_parked_bytes, st.parked_bytes)
        self.events.append(("park", name))

    def _complete_park(self, entry: _Entry, sources) -> None:
        for l in entry.host_leaves:
            if _is_device_array(l):
                l.block_until_ready()
        for l in sources:
            _delete(l)
        entry.pending_sources = None

    def drain(self) -> None:
        """Complete every in-flight (non-blocking) park: wait for the host
        copies and delete the device sources."""
        for entry in self._entries.values():
            if entry.pending_sources is not None:
                self._complete_park(entry, entry.pending_sources)

    def adopt(self, name: str, tree) -> None:
        """Insert an already-host-resident tree (numpy leaves, or arrays in
        the host memory kind) without a device round trip — how a
        checkpoint restore targets the lot directly (``checkpoint.store
        .restore(memory_kind=...)``), so resume never spikes HBM with trees
        that would immediately be parked."""
        assert name not in self._entries, f"{name!r} already parked"
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        nbytes = tree_nbytes(tree)
        self._entries[name] = _Entry(list(leaves), treedef, nbytes)
        st = self.stats
        st.parked_bytes += nbytes
        st.peak_parked_bytes = max(st.peak_parked_bytes, st.parked_bytes)
        self.events.append(("park", name))

    def prefetch(self, name: str) -> None:
        """Start the host->device copy of a parked tree without removing it
        from the lot; the following ``fetch`` consumes the in-flight copy.
        ``jax.device_put`` is asynchronous, so this overlaps whatever the
        device is still running."""
        if name in self._prefetched or name not in self._entries:
            return
        entry = self._entries[name]
        if entry.pending_sources is not None:
            self._complete_park(entry, entry.pending_sources)
        shs = entry.shardings or [None] * len(entry.host_leaves)
        self._prefetched[name] = [self._to_device(l, s)
                                  for l, s in zip(entry.host_leaves, shs)]
        self.events.append(("prefetch", name))

    def fetch(self, name: str):
        """Device-resident tree for ``name``; the entry leaves the lot.
        Uses the prefetched copy when one is in flight."""
        entry = self._entries.pop(name)
        if entry.pending_sources is not None:
            self._complete_park(entry, entry.pending_sources)
        pre = self._prefetched.pop(name, None)
        if pre is not None:
            leaves = pre
            self.stats.n_prefetch_hits += 1
            self.events.append(("fetch_hit", name))
        else:
            shs = entry.shardings or [None] * len(entry.host_leaves)
            leaves = [self._to_device(l, s)
                      for l, s in zip(entry.host_leaves, shs)]
            self.events.append(("fetch", name))
        st = self.stats
        st.n_fetch += 1
        st.parked_bytes -= entry.nbytes
        st.bytes_fetched_total += entry.nbytes
        return jax.tree_util.tree_unflatten(entry.treedef, leaves)

    def discard(self, name: str) -> None:
        """Drop a parked entry without fetching it back to device."""
        entry = self._entries.pop(name)
        self._prefetched.pop(name, None)
        if entry.pending_sources is not None:
            self._complete_park(entry, entry.pending_sources)
        self.stats.parked_bytes -= entry.nbytes

    def peek(self, name: str):
        """The host-resident tree, without fetching. Correctness-preserving
        stand-in while parked (jit coerces host leaves on accidental use —
        slow but right); the scheduler treats any such use as a plan bug."""
        entry = self._entries[name]
        return jax.tree_util.tree_unflatten(entry.treedef, entry.host_leaves)
