"""Offload-aware rematerialization: ``cfg.remat == "offload"``.

Gradient checkpointing (``remat="full"``) trades activation memory for
recompute; host offload trades it for PCIe traffic instead. With
``remat="offload"`` the per-group residual stream — annotated
``checkpoint_name(h, "residual")`` in ``models.transformer`` — is *saved*,
but spilled to the host memory space during the forward pass and fetched
back for the backward, via ``jax.checkpoint_policies
.save_and_offload_only_these_names``. Everything else recomputes, exactly
like ``remat="full"``.

On backends without a distinct host memory kind (the capability probe in
``kernels.compat``), the policy degrades to ``save_only_these_names
("residual")`` — the same liveness schedule with the saved residuals kept
on device, so the numerics and the jaxpr structure are identical and only
the placement differs. That keeps ``remat="offload"`` runnable (and
testable) everywhere.
"""
from __future__ import annotations

import jax

from repro.kernels import compat

# the activation name models.transformer tags on the scanned residual
# stream (the per-layer-group checkpoint the backward pass re-enters from)
RESIDUAL_NAME = "residual"


def offload_remat_policy():
    """The ``jax.checkpoint`` policy behind ``cfg.remat == "offload"``."""
    cp = jax.checkpoint_policies
    kind = compat.host_memory_kind()
    if kind is not None:
        return cp.save_and_offload_only_these_names(
            names_which_can_be_saved=[],
            names_which_can_be_offloaded=[RESIDUAL_NAME],
            offload_src=compat.device_memory_kind(),
            offload_dst=kind)
    return cp.save_only_these_names(RESIDUAL_NAME)


def remat_policy_for(remat: str):
    """Resolve a ``cfg.remat`` string to a ``jax.checkpoint`` policy
    (``None`` means checkpoint-everything, i.e. ``remat="full"``)."""
    if remat == "full":
        return None
    if remat == "dots":
        return jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
    if remat == "offload":
        return offload_remat_policy()
    raise ValueError(f"no checkpoint policy for remat={remat!r}")
