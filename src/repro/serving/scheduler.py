"""Continuous-batching serving scheduler with selectable KV-cache backends.

Two cache layouts behind one admit/decode/retire loop:

  * ``dense`` — the seed's fixed pool of B slots over a donated
    ``[B, capacity]`` rolling cache. Zero allocator churn, but every slot
    reserves ``capacity`` tokens of KV no matter how short its request.
  * ``paged`` — a vLLM-style global page pool (``repro.paged``): slots
    hold block tables instead of cache rows, pages are claimed as
    sequences grow and freed the step they retire, and admission is gated
    on free pages rather than free slots alone. When the pool runs dry
    mid-decode the youngest request is preempted (pages freed, request
    re-queued with its generated prefix for recompute) — the memory shape
    the paper's §3 inference-phase traces call for: reserved KV tracks
    *live tokens*, not worst-case capacity.

One jitted decode step serves all active slots either way; idle slots
decode into garbage that is masked out.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import Model
from repro.rlhf.rollout import sample_token


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # [P] int32
    max_new_tokens: int
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    n_preempted: int = 0
    t_submit: float = 0.0        # wall time at submit(); admission latency


class ContinuousBatcher:
    def __init__(self, model: Model, cfg: ModelConfig, params, *,
                 slots: int = 4, capacity: int = 128,
                 temperature: float = 1.0, top_k: int = 0,
                 eos_id: Optional[int] = None, seed: int = 0,
                 cache_backend: str = "dense", page_size: int = 16,
                 num_pages: Optional[int] = None, telemetry=None):
        assert cache_backend in ("dense", "paged"), cache_backend
        self.telemetry = telemetry          # obs.RunTelemetry | None
        self.model, self.cfg, self.params = model, cfg, params
        self.B, self.capacity = slots, capacity
        self.temperature, self.top_k, self.eos_id = temperature, top_k, eos_id
        self.backend = cache_backend
        self.queue: Deque[Request] = deque()
        self.active: List[Optional[Request]] = [None] * slots
        self.pos = np.zeros(slots, np.int64)        # next absolute position
        self.last_tok = np.zeros(slots, np.int64)
        self.key = jax.random.PRNGKey(seed)
        self.steps = 0
        self._next_rid = 0
        cache_dtype = jax.tree.leaves(params)[0].dtype

        if cache_backend == "dense":
            self.caches = model.init_cache(slots, capacity, cache_dtype)
            self.caches = {"segments": self.caches, "cross_kv": None}

            def decode(params, caches, tok, pos, key, live):
                logits, caches = model.decode_step(params, caches, tok, pos)
                t, _ = sample_token(key, logits, temperature=temperature,
                                    top_k=top_k)
                t = jnp.where(live, t, 0).astype(jnp.int32)
                return t, caches

            self._decode = jax.jit(decode, donate_argnums=(1,))
            self._prefill = jax.jit(
                lambda params, batch: model.prefill(params, batch, capacity))
        else:
            from repro.paged import PageManager, pool_token_bytes
            self.page_size = page_size
            self.max_blocks = -(-capacity // page_size)
            if num_pages is None:
                # default pool: what the dense layout would reserve
                num_pages = slots * self.max_blocks
            assert num_pages >= self.max_blocks, \
                "pool smaller than one max-length sequence"
            layer_token_bytes = pool_token_bytes(cfg, cache_dtype)
            self.pm = PageManager(
                num_pages, page_size,
                bytes_per_token=layer_token_bytes * cfg.num_layers)
            self.pools = model.init_paged_pools(num_pages, page_size,
                                                cache_dtype)

            def decode(params, pools, tok, pos, bt, key, live):
                logits, pools = model.paged_decode_step(params, pools, tok,
                                                        pos, bt)
                t, _ = sample_token(key, logits, temperature=temperature,
                                    top_k=top_k)
                t = jnp.where(live, t, 0).astype(jnp.int32)
                return t, pools

            self._decode = jax.jit(decode, donate_argnums=(1,))
            self._prefill = jax.jit(
                lambda params, batch, pools, bt, lens: model.paged_prefill(
                    params, batch, pools, bt, lens),
                donate_argnums=(2,))

    def submit(self, prompt: np.ndarray, max_new_tokens: int) -> Request:
        prompt = np.asarray(prompt, np.int32)
        if self.backend == "paged" and \
                len(prompt) + max_new_tokens > self.capacity:
            # reject up front — an unservable request must not reach _admit
            raise ValueError(
                f"request needs {len(prompt) + max_new_tokens} tokens, "
                f"capacity is {self.capacity}")
        req = Request(self._next_rid, prompt, max_new_tokens,
                      t_submit=time.perf_counter())
        self._next_rid += 1
        self.queue.append(req)
        if self.telemetry is not None:
            self.telemetry.registry.counter(
                "serving_requests_total", "requests submitted").inc()
        return req

    # -- paged helpers -------------------------------------------------------
    def _slot_block_tables(self) -> jnp.ndarray:
        sids = [r.rid if r is not None else None for r in self.active]
        return jnp.asarray(self.pm.block_table_array(sids, self.max_blocks))

    def _apply_copies(self, copies):
        """Perform CoW page copies on every layer pool."""
        if not copies:
            return
        from repro.paged import copy_pages
        src = [s for s, _ in copies]
        dst = [d for _, d in copies]
        self.pools = [
            {k: jax.vmap(copy_pages, in_axes=(0, None, None))(pool, src, dst)
             for k, pool in seg.items()}
            for seg in self.pools]

    def _preempt_youngest(self, *, protect: Optional[int] = None) -> bool:
        """Free the youngest active request's pages and re-queue it;
        re-admission recomputes its prompt *plus* generated-so-far prefill
        (``prompt`` itself is never mutated, so repeated preemption cannot
        duplicate tokens). Returns False if no victim is available."""
        victims = [s for s, r in enumerate(self.active)
                   if r is not None and s != protect]
        if not victims:
            return False
        s = max(victims, key=lambda s: self.active[s].rid)
        req = self.active[s]
        self.pm.free_seq(req.rid)
        req.n_preempted += 1
        self.queue.appendleft(req)
        self.active[s] = None
        if self.telemetry is not None:
            self.telemetry.registry.counter(
                "serving_preemptions_total",
                "requests preempted on page-pool exhaustion").inc()
            self.telemetry.tracer.instant(
                f"preempt:r{req.rid}", "serving", rid=req.rid,
                n_preempted=req.n_preempted)
        return True

    # -- internals -----------------------------------------------------------
    def _admit(self):
        for s in range(self.B):
            if self.active[s] is None and self.queue:
                req = self.queue[0]
                # recompute prefill: original prompt plus anything generated
                # before a preemption (empty for fresh requests)
                full = np.concatenate(
                    [req.prompt, np.asarray(req.out_tokens, np.int32)])
                P = len(full)
                if self.backend == "paged":
                    # gate admission on pages for the prefill + first decode
                    if not self.pm.can_allocate(P + 1):
                        break
                    self.queue.popleft()
                    self.pm.allocate(req.rid, P)
                    bt_row = jnp.asarray(self.pm.block_table_array(
                        [req.rid], self.max_blocks))
                    lg, self.pools = self._prefill(
                        self.params, {"tokens": jnp.asarray(full)[None]},
                        self.pools, bt_row,
                        jnp.full((1,), P, jnp.int32))
                else:
                    self.queue.popleft()
                    lg, caches1 = self._prefill(
                        self.params, {"tokens": jnp.asarray(full)[None]})
                    # write slot s of the pool from the batch-of-1 prefill
                    self.caches["segments"] = jax.tree.map(
                        lambda pool, new: pool.at[:, s:s + 1].set(new),
                        self.caches["segments"], caches1["segments"])
                self.key, k = jax.random.split(self.key)
                tok, _ = sample_token(k, lg, temperature=self.temperature,
                                      top_k=self.top_k)
                self.active[s] = req
                self.pos[s] = P
                self.last_tok[s] = int(tok[0])
                req.out_tokens.append(int(tok[0]))
                if self.telemetry is not None:
                    reg = self.telemetry.registry
                    reg.counter("serving_admissions_total",
                                "admissions incl. preemption re-admits").inc()
                    # latency only for first admission: a re-admit's wait is
                    # a preemption artifact, not queueing delay
                    if req.n_preempted == 0:
                        reg.histogram(
                            "serving_admission_latency_s",
                            "submit -> first admission wall time").observe(
                            time.perf_counter() - req.t_submit)

    def _retire(self):
        done = []
        for s, req in enumerate(self.active):
            if req is None:
                continue
            hit_eos = (self.eos_id is not None
                       and req.out_tokens
                       and req.out_tokens[-1] == self.eos_id)
            if hit_eos or len(req.out_tokens) >= req.max_new_tokens:
                req.done = True
                done.append(req)
                if self.backend == "paged":
                    self.pm.free_seq(req.rid)   # pages back to the pool
                self.active[s] = None           # slot freed
        return done

    def _grow_pages(self):
        """Claim the page each live slot's next token will write; preempt
        the youngest request when the pool is dry."""
        from repro.paged import PagePoolExhausted
        for s in range(self.B):
            req = self.active[s]
            if req is None:
                continue
            while True:
                try:
                    self._apply_copies(self.pm.append_token(req.rid))
                    break
                except PagePoolExhausted:
                    if not self._preempt_youngest(protect=s):
                        raise

    def _emit_step(self, t0_us: float, n_tokens: int, n_done: int) -> None:
        """One ``serve_step`` span + the backend occupancy/throughput
        metrics, all read from state the step already maintains."""
        tel = self.telemetry
        tr = tel.tracer
        dur_us = tr.now_us() - t0_us
        args = {"tokens": n_tokens, "retired": n_done,
                "queued": len(self.queue),
                "active": sum(r is not None for r in self.active),
                "kv_reserved_bytes": self.kv_reserved_bytes()}
        reg = tel.registry
        if n_tokens:
            reg.counter("serving_tokens_total",
                        "tokens generated (prefill-sampled + decoded)").inc(
                n_tokens)
        if dur_us > 0:
            reg.gauge("serving_tokens_per_s",
                      "decode throughput of the last step").set(
                n_tokens / (dur_us * 1e-6))
        if self.backend == "paged":
            st = self.pm.stats
            args.update(pages_in_use=st.pages_in_use,
                        cow_copies=st.n_cow_copies - self._cow_mark,
                        forks=st.n_forks - self._fork_mark)
            self._cow_mark, self._fork_mark = st.n_cow_copies, st.n_forks
            reg.gauge("paged_pages_in_use",
                      "pages currently allocated").set(st.pages_in_use)
            reg.gauge("paged_pages_free", "pages currently free").set(
                self.pm.num_pages - st.pages_in_use)
            cow = reg.counter("paged_cow_copies_total",
                              "copy-on-write page copies")
            cow.inc(st.n_cow_copies - cow.value())
            forks = reg.counter("paged_forks_total", "sequence forks")
            forks.inc(st.n_forks - forks.value())
            tr.sample("pages", {"in_use": st.pages_in_use,
                                "free": self.pm.num_pages - st.pages_in_use},
                      ts_us=t0_us + dur_us)
        tr.complete(f"serve_step:{self.steps - 1}", "serving", t0_us, dur_us,
                    **args)

    def step(self) -> List[Request]:
        """Admit, one decode step for all live slots, retire. Returns the
        requests completed this step."""
        t0_us = None
        if self.telemetry is not None:
            t0_us = self.telemetry.tracer.now_us()
            if not hasattr(self, "_cow_mark"):
                self._cow_mark = self._fork_mark = 0
        tokens_before = self._tokens_outstanding() \
            if self.telemetry is not None else 0
        self._admit()
        if self.backend == "paged":
            self._grow_pages()
        live = np.array([r is not None for r in self.active])
        if live.any():
            self.key, k = jax.random.split(self.key)
            tok_in = jnp.asarray(self.last_tok, jnp.int32)
            pos_in = jnp.asarray(self.pos, jnp.int32)
            if self.backend == "paged":
                pos_in = jnp.where(jnp.asarray(live), pos_in, -1)
                tok, self.pools = self._decode(
                    self.params, self.pools, tok_in, pos_in,
                    self._slot_block_tables(), k, jnp.asarray(live))
            else:
                tok, self.caches = self._decode(
                    self.params, self.caches, tok_in, pos_in, k,
                    jnp.asarray(live))
            tok = np.asarray(tok)
            for s, req in enumerate(self.active):
                if req is not None:
                    req.out_tokens.append(int(tok[s]))
                    self.last_tok[s] = int(tok[s])
                    self.pos[s] += 1
        self.steps += 1
        done = self._retire()
        if self.telemetry is not None:
            n_tokens = (self._tokens_outstanding()
                        + sum(len(r.out_tokens) for r in done)
                        - tokens_before)
            self._emit_step(t0_us, n_tokens, len(done))
        return done

    def _tokens_outstanding(self) -> int:
        """Generated tokens held by not-yet-retired requests (active or
        queued — preemption re-queues with tokens kept, so the per-step
        delta against this sum counts each token exactly once)."""
        return (sum(len(r.out_tokens) for r in self.active if r is not None)
                + sum(len(r.out_tokens) for r in self.queue))

    def run_until_drained(self, max_steps: int = 10_000) -> List[Request]:
        finished = []
        for _ in range(max_steps):
            finished.extend(self.step())
            if not self.queue and all(r is None for r in self.active):
                break
        return finished

    # -- introspection -------------------------------------------------------
    def kv_reserved_bytes(self) -> int:
        """Bytes of KV/state the backend currently reserves. Dense reserves
        the whole [B, capacity] cache up front (measured from the actual
        cache arrays, so Mamba/MLA states are counted correctly); paged
        reserves live pages."""
        if self.backend == "paged":
            return self.pm.reserved_bytes()
        return sum(x.size * x.dtype.itemsize
                   for x in jax.tree.leaves(self.caches["segments"]))
