"""Continuous-batching serving scheduler over the fixed-capacity donated
KV cache.

A fixed pool of B slots; requests join free slots between decode steps
(their prompts prefilled into the shared rolling cache at the slot's
absolute positions), finished sequences (EOS or max tokens) free their
slots immediately. One jitted decode step serves all active slots; idle
slots decode into a scratch row that is masked out. This is the memory
shape the paper's inference phases *should* have had: a single statically
allocated cache, zero allocator churn at request boundaries.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import Model
from repro.rlhf.rollout import sample_token


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # [P] int32
    max_new_tokens: int
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ContinuousBatcher:
    def __init__(self, model: Model, cfg: ModelConfig, params, *,
                 slots: int = 4, capacity: int = 128,
                 temperature: float = 1.0, top_k: int = 0,
                 eos_id: Optional[int] = None, seed: int = 0):
        self.model, self.cfg, self.params = model, cfg, params
        self.B, self.capacity = slots, capacity
        self.temperature, self.top_k, self.eos_id = temperature, top_k, eos_id
        self.queue: Deque[Request] = deque()
        self.active: List[Optional[Request]] = [None] * slots
        self.pos = np.zeros(slots, np.int64)        # next absolute position
        self.last_tok = np.zeros(slots, np.int64)
        cache_dtype = jax.tree.leaves(params)[0].dtype
        self.caches = model.init_cache(slots, capacity, cache_dtype)
        self.caches = {"segments": self.caches, "cross_kv": None}
        self.key = jax.random.PRNGKey(seed)
        self.steps = 0

        def decode(params, caches, tok, pos, key, live):
            logits, caches = model.decode_step(params, caches, tok, pos)
            t, _ = sample_token(key, logits, temperature=temperature,
                                top_k=top_k)
            t = jnp.where(live, t, 0).astype(jnp.int32)
            return t, caches

        self._decode = jax.jit(decode, donate_argnums=(1,))
        # per-slot prefill: batch of 1 written into slot s of the cache
        self._prefill = jax.jit(
            lambda params, batch: model.prefill(params, batch, capacity))

    def submit(self, prompt: np.ndarray, max_new_tokens: int) -> Request:
        req = Request(len(self.queue) + 1_000 * (self.steps + 1),
                      np.asarray(prompt, np.int32), max_new_tokens)
        self.queue.append(req)
        return req

    # -- internals -----------------------------------------------------------
    def _admit(self):
        for s in range(self.B):
            if self.active[s] is None and self.queue:
                req = self.queue.popleft()
                lg, caches1 = self._prefill(
                    self.params, {"tokens": jnp.asarray(req.prompt)[None]})
                # splice slot-s rows of the fresh cache into the pool
                def splice(pool, new):
                    return pool.at[:, s:s + 1].set(new)
                self.caches["segments"] = jax.tree.map(
                    lambda pool, new: pool.at[:, s:s + 1].set(new),
                    self.caches["segments"], caches1["segments"])
                self.key, k = jax.random.split(self.key)
                tok, _ = sample_token(k, lg, temperature=self.temperature,
                                      top_k=self.top_k)
                self.active[s] = req
                self.pos[s] = len(req.prompt)
                self.last_tok[s] = int(tok[0])
                req.out_tokens.append(int(tok[0]))

    def _retire(self):
        done = []
        for s, req in enumerate(self.active):
            if req is None:
                continue
            hit_eos = (self.eos_id is not None
                       and req.out_tokens
                       and req.out_tokens[-1] == self.eos_id)
            if hit_eos or len(req.out_tokens) >= req.max_new_tokens:
                req.done = True
                done.append(req)
                self.active[s] = None   # slot freed; cache rows overwritten
        return done

    def step(self) -> List[Request]:
        """Admit, one decode step for all live slots, retire. Returns the
        requests completed this step."""
        self._admit()
        live = np.array([r is not None for r in self.active])
        if live.any():
            self.key, k = jax.random.split(self.key)
            tok, self.caches = self._decode(
                self.params, self.caches,
                jnp.asarray(self.last_tok, jnp.int32),
                jnp.asarray(self.pos, jnp.int32), k, jnp.asarray(live))
            tok = np.asarray(tok)
            for s, req in enumerate(self.active):
                if req is not None:
                    req.out_tokens.append(int(tok[s]))
                    self.last_tok[s] = int(tok[s])
                    self.pos[s] += 1
        self.steps += 1
        return self._retire()

    def run_until_drained(self, max_steps: int = 10_000) -> List[Request]:
        finished = []
        for _ in range(max_steps):
            finished.extend(self.step())
            if not self.queue and all(r is None for r in self.active):
                break
        return finished
