"""Continuous-batching serving scheduler with selectable KV-cache backends.

Two cache layouts behind one admit/decode/retire loop:

  * ``dense`` — the seed's fixed pool of B slots over a donated
    ``[B, capacity]`` rolling cache. Zero allocator churn, but every slot
    reserves ``capacity`` tokens of KV no matter how short its request.
  * ``paged`` — a vLLM-style global page pool (``repro.paged``): slots
    hold block tables instead of cache rows, pages are claimed as
    sequences grow and freed the step they retire, and admission is gated
    on free pages rather than free slots alone. When the pool runs dry
    mid-decode the youngest request is preempted (pages freed, request
    re-queued with its generated prefix for recompute) — the memory shape
    the paper's §3 inference-phase traces call for: reserved KV tracks
    *live tokens*, not worst-case capacity.

One jitted decode step serves all active slots either way; idle slots
decode into garbage that is masked out.

Two decode-path speed features ride on top (DESIGN.md "Fast decode path"):

  * ``capture_buckets`` — a compile-bucket ladder (``serving.buckets``):
    prompts pad to the smallest capture length >= P (masked exactly via
    per-row ``lengths``), paged decode batches pad to a live-slot bucket
    (idle rows carry ``position = -1`` and write nothing), and an explicit
    warmup pass at construction compiles every bucket before traffic
    arrives. The compile cache tracks hits/misses/recompiles per
    ``(kind, backend, bucket)`` key and feeds ``serving_*`` metrics.
  * ``spec_decode`` — MTP self-speculative greedy decoding: draft
    ``spec_k`` tokens per slot from the model's MTP chain, verify all
    drafts in ONE batched forward, accept the greedy-consistent prefix.
    Output is bit-identical to vanilla greedy decoding by construction
    (every emitted token is the verify forward's own argmax); drafts only
    move the accept rate. Greedy-only (``temperature == 0, top_k == 0``).

Multi-tenant serving features (paged backend):

  * ``prefix_cache`` — cross-request prefix sharing: committed prompt
    pages are content-hash indexed in the ``PageManager`` and a new
    request whose prompt shares the prefix reuses them with a refcount
    bump, prefilling only the *suffix* (bucketed on suffix length). With
    the cache on, **every** prefill — cold included — runs through
    ``Model.paged_prefill_suffix``, so a hash hit is bit-identical to a
    cold prefill by construction. ``update_params`` bumps the pool's
    weight version and invalidates every cached prefix, so RLHF weight
    updates never serve stale KV.
  * per-tenant fairness — requests carry a ``tenant`` label; admission
    runs weighted round-robin over per-tenant FIFO queues using virtual
    time (``vtime += cost / weight``) with an anti-starvation aging term,
    so a heavy tenant cannot starve a light one and every queued request
    is admitted in bounded time. Preemption picks the victim holding the
    most *exclusively owned* pages (shared prefix pages survive their
    victim and keep serving siblings).
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict, deque
from typing import Deque, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import Model
from repro.rlhf.rollout import place_kv_tp, sample_token, spec_verify_step
from repro.sharding import ctx as shctx
from repro.serving.buckets import BucketLadder, CompileCache


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # [P] int32
    max_new_tokens: int
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    n_preempted: int = 0
    t_submit: float = 0.0        # wall time at submit(); admission latency
    tenant: str = "default"
    step_submit: int = 0         # batcher step at submit(); aging clock
    n_cached_tokens: int = 0     # prompt tokens served from the prefix cache


class ContinuousBatcher:
    def __init__(self, model: Model, cfg: ModelConfig, params, *,
                 slots: int = 4, capacity: int = 128,
                 temperature: float = 1.0, top_k: int = 0,
                 eos_id: Optional[int] = None, seed: int = 0,
                 cache_backend: str = "dense", page_size: int = 16,
                 num_pages: Optional[int] = None, telemetry=None,
                 capture_buckets: Optional[Sequence[int]] = None,
                 spec_decode: bool = False, spec_k: int = 2,
                 warmup: bool = True, prefix_cache: bool = False,
                 tenant_weights: Optional[Dict[str, float]] = None,
                 aging: float = 1.0, mesh=None):
        assert cache_backend in ("dense", "paged"), cache_backend
        # TP mesh (DESIGN.md §9): serving params arrive model-sharded from
        # the trainer's compute layout, the KV pool/cache commits sharded
        # over the kv-head axis, and every jitted program (prefill, decode,
        # spec verify) traces under ``ctx.use_mesh`` so its "model"
        # constraint hints resolve. None = the historical single-device /
        # pure-DP layout, byte-for-byte.
        self.mesh = mesh
        assert not (prefix_cache and cache_backend != "paged"), \
            "prefix caching needs the paged backend"
        self.telemetry = telemetry          # obs.RunTelemetry | None
        # memory observatory: owner registration for the attribution
        # engine, the run's flight recorder, and per-jit-program
        # compiled-memory stats joined to CompileCache keys
        self.flight = getattr(telemetry, "flight", None)
        self.attributor = None
        self.compiled_memory: dict = {}
        if telemetry is not None:
            from repro.obs import MemoryAttributor
            at = telemetry.attribution
            if at is None:
                at = telemetry.attribution = MemoryAttributor()
            at.register("serving_params", lambda: self.params)
            at.register("kv_cache", lambda: getattr(self, "caches", None))
            at.register("kv_pool", lambda: getattr(self, "pools", None))
            at.register("spec_state", lambda: getattr(self, "h_last", None))
            self.attributor = at
        self.model, self.cfg, self.params = model, cfg, params
        self.B, self.capacity = slots, capacity
        self.temperature, self.top_k, self.eos_id = temperature, top_k, eos_id
        self.backend = cache_backend
        self.prefix_cache = prefix_cache
        # per-tenant FIFO queues under weighted round-robin admission;
        # single-tenant traffic degenerates to the old global FIFO
        self.queues: "OrderedDict[str, Deque[Request]]" = OrderedDict()
        self.tenant_weights: Dict[str, float] = dict(tenant_weights or {})
        self.aging = aging
        self._vtime: Dict[str, float] = {}
        self._prefix_tokens_hit = 0
        self._prefix_tokens_total = 0
        self.active: List[Optional[Request]] = [None] * slots
        self.pos = np.zeros(slots, np.int64)        # next absolute position
        self.last_tok = np.zeros(slots, np.int64)
        self.key = jax.random.PRNGKey(seed)
        self.steps = 0
        self._next_rid = 0
        cache_dtype = jax.tree.leaves(params)[0].dtype

        # compile-bucket ladder + compile-cache accounting ------------------
        self.compile_cache = CompileCache()
        self.prefill_ladder = (BucketLadder(capture_buckets)
                               if capture_buckets else None)
        self.slot_ladder = None
        if capture_buckets and cache_backend == "paged":
            # live-slot buckets: ladder rungs clipped to the slot count
            # (dense rows cannot be subset — its decode stays full-B)
            self.slot_ladder = BucketLadder(
                [min(b, slots) for b in capture_buckets] + [slots])

        # speculative decoding ----------------------------------------------
        self.spec_decode = spec_decode
        self.spec_k = spec_k
        if spec_decode:
            assert model.supports_spec_decode(), \
                "spec decode needs a token-input attention-only model " \
                "with mtp_depth > 0"
            assert temperature <= 0.0 and top_k == 0, \
                "spec decode is greedy-only (temperature=0, top_k=0)"
            self.h_last = jnp.zeros((slots, cfg.d_model), cache_dtype)

        if cache_backend == "dense":
            self.caches = model.init_cache(slots, capacity, cache_dtype)
            self.caches = {"segments": place_kv_tp(self.caches, mesh),
                           "cross_kv": None}

            def decode(params, caches, tok, pos, key, live):
                logits, caches = model.decode_step(params, caches, tok, pos)
                t, _ = sample_token(key, logits, temperature=temperature,
                                    top_k=top_k)
                t = jnp.where(live, t, 0).astype(jnp.int32)
                return t, caches

            self._decode = jax.jit(decode, donate_argnums=(1,))
            # the lengths-masked prefill needs token inputs and attention
            # kinds; plain traffic on exotic models keeps the legacy path
            self._rich_prefill = self.prefill_ladder is not None or \
                spec_decode
            if self._rich_prefill:
                self._prefill = jax.jit(
                    lambda params, batch, lens: model.prefill(
                        params, batch, capacity, lengths=lens, return_h=True))
            else:
                self._prefill = jax.jit(
                    lambda params, batch: model.prefill(params, batch,
                                                        capacity))

            if spec_decode:
                def spec_step(params, caches, h_last, tok, pos, live):
                    return spec_verify_step(
                        model, spec_k,
                        lambda seq, positions: model.decode_multi(
                            params, caches, seq, positions),
                        params, h_last, tok, pos, live)

                self._spec = jax.jit(spec_step, donate_argnums=(1,))
        else:
            from repro.paged import PageManager, pool_token_bytes
            self.page_size = page_size
            self.max_blocks = -(-capacity // page_size)
            if num_pages is None:
                # default pool: what the dense layout would reserve
                num_pages = slots * self.max_blocks
            assert num_pages >= self.max_blocks, \
                "pool smaller than one max-length sequence"
            layer_token_bytes = pool_token_bytes(cfg, cache_dtype)
            self.pm = PageManager(
                num_pages, page_size,
                bytes_per_token=layer_token_bytes * cfg.num_layers)
            self.pools = place_kv_tp(
                model.init_paged_pools(num_pages, page_size, cache_dtype),
                mesh)

            def decode(params, pools, tok, pos, bt, key, live):
                logits, pools = model.paged_decode_step(params, pools, tok,
                                                        pos, bt)
                t, _ = sample_token(key, logits, temperature=temperature,
                                    top_k=top_k)
                t = jnp.where(live, t, 0).astype(jnp.int32)
                return t, pools

            self._decode = jax.jit(decode, donate_argnums=(1,))
            self._prefill = jax.jit(
                lambda params, batch, pools, bt, lens: model.paged_prefill(
                    params, batch, pools, bt, lens, return_h=True),
                donate_argnums=(2,))
            if prefix_cache:
                # with the cache on, ALL prefills (cold included) run the
                # suffix program — hash hits are bit-identical to cold
                # prefills because they are the same computation
                self._prefill_suffix = jax.jit(
                    lambda params, batch, pools, bt, start, lens:
                        model.paged_prefill_suffix(
                            params, batch, pools, bt, start, lens,
                            return_h=True),
                    donate_argnums=(2,))

            if spec_decode:
                def spec_step(params, pools, h_last, tok, pos, bt, live):
                    return spec_verify_step(
                        model, spec_k,
                        lambda seq, positions: model.paged_decode_multi(
                            params, pools, seq, positions, bt),
                        params, h_last, tok, pos, live)

                self._spec = jax.jit(spec_step, donate_argnums=(1,))

        if warmup and self.prefill_ladder is not None:
            self.warmup()

    # -- warmup capture ------------------------------------------------------
    def warmup(self, max_prompt_len: Optional[int] = None) -> None:
        """Compile every ladder bucket before traffic arrives. Runs real
        calls on the live caches with only dead writes (``lengths = 0``,
        ``position = -1``), so it must precede admission — which it does:
        construction is the one moment both backends are guaranteed empty.
        After this, any post-warmup compile-cache miss is a recompile.
        Traces run under the TP mesh (if any), so every bucket's program
        bakes in the same model-sharded layout ``step`` serves with."""
        with shctx.use_mesh(self.mesh):
            self._warmup_inner(max_prompt_len)

    def _warmup_inner(self, max_prompt_len: Optional[int]) -> None:
        cc = self.compile_cache
        if self.prefill_ladder is not None:
            for Sb in self.prefill_ladder.up_to(
                    max_prompt_len or self.capacity):
                batch = {"tokens": jnp.zeros((1, Sb), jnp.int32)}
                lens = jnp.zeros((1,), jnp.int32)
                if self.backend == "dense":
                    self._prefill(self.params, batch, lens)
                    cc.warm(("prefill", self.backend, Sb))
                    self._note_compiled(("prefill", self.backend, Sb),
                                        self._prefill, self.params, batch,
                                        lens)
                elif self.prefix_cache:
                    bt = jnp.full((1, self.max_blocks), -1, jnp.int32)
                    start = jnp.zeros((1,), jnp.int32)
                    _, self.pools, _ = self._prefill_suffix(
                        self.params, batch, self.pools, bt, start, lens)
                    cc.warm(("prefill", self.backend, Sb))
                    self._note_compiled(("prefill", self.backend, Sb),
                                        self._prefill_suffix, self.params,
                                        batch, self.pools, bt, start, lens)
                else:
                    bt = jnp.full((1, self.max_blocks), -1, jnp.int32)
                    _, self.pools, _ = self._prefill(
                        self.params, batch, self.pools, bt, lens)
                    cc.warm(("prefill", self.backend, Sb))
                    self._note_compiled(("prefill", self.backend, Sb),
                                        self._prefill, self.params, batch,
                                        self.pools, bt, lens)
        for nb in (self.slot_ladder.up_to(self.B)
                   if self.slot_ladder is not None else (self.B,)):
            tok = jnp.zeros((nb,), jnp.int32)
            pos = jnp.full((nb,), -1, jnp.int32)
            live = jnp.zeros((nb,), bool)
            self.key, k = jax.random.split(self.key)
            if self.backend == "dense":
                if nb != self.B:
                    continue                    # dense decode is full-B only
                if self.spec_decode:
                    *_, self.caches = self._spec(
                        self.params, self.caches, self.h_last, tok, pos,
                        live)
                    cc.warm(self._decode_key(nb))
                    self._note_compiled(self._decode_key(nb), self._spec,
                                        self.params, self.caches,
                                        self.h_last, tok, pos, live)
                else:
                    _, self.caches = self._decode(
                        self.params, self.caches, tok, pos, k, live)
                    cc.warm(self._decode_key(nb))
                    self._note_compiled(self._decode_key(nb), self._decode,
                                        self.params, self.caches, tok, pos,
                                        k, live)
            else:
                bt = jnp.full((nb, self.max_blocks), -1, jnp.int32)
                if self.spec_decode:
                    h = jnp.zeros((nb, self.cfg.d_model),
                                  self.h_last.dtype)
                    *_, self.pools = self._spec(
                        self.params, self.pools, h, tok, pos, bt, live)
                    cc.warm(self._decode_key(nb))
                    self._note_compiled(self._decode_key(nb), self._spec,
                                        self.params, self.pools, h, tok,
                                        pos, bt, live)
                else:
                    _, self.pools = self._decode(
                        self.params, self.pools, tok, pos, bt, k, live)
                    cc.warm(self._decode_key(nb))
                    self._note_compiled(self._decode_key(nb), self._decode,
                                        self.params, self.pools, tok, pos,
                                        bt, k, live)
        cc.finish_warmup()

    def _decode_key(self, nb: int):
        kind = "spec" if self.spec_decode else "decode"
        extents = (nb, self.spec_k + 1) if self.spec_decode else (nb,)
        return (kind, self.backend) + extents

    def _note_compiled(self, key, fn, *args) -> None:
        """Join this CompileCache key with its program's compiled-memory
        stats (XLA ``memory_analysis``): temp/arg/output bytes land in the
        registry under ``program=<key>`` and in ``self.compiled_memory``
        — so every bucket rung, and any post-warmup recompile, carries
        its memory cost. Lowering only traces; no execution."""
        if self.telemetry is None or key in self.compiled_memory:
            return
        from repro.obs import record_compiled_memory
        stats = record_compiled_memory(
            self.telemetry.registry, ":".join(str(k) for k in key),
            fn, *args)
        if stats is not None:
            self.compiled_memory[key] = stats

    def _record_key(self, key, fn=None, *args) -> None:
        hit = self.compile_cache.lookup(key)
        if self.telemetry is not None and not hit:
            self.telemetry.tracer.instant(
                f"compile:{':'.join(str(k) for k in key)}", "serving",
                recompile=self.compile_cache.warmed)
            # a post-warmup miss is a recompile: account its memory too
            if fn is not None:
                self._note_compiled(key, fn, *args)

    def submit(self, prompt: np.ndarray, max_new_tokens: int,
               tenant: str = "default") -> Request:
        prompt = np.asarray(prompt, np.int32)
        if self.backend == "paged" and \
                len(prompt) + max_new_tokens > self.capacity:
            # reject up front — an unservable request must not reach _admit
            raise ValueError(
                f"request needs {len(prompt) + max_new_tokens} tokens, "
                f"capacity is {self.capacity}")
        req = Request(self._next_rid, prompt, max_new_tokens,
                      t_submit=time.perf_counter(), tenant=tenant,
                      step_submit=self.steps)
        self._next_rid += 1
        q = self.queues.get(tenant)
        if q is None:
            q = self.queues[tenant] = deque()
        if not q:
            # a tenant going from idle to backlogged re-enters at the
            # current service frontier: it must not bank idle time and
            # then monopolise admission catching up
            floor = min((self._vtime.get(t, 0.0)
                         for t, tq in self.queues.items() if tq and t != tenant),
                        default=self._vtime.get(tenant, 0.0))
            self._vtime[tenant] = max(self._vtime.get(tenant, 0.0), floor)
        q.append(req)
        if self.telemetry is not None:
            self.telemetry.registry.counter(
                "serving_requests_total", "requests submitted").inc()
        return req

    @property
    def queue(self) -> List[Request]:
        """Flat view of all queued requests (oldest first), across tenants."""
        out = [r for q in self.queues.values() for r in q]
        out.sort(key=lambda r: r.rid)
        return out

    @property
    def n_queued(self) -> int:
        return sum(len(q) for q in self.queues.values())

    # -- paged helpers -------------------------------------------------------
    def _block_tables_for(self, sids: Sequence[Optional[int]]) -> jnp.ndarray:
        return jnp.asarray(self.pm.block_table_array(sids, self.max_blocks))

    def _slot_block_tables(self) -> jnp.ndarray:
        sids = [r.rid if r is not None else None for r in self.active]
        return self._block_tables_for(sids)

    def _apply_copies(self, copies):
        """Perform CoW page copies on every layer pool."""
        if not copies:
            return
        from repro.paged import copy_pages
        src = [s for s, _ in copies]
        dst = [d for _, d in copies]
        self.pools = [
            {k: jax.vmap(copy_pages, in_axes=(0, None, None))(pool, src, dst)
             for k, pool in seg.items()}
            for seg in self.pools]

    def _preempt_youngest(self, *, protect: Optional[int] = None) -> bool:
        """Free a victim request's pages and re-queue it; re-admission
        recomputes its prompt *plus* generated-so-far prefill (``prompt``
        itself is never mutated, so repeated preemption cannot duplicate
        tokens). The victim is the youngest active request; with the
        prefix cache on, ties in actual reclaim matter — the victim is
        the one holding the most *exclusively owned* pages (refcount 1),
        since shared prefix pages survive preemption and free nothing.
        Returns False if no victim is available."""
        victims = [s for s, r in enumerate(self.active)
                   if r is not None and s != protect]
        if not victims:
            return False
        if self.prefix_cache:
            s = max(victims, key=lambda s: (
                self.pm.reclaimable_pages(self.active[s].rid),
                self.active[s].rid))
        else:
            s = max(victims, key=lambda s: self.active[s].rid)
        req = self.active[s]
        self.pm.free_seq(req.rid)
        req.n_preempted += 1
        self.queues[req.tenant].appendleft(req)
        self.active[s] = None
        if self.telemetry is not None:
            self.telemetry.registry.counter(
                "serving_preemptions_total",
                "requests preempted on page-pool exhaustion").inc()
            self.telemetry.tracer.instant(
                f"preempt:r{req.rid}", "serving", rid=req.rid,
                n_preempted=req.n_preempted)
        return True

    # -- internals -----------------------------------------------------------
    def _pick_tenant(self) -> Optional[str]:
        """Weighted round-robin with anti-starvation aging: among
        backlogged tenants, pick the one minimising ``vtime[tenant] -
        aging * steps_waited`` for its queue head. Lowest virtual time
        (least service per unit weight) wins, and every waiting head's
        score falls by ``aging`` per step — so no tenant starves
        regardless of the weight ratio. Ties break on oldest request."""
        best, best_score = None, None
        for t, q in self.queues.items():
            if not q:
                continue
            score = (self._vtime.get(t, 0.0)
                     - self.aging * (self.steps - q[0].step_submit))
            if best is None or score < best_score or \
                    (score == best_score
                     and q[0].rid < self.queues[best][0].rid):
                best, best_score = t, score
        return best

    def _admit(self):
        for s in range(self.B):
            if self.active[s] is not None:
                continue
            tenant = self._pick_tenant()
            if tenant is None:
                break
            req = self.queues[tenant][0]
            # recompute prefill: original prompt plus anything generated
            # before a preemption (empty for fresh requests)
            full = np.concatenate(
                [req.prompt, np.asarray(req.out_tokens, np.int32)])
            P = len(full)
            n_cached = 0
            if self.backend == "paged" and self.prefix_cache:
                # gate admission on pages for the non-cached tail + first
                # decode token; matched pages are reused, not claimed
                if not self.pm.can_allocate_prefix(full, 1):
                    break
                self.queues[tenant].popleft()
                _, n_cached = self.pm.allocate_prefix(req.rid, full)
                suffix = full[n_cached:]
                # bucket on the *suffix* length — a hash hit compiles and
                # computes only the tail
                Sb = self.prefill_ladder.fit(len(suffix)) \
                    if self.prefill_ladder else len(suffix)
                padded = np.zeros(Sb, np.int32)
                padded[:len(suffix)] = suffix
                lens = jnp.full((1,), P, jnp.int32)
                start = jnp.full((1,), n_cached, jnp.int32)
                bt_row = self._block_tables_for([req.rid])
                pb = {"tokens": jnp.asarray(padded)[None]}
                lg, self.pools, h1 = self._prefill_suffix(
                    self.params, pb, self.pools, bt_row, start, lens)
                self.pm.commit_prefix(req.rid, full)
                self._prefix_tokens_hit += n_cached
                self._prefix_tokens_total += P
                req.n_cached_tokens = n_cached
                self._record_key(("prefill", self.backend, Sb),
                                 self._prefill_suffix, self.params, pb,
                                 self.pools, bt_row, start, lens)
            else:
                # pad the prompt up to its capture bucket; the per-row
                # ``lengths`` makes the padding exactly invisible
                Sb = self.prefill_ladder.fit(P) if self.prefill_ladder \
                    else P
                padded = np.zeros(Sb, np.int32)
                padded[:P] = full
                lens = jnp.full((1,), P, jnp.int32)
                if self.backend == "paged":
                    # gate admission on pages for the prefill + first decode
                    if not self.pm.can_allocate(P + 1):
                        break
                    self.queues[tenant].popleft()
                    self.pm.allocate(req.rid, P)
                    bt_row = self._block_tables_for([req.rid])
                    lg, self.pools, h1 = self._prefill(
                        self.params, {"tokens": jnp.asarray(padded)[None]},
                        self.pools, bt_row, lens)
                else:
                    self.queues[tenant].popleft()
                    if self._rich_prefill:
                        lg, caches1, h1 = self._prefill(
                            self.params,
                            {"tokens": jnp.asarray(padded)[None]}, lens)
                    else:
                        lg, caches1 = self._prefill(
                            self.params,
                            {"tokens": jnp.asarray(padded)[None]})
                        h1 = None
                    # write slot s of the pool from the batch-of-1 prefill
                    self.caches["segments"] = jax.tree.map(
                        lambda pool, new: pool.at[:, s:s + 1].set(new),
                        self.caches["segments"], caches1["segments"])
                pk = ("prefill", self.backend, Sb)
                pb = {"tokens": jnp.asarray(padded)[None]}
                if self.backend == "paged":
                    self._record_key(pk, self._prefill, self.params, pb,
                                     self.pools, bt_row, lens)
                elif self._rich_prefill:
                    self._record_key(pk, self._prefill, self.params, pb,
                                     lens)
                else:
                    self._record_key(pk, self._prefill, self.params, pb)
            # charge the tenant's virtual time for the service footprint
            # it just claimed (prompt + remaining generation budget)
            cost = P + req.max_new_tokens - len(req.out_tokens)
            self._vtime[tenant] = self._vtime.get(tenant, 0.0) \
                + cost / max(self.tenant_weights.get(tenant, 1.0), 1e-9)
            self.key, k = jax.random.split(self.key)
            tok, _ = sample_token(k, lg, temperature=self.temperature,
                                  top_k=self.top_k)
            self.active[s] = req
            self.pos[s] = P
            self.last_tok[s] = int(tok[0])
            req.out_tokens.append(int(tok[0]))
            if self.spec_decode:
                self.h_last = self.h_last.at[s].set(h1[0])
            if self.telemetry is not None:
                reg = self.telemetry.registry
                reg.counter("serving_admissions_total",
                            "admissions incl. preemption re-admits").inc()
                if n_cached:
                    reg.counter(
                        "paged_prefix_hit_tokens_total",
                        "prompt tokens served from the prefix cache").inc(
                        n_cached)
                # latency only for first admission: a re-admit's wait is
                # a preemption artifact, not queueing delay
                if req.n_preempted == 0:
                    reg.histogram(
                        "serving_admission_latency_s",
                        "submit -> first admission wall time").observe(
                        time.perf_counter() - req.t_submit)

    def _retire(self):
        done = []
        for s, req in enumerate(self.active):
            if req is None:
                continue
            hit_eos = (self.eos_id is not None
                       and req.out_tokens
                       and req.out_tokens[-1] == self.eos_id)
            if hit_eos or len(req.out_tokens) >= req.max_new_tokens:
                req.done = True
                done.append(req)
                if self.backend == "paged":
                    self.pm.free_seq(req.rid)   # pages back to the pool
                self.active[s] = None           # slot freed
        return done

    def _grow_pages(self, n: int = 1):
        """Claim the page(s) each live slot's next ``n`` tokens will write
        (spec decode grows by ``spec_k + 1`` before the verify forward);
        preempt the youngest request when the pool is dry."""
        from repro.paged import PagePoolExhausted
        for s in range(self.B):
            req = self.active[s]
            if req is None:
                continue
            while True:
                try:
                    self._apply_copies(self.pm.append_tokens(req.rid, n))
                    break
                except PagePoolExhausted:
                    if not self._preempt_youngest(protect=s):
                        raise

    # -- decode flavours -----------------------------------------------------
    def _append_emitted(self, s: int, emitted_toks) -> int:
        """Append a run of emitted tokens to slot ``s``'s request, stopping
        at EOS or the request's token budget. Returns the count actually
        taken (== position advance). Any truncation here retires the slot
        this very step, so the cache's extra draft entries — masked by
        position until overwritten — are never observed."""
        req = self.active[s]
        taken = 0
        for tokv in emitted_toks:
            req.out_tokens.append(int(tokv))
            taken += 1
            if (self.eos_id is not None and int(tokv) == self.eos_id) or \
                    len(req.out_tokens) >= req.max_new_tokens:
                break
        self.pos[s] += taken
        self.last_tok[s] = req.out_tokens[-1]
        return taken

    def _vanilla_decode(self, live_slots: List[int]) -> None:
        self.key, k = jax.random.split(self.key)
        if self.backend == "paged" and self.slot_ladder is not None:
            # gather live rows into a slot bucket; pad rows are idle
            # (position -1 -> dropped writes, masked sampling)
            nb = self.slot_ladder.fit(len(live_slots))
            tok_in = np.zeros(nb, np.int64)
            pos_in = np.full(nb, -1, np.int64)
            tok_in[:len(live_slots)] = self.last_tok[live_slots]
            pos_in[:len(live_slots)] = self.pos[live_slots]
            sids = [self.active[s].rid for s in live_slots]
            sids += [None] * (nb - len(live_slots))
            live_v = jnp.asarray(np.arange(nb) < len(live_slots))
            self._record_key(self._decode_key(nb))
            tok, self.pools = self._decode(
                self.params, self.pools, jnp.asarray(tok_in, jnp.int32),
                jnp.asarray(pos_in, jnp.int32), self._block_tables_for(sids),
                k, live_v)
            tok = np.asarray(tok)
            for j, s in enumerate(live_slots):
                self._append_emitted(s, [tok[j]])
            return
        live = np.array([r is not None for r in self.active])
        tok_in = jnp.asarray(self.last_tok, jnp.int32)
        pos_in = jnp.asarray(self.pos, jnp.int32)
        self._record_key(self._decode_key(self.B))
        if self.backend == "paged":
            pos_in = jnp.where(jnp.asarray(live), pos_in, -1)
            tok, self.pools = self._decode(
                self.params, self.pools, tok_in, pos_in,
                self._slot_block_tables(), k, jnp.asarray(live))
        else:
            tok, self.caches = self._decode(
                self.params, self.caches, tok_in, pos_in, k,
                jnp.asarray(live))
        tok = np.asarray(tok)
        for s in live_slots:
            self._append_emitted(s, [tok[s]])

    def _spec_decode_step(self, live_slots: List[int]) -> None:
        """Draft + one batched verify + accept for all live slots."""
        n_live = len(live_slots)
        if self.backend == "paged" and self.slot_ladder is not None:
            nb = self.slot_ladder.fit(n_live)
        elif self.backend == "paged":
            nb = self.B
        else:
            nb = self.B
        if self.backend == "paged":
            tok_in = np.zeros(nb, np.int64)
            pos_in = np.full(nb, -1, np.int64)
            tok_in[:n_live] = self.last_tok[live_slots]
            pos_in[:n_live] = self.pos[live_slots]
            sids = [self.active[s].rid for s in live_slots]
            sids += [None] * (nb - n_live)
            live_v = jnp.asarray(np.arange(nb) < n_live)
            h_in = self.h_last[np.asarray(live_slots, np.int32)]
            if nb > n_live:
                h_in = jnp.concatenate(
                    [h_in, jnp.zeros((nb - n_live,) + h_in.shape[1:],
                                     h_in.dtype)])
            self._record_key(self._decode_key(nb))
            greedy, _lp, n_acc, h_new, self.pools = self._spec(
                self.params, self.pools, h_in,
                jnp.asarray(tok_in, jnp.int32), jnp.asarray(pos_in, jnp.int32),
                self._block_tables_for(sids), live_v)
            rows = range(n_live)
        else:
            live = np.array([r is not None for r in self.active])
            pos_in = np.where(live, self.pos, -1)
            self._record_key(self._decode_key(nb))
            greedy, _lp, n_acc, h_new, self.caches = self._spec(
                self.params, self.caches, self.h_last,
                jnp.asarray(self.last_tok, jnp.int32),
                jnp.asarray(pos_in, jnp.int32), jnp.asarray(live))
            rows = live_slots
        greedy = np.asarray(greedy)
        n_acc_np = np.asarray(n_acc)
        reg = self.telemetry.registry if self.telemetry is not None else None
        for j, s in zip(rows, live_slots):
            pos_before = int(self.pos[s])
            take = int(n_acc_np[j]) + 1
            taken = self._append_emitted(s, greedy[j, :take])
            if self.backend == "paged":
                # drop the page claim for rejected (and untaken) drafts
                self.pm.truncate(self.active[s].rid, pos_before + taken)
            if reg is not None:
                reg.histogram(
                    "serving_specdec_accepted_len",
                    "accepted draft-prefix length per slot step").observe(
                    int(n_acc_np[j]))
                rejected = self.spec_k - int(n_acc_np[j])
                if rejected:
                    reg.counter(
                        "serving_specdec_drafts_rejected_total",
                        "draft tokens rejected by the verify step").inc(
                        rejected)
        # live rows of h_new are the trunk state at each slot's new last
        # accepted position; stale rows are refreshed at admission
        if self.backend == "paged":
            self.h_last = self.h_last.at[
                np.asarray(live_slots, np.int32)].set(h_new[:n_live])
        else:
            self.h_last = h_new

    def _emit_step(self, t0_us: float, n_tokens: int, n_done: int) -> None:
        """One ``serve_step`` span + the backend occupancy/throughput
        metrics, all read from state the step already maintains.
        ``n_tokens`` is a delta of per-request token counts, so bucket
        padding and idle decode rows can never inflate tokens/s — only
        tokens appended to live (admitted, non-padded) requests count."""
        tel = self.telemetry
        tr = tel.tracer
        dur_us = tr.now_us() - t0_us
        cc = self.compile_cache
        args = {"tokens": n_tokens, "retired": n_done,
                "queued": self.n_queued,
                "active": sum(r is not None for r in self.active),
                "recompiles": cc.recompiles,
                "kv_reserved_bytes": self.kv_reserved_bytes()}
        reg = tel.registry
        if n_tokens:
            reg.counter("serving_tokens_total",
                        "tokens generated (prefill-sampled + decoded)").inc(
                n_tokens)
        if dur_us > 0:
            reg.gauge("serving_tokens_per_s",
                      "decode throughput of the last step").set(
                n_tokens / (dur_us * 1e-6))
        reg.gauge("serving_compile_cache_hit_rate",
                  "compile-cache hit rate over all jit keys").set(
            cc.hit_rate)
        rec = reg.counter("serving_recompiles_total",
                          "post-warmup compile-cache misses (bucket escapes)")
        rec.inc(cc.recompiles - rec.value())
        if self.backend == "paged":
            st = self.pm.stats
            args.update(pages_in_use=st.pages_in_use,
                        cow_copies=st.n_cow_copies - self._cow_mark,
                        forks=st.n_forks - self._fork_mark)
            self._cow_mark, self._fork_mark = st.n_cow_copies, st.n_forks
            reg.gauge("paged_pages_in_use",
                      "pages currently allocated").set(st.pages_in_use)
            reg.gauge("paged_pages_free", "pages currently free").set(
                self.pm.num_pages - st.pages_in_use)
            cow = reg.counter("paged_cow_copies_total",
                              "copy-on-write page copies")
            cow.inc(st.n_cow_copies - cow.value())
            forks = reg.counter("paged_forks_total", "sequence forks")
            forks.inc(st.n_forks - forks.value())
            if self.prefix_cache:
                reg.gauge("paged_prefix_cached_pages",
                          "zero-ref pages parked in the prefix LRU").set(
                    self.pm.num_cached_pages)
                reg.gauge("paged_prefix_cached_bytes",
                          "KV bytes held by parked prefix pages").set(
                    self.pm.cached_bytes())
                reg.gauge("serving_prefix_hit_rate",
                          "cumulative prompt tokens served from cache").set(
                    self.prefix_hit_rate())
                hits = reg.counter("paged_prefix_hits_total",
                                   "pages reused via prefix match")
                hits.inc(st.n_prefix_hits - hits.value())
                ev = reg.counter("paged_prefix_evictions_total",
                                 "parked pages evicted under pool pressure")
                ev.inc(st.n_prefix_evictions - ev.value())
                args.update(prefix_cached_pages=self.pm.num_cached_pages,
                            prefix_hit_rate=round(self.prefix_hit_rate(), 4))
            tr.sample("pages", {"in_use": st.pages_in_use,
                                "free": self.pm.num_pages - st.pages_in_use},
                      ts_us=t0_us + dur_us)
        tr.complete(f"serve_step:{self.steps - 1}", "serving", t0_us, dur_us,
                    **args)

    def step(self) -> List[Request]:
        """Admit, one decode step for all live slots, retire. Returns the
        requests completed this step. With a flight recorder attached the
        step is watermark-checked, and a caught ``RESOURCE_EXHAUSTED``
        is captured (owner table, top buffers, recent serve steps) before
        the re-raise."""
        try:
            with shctx.use_mesh(self.mesh):
                done = self._step_inner()
        except Exception as e:
            fl = self.flight
            if fl is not None and fl.is_oom(e):
                from repro.rlhf.trainer import live_device_bytes
                at = self.attributor
                fl.record_oom(
                    e, snapshot_fn=(at.snapshot if at is not None else None),
                    live_bytes=live_device_bytes(), source="serving")
            raise
        if self.flight is not None:
            from repro.rlhf.trainer import live_device_bytes
            live = live_device_bytes()
            self.flight.note("serve_step", step=self.steps,
                             live_bytes=live, queued=self.n_queued,
                             kv_reserved_bytes=self.kv_reserved_bytes())
            at = self.attributor
            self.flight.check(
                live, snapshot_fn=(at.snapshot if at is not None else None),
                source="serving")
        return done

    def _step_inner(self) -> List[Request]:
        t0_us = None
        if self.telemetry is not None:
            t0_us = self.telemetry.tracer.now_us()
            if not hasattr(self, "_cow_mark"):
                self._cow_mark = self._fork_mark = 0
        tokens_before = self._tokens_outstanding() \
            if self.telemetry is not None else 0
        self._admit()
        if self.backend == "paged":
            # spec decode writes up to k+1 tokens per slot this step
            self._grow_pages(self.spec_k + 1 if self.spec_decode else 1)
        # recompute after growth: preemption may have evicted a slot
        live_slots = [s for s, r in enumerate(self.active) if r is not None]
        if live_slots:
            if self.spec_decode:
                self._spec_decode_step(live_slots)
            else:
                self._vanilla_decode(live_slots)
        self.steps += 1
        done = self._retire()
        if self.telemetry is not None:
            n_tokens = (self._tokens_outstanding()
                        + sum(len(r.out_tokens) for r in done)
                        - tokens_before)
            self._emit_step(t0_us, n_tokens, len(done))
        return done

    def _tokens_outstanding(self) -> int:
        """Generated tokens held by not-yet-retired requests (active or
        queued — preemption re-queues with tokens kept, so the per-step
        delta against this sum counts each token exactly once)."""
        return (sum(len(r.out_tokens) for r in self.active if r is not None)
                + sum(len(r.out_tokens) for r in self.queue))

    def run_until_drained(self, max_steps: int = 10_000) -> List[Request]:
        finished = []
        for _ in range(max_steps):
            finished.extend(self.step())
            if not self.n_queued and all(r is None for r in self.active):
                break
        return finished

    # -- weight updates ------------------------------------------------------
    def update_params(self, params, *,
                      weight_version: Optional[int] = None) -> None:
        """Swap serving weights (an RLHF iteration just updated the
        policy). With the prefix cache on this *must* be the entry point:
        the pool's weight version is bumped and every cached prefix is
        invalidated, so KV produced under old weights is never matched
        again. In-flight sequences are unaffected — callers swap weights
        between rollouts, when nothing is active."""
        self.params = params
        if self.backend == "paged" and self.prefix_cache:
            self.pm.set_weight_version(
                self.pm.weight_version + 1 if weight_version is None
                else weight_version)

    # -- introspection -------------------------------------------------------
    def prefix_hit_rate(self) -> float:
        """Cumulative fraction of admitted prompt tokens served from the
        prefix cache (0.0 before any admission)."""
        if not self._prefix_tokens_total:
            return 0.0
        return self._prefix_tokens_hit / self._prefix_tokens_total
    def kv_reserved_bytes(self) -> int:
        """Bytes of KV/state the backend currently reserves. Dense reserves
        the whole [B, capacity] cache up front (measured from the actual
        cache arrays, so Mamba/MLA states are counted correctly); paged
        reserves live pages."""
        if self.backend == "paged":
            return self.pm.reserved_bytes()
        return sum(x.size * x.dtype.itemsize
                   for x in jax.tree.leaves(self.caches["segments"]))
