from repro.serving.buckets import BucketLadder, CompileCache
from repro.serving.scheduler import ContinuousBatcher, Request

__all__ = ["BucketLadder", "CompileCache", "ContinuousBatcher", "Request"]
