from repro.serving.scheduler import ContinuousBatcher, Request

__all__ = ["ContinuousBatcher", "Request"]
