"""Compile-bucket ladder + compile-cache accounting for the decode path.

JAX recompiles a jitted function silently for every new input shape, so a
serving loop fed ragged traffic (arbitrary prompt lengths, varying live
slot counts) pays a fresh XLA compile per distinct shape. The fix is the
capture-list idiom of GPU serving engines (aphrodite/vLLM pre-capture
graphs for ``_BATCH_SIZES_TO_CAPTURE``), translated to JAX: pick every
dynamic extent from a small sorted *bucket ladder*, pad the inputs up to
the bucket, and mask the padding (right-padded prompts via per-row
``lengths``; idle slots via ``position = -1``). The compiled-program set is
then bounded by the ladder, and an explicit warmup pass compiles every
bucket before traffic arrives.

XLA's own compile cache is invisible from Python, so :class:`CompileCache`
tracks the key set *we* present to jit — ``(kind, backend, bucket...)`` —
and counts hits/misses; a miss after warmup is a ``recompile`` (a shape
escaped the ladder) and shows up in traces and the metrics registry.
"""
from __future__ import annotations

from typing import Dict, Iterable, Optional, Sequence, Tuple

Key = Tuple  # (kind, backend, *static extents)

# aphrodite's _BATCH_SIZES_TO_CAPTURE idiom: dense low end, then powers of
# two — covers both live-slot counts and (scaled up) prompt lengths
DEFAULT_CAPTURE = (1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024, 2048)


class BucketLadder:
    """Sorted capture list; ``fit(n)`` returns the smallest bucket >= n.

    Values above the top bucket fall through to their exact size — the
    call still works, it just compiles its own program (and the compile
    cache reports it as a post-warmup miss, i.e. a recompile)."""

    def __init__(self, buckets: Iterable[int]):
        self.buckets = tuple(sorted({int(b) for b in buckets if int(b) > 0}))
        assert self.buckets, "empty bucket ladder"

    def fit(self, n: int) -> int:
        for b in self.buckets:
            if n <= b:
                return b
        return n

    def up_to(self, n: int) -> Tuple[int, ...]:
        """The ladder rungs <= n plus, when n overflows every rung, n
        itself — the shapes a warmup pass should capture for extent n."""
        rungs = tuple(b for b in self.buckets if b <= n)
        if not rungs or rungs[-1] < n:
            rungs += (self.fit(n),)
        return rungs

    @classmethod
    def default(cls, cap: Optional[int] = None) -> "BucketLadder":
        buckets: Sequence[int] = DEFAULT_CAPTURE
        if cap is not None:
            buckets = [b for b in DEFAULT_CAPTURE if b <= cap] or [cap]
            if buckets[-1] < cap:
                buckets.append(cap)
        return cls(buckets)


class CompileCache:
    """Shadow of the jit program cache, keyed on the static extents we
    control. ``lookup(key)`` returns True on a hit; the first sighting of a
    key is a miss (XLA compiled a new program for it). Misses recorded
    after ``finish_warmup()`` additionally count as recompiles — the
    metric a correctly-sized ladder drives to zero."""

    def __init__(self):
        self._keys: set = set()
        self.hits = 0
        self.misses = 0
        self.recompiles = 0
        self.warmed = False

    def warm(self, key: Key) -> None:
        """Register a key during warmup capture (not a hit, not a miss)."""
        self._keys.add(key)

    def finish_warmup(self) -> None:
        self.warmed = True

    def lookup(self, key: Key) -> bool:
        if key in self._keys:
            self.hits += 1
            return True
        self._keys.add(key)
        self.misses += 1
        if self.warmed:
            self.recompiles += 1
        return False

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 1.0

    def stats(self) -> Dict[str, float]:
        return {"hits": self.hits, "misses": self.misses,
                "recompiles": self.recompiles, "hit_rate": self.hit_rate,
                "keys": len(self._keys)}
