"""Adafactor (factored second moment, no first moment) — the memory-lean
optimizer used for the 671B config. For rank>=2 leaves the second moment is
stored as a (row, col) outer-product factorization over the last two dims;
rank<2 (or tiny) leaves keep a full second moment."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def _factored(p) -> bool:
    return p.ndim >= 2 and p.shape[-1] >= 128 and p.shape[-2] >= 128


@dataclass(frozen=True)
class Adafactor:
    decay: float = 0.8
    eps: float = 1e-30
    clip_threshold: float = 1.0
    weight_decay: float = 0.0

    def init(self, params):
        def per_leaf(p):
            if _factored(p):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {
            "v": jax.tree.map(per_leaf, params),
            "count": jnp.zeros((), jnp.int32),
        }

    def init_specs(self, param_specs, params=None):
        """Moment specs are REPLICATED, matching :meth:`update_pspecs`:
        the adafactor update reduces across elements (factored means, the
        update-RMS clip), so under ZeRO the whole update runs on
        replicated operands — sharded moments would feed those reductions
        a partial-sum/all-reduce order that differs from one device by a
        ulp. The replicated residency is noise: factored moments are
        O(rows+cols), and the only full-size ``v`` moments belong to
        small (<128-dim) leaves."""
        def per_leaf(spec, p):
            if _factored(p):
                return {"vr": P(*([None] * (p.ndim - 1))),
                        "vc": P(*([None] * (p.ndim - 1)))}
            return {"v": P(*([None] * p.ndim))}
        specs = jax.tree.map(per_leaf, param_specs, params,
                             is_leaf=lambda x: isinstance(x, P))
        return {"v": specs, "count": P()}

    def update_pspecs(self, param_specs, params=None):
        """Param-shaped layout for the ZeRO update program: fully
        replicated. ``steps._run_sharded_update`` eagerly gathers the
        (DP-identical) grads and params onto it — a bit-exact all-gather
        — runs :meth:`update` with every reduction in single-device
        order, and re-slices the new params onto the persistent ZeRO
        layout afterwards. This is what makes adafactor bit-equal to
        ndp=1 under every ZeRO stage (DESIGN.md §3.3); elementwise
        optimizers (adamw) keep the sharded update layout instead."""
        return jax.tree.map(lambda s, p: P(*([None] * p.ndim)),
                            param_specs, params,
                            is_leaf=lambda x: isinstance(x, P))

    def update(self, grads, state, params, lr):
        count = state["count"] + 1
        c = count.astype(jnp.float32)
        beta = 1.0 - c ** (-self.decay)

        def upd(g, v, p):
            g32 = g.astype(jnp.float32)
            g2 = jnp.square(g32) + self.eps
            if "vr" in v:
                vr = beta * v["vr"] + (1 - beta) * g2.mean(-1)
                vc = beta * v["vc"] + (1 - beta) * g2.mean(-2)
                denom = (vr[..., None] * vc[..., None, :]
                         / jnp.maximum(vr.mean(-1)[..., None, None], self.eps))
                u = g32 * jax.lax.rsqrt(denom + self.eps)
                new_v = {"vr": vr, "vc": vc}
            else:
                vv = beta * v["v"] + (1 - beta) * g2
                u = g32 * jax.lax.rsqrt(vv + self.eps)
                new_v = {"v": vv}
            rms_u = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-12)
            u = u / jnp.maximum(1.0, rms_u / self.clip_threshold)
            new_p = (p.astype(jnp.float32)
                     - lr * (u + self.weight_decay * p.astype(jnp.float32)))
            return new_p.astype(p.dtype), new_v

        out = jax.tree.map(upd, grads, state["v"], params,
                           is_leaf=lambda x: isinstance(x, dict) and
                           ("v" in x or "vr" in x))
        leaves, treedef = jax.tree.flatten(out, is_leaf=lambda x: isinstance(x, tuple))
        new_p = jax.tree.unflatten(treedef, [l[0] for l in leaves])
        new_v = jax.tree.unflatten(treedef, [l[1] for l in leaves])
        return new_p, {"v": new_v, "count": count}

    def state_bytes_per_param(self) -> int:
        return 0  # factored: O(rows+cols), negligible vs params
