from repro.optim.adamw import AdamW
from repro.optim.adafactor import Adafactor
from repro.optim.schedule import warmup_cosine
from repro.optim.clip import clip_by_global_norm, global_norm


def make_optimizer(name: str, **kw):
    if name == "adamw":
        return AdamW(moment_dtype="float32", **kw)
    if name == "adamw_bf16":
        return AdamW(moment_dtype="bfloat16", **kw)
    if name == "adafactor":
        return Adafactor(**kw)
    raise ValueError(name)


__all__ = ["AdamW", "Adafactor", "warmup_cosine", "clip_by_global_norm",
           "global_norm", "make_optimizer"]
