"""AdamW with configurable moment dtype (fp32, or bf16 for the >=100B
configs — see DESIGN.md §6). Pure pytree functions; shard specs for the
optimizer state are derived from the parameter specs (ZeRO: the caller
re-spec's them onto the data axis)."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamW:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    moment_dtype: str = "float32"

    def init(self, params):
        dt = jnp.dtype(self.moment_dtype)
        zeros = lambda p: jnp.zeros(p.shape, dt)
        return {
            "mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32),
        }

    def init_specs(self, param_specs, params=None):
        """Optimizer-state PartitionSpecs mirroring the parameter specs."""
        from jax.sharding import PartitionSpec as P
        return {
            "mu": param_specs,
            "nu": param_specs,
            "count": P(),
        }

    def update(self, grads, state, params, lr):
        count = state["count"] + 1
        b1, b2 = self.b1, self.b2
        c = count.astype(jnp.float32)
        bc1 = 1.0 - b1 ** c
        bc2 = 1.0 - b2 ** c
        dt = jnp.dtype(self.moment_dtype)

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
            v32 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
            step = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + self.eps)
            step = step + self.weight_decay * p.astype(jnp.float32)
            new_p = p.astype(jnp.float32) - lr * step
            return new_p.astype(p.dtype), m32.astype(dt), v32.astype(dt)

        out = jax.tree.map(upd, grads, state["mu"], state["nu"], params)
        leaves, treedef = jax.tree.flatten(out, is_leaf=lambda x: isinstance(x, tuple))
        new_p = jax.tree.unflatten(treedef, [l[0] for l in leaves])
        new_m = jax.tree.unflatten(treedef, [l[1] for l in leaves])
        new_v = jax.tree.unflatten(treedef, [l[2] for l in leaves])
        return new_p, {"mu": new_m, "nu": new_v, "count": count}

    def state_bytes_per_param(self) -> int:
        return 2 * jnp.dtype(self.moment_dtype).itemsize
