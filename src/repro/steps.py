"""Step functions — the units the launcher jits / lowers, and the phases of
the RLHF pipeline (DESIGN.md §5):

  * ``train_step``    — PPO actor update (clipped ratio vs old_logp, KL vs
                        ref_logp) + optional MTP CE + MoE aux loss.
  * ``critic_step``   — clipped value-function regression.
  * ``lm_step``       — plain CE (SFT / reward-model pretext, examples).
  * ``prefill_step``  — rollout prompt processing, builds decode caches.
  * ``decode_step``   — one rollout token (full or sliding-window).

``input_specs`` produces ShapeDtypeStruct stand-ins for every (arch x input
shape) pair — the dry-run lowers against these, no allocation.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import Model
from repro.optim import clip_by_global_norm, make_optimizer
from repro.sharding import ctx


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------
def _full_seq_logp(logits, targets):
    """Per-position log-prob of ``targets`` [B, T] under logits [B, T, V].
    Full-length (no slicing before the reduction) so the seq dim keeps its
    sharding; never materializes fp32 [B,T,V] — the fp32 exp fuses into the
    reduce. This keeps the training-phase memory roofline honest."""
    tgt = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    mx = jax.lax.stop_gradient(logits.max(-1))
    lse = mx.astype(jnp.float32) + jnp.log(jnp.sum(
        jnp.exp(logits.astype(jnp.float32) - mx[..., None].astype(jnp.float32)),
        axis=-1))
    return tgt.astype(jnp.float32) - lse                   # [B, T]


def _action_logp(logits, tokens, prefix: int):
    """logits [B, P+S, V]; tokens [B, S]. Returns per-action log-probs
    aligned so out[:, t] scores tokens[:, t] (t >= 1); out[:, 0] = 0."""
    B, S = tokens.shape
    T = logits.shape[1]
    # full-length target map: position j scores tokens[:, j - prefix + 1]
    tgt_full = jnp.zeros((B, T), tokens.dtype)
    tgt_full = jax.lax.dynamic_update_slice(
        tgt_full, tokens[:, 1:], (0, prefix))
    logp_full = _full_seq_logp(logits, tgt_full)           # [B, T]
    act = jax.lax.dynamic_slice(logp_full, (0, prefix), (B, S - 1))
    return jnp.pad(act, ((0, 0), (1, 0)))                  # [B, S]


def ppo_actor_loss(logits, batch, *, prefix: int = 0, clip_eps: float = 0.2,
                   kl_coef: float = 0.1, entropy_coef: float = 0.0):
    tokens = batch["tokens"]
    mask = batch["loss_mask"].astype(jnp.float32)
    mask = mask.at[:, 0].set(0.0)
    denom = jnp.maximum(mask.sum(), 1.0)
    logp = _action_logp(logits, tokens, prefix)
    ratio = jnp.exp(logp - batch["old_logp"])
    adv = batch["advantages"]
    unclipped = ratio * adv
    clipped = jnp.clip(ratio, 1 - clip_eps, 1 + clip_eps) * adv
    ppo = -jnp.sum(jnp.minimum(unclipped, clipped) * mask) / denom
    # k3 KL estimator vs the frozen reference policy
    log_r = batch["ref_logp"] - logp
    kl = jnp.sum((jnp.exp(log_r) - 1.0 - log_r) * mask) / denom
    loss = ppo + kl_coef * kl
    metrics = {"ppo_loss": ppo, "kl": kl,
               "clip_frac": jnp.sum((jnp.abs(ratio - 1) > clip_eps) * mask) / denom}
    return loss, metrics


def critic_loss(values, batch, *, clip_eps: float = 0.2):
    mask = batch["loss_mask"].astype(jnp.float32)
    denom = jnp.maximum(mask.sum(), 1.0)
    returns = batch["returns"]
    old_v = batch.get("old_values", returns)
    v_clip = old_v + jnp.clip(values - old_v, -clip_eps, clip_eps)
    l = jnp.maximum(jnp.square(values - returns), jnp.square(v_clip - returns))
    loss = 0.5 * jnp.sum(l * mask) / denom
    return loss, {"vf_loss": loss}


def mtp_loss(logits, tokens, mask, *, offset: int = 2):
    """MTP CE: logits[:, i] scores tokens[:, i+offset] (full-length logits,
    the last ``offset`` positions are padding). Depth-d logits of the
    chained head use ``offset = d + 1``; the default 2 is depth 1."""
    S = tokens.shape[1]
    tgt_full = jnp.pad(tokens[:, offset:], ((0, 0), (0, offset)))
    nll = -_full_seq_logp(logits, tgt_full)[:, :S - offset]
    m = mask[:, offset:].astype(jnp.float32)
    return jnp.sum(nll * m) / jnp.maximum(m.sum(), 1.0)


def mtp_chain_loss(model, params, h, batch):
    """Mean CE over the depth-k MTP chain (depth 1 reproduces the old
    single-module loss bit-for-bit). ``params`` may be a base tree (hydra)
    — the chain always runs adapter-free, like the trunk aux loss."""
    lgs = model.mtp_chain_logits(params, h, batch["tokens"])
    losses = [mtp_loss(lg, batch["tokens"], batch["loss_mask"], offset=d + 1)
              for d, lg in enumerate(lgs, start=1)]
    total = losses[0]
    for extra in losses[1:]:
        total = total + extra
    return total / len(losses)


def lm_loss(logits, tokens, mask, *, prefix: int = 0):
    nll = -_action_logp(logits, tokens, prefix)[:, 1:]
    m = mask[:, 1:].astype(jnp.float32)
    return jnp.sum(nll * m) / jnp.maximum(m.sum(), 1.0)


# ---------------------------------------------------------------------------
# Step builders
# ---------------------------------------------------------------------------
def _prefix_len(cfg: ModelConfig) -> int:
    return cfg.num_prefix_embeddings if cfg.input_mode == "embeddings" else 0


def _accumulated_grads(loss_fn, params, batch, N: int, acc_dtype):
    """``value_and_grad(loss_fn)(params, batch)`` with N-way microbatch
    gradient accumulation under ``lax.scan`` (N == 1 is the plain call).
    ``loss_fn`` has signature ``(params, batch) -> (loss, metrics)``.
    Returns ``((loss, metrics), grads)`` averaged over microbatches."""
    if N == 1:
        return jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
    mbs = jax.tree.map(
        lambda x: x.reshape((N, x.shape[0] // N) + x.shape[1:]), batch)

    def body(carry, mb):
        gacc, lacc, macc = carry
        (l, met), g = jax.value_and_grad(
            loss_fn, has_aux=True)(params, mb)
        gacc = jax.tree.map(
            lambda a, b: a + b.astype(acc_dtype), gacc, g)
        macc = jax.tree.map(lambda a, b: a + b, macc, met)
        return (gacc, lacc + l, macc), None

    g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dtype), params)
    m0 = jax.eval_shape(lambda p, mb: loss_fn(p, mb)[1], params,
                        jax.tree.map(lambda x: x[0], mbs))
    m0 = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), m0)
    (grads, loss, metrics), _ = jax.lax.scan(
        body, (g0, jnp.zeros((), jnp.float32), m0), mbs)
    return ((loss / N, jax.tree.map(lambda m: m / N, metrics)),
            jax.tree.map(lambda g: g / N, grads))


def _trace_mesh(*shards):
    """Ambient mesh the sharded step programs trace under. TP needs the
    in-jit activation hints (``ctx.constrain`` "model" entries in the model
    forward) resolved against the real mesh, so any TP plan activates it;
    pure-DP plans return None — the historical mesh-free trace — so the
    ZeRO bit-identity contract (DESIGN.md §3) sees an unchanged program."""
    for shard in shards:
        if shard is not None and getattr(shard.strat, "ntp", 1) > 1:
            return shard.mesh
    return None


def _make_sharded_update(optimizer, shard, lr):
    """Update half of a ZeRO step: a jit whose operands (moments, grads,
    params) all arrive eagerly pre-placed on the SAME param-shaped update
    layout (``TreePlan.update_specs``) — uniform sharding keeps XLA's
    elementwise fusion identical to the unsharded program, which mixed
    layouts do not (per-operand reshards change FMA contraction by a ulp).
    The program's outputs STAY on the update layout (an in-graph gather
    back to replicated fuses into the elementwise math and perturbs it);
    ``_run_sharded_update`` re-places new params onto the persistent ZeRO
    layout eagerly afterwards — an exact-element all-gather below stage 3,
    a no-op at stage 3."""

    def apply_update(opt, step, grads, p_u):
        new_params, new_opt = optimizer.update(grads, opt, p_u, lr)
        new_params = shard.constrain_update(new_params)
        new_opt = shard.constrain_opt(new_opt)
        return new_params, new_opt, step + 1

    # donate: moments (rewritten), grads (consumed), and the update-layout
    # params (at ZeRO-3 the state buffers themselves — true in-place
    # update; below, the transient 1/ndp slice copy)
    return jax.jit(apply_update, donate_argnums=(0, 2, 3))


def _run_sharded_update(jit_update, shard, state, grads):
    grads = shard.place_grads(grads)
    p_u = shard.place_update_params(state["params"])
    new_params, new_opt, step = jit_update(state["opt"], state["step"],
                                           grads, p_u)
    return {"params": shard.place_params(new_params), "opt": new_opt,
            "step": step}


def make_train_step(model: Model, cfg: ModelConfig, *, lr: float = 3e-5,
                    kind: str = "ppo", kl_coef: float = 0.1,
                    max_grad_norm: float = 1.0, shard=None):
    """kind: ppo | critic | lm.

    ``shard`` (a ``sharding.TreePlan``) makes the step ZeRO-aware, split
    into two programs so the ZeRO layout can never perturb the arithmetic
    (DESIGN.md §3):

      1. a *grad* jit — params gathered to the DP-stripped compute specs
         at entry (the per-step all-gather of ZeRO-3; its transpose pins
         the parameter cotangent replicated, so no sharding pressure
         reaches the forward/backward matmuls), loss + clipped grads
         computed exactly as on one device;
      2. an eager ``device_put`` of the DP-identical grads (and, below
         stage 3, a transient slice of the params) onto the uniform
         update layout — bit-exact by construction;
      3. an *update* jit — elementwise optimizer math over uniformly
         sharded operands, outputs staying on that layout; new params are
         re-placed onto the persistent ZeRO shardings eagerly afterwards.

    Every stage therefore reproduces the unsharded step bit-for-bit while
    persistent params/opt live at ~1/ndp per device. (Adafactor reduces
    across elements inside its update; it declares a fully-replicated
    update layout via ``Adafactor.update_pspecs`` so those reductions run
    in single-device order — bit-equal too, at the cost of a transient
    replicated update.)"""
    optimizer = make_optimizer(cfg.optimizer)
    prefix = _prefix_len(cfg)
    # per-layer ZeRO-3 gather (gather_mode="layer"): the scan body
    # constrains one sliced layer period at a time (DESIGN.md §3.7)
    lspecs = getattr(shard, "layer_specs", None)

    def loss_fn(params, batch):
        if kind == "critic":
            values = model.forward_value(params, batch, layer_specs=lspecs)
            S = batch["tokens"].shape[1]
            values = values[:, prefix:prefix + S]
            return critic_loss(values, batch)
        logits, aux, h = model.forward(params, batch, layer_specs=lspecs)
        if kind == "lm":
            loss = lm_loss(logits, batch["tokens"], batch["loss_mask"],
                           prefix=prefix)
            metrics = {"lm_loss": loss}
        else:
            loss, metrics = ppo_actor_loss(logits, batch, prefix=prefix,
                                           kl_coef=kl_coef)
        if cfg.mtp_depth and kind != "critic":
            mtp = mtp_chain_loss(model, params, h, batch)
            loss = loss + 0.1 * mtp
            metrics["mtp_loss"] = mtp
        return loss + aux, metrics

    N = max(1, cfg.microbatches)
    # grad-accumulation dtype: bf16 for the memory-lean >=100B configs
    acc_dtype = jnp.float32 if cfg.optimizer == "adamw" else jnp.bfloat16

    def grads_and_metrics(state, batch):
        params = state["params"] if shard is None \
            else shard.gather(state["params"])
        (loss, metrics), grads = _accumulated_grads(
            loss_fn, params, batch, N, acc_dtype)
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        return grads, dict(metrics, loss=loss, grad_norm=gnorm)

    if shard is None:
        def train_step(state, batch):
            grads, metrics = grads_and_metrics(state, batch)
            new_params, new_opt = optimizer.update(grads, state["opt"],
                                                   state["params"], lr)
            return {"params": new_params, "opt": new_opt,
                    "step": state["step"] + 1}, metrics

        train_step.optimizer = optimizer
        return train_step

    jit_grads = jax.jit(grads_and_metrics)
    jit_update = _make_sharded_update(optimizer, shard, lr)
    mesh = _trace_mesh(shard)

    def train_step(state, batch):
        with ctx.use_mesh(mesh):
            grads, metrics = jit_grads(state, batch)
            new_state = _run_sharded_update(jit_update, shard, state, grads)
        return new_state, metrics

    train_step.optimizer = optimizer
    train_step.prejitted = True     # callers must NOT wrap in jax.jit
    train_step.jit_grads = jit_grads    # exposed so benchmarks can read the
    # compiled program's transient-peak stats (memory_analysis)
    return train_step


def init_train_state(model: Model, cfg: ModelConfig, key, optimizer):
    params = model.init(key)
    return {"params": params, "opt": optimizer.init(params),
            "step": jnp.zeros((), jnp.int32)}


def make_lora_train_step(model: Model, cfg: ModelConfig, *, lr: float = 3e-5,
                         kind: str = "ppo", kl_coef: float = 0.1,
                         max_grad_norm: float = 1.0, shard=None,
                         base_shard=None):
    """LoRA-aware twin of :func:`make_train_step` for the hydra engine.

    The step signature is ``(state, base_params, batch)``: gradients and the
    optimizer state cover ONLY the adapter leaves in ``state["params"]`` —
    the frozen trunk rides along as a non-donated, non-differentiated input,
    so its bytes are shared across every role's step. Microbatch gradient
    accumulation and the MTP auxiliary loss match :func:`make_train_step`
    (the MTP head stays frozen in the trunk; its loss still trains the
    adapter through the hidden states). kind: ppo | critic | lm.

    ``shard`` (the adapter's ``sharding.TreePlan``) and ``base_shard``
    (the frozen trunk's) make the step ZeRO-aware with the same
    gather-compute / slice-update contract as :func:`make_train_step`: the
    ZeRO-3 trunk is gathered for the forward, adapter grads are clipped
    replicated then sliced onto the adapter optimizer layout.
    """
    optimizer = make_optimizer(cfg.optimizer)
    prefix = _prefix_len(cfg)
    # per-layer ZeRO-3 gather of the frozen trunk inside the scan body
    # (the adapter itself always gathers whole — it is paper-small)
    blspecs = getattr(base_shard, "layer_specs", None)

    def loss_fn(adapter, base_params, batch):
        if kind == "critic":
            values = model.forward_value(base_params, batch, adapter=adapter,
                                         layer_specs=blspecs)
            S = batch["tokens"].shape[1]
            values = values[:, prefix:prefix + S]
            return critic_loss(values, batch)
        logits, aux, h = model.forward(base_params, batch, adapter=adapter,
                                       layer_specs=blspecs)
        if kind == "lm":
            loss = lm_loss(logits, batch["tokens"], batch["loss_mask"],
                           prefix=prefix)
            metrics = {"lm_loss": loss}
        else:
            loss, metrics = ppo_actor_loss(logits, batch, prefix=prefix,
                                           kl_coef=kl_coef)
        if cfg.mtp_depth and kind != "critic":
            mtp = mtp_chain_loss(model, base_params, h, batch)
            loss = loss + 0.1 * mtp
            metrics["mtp_loss"] = mtp
        return loss + aux, metrics

    N = max(1, cfg.microbatches)
    acc_dtype = jnp.float32 if cfg.optimizer == "adamw" else jnp.bfloat16

    def grads_and_metrics(state, base_params, batch):
        if base_shard is not None:
            base_params = base_shard.gather(base_params)
        adapter = state["params"] if shard is None \
            else shard.gather(state["params"])
        (loss, metrics), grads = _accumulated_grads(
            lambda ad, mb: loss_fn(ad, base_params, mb),
            adapter, batch, N, acc_dtype)
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        return grads, dict(metrics, loss=loss, grad_norm=gnorm)

    if shard is None and base_shard is None:
        def train_step(state, base_params, batch):
            grads, metrics = grads_and_metrics(state, base_params, batch)
            new_params, new_opt = optimizer.update(grads, state["opt"],
                                                   state["params"], lr)
            return {"params": new_params, "opt": new_opt,
                    "step": state["step"] + 1}, metrics

        train_step.optimizer = optimizer
        return train_step

    assert shard is not None, "base_shard without an adapter plan"
    jit_grads = jax.jit(grads_and_metrics)
    jit_update = _make_sharded_update(optimizer, shard, lr)
    mesh = _trace_mesh(base_shard, shard)

    def train_step(state, base_params, batch):
        with ctx.use_mesh(mesh):
            grads, metrics = jit_grads(state, base_params, batch)
            new_state = _run_sharded_update(jit_update, shard, state, grads)
        return new_state, metrics

    train_step.optimizer = optimizer
    train_step.prejitted = True     # callers must NOT wrap in jax.jit
    train_step.jit_grads = jit_grads
    return train_step


def init_lora_train_state(adapter, optimizer):
    """Train state whose params (and hence optimizer moments) are only the
    adapter tree — the trainable_fraction-scaled footprint of the paper's
    LoRA rows, realized."""
    return {"params": adapter, "opt": optimizer.init(adapter),
            "step": jnp.zeros((), jnp.int32)}


def make_prefill_step(model: Model, cfg: ModelConfig, *, capacity: int,
                      window: int = 0):
    def prefill_step(params, batch):
        return model.prefill(params, batch, capacity, window=window)
    return prefill_step


def make_decode_step(model: Model, cfg: ModelConfig, *, window: int = 0):
    def decode_step(params, caches, token, position):
        return model.decode_step(params, caches, token, position,
                                 window=window)
    return decode_step


# ---------------------------------------------------------------------------
# ShapeDtypeStruct stand-ins for the dry-run (no allocation)
# ---------------------------------------------------------------------------
def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def decode_window(cfg: ModelConfig, shape: ShapeConfig) -> int:
    """Sub-quadratic path: long_500k uses a sliding window for attention
    layers (SSM layers are O(1) anyway). 0 = full attention."""
    if shape.kind == "long_decode":
        return cfg.long_context_window
    return cfg.sliding_window


def cache_capacity(cfg: ModelConfig, shape: ShapeConfig) -> int:
    w = decode_window(cfg, shape)
    return min(shape.seq_len, w) if w else shape.seq_len


def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                dtype: str = "bfloat16") -> Dict[str, Any]:
    """Batch ShapeDtypeStructs for (arch, shape). For decode kinds this is
    the (token, position) pair; caches are built separately (they are
    threaded state, not per-step host input)."""
    B, S = shape.global_batch, shape.seq_len
    P = _prefix_len(cfg)
    S_tok = S - P if cfg.input_mode == "embeddings" else S
    f32 = jnp.float32
    out: Dict[str, Any] = {}
    if shape.kind in ("train", "prefill"):
        out["tokens"] = sds((B, S_tok), jnp.int32)
        if cfg.input_mode == "embeddings":
            out["prefix_embeds"] = sds((B, P, cfg.d_model), dtype)
        if cfg.input_mode == "encdec":
            out["frame_embeds"] = sds((B, cfg.num_prefix_embeddings,
                                       cfg.d_model), dtype)
        if shape.kind == "train":
            for k in ("loss_mask", "advantages", "old_logp", "ref_logp",
                      "returns"):
                out[k] = sds((B, S_tok), f32)
    else:  # decode kinds
        out["token"] = sds((B,), jnp.int32)
        out["position"] = sds((B,), jnp.int32)
    return out


def cache_specs(model: Model, cfg: ModelConfig, shape: ShapeConfig,
                dtype: str = "bfloat16"):
    """ShapeDtypeStructs of the decode caches for (arch, shape)."""
    cap = cache_capacity(cfg, shape)
    B = shape.global_batch
    segs = jax.eval_shape(
        lambda: model.init_cache(B, cap, jnp.dtype(dtype)))
    caches = {"segments": segs, "cross_kv": None}
    if cfg.input_mode == "encdec":
        Se = cfg.num_prefix_embeddings
        kvh, hd = cfg.num_kv_heads, cfg.resolved_head_dim()
        out = []
        for seg in model.segments:
            out.append(tuple(
                (sds((seg.n_groups, B, Se, kvh, hd), dtype),
                 sds((seg.n_groups, B, Se, kvh, hd), dtype))
                for _ in range(len(seg.kinds))))
        caches["cross_kv"] = out
    return caches
