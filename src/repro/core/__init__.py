# The paper's primary contribution: understanding + alleviating RLHF memory
# consumption. Allocator simulator (allocator.py), jaxpr liveness tracer
# (trace.py), RLHF phase plans (phases.py), memory-management strategies
# (strategies.py), empty_cache-policy profiler (profiler.py).
from repro.core.allocator import CachingAllocator
from repro.core.phases import (RLHF_PHASE_SEQUENCE, Phase, build_rlhf_phases,
                               phase_state_touches, runtime_state_touches)
from repro.core.profiler import POLICIES, RunResult, run_iteration
from repro.core.strategies import (MemoryStrategy, OFFLOAD_LEVELS,
                                   PAPER_STRATEGIES, lora_trainable_fraction,
                                   offload_managed_states, traced_strategy,
                                   traced_zero_scales)
from repro.core.trace import Trace, trace_function

__all__ = ["CachingAllocator", "Phase", "build_rlhf_phases",
           "RLHF_PHASE_SEQUENCE", "phase_state_touches",
           "runtime_state_touches", "POLICIES", "RunResult", "run_iteration",
           "MemoryStrategy", "OFFLOAD_LEVELS", "PAPER_STRATEGIES",
           "lora_trainable_fraction", "offload_managed_states", "Trace",
           "trace_function", "traced_strategy", "traced_zero_scales"]
