# The paper's primary contribution: understanding + alleviating RLHF memory
# consumption. Allocator simulator (allocator.py), jaxpr liveness tracer
# (trace.py), RLHF phase plans (phases.py), memory-management strategies
# (strategies.py), empty_cache-policy profiler (profiler.py).
from repro.core.allocator import CachingAllocator
from repro.core.phases import Phase, build_rlhf_phases
from repro.core.profiler import POLICIES, RunResult, run_iteration
from repro.core.strategies import (MemoryStrategy, PAPER_STRATEGIES,
                                   lora_trainable_fraction)
from repro.core.trace import Trace, trace_function

__all__ = ["CachingAllocator", "Phase", "build_rlhf_phases", "POLICIES",
           "RunResult", "run_iteration", "MemoryStrategy",
           "PAPER_STRATEGIES", "lora_trainable_fraction", "Trace",
           "trace_function"]
