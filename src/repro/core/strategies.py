"""Memory-management strategies (paper §2.2) and how they scale the traced
allocation events.

The paper's experiment grid is DP over 4 GPUs (no TP), LoRA dim 128. Each
strategy maps to per-tag size multipliers applied when a trace is replayed
through the allocator simulator:

  tag            None   ZeRO-1      ZeRO-2      ZeRO-3          offload
  param          1      1           1           1/ndp           -
  opt            1      1/ndp       1/ndp       1/ndp           0 (host)
  grad           1      1           1/ndp       1/ndp           -
  layer_slice    0      0           0           1 (gather temp) -
  temp/input     1      1           1           1               -

``layer_slice`` events are the per-layer parameter slices of the scan: with
ZeRO-3 they are real transient buffers (the per-layer all-gather of the
sharded weights — the varied-size churn the paper blames for fragmentation);
without ZeRO-3 the layer weights are views into persistent storage, so the
events vanish. Gradient checkpointing is not a multiplier — it swaps in the
remat="full" trace of the same model (the liveness change emerges from the
jaxpr, see core.trace).

LoRA scales grad/opt by the trainable fraction.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict


@dataclass(frozen=True)
class MemoryStrategy:
    name: str
    zero_stage: int = 0          # 0 = none
    cpu_offload: bool = False
    grad_ckpt: bool = False

    def scale(self, tag: str, *, ndp: int, trainable_fraction: float = 1.0,
              param_persistent: bool = True) -> float:
        z = self.zero_stage
        if tag == "param":
            return 1.0 / ndp if z >= 3 else 1.0
        if tag == "opt":
            if self.cpu_offload:
                return 0.0
            base = 1.0 / ndp if z >= 1 else 1.0
            return base * trainable_fraction
        if tag == "grad":
            base = 1.0 / ndp if z >= 2 else 1.0
            return base * trainable_fraction
        if tag == "layer_slice":
            return 1.0 if z >= 3 else 0.0
        if tag in ("input", "temp", "cache"):
            return 1.0
        return 1.0


PAPER_STRATEGIES = (
    MemoryStrategy("None"),
    MemoryStrategy("ZeRO-1", zero_stage=1),
    MemoryStrategy("ZeRO-2", zero_stage=2),
    MemoryStrategy("ZeRO-3", zero_stage=3),
    MemoryStrategy("ZeRO-3 + CPU Offloading", zero_stage=3, cpu_offload=True),
    MemoryStrategy("Gradient Checkpointing", grad_ckpt=True),
    MemoryStrategy("All Enabled", zero_stage=3, cpu_offload=True,
                   grad_ckpt=True),
)


def lora_trainable_fraction(n_params: int, cfg, rank: int = 128) -> float:
    """Approximate LoRA-r trainable fraction for a transformer config: every
    2D projection W[d_in, d_out] adds r*(d_in+d_out) trainable params."""
    if rank <= 0:
        return 1.0
    d, ff, L = cfg.d_model, max(cfg.d_ff, 1), cfg.num_layers
    hd = cfg.resolved_head_dim()
    per_layer = 0
    per_layer += rank * (d + cfg.num_heads * hd)          # wq
    per_layer += 2 * rank * (d + cfg.num_kv_heads * hd)   # wk, wv
    per_layer += rank * (cfg.num_heads * hd + d)          # wo
    n_mlp = 3 if cfg.mlp_gated else 2
    per_layer += n_mlp * rank * (d + ff)
    lora = per_layer * L
    return min(1.0, lora / max(n_params, 1))
