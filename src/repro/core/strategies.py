"""Memory-management strategies (paper §2.2) and how they scale the traced
allocation events.

The paper's experiment grid is DP over 4 GPUs (no TP), LoRA dim 128. Each
strategy maps to per-tag size multipliers applied when a trace is replayed
through the allocator simulator:

  tag            None   ZeRO-1      ZeRO-2      ZeRO-3          cpu_offload
  param          1      1           1           1/ndp           -
  opt            1      1/ndp       1/ndp       1/ndp           0 (host)
  grad           1      1           1/ndp       1/ndp           -
  layer_slice    0      0           0           1 (gather temp) -
  temp/input     1      1           1           1               -

``layer_slice`` events are the per-layer parameter slices of the scan: with
ZeRO-3 they are real transient buffers (the per-layer all-gather of the
sharded weights — the varied-size churn the paper blames for fragmentation);
without ZeRO-3 the layer weights are views into persistent storage, so the
events vanish. The runtime gather granularity
(``ShardingStrategy.gather_mode``) maps onto the same events: ``"layer"``
charges each slice at 1x (one layer period live per scan iteration —
the FSDP schedule the simulator has always assumed), ``"tree"`` charges it
at the scan length (every gathered layer concurrently live = the whole
replicated tree, what a whole-tree gather-before-scan realizes). The
traced entries carry the factor (``traced_zero_scales(gather_mode=...)``). Gradient checkpointing is not a multiplier — it swaps in the
remat="full" trace of the same model (the liveness change emerges from the
jaxpr, see core.trace).

Beyond the per-tag multipliers there is a *runtime offload* axis,
``MemoryStrategy.offload`` — the phase-aware HBM<->host swapping of
``repro.offload``, which the simulator models by parking/fetching whole
persistent buffer groups at phase boundaries (see
``profiler.run_iteration``) instead of scaling them:

  offload level   parked off-phase
  none            nothing (every tree HBM-resident for the whole iteration)
  optimizer       optimizer moments  (*_opt)
  roles           + per-role params/adapters (actor/critic/ref/reward)
  all             + the frozen base trunk while merged weights serve rollout
                    (hydra engine)

``cpu_offload`` stays the paper's DeepSpeed-style *static* placement (the
optimizer lives on host permanently, updates run there: scale 0); the
``offload`` axis is the dynamic schedule where state is HBM-resident
exactly during the phases that touch it.

LoRA scales grad/opt by the trainable fraction. The fraction is computed
EXACTLY, by building the real adapter tree of ``models.lora`` under
``jax.eval_shape`` (no allocation) and counting leaves — the analytic
per-projection formula it replaces drifted whenever the adapter-site rules
changed. ``MemoryStrategy.lora_rank`` threads the rank axis through the
strategy grid (the paper's grid fixes it at 128).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import lru_cache
from typing import Dict, Iterable, Optional, Set, Tuple

OFFLOAD_LEVELS = ("none", "optimizer", "roles", "all")

# role params/adapters swapped at level "roles"; the frozen trunk joins at
# "all" (its rollout-phase eviction is what the hydra merged copy enables)
_ROLE_STATES = ("actor_params", "critic_params", "ref_params",
                "reward_params")


def offload_managed_states(level: str, names: Iterable[str]) -> Set[str]:
    """Which persistent-state names the runtime offload level swaps.
    Shared by the allocator simulator and ``offload.OffloadPlan`` so the
    analytic and runtime schedules agree by construction."""
    if level not in OFFLOAD_LEVELS:
        raise ValueError(f"unknown offload level {level!r}; "
                         f"expected one of {OFFLOAD_LEVELS}")
    out: Set[str] = set()
    for n in names:
        if level == "none":
            break
        if n.endswith("_opt"):
            out.add(n)
        elif level in ("roles", "all") and n in _ROLE_STATES:
            out.add(n)
        elif level == "all" and n == "base_params":
            out.add(n)
    return out


@lru_cache(maxsize=256)
def _traced_lookup(traced: tuple) -> dict:
    """Tuple->dict view of a traced-scales tuple, cached — ``scale`` sits
    on the profiler's per-buffer hot path."""
    return dict(traced)


@dataclass(frozen=True)
class MemoryStrategy:
    name: str
    zero_stage: int = 0          # 0 = none
    cpu_offload: bool = False
    grad_ckpt: bool = False
    lora_rank: int = 128         # LoRA rank of the trainable-fraction axis
    offload: str = "none"        # runtime swap level (repro.offload)
    # ZeRO-3 all-gather granularity of the runtime being modelled
    # (rules.ShardingStrategy.gather_mode): "layer" = one layer period
    # transient, "tree" = whole-tree transient. Realized through the
    # traced "layer_slice" entry; the closed-form fallback stays at the
    # per-layer schedule.
    gather_mode: str = "layer"
    # TP degree of the runtime being modelled (rules.ShardingStrategy.ntp).
    # Only the traced entries realize it — the closed-form fallback stays
    # the paper's pure-DP 1/ndp model — so set it through
    # :func:`traced_strategy`, which rebuilds the spec trees on a
    # (data=ndp, model=ntp) SpecMesh.
    ntp: int = 1
    # traced per-device byte fractions from the *real* sharded spec trees
    # (built by :func:`traced_strategy` / :func:`traced_zero_scales`):
    # entries keyed "state:tag" (exact, per persistent group) with "tag"
    # aggregates as fallback. Empty = the closed-form 1/ndp model.
    traced: Tuple[Tuple[str, float], ...] = ()

    def scale(self, tag: str, *, ndp: int, trainable_fraction: float = 1.0,
              param_persistent: bool = True,
              state: Optional[str] = None) -> float:
        z = self.zero_stage
        if self.traced and tag in ("param", "opt", "grad"):
            if tag == "opt" and self.cpu_offload:
                return 0.0
            d = _traced_lookup(self.traced)
            v = d.get(f"{state}:{tag}") if state else None
            if v is None:
                v = d.get(tag)
            if v is not None:
                mult = trainable_fraction if tag in ("opt", "grad") else 1.0
                return v * mult
        if tag == "param":
            return 1.0 / ndp if z >= 3 else 1.0
        if tag == "opt":
            if self.cpu_offload:
                return 0.0
            base = 1.0 / ndp if z >= 1 else 1.0
            return base * trainable_fraction
        if tag == "grad":
            base = 1.0 / ndp if z >= 2 else 1.0
            return base * trainable_fraction
        if tag == "layer_slice":
            if z < 3:
                return 0.0
            if self.traced:
                v = _traced_lookup(self.traced).get("layer_slice")
                if v is not None:
                    return v
            return 1.0
        if tag in ("input", "temp", "cache"):
            return 1.0
        return 1.0


PAPER_STRATEGIES = (
    MemoryStrategy("None"),
    MemoryStrategy("ZeRO-1", zero_stage=1),
    MemoryStrategy("ZeRO-2", zero_stage=2),
    MemoryStrategy("ZeRO-3", zero_stage=3),
    MemoryStrategy("ZeRO-3 + CPU Offloading", zero_stage=3, cpu_offload=True),
    MemoryStrategy("Gradient Checkpointing", grad_ckpt=True),
    MemoryStrategy("All Enabled", zero_stage=3, cpu_offload=True,
                   grad_ckpt=True),
)


@lru_cache(maxsize=64)
def _exact_fraction(cfg, rank: int) -> float:
    import jax

    from repro.models import Model
    from repro.models.lora import trainable_fraction

    model = Model(cfg)
    base = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    adapter = jax.eval_shape(
        lambda: model.init_adapter(jax.random.PRNGKey(0), base, rank))
    return min(1.0, trainable_fraction(base, adapter))


def lora_trainable_fraction(cfg, rank: int = 128) -> float:
    """EXACT LoRA-r trainable fraction for a model config: the real adapter
    tree is built under ``jax.eval_shape`` (zero allocation) and its leaves
    counted against the base tree's. ``rank <= 0`` means full fine-tuning."""
    if rank <= 0:
        return 1.0
    return _exact_fraction(cfg, rank)


# ---------------------------------------------------------------------------
# Traced ndp axis: per-device fractions from the REAL sharded spec trees
# ---------------------------------------------------------------------------
def _tree_fraction(spec_tree, shape_tree, mesh) -> Tuple[float, float]:
    """(total_bytes, per_device_bytes) of a shape tree under its specs."""
    import jax
    import numpy as np
    from jax.sharding import PartitionSpec as P

    from repro.sharding.rules import spec_device_fraction
    flat_s = jax.tree_util.tree_leaves(
        spec_tree, is_leaf=lambda x: isinstance(x, P))
    flat_l = jax.tree_util.tree_leaves(shape_tree)
    tot = dev = 0.0
    for spec, leaf in zip(flat_s, flat_l):
        nb = float(np.prod(leaf.shape) *
                   jax.numpy.dtype(leaf.dtype).itemsize)
        tot += nb
        dev += nb * spec_device_fraction(spec, leaf, mesh)
    return tot, dev


@lru_cache(maxsize=64)
def traced_zero_scales(actor_cfg, critic_cfg=None, *, ndp: int,
                       zero_stage: int, engine: str = "separate",
                       lora_rank: int = 128,
                       gather_mode: str = "layer",
                       ntp: int = 1,
                       ) -> Tuple[Tuple[str, float], ...]:
    """Per-device byte fractions of every persistent RLHF state group,
    traced from the REAL sharded spec trees (``jax.eval_shape`` of the
    role trees under the mesh rules) instead of the closed-form ``1/ndp``.

    The returned tuple plugs into :attr:`MemoryStrategy.traced`: exact
    ``"<state>:<tag>"`` entries for every group of
    ``core.phases.build_rlhf_phases`` (so the simulator charges e.g. the
    hydra value heads at full size — they cannot shard — while the trunk
    shards to 1/ndp), plus byte-weighted ``"param"/"opt"/"grad"``
    aggregates as fallback for trace-level events. ``merged_rollout``
    carries the *compute-layout* fraction of the adapted subtree: merged
    generation runs from a DP-gathered copy by the runtime contract
    (DESIGN.md §3), but a gather only ever moves the DP dimension — TP
    entries survive it (DESIGN.md §9) — so the fraction is 1.0 at
    ``ntp=1`` and ~``1/ntp`` under tensor parallelism.

    ``ntp`` adds the tensor-parallel axis: the spec trees are rebuilt on
    a ``(data=ndp, model=ntp)`` mesh with the Megatron column/row rules
    of ``rules.param_pspecs``, so every fraction (params, optimizer,
    grads, the merged copy) reflects the composed ``dp x tp`` layout
    rather than an analytic ``1/(ndp*ntp)`` guess — value heads, biases
    and non-divisible dims stay replicated exactly as the runtime keeps
    them.

    ``gather_mode`` sets the ZeRO-3 transient term: each traced
    ``layer_slice`` event (one sliced layer period of the scan) is
    charged 1x under ``"layer"`` (per-layer FSDP gathers — one period
    live at a time) and at the actor's scan length under ``"tree"``
    (a whole-tree gather keeps every period live across the scan)."""
    import jax

    from repro.models import Model
    from repro.optim import make_optimizer
    from repro.sharding.rules import (ShardingStrategy, SpecMesh,
                                      adapter_pspecs, param_pspecs,
                                      zero_opt_pspecs)
    assert engine in ("separate", "hydra"), engine
    assert ntp >= 1, ntp
    # ntp=1 keeps the historical {"data"} mesh (and tensor_parallel off) so
    # the pure-DP traced entries — and everything cached against them — are
    # byte-for-byte what they were before the TP axis existed.
    axes = {"data": ndp, "model": ntp} if ntp > 1 else {"data": ndp}
    mesh = SpecMesh(axes)
    strat = ShardingStrategy(zero_stage=zero_stage, tensor_parallel=ntp > 1,
                             expert_parallel=False, ntp=ntp)
    key = jax.random.PRNGKey(0)
    actor = Model(actor_cfg)
    a_shapes = jax.eval_shape(actor.init, key)
    a_specs = param_pspecs(actor_cfg, mesh, strat, a_shapes)

    def opt_entry(pspecs, shapes, cfg):
        opt = make_optimizer(cfg.optimizer)
        o_shapes = jax.eval_shape(opt.init, shapes)
        o_specs = opt.init_specs(
            zero_opt_pspecs(pspecs, shapes, mesh, strat), shapes)
        return _tree_fraction(o_specs, o_shapes, mesh)

    groups: Dict[str, Tuple[str, Tuple[float, float]]] = {}
    if engine == "hydra":
        a_ad = jax.eval_shape(
            lambda k: actor.init_adapter(k, a_shapes, lora_rank), key)
        c_ad = jax.eval_shape(
            lambda k: actor.init_adapter(k, a_shapes, lora_rank,
                                         with_value=True), key)
        ad_specs = adapter_pspecs(mesh, strat, a_ad)
        cad_specs = adapter_pspecs(mesh, strat, c_ad)
        from repro.models.lora import adapted_subtree
        from repro.sharding.context import _strip_dp
        from jax.sharding import PartitionSpec as P
        merged = adapted_subtree(a_shapes, a_ad["lora"])
        # the merged rollout copy is DP-gathered but keeps its TP entries:
        # charge it at the compute layout (strip-DP of the base specs over
        # the adapted sites) — exactly (nb, nb) i.e. 1.0 when ntp == 1
        merged_specs = jax.tree.map(
            lambda s: _strip_dp(s, mesh),
            adapted_subtree(a_specs, a_ad["lora"]),
            is_leaf=lambda x: isinstance(x, P))
        groups = {
            "base_params": ("param", _tree_fraction(a_specs, a_shapes, mesh)),
            "actor_params": ("param", _tree_fraction(ad_specs, a_ad, mesh)),
            "critic_params": ("param", _tree_fraction(cad_specs, c_ad, mesh)),
            "reward_params": ("param", _tree_fraction(cad_specs, c_ad, mesh)),
            "actor_opt": ("opt", opt_entry(ad_specs, a_ad, actor_cfg)),
            "critic_opt": ("opt", opt_entry(cad_specs, c_ad, actor_cfg)),
            "merged_rollout": ("param",
                               _tree_fraction(merged_specs, merged, mesh)),
        }
        trainables = [("actor_params", ad_specs, a_ad, actor_cfg),
                      ("critic_params", cad_specs, c_ad, actor_cfg)]
    else:
        critic_cfg = critic_cfg or actor_cfg
        critic = Model(critic_cfg, with_value=True)
        c_shapes = jax.eval_shape(critic.init, key)
        c_specs = param_pspecs(critic_cfg, mesh, strat, c_shapes)
        groups = {
            "actor_params": ("param", _tree_fraction(a_specs, a_shapes, mesh)),
            "critic_params": ("param", _tree_fraction(c_specs, c_shapes, mesh)),
            "ref_params": ("param", _tree_fraction(a_specs, a_shapes, mesh)),
            "reward_params": ("param", _tree_fraction(c_specs, c_shapes, mesh)),
            "actor_opt": ("opt", opt_entry(a_specs, a_shapes, actor_cfg)),
            "critic_opt": ("opt", opt_entry(c_specs, c_shapes, critic_cfg)),
        }
        trainables = [("actor_params", a_specs, a_shapes, actor_cfg),
                      ("critic_params", c_specs, c_shapes, critic_cfg)]

    out = []
    agg: Dict[str, Tuple[float, float]] = {}
    for name, (tag, (tot, dev)) in groups.items():
        out.append((f"{name}:{tag}", dev / tot if tot else 1.0))
        t, d = agg.get(tag, (0.0, 0.0))
        agg[tag] = (t + tot, d + dev)
    for tag, (tot, dev) in agg.items():
        out.append((tag, dev / tot if tot else 1.0))
    # grads: ZeRO>=2 re-shards them onto the optimizer layout of the
    # trainable trees; below that they stay at the compute layout — fully
    # replicated in pure DP, TP-sharded (strip-DP of the param specs, i.e.
    # dW inherits W's model entries through the backward pass) under TP
    if zero_stage >= 2:
        gt = gd = 0.0
        for _, pspecs, shapes, _cfg in trainables:
            o_specs = zero_opt_pspecs(pspecs, shapes, mesh, strat)
            t, d = _tree_fraction(o_specs, shapes, mesh)
            gt, gd = gt + t, gd + d
        out.append(("grad", gd / gt if gt else 1.0))
    elif ntp > 1:
        from jax.sharding import PartitionSpec as P
        from repro.sharding.context import _strip_dp
        gt = gd = 0.0
        for _, pspecs, shapes, _cfg in trainables:
            comp = jax.tree.map(lambda s: _strip_dp(s, mesh), pspecs,
                                is_leaf=lambda x: isinstance(x, P))
            t, d = _tree_fraction(comp, shapes, mesh)
            gt, gd = gt + t, gd + d
        out.append(("grad", gd / gt if gt else 1.0))
    else:
        out.append(("grad", 1.0))
    # ZeRO-3 gather transient (see docstring): tree mode keeps every
    # gathered layer period live across the scan, so each per-slice event
    # scales by the number of scan iterations
    assert gather_mode in ("layer", "tree"), gather_mode
    n_slices = sum(seg.n_groups for seg in actor.segments)
    out.append(("layer_slice",
                1.0 if gather_mode == "layer" else float(n_slices)))
    return tuple(out)


def traced_strategy(base: MemoryStrategy, actor_cfg, critic_cfg=None, *,
                    ndp: int, engine: str = "separate",
                    lora_rank: Optional[int] = None) -> MemoryStrategy:
    """``base`` with its ndp (and, via ``base.ntp``, tp) axis traced from
    the real sharded trees."""
    return dataclasses.replace(
        base, traced=traced_zero_scales(
            actor_cfg, critic_cfg, ndp=ndp, zero_stage=base.zero_stage,
            engine=engine, gather_mode=base.gather_mode, ntp=base.ntp,
            lora_rank=base.lora_rank if lora_rank is None else lora_rank))
