"""Memory-management strategies (paper §2.2) and how they scale the traced
allocation events.

The paper's experiment grid is DP over 4 GPUs (no TP), LoRA dim 128. Each
strategy maps to per-tag size multipliers applied when a trace is replayed
through the allocator simulator:

  tag            None   ZeRO-1      ZeRO-2      ZeRO-3          cpu_offload
  param          1      1           1           1/ndp           -
  opt            1      1/ndp       1/ndp       1/ndp           0 (host)
  grad           1      1           1/ndp       1/ndp           -
  layer_slice    0      0           0           1 (gather temp) -
  temp/input     1      1           1           1               -

``layer_slice`` events are the per-layer parameter slices of the scan: with
ZeRO-3 they are real transient buffers (the per-layer all-gather of the
sharded weights — the varied-size churn the paper blames for fragmentation);
without ZeRO-3 the layer weights are views into persistent storage, so the
events vanish. Gradient checkpointing is not a multiplier — it swaps in the
remat="full" trace of the same model (the liveness change emerges from the
jaxpr, see core.trace).

Beyond the per-tag multipliers there is a *runtime offload* axis,
``MemoryStrategy.offload`` — the phase-aware HBM<->host swapping of
``repro.offload``, which the simulator models by parking/fetching whole
persistent buffer groups at phase boundaries (see
``profiler.run_iteration``) instead of scaling them:

  offload level   parked off-phase
  none            nothing (every tree HBM-resident for the whole iteration)
  optimizer       optimizer moments  (*_opt)
  roles           + per-role params/adapters (actor/critic/ref/reward)
  all             + the frozen base trunk while merged weights serve rollout
                    (hydra engine)

``cpu_offload`` stays the paper's DeepSpeed-style *static* placement (the
optimizer lives on host permanently, updates run there: scale 0); the
``offload`` axis is the dynamic schedule where state is HBM-resident
exactly during the phases that touch it.

LoRA scales grad/opt by the trainable fraction. The fraction is computed
EXACTLY, by building the real adapter tree of ``models.lora`` under
``jax.eval_shape`` (no allocation) and counting leaves — the analytic
per-projection formula it replaces drifted whenever the adapter-site rules
changed. ``MemoryStrategy.lora_rank`` threads the rank axis through the
strategy grid (the paper's grid fixes it at 128).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import lru_cache
from typing import Dict, Iterable, Set

OFFLOAD_LEVELS = ("none", "optimizer", "roles", "all")

# role params/adapters swapped at level "roles"; the frozen trunk joins at
# "all" (its rollout-phase eviction is what the hydra merged copy enables)
_ROLE_STATES = ("actor_params", "critic_params", "ref_params",
                "reward_params")


def offload_managed_states(level: str, names: Iterable[str]) -> Set[str]:
    """Which persistent-state names the runtime offload level swaps.
    Shared by the allocator simulator and ``offload.OffloadPlan`` so the
    analytic and runtime schedules agree by construction."""
    if level not in OFFLOAD_LEVELS:
        raise ValueError(f"unknown offload level {level!r}; "
                         f"expected one of {OFFLOAD_LEVELS}")
    out: Set[str] = set()
    for n in names:
        if level == "none":
            break
        if n.endswith("_opt"):
            out.add(n)
        elif level in ("roles", "all") and n in _ROLE_STATES:
            out.add(n)
        elif level == "all" and n == "base_params":
            out.add(n)
    return out


@dataclass(frozen=True)
class MemoryStrategy:
    name: str
    zero_stage: int = 0          # 0 = none
    cpu_offload: bool = False
    grad_ckpt: bool = False
    lora_rank: int = 128         # LoRA rank of the trainable-fraction axis
    offload: str = "none"        # runtime swap level (repro.offload)

    def scale(self, tag: str, *, ndp: int, trainable_fraction: float = 1.0,
              param_persistent: bool = True) -> float:
        z = self.zero_stage
        if tag == "param":
            return 1.0 / ndp if z >= 3 else 1.0
        if tag == "opt":
            if self.cpu_offload:
                return 0.0
            base = 1.0 / ndp if z >= 1 else 1.0
            return base * trainable_fraction
        if tag == "grad":
            base = 1.0 / ndp if z >= 2 else 1.0
            return base * trainable_fraction
        if tag == "layer_slice":
            return 1.0 if z >= 3 else 0.0
        if tag in ("input", "temp", "cache"):
            return 1.0
        return 1.0


PAPER_STRATEGIES = (
    MemoryStrategy("None"),
    MemoryStrategy("ZeRO-1", zero_stage=1),
    MemoryStrategy("ZeRO-2", zero_stage=2),
    MemoryStrategy("ZeRO-3", zero_stage=3),
    MemoryStrategy("ZeRO-3 + CPU Offloading", zero_stage=3, cpu_offload=True),
    MemoryStrategy("Gradient Checkpointing", grad_ckpt=True),
    MemoryStrategy("All Enabled", zero_stage=3, cpu_offload=True,
                   grad_ckpt=True),
)


@lru_cache(maxsize=64)
def _exact_fraction(cfg, rank: int) -> float:
    import jax

    from repro.models import Model
    from repro.models.lora import trainable_fraction

    model = Model(cfg)
    base = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    adapter = jax.eval_shape(
        lambda: model.init_adapter(jax.random.PRNGKey(0), base, rank))
    return min(1.0, trainable_fraction(base, adapter))


def lora_trainable_fraction(cfg, rank: int = 128) -> float:
    """EXACT LoRA-r trainable fraction for a model config: the real adapter
    tree is built under ``jax.eval_shape`` (zero allocation) and its leaves
    counted against the base tree's. ``rank <= 0`` means full fine-tuning."""
    if rank <= 0:
        return 1.0
    return _exact_fraction(cfg, rank)
