"""Memory profiler: replays RLHF phase traces through the caching-allocator
simulator under a (strategy, empty_cache policy) pair and reports the
paper's measurements — peak reserved / fragmentation / peak allocated,
per-phase timelines (Figure 1), and the modelled end-to-end time.

Realism notes (each maps to a paper observation):
  * inference-phase outputs (experience tensors, KV caches) stay live until
    the phase named by ``free_after`` completes — so training allocates on
    top of partially-occupied segments, the paper's §3.1 mechanism;
  * generation length varies per PPO iteration (sampling stops at EOS), so
    successive iterations have *different* allocation patterns — the
    "varying object sizes" of Appendix A;
  * the time model is max(flops/rate, weight-bytes/bandwidth) per phase plus
    cudaMalloc and empty_cache latencies — decode is bandwidth-bound.

empty_cache policies (paper §3.3): none | after_inference | after_training |
after_all.

The runtime-offload axis (``strategy.offload`` / the ``offload=`` kwarg, see
``repro.offload``) is modelled at *phase granularity*: a managed persistent
buffer group is device-resident exactly during the phases that touch it
(``PersistentBuffers.required_by``). At each boundary, evicted groups are
freed **before** ``empty_cache`` runs (so their segments can release — the
order the runtime scheduler uses too) and the next phase's groups are
malloc'd after the boundary record (the runtime fetch is an async
``device_put`` issued at the same point). Swap traffic pays a PCIe-bandwidth
term that overlaps phase compute: per phase, max(compute/HBM time, swap
time).

The simulator's predictions are also emitted at *runtime*: a trainer run
with a ``repro.obs.RunTelemetry`` attached replays ``run_iteration`` once
and rides each phase's predicted bytes on the measured phase span
(``sim_peak_bytes`` / ``sim_delta_bytes``), so sim-vs-measured divergence
is a first-class metric in every trace — see DESIGN.md §4.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.allocator import MB, CachingAllocator
from repro.core.phases import PersistentBuffers, Phase
from repro.core.strategies import MemoryStrategy, offload_managed_states

POLICIES = ("none", "after_inference", "after_training", "after_all")

# time model constants (rationale in DESIGN.md §1; exercised by
# tests/test_paper_claims.py)
_FLOPS_RATE = 60e12            # sustained bf16 FLOP/s per GPU (3090-class)
_HBM_BW = 800e9                # B/s
_CUDA_MALLOC_MS = 0.75         # cudaMalloc/cudaFree latency
_EMPTY_CACHE_MS = 2.0          # empty_cache API call overhead
_PCIE_BW = 16e9                # B/s host<->device swap bandwidth


@dataclass
class PhaseRecord:
    name: str
    kind: str
    reserved_end: int
    allocated_end: int
    peak_reserved: int
    frag_end: int
    host_bytes: int = 0            # parked on host at phase end (offload)
    alloc_peak: int = 0            # peak live bytes *within* this phase
    # device-resident persistent groups -> modelled bytes at the boundary
    # record — the simulator's per-state ledger the runtime attribution
    # engine diffs its measured owner table against (per-owner sim deltas)
    state_bytes_end: Dict[str, int] = field(default_factory=dict)


@dataclass
class RunResult:
    strategy: str
    policy: str
    peak_reserved: int
    peak_allocated: int
    frag_at_peak: int
    max_frag: int
    n_cuda_malloc: int
    n_empty_cache: int
    time_s: float
    phase_records: List[PhaseRecord] = field(default_factory=list)
    timeline: List[Tuple[int, int, int]] = field(default_factory=list)
    offload: str = "none"
    peak_host_bytes: int = 0       # peak parked on host (offload)
    swapped_bytes: int = 0         # cumulative host<->device swap traffic
    ndp: int = 1                   # DP/ZeRO domain size the run modelled
    ntp: int = 1                   # TP domain size the run modelled

    def row(self) -> dict:
        GB = 1 << 30
        return {
            "strategy": self.strategy, "policy": self.policy,
            "reserved_gb": round(self.peak_reserved / GB, 2),
            "frag_gb": round(self.frag_at_peak / GB, 2),
            "allocated_gb": round(self.peak_allocated / GB, 2),
            "time_s": round(self.time_s, 2),
        }


def _should_empty(policy: str, phase_kind: str) -> bool:
    if policy == "after_all":
        return True
    if policy == "after_inference":
        return phase_kind == "inference"
    if policy == "after_training":
        return phase_kind == "training"
    return False


def run_iteration(plans, persistent: PersistentBuffers,
                  strategy: MemoryStrategy, policy: str = "none", *,
                  ndp: int = 4, ntp: int = 1,
                  trainable_fraction: float = 1.0,
                  capacity: int = 24 << 30,
                  timeline: bool = False,
                  offload: Optional[str] = None) -> RunResult:
    """Replay PPO iterations. ``plans`` is a list of phase lists — one per
    iteration (varying generation lengths) — or a single phase list.
    ``capacity`` models the device HBM (24 GB RTX-3090 for Table 1,
    80 GB A100 for Table 2). ``offload`` (default: ``strategy.offload``)
    selects the runtime-offload level; see the module docstring.

    ``ntp`` records the TP domain of the run being modelled. The per-tag
    *fractions* of a TP run come in through ``strategy.traced`` (built on
    the dp x tp mesh by ``strategies.traced_strategy`` when
    ``strategy.ntp > 1``); the closed-form fallback stays the paper's
    pure-DP model, so passing ``ntp`` without a traced strategy only
    labels the result."""
    if plans and isinstance(plans[0], Phase):
        plans = [plans]
    offload = offload if offload is not None else \
        getattr(strategy, "offload", "none")
    alloc = CachingAllocator(timeline=timeline, capacity=capacity)
    # persistent groups pass their state name through, so a traced strategy
    # (``strategies.traced_strategy``: per-device fractions from the real
    # sharded trees) applies its exact per-group fraction; trace-level
    # events fall back to the per-tag aggregate (or the closed-form 1/ndp)
    scale = lambda tag, state=None: strategy.scale(
        tag, ndp=ndp, trainable_fraction=trainable_fraction, state=state)

    # phase-scoped buffer groups: offload-managed role state + transients
    # (e.g. the hydra merged rollout weights); everything else is resident
    # for the whole run, exactly as before the offload axis existed
    managed = offload_managed_states(offload, persistent.buffers) \
        & set(persistent.required_by)
    scoped = managed | (set(persistent.transient) & set(persistent.buffers))
    resident: Dict[str, List[int]] = {}
    state_bytes: Dict[str, int] = {}
    swapped_total = 0
    peak_host = 0
    parked_now = 0

    def group_bytes(name: str) -> int:
        total = 0
        for nb, tag in persistent.buffers[name]:
            s = scale(tag, name)
            if s > 0 and nb * s >= 4096:
                total += int(nb * s)
        return total

    def group_malloc(name: str):
        hs = []
        for nb, tag in persistent.buffers[name]:
            s = scale(tag, name)
            if s > 0 and nb * s >= 4096:
                hs.append(alloc.malloc(int(nb * s)))
        resident[name] = hs

    def group_free(name: str):
        for h in resident.pop(name):
            alloc.free(h)

    for name in persistent.buffers:
        state_bytes[name] = group_bytes(name)
        if name not in scoped:
            group_malloc(name)

    # flattened schedule (across iterations) for next-phase lookups
    flat: List[Phase] = [ph for phases in plans for ph in phases]

    def needed(idx: int) -> frozenset:
        if idx >= len(flat):
            return frozenset()
        pname = flat[idx].name
        return frozenset(n for n in scoped
                         if pname in persistent.required_by.get(n, ()))

    # initial placement for the first phase (not counted as swap traffic);
    # managed groups not needed by it start out parked on host
    for n in needed(0):
        group_malloc(n)
    parked_now = sum(state_bytes[n] for n in managed if n not in resident)
    peak_host = parked_now

    total_time = 0.0
    n_empty = 0
    gi = 0
    records: List[PhaseRecord] = []
    for phases in plans:
        deferred: Dict[str, List[int]] = {}
        for ph in phases:
            alloc_peak = alloc.allocated
            for rep in range(ph.repeats):
                handle_map: Dict[int, int] = {}
                for op, vid, nb, tag in ph.trace.events:
                    size = int(nb * scale(tag))
                    if size < 512:
                        continue
                    if op == "alloc":
                        handle_map[vid] = alloc.malloc(size)
                        alloc_peak = max(alloc_peak, alloc.allocated)
                    else:
                        h = handle_map.pop(vid, None)
                        if h is not None:
                            alloc.free(h)
                leftovers = list(handle_map.values())
                if ph.free_after and rep == ph.repeats - 1:
                    deferred.setdefault(ph.free_after, []).extend(leftovers)
                else:
                    for h in leftovers:
                        alloc.free(h)
            # outputs scheduled to die after this phase
            for h in deferred.pop(ph.name, []):
                alloc.free(h)
            # boundary, offload half 1: park groups the next phase doesn't
            # touch (free BEFORE empty_cache so their segments can release)
            nxt = needed(gi + 1)
            boundary_swap = 0
            for n in [r for r in list(resident) if r in scoped and r not in nxt]:
                group_free(n)
                if n in managed:
                    boundary_swap += state_bytes[n]
                    parked_now += state_bytes[n]
            if _should_empty(policy, ph.kind):
                alloc.empty_cache()
                n_empty += 1
            peak_host = max(peak_host, parked_now)
            records.append(PhaseRecord(
                ph.name, ph.kind, alloc.reserved, alloc.allocated,
                alloc.stats.peak_reserved, alloc.fragmentation(),
                host_bytes=parked_now, alloc_peak=alloc_peak,
                state_bytes_end={n: state_bytes[n] for n in resident
                                 if state_bytes[n] > 0}))
            # boundary, offload half 2: fetch the next phase's groups (the
            # runtime issues these as async device_puts at the same point)
            for n in nxt - frozenset(resident):
                group_malloc(n)
                if n in managed:
                    boundary_swap += state_bytes[n]
                    parked_now -= state_bytes[n]
            swapped_total += boundary_swap
            # swap copies overlap phase compute (double-buffered prefetch)
            total_time += max(max(ph.flops / _FLOPS_RATE,
                                  ph.hbm_bytes / _HBM_BW),
                              boundary_swap / _PCIE_BW)
            gi += 1
        # anything still deferred dies at iteration end
        for hs in deferred.values():
            for h in hs:
                alloc.free(h)

    st = alloc.stats
    time_s = (total_time + st.n_cuda_malloc * _CUDA_MALLOC_MS / 1e3
              + (n_empty + st.n_forced_flush) * _EMPTY_CACHE_MS / 1e3)
    return RunResult(
        strategy=strategy.name, policy=policy,
        peak_reserved=st.peak_reserved, peak_allocated=st.peak_allocated,
        frag_at_peak=st.frag_at_peak, max_frag=st.max_frag,
        n_cuda_malloc=st.n_cuda_malloc, n_empty_cache=n_empty,
        time_s=time_s, phase_records=records,
        timeline=alloc.timeline if timeline else [],
        offload=offload, peak_host_bytes=peak_host,
        swapped_bytes=swapped_total, ndp=ndp, ntp=ntp)
