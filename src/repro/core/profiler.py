"""Memory profiler: replays RLHF phase traces through the caching-allocator
simulator under a (strategy, empty_cache policy) pair and reports the
paper's measurements — peak reserved / fragmentation / peak allocated,
per-phase timelines (Figure 1), and the modelled end-to-end time.

Realism notes (each maps to a paper observation):
  * inference-phase outputs (experience tensors, KV caches) stay live until
    the phase named by ``free_after`` completes — so training allocates on
    top of partially-occupied segments, the paper's §3.1 mechanism;
  * generation length varies per PPO iteration (sampling stops at EOS), so
    successive iterations have *different* allocation patterns — the
    "varying object sizes" of Appendix A;
  * the time model is max(flops/rate, weight-bytes/bandwidth) per phase plus
    cudaMalloc and empty_cache latencies — decode is bandwidth-bound.

empty_cache policies (paper §3.3): none | after_inference | after_training |
after_all.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.allocator import MB, CachingAllocator
from repro.core.phases import PersistentBuffers, Phase
from repro.core.strategies import MemoryStrategy

POLICIES = ("none", "after_inference", "after_training", "after_all")

# time model constants (documented in EXPERIMENTS.md §Paper-claims)
_FLOPS_RATE = 60e12            # sustained bf16 FLOP/s per GPU (3090-class)
_HBM_BW = 800e9                # B/s
_CUDA_MALLOC_MS = 0.75         # cudaMalloc/cudaFree latency
_EMPTY_CACHE_MS = 2.0          # empty_cache API call overhead


@dataclass
class PhaseRecord:
    name: str
    kind: str
    reserved_end: int
    allocated_end: int
    peak_reserved: int
    frag_end: int


@dataclass
class RunResult:
    strategy: str
    policy: str
    peak_reserved: int
    peak_allocated: int
    frag_at_peak: int
    max_frag: int
    n_cuda_malloc: int
    n_empty_cache: int
    time_s: float
    phase_records: List[PhaseRecord] = field(default_factory=list)
    timeline: List[Tuple[int, int, int]] = field(default_factory=list)

    def row(self) -> dict:
        GB = 1 << 30
        return {
            "strategy": self.strategy, "policy": self.policy,
            "reserved_gb": round(self.peak_reserved / GB, 2),
            "frag_gb": round(self.frag_at_peak / GB, 2),
            "allocated_gb": round(self.peak_allocated / GB, 2),
            "time_s": round(self.time_s, 2),
        }


def _should_empty(policy: str, phase_kind: str) -> bool:
    if policy == "after_all":
        return True
    if policy == "after_inference":
        return phase_kind == "inference"
    if policy == "after_training":
        return phase_kind == "training"
    return False


def run_iteration(plans, persistent: PersistentBuffers,
                  strategy: MemoryStrategy, policy: str = "none", *,
                  ndp: int = 4, trainable_fraction: float = 1.0,
                  capacity: int = 24 << 30,
                  timeline: bool = False) -> RunResult:
    """Replay PPO iterations. ``plans`` is a list of phase lists — one per
    iteration (varying generation lengths) — or a single phase list.
    ``capacity`` models the device HBM (24 GB RTX-3090 for Table 1,
    80 GB A100 for Table 2)."""
    if plans and isinstance(plans[0], Phase):
        plans = [plans]
    alloc = CachingAllocator(timeline=timeline, capacity=capacity)
    scale = lambda tag: strategy.scale(tag, ndp=ndp,
                                       trainable_fraction=trainable_fraction)

    # persistent model/optimizer buffers live for the whole run
    for name, bufs in persistent.buffers.items():
        for nb, tag in bufs:
            s = scale(tag)
            if s > 0 and nb * s >= 4096:
                alloc.malloc(int(nb * s))

    total_time = 0.0
    n_empty = 0
    records: List[PhaseRecord] = []
    for phases in plans:
        deferred: Dict[str, List[int]] = {}
        for ph in phases:
            for rep in range(ph.repeats):
                handle_map: Dict[int, int] = {}
                for op, vid, nb, tag in ph.trace.events:
                    size = int(nb * scale(tag))
                    if size < 512:
                        continue
                    if op == "alloc":
                        handle_map[vid] = alloc.malloc(size)
                    else:
                        h = handle_map.pop(vid, None)
                        if h is not None:
                            alloc.free(h)
                leftovers = list(handle_map.values())
                if ph.free_after and rep == ph.repeats - 1:
                    deferred.setdefault(ph.free_after, []).extend(leftovers)
                else:
                    for h in leftovers:
                        alloc.free(h)
            # outputs scheduled to die after this phase
            for h in deferred.pop(ph.name, []):
                alloc.free(h)
            total_time += max(ph.flops / _FLOPS_RATE,
                              ph.hbm_bytes / _HBM_BW)
            if _should_empty(policy, ph.kind):
                alloc.empty_cache()
                n_empty += 1
            records.append(PhaseRecord(
                ph.name, ph.kind, alloc.reserved, alloc.allocated,
                alloc.stats.peak_reserved, alloc.fragmentation()))
        # anything still deferred dies at iteration end
        for hs in deferred.values():
            for h in hs:
                alloc.free(h)

    st = alloc.stats
    time_s = (total_time + st.n_cuda_malloc * _CUDA_MALLOC_MS / 1e3
              + (n_empty + st.n_forced_flush) * _EMPTY_CACHE_MS / 1e3)
    return RunResult(
        strategy=strategy.name, policy=policy,
        peak_reserved=st.peak_reserved, peak_allocated=st.peak_allocated,
        frag_at_peak=st.frag_at_peak, max_frag=st.max_frag,
        n_cuda_malloc=st.n_cuda_malloc, n_empty_cache=n_empty,
        time_s=time_s, phase_records=records,
        timeline=alloc.timeline if timeline else [])
