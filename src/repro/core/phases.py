"""RLHF phase plans: the PPO iteration of DeepSpeed-Chat / ColossalChat as a
sequence of traced phases (paper §2.1 / §3.1).

One PPO iteration touches four models:

  1. rollout      — actor prefill + N decode steps (experience generation)
  2. score_reward — reward-model forward over the generated sequences
  3. score_ref    — reference-model forward (KL logprobs)
  4. score_values — critic forward (value estimates)
  5. score_old    — actor forward (old logprobs)
  6. train_actor  — PPO update (fwd+bwd+opt)
  7. train_critic — value-function update

Each phase is a jaxpr-derived event trace at the *paper's* scale (OPT-1.3b
actor/ref + OPT-350m critic/reward, batch 2, prompt 256 + generate 256).
``naive_generation`` models ColossalChat's original ``generate()`` (paper
App. B): every decode step reallocates a grown KV cache instead of writing
into a fixed-capacity one.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from functools import lru_cache
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.trace import Trace, trace_function
from repro.models import Model
from repro.steps import (init_train_state, make_decode_step,
                         make_prefill_step, make_train_step)


@dataclass
class Phase:
    name: str
    kind: str                     # "inference" | "training"
    trace: Trace
    repeats: int = 1              # decode steps replay the same trace
    model: str = "actor"          # which persistent model it touches
    flops: float = 0.0            # analytic, for the time-overhead model
    hbm_bytes: float = 0.0        # weight traffic (decode is BW-bound)
    # phase outputs (experience / kv caches) stay live until the named
    # phase completes — None frees them immediately
    free_after: Optional[str] = None


# Canonical order of one PPO iteration — the phase sequence the runtime
# trainer executes and the offload scheduler compiles against.
RLHF_PHASE_SEQUENCE = (
    "rollout_prefill", "rollout_decode", "score_reward", "score_ref",
    "score_values", "score_old_logp", "train_actor", "train_critic")


def _collapse_rollout(seq):
    out = []
    for p in seq:
        c = "rollout" if p.startswith("rollout") else p
        if not out or out[-1] != c:
            out.append(c)
    return tuple(out)


# the same iteration at the granularity the runtime trainer bounds it
# (prefill+decode are one "rollout" phase between boundaries) — derived,
# not restated, so the two sequences cannot drift apart
RUNTIME_RLHF_PHASE_SEQUENCE = _collapse_rollout(RLHF_PHASE_SEQUENCE)


def phase_state_touches(engine: str = "separate") -> Dict[str, frozenset]:
    """state name -> the phases (trace-level names) during which that
    persistent tree must be device-resident. This is the paper's
    phase-exclusivity map, shared verbatim by the allocator simulator
    (``profiler.run_iteration(offload=...)``) and the runtime scheduler
    (``offload.OffloadPlan``) so the two can never disagree.

    Hydra notes: ``base_params`` sits out the rollout phases — generation
    runs from the *merged* copy (``merged_rollout``), so the trunk's
    adapted leaves are redundant there and the extreme preset
    (``offload="all"``) parks them; the merge itself happens in the
    boundary window where both trees briefly coexist."""
    if engine == "hydra":
        return {
            "base_params": frozenset(RLHF_PHASE_SEQUENCE)
            - {"rollout_prefill", "rollout_decode"},
            "merged_rollout": frozenset({"rollout_prefill", "rollout_decode"}),
            "actor_params": frozenset({"rollout_prefill", "rollout_decode",
                                       "score_old_logp", "train_actor"}),
            "actor_opt": frozenset({"train_actor"}),
            "critic_params": frozenset({"score_values", "train_critic"}),
            "critic_opt": frozenset({"train_critic"}),
            "reward_params": frozenset({"score_reward"}),
        }
    assert engine == "separate", engine
    return {
        "actor_params": frozenset({"rollout_prefill", "rollout_decode",
                                   "score_old_logp", "train_actor"}),
        "actor_opt": frozenset({"train_actor"}),
        "critic_params": frozenset({"score_values", "train_critic"}),
        "critic_opt": frozenset({"train_critic"}),
        "ref_params": frozenset({"score_ref"}),
        "reward_params": frozenset({"score_reward"}),
    }


def runtime_state_touches(engine: str = "separate") -> Dict[str, frozenset]:
    """:func:`phase_state_touches` with the two rollout trace phases
    collapsed into the single ``"rollout"`` phase the runtime trainer
    bounds — plus the trees the *merge* step needs resident at rollout
    entry (hydra: the base trunk feeds ``merge_adapter`` before the
    scheduler's mid-phase park kicks in)."""
    out = {}
    for name, phases in phase_state_touches(engine).items():
        collapsed = {("rollout" if p.startswith("rollout") else p)
                     for p in phases}
        if engine == "hydra" and name in ("base_params",):
            collapsed.add("rollout")     # resident for the merge itself
        out[name] = frozenset(collapsed)
    out.pop("merged_rollout", None)      # runtime merged tree is phase-local
    return out


@dataclass
class PersistentBuffers:
    """Long-lived allocations (model weights, optimizer states) shared
    across phases: name -> list[(nbytes, tag)].

    ``required_by`` (name -> phase names) records which phases touch each
    buffer — the residency schedule the offload axis swaps against; names
    absent from it are always-resident. ``transient`` names are
    phase-local at *every* offload level (the hydra engine's merged
    rollout weights exist only while generation runs)."""
    buffers: Dict[str, List[Tuple[int, str]]] = field(default_factory=dict)
    required_by: Dict[str, frozenset] = field(default_factory=dict)
    transient: frozenset = frozenset()


def _batch_specs(cfg: ModelConfig, B: int, S: int, train: bool):
    batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    if train:
        for k in ("loss_mask", "advantages", "old_logp", "ref_logp",
                  "returns"):
            batch[k] = jax.ShapeDtypeStruct((B, S), jnp.float32)
    return batch


def _tags_for(tree, tag):
    return jax.tree.map(lambda _: tag, tree)


def _fwd_flops(cfg: ModelConfig, tokens: int) -> float:
    return 2.0 * cfg.param_count() * tokens


def build_rlhf_phases(actor_cfg: ModelConfig, critic_cfg: ModelConfig, *,
                      batch: int = 2, prompt_len: int = 256,
                      gen_len: int = 256, grad_ckpt: bool = False,
                      naive_generation: bool = False,
                      min_bytes: int = 64 * 1024,
                      ppo_epochs: int = 1,
                      engine: str = "separate", lora_rank: int = 128):
    """Returns (phases, persistent buffers).

    ``engine="separate"`` is the paper's four-model pipeline;
    ``engine="hydra"`` traces the shared-base engine instead (one frozen
    trunk, per-role LoRA adapters at ``lora_rank``, adapter-only train
    steps, merged-weight rollout) so the analytic model covers the same
    layout the runtime offload subsystem swaps."""
    assert engine in ("separate", "hydra"), engine
    remat = "full" if grad_ckpt else "none"
    # fp16/bf16 mixed precision as the paper's frameworks use; fused
    # (flash) attention everywhere, as the 2023 frameworks' kernels did
    from repro.models import layers as _L
    _L.FLASH_MIN_ELEMS = 1 << 14
    actor_cfg = dataclasses.replace(actor_cfg, remat=remat,
                                    param_dtype="bfloat16")
    critic_cfg = dataclasses.replace(critic_cfg, remat=remat,
                                     param_dtype="bfloat16")
    S = prompt_len + gen_len
    actor = Model(actor_cfg)
    key = jax.random.PRNGKey(0)
    a_params = jax.eval_shape(actor.init, key)

    persistent = PersistentBuffers()

    def add_persistent(name, tree, tag):
        leaves = jax.tree.leaves(tree)
        persistent.buffers[name] = [
            (int(jnp.dtype(l.dtype).itemsize *
                 __import__("numpy").prod(l.shape)), tag) for l in leaves]

    if engine == "hydra":
        from repro.models.lora import adapted_subtree
        from repro.steps import init_lora_train_state, make_lora_train_step
        critic = actor                       # heads ride the shared trunk
        actor_ad = jax.eval_shape(
            lambda k: actor.init_adapter(k, a_params, lora_rank), key)
        critic_ad = jax.eval_shape(
            lambda k: actor.init_adapter(k, a_params, lora_rank,
                                         with_value=True), key)
        a_step = make_lora_train_step(actor, actor_cfg, kind="ppo")
        c_step = make_lora_train_step(actor, actor_cfg, kind="critic")
        a_state = jax.eval_shape(
            lambda ad: init_lora_train_state(ad, a_step.optimizer), actor_ad)
        c_state = jax.eval_shape(
            lambda ad: init_lora_train_state(ad, c_step.optimizer), critic_ad)
        add_persistent("base_params", a_params, "param")   # ONE frozen trunk
        add_persistent("actor_params", a_state["params"], "param")
        add_persistent("actor_opt", a_state["opt"], "opt")
        add_persistent("critic_params", c_state["params"], "param")
        add_persistent("critic_opt", c_state["opt"], "opt")
        add_persistent("reward_params", critic_ad, "param")  # frozen adapter
        # rollout generates from merged weights: a phase-local copy of the
        # trunk's adapted leaves (non-adapted leaves alias the base)
        add_persistent("merged_rollout",
                       adapted_subtree(a_params, actor_ad["lora"]), "param")
    else:
        critic = Model(critic_cfg, with_value=True)
        c_params = jax.eval_shape(critic.init, key)
        a_step = make_train_step(actor, actor_cfg, kind="ppo")
        c_step = make_train_step(critic, critic_cfg, kind="critic")
        a_state = jax.eval_shape(
            lambda k: init_train_state(actor, actor_cfg, k, a_step.optimizer),
            key)
        c_state = jax.eval_shape(
            lambda k: init_train_state(critic, critic_cfg, k,
                                       c_step.optimizer), key)
        add_persistent("actor_params", a_state["params"], "param")
        add_persistent("actor_opt", a_state["opt"], "opt")
        add_persistent("critic_params", c_state["params"], "param")
        add_persistent("critic_opt", c_state["opt"], "opt")
        add_persistent("ref_params", a_params, "param")     # frozen copy
        add_persistent("reward_params", c_params, "param")  # frozen copy

    # phase-exclusivity schedule: which phases touch which buffer (the
    # offload axis of profiler.run_iteration swaps against this)
    persistent.required_by = {
        k: v for k, v in phase_state_touches(engine).items()
        if k in persistent.buffers}
    persistent.transient = frozenset({"merged_rollout"}) & \
        frozenset(persistent.buffers)

    phases: List[Phase] = []

    # ---- rollout: prefill + gen_len decode steps --------------------------
    cap = S
    pf = make_prefill_step(actor, actor_cfg, capacity=cap)
    pf_batch = _batch_specs(actor_cfg, batch, prompt_len, train=False)
    tr_pf = trace_function(
        pf, (a_params, pf_batch),
        (_tags_for(a_params, "param"), _tags_for(pf_batch, "input")),
        min_bytes=min_bytes)
    a_bytes = actor_cfg.param_count() * 2
    # hydra scoring phases stream the shared trunk, not a separate critic
    c_bytes = a_bytes if engine == "hydra" else critic_cfg.param_count() * 2
    phases.append(Phase("rollout_prefill", "inference", tr_pf,
                        flops=_fwd_flops(actor_cfg, batch * prompt_len),
                        hbm_bytes=a_bytes,
                        free_after="rollout_decode"))

    caches = jax.eval_shape(lambda: actor.init_cache(batch, cap, jnp.bfloat16))
    caches_w = {"segments": caches, "cross_kv": None}
    dec = make_decode_step(actor, actor_cfg)
    tok = jax.ShapeDtypeStruct((batch,), jnp.int32)
    tr_dec = trace_function(
        dec, (a_params, caches_w, tok, tok),
        (_tags_for(a_params, "param"), _tags_for(caches_w, "cache"),
         "input", "input"), min_bytes=min_bytes // 8)
    if naive_generation:
        # HF-style dynamic KV cache, as the paper's frameworks used at the
        # time (DeepSpeed-Chat / ColossalChat generate(), paper App. B):
        # every step, every layer does new_kv = cat(old_kv, kv_t) — the new
        # (slightly larger) buffer is allocated while the old one is still
        # live, so no cached block ever fits and reserved memory churns.
        cache_bytes = sum(
            int(jnp.dtype(l.dtype).itemsize * __import__("numpy").prod(l.shape))
            for l in jax.tree.leaves(caches))
        L = actor_cfg.num_layers
        per_layer_tok = cache_bytes / (L * cap)     # bytes per layer per token
        grow = Trace()
        live = {}                                    # layer -> (vid, nb)
        vid = iter(range(10_000_000, 10**9))
        # decode-trace vids with no matching free (step outputs): free them
        # at step end so the synthetic trace stays balanced
        open_vids = {}
        for op, v, b, tg in tr_dec.events:
            if tg == "cache":
                continue
            if op == "alloc":
                open_vids[v] = (b, tg)
            else:
                open_vids.pop(v, None)
        for t in range(gen_len):
            cur = prompt_len + t + 1
            for l in range(L):
                v = next(vid)
                nb = int(per_layer_tok * cur)
                grow.alloc(v, nb, "temp")            # cat() result
                if l in live:
                    grow.free(*live[l], "temp")
                live[l] = (v, nb)
            # per-step activation temps from the real decode trace
            base = 500_000_000 + t * 200_000
            for op, v, b, tg in tr_dec.events:
                if tg == "cache":
                    continue
                (grow.alloc if op == "alloc" else grow.free)(base + v, b, tg)
            for v, (b, tg) in open_vids.items():
                grow.free(base + v, b, tg)
        for l, (v, nb) in live.items():
            grow.free(v, nb, "temp")
        phases.append(Phase("rollout_decode", "inference", grow,
                            flops=_fwd_flops(actor_cfg, batch * gen_len),
                            hbm_bytes=a_bytes * gen_len))
    else:
        phases.append(Phase("rollout_decode", "inference", tr_dec,
                            repeats=gen_len,
                            flops=_fwd_flops(actor_cfg, batch * gen_len),
                            hbm_bytes=a_bytes * gen_len))

    # ---- scoring inferences ------------------------------------------------
    full_batch = _batch_specs(actor_cfg, batch, S, train=False)

    def fwd_trace(model, params, cfg, value=False, adapter=None):
        """Forward trace; with ``adapter`` (hydra) the role's LoRA tree is
        a second persistent param input over the shared trunk."""
        if adapter is not None:
            fn = (lambda p, ad, b: model.forward_value(p, b, adapter=ad)) \
                if value else \
                (lambda p, ad, b: model.forward(p, b, adapter=ad)[0])
            return trace_function(
                fn, (params, adapter, full_batch),
                (_tags_for(params, "param"), _tags_for(adapter, "param"),
                 _tags_for(full_batch, "input")), min_bytes=min_bytes)
        fn = (lambda p, b: model.forward_value(p, b)) if value else \
            (lambda p, b: model.forward(p, b)[0])
        return trace_function(
            fn, (params, full_batch),
            (_tags_for(params, "param"), _tags_for(full_batch, "input")),
            min_bytes=min_bytes)

    hy = engine == "hydra"
    sc_params = a_params if hy else c_params
    phases.append(Phase("score_reward", "inference",
                        fwd_trace(critic, sc_params, critic_cfg, value=True,
                                  adapter=critic_ad if hy else None),
                        model="reward", hbm_bytes=c_bytes,
                        flops=_fwd_flops(critic_cfg, batch * S),
                        free_after="train_critic"))
    phases.append(Phase("score_ref", "inference",
                        fwd_trace(actor, a_params, actor_cfg), model="ref",
                        flops=_fwd_flops(actor_cfg, batch * S),
                        hbm_bytes=a_bytes, free_after="train_critic"))
    phases.append(Phase("score_values", "inference",
                        fwd_trace(critic, sc_params, critic_cfg, value=True,
                                  adapter=critic_ad if hy else None),
                        model="critic", hbm_bytes=c_bytes,
                        flops=_fwd_flops(critic_cfg, batch * S),
                        free_after="train_critic"))
    phases.append(Phase("score_old_logp", "inference",
                        fwd_trace(actor, a_params, actor_cfg,
                                  adapter=actor_ad if hy else None),
                        model="actor",
                        flops=_fwd_flops(actor_cfg, batch * S),
                        hbm_bytes=a_bytes, free_after="train_critic"))

    # ---- training ----------------------------------------------------------
    tb = _batch_specs(actor_cfg, batch, S, train=True)
    a_tags = {"params": _tags_for(a_state["params"], "param"),
              "opt": _tags_for(a_state["opt"], "opt"), "step": "opt"}
    c_tags = {"params": _tags_for(c_state["params"], "param"),
              "opt": _tags_for(c_state["opt"], "opt"), "step": "opt"}
    if hy:
        # lora steps: (adapter_state, frozen_base, batch) — grads/opt cover
        # only the adapter leaves; the trunk rides along un-differentiated
        tr_actor = trace_function(
            a_step, (a_state, a_params, tb),
            (a_tags, _tags_for(a_params, "param"), _tags_for(tb, "input")),
            min_bytes=min_bytes)
        tr_critic = trace_function(
            c_step, (c_state, a_params, tb),
            (c_tags, _tags_for(a_params, "param"), _tags_for(tb, "input")),
            min_bytes=min_bytes)
    else:
        tr_actor = trace_function(
            a_step, (a_state, tb), (a_tags, _tags_for(tb, "input")),
            min_bytes=min_bytes)
        tr_critic = trace_function(
            c_step, (c_state, tb), (c_tags, _tags_for(tb, "input")),
            min_bytes=min_bytes)
    phases.append(Phase("train_actor", "training", tr_actor,
                        repeats=ppo_epochs, hbm_bytes=3 * a_bytes,
                        flops=3 * _fwd_flops(actor_cfg, batch * S)))
    phases.append(Phase("train_critic", "training", tr_critic,
                        repeats=ppo_epochs, model="critic",
                        hbm_bytes=3 * c_bytes,
                        flops=3 * _fwd_flops(critic_cfg, batch * S)))
    return phases, persistent


def build_grpo_phases(actor_cfg: ModelConfig, *, batch: int = 2,
                      group_size: int = 8, prompt_len: int = 256,
                      gen_len: int = 256, grad_ckpt: bool = False,
                      naive_generation: bool = False,
                      min_bytes: int = 64 * 1024):
    """GRPO (beyond-paper ablation): two models only — actor + frozen
    reference; no critic, no reward-value model, no value scoring phases.
    The rollout batch is batch*group_size. Same trace machinery as PPO."""
    ppo_phases, ppo_persist = build_rlhf_phases(
        actor_cfg, actor_cfg, batch=batch * group_size,
        prompt_len=prompt_len, gen_len=gen_len, grad_ckpt=grad_ckpt,
        naive_generation=naive_generation, min_bytes=min_bytes)
    keep = {"rollout_prefill", "rollout_decode", "score_ref",
            "score_old_logp", "train_actor"}
    phases = [p for p in ppo_phases if p.name in keep]
    for p in phases:
        if p.free_after == "train_critic":
            p.free_after = "train_actor"
    keep_bufs = ("actor_params", "actor_opt", "ref_params")
    persistent = PersistentBuffers(
        {k: v for k, v in ppo_persist.buffers.items() if k in keep_bufs},
        required_by={k: frozenset(p for p in v if p in {ph.name for ph in phases})
                     for k, v in ppo_persist.required_by.items()
                     if k in keep_bufs})
    return phases, persistent
