"""Caching-allocator simulator — the mechanistic model behind the paper.

Faithful to the PyTorch CUDA caching allocator (paper §2.2 / Appendix A):

  * two pools — small (< 1 MiB requests, 2 MiB segments) and large;
  * requests rounded to 512 B; large requests get dedicated segments
    (>= 20 MiB rounded to 2 MiB granularity);
  * freed blocks are cached in their pool, split on reuse, and coalesced
    with free neighbours within the same segment;
  * ``cudaMalloc`` (segment growth) happens only when no cached block fits —
    *reserved* grows; *allocated* tracks live tensor bytes;
  * external fragmentation is measured exactly as the paper does (§3):
    at each cudaMalloc, fragmentation = reserved - allocated at that moment,
    attributable to free blocks that could not serve the request;
  * ``empty_cache()`` releases every segment with no live block back to the
    driver (the paper's §3.3 mitigation).

The simulator is driven by alloc/free event streams produced by the jaxpr
liveness tracer (`repro.core.trace`), one stream per RLHF phase.
"""
from __future__ import annotations

import bisect
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

KB = 1024
MB = 1024 * KB

ROUND = 512
SMALL_REQUEST = 1 * MB
SMALL_SEGMENT = 2 * MB
LARGE_SEGMENT_MIN = 20 * MB


def _round_size(size: int) -> int:
    if size <= 0:
        return ROUND
    return -(-size // ROUND) * ROUND


def _segment_size(rounded: int) -> int:
    if rounded <= SMALL_REQUEST:
        return SMALL_SEGMENT
    if rounded < LARGE_SEGMENT_MIN:
        return LARGE_SEGMENT_MIN
    return -(-rounded // SMALL_SEGMENT) * SMALL_SEGMENT


@dataclass
class Block:
    segment: "Segment"
    offset: int
    size: int
    free: bool = True
    prev: Optional["Block"] = None
    next: Optional["Block"] = None


@dataclass
class Segment:
    sid: int
    size: int
    small: bool
    head: Block = None  # type: ignore

    def live_bytes(self) -> int:
        n, b = 0, self.head
        while b is not None:
            if not b.free:
                n += b.size
            b = b.next
        return n


@dataclass
class AllocatorStats:
    reserved: int = 0
    allocated: int = 0
    peak_reserved: int = 0
    peak_allocated: int = 0
    n_cuda_malloc: int = 0
    n_alloc: int = 0
    n_forced_flush: int = 0
    # fragmentation measured at each cudaMalloc (paper Appendix B)
    frag_at_peak: int = 0
    max_frag: int = 0


class CachingAllocator:
    """BFC-style caching allocator with small/large pools."""

    def __init__(self, timeline: bool = False,
                 capacity: Optional[int] = None):
        self.capacity = capacity        # device HBM size; None = unbounded
        self.segments: List[Segment] = []
        # free lists: (size, counter) -> Block, kept sorted for best-fit
        self._free_small: List[Tuple[int, int, Block]] = []
        self._free_large: List[Tuple[int, int, Block]] = []
        self._counter = 0
        self._handles: Dict[int, Block] = {}
        self._next_handle = 1
        self.stats = AllocatorStats()
        self._frag_at_last_grow = 0
        self.timeline_enabled = timeline
        self.timeline: List[Tuple[int, int, int]] = []   # (event#, reserved, allocated)
        self._events = 0

    # -- free-list helpers ---------------------------------------------------
    def _pool(self, small: bool):
        return self._free_small if small else self._free_large

    def _insert_free(self, block: Block):
        block.free = True
        self._counter += 1
        bisect.insort(self._pool(block.segment.small),
                      (block.size, self._counter, block))

    def _remove_free(self, block: Block):
        pool = self._pool(block.segment.small)
        i = bisect.bisect_left(pool, (block.size, -1, None))
        while i < len(pool):
            if pool[i][2] is block:
                pool.pop(i)
                return
            if pool[i][0] != block.size:
                break
            i += 1
        raise RuntimeError("free block not found in pool")

    def _tick(self):
        self._events += 1
        if self.timeline_enabled:
            self.timeline.append((self._events, self.stats.reserved,
                                  self.stats.allocated))

    # -- public API -----------------------------------------------------------
    def malloc(self, size: int) -> int:
        rounded = _round_size(size)
        small = rounded <= SMALL_REQUEST
        pool = self._pool(small)
        # best fit search (default CUDA allocator: any block >= request is
        # usable and the remainder is split back into the pool)
        i = bisect.bisect_left(pool, (rounded, -1, None))
        block = None
        if i < len(pool):
            block = pool[i][2]
            pool.pop(i)
        grew = False
        if block is None:
            # fragmentation measurement point (paper App. B): cached bytes
            # that could not serve this request
            frag = self.stats.reserved - self.stats.allocated
            self.stats.max_frag = max(self.stats.max_frag, frag)
            self._frag_at_last_grow = frag
            block = self._grow(rounded, small)
            grew = True
        # split
        remainder = block.size - rounded
        min_split = ROUND if small else MB
        if remainder >= min_split:
            tail = Block(block.segment, block.offset + rounded, remainder,
                         prev=block, next=block.next)
            if block.next is not None:
                block.next.prev = tail
            block.next = tail
            block.size = rounded
            self._insert_free(tail)
        block.free = False
        self.stats.allocated += block.size
        self.stats.n_alloc += 1
        if self.stats.allocated > self.stats.peak_allocated:
            self.stats.peak_allocated = self.stats.allocated
        if grew and self.stats.reserved > self.stats.peak_reserved:
            # frag at the growth that set the (new) reserved peak
            self.stats.peak_reserved = self.stats.reserved
            self.stats.frag_at_peak = self._frag_at_last_grow
        h = self._next_handle
        self._next_handle += 1
        self._handles[h] = block
        self._tick()
        return h

    def _grow(self, rounded: int, small: bool) -> Block:
        seg_size = _segment_size(rounded)
        if self.capacity is not None and \
                self.stats.reserved + seg_size > self.capacity:
            # real allocator's OOM fallback: release all cached blocks,
            # then retry the cudaMalloc (paper App. A)
            self.empty_cache()
            self.stats.n_forced_flush += 1
            if self.stats.reserved + seg_size > self.capacity:
                raise MemoryError(
                    f"simulated OOM: reserved {self.stats.reserved} + "
                    f"{seg_size} > capacity {self.capacity}")
        seg = Segment(len(self.segments), seg_size, small)
        blk = Block(seg, 0, seg_size)
        seg.head = blk
        self.segments.append(seg)
        self.stats.reserved += seg_size
        self.stats.n_cuda_malloc += 1
        return blk

    def free(self, handle: int):
        block = self._handles.pop(handle)
        assert not block.free
        self.stats.allocated -= block.size
        # coalesce with free neighbours
        if block.next is not None and block.next.free:
            nxt = block.next
            self._remove_free(nxt)
            block.size += nxt.size
            block.next = nxt.next
            if nxt.next is not None:
                nxt.next.prev = block
        if block.prev is not None and block.prev.free:
            prv = block.prev
            self._remove_free(prv)
            prv.size += block.size
            prv.next = block.next
            if block.next is not None:
                block.next.prev = prv
            block = prv
        self._insert_free(block)
        self._tick()

    def empty_cache(self) -> int:
        """Release every segment with no live blocks. Returns bytes freed."""
        released = 0
        keep: List[Segment] = []
        for seg in self.segments:
            if seg.live_bytes() == 0:
                b = seg.head
                while b is not None:
                    if b.free:
                        self._remove_free(b)
                    b = b.next
                released += seg.size
                self.stats.reserved -= seg.size
            else:
                keep.append(seg)
        self.segments = keep
        self._tick()
        return released

    # -- introspection ---------------------------------------------------------
    @property
    def reserved(self) -> int:
        return self.stats.reserved

    @property
    def allocated(self) -> int:
        return self.stats.allocated

    def fragmentation(self) -> int:
        return self.stats.reserved - self.stats.allocated

    def live_handles(self) -> int:
        return len(self._handles)
