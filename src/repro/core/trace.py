"""jaxpr liveness tracer: turns a real JAX computation into the alloc/free
event stream that drives the allocator simulator.

This is what makes the reproduction *trace-driven* rather than hard-coded
(DESIGN.md §2): the memory behaviour of each RLHF phase — and the effect of
each memory-management strategy — emerges from the actual jaxpr of our real
models:

  * sequential walk with last-use liveness (alloc outputs, free dead inputs);
  * ``scan`` bodies are inlined ``length`` times — per-iteration xs slices
    are *transient full-size* buffers while the stacked xs stay persistent,
    which is exactly the ZeRO-3 per-layer all-gather churn the paper blames
    for fragmentation;
  * ``remat``/``checkpoint`` regions recurse, so gradient checkpointing's
    liveness reduction emerges from the jaxpr, not from a model;
  * inputs are tagged (param / opt / input / cache) so strategies can scale
    persistent buffers (ZeRO sharding, CPU offload) without touching the
    event structure; internal temps whose byte size matches a parameter leaf
    are tagged ``grad`` (gradient buffers mirror parameter shapes).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.extend import core as jcore

Event = Tuple[str, int, int, str]   # (op, vid, nbytes, tag)


@dataclass
class Trace:
    events: List[Event] = field(default_factory=list)
    n_vars: int = 0

    def alloc(self, vid: int, nbytes: int, tag: str):
        self.events.append(("alloc", vid, nbytes, tag))

    def free(self, vid: int, nbytes: int, tag: str):
        self.events.append(("free", vid, nbytes, tag))

    def total_alloc_bytes(self) -> int:
        return sum(e[2] for e in self.events if e[0] == "alloc")

    def peak_live(self) -> int:
        live = peak = 0
        for op, _, b, _ in self.events:
            live += b if op == "alloc" else -b
            peak = max(peak, live)
        return peak


def _aval_bytes(aval) -> int:
    try:
        return int(np.prod(aval.shape)) * aval.dtype.itemsize
    except Exception:
        return 0


_SUBJAXPR_PRIMS = ("pjit", "closed_call", "custom_jvp_call",
                   "custom_vjp_call", "custom_vjp_call_jaxpr", "remat2",
                   "checkpoint", "core_call", "xla_call")


def _sub_jaxpr(eqn):
    p = eqn.params
    for key in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
        if key in p:
            j = p[key]
            return j.jaxpr if hasattr(j, "jaxpr") else j
    return None


class _Tracer:
    def __init__(self, trace: Trace, min_bytes: int):
        self.trace = trace
        self.ids = itertools.count(1)
        self.min_bytes = min_bytes

    def run(self, jaxpr, invar_tags: Dict, skip_alloc_outvars=frozenset(),
            param_sizes: Optional[set] = None):
        """Emit events for one execution of `jaxpr`. invar_tags maps var ->
        (vid, nbytes, tag, persistent: bool). Returns {outvar: entry}."""
        env: Dict = dict(invar_tags)
        param_sizes = param_sizes or set()

        # liveness: last use index per var
        last_use: Dict = {}
        for i, eqn in enumerate(jaxpr.eqns):
            for v in eqn.invars:
                if isinstance(v, jcore.Var):
                    last_use[v] = i
        for v in jaxpr.outvars:
            if isinstance(v, jcore.Var):
                last_use[v] = len(jaxpr.eqns) + 1

        def lookup(v):
            if isinstance(v, jcore.Literal):
                return None
            return env.get(v)

        def new_entry(v, tag="temp", persistent=False):
            nb = _aval_bytes(v.aval)
            if nb in param_sizes and tag == "temp":
                tag = "grad"
            vid = next(self.ids)
            entry = (vid, nb, tag, persistent)
            if not isinstance(v, jcore.Literal):
                env[v] = entry
            if nb >= self.min_bytes:
                self.trace.alloc(vid, nb, tag)
            return entry

        def free_entry(entry):
            vid, nb, tag, persistent = entry
            if not persistent and nb >= self.min_bytes:
                self.trace.free(vid, nb, tag)

        for i, eqn in enumerate(jaxpr.eqns):
            name = eqn.primitive.name
            sub = _sub_jaxpr(eqn) if name in _SUBJAXPR_PRIMS else None
            if name == "scan":
                self._scan(eqn, env, lookup, new_entry, free_entry,
                           param_sizes)
            elif name == "while":
                self._while(eqn, env, lookup, new_entry, free_entry,
                            param_sizes)
            elif sub is not None:
                pairs = [(inner, lookup(outer))
                         for outer, inner in zip(eqn.invars, sub.invars)]
                out_env = self._call_sub(sub, pairs, param_sizes)
                for outer, inner in zip(eqn.outvars, sub.outvars):
                    e = out_env.get(inner)
                    if e is None:
                        e = new_entry(outer)
                    else:
                        env[outer] = e
            else:
                for v in eqn.outvars:
                    if str(v) == "_" or v in skip_alloc_outvars:
                        continue
                    new_entry(v)
            # free inputs that died at this eqn
            for v in set(x for x in eqn.invars if isinstance(x, jcore.Var)):
                if last_use.get(v) == i:
                    e = env.pop(v, None)
                    if e is not None:
                        free_entry(e)

        out = {}
        for v in jaxpr.outvars:
            if isinstance(v, jcore.Var) and v in env:
                out[v] = env[v]
        # free remaining non-output temps
        outset = set(id(e) for e in out.values())
        for v, e in list(env.items()):
            if isinstance(v, jcore.Var) and id(e) not in outset and \
                    last_use.get(v, 0) <= len(jaxpr.eqns):
                pass  # already freed at last use
        return out

    def _call_sub(self, sub, pairs, param_sizes):
        """Run a sub-jaxpr with *borrowed* caller entries (the callee never
        frees them — the caller's liveness does). Returned outvar entries are
        resolved back to the caller's originals."""
        return self._call_sub_skip(sub, pairs, param_sizes, frozenset())

    def _call_sub_skip(self, sub, pairs, param_sizes, skip_outvars):
        orig_by_vid = {}
        tags = {}
        for inner, e in pairs:
            if e is None:
                continue
            orig_by_vid[e[0]] = e
            tags[inner] = (e[0], e[1], e[2], True)   # borrowed
        out_env = self.run(sub, tags, skip_alloc_outvars=skip_outvars,
                           param_sizes=param_sizes)
        return {v: orig_by_vid.get(e[0], e) for v, e in out_env.items()}

    # ------------------------------------------------------------------ scan
    def _scan(self, eqn, env, lookup, new_entry, free_entry, param_sizes):
        body = eqn.params["jaxpr"].jaxpr
        length = eqn.params["length"]
        n_consts = eqn.params["num_consts"]
        n_carry = eqn.params["num_carry"]
        consts = eqn.invars[:n_consts]
        init = eqn.invars[n_consts:n_consts + n_carry]
        xs = eqn.invars[n_consts + n_carry:]
        carry_out = eqn.outvars[:n_carry]
        ys = eqn.outvars[n_carry:]

        # ys buffers are allocated up front and written in place
        ys_entries = [new_entry(v, tag="temp") for v in ys]
        carry_entries = [lookup(v) or new_entry(v) for v in init]

        body_consts = body.invars[:n_consts]
        body_carry = body.invars[n_consts:n_consts + n_carry]
        body_xs = body.invars[n_consts + n_carry:]
        body_out_carry = body.outvars[:n_carry]
        body_out_ys = set(v for v in body.outvars[n_carry:]
                          if isinstance(v, jcore.Var))

        carry_owned = [False] * len(carry_entries)   # init carries: outer owns
        reps = min(length, MAX_SCAN_REPS)
        for it in range(reps):
            pairs = []
            for outer, inner in zip(consts, body_consts):
                pairs.append((inner, lookup(outer)))
            for e, inner in zip(carry_entries, body_carry):
                pairs.append((inner, e))
            # per-iteration xs slice: a transient buffer of the *sliced*
            # size (under ZeRO-3 this is the gathered per-layer params)
            slice_entries = []
            for inner in body_xs:
                vid = next(self.ids)
                nb = _aval_bytes(inner.aval)
                if nb >= self.min_bytes:
                    self.trace.alloc(vid, nb, "layer_slice")
                e = (vid, nb, "layer_slice", False)
                pairs.append((inner, e))
                slice_entries.append(e)
            out_env = self._call_sub_skip(body, pairs, param_sizes,
                                          body_out_ys)
            known_vids = {e[0] for _, e in pairs if e is not None}
            new_carries, new_owned = [], []
            for inner in body_out_carry:
                e = out_env.get(inner) if isinstance(inner, jcore.Var) else None
                new_carries.append(e)
                new_owned.append(e is not None and e[0] not in known_vids)
            for old, owned, new in zip(carry_entries, carry_owned, new_carries):
                if owned and old is not None and new is not None and \
                        old[0] != new[0]:
                    free_entry((old[0], old[1], old[2], False))
            carry_entries = [n if n is not None else o
                             for n, o in zip(new_carries, carry_entries)]
            carry_owned = [nw or (n is None and ow) for n, nw, ow in
                           zip(new_carries, new_owned, carry_owned)]
            for e in slice_entries:
                free_entry(e)
        for outer, e, owned in zip(carry_out, carry_entries, carry_owned):
            if e is not None:
                # outer takes ownership of scan-created carries
                env[outer] = (e[0], e[1], e[2], not owned)

    # ----------------------------------------------------------------- while
    def _while(self, eqn, env, lookup, new_entry, free_entry, param_sizes):
        body = eqn.params["body_jaxpr"].jaxpr
        n_b = eqn.params["body_nconsts"]
        n_c = eqn.params["cond_nconsts"]
        pairs = [(inner, lookup(outer))
                 for outer, inner in zip(eqn.invars[n_c + n_b:],
                                         body.invars[n_b:])]
        self._call_sub(body, pairs, param_sizes)
        for v in eqn.outvars:
            new_entry(v)


MAX_SCAN_REPS = 512


def trace_function(fn, args, arg_tags, *, min_bytes: int = 64 * 1024,
                   donate_tags: Sequence[str] = ()) -> Trace:
    """Trace `fn(*args)`. ``arg_tags`` is a pytree (matching args) of
    category strings for the persistent inputs: param | opt | input | cache.
    Returns the alloc/free event stream (inputs emitted first as persistent
    allocs, freed at the end unless persistent)."""
    closed = jax.make_jaxpr(fn)(*args)
    jaxpr = closed.jaxpr
    flat_args, _ = jax.tree_util.tree_flatten(args)
    flat_tags, _ = jax.tree_util.tree_flatten(arg_tags)
    assert len(flat_args) == len(flat_tags), (len(flat_args), len(flat_tags))

    trace = Trace()
    tr = _Tracer(trace, min_bytes)
    param_sizes = set()
    for a, t in zip(flat_args, flat_tags):
        if t == "param":
            nb = int(np.prod(a.shape)) * np.dtype(a.dtype).itemsize
            param_sizes.add(nb)
            if np.dtype(a.dtype).itemsize == 2:
                param_sizes.add(2 * nb)   # fp32 grad/update temps of the leaf

    invar_tags = {}
    for v, a, t in zip(jaxpr.invars, flat_args, flat_tags):
        nb = _aval_bytes(v.aval)
        vid = next(tr.ids)
        invar_tags[v] = (vid, nb, t, True)   # persistent: allocator-external
    tr.run(jaxpr, invar_tags, param_sizes=param_sizes)
    trace.n_vars = next(tr.ids)
    return trace
