"""Byte-level tokenizer with special tokens — a real, dependency-free
tokenizer for the runnable examples (vocab 256 bytes + specials)."""
from __future__ import annotations

from typing import List, Sequence


class ByteTokenizer:
    PAD = 256
    BOS = 257
    EOS = 258

    @property
    def vocab_size(self) -> int:
        return 259

    def encode(self, text: str, *, bos: bool = True,
               eos: bool = False) -> List[int]:
        ids = list(text.encode("utf-8"))
        if bos:
            ids = [self.BOS] + ids
        if eos:
            ids = ids + [self.EOS]
        return ids

    def decode(self, ids: Sequence[int]) -> str:
        return bytes(i for i in ids if i < 256).decode("utf-8",
                                                       errors="replace")

    def pad_to(self, ids: Sequence[int], length: int) -> List[int]:
        ids = list(ids)[:length]
        return ids + [self.PAD] * (length - len(ids))
