from repro.data.tokenizer import ByteTokenizer
from repro.data.datasets import (PromptDataset, SyntheticTextDataset,
                                 synthetic_instruction_prompts)
from repro.data.loader import Batcher

__all__ = ["ByteTokenizer", "PromptDataset", "SyntheticTextDataset",
           "synthetic_instruction_prompts", "Batcher"]
