"""Datasets for the examples and tests: synthetic instruction prompts and a
Zipf-ish synthetic LM corpus (fully offline, deterministic)."""
from __future__ import annotations

from typing import Iterator, List, Optional

import numpy as np

from repro.data.tokenizer import ByteTokenizer

_TEMPLATES = [
    "Summarize the following paragraph about {}.",
    "Write a short poem about {}.",
    "Explain {} to a five year old.",
    "List three facts about {}.",
    "Translate '{}' into French.",
    "What is the capital of {}?",
    "Give advice on how to learn {}.",
    "Describe the history of {}.",
]
_TOPICS = [
    "gradient descent", "the moon", "volcanoes", "sourdough bread",
    "distributed systems", "whales", "the Renaissance", "chess",
    "memory allocators", "reinforcement learning", "tensors", "compilers",
]


def synthetic_instruction_prompts(n: int, seed: int = 0) -> List[str]:
    rng = np.random.RandomState(seed)
    out = []
    for _ in range(n):
        t = _TEMPLATES[rng.randint(len(_TEMPLATES))]
        out.append(t.format(_TOPICS[rng.randint(len(_TOPICS))]))
    return out


class PromptDataset:
    """Tokenized, fixed-length prompt batches for RLHF rollouts."""

    def __init__(self, prompts: List[str], prompt_len: int,
                 tokenizer: Optional[ByteTokenizer] = None):
        self.tok = tokenizer or ByteTokenizer()
        self.prompt_len = prompt_len
        self._ids = np.array(
            [self.tok.pad_to(self.tok.encode(p), prompt_len)
             for p in prompts], dtype=np.int32)

    def __len__(self):
        return len(self._ids)

    def batches(self, batch_size: int, seed: int = 0,
                epochs: int = 10_000) -> Iterator[np.ndarray]:
        rng = np.random.RandomState(seed)
        for _ in range(epochs):
            perm = rng.permutation(len(self._ids))
            for i in range(0, len(perm) - batch_size + 1, batch_size):
                yield self._ids[perm[i:i + batch_size]]


class SyntheticTextDataset:
    """Markov-chain synthetic corpus: enough structure that CE loss visibly
    drops during the example training runs."""

    def __init__(self, vocab_size: int, seq_len: int, seed: int = 0,
                 branching: int = 4):
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        rng = np.random.RandomState(seed)
        self._next = rng.randint(0, vocab_size,
                                 size=(vocab_size, branching)).astype(np.int32)
        self._seed = seed

    def batches(self, batch_size: int) -> Iterator[np.ndarray]:
        rng = np.random.RandomState(self._seed + 1)
        while True:
            toks = np.empty((batch_size, self.seq_len), np.int32)
            cur = rng.randint(0, self.vocab_size, size=batch_size)
            for t in range(self.seq_len):
                toks[:, t] = cur
                branch = rng.randint(0, self._next.shape[1], size=batch_size)
                cur = self._next[cur, branch]
            yield toks
