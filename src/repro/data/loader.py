"""Device batcher: host numpy batches -> (sharded) jax arrays."""
from __future__ import annotations

from typing import Iterator, Optional

import jax
import numpy as np


class Batcher:
    def __init__(self, it: Iterator[np.ndarray],
                 sharding: Optional[jax.sharding.Sharding] = None):
        self._it = it
        self._sharding = sharding

    def __iter__(self):
        return self

    def __next__(self):
        batch = next(self._it)
        if self._sharding is not None:
            return jax.device_put(batch, self._sharding)
        return jax.device_put(batch)
