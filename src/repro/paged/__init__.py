# Paged KV-cache subsystem: vLLM-style block-paged attention for serving
# and RLHF rollout. Page bookkeeping (page_manager.py) is host-side and
# emits allocator-simulator events; device pools + scatter/gather live in
# paged_cache.py; the Pallas paged decode kernel and its pure-JAX oracle
# in attention.py.
from repro.paged.attention import (paged_attention_decode,
                                   paged_attention_reference,
                                   paged_decode_attention)
from repro.paged.page_manager import (PageManager, PageManagerStats,
                                      PagePoolExhausted)
from repro.paged.paged_cache import (append_decode, copy_pages, gather_kv,
                                     init_pool, pool_token_bytes,
                                     scatter_prefill)

__all__ = ["PageManager", "PageManagerStats", "PagePoolExhausted",
           "init_pool", "pool_token_bytes", "scatter_prefill",
           "append_decode", "gather_kv", "copy_pages",
           "paged_attention_reference", "paged_decode_attention",
           "paged_attention_decode"]
