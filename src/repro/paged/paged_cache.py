"""JAX-side paged KV store: the device arrays behind the page manager.

One pool per attention layer, laid out ``[num_pages, page_size, kv_heads,
head_dim]`` (k and v). Sequences address the pool through block tables
``[B, max_blocks]`` of page ids (-1 = unallocated); the logical token at
index ``i`` of sequence ``b`` lives at ``pool[bt[b, i // ps], i % ps]``,
so positions stay dense (0..len) and masking needs no per-slot position
array — validity is ``i <= current_position`` and ``bt >= 0``.

Three access patterns:
  * :func:`scatter_prefill` — write a prompt's ``[B, S]`` K/V into pages
    (the gather/scatter half of prefill; compute stays dense);
  * :func:`append_decode` — scatter one decode-step token per sequence;
  * :func:`gather_kv` — materialise ``[B, max_blocks*ps]`` K/V for the
    pure-JAX reference attention path (the Pallas kernel in
    ``repro.paged.attention`` indexes pages in place instead).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def init_pool(cfg: ModelConfig, num_pages: int, page_size: int,
              dtype) -> Dict[str, jax.Array]:
    """One attention layer's paged pool (k/v only — positions are dense)."""
    kvh, hd = cfg.num_kv_heads, cfg.resolved_head_dim()
    return {
        "k": jnp.zeros((num_pages, page_size, kvh, hd), dtype),
        "v": jnp.zeros((num_pages, page_size, kvh, hd), dtype),
    }


def pool_token_bytes(cfg: ModelConfig, dtype) -> int:
    """KV bytes for one token in one layer (sizing for PageManager events)."""
    kvh, hd = cfg.num_kv_heads, cfg.resolved_head_dim()
    return 2 * kvh * hd * jnp.dtype(dtype).itemsize


def _flat_targets(block_tables: jax.Array, page_size: int, S: int):
    """Page/slot coordinates of logical tokens 0..S-1 per sequence.
    block_tables [B, nb] -> (page [B,S], slot [B,S]); page is clamped to 0
    for unallocated entries (callers mask those writes)."""
    idx = jnp.arange(S, dtype=jnp.int32)
    blk = jnp.minimum(idx // page_size, block_tables.shape[1] - 1)   # [S]
    page = jnp.take_along_axis(
        block_tables, jnp.broadcast_to(blk[None], (block_tables.shape[0], S)),
        axis=1)                                          # [B, S]
    return page, jnp.broadcast_to(idx % page_size, page.shape)


def scatter_prefill(pool: Dict[str, jax.Array], k_new: jax.Array,
                    v_new: jax.Array, block_tables: jax.Array,
                    lengths: jax.Array) -> Dict[str, jax.Array]:
    """Scatter prompt K/V into the pool. k_new/v_new [B, S, kvh, hd];
    block_tables [B, nb]; lengths [B] (tokens valid per row)."""
    num_pages, ps = pool["k"].shape[:2]
    B, S = k_new.shape[:2]
    page, slot = _flat_targets(block_tables, ps, S)
    valid = (jnp.arange(S)[None, :] < lengths[:, None]) & (page >= 0)
    # invalid rows scatter out of bounds and are dropped (mode="drop") —
    # writing anything in-bounds could clobber another sequence's page
    page = jnp.where(valid, page, num_pages).reshape(-1)
    slot = slot.reshape(-1)
    flat_k = k_new.reshape(B * S, *k_new.shape[2:])
    flat_v = v_new.reshape(B * S, *v_new.shape[2:])
    return {
        "k": pool["k"].at[page, slot].set(flat_k, mode="drop"),
        "v": pool["v"].at[page, slot].set(flat_v, mode="drop"),
    }


def append_decode(pool: Dict[str, jax.Array], k_t: jax.Array, v_t: jax.Array,
                  block_tables: jax.Array,
                  position: jax.Array) -> Dict[str, jax.Array]:
    """Write one token per sequence at logical index ``position``.
    k_t/v_t [B, kvh, hd]; position [B] int32. Rows whose block table has no
    page at that index (idle slots, position -1) write back in place."""
    num_pages, ps = pool["k"].shape[:2]
    pos = jnp.maximum(position, 0).astype(jnp.int32)
    blk = jnp.minimum(pos // ps, block_tables.shape[1] - 1)
    page = jnp.take_along_axis(block_tables, blk[:, None], axis=1)[:, 0]
    slot = pos % ps
    valid = (page >= 0) & (position >= 0)
    page = jnp.where(valid, page, num_pages)     # OOB rows are dropped
    return {
        "k": pool["k"].at[page, slot].set(k_t, mode="drop"),
        "v": pool["v"].at[page, slot].set(v_t, mode="drop"),
    }


def append_decode_multi(pool: Dict[str, jax.Array], k_t: jax.Array,
                        v_t: jax.Array, block_tables: jax.Array,
                        positions: jax.Array) -> Dict[str, jax.Array]:
    """Write T tokens per sequence at logical indices ``positions``
    (speculative-decode verify). k_t/v_t [B, T, kvh, hd]; positions [B, T]
    int32. Entries with position -1 or no allocated page are dropped —
    identical masking to :func:`append_decode`, vectorised over T."""
    num_pages, ps = pool["k"].shape[:2]
    B, T = positions.shape
    pos = jnp.maximum(positions, 0).astype(jnp.int32)
    blk = jnp.minimum(pos // ps, block_tables.shape[1] - 1)       # [B, T]
    page = jnp.take_along_axis(block_tables, blk, axis=1)         # [B, T]
    slot = pos % ps
    valid = (page >= 0) & (positions >= 0)
    page = jnp.where(valid, page, num_pages).reshape(-1)          # OOB drop
    slot = slot.reshape(-1)
    flat_k = k_t.reshape(B * T, *k_t.shape[2:])
    flat_v = v_t.reshape(B * T, *v_t.shape[2:])
    return {
        "k": pool["k"].at[page, slot].set(flat_k, mode="drop"),
        "v": pool["v"].at[page, slot].set(flat_v, mode="drop"),
    }


def gather_kv(pool: Dict[str, jax.Array], block_tables: jax.Array):
    """Materialise per-sequence K/V [B, nb*ps, kvh, hd] (reference path).
    Unallocated blocks gather page 0 — callers mask by position."""
    pages = jnp.maximum(block_tables, 0)                 # [B, nb]
    k = pool["k"][pages]                                 # [B, nb, ps, kvh, hd]
    v = pool["v"][pages]
    B, nb, ps = k.shape[:3]
    return (k.reshape(B, nb * ps, *k.shape[3:]),
            v.reshape(B, nb * ps, *v.shape[3:]))


def copy_pages(pool: Dict[str, jax.Array], src, dst) -> Dict[str, jax.Array]:
    """Copy-on-write page copies. src/dst: int sequences of page ids."""
    src = jnp.asarray(src, jnp.int32)
    dst = jnp.asarray(dst, jnp.int32)
    return {
        "k": pool["k"].at[dst].set(pool["k"][src]),
        "v": pool["v"].at[dst].set(pool["v"][src]),
    }
