"""Free-list page allocator for the paged KV cache (vLLM-style).

A global pool of ``num_pages`` fixed-size pages; each sequence owns an
ordered *block table* of page ids covering its logical token range
``[0, seq_len)``. Pages are ref-counted so a forked sequence (GRPO groups,
shared system prompts) shares its parent's prompt pages copy-on-write:
full shared pages stay shared forever (they are append-only), and only a
*partial* last page is copied when a writer appends into it.

Accounting speaks the same event vocabulary as ``repro.core.trace`` /
``repro.core.allocator`` — ``(op, vid, nbytes, tag)`` tuples with op in
{"alloc", "free"} — so a paged serving run can be replayed through the
paper's :class:`~repro.core.allocator.CachingAllocator` and compared
against the dense ``[B, capacity]`` layout on reserved bytes and
fragmentation. Internal fragmentation of the paged layout is bounded by
construction: at most one partially-filled page per live sequence.

Cross-request prefix caching (the vLLM block-reuse idiom) extends the
same pool: every *full* page of a committed prompt is indexed by a hash
chain over its token ids (``digest_i = sha256(digest_{i-1} ||
tokens_page_i)``), so a later request whose prompt shares the prefix
takes a ref-count bump on the cached pages instead of re-prefilling
them. Pages whose refcount drops to zero while indexed are *parked* in
an LRU list — still resident in the pool, evicted lazily only when a
fresh allocation finds the free list empty. A weight-version bump
(RLHF updates params between rollouts) invalidates the whole index so
stale KV is never served across a weight update.
"""
from __future__ import annotations

import hashlib
import itertools
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

Event = Tuple[str, int, int, str]   # (op, vid, nbytes, tag) — trace.Event

PAGE_TAG = "kv_page"


class PagePoolExhausted(Exception):
    """Raised when an allocation cannot be served; callers preempt."""


@dataclass
class PageManagerStats:
    num_pages: int
    page_size: int
    pages_in_use: int = 0
    peak_pages_in_use: int = 0
    n_page_alloc: int = 0
    n_page_free: int = 0
    n_cow_copies: int = 0
    n_forks: int = 0
    n_prefix_hits: int = 0        # pages served from the prefix index
    n_prefix_queries: int = 0     # allocate_prefix calls
    n_prefix_evictions: int = 0   # parked pages reclaimed under pressure
    n_prefix_invalidations: int = 0


@dataclass
class _Seq:
    pages: List[int] = field(default_factory=list)
    length: int = 0            # logical tokens written


class PageManager:
    """Block allocator over a fixed page pool with per-sequence tables.

    ``bytes_per_token`` (KV bytes for one token across all layers) sizes
    the alloc/free events; with the default 0 the events are still emitted
    with ``nbytes = page_size`` so replay remains meaningful in "slot"
    units.
    """

    def __init__(self, num_pages: int, page_size: int, *,
                 bytes_per_token: int = 0):
        assert num_pages > 0 and page_size > 0
        self.num_pages = num_pages
        self.page_size = page_size
        self.bytes_per_token = bytes_per_token
        self._free: List[int] = list(range(num_pages - 1, -1, -1))  # LIFO
        self._refcount: List[int] = [0] * num_pages
        self._seqs: Dict[int, _Seq] = {}
        self._vids = itertools.count(1)
        self._page_vid: List[int] = [0] * num_pages   # vid of live page
        self.events: List[Event] = []
        self.stats = PageManagerStats(num_pages, page_size)
        # -- prefix cache state --
        # digest -> page holding that (chain-hashed) full page of prompt KV
        self._cached: Dict[bytes, int] = {}
        # per-page digest when indexed (inverse of _cached), else None
        self._page_hash: List[Optional[bytes]] = [None] * num_pages
        # zero-ref indexed pages, oldest-parked first (evictable)
        self._lru: "OrderedDict[int, None]" = OrderedDict()
        self.weight_version = 0

    # -- low-level page ops --------------------------------------------------
    @property
    def page_bytes(self) -> int:
        return (self.bytes_per_token or 1) * self.page_size

    @property
    def num_free_pages(self) -> int:
        return len(self._free)

    @property
    def num_cached_pages(self) -> int:
        """Zero-ref pages parked in the prefix-cache LRU (evictable)."""
        return len(self._lru)

    def cached_bytes(self) -> int:
        return len(self._lru) * self.page_bytes

    def _deindex(self, p: int):
        h = self._page_hash[p]
        if h is not None:
            self._page_hash[p] = None
            if self._cached.get(h) == p:
                del self._cached[h]
        self._lru.pop(p, None)

    def _release_parked(self, p: int):
        """Truly free a parked page: drop its index entry and emit the
        deferred free event, returning the page to the free list."""
        assert self._refcount[p] == 0
        self._deindex(p)
        self.events.append(("free", self._page_vid[p], self.page_bytes,
                            PAGE_TAG))
        self.stats.n_page_free += 1
        self._free.append(p)
        self.stats.pages_in_use = self.num_pages - len(self._free)

    def _evict_one(self) -> int:
        """LRU eviction under pool pressure: reclaim the oldest parked
        (zero-ref, indexed) page."""
        p, _ = self._lru.popitem(last=False)
        self._release_parked(p)
        self.stats.n_prefix_evictions += 1
        return p

    def _grab_page(self) -> int:
        if not self._free and self._lru:
            self._evict_one()               # pool pressure: LRU eviction
        if not self._free:
            raise PagePoolExhausted(
                f"page pool exhausted ({self.num_pages} pages of "
                f"{self.page_size} tokens)")
        p = self._free.pop()
        assert self._refcount[p] == 0
        self._refcount[p] = 1
        vid = next(self._vids)
        self._page_vid[p] = vid
        self.events.append(("alloc", vid, self.page_bytes, PAGE_TAG))
        self.stats.n_page_alloc += 1
        self.stats.pages_in_use = self.num_pages - len(self._free)
        self.stats.peak_pages_in_use = max(self.stats.peak_pages_in_use,
                                           self.stats.pages_in_use)
        return p

    def _drop_ref(self, p: int):
        assert self._refcount[p] > 0, f"double free of page {p}"
        self._refcount[p] -= 1
        if self._refcount[p] == 0:
            if self._page_hash[p] is not None:
                # indexed page: park in the LRU instead of freeing — its KV
                # stays resident and a later prefix match revives it. The
                # free event is deferred until eviction/invalidation.
                self._lru[p] = None
                return
            self.events.append(("free", self._page_vid[p], self.page_bytes,
                                PAGE_TAG))
            self.stats.n_page_free += 1
            self._free.append(p)
            self.stats.pages_in_use = self.num_pages - len(self._free)

    # -- sequence API --------------------------------------------------------
    def pages_needed(self, num_tokens: int) -> int:
        return -(-num_tokens // self.page_size)

    def _allocatable(self) -> int:
        """Pages a fresh allocation can claim: free + evictable (parked)."""
        return len(self._free) + len(self._lru)

    def can_allocate(self, num_tokens: int) -> bool:
        return self.pages_needed(num_tokens) <= self._allocatable()

    def allocate(self, seq_id: int, num_tokens: int) -> List[int]:
        """Claim pages covering ``num_tokens`` logical tokens for a new
        sequence. Atomic: on exhaustion nothing is allocated."""
        assert seq_id not in self._seqs, f"seq {seq_id} already allocated"
        need = self.pages_needed(num_tokens)
        if need > self._allocatable():
            raise PagePoolExhausted(
                f"need {need} pages, {self._allocatable()} allocatable")
        seq = _Seq([self._grab_page() for _ in range(need)], num_tokens)
        self._seqs[seq_id] = seq
        return list(seq.pages)

    def fork(self, parent_id: int, child_id: int) -> List[int]:
        """Child shares every parent page (copy-on-write prompt prefix)."""
        assert child_id not in self._seqs
        parent = self._seqs[parent_id]
        for p in parent.pages:
            self._refcount[p] += 1
        self._seqs[child_id] = _Seq(list(parent.pages), parent.length)
        self.stats.n_forks += 1
        return list(parent.pages)

    def append_token(self, seq_id: int) -> List[Tuple[int, int]]:
        """Extend a sequence by one logical token. Returns a list of
        ``(src_page, dst_page)`` device copies the caller must perform:
        a CoW copy when the written page was shared, else nothing (a fresh
        page needs no copy). Atomic on exhaustion."""
        seq = self._seqs[seq_id]
        copies: List[Tuple[int, int]] = []
        if seq.length % self.page_size == 0:
            seq.pages.append(self._grab_page())
        else:
            last = seq.pages[-1]
            if self._refcount[last] > 1:
                fresh = self._grab_page()          # may raise; state intact
                copies.append((last, fresh))
                self._drop_ref(last)
                seq.pages[-1] = fresh
                self.stats.n_cow_copies += 1
            elif self._page_hash[last] is not None:
                # sole owner about to mutate an indexed page (truncated
                # below full, now re-appending): the stored digest no
                # longer describes the content — drop the index entry.
                self._deindex(last)
        seq.length += 1
        return copies

    def append_tokens(self, seq_id: int, n: int) -> List[Tuple[int, int]]:
        """Extend a sequence by ``n`` logical tokens (speculative decode
        grows each slot by ``k+1`` before the verify forward). Atomic: the
        total page need — growth pages plus at most one CoW copy of a
        shared partial last page — is checked up front, so on exhaustion
        nothing is allocated. Returns the concatenated CoW copies."""
        seq = self._seqs[seq_id]
        need = self.pages_needed(seq.length + n) - len(seq.pages)
        if seq.length % self.page_size != 0 and \
                self._refcount[seq.pages[-1]] > 1:
            need += 1                      # CoW copy of the shared last page
        if need > self._allocatable():
            raise PagePoolExhausted(
                f"need {need} pages, {self._allocatable()} allocatable")
        copies: List[Tuple[int, int]] = []
        for _ in range(n):
            copies.extend(self.append_token(seq_id))
        return copies

    def truncate(self, seq_id: int, length: int) -> None:
        """Shrink a sequence's logical length (drop rejected draft tokens
        after the accept step). Whole pages past the new length are freed
        (ref-dropped — a forked sibling may keep them alive); stale tokens
        in the kept partial last page are masked by position and
        overwritten by future appends (CoW fires then if it is shared)."""
        seq = self._seqs[seq_id]
        assert 0 <= length <= seq.length, (length, seq.length)
        keep = self.pages_needed(length)
        for p in seq.pages[keep:]:
            self._drop_ref(p)
        del seq.pages[keep:]
        seq.length = length

    def free_seq(self, seq_id: int):
        seq = self._seqs.pop(seq_id)
        for p in seq.pages:
            self._drop_ref(p)

    def seq_len(self, seq_id: int) -> int:
        return self._seqs[seq_id].length

    def block_table(self, seq_id: int) -> List[int]:
        return list(self._seqs[seq_id].pages)

    def block_table_array(self, seq_ids: Sequence[Optional[int]],
                          max_blocks: int):
        """Padded ``[len(seq_ids), max_blocks]`` int32 table; -1 = no page.
        ``None`` entries (idle slots) yield all -1 rows."""
        import numpy as np
        bt = np.full((len(seq_ids), max_blocks), -1, np.int32)
        for i, sid in enumerate(seq_ids):
            if sid is None or sid not in self._seqs:
                continue
            pages = self._seqs[sid].pages
            assert len(pages) <= max_blocks, (len(pages), max_blocks)
            bt[i, :len(pages)] = pages
        return bt

    # -- prefix cache --------------------------------------------------------
    @staticmethod
    def _chain(prev: bytes, page_tokens) -> bytes:
        """One link of the page hash chain: the digest commits to the full
        token history up to and including this page, so a digest match
        implies the whole prefix matches."""
        import numpy as np
        buf = np.ascontiguousarray(np.asarray(page_tokens, np.int64))
        return hashlib.sha256(prev + buf.tobytes()).digest()

    def hashable_prefix_tokens(self, num_tokens: int) -> int:
        """Longest prefix eligible for cache reuse: whole pages only, and
        strictly shorter than the prompt — the final prompt token is always
        recomputed because its logits seed decoding."""
        return self.page_size * ((num_tokens - 1) // self.page_size)

    def match_prefix(self, tokens: Sequence[int]) -> Tuple[List[int], int]:
        """Longest indexed prefix of ``tokens``. Returns ``(pages,
        n_cached_tokens)``; takes no references (read-only probe)."""
        limit = self.hashable_prefix_tokens(len(tokens))
        pages: List[int] = []
        h = b""
        for i in range(0, limit, self.page_size):
            h = self._chain(h, tokens[i:i + self.page_size])
            p = self._cached.get(h)
            if p is None:
                break
            pages.append(p)
        return pages, len(pages) * self.page_size

    def can_allocate_prefix(self, tokens: Sequence[int],
                            extra_tokens: int = 0) -> bool:
        """Admission gate for :meth:`allocate_prefix`: would a sequence of
        ``len(tokens) + extra_tokens`` fit, given the prefix pages a match
        would reuse?"""
        cached, _ = self.match_prefix(tokens)
        need = self.pages_needed(len(tokens) + extra_tokens) - len(cached)
        parked = sum(1 for p in cached if self._refcount[p] == 0)
        return need <= self._allocatable() - parked

    def allocate_prefix(self, seq_id: int,
                        tokens: Sequence[int]) -> Tuple[List[int], int]:
        """Like :meth:`allocate` but reuses indexed pages covering the
        longest cached prefix of ``tokens``: matched pages take a refcount
        bump (parked ones are revived from the LRU) and only the suffix
        grabs fresh pages. Atomic on exhaustion. Returns ``(block_table,
        n_cached_tokens)`` — the caller prefills only ``tokens[n_cached:]``.
        """
        assert seq_id not in self._seqs, f"seq {seq_id} already allocated"
        cached, n_cached = self.match_prefix(tokens)
        need = self.pages_needed(len(tokens)) - len(cached)
        # matched parked pages are about to be revived — they no longer
        # count toward the evictable headroom fresh grabs can draw from
        parked = sum(1 for p in cached if self._refcount[p] == 0)
        if need > self._allocatable() - parked:
            raise PagePoolExhausted(
                f"need {need} pages, "
                f"{self._allocatable() - parked} allocatable")
        for p in cached:
            if self._refcount[p] == 0:
                self._lru.pop(p, None)      # revive before grabbing fresh
            self._refcount[p] += 1
        pages = cached + [self._grab_page() for _ in range(need)]
        self._seqs[seq_id] = _Seq(pages, len(tokens))
        self.stats.n_prefix_queries += 1
        self.stats.n_prefix_hits += len(cached)
        return list(pages), n_cached

    def commit_prefix(self, seq_id: int, tokens: Sequence[int]) -> int:
        """Index every full page of a freshly prefilled prompt so later
        requests can reuse it. Full pages are append-only (mutation goes
        through CoW or :meth:`_deindex`), so the digest stays truthful for
        the page's lifetime. Returns the number of pages newly indexed."""
        seq = self._seqs[seq_id]
        n_full = min(len(tokens), seq.length) // self.page_size
        h = b""
        added = 0
        for i in range(n_full):
            h = self._chain(h, tokens[i * self.page_size:
                                      (i + 1) * self.page_size])
            p = seq.pages[i]
            if self._page_hash[p] is not None or h in self._cached:
                continue        # already indexed (ours or a twin's page)
            self._cached[h] = p
            self._page_hash[p] = h
            added += 1
        return added

    def invalidate_prefix_cache(self):
        """Drop the entire index. Parked pages are truly freed; live
        (ref > 0) pages just lose their index entries — in-flight
        sequences keep their KV, which the batcher guarantees was
        produced under the current weights (invalidation happens *at*
        the weight swap, before any new admission)."""
        while self._lru:
            p, _ = self._lru.popitem(last=False)
            self._release_parked(p)
        self._cached.clear()
        self._page_hash = [None] * self.num_pages
        self.stats.n_prefix_invalidations += 1

    def set_weight_version(self, version: int):
        """Serve-side hook for RLHF weight updates: a version bump
        invalidates every cached prefix so stale KV is never matched."""
        if version != self.weight_version:
            self.weight_version = version
            self.invalidate_prefix_cache()

    def reclaimable_pages(self, seq_id: int) -> int:
        """Pages only this sequence references (refcount == 1) — what
        preempting it would actually return to the pool; shared prefix
        pages survive the victim."""
        return sum(1 for p in self._seqs[seq_id].pages
                   if self._refcount[p] == 1)

    # -- accounting ----------------------------------------------------------
    def used_token_slots(self) -> int:
        """Token slots actually holding KV (shared pages counted once)."""
        counted = set()
        total = 0
        for seq in self._seqs.values():
            for i, p in enumerate(seq.pages):
                if p in counted:
                    continue
                counted.add(p)
                full = (i + 1) * self.page_size <= seq.length
                total += self.page_size if full else \
                    seq.length - i * self.page_size
        return total

    def reserved_token_slots(self) -> int:
        return self.stats.pages_in_use * self.page_size

    def fragmentation_slots(self) -> int:
        """Internal fragmentation: reserved minus used token slots. Bounded
        by ``page_size - 1`` per live sequence. Parked prefix-cache pages
        are full of reusable KV, not waste — excluded."""
        return self.reserved_token_slots() - self.used_token_slots() \
            - len(self._lru) * self.page_size

    def reserved_bytes(self) -> int:
        return self.stats.pages_in_use * self.page_bytes

    def check_invariants(self):
        """Pool conservation + refcount sanity (used by property tests)."""
        assert len(self._free) + self.stats.pages_in_use == self.num_pages
        assert all(r >= 0 for r in self._refcount)
        held: Dict[int, int] = {}
        for seq in self._seqs.values():
            for p in seq.pages:
                held[p] = held.get(p, 0) + 1
        free = set(self._free)
        parked = set(self._lru)
        assert not free & parked
        for p, r in enumerate(self._refcount):
            assert held.get(p, 0) == r, (p, held.get(p, 0), r)
            # zero-ref pages are either free or parked in the prefix LRU
            assert (r == 0) == (p in free or p in parked)
        for p in parked:
            assert self._page_hash[p] is not None
        for h, p in self._cached.items():
            assert self._page_hash[p] == h

    def replay_into(self, allocator=None):
        """Replay the page event stream through the paper's caching-
        allocator simulator; returns the allocator for stats inspection."""
        if allocator is None:
            from repro.core.allocator import CachingAllocator
            allocator = CachingAllocator()
        handles: Dict[int, int] = {}
        for op, vid, nbytes, _tag in self.events:
            if op == "alloc":
                handles[vid] = allocator.malloc(nbytes)
            else:
                allocator.free(handles.pop(vid))
        return allocator
