"""Free-list page allocator for the paged KV cache (vLLM-style).

A global pool of ``num_pages`` fixed-size pages; each sequence owns an
ordered *block table* of page ids covering its logical token range
``[0, seq_len)``. Pages are ref-counted so a forked sequence (GRPO groups,
shared system prompts) shares its parent's prompt pages copy-on-write:
full shared pages stay shared forever (they are append-only), and only a
*partial* last page is copied when a writer appends into it.

Accounting speaks the same event vocabulary as ``repro.core.trace`` /
``repro.core.allocator`` — ``(op, vid, nbytes, tag)`` tuples with op in
{"alloc", "free"} — so a paged serving run can be replayed through the
paper's :class:`~repro.core.allocator.CachingAllocator` and compared
against the dense ``[B, capacity]`` layout on reserved bytes and
fragmentation. Internal fragmentation of the paged layout is bounded by
construction: at most one partially-filled page per live sequence.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

Event = Tuple[str, int, int, str]   # (op, vid, nbytes, tag) — trace.Event

PAGE_TAG = "kv_page"


class PagePoolExhausted(Exception):
    """Raised when an allocation cannot be served; callers preempt."""


@dataclass
class PageManagerStats:
    num_pages: int
    page_size: int
    pages_in_use: int = 0
    peak_pages_in_use: int = 0
    n_page_alloc: int = 0
    n_page_free: int = 0
    n_cow_copies: int = 0
    n_forks: int = 0


@dataclass
class _Seq:
    pages: List[int] = field(default_factory=list)
    length: int = 0            # logical tokens written


class PageManager:
    """Block allocator over a fixed page pool with per-sequence tables.

    ``bytes_per_token`` (KV bytes for one token across all layers) sizes
    the alloc/free events; with the default 0 the events are still emitted
    with ``nbytes = page_size`` so replay remains meaningful in "slot"
    units.
    """

    def __init__(self, num_pages: int, page_size: int, *,
                 bytes_per_token: int = 0):
        assert num_pages > 0 and page_size > 0
        self.num_pages = num_pages
        self.page_size = page_size
        self.bytes_per_token = bytes_per_token
        self._free: List[int] = list(range(num_pages - 1, -1, -1))  # LIFO
        self._refcount: List[int] = [0] * num_pages
        self._seqs: Dict[int, _Seq] = {}
        self._vids = itertools.count(1)
        self._page_vid: List[int] = [0] * num_pages   # vid of live page
        self.events: List[Event] = []
        self.stats = PageManagerStats(num_pages, page_size)

    # -- low-level page ops --------------------------------------------------
    @property
    def page_bytes(self) -> int:
        return (self.bytes_per_token or 1) * self.page_size

    @property
    def num_free_pages(self) -> int:
        return len(self._free)

    def _grab_page(self) -> int:
        if not self._free:
            raise PagePoolExhausted(
                f"page pool exhausted ({self.num_pages} pages of "
                f"{self.page_size} tokens)")
        p = self._free.pop()
        assert self._refcount[p] == 0
        self._refcount[p] = 1
        vid = next(self._vids)
        self._page_vid[p] = vid
        self.events.append(("alloc", vid, self.page_bytes, PAGE_TAG))
        self.stats.n_page_alloc += 1
        self.stats.pages_in_use = self.num_pages - len(self._free)
        self.stats.peak_pages_in_use = max(self.stats.peak_pages_in_use,
                                           self.stats.pages_in_use)
        return p

    def _drop_ref(self, p: int):
        assert self._refcount[p] > 0, f"double free of page {p}"
        self._refcount[p] -= 1
        if self._refcount[p] == 0:
            self.events.append(("free", self._page_vid[p], self.page_bytes,
                                PAGE_TAG))
            self.stats.n_page_free += 1
            self._free.append(p)
            self.stats.pages_in_use = self.num_pages - len(self._free)

    # -- sequence API --------------------------------------------------------
    def pages_needed(self, num_tokens: int) -> int:
        return -(-num_tokens // self.page_size)

    def can_allocate(self, num_tokens: int) -> bool:
        return self.pages_needed(num_tokens) <= len(self._free)

    def allocate(self, seq_id: int, num_tokens: int) -> List[int]:
        """Claim pages covering ``num_tokens`` logical tokens for a new
        sequence. Atomic: on exhaustion nothing is allocated."""
        assert seq_id not in self._seqs, f"seq {seq_id} already allocated"
        need = self.pages_needed(num_tokens)
        if need > len(self._free):
            raise PagePoolExhausted(
                f"need {need} pages, {len(self._free)} free")
        seq = _Seq([self._grab_page() for _ in range(need)], num_tokens)
        self._seqs[seq_id] = seq
        return list(seq.pages)

    def fork(self, parent_id: int, child_id: int) -> List[int]:
        """Child shares every parent page (copy-on-write prompt prefix)."""
        assert child_id not in self._seqs
        parent = self._seqs[parent_id]
        for p in parent.pages:
            self._refcount[p] += 1
        self._seqs[child_id] = _Seq(list(parent.pages), parent.length)
        self.stats.n_forks += 1
        return list(parent.pages)

    def append_token(self, seq_id: int) -> List[Tuple[int, int]]:
        """Extend a sequence by one logical token. Returns a list of
        ``(src_page, dst_page)`` device copies the caller must perform:
        a CoW copy when the written page was shared, else nothing (a fresh
        page needs no copy). Atomic on exhaustion."""
        seq = self._seqs[seq_id]
        copies: List[Tuple[int, int]] = []
        if seq.length % self.page_size == 0:
            seq.pages.append(self._grab_page())
        else:
            last = seq.pages[-1]
            if self._refcount[last] > 1:
                fresh = self._grab_page()          # may raise; state intact
                copies.append((last, fresh))
                self._drop_ref(last)
                seq.pages[-1] = fresh
                self.stats.n_cow_copies += 1
        seq.length += 1
        return copies

    def append_tokens(self, seq_id: int, n: int) -> List[Tuple[int, int]]:
        """Extend a sequence by ``n`` logical tokens (speculative decode
        grows each slot by ``k+1`` before the verify forward). Atomic: the
        total page need — growth pages plus at most one CoW copy of a
        shared partial last page — is checked up front, so on exhaustion
        nothing is allocated. Returns the concatenated CoW copies."""
        seq = self._seqs[seq_id]
        need = self.pages_needed(seq.length + n) - len(seq.pages)
        if seq.length % self.page_size != 0 and \
                self._refcount[seq.pages[-1]] > 1:
            need += 1                      # CoW copy of the shared last page
        if need > len(self._free):
            raise PagePoolExhausted(
                f"need {need} pages, {len(self._free)} free")
        copies: List[Tuple[int, int]] = []
        for _ in range(n):
            copies.extend(self.append_token(seq_id))
        return copies

    def truncate(self, seq_id: int, length: int) -> None:
        """Shrink a sequence's logical length (drop rejected draft tokens
        after the accept step). Whole pages past the new length are freed
        (ref-dropped — a forked sibling may keep them alive); stale tokens
        in the kept partial last page are masked by position and
        overwritten by future appends (CoW fires then if it is shared)."""
        seq = self._seqs[seq_id]
        assert 0 <= length <= seq.length, (length, seq.length)
        keep = self.pages_needed(length)
        for p in seq.pages[keep:]:
            self._drop_ref(p)
        del seq.pages[keep:]
        seq.length = length

    def free_seq(self, seq_id: int):
        seq = self._seqs.pop(seq_id)
        for p in seq.pages:
            self._drop_ref(p)

    def seq_len(self, seq_id: int) -> int:
        return self._seqs[seq_id].length

    def block_table(self, seq_id: int) -> List[int]:
        return list(self._seqs[seq_id].pages)

    def block_table_array(self, seq_ids: Sequence[Optional[int]],
                          max_blocks: int):
        """Padded ``[len(seq_ids), max_blocks]`` int32 table; -1 = no page.
        ``None`` entries (idle slots) yield all -1 rows."""
        import numpy as np
        bt = np.full((len(seq_ids), max_blocks), -1, np.int32)
        for i, sid in enumerate(seq_ids):
            if sid is None or sid not in self._seqs:
                continue
            pages = self._seqs[sid].pages
            assert len(pages) <= max_blocks, (len(pages), max_blocks)
            bt[i, :len(pages)] = pages
        return bt

    # -- accounting ----------------------------------------------------------
    def used_token_slots(self) -> int:
        """Token slots actually holding KV (shared pages counted once)."""
        counted = set()
        total = 0
        for seq in self._seqs.values():
            for i, p in enumerate(seq.pages):
                if p in counted:
                    continue
                counted.add(p)
                full = (i + 1) * self.page_size <= seq.length
                total += self.page_size if full else \
                    seq.length - i * self.page_size
        return total

    def reserved_token_slots(self) -> int:
        return self.stats.pages_in_use * self.page_size

    def fragmentation_slots(self) -> int:
        """Internal fragmentation: reserved minus used token slots. Bounded
        by ``page_size - 1`` per live sequence."""
        return self.reserved_token_slots() - self.used_token_slots()

    def reserved_bytes(self) -> int:
        return self.stats.pages_in_use * self.page_bytes

    def check_invariants(self):
        """Pool conservation + refcount sanity (used by property tests)."""
        assert len(self._free) + self.stats.pages_in_use == self.num_pages
        assert all(r >= 0 for r in self._refcount)
        held: Dict[int, int] = {}
        for seq in self._seqs.values():
            for p in seq.pages:
                held[p] = held.get(p, 0) + 1
        free = set(self._free)
        for p, r in enumerate(self._refcount):
            assert held.get(p, 0) == r, (p, held.get(p, 0), r)
            assert (r == 0) == (p in free)

    def replay_into(self, allocator=None):
        """Replay the page event stream through the paper's caching-
        allocator simulator; returns the allocator for stats inspection."""
        if allocator is None:
            from repro.core.allocator import CachingAllocator
            allocator = CachingAllocator()
        handles: Dict[int, int] = {}
        for op, vid, nbytes, _tag in self.events:
            if op == "alloc":
                handles[vid] = allocator.malloc(nbytes)
            else:
                allocator.free(handles.pop(vid))
        return allocator
