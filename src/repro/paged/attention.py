"""Paged decode attention — block-table-indexed flash decode over the pool.

The Pallas kernel walks each sequence's block table as the innermost
sequential grid axis: program ``(b, k, j)`` attends query heads of KV group
``k`` of sequence ``b`` against page ``bt[b, j]`` of the pool, carrying the
online-softmax ``(acc, m, l)`` across pages in VMEM scratch. The block
table and current positions ride in as scalar prefetch so the page id is
known *before* the block's DMA is issued — the K/V BlockSpec index map
reads ``bt_ref`` directly, which is what makes the gather free: pages are
streamed HBM->VMEM exactly once each, no materialised ``[B, S]`` view.

Masking is positional: logical token ``j*ps + i`` is valid iff it is
``<= position[b]`` and the block is allocated (``bt >= 0``); unallocated
blocks alias page 0 and mask to -inf, so ragged block tables need no host
padding logic. The pure-JAX :func:`paged_attention_reference` (gather +
masked softmax) is the oracle for tests and the CPU fallback.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

from repro.paged.paged_cache import gather_kv

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Pure-JAX reference (oracle / CPU path)
# ---------------------------------------------------------------------------
def paged_attention_reference(q, pool, block_tables, position):
    """q [B,H,D]; pool {"k","v": [P,ps,K,D]}; block_tables [B,nb] int32
    (-1 = unallocated); position [B] (last valid logical index) ->
    [B,H,Dv]. fp32 softmax, GQA grouping identical to layers.sdpa."""
    B, H, D = q.shape
    ps = pool["k"].shape[1]
    K = pool["k"].shape[2]
    G = H // K
    k, v = gather_kv(pool, block_tables)                 # [B, nb*ps, K, D]
    S = k.shape[1]
    idx = jnp.arange(S, dtype=jnp.int32)
    allocated = jnp.repeat(block_tables >= 0, ps, axis=1)    # [B, nb*ps]
    valid = allocated & (idx[None, :] <= position[:, None])
    qg = q.reshape(B, K, G, D).astype(jnp.float32)
    s = jnp.einsum("bkgd,bskd->bkgs", qg,
                   k.astype(jnp.float32)) / math.sqrt(D)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v.astype(jnp.float32))
    return out.reshape(B, H, -1).astype(q.dtype)


def paged_attention_reference_multi(q, pool, block_tables, positions):
    """Multi-query twin of :func:`paged_attention_reference` for the
    speculative-decode verify step. q [B,T,H,D]; positions [B,T] (per-query
    last valid logical index; -1 queries see nothing and produce garbage the
    caller masks) -> [B,T,H,Dv]. Token j of a draft run IS context for
    token j+1 because validity is per-query ``idx <= positions[:, j]``."""
    B, T, H, D = q.shape
    ps = pool["k"].shape[1]
    K = pool["k"].shape[2]
    G = H // K
    k, v = gather_kv(pool, block_tables)                 # [B, S, K, D]
    S = k.shape[1]
    idx = jnp.arange(S, dtype=jnp.int32)
    allocated = jnp.repeat(block_tables >= 0, ps, axis=1)        # [B, S]
    valid = allocated[:, None, :] \
        & (idx[None, None, :] <= positions[:, :, None])          # [B, T, S]
    qg = q.reshape(B, T, K, G, D).astype(jnp.float32)
    s = jnp.einsum("btkgd,bskd->btkgs", qg,
                   k.astype(jnp.float32)) / math.sqrt(D)
    s = jnp.where(valid[:, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("btkgs,bskd->btkgd", p, v.astype(jnp.float32))
    return out.reshape(B, T, H, -1).astype(q.dtype)


# ---------------------------------------------------------------------------
# Pallas kernel
# ---------------------------------------------------------------------------
def _paged_decode_kernel(bt_ref, pos_ref, q_ref, k_ref, v_ref, o_ref,
                         acc_ref, m_ref, l_ref, *, page_size: int,
                         scale: float):
    b = pl.program_id(0)
    j = pl.program_id(2)
    nb = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # [G, D]
    k = k_ref[0, 0].astype(jnp.float32)                  # [ps, D]
    v = v_ref[0, 0].astype(jnp.float32)                  # [ps, Dv]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [G, ps]

    cur = pos_ref[b]
    idx = j * page_size + jax.lax.broadcasted_iota(jnp.int32, (page_size,), 0)
    valid = (idx <= cur) & (bt_ref[b, j] >= 0)
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev = m_ref[...]
    m_cur = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_cur[:, None])
    alpha = jnp.exp(m_prev - m_cur)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))
    m_ref[...] = m_cur

    @pl.when(j == nb - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention(q, k_pool, v_pool, block_tables, position, *,
                           interpret: bool = True):
    """q [B,H,D]; pools [P,ps,K,D]; block_tables [B,nb]; position [B] ->
    [B,H,Dv]. One flash pass per (sequence, kv head) over that sequence's
    pages."""
    B, H, D = q.shape
    P, ps, K, _ = k_pool.shape
    Dv = v_pool.shape[-1]
    G = H // K
    nb = block_tables.shape[1]

    bt = jnp.asarray(block_tables, jnp.int32)
    pos = jnp.asarray(position, jnp.int32)
    qh = q.reshape(B, K, G, D)
    kh = k_pool.transpose(0, 2, 1, 3)            # [P, K, ps, D]
    vh = v_pool.transpose(0, 2, 1, 3)

    def page_of(b, j, bt_ref):
        # -1 (unallocated) aliases page 0; the kernel masks it to -inf
        return jnp.maximum(bt_ref[b, j], 0)

    kernel = functools.partial(_paged_decode_kernel, page_size=ps,
                               scale=1.0 / math.sqrt(D))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                   # block table, positions
        grid=(B, K, nb),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, k, j, bt, pos:
                         (b, k, 0, 0)),
            pl.BlockSpec((1, 1, ps, D), lambda b, k, j, bt, pos:
                         (page_of(b, j, bt), k, 0, 0)),
            pl.BlockSpec((1, 1, ps, Dv), lambda b, k, j, bt, pos:
                         (page_of(b, j, bt), k, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, Dv), lambda b, k, j, bt, pos:
                               (b, k, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, Dv), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, G, Dv), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(bt, pos, qh, kh, vh)
    return out.reshape(B, H, Dv)


# ---------------------------------------------------------------------------
# Layer-level decode (the paged twin of layers.attention_decode)
# ---------------------------------------------------------------------------
def paged_attention_decode(params, x, position, pool, block_tables, cfg, *,
                           use_kernel: bool = False, adapter=None):
    """One-token decode against a paged pool. x [B,1,D]; position [B]
    absolute (== logical index; paged sequences are densely 0-indexed).
    Appends this step's K/V to the pool, attends over the block table.
    ``adapter``: optional LoRA site dict (unmerged A·B on the projections).
    Returns (out [B,1,D], new_pool)."""
    from repro.models import layers as L
    from repro.models.lora import lora_delta
    from repro.paged.paged_cache import append_decode

    B = x.shape[0]
    q, k, v = L._project_qkv(params, x, cfg, adapter=adapter)
    sin, cos = L.rope_tables(position[:, None], cfg.resolved_head_dim(),
                             cfg.rope_theta)
    q = L.apply_rope(q, sin, cos)
    k = L.apply_rope(k, sin, cos)
    pool = append_decode(pool, k[:, 0], v[:, 0], block_tables, position)
    if use_kernel:
        import jax as _jax
        out = paged_decode_attention(
            q[:, 0], pool["k"], pool["v"], block_tables, position,
            interpret=_jax.default_backend() != "tpu")
    else:
        out = paged_attention_reference(q[:, 0], pool, block_tables, position)
    out = out.reshape(B, 1, -1)
    out = out @ params["wo"] + lora_delta(out, (adapter or {}).get("wo"))
    return out, pool


def paged_attention_decode_multi(params, x, positions, pool, block_tables,
                                 cfg, *, adapter=None):
    """T-token decode against a paged pool (speculative-decode verify).
    x [B,T,D]; positions [B,T] logical indices (consecutive per row; -1
    entries are dropped writes and all-masked queries). Appends all T K/V
    first, then attends with per-query position masks. Returns
    (out [B,T,D], new_pool)."""
    from repro.models import layers as L
    from repro.models.lora import lora_delta
    from repro.paged.paged_cache import append_decode_multi

    B, T = x.shape[:2]
    q, k, v = L._project_qkv(params, x, cfg, adapter=adapter)
    sin, cos = L.rope_tables(positions, cfg.resolved_head_dim(),
                             cfg.rope_theta)
    q = L.apply_rope(q, sin, cos)
    k = L.apply_rope(k, sin, cos)
    pool = append_decode_multi(pool, k, v, block_tables, positions)
    out = paged_attention_reference_multi(q, pool, block_tables, positions)
    out = out.reshape(B, T, -1)
    out = out @ params["wo"] + lora_delta(out, (adapter or {}).get("wo"))
    return out, pool
