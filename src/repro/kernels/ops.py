"""jit'd dispatch wrappers over the Pallas kernels.

On TPU the kernels run compiled (interpret=False); on CPU (this container)
they run in interpret mode, which executes the kernel body in Python for
correctness validation. ``models/`` calls these through ``use_kernel``
flags; the default model path uses the XLA twins (models.flash etc.), which
lower everywhere.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import decode_attention as _dec
from repro.kernels import flash_attention as _fa
from repro.kernels import rmsnorm as _rn
from repro.kernels import ssd_scan as _ssd


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, mask=None, *, causal: bool = True,
                    window: int = 0):
    """Drop-in for models.layers.sdpa's kernel path (mask arg accepted for
    signature compatibility; masking is structural)."""
    return _fa.flash_attention_fwd(q, k, v, causal=causal, window=window,
                                   interpret=_interpret())


def decode_attention(q, k_cache, v_cache, pos, position, *, window: int = 0):
    return _dec.decode_attention(q, k_cache, v_cache, pos, position,
                                 window=window, interpret=_interpret())


def ssd_scan(x, a, b, c, chunk: int, initial_state=None):
    if initial_state is not None:
        raise NotImplementedError(
            "kernel path supports zero initial state (prefill); chunked "
            "continuation uses the XLA path")
    return _ssd.ssd_scan(x, a, b, c, chunk=chunk, interpret=_interpret())


def rmsnorm(x, scale, eps: float = 1e-5):
    return _rn.rmsnorm(x, scale, eps=eps, interpret=_interpret())
