"""Pure-jnp oracles for every Pallas kernel. Deliberately naive — these are
the ground truth the kernels (and the XLA flash path) are validated against.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  q_offset: int = 0):
    """q [B,Sq,H,D], k/v [B,Sk,K,Dv]; H % K == 0. fp32 softmax, dense."""
    B, Sq, H, D = q.shape
    K = k.shape[2]
    G = H // K
    qg = q.reshape(B, Sq, K, G, D).astype(jnp.float32)
    s = jnp.einsum("bqkgd,btkd->bkgqt", qg,
                   k.astype(jnp.float32)) / math.sqrt(D)
    qi = jnp.arange(Sq)[:, None] + q_offset
    kj = jnp.arange(k.shape[1])[None, :]
    m = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        m = kj <= qi
        if window:
            m &= kj > qi - window
    s = jnp.where(m[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqt,btkd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, v.shape[-1]).astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, pos, position, *,
                         window: int = 0):
    """One-token decode. q [B,H,D]; caches [B,C,K,D]; pos [B,C] absolute
    positions (-1 empty); position [B] current."""
    B, H, D = q.shape
    K = k_cache.shape[2]
    G = H // K
    qg = q.reshape(B, K, G, D).astype(jnp.float32)
    s = jnp.einsum("bkgd,bckd->bkgc", qg,
                   k_cache.astype(jnp.float32)) / math.sqrt(D)
    valid = (pos >= 0) & (pos <= position[:, None])
    if window:
        valid &= pos > (position[:, None] - window)
    s = jnp.where(valid[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgc,bckd->bkgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, H, v_cache.shape[-1]).astype(q.dtype)


def ssd_ref(x, a, b, c, initial_state=None):
    """Sequential (step-by-step) SSD recurrence — the strongest oracle.
    x [B,S,H,P] (pre-multiplied by dt), a [B,S,H] (log-decay), b/c [B,S,N].
    Returns (y [B,S,H,P], final_state [B,H,P,N])."""
    B, S, H, P = x.shape
    N = b.shape[-1]
    if initial_state is None:
        initial_state = jnp.zeros((B, H, P, N), jnp.float32)

    def step(state, xs):
        xt, at, bt, ct = xs
        dA = jnp.exp(at.astype(jnp.float32))            # [B,H]
        upd = xt.astype(jnp.float32)[..., None] * bt[:, None, None, :]
        state = state * dA[..., None, None] + upd
        y = jnp.einsum("bhpn,bn->bhp", state, ct.astype(jnp.float32))
        return state, y

    xs = (x.transpose(1, 0, 2, 3), a.transpose(1, 0, 2),
          b.transpose(1, 0, 2), c.transpose(1, 0, 2))
    final, ys = jax.lax.scan(step, initial_state.astype(jnp.float32), xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), final.astype(x.dtype)


def rmsnorm_ref(x, scale, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)
            * scale.astype(jnp.float32)).astype(x.dtype)
