# Pallas TPU kernels for the perf-critical compute layers, each with a
# pure-jnp oracle in ref.py and a jit'd dispatch wrapper in ops.py:
#   flash_attention  — training/prefill attention (online softmax, GQA)
#   decode_attention — flash-decode over rolling KV caches (pos-masked)
#   ssd_scan         — Mamba2 SSD chunk scan (sequential chunk grid axis)
#   rmsnorm          — fused row norm
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
