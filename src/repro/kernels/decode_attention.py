"""Flash-decode — Pallas TPU kernel for single-token attention over a
(rolling) KV cache.

One program per (batch, kv-head); the cache-length dimension is the
innermost sequential grid axis, with fp32 (acc, m, l) scratch carrying the
online softmax across cache blocks. Masking is data-driven: the cache's
per-slot absolute positions (``pos``, -1 = empty) are streamed alongside
K/V, so rolling-buffer wraparound and sliding windows need no index
arithmetic in the host code. All G query heads of a KV group are processed
together ([G, D] x [D, block_c] on the MXU).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, pos_ref, cur_ref, o_ref,
                   acc_ref, m_ref, l_ref, *, window: int, scale: float):
    j = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32) * scale            # [G, D]
    k = k_ref[0].astype(jnp.float32)                    # [bc, D]
    v = v_ref[0].astype(jnp.float32)                    # [bc, Dv]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [G, bc]

    pos = pos_ref[0]                                    # [bc] int32
    cur = cur_ref[0, 0]
    valid = (pos >= 0) & (pos <= cur)
    if window:
        valid &= pos > (cur - window)
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev = m_ref[...]
    m_cur = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_cur[:, None])
    alpha = jnp.exp(m_prev - m_cur)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())))
    m_ref[...] = m_cur

    @pl.when(j == nc - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "block_c",
                                             "interpret"))
def decode_attention(q, k_cache, v_cache, pos, position, *, window: int = 0,
                     block_c: int = 512, interpret: bool = True):
    """q [B,H,D]; caches [B,C,K,Dv]; pos [B,C] int32; position [B] ->
    [B,H,Dv]."""
    B, H, D = q.shape
    C, K = k_cache.shape[1], k_cache.shape[2]
    Dv = v_cache.shape[-1]
    G = H // K
    block_c = min(block_c, max(C, 8))
    pc = (-C) % block_c
    kp = jnp.pad(k_cache, ((0, 0), (0, pc), (0, 0), (0, 0)))
    vp = jnp.pad(v_cache, ((0, 0), (0, pc), (0, 0), (0, 0)))
    posp = jnp.pad(pos, ((0, 0), (0, pc)), constant_values=-1)
    qh = q.reshape(B * K, G, D)
    kh = kp.transpose(0, 2, 1, 3).reshape(B * K, C + pc, D)
    vh = vp.transpose(0, 2, 1, 3).reshape(B * K, C + pc, Dv)
    posh = jnp.repeat(posp, K, axis=0)                  # [B*K, C+pc]
    curh = jnp.repeat(position.astype(jnp.int32)[:, None], K, axis=0)
    nc = (C + pc) // block_c

    kernel = functools.partial(_decode_kernel, window=window,
                               scale=1.0 / math.sqrt(D))
    out = pl.pallas_call(
        kernel,
        grid=(B * K, nc),
        in_specs=[
            pl.BlockSpec((1, G, D), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, block_c, D), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, block_c, Dv), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, block_c), lambda b, j: (b, j)),
            pl.BlockSpec((1, 1), lambda b, j: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, Dv), lambda b, j: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * K, G, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, Dv), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(qh, kh, vh, posh, curh)
    return out.reshape(B, H, Dv)
