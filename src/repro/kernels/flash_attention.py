"""Flash attention forward — Pallas TPU kernel.

Grid ``(B*H, num_q_blocks, num_k_blocks)`` with the KV dimension innermost
and *arbitrary* (sequential), so the fp32 (acc, m, l) online-softmax state
lives in VMEM scratch across KV iterations. Blocks are MXU-aligned
(block_q x head_dim and block_k x head_dim, multiples of (8, 128) for fp32 /
(16, 128) for bf16). GQA is handled in the index maps: query head h reads
KV head h // group_size — no KV replication in HBM.

Validated in interpret mode against kernels.ref.attention_ref (see
tests/test_kernels.py); the XLA twin used inside the models is
repro.models.flash.flash_sdpa.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = -1e30


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                causal: bool, window: int, sk: int, block_q: int,
                block_k: int, scale: float):
    j = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32) * scale          # [bq, d]
    k = k_ref[0].astype(jnp.float32)                  # [bk, d]
    v = v_ref[0].astype(jnp.float32)                  # [bk, dv]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [bq, bk]

    i = pl.program_id(1)
    q_idx = i * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_idx = j * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = k_idx < sk
    if causal:
        mask &= k_idx <= q_idx
        if window:
            mask &= k_idx > q_idx - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_cur = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_cur[:, None])
    alpha = jnp.exp(m_prev - m_cur)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())))
    m_ref[...] = m_cur

    @pl.when(j == nk - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention_fwd(q, k, v, *, causal: bool = True, window: int = 0,
                        block_q: int = 128, block_k: int = 128,
                        interpret: bool = True):
    """q [B,Sq,H,D]; k/v [B,Sk,K,D] with H % K == 0 -> [B,Sq,H,Dv]."""
    B, Sq, H, D = q.shape
    Sk, K = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    G = H // K
    block_q = min(block_q, max(Sq, 8))
    block_k = min(block_k, max(Sk, 8))
    pq = (-Sq) % block_q
    pk = (-Sk) % block_k
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    # layout: heads major so one program sees one (batch, head) pair
    qh = qp.transpose(0, 2, 1, 3).reshape(B * H, Sq + pq, D)
    kh = kp.transpose(0, 2, 1, 3).reshape(B * K, Sk + pk, D)
    vh = vp.transpose(0, 2, 1, 3).reshape(B * K, Sk + pk, Dv)
    nq = (Sq + pq) // block_q
    nk = (Sk + pk) // block_k

    grid = (B * H, nq, nk)
    kernel = functools.partial(
        _fwd_kernel, causal=causal, window=window, sk=Sk,
        block_q=block_q, block_k=block_k, scale=1.0 / math.sqrt(D))
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, i, j: (b // G, j, 0)),
            pl.BlockSpec((1, block_k, Dv), lambda b, i, j: (b // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, Dv), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq + pq, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, Dv), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qh, kh, vh)
    out = out.reshape(B, H, Sq + pq, Dv)[:, :, :Sq]
    return out.transpose(0, 2, 1, 3)
