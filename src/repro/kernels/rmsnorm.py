"""Fused RMSNorm — Pallas TPU kernel (row blocks, fp32 reduction)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)
                  * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows",
                                             "interpret"))
def rmsnorm(x, scale, *, eps: float = 1e-5, block_rows: int = 256,
            interpret: bool = True):
    """x [..., D], scale [D]."""
    orig_shape = x.shape
    D = x.shape[-1]
    rows = 1
    for d in x.shape[:-1]:
        rows *= d
    xf = x.reshape(rows, D)
    block_rows = min(block_rows, max(rows, 1))
    pr = (-rows) % block_rows
    xf = jnp.pad(xf, ((0, pr), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=((rows + pr) // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows + pr, D), x.dtype),
        interpret=interpret,
    )(xf, scale)
    return out[:rows].reshape(orig_shape)
