"""Mamba2 SSD chunk scan — Pallas TPU kernel.

One program per (batch, head); chunks are the innermost sequential grid
axis, carrying the [P, N] SSM state in fp32 VMEM scratch. Within a chunk
everything is dense MXU work:

    L      = exp(segsum(a_chunk))          [l, l] lower-triangular
    y_diag = ((C B^T) * L) x               intra-chunk
    y_off  = C state^T * exp(a_cum)        contribution of carried state
    state  = exp(a_sum) state + (B * decay)^T x

This is the TPU-native shape of the SSD algorithm (arXiv 2405.21060 §6):
instead of the paper's GPU warp-level scan, the inter-chunk recurrence is a
sequential grid axis (cheap: S/chunk steps) and all intra-chunk terms are
(l x l)/(l x N)/(P x N) matmuls sized to the MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _ssd_kernel(x_ref, a_ref, b_ref, c_ref, y_ref, fin_ref, state_ref, *,
                nc: int, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    P, N = state_ref.shape
    x = x_ref[...].reshape(chunk, P).astype(jnp.float32)
    a = a_ref[...].reshape(chunk).astype(jnp.float32)
    b = b_ref[...].reshape(chunk, N).astype(jnp.float32)
    c = c_ref[...].reshape(chunk, N).astype(jnp.float32)

    a_cum = jnp.cumsum(a)                        # [l]
    # segsum: L[i,j] = exp(sum_{j<k<=i} a_k) for j<=i else 0
    diff = a_cum[:, None] - a_cum[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(jj <= ii, jnp.exp(diff), 0.0)  # [l, l]

    cb = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())))   # [l, l]
    y_diag = jax.lax.dot_general((cb * L).astype(x.dtype), x,
                                 (((1,), (0,)), ((), ())))     # [l, P]

    state = state_ref[...]                       # [P, N] fp32
    y_off = jax.lax.dot_general(
        c * jnp.exp(a_cum)[:, None], state,
        (((1,), (1,)), ((), ())))                # [l, P]
    y_ref[...] = (y_diag + y_off).astype(y_ref.dtype).reshape(y_ref.shape)

    decay = jnp.exp(a_cum[-1] - a_cum)           # [l]
    bx = jax.lax.dot_general((b * decay[:, None]), x,
                             (((0,), (0,)), ((), ())))          # [N, P]
    state_ref[...] = state * jnp.exp(a_cum[-1]) + bx.T

    @pl.when(ci == nc - 1)
    def _done():
        fin_ref[...] = state_ref[...].astype(fin_ref.dtype).reshape(
            fin_ref.shape)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, a, b, c, chunk: int = 128, *, interpret: bool = True):
    """x [B,S,H,P] (pre-multiplied by dt), a [B,S,H] log-decay, b/c [B,S,N]
    -> (y [B,S,H,P], final_state [B,H,P,N])."""
    B, S, H, P = x.shape
    N = b.shape[-1]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    xh = x.transpose(0, 2, 1, 3).reshape(B, H, nc, chunk, P)
    ah = a.transpose(0, 2, 1).reshape(B, H, nc, chunk)
    bh = b.reshape(B, nc, chunk, N)
    ch = c.reshape(B, nc, chunk, N)

    kernel = functools.partial(_ssd_kernel, nc=nc, chunk=chunk)
    y, fin = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, chunk, P), lambda b_, h, ci: (b_, h, ci, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk), lambda b_, h, ci: (b_, h, ci, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda b_, h, ci: (b_, ci, 0, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda b_, h, ci: (b_, ci, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, chunk, P), lambda b_, h, ci: (b_, h, ci, 0, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b_, h, ci: (b_, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, nc, chunk, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), x.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xh, ah, bh, ch)
    y = y.reshape(B, H, S, P).transpose(0, 2, 1, 3)
    return y, fin
