"""Version/backed shims over accelerator API surfaces.

``pltpu.TPUCompilerParams`` was renamed ``CompilerParams`` upstream;
resolve whichever this jax ships so the kernels lower on both.

This module is also the single capability probe for JAX *memory kinds*
(the ``device`` / ``pinned_host`` spaces behind ``jax.device_put``-based
host offload). Everything in ``repro.offload`` and the sharding rules
gates on these three functions rather than sniffing the backend again:

  * :func:`host_memory_kind`   — the distinct host space ("pinned_host" on
    TPU/GPU runtimes that expose one), or ``None`` when the backend has no
    separate host memory (CPU: default memory *is* host already);
  * :func:`device_memory_kind` — the default (HBM) memory kind;
  * :func:`supports_host_offload` — convenience predicate.

On backends where :func:`host_memory_kind` is ``None``, offload callers
fall back to committed host copies (``numpy`` round trips through
``jax.device_put``) — bit-identical, just without the pinned DMA path.
"""
from __future__ import annotations

import functools
from typing import Optional

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams


@functools.lru_cache(maxsize=None)
def _memory_probe():
    """(default_kind, frozenset(all kinds)) of device 0; safe on any backend."""
    import jax
    try:
        dev = jax.devices()[0]
        kinds = frozenset(m.kind for m in dev.addressable_memories())
        return dev.default_memory().kind, kinds
    except Exception:               # very old jax / exotic backend
        return "device", frozenset(("device",))


def device_memory_kind() -> str:
    """Memory kind of the default (accelerator) space — "device" on
    TPU/GPU, "unpinned_host" on the CPU backend."""
    return _memory_probe()[0]


def host_memory_kind() -> Optional[str]:
    """The host memory kind usable as a ``jax.device_put`` target for
    offload, or None when the backend exposes no space distinct from its
    default (CPU). Prefers "pinned_host" (DMA-able) over "unpinned_host"."""
    default, kinds = _memory_probe()
    for kind in ("pinned_host", "unpinned_host"):
        if kind in kinds and kind != default:
            return kind
    return None


def supports_host_offload() -> bool:
    """True when runtime HBM<->host swapping can use real memory-kind
    placement (vs the committed-numpy fallback)."""
    return host_memory_kind() is not None


__all__ = ["CompilerParams", "device_memory_kind", "host_memory_kind",
           "supports_host_offload"]
