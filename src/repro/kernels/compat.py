"""Version shims over the Pallas TPU API surface.

``pltpu.TPUCompilerParams`` was renamed ``CompilerParams`` upstream;
resolve whichever this jax ships so the kernels lower on both.
"""
from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams

__all__ = ["CompilerParams"]
