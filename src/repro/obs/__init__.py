"""Unified runtime telemetry (``repro.obs``): one metrics registry + one
span tracer per run, written out as

  * a **JSONL** file — meta line, then every span/instant/memory sample,
    then the final metrics snapshot — that ``launch/report.py`` renders
    into a per-phase table and ASCII memory timeline with zero
    recomputation, and
  * a **Chrome-trace JSON** (Perfetto / ``chrome://tracing``) with one
    row per subsystem (phases, offload, serving) and counter tracks for
    the live device/host-bytes timeline.

``RunTelemetry`` is the object the instrumented subsystems share:
``RLHFTrainer(..., telemetry=...)`` emits one span per canonical PPO
phase carrying measured bytes AND the traced allocator-simulator's
prediction (the sim-vs-measured delta); ``OffloadExecutor`` emits
park/fetch spans with PCIe bytes; ``serving.ContinuousBatcher`` emits
page-pool occupancy, preemption/CoW counters, admission latency and
tokens/sec. See DESIGN.md §4 for the span taxonomy and metric names.
"""
from __future__ import annotations

import json
import time
from dataclasses import dataclass, field
from typing import Any, Dict, Optional

from repro.obs.attribution import (AttributionSnapshot, MemoryAttributor,
                                   compiled_memory_stats,
                                   record_compiled_memory)
from repro.obs.flight import FlightRecorder
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               global_registry, set_global_registry)
from repro.obs.tracer import Span, SpanTracer

__all__ = ["AttributionSnapshot", "Counter", "FlightRecorder", "Gauge",
           "Histogram", "MemoryAttributor", "MetricsRegistry", "RunTelemetry",
           "Span", "SpanTracer", "compiled_memory_stats", "global_registry",
           "record_compiled_memory", "set_global_registry"]


@dataclass
class RunTelemetry:
    """One run's telemetry bundle: a registry, a tracer, and run metadata.

    ``sim_delta=True`` asks the RLHF trainer to run the traced allocator
    simulator once (lazily, at the first ``train_step``) and attach the
    per-phase predicted bytes to every phase span — divergence between
    the analytic model and the measured run becomes a first-class metric
    instead of a benchmark assertion. Setup cost is one-time and is
    excluded from the tracer's self-time accounting.
    """
    registry: MetricsRegistry = field(default_factory=MetricsRegistry)
    tracer: SpanTracer = field(default_factory=SpanTracer)
    sim_delta: bool = True
    meta: Dict[str, Any] = field(default_factory=dict)
    # Optional memory-attribution engine; instrumented subsystems create
    # one lazily (and register their owner trees) when absent.
    attribution: Optional[MemoryAttributor] = None
    # Optional OOM flight recorder; shared by every subsystem on the run.
    flight: Optional[FlightRecorder] = None

    @classmethod
    def create(cls, *, sim_delta: bool = True, jax_annotate: bool = False,
               registry: Optional[MetricsRegistry] = None,
               attribution: Optional[MemoryAttributor] = None,
               flight: Optional[FlightRecorder] = None,
               **meta) -> "RunTelemetry":
        return cls(registry=registry or MetricsRegistry(),
                   tracer=SpanTracer(jax_annotate=jax_annotate),
                   sim_delta=sim_delta, meta=dict(meta),
                   attribution=attribution, flight=flight)

    # ------------------------------------------------------------- export
    def write_jsonl(self, path: str) -> str:
        """The single-file run record ``launch/report.py`` consumes."""
        with open(path, "w") as f:
            f.write(json.dumps(
                {"type": "meta", "t0_wall": self.tracer.t0_wall,
                 "written": time.time(),
                 "self_time_s": round(self.tracer.self_time_s, 6),
                 **self.meta}, sort_keys=True) + "\n")
            self.tracer.write_jsonl(f)
            self.registry.write_jsonl(f)
        return path

    def write_chrome_trace(self, path: str) -> str:
        return self.tracer.write_chrome_trace(path)

    def write(self, jsonl_path: Optional[str] = None,
              trace_path: Optional[str] = None) -> None:
        if jsonl_path:
            self.write_jsonl(jsonl_path)
        if trace_path:
            self.write_chrome_trace(trace_path)
