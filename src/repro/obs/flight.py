"""OOM flight recorder (``repro.obs.flight``).

A crash dump for memory: :class:`FlightRecorder` keeps a bounded ring
buffer of recent context — phase spans, metric samples, offload
park/fetch events, serving steps — and when HBM pressure crosses a
configurable watermark fraction (or an XLA ``RESOURCE_EXHAUSTED`` error
is caught in flight), it dumps a forensic JSON bundle: who owned how
many bytes (from the attribution snapshot), the top-k live buffers with
owner paths, and the phase history leading up to the breach.

Capacity resolution, in order:
  1. explicit ``capacity_bytes`` (tests, known HBM budgets);
  2. ``device.memory_stats()["bytes_limit"]`` of the first local device
     (real accelerators);
  3. calibration fallback — the first ``check()`` latches its own live
     bytes as capacity, so a *forced* low watermark (< 1.0) still
     triggers deterministically on backends (CPU) that report no limit.

The recorder is a pure observer: it never frees, never retries, never
swallows the exception — ``record_oom`` captures and the caller
re-raises. Each trigger kind fires at most once per recorder (latched)
so a breached run doesn't dump on every subsequent boundary.
"""
from __future__ import annotations

import json
import os
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional

__all__ = ["FlightRecorder"]

SCHEMA = "flight-recorder/v1"


def _device_bytes_limit() -> Optional[int]:
    try:
        import jax
        stats = jax.local_devices()[0].memory_stats()
        limit = int(stats.get("bytes_limit", 0))
        return limit or None
    except Exception:
        return None


class FlightRecorder:
    """Watermark-triggered forensic memory dump.

    Parameters
    ----------
    watermark : fraction of capacity at which ``check()`` trips.
    capacity_bytes : HBM budget; None -> device bytes_limit, else the
        calibration fallback described in the module docstring.
    ring : max retained context events (spans/samples/offload events).
    top_k : live buffers listed in the dump.
    path : when set, each dump is also written to ``path`` (a single
        trigger) or ``path`` with an index suffix for later triggers.
    """

    def __init__(self, watermark: float = 0.92,
                 capacity_bytes: Optional[int] = None, ring: int = 256,
                 top_k: int = 10, path: Optional[str] = None):
        self.watermark = float(watermark)
        self.capacity_bytes = capacity_bytes if capacity_bytes \
            else _device_bytes_limit()
        self._calibrated = self.capacity_bytes is not None
        self.top_k = top_k
        self.path = path
        self.ring: deque = deque(maxlen=ring)
        self.phase_history: deque = deque(maxlen=64)
        self.dumps: List[dict] = []
        self.triggered: Dict[str, bool] = {}

    # ------------------------------------------------------------- context
    def note(self, event: str, **payload) -> None:
        """Push one context event into the ring (cheap; no walk)."""
        rec = {"event": event, "t": time.time()}
        rec.update(payload)
        self.ring.append(rec)
        if event == "phase":
            self.phase_history.append(
                {k: payload.get(k) for k in
                 ("phase", "live_bytes", "host_bytes") if k in payload})

    # ------------------------------------------------------------ triggers
    def check(self, live_bytes: int,
              snapshot_fn: Optional[Callable[[], Any]] = None,
              phase: Optional[str] = None, source: str = "") -> Optional[dict]:
        """Trip on ``live_bytes >= watermark * capacity``. The snapshot is
        taken lazily (only on a trigger) so the steady-state cost of a
        check is two comparisons."""
        if not self._calibrated:
            # CPU fallback: latch first observation as the budget so a
            # forced watermark < 1.0 still has something to breach. The
            # calibration sample itself cannot breach (it IS the budget);
            # the next check that reaches watermark * this value trips.
            self.capacity_bytes = max(int(live_bytes), 1)
            self._calibrated = True
            return None
        if self.triggered.get("watermark"):
            return None
        if live_bytes < self.watermark * self.capacity_bytes:
            return None
        self.triggered["watermark"] = True
        return self._dump("watermark", live_bytes=int(live_bytes),
                          snapshot_fn=snapshot_fn, phase=phase,
                          source=source)

    @staticmethod
    def is_oom(exc: BaseException) -> bool:
        return "RESOURCE_EXHAUSTED" in repr(exc)

    def record_oom(self, exc: BaseException,
                   snapshot_fn: Optional[Callable[[], Any]] = None,
                   live_bytes: int = 0, phase: Optional[str] = None,
                   source: str = "") -> Optional[dict]:
        """Capture a dump for a caught ``RESOURCE_EXHAUSTED``. The caller
        re-raises; the recorder only observes."""
        if self.triggered.get("resource_exhausted"):
            return None
        self.triggered["resource_exhausted"] = True
        return self._dump("resource_exhausted", live_bytes=int(live_bytes),
                          snapshot_fn=snapshot_fn, phase=phase,
                          source=source, error=repr(exc)[:2000])

    # ---------------------------------------------------------------- dump
    def _dump(self, trigger: str, *, live_bytes: int, snapshot_fn,
              phase: Optional[str], source: str,
              error: Optional[str] = None) -> dict:
        snap = None
        if snapshot_fn is not None:
            try:
                snap = snapshot_fn()
            except Exception:
                snap = None
        owners = dict(getattr(snap, "owners", {}) or {})
        owners = {k: v for k, v in owners.items() if v}
        bundle = {
            "schema": SCHEMA,
            "trigger": trigger,
            "t_wall": time.time(),
            "source": source,
            "phase": phase,
            "live_bytes": live_bytes,
            "capacity_bytes": self.capacity_bytes,
            "watermark": self.watermark,
            "owners": owners,
            "owners_ranked": [k for k, _ in sorted(owners.items(),
                                                   key=lambda kv: -kv[1])],
            "unattributed": int(getattr(snap, "unattributed", 0)),
            "host_owners": dict(getattr(snap, "host_owners", {}) or {}),
            "top_buffers": list(getattr(snap, "top_buffers",
                                        []) or [])[:self.top_k],
            "phase_history": list(self.phase_history),
            "ring": list(self.ring),
        }
        if error is not None:
            bundle["error"] = error
        self.dumps.append(bundle)
        if self.path:
            path = self.path if len(self.dumps) == 1 else \
                f"{self.path}.{len(self.dumps) - 1}"
            try:
                d = os.path.dirname(path)
                if d:
                    os.makedirs(d, exist_ok=True)
                with open(path, "w") as fh:
                    json.dump(bundle, fh, indent=1, default=str)
            except OSError:
                pass
        return bundle
