"""Per-buffer HBM ownership attribution (``repro.obs.attribution``).

The sim-vs-measured delta on a phase span (PR 6) says *how far* the run
diverged from the allocator simulator; it cannot say *which subsystem owns
the divergent bytes*. This module closes that gap: a
:class:`MemoryAttributor` holds a registry of **owner trees** — named
getters over the long-lived pytrees of a run (frozen trunk, per-role
adapters/value heads, optimizer states, paged KV pools, rollout/experience
buffers, the merged rollout weights while they exist) — and
:meth:`MemoryAttributor.snapshot` classifies every array in
``jax.live_arrays()`` by **buffer identity** into an owner -> bytes table.

Exactness contract: the snapshot walks the live set ONCE and derives the
total, the per-owner bytes and the unattributed residue from that single
walk, so

    sum(owners.values()) + unattributed == total_bytes      (always, exactly)

and ``PhaseMemoryManager`` uses ``total_bytes`` *as* the phase record's
live bytes whenever an attributor is attached — the per-owner table on a
phase span therefore sums to the span's ``measured_bytes`` to the byte.

Owner getters are re-read on every snapshot because donated train steps
rewrite the state arrays each iteration; a getter returning ``None`` (a
buffer group that does not exist right now, e.g. the merged rollout tree
outside the rollout phase) contributes nothing. When one array appears in
two owner trees (aliases: the hydra reference IS the base trunk), the
first-registered owner wins — registration order is priority order.

Snapshots store only metadata (bytes, shape, dtype, owner, tree path) and
never retain array references, so an attributor can never extend a
buffer's lifetime — telemetry stays a pure observer.

The second half of the file is per-jitted-program compiled-memory
accounting: :func:`compiled_memory_stats` reads XLA's
``memory_analysis()`` (temp/argument/output/code bytes) off a compiled
program and :func:`record_compiled_memory` feeds it into a metrics
registry, keyed by program name — ``serving.ContinuousBatcher`` joins
these entries with its ``CompileCache`` keys so every bucket rung (and
any post-warmup recompile) carries its compiled-memory cost.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

__all__ = ["AttributionSnapshot", "MemoryAttributor",
           "compiled_memory_stats", "record_compiled_memory"]


@dataclass
class AttributionSnapshot:
    """One classification pass over ``jax.live_arrays()``.

    ``owners`` maps owner name -> live *device* bytes (host-memory-kind
    arrays are excluded, mirroring ``rlhf.live_device_bytes``);
    ``host_owners`` is the same table for host-kind arrays (parked state).
    ``total_bytes`` is the device total of the same walk, so the exactness
    identity in the module docstring holds by construction."""
    owners: Dict[str, int] = field(default_factory=dict)
    unattributed: int = 0
    total_bytes: int = 0
    host_owners: Dict[str, int] = field(default_factory=dict)
    host_unattributed: int = 0
    # [{nbytes, shape, dtype, owner, path}] — metadata only, no array refs
    top_buffers: List[dict] = field(default_factory=list)
    n_arrays: int = 0
    walk_s: float = 0.0

    def ranked(self) -> List[str]:
        """Owner names by live device bytes, descending (nonzero only)."""
        return [k for k, v in sorted(self.owners.items(),
                                     key=lambda kv: -kv[1]) if v > 0]

    def table(self) -> Dict[str, int]:
        """Nonzero owner -> bytes (the dict that rides phase-span args)."""
        return {k: v for k, v in self.owners.items() if v}

    def to_record(self) -> dict:
        return {"owners": self.table(), "unattributed": self.unattributed,
                "total_bytes": self.total_bytes,
                "host_owners": dict(self.host_owners),
                "top_buffers": list(self.top_buffers),
                "n_arrays": self.n_arrays}


class MemoryAttributor:
    """Registry of named owner-tree getters + the live-set classifier."""

    def __init__(self, *, top_k: int = 10):
        self.top_k = top_k
        self._getters: Dict[str, Callable[[], Any]] = {}

    # ------------------------------------------------------------ registry
    def register(self, name: str, getter: Callable[[], Any]) -> None:
        """Register an owner. ``getter`` is called at every snapshot and
        returns the owner's current pytree (or None when the owner holds
        nothing right now). Re-registering a name replaces its getter but
        keeps its original priority slot."""
        self._getters[name] = getter

    def register_tree(self, name: str, tree: Any) -> None:
        """Convenience for owners whose tree object never gets replaced
        (e.g. a serving param tree)."""
        self.register(name, lambda: tree)

    def owners(self):
        return tuple(self._getters)

    # ------------------------------------------------------------ snapshot
    def _identity_map(self) -> Dict[int, tuple]:
        """id(array) -> (owner, path) over all registered owner trees.
        First registration wins on aliases."""
        import jax
        ident: Dict[int, tuple] = {}
        for name, get in self._getters.items():
            try:
                tree = get()
            except Exception:
                tree = None
            if tree is None:
                continue
            for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
                if getattr(leaf, "nbytes", 0):
                    ident.setdefault(
                        id(leaf), (name, jax.tree_util.keystr(path)))
        return ident

    def snapshot(self) -> AttributionSnapshot:
        """Classify the current live set. One walk; see module docstring
        for the exactness contract. The wall cost is returned in
        ``walk_s`` so callers can charge it to the telemetry self-time
        (the attribution pass counts against the <=2% overhead gate)."""
        import jax

        from repro.kernels import compat
        t0 = time.perf_counter()
        host_kind = compat.host_memory_kind()
        ident = self._identity_map()
        snap = AttributionSnapshot(
            owners={name: 0 for name in self._getters})
        sizes: List[tuple] = []
        for a in jax.live_arrays():
            nb = getattr(a, "nbytes", 0)
            who = ident.get(id(a))
            on_host = host_kind is not None and \
                getattr(a.sharding, "memory_kind", None) == host_kind
            if on_host:
                if who is not None:
                    snap.host_owners[who[0]] = \
                        snap.host_owners.get(who[0], 0) + nb
                else:
                    snap.host_unattributed += nb
                continue
            snap.n_arrays += 1
            snap.total_bytes += nb
            if who is not None:
                snap.owners[who[0]] += nb
            else:
                snap.unattributed += nb
            # metadata only — never keep the array itself alive
            sizes.append((nb, str(getattr(a, "shape", ())),
                          str(getattr(a, "dtype", "?")), who))
        sizes.sort(key=lambda r: -r[0])
        snap.top_buffers = [
            {"nbytes": nb, "shape": shape, "dtype": dtype,
             "owner": who[0] if who else "(unattributed)",
             "path": who[1] if who else ""}
            for nb, shape, dtype, who in sizes[:self.top_k]]
        snap.walk_s = time.perf_counter() - t0
        return snap


# --------------------------------------------------- compiled-memory stats
def compiled_memory_stats(compiled) -> Optional[Dict[str, int]]:
    """temp/argument/output/generated-code bytes of a compiled XLA
    program, or None when the backend exposes no ``memory_analysis()``."""
    try:
        ma = compiled.memory_analysis()
        return {
            "temp_bytes": int(ma.temp_size_in_bytes),
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "generated_code_bytes": int(ma.generated_code_size_in_bytes),
        }
    except Exception:
        return None


def record_compiled_memory(registry, program: str, fn, *args,
                           **kwargs) -> Optional[Dict[str, int]]:
    """Lower+compile ``fn`` for ``args`` and feed its compiled-memory
    stats into ``registry`` as gauges labelled ``program=...``.

    Lowering only traces — it never executes the program — so this is a
    pure observer; it is one-time setup cost (like the simulator replay)
    and is deliberately NOT charged to the tracer's self-time. Returns the
    stats dict, or None when the function cannot be lowered (e.g. the
    pre-jitted ZeRO two-program steps) or the backend has no
    ``memory_analysis``."""
    try:
        compiled = fn.lower(*args, **kwargs).compile()
    except Exception:
        return None
    stats = compiled_memory_stats(compiled)
    if stats is None:
        return None
    for key, val in stats.items():
        registry.gauge(
            f"compiled_{key}",
            "per-jitted-program compiled-memory accounting "
            "(XLA memory_analysis)").set(val, program=program)
    return stats
