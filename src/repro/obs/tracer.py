"""SpanTracer: nested phase/step spans with memory samples, exported as
Chrome-trace/Perfetto JSON.

The push-model half of the runtime telemetry layer (``repro.obs``). A
span is a named wall-clock interval with arbitrary ``args`` — the
instrumented subsystems attach per-device live HBM bytes, host bytes and
PCIe transfer bytes sampled at the span boundary, and the RLHF trainer
attaches the traced allocator-simulator's predicted peak for the phase so
the *sim-vs-measured delta* rides every phase span (see
``rlhf.trainer.PhaseMemoryManager``).

Three ways to record:

  * ``begin(name)`` / ``end()`` — stack-nested, for intervals whose
    endpoints the caller controls (the per-iteration parent span, offload
    park/fetch windows);
  * ``complete(name, t0, t1)`` — retroactive, for intervals delimited by
    events (phase boundaries: a phase's start is the previous boundary);
  * ``instant(name)`` / ``sample(values)`` — point events and counter
    tracks (the live device/host-bytes timeline Perfetto renders as an
    area chart).

Export targets:

  * :meth:`chrome_trace` / :meth:`write_chrome_trace` — the Trace Event
    Format JSON (``{"traceEvents": [...]}``) loadable in Perfetto /
    ``chrome://tracing``: ``X`` complete events for spans, ``C`` counter
    events for memory tracks, ``i`` instants, ``M`` thread-name metadata
    naming one row per category;
  * :meth:`write_jsonl` — one JSON object per span/instant/sample, the
    file ``launch/report.py`` renders without recomputation.

Self-accounting: every public recording method adds its own elapsed time
to ``self_time_s``, so a run can report the telemetry tax directly
(``overhead_fraction(wall_s)``) instead of relying on noisy A/B timing.

``jax_annotate=True`` additionally brackets every ``begin``/``end`` span
in a ``jax.profiler.TraceAnnotation`` so the spans line up with XLA's own
rows when a ``jax.profiler.trace()`` capture is active; it is a no-op
when the profiler isn't available.
"""
from __future__ import annotations

import json
import os
import time
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

# stable tid per category → one named row per subsystem in Perfetto
_CATEGORY_TIDS = {"iteration": 1, "phase": 2, "offload": 3, "serving": 4,
                  "bench": 5, "misc": 9}


@dataclass
class Span:
    name: str
    cat: str
    ts_us: float                 # start, µs since tracer epoch
    dur_us: float = 0.0
    depth: int = 0
    args: Dict[str, Any] = field(default_factory=dict)

    def record(self) -> dict:
        return {"type": "span", "name": self.name, "cat": self.cat,
                "ts_us": round(self.ts_us, 1), "dur_us": round(self.dur_us, 1),
                "depth": self.depth, "args": self.args}


class SpanTracer:
    def __init__(self, *, jax_annotate: bool = False):
        self.t0_wall = time.time()           # epoch anchor for export
        self._t0 = time.perf_counter()
        self.spans: List[Span] = []          # finished, in completion order
        self.instants: List[dict] = []
        self.samples: List[dict] = []        # counter-track samples
        self._stack: List[Span] = []
        self._annotations: List[Any] = []
        self.jax_annotate = jax_annotate
        self.self_time_s = 0.0

    # ------------------------------------------------------------- clock
    def now_us(self) -> float:
        return (time.perf_counter() - self._t0) * 1e6

    @property
    def depth(self) -> int:
        return len(self._stack)

    # ----------------------------------------------------------- recording
    def begin(self, name: str, cat: str = "misc", **args) -> Span:
        t = time.perf_counter()
        sp = Span(name, cat, self.now_us(), depth=len(self._stack),
                  args=dict(args))
        self._stack.append(sp)
        if self.jax_annotate:
            self._annotations.append(self._enter_annotation(name))
        self.self_time_s += time.perf_counter() - t
        return sp

    def end(self, **args) -> Span:
        t = time.perf_counter()
        assert self._stack, "SpanTracer.end() with no open span"
        sp = self._stack.pop()
        if self.jax_annotate and self._annotations:
            self._exit_annotation(self._annotations.pop())
        sp.dur_us = self.now_us() - sp.ts_us
        sp.args.update(args)
        self.spans.append(sp)
        self.self_time_s += time.perf_counter() - t
        return sp

    @contextmanager
    def span(self, name: str, cat: str = "misc", **args):
        sp = self.begin(name, cat, **args)
        try:
            yield sp
        finally:
            self.end()

    def complete(self, name: str, cat: str, ts_us: float, dur_us: float,
                 **args) -> Span:
        """Add a retroactive span: the interval ``[ts_us, ts_us+dur_us]``
        is already over (phase boundaries delimit phases after the fact).
        Nesting depth is the current stack depth — a completed phase sits
        under whatever parent span is open."""
        t = time.perf_counter()
        sp = Span(name, cat, ts_us, dur_us, depth=len(self._stack),
                  args=dict(args))
        self.spans.append(sp)
        self.self_time_s += time.perf_counter() - t
        return sp

    def instant(self, name: str, cat: str = "misc", **args) -> None:
        t = time.perf_counter()
        self.instants.append({"type": "instant", "name": name, "cat": cat,
                              "ts_us": round(self.now_us(), 1),
                              "args": dict(args)})
        self.self_time_s += time.perf_counter() - t

    def sample(self, track: str, values: Dict[str, float],
               ts_us: Optional[float] = None) -> None:
        """One point on a counter track (Perfetto area chart) — e.g.
        ``sample("memory", {"device_mib": ..., "host_mib": ...})``."""
        t = time.perf_counter()
        self.samples.append({"type": "sample", "track": track,
                             "ts_us": round(ts_us if ts_us is not None
                                            else self.now_us(), 1),
                             "values": {k: float(v)
                                        for k, v in values.items()}})
        self.self_time_s += time.perf_counter() - t

    # ------------------------------------------------- jax.profiler bridge
    @staticmethod
    def _enter_annotation(name: str):
        try:
            from jax.profiler import TraceAnnotation
            ann = TraceAnnotation(name)
            ann.__enter__()
            return ann
        except Exception:
            return None

    @staticmethod
    def _exit_annotation(ann) -> None:
        if ann is not None:
            try:
                ann.__exit__(None, None, None)
            except Exception:
                pass

    # -------------------------------------------------------------- export
    def overhead_fraction(self, wall_s: float) -> float:
        """Telemetry self-time as a fraction of ``wall_s``."""
        return self.self_time_s / wall_s if wall_s > 0 else 0.0

    @staticmethod
    def _tid(cat: str) -> int:
        return _CATEGORY_TIDS.get(cat, _CATEGORY_TIDS["misc"])

    def chrome_trace(self) -> dict:
        """Trace Event Format dict (Perfetto / chrome://tracing)."""
        pid = os.getpid()
        ev: List[dict] = [
            {"ph": "M", "pid": pid, "tid": 0, "name": "process_name",
             "args": {"name": "repro-telemetry"}}]
        cats = {sp.cat for sp in self.spans}
        cats |= {i["cat"] for i in self.instants}
        for cat in sorted(cats, key=self._tid):
            ev.append({"ph": "M", "pid": pid, "tid": self._tid(cat),
                       "name": "thread_name", "args": {"name": cat}})
        for sp in self.spans:
            ev.append({"ph": "X", "pid": pid, "tid": self._tid(sp.cat),
                       "name": sp.name, "cat": sp.cat,
                       "ts": round(sp.ts_us, 1),
                       "dur": round(max(sp.dur_us, 0.1), 1),
                       "args": sp.args})
        for it in self.instants:
            ev.append({"ph": "i", "pid": pid, "tid": self._tid(it["cat"]),
                       "name": it["name"], "cat": it["cat"],
                       "ts": it["ts_us"], "s": "t", "args": it["args"]})
        for sm in self.samples:
            ev.append({"ph": "C", "pid": pid, "tid": 0, "name": sm["track"],
                       "ts": sm["ts_us"], "args": sm["values"]})
        return {"traceEvents": ev, "displayTimeUnit": "ms",
                "otherData": {"t0_wall": self.t0_wall,
                              "self_time_s": round(self.self_time_s, 6)}}

    def write_chrome_trace(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)
        return path

    def records(self) -> List[dict]:
        """All spans/instants/samples as JSONL-ready dicts, time-ordered."""
        out = [sp.record() for sp in self.spans]
        out.extend(self.instants)
        out.extend(self.samples)
        out.sort(key=lambda r: r["ts_us"])
        return out

    def write_jsonl(self, path_or_file) -> int:
        recs = self.records()
        if hasattr(path_or_file, "write"):
            for r in recs:
                path_or_file.write(json.dumps(r, sort_keys=True) + "\n")
        else:
            with open(path_or_file, "a") as f:
                for r in recs:
                    f.write(json.dumps(r, sort_keys=True) + "\n")
        return len(recs)
