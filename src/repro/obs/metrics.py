"""MetricsRegistry: counters, gauges, and histograms with labels.

The pull-model metrics half of the runtime telemetry layer
(``repro.obs``). Every subsystem writes into a registry — the trainer's
phase loop, the offload executor, the continuous batcher, the sharding
gather paths — and a run snapshots it once at the end (``snapshot()`` /
``write_jsonl()``); nothing is aggregated out-of-process.

Design points:

  * **Cheap when hot.** ``inc``/``set``/``observe`` are a dict lookup and
    a float add — no locks beyond the GIL, no string formatting, no I/O.
    Instruments can therefore stay enabled unconditionally (the page
    allocator's per-token path, the serving step loop) without a
    measurable tax; the ≤2 % telemetry-overhead budget is enforced by
    the tracer's self-accounting (``SpanTracer.self_time_s``).
  * **Labels are kwargs.** ``counter("x").inc(3, phase="rollout")`` keys
    a child series by the sorted label items. Unlabeled use keys the
    ``()`` series.
  * **Idempotent registration.** ``registry.counter("x")`` returns the
    existing instrument (same-kind check) so call sites don't coordinate.
  * **One process-global default.** Call sites deep inside frozen
    dataclasses (``TreePlan.gather_copy``) that can't thread a registry
    use :func:`global_registry`; tests swap it with
    :func:`set_global_registry`.

JSONL schema (one line per series, shared with ``SpanTracer`` output so
``launch/report.py`` renders a run from a single file):

    {"type": "metric", "name": ..., "kind": "counter|gauge|histogram",
     "labels": {...}, "value": ...}                 # counter/gauge
    {"type": "metric", "name": ..., "kind": "histogram", "labels": {...},
     "count": n, "sum": s, "min": ..., "max": ..., "buckets": {"le": n}}
"""
from __future__ import annotations

import json
import math
from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, List, Optional, Tuple

LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Dict[str, Any]) -> LabelKey:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


@dataclass
class _Metric:
    name: str
    help: str = ""

    kind = "abstract"

    def series(self) -> Iterable[Tuple[LabelKey, dict]]:  # pragma: no cover
        raise NotImplementedError


@dataclass
class Counter(_Metric):
    """Monotonically-increasing sum per label set."""
    values: Dict[LabelKey, float] = field(default_factory=dict)

    kind = "counter"

    def inc(self, v: float = 1.0, **labels) -> None:
        assert v >= 0, f"counter {self.name} cannot decrease (inc {v})"
        k = _label_key(labels)
        self.values[k] = self.values.get(k, 0.0) + v

    def value(self, **labels) -> float:
        return self.values.get(_label_key(labels), 0.0)

    def series(self):
        for k, v in self.values.items():
            yield k, {"value": v}


@dataclass
class Gauge(_Metric):
    """Last-written value per label set (plus the max ever seen, so peak
    residency/occupancy survives the final ``set`` of a drained pool)."""
    values: Dict[LabelKey, float] = field(default_factory=dict)
    peaks: Dict[LabelKey, float] = field(default_factory=dict)

    kind = "gauge"

    def set(self, v: float, **labels) -> None:
        k = _label_key(labels)
        self.values[k] = float(v)
        if v > self.peaks.get(k, -math.inf):
            self.peaks[k] = float(v)

    def inc(self, v: float = 1.0, **labels) -> None:
        k = _label_key(labels)
        self.set(self.values.get(k, 0.0) + v, **dict(k))

    def value(self, **labels) -> float:
        return self.values.get(_label_key(labels), 0.0)

    def peak(self, **labels) -> float:
        return self.peaks.get(_label_key(labels), 0.0)

    def series(self):
        for k, v in self.values.items():
            yield k, {"value": v, "peak": self.peaks[k]}


# default: exponential, 1 us .. ~16 s when observing seconds
_DEFAULT_BUCKETS = tuple(1e-6 * 4 ** i for i in range(13))


@dataclass
class Histogram(_Metric):
    """Cumulative-bucket histogram (+count/sum/min/max) per label set."""
    buckets: Tuple[float, ...] = _DEFAULT_BUCKETS
    values: Dict[LabelKey, dict] = field(default_factory=dict)

    kind = "histogram"

    def observe(self, v: float, **labels) -> None:
        k = _label_key(labels)
        s = self.values.get(k)
        if s is None:
            s = self.values[k] = {
                "count": 0, "sum": 0.0, "min": math.inf, "max": -math.inf,
                "bucket_counts": [0] * (len(self.buckets) + 1)}
        s["count"] += 1
        s["sum"] += v
        s["min"] = min(s["min"], v)
        s["max"] = max(s["max"], v)
        for i, le in enumerate(self.buckets):
            if v <= le:
                s["bucket_counts"][i] += 1
                break
        else:
            s["bucket_counts"][-1] += 1               # +Inf bucket

    def summary(self, **labels) -> Optional[dict]:
        return self.values.get(_label_key(labels))

    def series(self):
        for k, s in self.values.items():
            cum, out = 0, {}
            for le, n in zip(self.buckets, s["bucket_counts"]):
                cum += n
                out[f"{le:g}"] = cum
            out["+Inf"] = s["count"]
            yield k, {"count": s["count"], "sum": s["sum"],
                      "min": s["min"], "max": s["max"], "buckets": out}


class MetricsRegistry:
    """Process-local instrument registry with an in-process pull API
    (:meth:`snapshot`) and a JSONL snapshot writer (:meth:`write_jsonl`)."""

    def __init__(self):
        self._metrics: Dict[str, _Metric] = {}

    def _get(self, cls, name: str, help: str, **kw):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls(name=name, help=help, **kw)
        elif not isinstance(m, cls):
            raise TypeError(f"metric {name!r} already registered as "
                            f"{m.kind}, requested {cls.kind}")
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets: Optional[Tuple[float, ...]] = None) -> Histogram:
        if buckets is not None:
            return self._get(Histogram, name, help, buckets=tuple(buckets))
        return self._get(Histogram, name, help)

    def get(self, name: str) -> Optional[_Metric]:
        return self._metrics.get(name)

    def names(self) -> List[str]:
        return sorted(self._metrics)

    def clear(self) -> None:
        self._metrics.clear()

    # --------------------------------------------------------------- export
    def snapshot(self) -> List[dict]:
        """One dict per (metric, label set) — the in-process pull API and
        exactly what :meth:`write_jsonl` serializes."""
        out = []
        for name in sorted(self._metrics):
            m = self._metrics[name]
            for key, payload in m.series():
                rec = {"type": "metric", "name": name, "kind": m.kind,
                       "labels": dict(key)}
                if m.help:
                    rec["help"] = m.help
                rec.update(payload)
                out.append(rec)
        return out

    def write_jsonl(self, path_or_file) -> int:
        """Append the snapshot as JSON lines; returns lines written."""
        recs = self.snapshot()
        if hasattr(path_or_file, "write"):
            for r in recs:
                path_or_file.write(json.dumps(r, sort_keys=True) + "\n")
        else:
            with open(path_or_file, "a") as f:
                for r in recs:
                    f.write(json.dumps(r, sort_keys=True) + "\n")
        return len(recs)


_GLOBAL: Optional[MetricsRegistry] = None


def global_registry() -> MetricsRegistry:
    """The process-global default registry — for instruments that cannot
    thread a registry through their call sites (e.g. the frozen
    ``sharding.TreePlan``). Created lazily; never None."""
    global _GLOBAL
    if _GLOBAL is None:
        _GLOBAL = MetricsRegistry()
    return _GLOBAL


def set_global_registry(reg: Optional[MetricsRegistry]) -> MetricsRegistry:
    """Swap the global registry (tests; ``None`` resets to a fresh one).
    Returns the registry now installed."""
    global _GLOBAL
    _GLOBAL = reg if reg is not None else MetricsRegistry()
    return _GLOBAL
