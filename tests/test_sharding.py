"""Sharding rules: every emitted PartitionSpec must be divisibility-valid
for its leaf on the production meshes, for every architecture."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ASSIGNED_ARCHS, SHAPES, get_config
from repro.models import Model
from repro.sharding import ShardingStrategy, param_pspecs, zero_opt_pspecs
from repro.steps import make_train_step

# runs (also) in the CI multidevice job's forced-device topology
pytestmark = pytest.mark.multidevice


class FakeMesh:
    """Spec-validation stand-in (no devices needed)."""
    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


MESHES = [FakeMesh({"data": 16, "model": 16}),
          FakeMesh({"pod": 2, "data": 16, "model": 16})]


def _axsize(mesh, entry):
    if entry is None:
        return 1
    if isinstance(entry, str):
        return mesh.shape[entry]
    n = 1
    for a in entry:
        n *= mesh.shape[a]
    return n


def _validate(specs, shapes, mesh):
    flat_s = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    flat_l = jax.tree_util.tree_leaves(shapes)
    assert len(flat_s) == len(flat_l)
    for spec, leaf in zip(flat_s, flat_l):
        entries = list(spec) + [None] * (len(leaf.shape) - len(spec))
        used = []
        for dim, entry in zip(leaf.shape, entries):
            n = _axsize(mesh, entry)
            assert dim % n == 0, (spec, leaf.shape, entry)
            if entry is not None:
                es = entry if isinstance(entry, tuple) else (entry,)
                for e in es:
                    assert e not in used, f"axis reused {spec}"
                    used.append(e)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
@pytest.mark.parametrize("mesh", MESHES, ids=["1pod", "2pod"])
def test_param_specs_divisible(arch, mesh):
    cfg = get_config(arch)
    model = Model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    strat = ShardingStrategy()
    specs = param_pspecs(cfg, mesh, strat, shapes)
    _validate(specs, shapes, mesh)


@pytest.mark.parametrize("arch", ["llama3_2_3b", "deepseek_v3_671b",
                                  "granite_moe_3b_a800m"])
def test_zero1_opt_specs_divisible(arch):
    mesh = MESHES[0]
    cfg = get_config(arch)
    model = Model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    strat = ShardingStrategy(zero_stage=1)
    pspecs = param_pspecs(cfg, mesh, strat, shapes)
    ospecs = zero_opt_pspecs(pspecs, shapes, mesh, strat)
    _validate(ospecs, shapes, mesh)
    # ZeRO-1 must shard something over the DP domain that params don't
    flat_p = jax.tree_util.tree_leaves(pspecs,
                                       is_leaf=lambda x: isinstance(x, P))
    flat_o = jax.tree_util.tree_leaves(ospecs,
                                       is_leaf=lambda x: isinstance(x, P))
    assert any(po != oo for po, oo in zip(flat_p, flat_o))


def test_tp_shards_attention_and_experts():
    mesh = MESHES[0]
    cfg = get_config("deepseek_v3_671b")
    model = Model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = param_pspecs(cfg, mesh, ShardingStrategy(), shapes)
    # expert dim (256) is expert-parallel over model
    w_in_spec = specs["segment1"]["slot0"]["ffn"]["w_in"]
    assert "model" in jax.tree_util.tree_leaves(
        w_in_spec, is_leaf=lambda x: x is not None) or \
        w_in_spec[1] == "model"
