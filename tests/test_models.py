"""Per-architecture smoke tests: reduced variant (2 layers, d_model<=512,
<=4 experts) — one forward, one PPO train step, one decode step on CPU,
asserting shapes + finiteness; plus prefill+decode == full forward."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, PAPER_ARCHS, get_config
from repro.models import Model
from repro.steps import init_train_state, make_train_step

ALL_ARCHS = list(ASSIGNED_ARCHS) + list(PAPER_ARCHS)

# multi-minute archs (big scanned stacks / enc-dec) carry the `slow` mark
# on the compile-heavy tests: CI runs them in the dedicated -m slow job
_HEAVY_ARCHS = {"jamba_v0_1_52b", "deepseek_v3_671b", "seamless_m4t_large_v2"}


def _arch_params(archs):
    return [pytest.param(a, marks=pytest.mark.slow) if a in _HEAVY_ARCHS
            else a for a in archs]


def _smoke_cfg(arch):
    return get_config(arch).smoke()


def _batch_for(cfg, B, S, key, train=False):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.input_mode == "embeddings":
        batch["prefix_embeds"] = jnp.ones(
            (B, cfg.num_prefix_embeddings, cfg.d_model), jnp.float32) * 0.01
    if cfg.input_mode == "encdec":
        batch["frame_embeds"] = jnp.ones(
            (B, 16, cfg.d_model), jnp.float32) * 0.01
    if train:
        f = jnp.float32
        batch.update({
            "loss_mask": jnp.ones((B, S), f),
            "advantages": jax.random.normal(jax.random.fold_in(key, 1),
                                            (B, S)),
            "old_logp": -3.0 * jnp.ones((B, S), f),
            "ref_logp": -3.0 * jnp.ones((B, S), f),
            "returns": jnp.zeros((B, S), f),
        })
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward(arch):
    cfg = _smoke_cfg(arch)
    assert cfg.num_layers <= max(2, len(cfg.period))
    assert cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 32
    batch = _batch_for(cfg, B, S, jax.random.PRNGKey(1))
    logits, aux, h = model.forward(params, batch)
    P = cfg.num_prefix_embeddings if cfg.input_mode == "embeddings" else 0
    assert logits.shape == (B, P + S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", _arch_params(ALL_ARCHS))
def test_smoke_train_step(arch):
    cfg = _smoke_cfg(arch)
    model = Model(cfg)
    step = make_train_step(model, cfg, kind="ppo", lr=1e-4)
    state = init_train_state(model, cfg, jax.random.PRNGKey(0),
                             step.optimizer)
    B, S = 2, 32
    batch = _batch_for(cfg, B, S, jax.random.PRNGKey(1), train=True)
    new_state, metrics = jax.jit(step)(state, batch)
    assert all(bool(jnp.isfinite(v)) for v in metrics.values()), metrics
    delta = float(jnp.abs(new_state["params"]["embed"]
                          - state["params"]["embed"]).max())
    assert delta > 0, "parameters did not update"


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_decode_step(arch):
    cfg = _smoke_cfg(arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    batch = _batch_for(cfg, B, S, jax.random.PRNGKey(1))
    logits, caches = model.prefill(params, batch, capacity=48)
    assert logits.shape == (B, cfg.vocab_size)
    P = cfg.num_prefix_embeddings if cfg.input_mode == "embeddings" else 0
    tok = jnp.argmax(logits, -1)
    pos = jnp.full((B,), P + S, jnp.int32)
    lg, caches = model.decode_step(params, caches, tok, pos)
    assert lg.shape == (B, cfg.vocab_size)
    assert bool(jnp.isfinite(lg).all())


@pytest.mark.parametrize("arch", _arch_params([
    "llama3_2_3b", "mamba2_370m", "jamba_v0_1_52b", "deepseek_v3_671b",
    "seamless_m4t_large_v2", "internvl2_2b", "granite_moe_3b_a800m"]))
def test_decode_matches_forward(arch):
    """prefill+decode must reproduce the full-sequence forward logits."""
    cfg = _smoke_cfg(arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 17
    toks = jax.random.randint(jax.random.PRNGKey(42), (B, S + 1), 0,
                              cfg.vocab_size)
    full = _batch_for(cfg, B, S + 1, jax.random.PRNGKey(7))
    full["tokens"] = toks
    pre = dict(full, tokens=toks[:, :S])
    logits_full, _, _ = model.forward(params, full)
    P = cfg.num_prefix_embeddings if cfg.input_mode == "embeddings" else 0
    lg_pre, caches = model.prefill(params, pre, capacity=64)
    np.testing.assert_allclose(np.asarray(lg_pre),
                               np.asarray(logits_full[:, P + S - 1]),
                               atol=5e-5)
    pos = jnp.full((B,), P + S, jnp.int32)
    lg_dec, _ = model.decode_step(params, caches, toks[:, S], pos)
    np.testing.assert_allclose(np.asarray(lg_dec),
                               np.asarray(logits_full[:, P + S]), atol=5e-5)


def test_sliding_window_restricts_attention():
    cfg = dataclasses.replace(_smoke_cfg("llama3_2_3b"), sliding_window=8)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 32), 0,
                              cfg.vocab_size)
    lw, _, _ = model.forward(params, {"tokens": toks}, window=8)
    lf, _, _ = model.forward(params, {"tokens": toks}, window=0)
    # early positions agree (window covers full history), late differ
    assert float(jnp.abs(lw[:, 4] - lf[:, 4]).max()) < 1e-5
    assert float(jnp.abs(lw[:, -1] - lf[:, -1]).max()) > 1e-6


def test_mtp_logits_shape():
    cfg = _smoke_cfg("deepseek_v3_671b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    _, _, h = model.forward(params, {"tokens": toks})
    ml = model.mtp_logits(params, h, toks)
    assert ml.shape == (2, 16, cfg.vocab_size)
    assert bool(jnp.isfinite(ml).all())
