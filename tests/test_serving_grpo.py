"""Continuous-batching scheduler + GRPO trainer."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import Model
from repro.rlhf.grpo import GRPOConfig, GRPOTrainer
from repro.rlhf.reward import make_target_token_reward
from repro.serving import ContinuousBatcher


def _tiny_cfg():
    return dataclasses.replace(
        get_config("llama3_2_3b").smoke(), num_layers=2, d_model=128,
        d_ff=256, vocab_size=64, num_heads=4, num_kv_heads=2, head_dim=32)


def test_continuous_batcher_drains_all_requests():
    cfg = _tiny_cfg()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cb = ContinuousBatcher(model, cfg, params, slots=3, capacity=64)
    rng = np.random.RandomState(0)
    reqs = [cb.submit(rng.randint(0, 64, size=8), max_new_tokens=5 + i)
            for i in range(7)]
    done = cb.run_until_drained()
    assert len(done) == 7
    assert all(len(r.out_tokens) == r.max_new_tokens for r in done)
    assert all(r.done for r in reqs)
    # with 3 slots and 7 requests, batching must overlap: far fewer steps
    # than sum of lengths
    assert cb.steps < sum(5 + i for i in range(7))


def test_continuous_batcher_matches_isolated_decode():
    """A request served alongside others must produce the same tokens as
    the same request served alone (slot isolation)."""
    cfg = _tiny_cfg()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = np.arange(8) % cfg.vocab_size

    def greedy_run(slots, extra):
        cb = ContinuousBatcher(model, cfg, params, slots=slots,
                               capacity=64, temperature=0.0, seed=7)
        r = cb.submit(prompt, 10)
        rng = np.random.RandomState(1)
        for _ in range(extra):
            cb.submit(rng.randint(0, 64, size=8), 10)
        cb.run_until_drained()
        return r.out_tokens

    alone = greedy_run(1, 0)
    crowded = greedy_run(3, 2)
    assert alone == crowded


def test_continuous_batcher_eos_frees_slot():
    cfg = _tiny_cfg()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cb = ContinuousBatcher(model, cfg, params, slots=2, capacity=64,
                           temperature=1.0, eos_id=3, seed=3)
    for i in range(4):
        cb.submit((np.arange(8) + i) % cfg.vocab_size, 30)
    done = cb.run_until_drained()
    assert len(done) == 4
    for r in done:
        if 3 in r.out_tokens:
            assert r.out_tokens[-1] == 3 or len(r.out_tokens) == 30


@pytest.mark.slow
def test_grpo_improves_verifiable_reward():
    cfg = _tiny_cfg()
    rl = GRPOConfig(prompt_len=8, gen_len=12, group_size=8, lr=3e-3,
                    kl_coef=0.0)
    tr = GRPOTrainer(cfg, rl, jax.random.PRNGKey(0),
                     make_target_token_reward(7))
    key = jax.random.PRNGKey(1)
    rewards = []
    for step in range(18):
        k1, k2, key = jax.random.split(key, 3)
        prompts = jax.random.randint(k1, (4, 8), 0, cfg.vocab_size)
        m = tr.train_step(prompts, k2)
        rewards.append(m["mean_reward"])
    assert sum(rewards[-5:]) / 5 > sum(rewards[:5]) / 5 + 0.05, rewards
