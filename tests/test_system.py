"""End-to-end system behaviour: RLHF PPO improves a verifiable reward, LM
training reduces loss, rollout memory is flat, checkpoint round-trips, and
the tokenizer/data plumbing works (paper-claim assertions live in
test_paper_claims.py)."""
import dataclasses
import gc
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import ByteTokenizer, PromptDataset, SyntheticTextDataset, \
    synthetic_instruction_prompts
from repro.models import Model
from repro.rlhf import RLHFConfig, RLHFTrainer, Rollout, live_device_bytes
from repro.rlhf.reward import make_target_token_reward
from repro.steps import init_train_state, make_train_step


def _tiny_cfg():
    return dataclasses.replace(
        get_config("llama3_2_3b").smoke(), num_layers=2, d_model=128,
        d_ff=256, vocab_size=64, num_heads=4, num_kv_heads=2, head_dim=32)


def test_lm_training_reduces_loss():
    cfg = _tiny_cfg()
    model = Model(cfg)
    step = make_train_step(model, cfg, kind="lm", lr=3e-4)
    state = init_train_state(model, cfg, jax.random.PRNGKey(0),
                             step.optimizer)
    data = SyntheticTextDataset(cfg.vocab_size, 64, seed=0)
    jit_step = jax.jit(step, donate_argnums=(0,))
    losses = []
    for i, toks in zip(range(30), data.batches(8)):
        batch = {"tokens": jnp.asarray(toks),
                 "loss_mask": jnp.ones_like(jnp.asarray(toks), jnp.float32)}
        state, m = jit_step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.2, losses[::6]


@pytest.mark.slow
def test_rlhf_ppo_improves_verifiable_reward():
    cfg = _tiny_cfg()
    rl = RLHFConfig(prompt_len=8, gen_len=16, lr=3e-3, critic_lr=3e-3,
                    kl_coef=0.0, top_k=0)
    tr = RLHFTrainer(cfg, cfg, rl, jax.random.PRNGKey(0),
                     reward_fn=make_target_token_reward(7))
    key = jax.random.PRNGKey(1)
    rewards = []
    for step in range(25):
        k1, k2, key = jax.random.split(key, 3)
        prompts = jax.random.randint(k1, (16, rl.prompt_len), 0,
                                     cfg.vocab_size)
        m = tr.train_step(prompts, k2)
        rewards.append(m["mean_reward"])
    # random baseline is 1/64 ~ 0.016; PPO should at least triple it
    assert sum(rewards[-5:]) / 5 > 0.05, [round(r, 3) for r in rewards]
    # 7 phase boundaries per iteration (rollout + 4 scores + 2 trains)
    assert len(tr.memory.records) == 25 * 7


def test_rollout_memory_is_flat():
    """Fixed-capacity donated cache: live bytes must not grow across
    requests (the framework-level fix for the paper's App-B pathology)."""
    cfg = _tiny_cfg()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ro = Rollout(model, cfg, capacity=48, temperature=1.0)
    key = jax.random.PRNGKey(1)
    livest = []
    for r in range(4):
        key, k = jax.random.split(key)
        prompts = jax.random.randint(k, (4, 16), 0, cfg.vocab_size)
        res = ro.generate(params, {"tokens": prompts}, 32, k)
        del res
        gc.collect()
        livest.append(live_device_bytes())
    assert livest[-1] <= livest[1] * 1.05, livest


def test_rollout_respects_eos():
    cfg = _tiny_cfg()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ro = Rollout(model, cfg, capacity=48, temperature=1.0, eos_id=3)
    k = jax.random.PRNGKey(5)
    prompts = jax.random.randint(k, (8, 8), 0, cfg.vocab_size)
    res = ro.generate(params, {"tokens": prompts}, 24, k)
    toks = np.asarray(res.tokens)
    mask = np.asarray(res.mask)
    for b in range(toks.shape[0]):
        gen = toks[b, 8:]
        eos_pos = np.where(gen == 3)[0]
        if len(eos_pos):
            assert mask[b, 8 + eos_pos[0] + 1:].sum() == 0


def test_checkpoint_roundtrip():
    from repro.checkpoint import latest_step, restore, save
    cfg = _tiny_cfg()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    with tempfile.TemporaryDirectory() as d:
        save(d, 7, params)
        assert latest_step(d) == 7
        like = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        back = restore(d, 7, like)
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(back)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_tokenizer_roundtrip():
    tok = ByteTokenizer()
    s = "Understanding RLHF memory 😀"
    assert tok.decode(tok.encode(s)) == s
    assert len(tok.pad_to(tok.encode(s), 64)) == 64


def test_prompt_dataset_batches():
    ds = PromptDataset(synthetic_instruction_prompts(16), 24)
    b = next(ds.batches(4))
    assert b.shape == (4, 24)
    assert b.dtype == np.int32


def test_experience_buffer_minibatches():
    from repro.rlhf import ExperienceBuffer
    buf = ExperienceBuffer()
    for i in range(3):
        buf.add({"tokens": jnp.full((4, 8), i, jnp.int32),
                 "advantages": jnp.ones((4, 8))})
    assert len(buf) == 12
    mbs = list(buf.minibatches(6, jax.random.PRNGKey(0), epochs=2))
    assert len(mbs) == 4
    assert mbs[0]["tokens"].shape == (6, 8)
    buf.clear()
    assert len(buf) == 0
