"""Minimal, deterministic stand-in for ``hypothesis`` (loaded by conftest
only when the real package is not installed — `pip install -e .[test]`
gets the real one).

Covers exactly the surface the test suite uses: ``given``/``settings`` and
``strategies.{integers, booleans, tuples, lists, randoms}``. Examples are
drawn from seeded ``random.Random`` streams, so runs are reproducible; the
stub does no shrinking — a failing example is reported as-is by pytest.
"""
from __future__ import annotations

import functools
import inspect
import random
import types

DEFAULT_MAX_EXAMPLES = 25
_SEED = 0xC0FFEE


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rnd: random.Random):
        return self._draw(rnd)


def integers(min_value=0, max_value=1 << 16):
    return _Strategy(lambda r: r.randint(min_value, max_value))


def booleans():
    return _Strategy(lambda r: r.random() < 0.5)


def floats(min_value=0.0, max_value=1.0, **_ignored):
    return _Strategy(lambda r: r.uniform(min_value, max_value))


def tuples(*ss):
    return _Strategy(lambda r: tuple(s.draw(r) for s in ss))


def lists(elements, min_size=0, max_size=16):
    def draw(r):
        n = r.randint(min_size, max_size)
        return [elements.draw(r) for _ in range(n)]
    return _Strategy(draw)


def sampled_from(seq):
    return _Strategy(lambda r: r.choice(list(seq)))


def randoms():
    return _Strategy(lambda r: random.Random(r.randint(0, 1 << 30)))


def given(*strategies_args):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_max_examples", DEFAULT_MAX_EXAMPLES)
            for i in range(n):
                rnd = random.Random(_SEED + i)
                drawn = [s.draw(rnd) for s in strategies_args]
                fn(*args, *drawn, **kwargs)
        # mirror the real attribute shape; pytest plugins peek at
        # fn.hypothesis.inner_test, and the strategy-filled params must be
        # hidden from pytest's fixture resolution
        wrapper.hypothesis = types.SimpleNamespace(inner_test=fn)
        del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature([])
        return wrapper
    return deco


def settings(max_examples=DEFAULT_MAX_EXAMPLES, deadline=None, **_ignored):
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco


strategies = types.ModuleType("hypothesis.strategies")
for _name in ("integers", "booleans", "floats", "tuples", "lists",
              "sampled_from", "randoms"):
    setattr(strategies, _name, globals()[_name])
