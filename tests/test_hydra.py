"""Hydra shared-base engine: adapter correctness (merged vs unmerged,
rank-0 identity), frozen-base PPO training, phase-memory policies, and the
exact trainable-fraction accounting."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import Model
from repro.models import lora as LORA
from repro.rlhf import (MEMORY_POLICIES, ModelEngine, PhaseMemoryManager,
                        RLHFConfig, RLHFTrainer)
from repro.rlhf.reward import make_target_token_reward


def small_cfg(**kw):
    base = dict(num_layers=2, d_model=64, d_ff=128, vocab_size=64,
                num_heads=4, num_kv_heads=2, head_dim=16)
    base.update(kw)
    return dataclasses.replace(get_config("llama3_2_3b").smoke(), **base)


def randomized_adapter(model, params, rank, key, with_value=False):
    """Adapter with nonzero B (so the delta actually changes the forward)."""
    ad = model.init_adapter(key, params, rank, with_value=with_value)
    leaves, treedef = jax.tree.flatten(ad)
    ks = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(treedef, [
        0.05 * jax.random.normal(k, l.shape, l.dtype)
        for k, l in zip(ks, leaves)])


@pytest.fixture(scope="module")
def setup():
    cfg = small_cfg()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    adapter = randomized_adapter(model, params, 4, jax.random.PRNGKey(1))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(2), (2, 12),
                                          0, cfg.vocab_size)}
    return cfg, model, params, adapter, batch


def test_merged_vs_unmerged_forward_equivalence(setup):
    cfg, model, params, adapter, batch = setup
    unmerged, _, _ = model.forward(params, batch, adapter=adapter)
    merged = model.merge_adapter(params, adapter)
    merged_lg, _, _ = model.forward(merged, batch)
    np.testing.assert_allclose(np.asarray(unmerged), np.asarray(merged_lg),
                               atol=2e-5)
    # the adapter actually does something
    base_lg, _, _ = model.forward(params, batch)
    assert float(jnp.abs(unmerged - base_lg).max()) > 1e-3


def test_unmerge_restores_base(setup):
    cfg, model, params, adapter, batch = setup
    merged = model.merge_adapter(params, adapter)
    restored = model.unmerge_adapter(merged, adapter)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_merged_leaves_are_exactly_the_adapted_sites(setup):
    cfg, model, params, adapter, batch = setup
    merged = model.merge_adapter(params, adapter)
    fresh = LORA.merged_leaves(merged, adapter["lora"])
    n_sites = len(jax.tree.leaves(adapter["lora"])) // 2   # a+b per site
    assert len(fresh) == n_sites
    base_ids = {id(l) for l in jax.tree.leaves(params)}
    assert all(id(l) not in base_ids for l in fresh)
    # non-adapted leaves of the merged tree alias the base (no copy)
    n_aliased = sum(id(l) in base_ids for l in jax.tree.leaves(merged))
    assert n_aliased == len(jax.tree.leaves(params)) - n_sites


def test_rank0_adapter_is_base_forward(setup):
    cfg, model, params, _, batch = setup
    ad0 = model.init_adapter(jax.random.PRNGKey(3), params, 0,
                             with_value=True)
    assert ad0["lora"] == {}
    lg0, _, _ = model.forward(params, batch, adapter=ad0)
    lg_base, _, _ = model.forward(params, batch)
    assert bool(jnp.array_equal(lg0, lg_base))
    # merge with an empty lora tree is the identity
    assert model.merge_adapter(params, ad0) is not params  # new dict shell
    for a, b in zip(jax.tree.leaves(params),
                    jax.tree.leaves(model.merge_adapter(params, ad0))):
        assert a is b


def test_adapter_decode_matches_adapter_forward(setup):
    """Greedy decode with the unmerged adapter == teacher-forced adapter
    forward argmax (the decode_step adapter path)."""
    cfg, model, params, adapter, batch = setup
    P = batch["tokens"].shape[1]
    logits_pf, caches = model.prefill(params, batch, P + 4, adapter=adapter)
    toks = [jnp.argmax(logits_pf, -1).astype(jnp.int32)]
    for t in range(3):
        pos = jnp.full((2,), P + t, jnp.int32)
        lg, caches = model.decode_step(params, caches, toks[-1], pos,
                                       adapter=adapter)
        toks.append(jnp.argmax(lg, -1).astype(jnp.int32))
    full = jnp.concatenate([batch["tokens"], jnp.stack(toks[:-1], 1)], 1)
    lg_full, _, _ = model.forward(params, {"tokens": full}, adapter=adapter)
    greedy = jnp.argmax(lg_full[:, P - 1:], -1)
    np.testing.assert_array_equal(np.asarray(jnp.stack(toks, 1)),
                                  np.asarray(greedy))


def test_paged_decode_adapter_matches_dense(setup):
    cfg, model, params, adapter, batch = setup
    assert model.supports_paged()
    B, P = batch["tokens"].shape
    ps, nb = 4, -(-(P + 1) // 4)
    pools = model.init_paged_pools(B * nb, ps, jnp.float32)
    bt = jnp.arange(B * nb, dtype=jnp.int32).reshape(B, nb)
    _, pools = model.paged_prefill(params, batch, pools, bt,
                                   jnp.full((B,), P, jnp.int32),
                                   adapter=adapter)
    tok = jnp.zeros((B,), jnp.int32)
    pos = jnp.full((B,), P, jnp.int32)
    lg_paged, _ = model.paged_decode_step(params, pools, tok, pos, bt,
                                          adapter=adapter)
    _, caches = model.prefill(params, batch, P + 1, adapter=adapter)
    lg_dense, _ = model.decode_step(params, caches, tok, pos,
                                    adapter=adapter)
    np.testing.assert_allclose(np.asarray(lg_paged), np.asarray(lg_dense),
                               atol=2e-5)


@pytest.mark.slow
def test_hydra_ppo_base_frozen_adapters_move():
    """2-step PPO smoke on engine="hydra": the base tree is bit-identical
    before/after — only the adapters (and their opt states) moved."""
    cfg = small_cfg()
    rl = RLHFConfig(prompt_len=8, gen_len=8, lr=3e-3, critic_lr=3e-3,
                    kl_coef=0.0, top_k=0, engine="hydra", lora_rank=4)
    tr = RLHFTrainer(cfg, cfg, rl, jax.random.PRNGKey(0),
                     reward_fn=make_target_token_reward(7))
    base_before = jax.tree.map(lambda x: np.asarray(x).copy(),
                               tr.base_params)
    actor_before = jax.tree.map(lambda x: np.asarray(x).copy(),
                                tr.actor_state["params"])
    key = jax.random.PRNGKey(1)
    prompts = jax.random.randint(key, (4, 8), 0, cfg.vocab_size)
    for s in range(2):
        metrics = tr.train_step(prompts, jax.random.fold_in(key, s))
    assert np.isfinite(metrics["loss"])
    for a, b in zip(jax.tree.leaves(base_before),
                    jax.tree.leaves(tr.base_params)):
        assert np.array_equal(a, np.asarray(b)), "frozen base moved!"
    moved = any(not np.array_equal(a, np.asarray(b))
                for a, b in zip(jax.tree.leaves(actor_before),
                                jax.tree.leaves(tr.actor_state["params"])))
    assert moved, "actor adapter never trained"
    # ref IS the base — no separate copy
    assert tr.ref_params is tr.base_params
    # the donated steps must not leave the engine's adapter view pointing
    # at deleted buffers: it tracks the live trained values
    for role in ("actor", "critic"):
        for leaf in jax.tree.leaves(tr.engine.adapters[role]):
            assert not leaf.is_deleted(), f"{role} adapter view was donated"
    assert tr.engine.adapters["actor"] is tr.actor_state["params"]
    assert tr.engine.adapters["critic"] is tr.critic_state["params"]


def test_separate_reward_seeded_from_critic_init():
    cfg = small_cfg()
    rl = RLHFConfig(prompt_len=8, gen_len=8, engine="separate")
    tr = RLHFTrainer(cfg, cfg, rl, jax.random.PRNGKey(0),
                     reward_fn=make_target_token_reward(7))
    for a, b in zip(jax.tree.leaves(tr.reward_params),
                    jax.tree.leaves(tr.critic_state["params"])):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_engine_memory_accounting_and_fraction():
    cfg = small_cfg(d_model=128, d_ff=256, head_dim=32)
    eng = ModelEngine(cfg, jax.random.PRNGKey(0), rank=8)
    from repro.core import lora_trainable_fraction
    assert eng.trainable_fraction("actor") == pytest.approx(
        lora_trainable_fraction(cfg, 8), rel=0.05)
    acc = eng.memory_accounting()
    hy = sum(r["params"] + r["opt"] for r in acc["hydra"].values())
    sep = sum(r["params"] + r["opt"] for r in acc["separate"].values())
    assert hy < 0.6 * sep
    # reward adapter is seeded from the critic adapter init
    for a, b in zip(jax.tree.leaves(eng.adapters["reward"]),
                    jax.tree.leaves(eng.adapters["critic"])):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_memory_manager_validates_policy():
    with pytest.raises(ValueError):
        PhaseMemoryManager(policy="after_lunch")


@pytest.mark.parametrize("policy", MEMORY_POLICIES)
def test_memory_manager_all_policies_record(policy):
    mm = PhaseMemoryManager(policy=policy)
    dead = jnp.ones((16,))
    mm.boundary("rollout", "inference", {"x": dead})
    assert dead.is_deleted()
    mm.boundary("train_actor", "training")
    assert [r["phase"] for r in mm.records] == ["rollout", "train_actor"]
    assert all(r["live_bytes"] >= 0 for r in mm.records)
