"""jaxpr liveness tracer: event balance, remat effect, scan semantics."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core.trace import Trace, trace_function
from repro.models import Model
from repro.steps import init_train_state, make_train_step


def _train_trace(remat, num_layers=4, d=128, B=2, S=64, min_bytes=512):
    cfg = dataclasses.replace(
        get_config("opt_1_3b").smoke(), num_layers=num_layers, d_model=d,
        d_ff=2 * d, vocab_size=256, remat=remat)
    m = Model(cfg)
    ts = make_train_step(m, cfg, kind="ppo")
    state = jax.eval_shape(
        lambda k: init_train_state(m, cfg, k, ts.optimizer),
        jax.random.PRNGKey(0))
    batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    for k in ("loss_mask", "advantages", "old_logp", "ref_logp", "returns"):
        batch[k] = jax.ShapeDtypeStruct((B, S), jnp.float32)
    tags = ({"params": jax.tree.map(lambda _: "param", state["params"]),
             "opt": jax.tree.map(lambda _: "opt", state["opt"]),
             "step": "opt"},
            jax.tree.map(lambda _: "input", batch))
    return trace_function(ts, (state, batch), tags, min_bytes=min_bytes)


def _check_balance(tr: Trace):
    live = {}
    for op, vid, nb, tag in tr.events:
        if op == "alloc":
            assert vid not in live, f"double alloc {vid}"
            live[vid] = nb
        else:
            assert vid in live, f"free of unallocated {vid}"
            assert live.pop(vid) == nb, f"size mismatch on free {vid}"
    return live


def test_trace_balanced():
    tr = _train_trace("none")
    leftovers = _check_balance(tr)
    # only the step outputs stay live
    assert len(leftovers) < 100


def test_remat_reduces_peak():
    t_none = _train_trace("none", num_layers=8, d=256, S=256)
    t_full = _train_trace("full", num_layers=8, d=256, S=256)
    assert t_full.peak_live() < 0.6 * t_none.peak_live(), (
        t_full.peak_live(), t_none.peak_live())
    # ... while total churn (recompute) goes up
    assert t_full.total_alloc_bytes() > t_none.total_alloc_bytes()


def test_layer_slices_emitted_per_scan_iteration():
    tr = _train_trace("none", num_layers=6)
    slices = [e for e in tr.events if e[0] == "alloc" and e[3] == "layer_slice"]
    # at least one slice per layer for fwd and bwd scans
    assert len(slices) >= 12


def test_grad_tagging():
    tr = _train_trace("none")
    tags = {e[3] for e in tr.events}
    assert "grad" in tags
    assert "temp" in tags


def test_scan_trace_scales_with_length():
    tr4 = _train_trace("none", num_layers=4)
    tr8 = _train_trace("none", num_layers=8)
    assert tr8.total_alloc_bytes() > 1.5 * tr4.total_alloc_bytes()
