"""Mesh-sharded ZeRO RLHF engines: bit-identity, per-device accounting,
and offload composition. Heavy runtime checks run in subprocesses with
forced host devices (the flag must be set before jax initializes); the
spec-level checks (adapter rules, traced scales, the strategy grid) run
in-process with no devices needed."""
import os
import subprocess
import sys
import textwrap

import pytest

# runs (also) in the CI multidevice job's forced-device topology
pytestmark = pytest.mark.multidevice

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# The runtime smokes spawn multi-trainer subprocesses (minutes each); they
# run in the CI `multidevice` job, whose environment forces host devices.
# The spec-level tests below always run.
runtime_smoke = pytest.mark.skipif(
    "host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""),
    reason="runtime ZeRO smokes run in the multidevice CI job (set "
           "XLA_FLAGS=--xla_force_host_platform_device_count=8 to enable)")


def _run(code: str, devices: int = 8, timeout: int = 1200):
    env = dict(os.environ,
               PYTHONPATH=os.path.join(_ROOT, "src"),
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, \
        f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    return r.stdout


_SMOKE_PRELUDE = """
    import dataclasses, jax, jax.numpy as jnp
    from repro.configs import get_config
    from repro.rlhf import RLHFConfig, RLHFTrainer
    from repro.rlhf.reward import make_target_token_reward
    from repro.sharding import ShardedContext

    cfg = dataclasses.replace(
        get_config("llama3_2_3b").smoke(), num_layers=2, d_model=128,
        d_ff=256, vocab_size=64, num_heads=4, num_kv_heads=2, head_dim=32)
    P, G, B = 8, 12, 4
    key = jax.random.PRNGKey(1)
    prompts = jax.random.randint(key, (B, P), 0, cfg.vocab_size)

    def run(engine, shard, offload="none", steps=2):
        rl = RLHFConfig(prompt_len=P, gen_len=G, lr=1e-3, critic_lr=1e-3,
                        kl_coef=0.0, top_k=0, engine=engine, lora_rank=8,
                        offload=offload)
        tr = RLHFTrainer(cfg, cfg, rl, jax.random.PRNGKey(0),
                         reward_fn=make_target_token_reward(7), shard=shard)
        ms = [tr.train_step(prompts, jax.random.fold_in(key, s))
              for s in range(steps)]
        return tr, ms

    def assert_biteq(m1, m2, label):
        for a, b in zip(m1, m2):
            for k in ("loss", "ppo_loss", "vf_loss"):
                if k in a:
                    assert a[k] == b[k], (label, k, a[k], b[k])
"""


@runtime_smoke
@pytest.mark.parametrize("engine", ["separate", "hydra"])
@pytest.mark.parametrize("zero_stage", [1, 3])
def test_ppo_bit_identity(engine, zero_stage):
    """2-step PPO losses bit-identical between ndp=1 and ndp=8 at every
    ZeRO stage, both engines."""
    _run(_SMOKE_PRELUDE + f"""
    tr1, m1 = run("{engine}", None)
    sc = ShardedContext.create(8, zero_stage={zero_stage})
    tr8, m8 = run("{engine}", sc)
    assert_biteq(m1, m8, "{engine}-z{zero_stage}")
    b1, b8 = tr1.per_device_state_bytes(), tr8.per_device_state_bytes()
    assert b8 < b1, (b8, b1)   # every stage must cut per-device state
    print("OK", b1, b8)
    """)


@runtime_smoke
def test_zero3_per_device_cut_separate():
    """ZeRO-3 per-device param+opt bytes <= 30% of the replicated figure
    (which per device equals the ndp=1 total) for the separate engine."""
    _run(_SMOKE_PRELUDE + """
    tr1, _ = run("separate", None, steps=1)
    sc = ShardedContext.create(8, zero_stage=3)
    tr8, _ = run("separate", sc, steps=1)
    b1, b8 = tr1.per_device_state_bytes(), tr8.per_device_state_bytes()
    assert b8 <= 0.30 * b1, (b8, b1)
    print("cut to", 100 * b8 / b1, "%")
    """)


@runtime_smoke
def test_offload_composes_with_zero3():
    """offload="all" over ZeRO-3-sharded state: losses still bit-equal to
    the unsharded baseline, and the parking lot round-trips the shards
    sharding-intact (fetch restores the 1/ndp per-device layout)."""
    _run(_SMOKE_PRELUDE + """
    from repro.sharding import tree_per_device_bytes
    tr1, m1 = run("hydra", None)
    sc = ShardedContext.create(8, zero_stage=3)
    tro, mo = run("hydra", sc, offload="all")
    assert_biteq(m1, mo, "hydra-z3-offload")
    # after the final boundary the actor adapter is device-resident and
    # must still be ZeRO-sharded, not gathered by the host round trip
    spd = tree_per_device_bytes(tro.base_params)
    tot = sum(l.nbytes for l in jax.tree.leaves(tro.base_params))
    assert spd < tot, (spd, tot)
    print("OK parked/fetched sharded", spd, tot)
    """)


@runtime_smoke
def test_sharded_rollout_paged_and_dense():
    """Greedy rollout under the mesh — dense AND paged decode — matches
    the unsharded tokens on the separate engine."""
    _run(_SMOKE_PRELUDE + """
    from repro.rlhf import Rollout
    tr1, _ = run("separate", None, steps=1)
    sc = ShardedContext.create(8, zero_stage=3)
    tr8, _ = run("separate", sc, steps=1)
    tok1 = Rollout(tr1.actor, cfg, capacity=P + G, temperature=0.0,
                   top_k=0).generate(tr1.actor_state["params"],
                                     {"tokens": prompts}, G, key).tokens
    p8, owned = tr8.actor_plan.gather_copy(tr8.actor_state["params"])
    assert owned     # ZeRO-3: a fresh copy the caller must delete
    for backend in ("dense", "paged"):
        ro = Rollout(tr8.actor, cfg, capacity=P + G, temperature=0.0,
                     top_k=0, backend=backend).generate(
            p8, {"tokens": prompts}, G, key)
        assert bool(jnp.array_equal(tok1, ro.tokens)), backend
    print("rollout identical (dense+paged)")
    """)


# ---------------------------------------------------------------------------
# Spec-level checks: no devices needed
# ---------------------------------------------------------------------------
def test_adapter_pspecs_rules():
    import jax
    from jax.sharding import PartitionSpec as P

    from repro.configs import get_config
    from repro.models import Model
    from repro.sharding import ShardingStrategy, SpecMesh, adapter_pspecs

    cfg = get_config("llama3_2_3b")
    model = Model(cfg)
    key = jax.random.PRNGKey(0)
    base = jax.eval_shape(model.init, key)
    ad = jax.eval_shape(
        lambda k: model.init_adapter(k, base, 128, with_value=True), key)
    mesh = SpecMesh({"data": 8})
    specs = adapter_pspecs(mesh, ShardingStrategy(zero_stage=3), ad)
    flat = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, P))[0]
    leaves = jax.tree_util.tree_flatten_with_path(ad)[0]
    n_sharded = 0
    for (kp, spec), (_, leaf) in zip(flat, leaves):
        path = tuple(str(getattr(k, "key", k)) for k in kp)
        entries = list(spec) + [None] * (len(leaf.shape) - len(spec))
        for dim, e in zip(leaf.shape, entries):
            if e is not None:
                n = mesh.shape[e] if isinstance(e, str) else \
                    __import__("math").prod(mesh.shape[a] for a in e)
                assert dim % n == 0, (path, spec, leaf.shape)
                n_sharded += 1
        if "value_head" in path:
            assert all(e is None for e in entries), (path, spec)
    assert n_sharded > 0, "ZeRO-3 must shard some adapter leaves"
    # below stage 3 the adapter replicates entirely
    specs1 = adapter_pspecs(mesh, ShardingStrategy(zero_stage=1), ad)
    for spec in jax.tree.leaves(specs1,
                                is_leaf=lambda x: isinstance(x, P)):
        assert all(e is None for e in spec), spec


def test_zero_opt_pspecs_stage0_replicated():
    import jax
    from jax.sharding import PartitionSpec as P

    from repro.configs import get_config
    from repro.models import Model
    from repro.sharding import (ShardingStrategy, SpecMesh, param_pspecs,
                                zero_opt_pspecs)

    cfg = get_config("llama3_2_3b")
    shapes = jax.eval_shape(Model(cfg).init, jax.random.PRNGKey(0))
    mesh = SpecMesh({"data": 8})
    strat = ShardingStrategy(zero_stage=0, tensor_parallel=False)
    pspecs = param_pspecs(cfg, mesh, strat, shapes)
    ospecs = zero_opt_pspecs(pspecs, shapes, mesh, strat)
    for spec in jax.tree.leaves(ospecs, is_leaf=lambda x: isinstance(x, P)):
        assert all(e is None for e in spec), spec


@pytest.mark.parametrize("engine", ["separate", "hydra"])
@pytest.mark.parametrize("zero_stage", [0, 1, 2, 3])
@pytest.mark.parametrize("offload", ["none", "all"])
def test_scale_agrees_with_sharded_accounting(engine, zero_stage, offload):
    """Grid: MemoryStrategy.scale's closed-form 1/ndp model must agree
    with the real sharded per-device byte accounting (traced from the
    actual spec trees) for every persistent state group — up to the
    leaves the rules cannot shard (norms, value heads, small biases),
    which only ever push the real figure *above* the closed form."""
    import dataclasses as dc

    import jax

    from repro.configs import get_config
    from repro.core import (MemoryStrategy, build_rlhf_phases,
                            run_iteration, traced_strategy)

    ndp = 8
    cfg = dc.replace(
        get_config("llama3_2_3b").smoke(), num_layers=2, d_model=1024,
        d_ff=2048, vocab_size=64, num_heads=8, num_kv_heads=4, head_dim=128)
    strat = MemoryStrategy(f"Z{zero_stage}", zero_stage=zero_stage,
                           offload=offload)
    tstrat = traced_strategy(strat, cfg, cfg, ndp=ndp, engine=engine,
                             lora_rank=16)
    ph, persist = build_rlhf_phases(cfg, cfg, batch=2, prompt_len=8,
                                    gen_len=8, engine=engine, lora_rank=16,
                                    min_bytes=2048)
    traced = dict(tstrat.traced)
    for name, bufs in persist.buffers.items():
        for tag in {t for _, t in bufs}:
            closed = strat.scale(tag, ndp=ndp)
            real = traced.get(f"{name}:{tag}", traced.get(tag, 1.0))
            if name == "merged_rollout":
                assert real == 1.0      # gathered copy, ndp-independent
                continue
            # real >= closed (unshardable leaves), within 2x for the
            # big-2D-dominated trees of this config
            assert real >= closed - 1e-9, (name, tag, real, closed)
            assert real <= max(2.0 * closed, 0.02), \
                (name, tag, real, closed)
    # the traced simulator run exists and orders correctly: offload only
    # ever lowers the peak, sharding only ever lowers per-device bytes
    r = run_iteration(ph, persist, tstrat, "none", ndp=ndp,
                      trainable_fraction=1.0, capacity=None)
    r0 = run_iteration(ph, persist, dc.replace(tstrat, offload="none"),
                       "none", ndp=ndp, trainable_fraction=1.0,
                       capacity=None)
    assert r.peak_allocated <= r0.peak_allocated + 1
    if zero_stage >= 3 and offload == "none":
        rrep = run_iteration(
            ph, persist,
            traced_strategy(MemoryStrategy("Z0"), cfg, cfg, ndp=ndp,
                            engine=engine, lora_rank=16),
            "none", ndp=ndp, trainable_fraction=1.0, capacity=None)
        assert r.peak_allocated < rrep.peak_allocated
