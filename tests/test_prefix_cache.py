"""Cross-request prefix caching: page-index semantics (park / revive /
evict / invalidate), warm-suffix-prefill bit-identity against cold
prefill, greedy serving identity with the cache on vs off (base and hydra
merged weights), and multi-tenant fairness under adversarial arrivals."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.models import Model
from repro.paged import PageManager, PagePoolExhausted
from repro.serving import ContinuousBatcher


def _tiny_cfg():
    return dataclasses.replace(
        get_config("llama3_2_3b").smoke(), num_layers=2, d_model=128,
        d_ff=256, vocab_size=64, num_heads=4, num_kv_heads=2, head_dim=32)


# ---------------------------------------------------------------------------
# PageManager prefix-index semantics
# ---------------------------------------------------------------------------
def test_commit_and_match_prefix():
    pm = PageManager(8, 4)
    toks = np.arange(12)
    pm.allocate(0, 12)
    assert pm.commit_prefix(0, toks) == 3        # 3 full pages indexed
    # a longer prompt sharing the prefix matches all three committed pages
    probe = np.concatenate([toks, [7, 7]])
    pages, n = pm.match_prefix(probe)
    assert n == 12 and pages == pm.block_table(0)
    # the same 12-token prompt only matches up to the hashable cap (the
    # final prompt token is always recomputed — its logits seed decoding)
    assert pm.hashable_prefix_tokens(12) == 8
    _, n = pm.match_prefix(toks)
    assert n == 8
    # a diverging prompt matches nothing past the divergence point
    _, n = pm.match_prefix(np.concatenate([toks[:4], [9] * 8]))
    assert n == 4
    pm.check_invariants()


def test_allocate_prefix_shares_pages_and_counts_hits():
    pm = PageManager(8, 4)
    toks = np.arange(12)
    pm.allocate(0, 12)
    pm.commit_prefix(0, toks)
    probe = np.concatenate([toks, [7, 7]]).astype(np.int64)
    bt, n_cached = pm.allocate_prefix(1, probe)
    assert n_cached == 12
    assert bt[:3] == pm.block_table(0)           # shared, not re-allocated
    assert all(pm._refcount[p] == 2 for p in bt[:3])
    assert pm.stats.n_prefix_hits == 3 and pm.stats.n_prefix_queries == 1
    pm.check_invariants()


def test_freed_indexed_pages_park_and_revive_without_refill():
    pm = PageManager(8, 4)
    toks = np.arange(8)
    pm.allocate(0, 8)
    pm.commit_prefix(0, toks)
    frees_before = pm.stats.n_page_free
    pm.free_seq(0)
    # indexed pages park in the LRU: still resident, free event deferred
    assert pm.num_cached_pages == 2
    assert pm.stats.pages_in_use == 2
    assert pm.stats.n_page_free == frees_before
    # a matching request revives them — refcount bump, no fresh allocation
    allocs_before = pm.stats.n_page_alloc
    probe = np.concatenate([toks, [3]])
    _, n_cached = pm.allocate_prefix(1, probe)
    assert n_cached == 8 and pm.num_cached_pages == 0
    assert pm.stats.n_page_alloc == allocs_before + 1   # just the tail page
    pm.check_invariants()


def test_eviction_reclaims_only_zero_ref_parked_pages():
    pm = PageManager(4, 4)
    pm.allocate(0, 8)
    pm.commit_prefix(0, np.arange(8))
    pm.free_seq(0)                               # 2 parked, 2 free
    live_bt = pm.allocate(1, 8)                  # claims the 2 free pages
    assert pm.num_cached_pages == 2 and pm.num_free_pages == 0
    # pool pressure: fresh allocation evicts the parked pages, never the
    # live sequence's
    pm.allocate(2, 8)
    assert pm.stats.n_prefix_evictions == 2
    assert pm.block_table(1) == live_bt
    assert all(pm._refcount[p] == 1 for p in live_bt)
    # everything is referenced now — exhaustion, not eviction
    with pytest.raises(PagePoolExhausted):
        pm.allocate(3, 4)
    assert pm.stats.n_prefix_evictions == 2
    pm.check_invariants()


def test_weight_version_bump_invalidates_cached_prefixes():
    pm = PageManager(8, 4)
    toks = np.arange(8)
    pm.allocate(0, 8)
    pm.commit_prefix(0, toks)
    pm.free_seq(0)
    assert pm.num_cached_pages == 2
    pm.set_weight_version(1)
    # parked pages are truly freed, the index is empty
    assert pm.num_cached_pages == 0 and pm.num_free_pages == 8
    assert pm.match_prefix(np.concatenate([toks, [3]]))[1] == 0
    assert pm.stats.n_prefix_invalidations == 1
    pm.set_weight_version(1)                     # same version: no-op
    assert pm.stats.n_prefix_invalidations == 1
    # a live sequence survives invalidation with its pages intact
    bt = pm.allocate(1, 8)
    pm.commit_prefix(1, toks)
    pm.set_weight_version(2)
    assert pm.block_table(1) == bt
    assert pm.match_prefix(np.concatenate([toks, [3]]))[1] == 0
    pm.check_invariants()


def test_sole_owner_mutation_deindexes_page():
    pm = PageManager(8, 4)
    toks = np.arange(12)
    pm.allocate(0, 12)
    pm.commit_prefix(0, toks)
    probe = np.concatenate([toks, [9]])
    assert pm.match_prefix(probe)[1] == 12
    # truncate into the last indexed page, then append: the digest no
    # longer describes the content, so the page must leave the index
    pm.truncate(0, 9)
    pm.append_token(0)
    assert pm.match_prefix(probe)[1] == 8
    pm.check_invariants()


@settings(max_examples=40, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 6), st.integers(0, 19)),
                min_size=1, max_size=80))
def test_prefix_cache_invariants_random_traffic(ops):
    """Refcounts never underflow and pages are conserved under random
    interleavings of prefix-allocate/commit, fork, append (CoW), truncate,
    free and whole-index invalidation. ``check_invariants`` asserts the
    full zero-ref <=> free-or-parked bijection after every op."""
    pm = PageManager(24, 4)
    base = np.arange(12)                  # shared 3-page prefix pool-wide
    next_id = 0
    live = {}                             # seq_id -> logical length

    def prompt(v):
        return np.concatenate([base, np.full(v % 3 + 1, 20 + v % 5)])

    for op, arg in ops:
        ids = sorted(live)
        try:
            if op == 0 or not ids:                        # prefix allocate
                toks = prompt(arg)
                pm.allocate_prefix(next_id, toks)
                pm.commit_prefix(next_id, toks)
                live[next_id] = len(toks)
                next_id += 1
            elif op == 1:                                 # fork
                pm.fork(ids[arg % len(ids)], next_id)
                live[next_id] = live[ids[arg % len(ids)]]
                next_id += 1
            elif op == 2:                                 # append (CoW)
                sid = ids[arg % len(ids)]
                pm.append_token(sid)
                live[sid] += 1
            elif op == 3:                                 # truncate
                sid = ids[arg % len(ids)]
                new_len = arg % (live[sid] + 1)
                pm.truncate(sid, new_len)
                live[sid] = new_len
            elif op == 4:                                 # free
                sid = ids[arg % len(ids)]
                pm.free_seq(sid)
                del live[sid]
            elif op == 5:                                 # invalidate all
                pm.invalidate_prefix_cache()
            else:                                         # re-commit
                sid = ids[arg % len(ids)]
                pm.commit_prefix(sid, prompt(arg))
        except PagePoolExhausted:
            pass
        pm.check_invariants()
    for sid in list(live):
        pm.free_seq(sid)
    pm.invalidate_prefix_cache()
    pm.check_invariants()
    assert pm.num_free_pages == 24                # nothing leaked


# ---------------------------------------------------------------------------
# Warm suffix prefill == cold prefill, bitwise
# ---------------------------------------------------------------------------
def test_warm_suffix_prefill_bit_identical_to_cold():
    """A hash-hit prompt prefills only its suffix against the cached
    prefix pages. At equal bucket widths the result must be
    *bit-identical* to the cold computation — prefix KV revived from the
    cache (shared pages, arbitrary physical ids) is indistinguishable
    from prefix KV privately written a moment earlier — and numerically
    equal to the one-shot dense-compute ``paged_prefill`` path (different
    reduction shapes => ULP tolerance, not bitwise)."""
    cfg = _tiny_cfg()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    dtype = jax.tree.leaves(params)[0].dtype
    ps = 8
    toks_a = (np.arange(16) * 3) % cfg.vocab_size
    toks_b = np.concatenate([toks_a, [5, 9, 2, 7, 1, 4]])     # 22 tokens

    def suffix_prefill(pm, pools, seq_id, start):
        suffix = np.zeros(8, np.int32)            # bucket of 8, both legs
        suffix[:len(toks_b) - start] = toks_b[start:]
        bt = jnp.asarray(pm.block_table_array([seq_id], 4))
        return model.paged_prefill_suffix(
            params, {"tokens": jnp.asarray(suffix)[None]}, pools, bt,
            jnp.asarray([start], jnp.int32),
            jnp.asarray([len(toks_b)], jnp.int32))

    # cold: private pages, prefix written by a width-16 prefill, then the
    # 6-token tail through the suffix kernel
    pm = PageManager(16, ps)
    pools = model.init_paged_pools(16, ps, dtype)
    pm.allocate(0, len(toks_b))
    bt = jnp.asarray(pm.block_table_array([0], 4))
    _, pools = model.paged_prefill(
        params, {"tokens": jnp.asarray(toks_a, jnp.int32)[None]}, pools,
        bt, jnp.asarray([16], jnp.int32))
    logits_cold, _ = suffix_prefill(pm, pools, 0, 16)

    # warm: prefill A (same width-16 call), commit, then B revives A's
    # cached 16-token prefix and prefills only its tail
    pm = PageManager(16, ps)
    pools = model.init_paged_pools(16, ps, dtype)
    pm.allocate(0, len(toks_a))
    bt_a = jnp.asarray(pm.block_table_array([0], 4))
    _, pools = model.paged_prefill(
        params, {"tokens": jnp.asarray(toks_a, jnp.int32)[None]}, pools,
        bt_a, jnp.asarray([len(toks_a)], jnp.int32))
    pm.commit_prefix(0, toks_a)
    pm.free_seq(0)                                # park -> revive on match
    _, n_cached = pm.allocate_prefix(1, toks_b)
    assert n_cached == 16
    logits_warm, _ = suffix_prefill(pm, pools, 1, 16)
    assert np.array_equal(np.asarray(logits_warm), np.asarray(logits_cold))

    # and the one-shot dense-compute prefill path agrees numerically
    pm2 = PageManager(16, ps)
    pools2 = model.init_paged_pools(16, ps, dtype)
    pm2.allocate(0, len(toks_b))
    bt2 = jnp.asarray(pm2.block_table_array([0], 4))
    logits_dense, _ = model.paged_prefill(
        params, {"tokens": jnp.asarray(toks_b, jnp.int32)[None]}, pools2,
        bt2, jnp.asarray([len(toks_b)], jnp.int32))
    np.testing.assert_allclose(np.asarray(logits_warm),
                               np.asarray(logits_dense), atol=2e-5)


# ---------------------------------------------------------------------------
# Serving identity: cache on == cache off == dense, greedily, bitwise
# ---------------------------------------------------------------------------
def _greedy_serve(model, cfg, params, prompts, *, backend, prefix_cache,
                  num_pages=None):
    cb = ContinuousBatcher(model, cfg, params, slots=2, capacity=48,
                           temperature=0.0, seed=3, cache_backend=backend,
                           page_size=8, num_pages=num_pages,
                           prefix_cache=prefix_cache)
    reqs = [cb.submit(p, 12) for p in prompts]
    cb.run_until_drained()
    cb.pm.check_invariants() if backend == "paged" else None
    return [r.out_tokens for r in reqs], cb


def test_batcher_prefix_cache_greedy_identity():
    cfg = _tiny_cfg()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    base = np.arange(16) % cfg.vocab_size
    prompts = [np.concatenate([base, [i, i + 1, i + 2]]) for i in range(4)]
    dense, _ = _greedy_serve(model, cfg, params, prompts,
                             backend="dense", prefix_cache=False)
    off, _ = _greedy_serve(model, cfg, params, prompts,
                           backend="paged", prefix_cache=False)
    on, cb = _greedy_serve(model, cfg, params, prompts,
                           backend="paged", prefix_cache=True)
    assert dense == off == on
    assert cb.prefix_hit_rate() > 0.4            # prefix actually reused
    assert cb.pm.stats.n_prefix_hits > 0


def test_batcher_prefix_cache_greedy_identity_hydra_merged():
    """The cache must be transparent under hydra *merged* weights too —
    the serving path RLHF actually uses (merge adapter, serve, unmerge)."""
    cfg = _tiny_cfg()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ad = model.init_adapter(jax.random.PRNGKey(1), params, 4)
    leaves, treedef = jax.tree.flatten(ad)
    ks = jax.random.split(jax.random.PRNGKey(2), len(leaves))
    ad = jax.tree.unflatten(treedef, [
        0.05 * jax.random.normal(k, l.shape, l.dtype)
        for k, l in zip(ks, leaves)])
    merged = model.merge_adapter(params, ad)
    base = (np.arange(16) * 5) % cfg.vocab_size
    prompts = [np.concatenate([base, [i, i + 3]]) for i in range(3)]
    off, _ = _greedy_serve(model, cfg, merged, prompts,
                           backend="paged", prefix_cache=False)
    on, cb = _greedy_serve(model, cfg, merged, prompts,
                           backend="paged", prefix_cache=True)
    assert off == on
    assert cb.prefix_hit_rate() > 0.3


def test_batcher_prefix_cache_reduces_peak_pages():
    cfg = _tiny_cfg()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    base = np.arange(24) % cfg.vocab_size        # 3 shared full pages
    prompts = [np.concatenate([base, [i]]) for i in range(6)]
    _, cb_off = _greedy_serve(model, cfg, params, prompts,
                              backend="paged", prefix_cache=False,
                              num_pages=32)
    _, cb_on = _greedy_serve(model, cfg, params, prompts,
                             backend="paged", prefix_cache=True,
                             num_pages=32)
    assert cb_on.pm.stats.peak_pages_in_use \
        < cb_off.pm.stats.peak_pages_in_use


def test_update_params_invalidates_prefix_cache():
    cfg = _tiny_cfg()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cb = ContinuousBatcher(model, cfg, params, slots=2, capacity=48,
                           temperature=0.0, seed=0, cache_backend="paged",
                           page_size=8, prefix_cache=True)
    prompt = np.arange(17) % cfg.vocab_size
    cb.submit(prompt, 8)
    cb.run_until_drained()
    assert cb.pm.match_prefix(np.concatenate([prompt, [1]]))[1] > 0
    # an RLHF weight update must flush every cached prefix: the old KV
    # was produced under the old policy
    cb.update_params(params, weight_version=1)
    assert cb.pm.match_prefix(np.concatenate([prompt, [1]]))[1] == 0
    assert cb.pm.stats.n_prefix_invalidations == 1
    # and serving continues correctly after the flush
    r = cb.submit(prompt, 8)
    cb.run_until_drained()
    assert len(r.out_tokens) == 8
    cb.pm.check_invariants()


def test_grpo_group_fork_matches_repeat_with_fewer_pages():
    """Rollout(group_size=G) prefills each unique prompt once and forks G
    children sharing its pages CoW. The sampled stream (tokens AND logp,
    at temperature > 0) must be bit-identical to pre-repeating the
    prompts through the unshared path, with a strictly lower page peak."""
    from repro.rlhf import Rollout
    cfg = _tiny_cfg()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = jnp.asarray(
        np.stack([np.arange(8), np.arange(8) + 3]) % cfg.vocab_size)
    key = jax.random.PRNGKey(5)
    ro = Rollout(model, cfg, capacity=20, temperature=0.8, top_k=20,
                 backend="paged", page_size=4)
    fork = ro.generate(params, {"tokens": prompts}, 12, key, group_size=3)
    pm_fork = ro.page_manager
    rep = ro.generate(params, {"tokens": jnp.repeat(prompts, 3, axis=0)},
                      12, key)
    pm_rep = ro.page_manager
    assert np.array_equal(np.asarray(fork.tokens), np.asarray(rep.tokens))
    assert np.array_equal(np.asarray(fork.logp), np.asarray(rep.logp))
    assert pm_fork.stats.n_forks == 4              # (G-1) * B
    assert pm_fork.stats.peak_pages_in_use < pm_rep.stats.peak_pages_in_use
    pm_fork.check_invariants()


# ---------------------------------------------------------------------------
# Multi-tenant fairness
# ---------------------------------------------------------------------------
def test_tenant_fairness_bounds_starvation():
    """Adversarial arrivals: one tenant floods the queue before a light
    tenant's requests trickle in. Weighted round-robin with aging must
    admit the light tenant long before the flood drains — under global
    FIFO (rid order) it would wait behind every flooded request."""
    cfg = _tiny_cfg()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cb = ContinuousBatcher(model, cfg, params, slots=1, capacity=32,
                           temperature=0.0, seed=0, cache_backend="paged",
                           page_size=8, num_pages=8,
                           tenant_weights={"heavy": 1.0, "light": 1.0})
    heavy = [cb.submit(np.arange(8) + (i % 4), 8, tenant="heavy")
             for i in range(10)]
    light = [cb.submit(np.arange(8) * 2 % cfg.vocab_size, 8,
                       tenant="light") for _ in range(2)]
    admit_step = {}
    while cb.n_queued or any(r is not None for r in cb.active):
        cb.step()
        for r in heavy + light:
            if r.out_tokens and r.rid not in admit_step:
                admit_step[r.rid] = cb.steps
    assert all(len(r.out_tokens) == 8 for r in heavy + light)
    last_heavy = max(admit_step[r.rid] for r in heavy)
    # equal weights => interleaved admission: both light requests beat the
    # flood's tail by a wide margin instead of queueing behind all of it
    assert all(admit_step[r.rid] < last_heavy - 8 for r in light)


def test_tenant_weights_shape_admission_order():
    """4:1 weights => the favored tenant's backlog is admitted ~4x as
    often; its mean admission step must come strictly earlier."""
    cfg = _tiny_cfg()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cb = ContinuousBatcher(model, cfg, params, slots=1, capacity=32,
                           temperature=0.0, seed=0, cache_backend="paged",
                           page_size=8, num_pages=8,
                           tenant_weights={"gold": 4.0, "bronze": 1.0})
    gold = [cb.submit(np.arange(8) + i % 3, 6, tenant="gold")
            for i in range(6)]
    bronze = [cb.submit(np.arange(8) + i % 3, 6, tenant="bronze")
              for i in range(6)]
    admit_step = {}
    while cb.n_queued or any(r is not None for r in cb.active):
        cb.step()
        for r in gold + bronze:
            if r.out_tokens and r.rid not in admit_step:
                admit_step[r.rid] = cb.steps
    mean = lambda rs: sum(admit_step[r.rid] for r in rs) / len(rs)  # noqa
    assert mean(gold) < mean(bronze)
    assert all(len(r.out_tokens) == 6 for r in gold + bronze)  # no loss
