"""HLO-text analyzer: trip-count multiplication, dot flops, collective
byte accounting — against a hand-written HLO module."""
from repro.launch.hlo_analysis import analyze, parse_module

HLO = """
HloModule test

%body.1 (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]) parameter(0)
  %t = f32[8,16]{1,0} get-tuple-element(%p), index=1
  %w = f32[16,16]{1,0} constant({...})
  %d = f32[8,16]{1,0} dot(%t, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,16]{1,0} all-reduce(%d), replica_groups={}, to_apply=%sum.2
  ROOT %r = (s32[], f32[8,16]) tuple(%t, %ar)
}

%cond.3 (p2: (s32[], f32[8,16])) -> pred[] {
  %p2 = (s32[], f32[8,16]) parameter(0)
  ROOT %lt = pred[] constant(true)
}

%sum.2 (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main.9 (x: f32[8,16]) -> f32[8,16] {
  %x = f32[8,16]{1,0} parameter(0)
  %init = (s32[], f32[8,16]) tuple(%x, %x)
  %w2 = (s32[], f32[8,16]) while(%init), condition=%cond.3, body=%body.1, backend_config={"known_trip_count":{"n":"5"}}
  %ag = f32[32,16]{1,0} all-gather(%x), dimensions={0}
  ROOT %out = f32[8,16]{1,0} get-tuple-element(%w2), index=1
}
"""


def test_parse_computations():
    comps, symbols, entry = parse_module(HLO)
    assert entry == "main.9"
    assert "body.1" in comps and "cond.3" in comps
    whiles = [o for o in comps["main.9"].ops if o.opcode == "while"]
    assert len(whiles) == 1
    assert whiles[0].trip == 5
    assert set(whiles[0].calls) == {"cond.3", "body.1"}


def test_trip_count_multiplies_flops_and_collectives():
    s = analyze(HLO)
    # dot: 2 * 8*16 * 16 = 4096 flops, x5 trips
    assert s.flops == 5 * 2 * 8 * 16 * 16
    # all-reduce f32[8,16] = 512B x2 (ring) x5; all-gather 32*16*4 = 2048B x1
    assert s.coll_bytes["all-reduce"] == 5 * 512 * 2
    assert s.coll_bytes["all-gather"] == 2048


def test_symbols_resolve_operand_shapes():
    comps, symbols, _ = parse_module(HLO)
    assert symbols["d"] == [("f32", "8,16")]
    assert symbols["ag"] == [("f32", "32,16")]
