"""Sharding context: divisibility-aware entry resolution and no-op
behavior without a mesh."""
import jax.numpy as jnp
import pytest

from repro.sharding import ctx


class FakeMesh:
    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


def test_constrain_noop_without_mesh():
    ctx.set_current_mesh(None)
    x = jnp.ones((4, 6))
    assert ctx.constrain(x, "dp", "model") is x


@pytest.mark.parametrize("entry,dim,expect", [
    ("dp", 32, ("pod", "data")),        # divisible by pod*data=32
    ("dp", 16, "data"),                 # only data divides
    ("dp", 7, None),                    # nothing divides
    ("model", 32, "model"),
    ("model", 7, None),
    (None, 5, None),
])
def test_resolve_entry_multipod(entry, dim, expect):
    mesh = FakeMesh({"pod": 2, "data": 16, "model": 16})
    assert ctx.resolve_entry(mesh, entry, dim) == expect


def test_use_mesh_context_manager():
    mesh = FakeMesh({"data": 2, "model": 2})
    assert ctx.current_mesh() is None
    with ctx.use_mesh(mesh):
        assert ctx.current_mesh() is mesh
    assert ctx.current_mesh() is None


def test_rollout_sampling_determinism_and_topk():
    import jax
    from repro.rlhf.rollout import sample_token
    key = jax.random.PRNGKey(0)
    logits = jax.random.normal(key, (4, 64))
    t1, lp1 = sample_token(key, logits, temperature=1.0, top_k=4)
    t2, lp2 = sample_token(key, logits, temperature=1.0, top_k=4)
    assert (t1 == t2).all()
    # top-k=1 equals argmax
    t3, _ = sample_token(key, logits, temperature=1.0, top_k=1)
    assert (t3 == logits.argmax(-1)).all()
    # greedy
    t4, _ = sample_token(key, logits, temperature=0.0)
    assert (t4 == logits.argmax(-1)).all()
