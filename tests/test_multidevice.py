"""Multi-device tests run in subprocesses (they need
--xla_force_host_platform_device_count BEFORE jax initializes, which the
main pytest process must not set)."""
import os
import subprocess
import sys
import textwrap

import pytest

# runs (also) in the CI multidevice job's forced-device topology
pytestmark = pytest.mark.multidevice

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, devices: int = 8, timeout: int = 900):
    env = dict(os.environ,
               PYTHONPATH=os.path.join(_ROOT, "src"),
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    return r.stdout


@pytest.mark.slow
def test_moe_shard_map_matches_fallback():
    _run("""
        import jax, jax.numpy as jnp, dataclasses
        from repro.configs import get_config
        from repro.models import moe as MOE
        from repro.models import moe_shard_map as MSM
        from repro.sharding import ctx
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((2, 4), ("data", "model"))
        for arch in ("granite_moe_3b_a800m", "deepseek_v3_671b"):
            cfg = dataclasses.replace(get_config(arch).smoke(),
                                      param_dtype="float32")
            params = MOE.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
            x = jax.random.normal(jax.random.PRNGKey(1), (4, 32, cfg.d_model)) * 0.5
            ctx.set_current_mesh(None)
            y_ref, aux_ref = MOE.moe_fwd(params, x, cfg=cfg, capacity_factor=8.0)
            g_ref = jax.grad(lambda p, x: MOE.moe_fwd(p, x, cfg=cfg,
                             capacity_factor=8.0)[0].sum())(params, x)
            ctx.set_current_mesh(mesh)
            assert MSM.usable(cfg, 4, 32)
            y, aux = jax.jit(lambda p, x: MOE.moe_fwd(p, x, cfg=cfg,
                             capacity_factor=8.0))(params, x)
            g = jax.jit(jax.grad(lambda p, x: MOE.moe_fwd(p, x, cfg=cfg,
                        capacity_factor=8.0)[0].sum()))(params, x)
            ctx.set_current_mesh(None)
            assert float(jnp.abs(y - y_ref).max()) < 1e-5, arch
            assert abs(float(aux - aux_ref)) < 1e-6, arch
            for a, b in zip(jax.tree.leaves(g), jax.tree.leaves(g_ref)):
                assert float(jnp.abs(a - b).max()) < 1e-5, arch
            print(arch, "OK")
    """)


def test_sharded_train_step_matches_single_device():
    """The same train step on a 4-device mesh (with all constraints active)
    must produce the same loss as the meshless single-device run."""
    _run("""
        import jax, jax.numpy as jnp, dataclasses
        from repro.configs import get_config
        from repro.models import Model
        from repro.sharding import ctx
        from repro.steps import make_train_step, init_train_state
        cfg = dataclasses.replace(get_config("llama3_2_3b").smoke(),
                                  param_dtype="float32")
        m = Model(cfg)
        ts = make_train_step(m, cfg, kind="ppo")
        state = init_train_state(m, cfg, jax.random.PRNGKey(0), ts.optimizer)
        B, S = 4, 32
        k = jax.random.PRNGKey(1)
        batch = {"tokens": jax.random.randint(k, (B, S), 0, cfg.vocab_size),
                 "loss_mask": jnp.ones((B, S)),
                 "advantages": jax.random.normal(k, (B, S)),
                 "old_logp": -jnp.ones((B, S)) * 3,
                 "ref_logp": -jnp.ones((B, S)) * 3,
                 "returns": jnp.zeros((B, S))}
        ctx.set_current_mesh(None)
        _, m1 = jax.jit(ts)(state, batch)
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((2, 4), ("data", "model"))
        ctx.set_current_mesh(mesh)
        _, m2 = jax.jit(ts)(state, batch)
        ctx.set_current_mesh(None)
        d = abs(float(m1["loss"]) - float(m2["loss"]))
        assert d < 1e-4, (float(m1["loss"]), float(m2["loss"]))
        print("loss match", float(m1["loss"]), float(m2["loss"]))
    """)


@pytest.mark.parametrize("arch,shape", [
    ("llama3_2_3b", "train_4k"),
    ("mamba2_370m", "decode_32k"),
    ("granite_moe_3b_a800m", "prefill_32k"),
    ("jamba_v0_1_52b", "long_500k"),
])
@pytest.mark.slow
def test_dryrun_single_combo(arch, shape):
    """One (arch x shape) dry-run compile on the 512-host-device mesh."""
    _run(f"""
        from repro.launch.dryrun import run_one
        rec = run_one("{arch}", "{shape}", verbose=False)
        assert rec["ok"]
        print(rec["arch"], rec["shape"], rec["bytes_per_device"]["temps"])
    """, devices=512, timeout=1200)
