"""Unified runtime telemetry (repro.obs): metric registry semantics and
JSONL round-trip, span nesting/ordering and Chrome-trace schema validity,
phase-span coverage (one span per canonical runtime phase, both engines),
the sim-vs-measured delta on a 2-step PPO run, offload/serving
instrumentation, and the live_host_bytes / per_device_live_bytes("host")
accounting."""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.phases import RUNTIME_RLHF_PHASE_SEQUENCE
from repro.launch.report import load, phase_table, render
from repro.obs import (MetricsRegistry, RunTelemetry, SpanTracer,
                       set_global_registry)
from repro.rlhf import RLHFConfig, RLHFTrainer, live_host_bytes
from repro.rlhf.reward import make_target_token_reward
from repro.rlhf.trainer import per_device_live_bytes


def micro_cfg(**kw):
    base = dict(num_layers=2, d_model=32, d_ff=64, vocab_size=32,
                num_heads=2, num_kv_heads=1, head_dim=16)
    base.update(kw)
    return dataclasses.replace(get_config("llama3_2_3b").smoke(), **base)


def micro_rl(**kw):
    base = dict(prompt_len=4, gen_len=4, lr=1e-3, critic_lr=1e-3,
                kl_coef=0.0, top_k=0, engine="hydra", lora_rank=2)
    base.update(kw)
    return RLHFConfig(**base)


def run_ppo(engine, telemetry, steps=2, **rl_kw):
    cfg = micro_cfg()
    rl = micro_rl(engine=engine, **rl_kw)
    tr = RLHFTrainer(cfg, cfg, rl, jax.random.PRNGKey(0),
                     reward_fn=make_target_token_reward(7),
                     telemetry=telemetry)
    key = jax.random.PRNGKey(1)
    ms = []
    for s in range(steps):
        prompts = jax.random.randint(jax.random.fold_in(key, s),
                                     (2, rl.prompt_len), 0, cfg.vocab_size)
        ms.append(tr.train_step(prompts, jax.random.fold_in(key, 100 + s)))
    return tr, ms


# ---------------------------------------------------------------- metrics
def test_registry_counter_gauge_histogram():
    reg = MetricsRegistry()
    c = reg.counter("c", "a counter")
    c.inc()
    c.inc(2.5, phase="rollout")
    assert c.value() == 1.0 and c.value(phase="rollout") == 2.5
    with pytest.raises(AssertionError):
        c.inc(-1)
    g = reg.gauge("g")
    g.set(5)
    g.set(3)
    assert g.value() == 3 and g.peak() == 5
    h = reg.histogram("h")
    for v in (1e-5, 1e-3, 0.1, 7.0):
        h.observe(v)
    s = h.summary()
    assert s["count"] == 4 and s["min"] == 1e-5 and s["max"] == 7.0
    # idempotent re-registration returns the same instrument; kind clash
    # raises
    assert reg.counter("c") is c
    with pytest.raises(TypeError):
        reg.gauge("c")


def test_registry_snapshot_and_jsonl_roundtrip(tmp_path):
    reg = MetricsRegistry()
    reg.counter("x").inc(3, phase="train_actor")
    reg.gauge("y").set(1.5)
    reg.histogram("z").observe(0.25)
    path = tmp_path / "m.jsonl"
    n = reg.write_jsonl(str(path))
    recs = [json.loads(l) for l in path.read_text().splitlines()]
    assert len(recs) == n == 3
    assert recs == reg.snapshot()
    byname = {r["name"]: r for r in recs}
    assert byname["x"]["labels"] == {"phase": "train_actor"}
    assert byname["x"]["value"] == 3
    assert byname["y"]["peak"] == 1.5
    assert byname["z"]["count"] == 1 and byname["z"]["buckets"]["+Inf"] == 1


# ----------------------------------------------------------------- tracer
def test_span_nesting_and_ordering():
    tr = SpanTracer()
    with tr.span("outer", "iteration"):
        with tr.span("inner", "phase"):
            pass
        tr.instant("evt", "phase")
    assert [s.name for s in tr.spans] == ["inner", "outer"]  # completion order
    inner, outer = tr.spans
    assert inner.depth == 1 and outer.depth == 0
    assert outer.ts_us <= inner.ts_us
    assert inner.ts_us + inner.dur_us <= outer.ts_us + outer.dur_us + 1
    # records() re-sorts by start time
    recs = tr.records()
    assert [r["name"] for r in recs] == ["outer", "inner", "evt"]
    # retroactive spans: a completed interval lands where it says it was
    sp = tr.complete("retro", "phase", 5.0, 2.0, foo=1)
    assert sp.ts_us == 5.0 and sp.dur_us == 2.0 and sp.args == {"foo": 1}
    assert tr.self_time_s > 0


def test_unbalanced_end_asserts():
    tr = SpanTracer()
    with pytest.raises(AssertionError):
        tr.end()


def test_chrome_trace_schema(tmp_path):
    tr = SpanTracer()
    with tr.span("a", "phase", bytes=7):
        pass
    tr.instant("i", "offload")
    tr.sample("memory", {"device_mib": 1.0})
    path = tr.write_chrome_trace(str(tmp_path / "t.json"))
    d = json.load(open(path))
    assert isinstance(d["traceEvents"], list)
    phases = {e["ph"] for e in d["traceEvents"]}
    assert phases == {"M", "X", "i", "C"}
    for e in d["traceEvents"]:
        assert isinstance(e["pid"], int) and isinstance(e["tid"], int)
        if e["ph"] == "X":
            assert e["dur"] > 0 and e["name"] == "a"
            assert e["args"] == {"bytes": 7}
    names = {e["args"]["name"] for e in d["traceEvents"] if e["ph"] == "M"}
    assert {"repro-telemetry", "phase", "offload"} <= names
    assert d["otherData"]["self_time_s"] >= 0


# ---------------------------------------------------- trainer integration
@pytest.mark.parametrize("engine", ["hydra", "separate"])
def test_phase_span_coverage_both_engines(engine):
    tel = RunTelemetry.create(sim_delta=False)
    run_ppo(engine, tel, steps=2)[0]
    phase_spans = [s for s in tel.tracer.spans if s.cat == "phase"]
    assert len(phase_spans) == 2 * len(RUNTIME_RLHF_PHASE_SEQUENCE)
    # each iteration tiles the canonical sequence, in order
    names = [s.name for s in phase_spans]
    assert names == list(RUNTIME_RLHF_PHASE_SEQUENCE) * 2
    for s in phase_spans:
        assert s.args["measured_bytes"] >= 0
        assert "measured_peak_bytes" in s.args
        assert "host_bytes" in s.args and "pcie_bytes" in s.args
    iters = [s for s in tel.tracer.spans if s.cat == "iteration"]
    assert len(iters) == 2
    assert tel.registry.counter("rlhf_iterations_total").value() == 2


@pytest.mark.slow
def test_sim_vs_measured_delta_smoke():
    tel = RunTelemetry.create(sim_delta=True)
    tr, _ = run_ppo("hydra", tel, steps=2, offload="all")
    assert set(tr.memory.sim_phase_bytes) == set(RUNTIME_RLHF_PHASE_SEQUENCE)
    for s in tel.tracer.spans:
        if s.cat == "phase":
            assert "sim_peak_bytes" in s.args
            assert s.args["sim_delta_bytes"] == \
                s.args["measured_bytes"] - s.args["sim_bytes"]
    # offload instrumentation rode along
    off = [s for s in tel.tracer.spans if s.cat == "offload"]
    assert any(s.name.startswith("park:") for s in off)
    assert any(s.name.startswith("fetch:") for s in off)
    assert tel.registry.counter("offload_parked_bytes_total").value() > 0


def test_telemetry_does_not_change_numerics():
    # instrumentation must be a pure observer: PPO losses bit-identical
    # with and without a telemetry bundle attached
    _, ms_plain = run_ppo("hydra", None, steps=2, offload="all")
    tel = RunTelemetry.create(sim_delta=False)
    _, ms_tel = run_ppo("hydra", tel, steps=2, offload="all")
    for a, b in zip(ms_plain, ms_tel):
        for k in ("loss", "ppo_loss", "vf_loss"):
            assert a[k] == b[k], k


# --------------------------------------------------------------- report
def test_report_renders_from_jsonl(tmp_path):
    tel = RunTelemetry.create(sim_delta=False, engine="hydra")
    run_ppo("hydra", tel, steps=1, offload="all")
    path = str(tmp_path / "run.jsonl")
    tel.write_jsonl(path)
    meta, events, samples, metrics = load(path)
    assert meta["type"] == "meta" and "self_time_s" in meta
    assert any(e["cat"] == "phase" for e in events)
    assert any(s["track"] == "memory" for s in samples)
    assert metrics
    table = phase_table(events)
    for ph in RUNTIME_RLHF_PHASE_SEQUENCE:
        assert ph in table
    out = render(path, show_metrics=True)
    assert "live device memory" in out and "rlhf_iterations_total" in out


# -------------------------------------------------- serving instrumentation
def test_serving_batcher_metrics():
    from repro.models import Model
    from repro.serving import ContinuousBatcher
    cfg = micro_cfg(num_kv_heads=2, head_dim=16, d_model=64)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tel = RunTelemetry.create(sim_delta=False)
    cb = ContinuousBatcher(model, cfg, params, slots=2, capacity=32,
                           temperature=0.0, seed=0, cache_backend="paged",
                           page_size=8, telemetry=tel)
    rng = np.random.RandomState(0)
    for g in (4, 6, 5):
        cb.submit(rng.randint(0, cfg.vocab_size, size=4), g)
    done = cb.run_until_drained()
    assert len(done) == 3
    reg = tel.registry
    assert reg.counter("serving_requests_total").value() == 3
    assert reg.counter("serving_admissions_total").value() >= 3
    total_toks = sum(len(r.out_tokens) for r in done)
    assert reg.counter("serving_tokens_total").value() == total_toks
    lat = reg.histogram("serving_admission_latency_s").summary()
    assert lat["count"] == 3
    assert reg.gauge("paged_pages_in_use").peak() > 0
    steps = [s for s in tel.tracer.spans if s.cat == "serving"]
    assert len(steps) == cb.steps
    assert all("kv_reserved_bytes" in s.args for s in steps)


# ------------------------------------------------- host-bytes accounting
def test_live_host_bytes_and_per_device_host():
    from repro.kernels import compat
    base = live_host_bytes()
    assert base >= 0
    with pytest.raises(AssertionError):
        per_device_live_bytes(memory="neither")
    if compat.host_memory_kind() is None:
        assert per_device_live_bytes(memory="host") == 0
        pytest.skip("no host memory kind on this backend")
    x = jax.device_put(
        jnp.ones((128, 128), jnp.float32),
        jax.sharding.SingleDeviceSharding(
            jax.devices()[0], memory_kind=compat.host_memory_kind()))
    x.block_until_ready()
    grew = live_host_bytes() - base
    assert grew >= x.nbytes
    assert per_device_live_bytes(memory="host") >= x.nbytes
    del x


def test_gather_copy_counts_bytes():
    reg = set_global_registry(None)
    tr, _ = run_ppo("hydra", None, steps=1)
    # ndp=1 / unsharded: gather_copy is pass-through, nothing counted
    assert reg.counter("sharding_gather_copy_bytes_total").value() == 0
    del tr
    set_global_registry(None)
