"""PPO math: GAE vs a hand-rolled loop, whitening properties, KL-shaped
rewards, and clipped-loss behavior."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.rlhf.ppo import gae, kl_shaped_rewards, whiten
from repro.steps import critic_loss, ppo_actor_loss


def _gae_numpy(rewards, values, mask, gamma, lam):
    B, S = rewards.shape
    adv = np.zeros((B, S))
    for b in range(B):
        a = 0.0
        vn = 0.0
        for t in reversed(range(S)):
            delta = rewards[b, t] + gamma * vn * mask[b, t] - values[b, t]
            a = delta + gamma * lam * a * mask[b, t]
            adv[b, t] = a
            vn = values[b, t]
    return adv * mask


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 4), st.integers(2, 12), st.floats(0.9, 1.0),
       st.floats(0.8, 1.0), st.randoms())
def test_gae_matches_reference_loop(B, S, gamma, lam, rnd):
    rng = np.random.RandomState(rnd.randint(0, 2**31))
    rewards = rng.randn(B, S).astype(np.float32)
    values = rng.randn(B, S).astype(np.float32)
    mask = (rng.rand(B, S) > 0.2).astype(np.float32)
    adv, ret = gae(jnp.asarray(rewards), jnp.asarray(values),
                   jnp.asarray(mask), gamma=gamma, lam=lam)
    ref = _gae_numpy(rewards, values, mask, gamma, lam)
    np.testing.assert_allclose(np.asarray(adv), ref, atol=1e-4)
    np.testing.assert_allclose(np.asarray(ret), ref + values * 0 + np.asarray(adv) + values - np.asarray(adv), atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 4), st.integers(4, 32), st.randoms())
def test_whiten_zero_mean_unit_var(B, S, rnd):
    rng = np.random.RandomState(rnd.randint(0, 2**31))
    x = jnp.asarray(rng.randn(B, S).astype(np.float32) * 5 + 3)
    mask = jnp.asarray((rng.rand(B, S) > 0.3).astype(np.float32))
    if float(mask.sum()) < 2:
        return
    w = whiten(x, mask)
    n = float(mask.sum())
    mean = float((w * mask).sum() / n)
    var = float((jnp.square(w) * mask).sum() / n)
    assert abs(mean) < 1e-3
    assert abs(var - 1.0) < 1e-2


def test_kl_rewards_terminal_placement():
    logp = jnp.zeros((2, 5))
    ref = jnp.zeros((2, 5))
    mask = jnp.asarray([[1, 1, 1, 0, 0], [1, 1, 1, 1, 1]], jnp.float32)
    r = kl_shaped_rewards(logp, ref, jnp.asarray([2.0, -1.0]), mask)
    np.testing.assert_allclose(np.asarray(r[0]), [0, 0, 2.0, 0, 0])
    np.testing.assert_allclose(np.asarray(r[1]), [0, 0, 0, 0, -1.0])


def test_ppo_loss_clipping_is_pessimistic():
    """Clipped objective must never be better (lower loss) than unclipped
    when the ratio moves in the advantage's favour beyond the clip."""
    B, S, V = 1, 6, 16
    logits = jnp.zeros((B, S, V))
    tokens = jnp.zeros((B, S), jnp.int32)
    base = {
        "tokens": tokens,
        "loss_mask": jnp.ones((B, S), jnp.float32),
        "advantages": jnp.ones((B, S), jnp.float32),
        "ref_logp": jnp.full((B, S), -np.log(V), jnp.float32),
    }
    logp_now = -np.log(V)
    # old logp much lower -> ratio = e^2 >> 1+eps -> clipped at 1.2
    loss_clip, _ = ppo_actor_loss(
        logits, dict(base, old_logp=jnp.full((B, S), logp_now - 2.0)),
        kl_coef=0.0)
    unclipped_obj = -np.exp(2.0)          # what no-clipping would give
    assert float(loss_clip) >= unclipped_obj + 1.0   # pessimistic vs ratio
    np.testing.assert_allclose(float(loss_clip), -1.2, atol=1e-5)
    # ratio == 1: loss = -mean(adv) over valid (non-first) positions
    loss_eq, _ = ppo_actor_loss(
        logits, dict(base, old_logp=jnp.full((B, S), logp_now)), kl_coef=0.0)
    np.testing.assert_allclose(float(loss_eq), -1.0, atol=1e-5)
    # clipped region has zero gradient wrt logits
    g = jax.grad(lambda lg: ppo_actor_loss(
        lg, dict(base, old_logp=jnp.full((B, S), logp_now - 2.0)),
        kl_coef=0.0)[0])(logits)
    assert float(jnp.abs(g).max()) < 1e-7


def test_critic_loss_value_clipping():
    B, S = 1, 4
    batch = {
        "tokens": jnp.zeros((B, S), jnp.int32),
        "loss_mask": jnp.ones((B, S), jnp.float32),
        "returns": jnp.zeros((B, S), jnp.float32),
        "old_values": jnp.zeros((B, S), jnp.float32),
    }
    # prediction moved far from old values -> clipped term dominates
    v_far = jnp.full((B, S), 10.0)
    loss_far, _ = critic_loss(v_far, batch)
    v_near = jnp.full((B, S), 0.1)
    loss_near, _ = critic_loss(v_near, batch)
    assert float(loss_far) > float(loss_near)
