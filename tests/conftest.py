"""Test bootstrap: make `import repro` and `import hypothesis` work in a
bare container. The src/ tree is added to sys.path when the package is not
installed, and a deterministic hypothesis stand-in (_hypothesis_fallback)
is registered when the real library is absent."""
import importlib.util
import pathlib
import sys

_ROOT = pathlib.Path(__file__).resolve().parent.parent

try:
    import repro  # noqa: F401
except ModuleNotFoundError:
    sys.path.insert(0, str(_ROOT / "src"))

try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    spec = importlib.util.spec_from_file_location(
        "hypothesis",
        pathlib.Path(__file__).with_name("_hypothesis_fallback.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = mod.strategies
