"""Phase-aware host-offload subsystem (repro.offload): park/fetch bit
identity for every role tree, prefetch-overlap ordering, the offload-level
x memory-policy grid, 2-step PPO loss equality between offload="all" and
"none", offload-aware remat, host-targeted checkpoint restore, and the
analytic/runtime schedule agreement."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (MemoryStrategy, OFFLOAD_LEVELS, build_rlhf_phases,
                        offload_managed_states, phase_state_touches,
                        run_iteration, runtime_state_touches)
from repro.models import Model
from repro.offload import (HostParkingLot, OffloadExecutor, OffloadPlan,
                           RUNTIME_PHASE_SEQUENCE, tree_nbytes)
from repro.rlhf import MEMORY_POLICIES, ModelEngine, RLHFConfig, RLHFTrainer
from repro.rlhf.reward import make_target_token_reward


def micro_cfg(**kw):
    base = dict(num_layers=2, d_model=32, d_ff=64, vocab_size=32,
                num_heads=2, num_kv_heads=1, head_dim=16)
    base.update(kw)
    return dataclasses.replace(get_config("llama3_2_3b").smoke(), **base)


def micro_rl(**kw):
    base = dict(prompt_len=4, gen_len=4, lr=1e-3, critic_lr=1e-3,
                kl_coef=0.0, top_k=0, engine="hydra", lora_rank=2)
    base.update(kw)
    return RLHFConfig(**base)


def assert_tree_bit_identical(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        assert jnp.dtype(x.dtype) == jnp.dtype(y.dtype)
        assert x.shape == y.shape
        xv = np.asarray(x).view(np.uint8) if np.asarray(x).size else \
            np.asarray(x)
        yv = np.asarray(y).view(np.uint8) if np.asarray(y).size else \
            np.asarray(y)
        np.testing.assert_array_equal(xv, yv)


# ---------------------------------------------------------------- host store
def test_park_fetch_bit_identity_every_role_tree():
    """Round trip through the lot is bit-exact for each hydra role tree
    (frozen base incl. bf16 leaves, per-role adapters, value heads)."""
    eng = ModelEngine(micro_cfg(), jax.random.PRNGKey(0), rank=2)
    lot = HostParkingLot()
    trees = {"base_params": eng.base_params,
             **{f"{r}_params": ad for r, ad in eng.adapters.items()}}
    originals = {k: jax.tree.map(np.asarray, v) for k, v in trees.items()}
    for name, tree in trees.items():
        lot.park(name, tree)
        assert name in lot
    assert lot.parked_bytes() == sum(tree_nbytes(v) for v in originals.values())
    for name in trees:
        fetched = lot.fetch(name)
        assert_tree_bit_identical(originals[name], fetched)
    assert lot.parked_bytes() == 0


def test_park_frees_device_bytes():
    from repro.rlhf import live_device_bytes
    eng = ModelEngine(micro_cfg(), jax.random.PRNGKey(0), rank=2)
    lot = HostParkingLot()
    before = live_device_bytes()
    nb = tree_nbytes(eng.adapters["reward"])
    lot.park("reward_params", eng.adapters["reward"])
    eng.adapters["reward"] = lot.peek("reward_params")
    import gc
    gc.collect()
    assert live_device_bytes() <= before - nb + 1024


def test_prefetch_overlap_ordering():
    """prefetch starts the host->device copy before fetch consumes it; the
    event stream records the overlap and the fetch is a hit."""
    lot = HostParkingLot()
    tree = {"w": jnp.arange(64, dtype=jnp.float32)}
    ref = np.asarray(tree["w"]).copy()
    lot.park("x", tree)
    lot.prefetch("x")
    assert "x" in lot                    # prefetch does not remove
    out = lot.fetch("x")
    np.testing.assert_array_equal(np.asarray(out["w"]), ref)
    ops = [op for op, name in lot.events if name == "x"]
    assert ops == ["park", "prefetch", "fetch_hit"]
    assert lot.stats.n_prefetch_hits == 1
    # cold fetch (no prefetch) records as a plain fetch
    lot.park("y", {"w": jnp.ones((4,))})
    lot.fetch("y")
    assert ("fetch", "y") in lot.events


def test_nonblocking_park_drain():
    lot = HostParkingLot()
    src = jnp.arange(128, dtype=jnp.int32)
    ref = np.asarray(src).copy()
    lot.park("x", {"w": src}, block=False)
    lot.drain()
    assert src.is_deleted()              # source freed on drain
    np.testing.assert_array_equal(np.asarray(lot.fetch("x")["w"]), ref)


def test_adopt_and_discard():
    lot = HostParkingLot()
    lot.adopt("x", {"w": np.arange(8, dtype=np.float32)})
    assert lot.parked_bytes() == 32
    lot.discard("x")
    assert "x" not in lot and lot.parked_bytes() == 0


# ----------------------------------------------------------------- scheduler
def test_plan_matches_simulator_schedule():
    """The runtime plan and the allocator simulator compile from the same
    touch map in core.phases — collapsing rollout must be the only
    difference, and every managed state must be parked for at least one
    phase."""
    for engine in ("separate", "hydra"):
        trace_map = phase_state_touches(engine)
        run_map = runtime_state_touches(engine)
        for name, phases in run_map.items():
            collapsed = {("rollout" if p.startswith("rollout") else p)
                         for p in trace_map[name]}
            assert phases - {"rollout"} == collapsed - {"rollout"}, name
        for level in OFFLOAD_LEVELS:
            plan = OffloadPlan.compile(level, engine=engine,
                                       states=run_map)
            assert plan.managed == frozenset(
                offload_managed_states(level, run_map))
            for name in plan.managed:
                # base_params is parked by the mid-rollout hook (once the
                # merged copy exists), not at a boundary
                if name == "base_params":
                    continue
                assert any(name in plan.evict_before(p)
                           for p in RUNTIME_PHASE_SEQUENCE), \
                    f"{name} never parked at level {level}"
            # every phase's resident set is exactly what it touches
            for ph in RUNTIME_PHASE_SEQUENCE:
                assert plan.resident_for(ph) == \
                    plan.managed & plan.required[ph]


def test_executor_roundtrip_repoints_aliases():
    state = {"params": {"w": jnp.arange(16, dtype=jnp.float32)}}
    ref = np.asarray(state["params"]["w"]).copy()
    plan = OffloadPlan.compile("roles", engine="separate",
                               states=("actor_params",))
    lot = HostParkingLot()
    acc = {"actor_params": (lambda: state["params"],
                            lambda v: state.__setitem__("params", v))}
    ex = OffloadExecutor(plan, lot, acc)
    ex.start()                            # rollout touches the actor: no park
    assert "actor_params" not in lot
    ex.park_for_boundary("rollout")       # next: score_reward -> park
    assert "actor_params" in lot
    assert isinstance(jax.tree.leaves(state["params"])[0],
                      (np.ndarray, jax.Array))
    ex.fetch_for_boundary("score_old_logp")   # next: train_actor -> fetch
    assert "actor_params" not in lot
    np.testing.assert_array_equal(np.asarray(state["params"]["w"]), ref)


# ------------------------------------------------ trainer grid + equality
@pytest.mark.parametrize("level", OFFLOAD_LEVELS)
@pytest.mark.slow
def test_offload_level_x_memory_policy_grid(level):
    """Every offload level composes with every PhaseMemoryManager policy:
    one PPO step runs, losses are finite, and managed state actually
    lands on host for levels beyond "none"."""
    cfg = micro_cfg()
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 4), 0,
                                 cfg.vocab_size)
    rl = micro_rl(offload=level, memory_policy=MEMORY_POLICIES[0])
    tr = RLHFTrainer(cfg, cfg, rl, jax.random.PRNGKey(0),
                     reward_fn=make_target_token_reward(7))
    for policy in MEMORY_POLICIES:
        # the empty_cache policy is a runtime knob of boundary(): cycle it
        # on one trainer rather than recompiling a fresh engine per cell
        tr.memory.policy = policy
        m = tr.train_step(prompts, jax.random.PRNGKey(2))
        assert np.isfinite(m["loss"]) and np.isfinite(m["vf_loss"])
    host = [r["host_bytes"] for r in tr.memory.records]
    assert len(tr.memory.records) >= 4 * 7
    if level == "none":
        assert tr.offload is None and all(h == 0 for h in host)
    else:
        assert max(host) > 0
        # boundary fetches ride the prefetch path (copies issued
        # back-to-back before installation)
        assert tr.offload_lot.stats.n_prefetch_hits > 0
        assert tr.offload_lot.stats.n_fetch == \
            tr.offload_lot.stats.n_prefetch_hits


@pytest.mark.parametrize("engine", ["hydra", "separate"])
@pytest.mark.slow
def test_two_step_ppo_loss_equality_all_vs_none(engine):
    """offload="all" must be a pure placement change: 2 PPO steps produce
    exactly the same losses/metrics as offload="none"."""
    cfg = micro_cfg()
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 4), 0,
                                 cfg.vocab_size)
    metrics = {}
    for level in ("none", "all"):
        rl = micro_rl(offload=level, engine=engine)
        tr = RLHFTrainer(cfg, cfg, rl, jax.random.PRNGKey(0),
                         reward_fn=make_target_token_reward(7))
        metrics[level] = [tr.train_step(prompts, jax.random.PRNGKey(s))
                          for s in range(2)]
    for a, b in zip(metrics["none"], metrics["all"]):
        assert set(a) == set(b)
        for k in a:
            assert a[k] == b[k], (k, a[k], b[k])


# ----------------------------------------------------- offload-aware remat
def test_remat_offload_matches_full():
    """remat="offload" changes activation *placement*, not math: loss and
    grads match remat="full" to fp tolerance (on CPU the policy degrades
    to save_only_these_names over the same named residual)."""
    tol = 1e-5
    grads, losses = {}, {}
    for remat in ("full", "offload"):
        cfg = micro_cfg(remat=remat)
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 8),
                                              0, cfg.vocab_size),
                 "loss_mask": jnp.ones((2, 8), jnp.float32)}

        def loss_fn(p):
            from repro.steps import lm_loss
            logits, aux, _ = model.forward(p, batch)
            return lm_loss(logits, batch["tokens"], batch["loss_mask"]) + aux

        losses[remat], grads[remat] = jax.value_and_grad(loss_fn)(params)
    assert abs(losses["full"] - losses["offload"]) <= tol
    for a, b in zip(jax.tree.leaves(grads["full"]),
                    jax.tree.leaves(grads["offload"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=tol)


def test_offload_remat_policy_gates_on_backend():
    from repro.kernels import compat
    from repro.offload.policies import offload_remat_policy
    pol = offload_remat_policy()
    assert callable(pol)
    # the memory-kind path only engages when the backend has a host space
    if compat.host_memory_kind() is None:
        assert "offload" not in getattr(pol, "__name__", "")


# ----------------------------------------------------- checkpoint to host
@pytest.mark.slow
def test_restore_targets_host_memory_kind(tmp_path):
    """restore(memory_kind=...) never lands leaves in device HBM: on
    backends without that kind they stay as host numpy arrays, which
    adopt_parked installs without a device round trip."""
    from repro.checkpoint import restore, save
    eng = ModelEngine(micro_cfg(), jax.random.PRNGKey(0), rank=2)
    tree = eng.adapters["critic"]
    save(str(tmp_path), 3, tree)
    like = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), tree)
    restored = restore(str(tmp_path), 3, like, memory_kind="pinned_host")
    from repro.kernels import compat
    if compat.host_memory_kind() is None:
        assert all(isinstance(l, np.ndarray)
                   for l in jax.tree.leaves(restored))
    else:
        assert all(l.sharding.memory_kind == compat.host_memory_kind()
                   for l in jax.tree.leaves(restored))
    assert_tree_bit_identical(jax.tree.map(np.asarray, tree), restored)
    # adopt into a live trainer's lot: resume without the HBM spike
    rl = micro_rl(offload="all")
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 4), 0, 32)
    tr = RLHFTrainer(micro_cfg(), micro_cfg(), rl, jax.random.PRNGKey(0),
                     reward_fn=make_target_token_reward(7))
    tr.offload.adopt_parked("critic_params", restored)
    m = tr.train_step(prompts, jax.random.PRNGKey(2))
    assert np.isfinite(m["vf_loss"])


def test_restore_default_unchanged(tmp_path):
    from repro.checkpoint import restore, save
    tree = {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)}
    save(str(tmp_path), 1, tree)
    like = jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype),
                        tree)
    out = restore(str(tmp_path), 1, like)
    assert isinstance(jax.tree.leaves(out)[0], jax.Array)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(tree["w"]))


# --------------------------------------------------- sharding + simulator
def test_opt_shardings_offload_flag():
    """ShardingStrategy.offload_optimizer resolves to real placement: on
    memory-kind backends the opt shardings retarget the host kind, on CPU
    they fall back to plain device shardings (the parking lot covers the
    dynamic case there)."""
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.kernels import compat
    from repro.sharding import ShardingStrategy, opt_shardings
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    specs = {"m": P(), "v": P()}
    plain = opt_shardings(mesh, specs, ShardingStrategy())
    off = opt_shardings(mesh, specs,
                        ShardingStrategy(offload_optimizer=True))
    kind = compat.host_memory_kind()
    for name in specs:
        if kind is None:
            assert off[name] == plain[name]
        else:
            assert off[name].memory_kind == kind


def test_simulator_offload_monotone_and_agrees_with_levels():
    """More offload never raises the simulated peak; managed sets follow
    the level lattice; hydra transients (merged rollout weights) are
    phase-local at every level."""
    cfg = micro_cfg(num_heads=4, num_kv_heads=2, d_model=128, d_ff=256)
    ph, per = build_rlhf_phases(cfg, cfg, batch=2, prompt_len=4, gen_len=4,
                                min_bytes=512, engine="hydra", lora_rank=8)
    assert per.transient == frozenset({"merged_rollout"})
    peaks = {}
    for level in OFFLOAD_LEVELS:
        r = run_iteration(ph, per, MemoryStrategy("None", offload=level),
                          "none", ndp=1, capacity=None)
        peaks[level] = r.peak_allocated
        assert (r.peak_host_bytes > 0) == (level != "none")
        # parked state is visible in the per-phase records
        assert (max(rec.host_bytes for rec in r.phase_records) > 0) \
            == (level != "none")
    assert peaks["optimizer"] <= peaks["none"]
    assert peaks["roles"] <= peaks["optimizer"]
    assert peaks["all"] <= peaks["roles"]
