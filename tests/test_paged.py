"""Paged KV-cache subsystem: page-manager properties, paged-vs-dense
attention equivalence, scheduler/rollout backend equivalence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.kernels.decode_attention import decode_attention
from repro.models import Model
from repro.paged import (PageManager, PagePoolExhausted, append_decode,
                         paged_attention_reference, paged_decode_attention,
                         scatter_prefill)
from repro.rlhf import Rollout
from repro.serving import ContinuousBatcher


def _tiny_cfg():
    return dataclasses.replace(
        get_config("llama3_2_3b").smoke(), num_layers=2, d_model=128,
        d_ff=256, vocab_size=64, num_heads=4, num_kv_heads=2, head_dim=32)


# ---------------------------------------------------------------------------
# PageManager properties
# ---------------------------------------------------------------------------
def test_page_manager_basics():
    pm = PageManager(8, 4)
    bt = pm.allocate(0, 6)                       # 2 pages
    assert len(bt) == 2 and pm.stats.pages_in_use == 2
    assert pm.fragmentation_slots() == 2         # 8 slots reserved, 6 used
    pm.free_seq(0)
    assert pm.stats.pages_in_use == 0
    pm.check_invariants()


def test_page_manager_exhaustion_is_atomic():
    pm = PageManager(4, 4)
    pm.allocate(0, 12)                           # 3 of 4 pages
    with pytest.raises(PagePoolExhausted):
        pm.allocate(1, 8)                        # needs 2, only 1 free
    pm.check_invariants()
    assert pm.num_free_pages == 1                # nothing leaked
    pm.allocate(2, 4)
    pm.check_invariants()


def test_page_manager_double_free_rejected():
    pm = PageManager(4, 4)
    pm.allocate(0, 4)
    pm.free_seq(0)
    with pytest.raises(KeyError):
        pm.free_seq(0)


def test_fork_shares_pages_and_cow_copies_on_append():
    pm = PageManager(8, 4)
    pm.allocate(0, 6)                            # page 1 is partial (2 used)
    pm.fork(0, 1)
    assert pm.stats.pages_in_use == 2            # fully shared
    copies = pm.append_token(1)                  # writes into shared partial
    assert len(copies) == 1                      # CoW copy of the last page
    assert pm.stats.n_cow_copies == 1
    assert pm.stats.pages_in_use == 3
    # parent still sees its original page; tables diverge at the tail
    assert pm.block_table(0)[0] == pm.block_table(1)[0]
    assert pm.block_table(0)[1] != pm.block_table(1)[1]
    # appending on a page boundary shares nothing -> no copy
    pm.free_seq(0)
    pm.free_seq(1)
    pm.check_invariants()
    assert pm.stats.pages_in_use == 0


@settings(max_examples=40, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 3), st.integers(1, 30)),
                min_size=1, max_size=60))
def test_page_manager_invariants_random_traffic(ops):
    """Pages conserved, refcounts exact, fragmentation bounded by one page
    per live sequence, under random alloc/append/fork/free traffic."""
    pm = PageManager(32, 4)
    next_id = 0
    live = []
    for op, arg in ops:
        try:
            if op == 0 or not live:                      # allocate
                pm.allocate(next_id, arg)
                live.append(next_id)
                next_id += 1
            elif op == 1:                                # append
                pm.append_token(live[arg % len(live)])
            elif op == 2:                                # fork
                pm.fork(live[arg % len(live)], next_id)
                live.append(next_id)
                next_id += 1
            else:                                        # free
                pm.free_seq(live.pop(arg % len(live)))
        except PagePoolExhausted:
            pass
        pm.check_invariants()
        assert pm.fragmentation_slots() <= len(live) * (pm.page_size - 1)
    for sid in live:
        pm.free_seq(sid)
    pm.check_invariants()
    assert pm.stats.pages_in_use == 0
    assert pm.num_free_pages == pm.num_pages


def test_event_stream_replays_through_allocator_sim():
    pm = PageManager(16, 8, bytes_per_token=4096)
    pm.allocate(0, 20)
    pm.allocate(1, 9)
    for _ in range(5):
        pm.append_token(0)
    pm.free_seq(0)
    pm.free_seq(1)
    alloc = pm.replay_into()
    assert alloc.allocated == 0
    assert alloc.stats.peak_allocated == \
        pm.stats.peak_pages_in_use * pm.page_bytes
    assert alloc.stats.n_alloc == pm.stats.n_page_alloc


# ---------------------------------------------------------------------------
# Attention equivalence: paged reference / Pallas kernel vs dense kernel
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,H,K,D,ps,nb,dt", [
    (2, 4, 2, 32, 8, 6, jnp.float32),
    (3, 8, 8, 16, 4, 9, jnp.float32),
    (1, 4, 1, 64, 16, 4, jnp.bfloat16),
])
def test_paged_attention_matches_dense(B, H, K, D, ps, nb, dt):
    rng = np.random.RandomState(B * H + D)
    C = nb * ps
    lens = rng.randint(1, C, size=B)
    pm = PageManager(B * nb, ps)
    for b in range(B):
        pm.allocate(b, int(lens[b]))
    bt = jnp.asarray(pm.block_table_array(list(range(B)), nb))

    S = int(lens.max())
    k_new = jnp.asarray(rng.randn(B, S, K, D), dt)
    v_new = jnp.asarray(rng.randn(B, S, K, D), dt)
    pool = scatter_prefill(
        {"k": jnp.zeros((B * nb, ps, K, D), dt),
         "v": jnp.zeros((B * nb, ps, K, D), dt)},
        k_new, v_new, bt, jnp.asarray(lens))
    q = jnp.asarray(rng.randn(B, H, D), dt)
    position = jnp.asarray(lens - 1, jnp.int32)

    # dense oracle: same K/V packed [B, C] with explicit per-slot positions
    kd = np.zeros((B, C, K, D), np.float32)
    vd = np.zeros_like(kd)
    posd = np.full((B, C), -1, np.int32)
    kf = np.asarray(k_new, np.float32)
    vf = np.asarray(v_new, np.float32)
    for b in range(B):
        kd[b, :lens[b]] = kf[b, :lens[b]]
        vd[b, :lens[b]] = vf[b, :lens[b]]
        posd[b, :lens[b]] = np.arange(lens[b])
    dense = decode_attention(q.astype(jnp.float32), jnp.asarray(kd),
                             jnp.asarray(vd), jnp.asarray(posd), position)

    tol = 2e-2 if dt == jnp.bfloat16 else 1e-5
    ref = paged_attention_reference(q, pool, bt, position)
    np.testing.assert_allclose(np.asarray(ref, np.float32),
                               np.asarray(dense, np.float32), atol=tol)
    ker = paged_decode_attention(q, pool["k"], pool["v"], bt, position,
                                 interpret=True)
    np.testing.assert_allclose(np.asarray(ker, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


def test_append_decode_writes_only_live_rows():
    ps, P_, K, D = 4, 8, 2, 16
    pool = {"k": jnp.zeros((P_, ps, K, D)), "v": jnp.zeros((P_, ps, K, D))}
    bt = jnp.asarray([[0, 1], [2, -1]], jnp.int32)
    kt = jnp.ones((2, K, D))
    out = append_decode(pool, kt, kt, bt, jnp.asarray([5, -1], jnp.int32))
    k = np.asarray(out["k"])
    assert k[1, 1].sum() == K * D          # seq 0, logical idx 5 -> page 1
    assert k.sum() == K * D                # idle row dropped, nothing else


# ---------------------------------------------------------------------------
# Model / scheduler / rollout backends
# ---------------------------------------------------------------------------
def test_model_paged_decode_matches_dense_logits():
    cfg = _tiny_cfg()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, P_len, ps = 2, 8, 4
    toks = jnp.asarray(np.random.RandomState(0).randint(0, 64, (B, P_len)))
    lg_d, caches = model.prefill(params, {"tokens": toks}, 32)
    caches = {"segments": caches["segments"], "cross_kv": None}
    pm = PageManager(32, ps)
    for b in range(B):
        pm.allocate(b, P_len + 6)
    bt = jnp.asarray(pm.block_table_array([0, 1], -(-(P_len + 6) // ps)))
    pools = model.init_paged_pools(32, ps, jnp.float32)
    lg_p, pools = model.paged_prefill(params, {"tokens": toks}, pools, bt,
                                      jnp.full((B,), P_len, jnp.int32))
    np.testing.assert_allclose(np.asarray(lg_d), np.asarray(lg_p), atol=1e-5)
    tok = jnp.argmax(lg_d, -1).astype(jnp.int32)
    pos = jnp.full((B,), P_len, jnp.int32)
    for _ in range(4):
        lg_d, caches = model.decode_step(params, caches, tok, pos)
        lg_p, pools = model.paged_decode_step(params, pools, tok, pos, bt)
        np.testing.assert_allclose(np.asarray(lg_d), np.asarray(lg_p),
                                   atol=1e-5)
        tok = jnp.argmax(lg_d, -1).astype(jnp.int32)
        pos = pos + 1


def test_batcher_paged_matches_dense_greedy():
    cfg = _tiny_cfg()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = np.arange(8) % cfg.vocab_size

    def run(backend):
        cb = ContinuousBatcher(model, cfg, params, slots=3, capacity=64,
                               temperature=0.0, seed=7,
                               cache_backend=backend, page_size=8)
        r = cb.submit(prompt, 10)
        rng = np.random.RandomState(1)
        for _ in range(2):
            cb.submit(rng.randint(0, 64, size=8), 10)
        cb.run_until_drained()
        return r.out_tokens, cb

    dense, _ = run("dense")
    paged, cb = run("paged")
    assert dense == paged
    assert cb.pm.stats.pages_in_use == 0         # everything retired
    cb.pm.check_invariants()
    # ragged completions must reserve less than the dense worst case
    assert cb.pm.stats.peak_pages_in_use * cb.page_size < 3 * 64


def test_batcher_paged_preempts_and_completes_on_tiny_pool():
    cfg = _tiny_cfg()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cb = ContinuousBatcher(model, cfg, params, slots=3, capacity=64,
                           temperature=0.0, seed=7, cache_backend="paged",
                           page_size=8, num_pages=9)   # < 3 full sequences
    reqs = [cb.submit((np.arange(8) + i) % cfg.vocab_size, 20)
            for i in range(4)]
    done = cb.run_until_drained()
    assert len(done) == 4
    assert all(len(r.out_tokens) == 20 for r in reqs)
    assert sum(r.n_preempted for r in reqs) >= 1
    cb.pm.check_invariants()
    assert cb.pm.stats.pages_in_use == 0


def test_batcher_paged_preemption_preserves_greedy_output():
    """Recompute preemption (even repeated) must not corrupt context:
    greedy completions from a starved pool equal the unstarved ones."""
    cfg = _tiny_cfg()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    def run(num_pages):
        cb = ContinuousBatcher(model, cfg, params, slots=3, capacity=64,
                               temperature=0.0, seed=7,
                               cache_backend="paged", page_size=8,
                               num_pages=num_pages)
        reqs = [cb.submit((np.arange(8) + i) % cfg.vocab_size, 24)
                for i in range(4)]
        cb.run_until_drained()
        return [r.out_tokens for r in reqs], sum(r.n_preempted for r in reqs)

    roomy, p0 = run(24)
    tight, p1 = run(8)            # pool of one max-length sequence
    assert p0 == 0 and p1 >= 1
    assert roomy == tight
    assert all(len(t) == 24 for t in tight)


def test_batcher_rejects_request_beyond_capacity():
    cfg = _tiny_cfg()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cb = ContinuousBatcher(model, cfg, params, slots=2, capacity=32,
                           cache_backend="paged", page_size=8)
    with pytest.raises(ValueError):
        cb.submit(np.arange(30), 10)
    cb.submit(np.arange(8), 10)            # servable request still accepted
    assert len(cb.queue) == 1


def test_rollout_paged_matches_dense_exactly():
    cfg = _tiny_cfg()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jnp.asarray(np.random.RandomState(0).randint(0, 64, (3, 9)))
    key = jax.random.PRNGKey(5)
    kw = dict(capacity=40, temperature=0.7, top_k=8, eos_id=3)
    a = Rollout(model, cfg, **kw).generate(params, {"tokens": toks}, 12, key)
    rp = Rollout(model, cfg, backend="paged", page_size=4, **kw)
    b = rp.generate(params, {"tokens": toks}, 12, key)
    assert bool((a.tokens == b.tokens).all())
    assert bool((a.mask == b.mask).all())
    np.testing.assert_allclose(np.asarray(a.logp), np.asarray(b.logp),
                               atol=1e-5)
    pm = rp.page_manager
    assert pm.stats.pages_in_use == 0
    # replays cleanly through the paper's allocator simulator
    alloc = pm.replay_into()
    assert alloc.allocated == 0 and alloc.stats.peak_allocated > 0
