"""input_specs / batch_pspecs / cache_specs consistency for every
(arch x shape) pair — pure-Python spec checks, no compilation."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ASSIGNED_ARCHS, SHAPES, get_config
from repro.models import Model
from repro.sharding import ShardingStrategy, batch_pspecs
from repro.steps import cache_capacity, cache_specs, decode_window, \
    input_specs


class FakeMesh:
    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


MESH = FakeMesh({"data": 16, "model": 16})


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
@pytest.mark.parametrize("shape_name", list(SHAPES))
def test_specs_cover_inputs(arch, shape_name):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    batch = input_specs(cfg, shape)
    if shape.kind in ("train", "prefill"):
        specs = batch_pspecs(cfg, shape, MESH)
        for k, sds in batch.items():
            assert k in specs, (arch, shape_name, k)
            spec = specs[k]
            assert len(spec) <= len(sds.shape)
        # token count covers the sequence (minus VLM prefix)
        P_ = cfg.num_prefix_embeddings if cfg.input_mode == "embeddings" else 0
        assert batch["tokens"].shape == (shape.global_batch,
                                         shape.seq_len - P_)
    else:
        assert batch["token"].shape == (shape.global_batch,)
        assert batch["position"].shape == (shape.global_batch,)


@pytest.mark.parametrize("arch", ["llama3_2_3b", "deepseek_v3_671b",
                                  "mamba2_370m", "jamba_v0_1_52b",
                                  "seamless_m4t_large_v2"])
@pytest.mark.parametrize("shape_name", ["decode_32k", "long_500k"])
def test_cache_specs_shapes(arch, shape_name):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    model = Model(cfg)
    caches = cache_specs(model, cfg, shape)
    cap = cache_capacity(cfg, shape)
    w = decode_window(cfg, shape)
    if shape.kind == "long_decode" and cfg.num_heads:
        assert cap == min(shape.seq_len, cfg.long_context_window)
    for seg, segspec in zip(model.segments, caches["segments"]):
        for i, kind in enumerate(seg.kinds):
            leaf = jax.tree.leaves(segspec[f"slot{i}"])[0]
            assert leaf.shape[0] == seg.n_groups
            assert leaf.shape[1] == shape.global_batch
    if cfg.input_mode == "encdec":
        assert caches["cross_kv"] is not None
