"""Fast decode path: compile-bucket ladder + MTP self-speculative decoding.

Covers (DESIGN.md "Fast decode path"):
  * depth-k MTP plumbing — ``mtp_depth > 1`` init/load compatibility,
    chained draft logits, the depth-1 tree staying bit-identical;
  * multi-token cache primitives — ``decode_multi`` vs a sequential
    ``decode_step`` oracle, paged multi-append vs single-append;
  * greedy bit-identity of speculative decoding vs vanilla on the dense
    AND paged backends, standalone and through the hydra merged-adapter
    rollout and the continuous batcher (incl. EOS truncation);
  * the bucket ladder — identical outputs across bucket boundaries with
    zero post-warmup recompiles, and exactness of lengths-masked prefill;
  * ``PageManager.append_tokens`` atomicity and ``truncate``.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import Model
from repro.paged import PageManager, PagePoolExhausted
from repro.rlhf.rollout import Rollout
from repro.serving import BucketLadder, CompileCache, ContinuousBatcher


def tiny_cfg(**kw):
    base = dict(num_layers=2, d_model=64, d_ff=128, vocab_size=64,
                num_heads=4, num_kv_heads=2, head_dim=16, mtp_depth=3)
    base.update(kw)
    return dataclasses.replace(get_config("llama3_2_3b").smoke(), **base)


@pytest.fixture(scope="module")
def setup():
    cfg = tiny_cfg()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _trees_equal(a, b):
    return all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# --------------------------------------------------------------- mtp depth-k
def test_smoke_config_keeps_mtp_depth():
    """The smoke() depth-1 clamp is gone: depth-k survives to CPU scale."""
    cfg = dataclasses.replace(get_config("deepseek_v3_671b"),
                              mtp_depth=3).smoke()
    assert cfg.mtp_depth == 3


def test_depth_k_init_and_depth1_compat(setup):
    cfg, model, params = setup
    extra = params["mtp_extra"]
    assert jax.tree.leaves(extra)[0].shape[0] == cfg.mtp_depth - 1
    # depth-1 model from the same seed: no extras, identical shared tree
    m1 = Model(dataclasses.replace(cfg, mtp_depth=1))
    p1 = m1.init(jax.random.PRNGKey(0))
    assert "mtp_extra" not in p1
    assert _trees_equal(p1["mtp"], params["mtp"])
    assert _trees_equal(p1["segment0"], params["segment0"])


def test_chain_logits_depth1_matches_mtp_logits(setup):
    cfg, model, params = setup
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 10), 0,
                                          cfg.vocab_size)}
    _, _, h = model.forward(params, batch)
    chain = model.mtp_chain_logits(params, h, batch["tokens"])
    assert len(chain) == cfg.mtp_depth
    single = model.mtp_logits(params, h, batch["tokens"])
    np.testing.assert_array_equal(np.asarray(chain[0]), np.asarray(single))


def test_depth_k_params_shard(setup):
    """mtp_extra's stacked-depth lead axis is stripped like a segment
    stack, so every depth-k leaf gets a spec that divides its shape."""
    cfg, model, params = setup
    from repro.sharding import ShardingStrategy, param_pspecs
    from tests.test_sharding import MESHES, _validate
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = param_pspecs(cfg, MESHES[0], ShardingStrategy(zero_stage=3),
                         shapes)
    assert "mtp_extra" in specs
    _validate(specs, shapes, MESHES[0])


# -------------------------------------------------- multi-token cache verify
def test_decode_multi_matches_sequential(setup):
    cfg, model, params = setup
    B, P, T, cap = 2, 6, 4, 32
    key = jax.random.PRNGKey(2)
    prompts = jax.random.randint(key, (B, P), 0, cfg.vocab_size)
    toks = jax.random.randint(jax.random.fold_in(key, 1), (B, T), 0,
                              cfg.vocab_size)
    _, caches = model.prefill(params, {"tokens": prompts}, cap)
    seq_caches = jax.tree.map(lambda x: x, caches)
    seq_logits = []
    for t in range(T):
        lg, seq_caches = model.decode_step(
            params, seq_caches, toks[:, t], jnp.full((B,), P + t, jnp.int32))
        seq_logits.append(lg)
    seq_logits = jnp.stack(seq_logits, 1)
    positions = P + jnp.arange(T, dtype=jnp.int32)[None] \
        + jnp.zeros((B, 1), jnp.int32)
    multi_logits, h, _ = model.decode_multi(params, caches, toks, positions)
    np.testing.assert_allclose(np.asarray(multi_logits),
                               np.asarray(seq_logits), rtol=2e-5, atol=2e-5)
    assert h.shape == (B, T, cfg.d_model)


def test_paged_append_multi_matches_sequential():
    from repro.paged import paged_cache as PC
    cfg = tiny_cfg()
    ps, num_pages = 4, 12
    kvh, hd = cfg.num_kv_heads, cfg.resolved_head_dim()
    key = jax.random.PRNGKey(3)
    B, T = 2, 3
    pool = {"k": jax.random.normal(key, (num_pages, ps, kvh, hd)),
            "v": jax.random.normal(jax.random.fold_in(key, 1),
                                   (num_pages, ps, kvh, hd))}
    k_t = jax.random.normal(jax.random.fold_in(key, 2), (B, T, kvh, hd))
    v_t = jax.random.normal(jax.random.fold_in(key, 3), (B, T, kvh, hd))
    bt = jnp.asarray([[0, 1, 2], [3, 4, -1]], jnp.int32)
    positions = jnp.asarray([[5, 6, 7], [2, 3, -1]], jnp.int32)  # -1 = dead
    multi = PC.append_decode_multi(pool, k_t, v_t, bt, positions)
    seq = pool
    for t in range(T):
        seq = PC.append_decode(seq, k_t[:, t], v_t[:, t], bt,
                               positions[:, t])
    for name in ("k", "v"):
        np.testing.assert_array_equal(np.asarray(multi[name]),
                                      np.asarray(seq[name]))


def test_prefill_lengths_masking_exact(setup):
    """Bucket-padded prefill == unpadded prefill: same logits, and the
    caches produce the same continuation."""
    cfg, model, params = setup
    B, P, pad, cap = 2, 7, 9, 32
    prompts = jax.random.randint(jax.random.PRNGKey(4), (B, P), 0,
                                 cfg.vocab_size)
    lg_ref, c_ref = model.prefill(params, {"tokens": prompts}, cap)
    padded = jnp.pad(prompts, ((0, 0), (0, pad)))
    lg_b, c_b, h_b = model.prefill(params, {"tokens": padded}, cap,
                                   lengths=jnp.full((B,), P, jnp.int32),
                                   return_h=True)
    np.testing.assert_allclose(np.asarray(lg_ref), np.asarray(lg_b),
                               rtol=1e-6, atol=1e-6)
    nxt = jnp.argmax(lg_ref, -1).astype(jnp.int32)
    pos = jnp.full((B,), P, jnp.int32)
    lg1_ref, _ = model.decode_step(params, c_ref, nxt, pos)
    lg1_b, _ = model.decode_step(params, c_b, nxt, pos)
    np.testing.assert_allclose(np.asarray(lg1_ref), np.asarray(lg1_b),
                               rtol=1e-6, atol=1e-6)


# ------------------------------------------------------- rollout bit-identity
@pytest.mark.parametrize("backend", ["dense", "paged"])
def test_rollout_specdec_bit_identical(setup, backend):
    cfg, model, params = setup
    key = jax.random.PRNGKey(5)
    prompts = jax.random.randint(jax.random.PRNGKey(6), (3, 9), 0,
                                 cfg.vocab_size)
    batch, cap, gen = {"tokens": prompts}, 9 + 14, 14
    van = Rollout(model, cfg, capacity=cap, temperature=0.0, top_k=0,
                  backend=backend, page_size=4)
    ref = van.generate(params, batch, gen, key)
    spec = Rollout(model, cfg, capacity=cap, temperature=0.0, top_k=0,
                   backend=backend, page_size=4, spec_decode=True, spec_k=3)
    out = spec.generate(params, batch, gen, key)
    np.testing.assert_array_equal(np.asarray(ref.tokens),
                                  np.asarray(out.tokens))
    np.testing.assert_allclose(np.asarray(ref.logp), np.asarray(out.logp),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(ref.mask), np.asarray(out.mask))
    if backend == "paged":
        assert spec.page_manager.stats.pages_in_use == 0
        spec.page_manager.check_invariants()


def test_rollout_specdec_hydra_merged(setup):
    """Spec decode through the hydra merged-weight path: drafts and verify
    both use the merged tree, so output matches vanilla merged greedy."""
    cfg, model, params = setup
    from tests.test_hydra import randomized_adapter
    adapter = randomized_adapter(model, params, 4, jax.random.PRNGKey(7))
    key = jax.random.PRNGKey(8)
    prompts = jax.random.randint(jax.random.PRNGKey(9), (2, 8), 0,
                                 cfg.vocab_size)
    batch, cap, gen = {"tokens": prompts}, 8 + 12, 12
    van = Rollout(model, cfg, capacity=cap, temperature=0.0, top_k=0)
    ref = van.generate(params, batch, gen, key, adapter=adapter)
    spec = Rollout(model, cfg, capacity=cap, temperature=0.0, top_k=0,
                   spec_decode=True, spec_k=2)
    out = spec.generate(params, batch, gen, key, adapter=adapter)
    np.testing.assert_array_equal(np.asarray(ref.tokens),
                                  np.asarray(out.tokens))
    np.testing.assert_allclose(np.asarray(ref.logp), np.asarray(out.logp),
                               rtol=1e-6, atol=1e-6)


def test_rollout_spec_k_beyond_trained_depth(setup):
    """spec_k > mtp_depth reuses the deepest module; still greedy-exact."""
    cfg, model, params = setup
    key = jax.random.PRNGKey(10)
    prompts = jax.random.randint(jax.random.PRNGKey(11), (2, 6), 0,
                                 cfg.vocab_size)
    batch, cap, gen = {"tokens": prompts}, 6 + 10, 10
    ref = Rollout(model, cfg, capacity=cap, temperature=0.0,
                  top_k=0).generate(params, batch, gen, key)
    out = Rollout(model, cfg, capacity=cap, temperature=0.0, top_k=0,
                  spec_decode=True,
                  spec_k=cfg.mtp_depth + 2).generate(params, batch, gen, key)
    np.testing.assert_array_equal(np.asarray(ref.tokens),
                                  np.asarray(out.tokens))


# ------------------------------------------------------- batcher bit-identity
def _run_batcher(model, cfg, params, prompts, gens, **kw):
    cb = ContinuousBatcher(model, cfg, params, slots=3, capacity=64,
                           temperature=0.0, seed=7, **kw)
    for p, g in zip(prompts, gens):
        cb.submit(p, g)
    done = cb.run_until_drained()
    return {r.rid: r.out_tokens for r in done}, cb


@pytest.mark.parametrize("backend", ["dense", "paged"])
def test_batcher_specdec_bit_identical(setup, backend):
    cfg, model, params = setup
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab_size, size=n)
               for n in (5, 9, 12, 7, 3)]
    gens = [11, 8, 13, 11, 9]
    kw = dict(cache_backend=backend, page_size=8, eos_id=5)
    ref, _ = _run_batcher(model, cfg, params, prompts, gens, **kw)
    out, cb = _run_batcher(model, cfg, params, prompts, gens,
                           spec_decode=True, spec_k=3,
                           capture_buckets=(4, 8, 16, 32), **kw)
    assert ref == out
    if backend == "paged":
        cb.pm.check_invariants()
        assert cb.pm.stats.pages_in_use == 0


def test_batcher_spec_preemption(setup):
    """Spec decode under page pressure: grow-by-k+1 triggers preemption,
    output still matches vanilla."""
    cfg, model, params = setup
    rng = np.random.RandomState(1)
    prompts = [rng.randint(0, cfg.vocab_size, size=8) for _ in range(4)]
    gens = [14] * 4
    kw = dict(cache_backend="paged", page_size=8, num_pages=9)
    ref, _ = _run_batcher(model, cfg, params, prompts, gens, **kw)
    out, cb = _run_batcher(model, cfg, params, prompts, gens,
                           spec_decode=True, spec_k=2, **kw)
    assert ref == out
    cb.pm.check_invariants()


# ------------------------------------------------------------- bucket ladder
def test_bucket_ladder_fit():
    lad = BucketLadder((4, 8, 16))
    assert [lad.fit(n) for n in (1, 4, 5, 8, 16, 17)] == [4, 4, 8, 8, 16, 17]
    assert lad.up_to(16) == (4, 8, 16)
    assert lad.up_to(20) == (4, 8, 16, 20)
    assert BucketLadder.default(24).buckets[-1] == 24


def test_compile_cache_recompile_accounting():
    cc = CompileCache()
    cc.warm(("decode", "dense", 4))
    cc.finish_warmup()
    assert cc.lookup(("decode", "dense", 4)) and cc.recompiles == 0
    assert not cc.lookup(("decode", "dense", 5))
    assert cc.recompiles == 1 and cc.hits == 1


@pytest.mark.parametrize("backend", ["dense", "paged"])
def test_bucket_boundary_identical_and_no_recompiles(setup, backend):
    """Prompts at b-1 / b / b+1 around a bucket edge: outputs identical to
    the unbucketed batcher and zero post-warmup recompiles."""
    cfg, model, params = setup
    rng = np.random.RandomState(2)
    prompts = [rng.randint(0, cfg.vocab_size, size=n) for n in (7, 8, 9)]
    gens = [10, 10, 10]
    kw = dict(cache_backend=backend, page_size=8)
    ref, _ = _run_batcher(model, cfg, params, prompts, gens, **kw)
    out, cb = _run_batcher(model, cfg, params, prompts, gens,
                           capture_buckets=(4, 8, 16, 32), **kw)
    assert ref == out
    st = cb.compile_cache.stats()
    assert st["recompiles"] == 0
    assert st["hit_rate"] == 1.0          # every traffic shape was captured


def test_rollout_bucketed_prefill_identical(setup):
    """Ragged prompt lengths through a bucketed Rollout reuse ladder
    shapes and reproduce the unbucketed stream (greedy)."""
    cfg, model, params = setup
    cap = 16 + 10
    van = Rollout(model, cfg, capacity=cap, temperature=0.0, top_k=0)
    bkt = Rollout(model, cfg, capacity=cap, temperature=0.0, top_k=0,
                  capture_buckets=(8, 16))
    bkt.warmup(params, 2, 16)
    for P in (5, 7, 8, 11):
        prompts = jax.random.randint(jax.random.fold_in(
            jax.random.PRNGKey(12), P), (2, P), 0, cfg.vocab_size)
        key = jax.random.PRNGKey(13)
        r0 = van.generate(params, {"tokens": prompts}, 10, key)
        r1 = bkt.generate(params, {"tokens": prompts}, 10, key)
        np.testing.assert_array_equal(np.asarray(r0.tokens),
                                      np.asarray(r1.tokens))
    assert bkt.compile_cache.recompiles == 0


# ------------------------------------------------------- page manager growth
def test_append_tokens_matches_single_appends():
    a, b = PageManager(16, 4), PageManager(16, 4)
    for pm in (a, b):
        pm.allocate(0, 6)
    copies_multi = a.append_tokens(0, 7)
    copies_single = []
    for _ in range(7):
        copies_single.extend(b.append_token(0))
    assert copies_multi == copies_single
    assert a.seq_len(0) == b.seq_len(0) == 13
    assert a.block_table(0) == b.block_table(0)
    a.check_invariants()


def test_append_tokens_atomic_on_exhaustion():
    pm = PageManager(3, 4)
    pm.allocate(0, 4)                     # 1 page used, 2 free
    before = (pm.seq_len(0), pm.block_table(0), pm.num_free_pages)
    with pytest.raises(PagePoolExhausted):
        pm.append_tokens(0, 12)           # needs 3 pages, only 2 free
    assert (pm.seq_len(0), pm.block_table(0), pm.num_free_pages) == before
    pm.check_invariants()


def test_append_tokens_atomic_with_cow():
    """A shared partial last page adds one CoW page to the atomic check."""
    pm = PageManager(3, 4)
    pm.allocate(0, 6)                     # 2 pages (last partial), 1 free
    pm.fork(0, 1)
    before = pm.num_free_pages
    with pytest.raises(PagePoolExhausted):
        pm.append_tokens(0, 3)            # CoW copy + growth page = 2 > 1
    assert pm.num_free_pages == before
    copies = pm.append_tokens(0, 1)       # CoW alone fits
    assert len(copies) == 1
    pm.check_invariants()


def test_truncate_frees_whole_pages():
    pm = PageManager(8, 4)
    pm.allocate(0, 3)
    pm.append_tokens(0, 7)                # length 10 -> 3 pages
    assert len(pm.block_table(0)) == 3
    pm.truncate(0, 5)
    assert pm.seq_len(0) == 5 and len(pm.block_table(0)) == 2
    pm.truncate(0, 0)
    assert pm.block_table(0) == []
    pm.check_invariants()


def test_truncate_respects_forked_pages():
    pm = PageManager(8, 4)
    pm.allocate(0, 8)                     # 2 full pages
    pm.fork(0, 1)
    pm.truncate(0, 4)                     # drops parent's ref on page 2
    assert len(pm.block_table(1)) == 2    # child keeps it alive
    pm.free_seq(0)
    pm.free_seq(1)
    assert pm.stats.pages_in_use == 0
    pm.check_invariants()
