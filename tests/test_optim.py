"""Optimizers: AdamW vs a hand-rolled reference step, Adafactor shapes and
descent, schedules, clipping."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (Adafactor, AdamW, clip_by_global_norm, global_norm,
                         warmup_cosine)


def test_adamw_first_step_matches_reference():
    opt = AdamW(b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.0)
    params = {"w": jnp.array([1.0, -2.0, 3.0])}
    grads = {"w": jnp.array([0.5, 0.5, -1.0])}
    state = opt.init(params)
    new_p, new_s = opt.update(grads, state, params, lr=0.1)
    # after bias correction, first step is lr * sign-ish of grad
    g = np.array([0.5, 0.5, -1.0])
    m_hat = 0.1 * g / 0.1
    v_hat = 0.05 * g**2 / 0.05
    ref = np.array([1.0, -2.0, 3.0]) - 0.1 * m_hat / (np.sqrt(v_hat) + 1e-8)
    np.testing.assert_allclose(np.asarray(new_p["w"]), ref, rtol=1e-5)
    assert int(new_s["count"]) == 1


def test_adamw_weight_decay_shrinks():
    opt = AdamW(weight_decay=0.1)
    params = {"w": jnp.ones((4,))}
    zeros = {"w": jnp.zeros((4,))}
    state = opt.init(params)
    new_p, _ = opt.update(zeros, state, params, lr=0.1)
    assert float(new_p["w"][0]) < 1.0


def test_adamw_bf16_moments():
    opt = AdamW(moment_dtype="bfloat16")
    params = {"w": jnp.ones((8,), jnp.bfloat16)}
    state = opt.init(params)
    assert state["mu"]["w"].dtype == jnp.bfloat16
    assert opt.state_bytes_per_param() == 4


def test_adafactor_factored_state_shapes():
    opt = Adafactor()
    params = {"big": jnp.ones((256, 512)), "small": jnp.ones((8,))}
    state = opt.init(params)
    assert state["v"]["big"]["vr"].shape == (256,)
    assert state["v"]["big"]["vc"].shape == (512,)
    assert state["v"]["small"]["v"].shape == (8,)


def test_adafactor_descends_quadratic():
    opt = Adafactor()
    params = {"w": jnp.full((256, 256), 3.0)}
    state = opt.init(params)
    loss = lambda p: 0.5 * jnp.sum(p["w"] ** 2)
    l0 = float(loss(params))
    for _ in range(20):
        grads = jax.grad(loss)(params)
        params, state = opt.update(grads, state, params, lr=0.05)
    assert float(loss(params)) < 0.5 * l0


def test_warmup_cosine_shape():
    lr = [float(warmup_cosine(jnp.asarray(s), peak_lr=1.0, warmup_steps=10,
                              total_steps=100)) for s in range(100)]
    assert lr[0] < lr[9] <= 1.0
    assert abs(lr[9] - 1.0) < 0.01
    assert lr[99] < lr[50] < lr[10]
    assert lr[99] >= 0.1 - 1e-3   # floor


def test_clip_by_global_norm():
    tree = {"a": jnp.ones((4,)) * 3.0, "b": jnp.ones((4,)) * 4.0}
    norm = float(global_norm(tree))
    np.testing.assert_allclose(norm, 10.0, rtol=1e-6)
    clipped, n = clip_by_global_norm(tree, 5.0)
    np.testing.assert_allclose(float(global_norm(clipped)), 5.0, rtol=1e-5)
    # no-op below the threshold
    same, _ = clip_by_global_norm(tree, 100.0)
    np.testing.assert_allclose(np.asarray(same["a"]), 3.0, rtol=1e-6)
