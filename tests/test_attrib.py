"""Memory attribution observatory (PR 8): MemoryAttributor exactness and
alias priority, FlightRecorder triggers/ring/dump schema, the attribution
tables riding RLHF phase spans (sum + residue == measured, per-owner sim
deltas), the watermark dump from a real PPO run, serving-side attribution
in ContinuousBatcher, and compiled-memory accounting."""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.obs import (FlightRecorder, MemoryAttributor, MetricsRegistry,
                       RunTelemetry, record_compiled_memory)
from repro.rlhf import RLHFConfig, RLHFTrainer, live_device_bytes
from repro.rlhf.reward import make_target_token_reward


def micro_cfg(**kw):
    base = dict(num_layers=2, d_model=32, d_ff=64, vocab_size=32,
                num_heads=2, num_kv_heads=1, head_dim=16)
    base.update(kw)
    return dataclasses.replace(get_config("llama3_2_3b").smoke(), **base)


def micro_rl(**kw):
    base = dict(prompt_len=4, gen_len=4, lr=1e-3, critic_lr=1e-3,
                kl_coef=0.0, top_k=0, engine="hydra", lora_rank=2)
    base.update(kw)
    return RLHFConfig(**base)


def run_ppo(engine, telemetry, steps=2, **rl_kw):
    cfg = micro_cfg()
    rl = micro_rl(engine=engine, **rl_kw)
    tr = RLHFTrainer(cfg, cfg, rl, jax.random.PRNGKey(0),
                     reward_fn=make_target_token_reward(7),
                     telemetry=telemetry)
    key = jax.random.PRNGKey(1)
    ms = []
    for s in range(steps):
        prompts = jax.random.randint(jax.random.fold_in(key, s),
                                     (2, rl.prompt_len), 0, cfg.vocab_size)
        ms.append(tr.train_step(prompts, jax.random.fold_in(key, 100 + s)))
    return tr, ms


def _phase_spans(tel):
    return [sp for sp in tel.tracer.spans if sp.cat == "phase"]


# ------------------------------------------------------------- attributor
def test_attributor_exactness_and_residue():
    """sum(owners) + unattributed == total_bytes, and total matches the
    independent live_device_bytes() walk."""
    a = jnp.ones((64, 64))
    b = jnp.ones((32, 32))
    at = MemoryAttributor()
    at.register("a", lambda: {"x": a})
    at.register("b", lambda: b)
    snap = at.snapshot()
    assert snap.owners["a"] >= a.nbytes and snap.owners["b"] >= b.nbytes
    assert sum(snap.owners.values()) + snap.unattributed == snap.total_bytes
    assert snap.total_bytes == live_device_bytes()
    # an unregistered array lands in the residue
    c = jnp.ones((16, 16))
    snap2 = at.snapshot()
    assert snap2.unattributed >= snap.unattributed + c.nbytes
    del c


def test_attributor_alias_first_registration_wins():
    shared = jnp.ones((8, 8))
    at = MemoryAttributor()
    at.register("first", lambda: shared)
    at.register("second", lambda: {"alias": shared})
    snap = at.snapshot()
    assert snap.owners["first"] >= shared.nbytes
    assert snap.owners["second"] == 0
    # no double counting: the alias contributes once to the total
    assert sum(snap.owners.values()) + snap.unattributed == snap.total_bytes


def test_attributor_none_getter_and_top_buffers():
    big = jnp.ones((128, 128))
    at = MemoryAttributor(top_k=3)
    at.register("gone", lambda: None)          # owner holds nothing now
    at.register("big", lambda: big)
    snap = at.snapshot()
    assert snap.owners["gone"] == 0
    assert 1 <= len(snap.top_buffers) <= 3      # capped at top_k
    tb = snap.top_buffers[0]
    assert tb["owner"] == "big" and tb["nbytes"] == big.nbytes
    # metadata only — shape/dtype are strings, no array refs retained
    assert isinstance(tb["shape"], str) and isinstance(tb["dtype"], str)
    assert snap.ranked()[0] == "big"
    assert snap.table() == {k: v for k, v in snap.owners.items() if v}


# --------------------------------------------------------- flight recorder
def test_flight_watermark_trigger_and_latch(tmp_path):
    path = str(tmp_path / "dump.json")
    fl = FlightRecorder(watermark=0.5, capacity_bytes=1000, ring=4,
                        path=path)
    for i in range(10):
        fl.note("tick", i=i)
    assert len(fl.ring) == 4                    # bounded
    assert fl.check(100) is None                # below watermark
    at = MemoryAttributor()
    x = jnp.ones((4, 4))
    at.register("x", lambda: x)
    dump = fl.check(600, snapshot_fn=at.snapshot, phase="p", source="t")
    assert dump is not None and dump["trigger"] == "watermark"
    assert dump["schema"] == "flight-recorder/v1"
    assert dump["live_bytes"] == 600 and dump["capacity_bytes"] == 1000
    assert dump["owners"].get("x", 0) >= x.nbytes
    assert dump["owners_ranked"][0] == "x"
    assert len(dump["ring"]) == 4
    # latched: a second breach does not dump again
    assert fl.check(999) is None and len(fl.dumps) == 1
    disk = json.load(open(path))
    assert disk["trigger"] == "watermark"


def test_flight_calibration_fallback():
    """With no explicit capacity and no device bytes_limit info used, the
    first check latches the budget and cannot itself breach; the next
    check crossing watermark * budget trips."""
    fl = FlightRecorder(watermark=0.5, ring=8)
    fl.capacity_bytes, fl._calibrated = None, False      # force fallback
    assert fl.check(1000) is None                        # calibrates
    assert fl.capacity_bytes == 1000
    assert fl.check(400) is None                         # 0.4 < 0.5
    assert fl.check(600) is not None                     # 0.6 >= 0.5


def test_flight_is_oom_and_record_oom():
    fl = FlightRecorder(capacity_bytes=1 << 30)
    assert fl.is_oom(RuntimeError("RESOURCE_EXHAUSTED: out of memory"))
    assert not fl.is_oom(ValueError("shape mismatch"))
    exc = RuntimeError("RESOURCE_EXHAUSTED: 2.5GiB")
    dump = fl.record_oom(exc, live_bytes=123, phase="train_actor",
                         source="rlhf")
    assert dump["trigger"] == "resource_exhausted"
    assert "RESOURCE_EXHAUSTED" in dump["error"]
    assert dump["phase"] == "train_actor"
    assert fl.record_oom(exc) is None            # latched per kind
    # watermark latch is independent of the OOM latch
    assert fl.check(1 << 30) is not None


def test_flight_phase_history():
    fl = FlightRecorder(capacity_bytes=1 << 30)
    fl.note("phase", phase="rollout", live_bytes=10, host_bytes=5)
    fl.note("sample", phase="x", live_bytes=99)          # not a boundary
    fl.note("phase", phase="train_actor", live_bytes=20, host_bytes=0)
    assert [p["phase"] for p in fl.phase_history] == \
        ["rollout", "train_actor"]


# ------------------------------------------------- trainer integration
@pytest.mark.parametrize("engine", ["hydra", "separate"])
def test_ppo_spans_carry_exact_attribution(engine):
    tel = RunTelemetry.create(engine=engine)
    tr, _ = run_ppo(engine, tel, steps=2)
    spans = _phase_spans(tel)
    assert spans, "no phase spans"
    for sp in spans:
        a = sp.args
        assert "attrib" in a, sp.name
        assert sum(a["attrib"].values()) + a["attrib_unattributed"] \
            == a["measured_bytes"], sp.name
    # the sim join: at least some spans diff the owner table against the
    # simulator's per-state ledger, per-owner
    deltas = [sp.args["attrib_sim_delta"] for sp in spans
              if "attrib_sim_delta" in sp.args]
    assert deltas
    sim_names = set().union(*(d.keys() for d in deltas))
    assert sim_names & {"actor_params", "critic_opt", "base_params",
                        "ref_params"}
    # owner gauges reached the registry
    g = tel.registry.get("rlhf_owner_live_bytes")
    assert g is not None


def test_ppo_watermark_dump_names_owners(tmp_path):
    path = str(tmp_path / "flight.json")
    fl = FlightRecorder(watermark=0.9, ring=64, path=path)
    tel = RunTelemetry.create(engine="hydra", flight=fl)
    run_ppo("hydra", tel, steps=2)
    assert fl.dumps, "watermark never tripped"
    dump = fl.dumps[0]
    assert dump["trigger"] == "watermark" and dump["source"] == "rlhf"
    assert dump["owners_ranked"] and dump["top_buffers"]
    assert all(dump["owners"][o] > 0 for o in dump["owners_ranked"][:3])
    assert dump["phase_history"], "dump carries no phase history"
    assert json.load(open(path))["schema"] == "flight-recorder/v1"


def test_telemetry_is_pure_observer():
    """Attribution + flight recorder must not change training math: losses
    bit-equal with and without them attached."""
    tel = RunTelemetry.create(engine="hydra",
                              flight=FlightRecorder(watermark=0.9))
    _, with_obs = run_ppo("hydra", tel, steps=2)
    _, without = run_ppo("hydra", None, steps=2)
    for a, b in zip(with_obs, without):
        for k in ("loss", "vf_loss", "ppo_loss"):
            assert a[k] == b[k], (k, a[k], b[k])


# ------------------------------------------------- serving + compiled mem
def test_serving_attribution_and_compiled_memory():
    from repro.models import Model
    from repro.serving import ContinuousBatcher
    cfg = micro_cfg()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    fl = FlightRecorder(watermark=0.99, ring=32)
    tel = RunTelemetry.create(run="serving-test", flight=fl)
    cb = ContinuousBatcher(model, cfg, params, slots=2, capacity=32,
                           temperature=0.0, seed=0, cache_backend="paged",
                           page_size=8, telemetry=tel)
    rng = np.random.RandomState(0)
    for _ in range(3):
        cb.submit(rng.randint(0, cfg.vocab_size, size=4), 4)
    cb.run_until_drained()
    at = tel.attribution
    assert at is not None
    snap = at.snapshot()
    assert snap.owners["serving_params"] > 0
    assert snap.owners["kv_pool"] > 0
    assert sum(snap.owners.values()) + snap.unattributed == snap.total_bytes
    # CompileCache keys joined with compiled-memory stats
    assert cb.compiled_memory, "no compiled programs recorded"
    for key, stats in cb.compiled_memory.items():
        assert stats is None or "temp_bytes" in stats
    names = {m["name"] for m in tel.registry.snapshot()}
    assert "compiled_temp_bytes" in names
    # the forced near-1.0 watermark tripped during serving with context
    if fl.dumps:
        assert fl.dumps[0]["source"] == "serving"


def test_record_compiled_memory_unit():
    reg = MetricsRegistry()
    fn = jax.jit(lambda x: x * 2 + 1)
    x = jnp.ones((8, 8))
    stats = record_compiled_memory(reg, "double", fn, x)
    if stats is not None:                  # backend exposes memory_analysis
        assert set(stats) == {"temp_bytes", "argument_bytes",
                              "output_bytes", "generated_code_bytes"}
        g = reg.get("compiled_output_bytes")
        assert g.value(program="double") == stats["output_bytes"]
    # a non-lowerable callable degrades to None, not an exception
    assert record_compiled_memory(reg, "plain", lambda y: y, x) is None
