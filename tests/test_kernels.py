"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref as R
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.rmsnorm import rmsnorm
from repro.kernels.ssd_scan import ssd_scan
from repro.models.flash import flash_sdpa


def _tol(dt):
    return 2e-2 if dt == jnp.bfloat16 else 5e-5


@pytest.mark.parametrize("B,Sq,Sk,H,K,D,causal,window,bq,bk,dt", [
    (2, 128, 128, 4, 2, 64, True, 0, 64, 64, jnp.float32),
    (1, 256, 256, 8, 8, 128, True, 64, 128, 128, jnp.bfloat16),
    (2, 100, 100, 6, 2, 32, True, 0, 64, 64, jnp.float32),
    (1, 64, 192, 4, 1, 64, False, 0, 32, 64, jnp.float32),
    (1, 96, 96, 2, 2, 128, True, 32, 32, 32, jnp.bfloat16),
])
def test_flash_attention_kernel(B, Sq, Sk, H, K, D, causal, window, bq, bk, dt):
    ks = jax.random.split(jax.random.PRNGKey(B + Sq), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, D), dt)
    k = jax.random.normal(ks[1], (B, Sk, K, D), dt)
    v = jax.random.normal(ks[2], (B, Sk, K, D), dt)
    out = flash_attention_fwd(q, k, v, causal=causal, window=window,
                              block_q=bq, block_k=bk)
    ref = R.attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=_tol(dt))


@pytest.mark.parametrize("B,Sq,Sk,H,K,D,causal,window", [
    (2, 64, 64, 8, 2, 32, True, 0),
    (2, 128, 128, 4, 4, 16, True, 24),
    (1, 37, 53, 6, 3, 8, False, 0),
])
def test_flash_xla_twin_grad(B, Sq, Sk, H, K, D, causal, window):
    """The XLA flash path (used inside the models) must match the oracle in
    both forward and gradients."""
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, D))
    k = jax.random.normal(ks[1], (B, Sk, K, D))
    v = jax.random.normal(ks[2], (B, Sk, K, D))
    f_ref = lambda q, k, v: (R.attention_ref(
        q, k, v, causal=causal, window=window) ** 2).sum()
    f_fl = lambda q, k, v: (flash_sdpa(q, k, v, causal, window, 16) ** 2).sum()
    np.testing.assert_allclose(f_fl(q, k, v), f_ref(q, k, v), rtol=1e-5)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    gf = jax.grad(f_fl, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gr, gf):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


@pytest.mark.parametrize("B,H,K,D,C,window,bc,dt", [
    (2, 8, 2, 64, 128, 0, 64, jnp.float32),
    (1, 4, 4, 32, 96, 24, 32, jnp.float32),
    (2, 6, 1, 128, 256, 0, 512, jnp.bfloat16),
])
def test_decode_attention_kernel(B, H, K, D, C, window, bc, dt):
    ks = jax.random.split(jax.random.PRNGKey(H + C), 4)
    q = jax.random.normal(ks[0], (B, H, D), dt)
    kc = jax.random.normal(ks[1], (B, C, K, D), dt)
    vc = jax.random.normal(ks[2], (B, C, K, D), dt)
    position = jnp.array([C + 5] * B) if window else jnp.array([C - 2] * B)
    slots = jnp.arange(C)[None, :].repeat(B, 0)
    base = position[:, None] - (position[:, None] % C)
    pos = jnp.where(slots <= (position[:, None] % C), base + slots,
                    base - C + slots)
    pos = jnp.where(pos < 0, -1, pos).astype(jnp.int32)
    out = decode_attention(q, kc, vc, pos, position, window=window,
                           block_c=bc)
    ref = R.decode_attention_ref(q, kc, vc, pos, position, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=_tol(dt))


@pytest.mark.parametrize("B,S,H,P,N,chunk,dt", [
    (2, 128, 4, 32, 16, 32, jnp.float32),
    (1, 256, 8, 64, 128, 128, jnp.float32),
    (2, 64, 2, 16, 8, 16, jnp.float32),
    (1, 128, 4, 64, 64, 64, jnp.bfloat16),
])
def test_ssd_scan_kernel(B, S, H, P, N, chunk, dt):
    ks = jax.random.split(jax.random.PRNGKey(S), 4)
    x = (jax.random.normal(ks[0], (B, S, H, P)) * 0.5).astype(dt)
    a = -jnp.abs(jax.random.normal(ks[1], (B, S, H))) * 0.3
    b = (jax.random.normal(ks[2], (B, S, N)) * 0.5).astype(dt)
    c = (jax.random.normal(ks[3], (B, S, N)) * 0.5).astype(dt)
    y, fin = ssd_scan(x, a.astype(dt), b, c, chunk=chunk)
    yr, finr = R.ssd_ref(x.astype(jnp.float32), a, b.astype(jnp.float32),
                         c.astype(jnp.float32))
    atol = 5e-2 if dt == jnp.bfloat16 else 1e-3
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), atol=atol)
    np.testing.assert_allclose(np.asarray(fin, np.float32),
                               np.asarray(finr, np.float32), atol=atol)


def test_ssd_model_chunked_matches_sequential():
    """The model's XLA chunked SSD (matmul form) vs the sequential oracle."""
    from repro.models.mamba import ssd_chunked
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    B, S, H, P, N = 2, 96, 4, 32, 16
    x = jax.random.normal(ks[0], (B, S, H, P)) * 0.5
    a = -jnp.abs(jax.random.normal(ks[1], (B, S, H))) * 0.3
    b = jax.random.normal(ks[2], (B, S, N)) * 0.5
    c = jax.random.normal(ks[3], (B, S, N)) * 0.5
    y, fin = ssd_chunked(x, a, b, c, 32)
    yr, finr = R.ssd_ref(x, a, b, c)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-4)
    np.testing.assert_allclose(np.asarray(fin), np.asarray(finr), atol=1e-4)


@pytest.mark.parametrize("shape,dt", [
    ((4, 64, 256), jnp.bfloat16),
    ((3, 100), jnp.float32),
    ((2, 7, 384), jnp.bfloat16),
    ((1, 1, 128), jnp.float32),
])
def test_rmsnorm_kernel(shape, dt):
    ks = jax.random.split(jax.random.PRNGKey(shape[-1]), 2)
    x = jax.random.normal(ks[0], shape, dt)
    s = jax.random.normal(ks[1], shape[-1:])
    out = rmsnorm(x, s)
    ref = R.rmsnorm_ref(x, s)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=_tol(dt))
