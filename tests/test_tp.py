"""Tensor parallelism as a runtime axis (DESIGN.md §9): Megatron spec
rules, TP x ZeRO composition, and the dp x tp runtime smokes.

Spec-structure checks run in-process on one device (SpecMesh — no device
state touched); the runtime allclose/identity smokes spawn forced-device
subprocesses and run in the CI multidevice job, like test_zero_rlhf.py.

The correctness bar under TP is ALLCLOSE, not bitwise: TP splits matmul
contractions, so partial sums reduce in a different order than the
single-device program (~1 ulp of the accumulation dtype per layer). The
pure-DP ZeRO contract (test_zero_rlhf.py) stays bit-identical because DP
never splits a contraction.
"""
import os
import subprocess
import sys
import textwrap

import pytest

# runs (also) in the CI multidevice job's forced-device topology
pytestmark = pytest.mark.multidevice

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

runtime_smoke = pytest.mark.skipif(
    "host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""),
    reason="runtime TP smokes run in the multidevice CI job (set "
           "XLA_FLAGS=--xla_force_host_platform_device_count=8 to enable)")


def _run(code: str, devices: int = 8, timeout: int = 1200):
    env = dict(os.environ,
               PYTHONPATH=os.path.join(_ROOT, "src"),
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, \
        f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    return r.stdout


# f32 params + greedy rollout: reduction-order drift stays ~1e-7 relative
# and the trajectories cannot fork on it, so allclose compares numerics,
# not diverged experience (see benchmarks/tp_smoke.py).
_SMOKE_PRELUDE = """
    import dataclasses, jax, jax.numpy as jnp
    from repro.configs import get_config
    from repro.rlhf import RLHFConfig, RLHFTrainer
    from repro.rlhf.reward import make_target_token_reward
    from repro.sharding import ShardedContext

    cfg = dataclasses.replace(
        get_config("llama3_2_3b").smoke(), num_layers=2, d_model=128,
        d_ff=256, vocab_size=64, num_heads=4, num_kv_heads=2, head_dim=32,
        param_dtype="float32")
    P, G, B = 8, 12, 4
    key = jax.random.PRNGKey(1)
    prompts = jax.random.randint(key, (B, P), 0, cfg.vocab_size)

    def run(engine, shard, steps=2):
        rl = RLHFConfig(prompt_len=P, gen_len=G, lr=1e-3, critic_lr=1e-3,
                        kl_coef=0.0, top_k=0, temperature=0.0,
                        engine=engine, lora_rank=8)
        tr = RLHFTrainer(cfg, cfg, rl, jax.random.PRNGKey(0),
                         reward_fn=make_target_token_reward(7), shard=shard)
        ms = [tr.train_step(prompts, jax.random.fold_in(key, s))
              for s in range(steps)]
        return tr, ms

    def assert_allclose(m1, m2, label, rtol=1e-4, atol=1e-6):
        for a, b in zip(m1, m2):
            for k in ("loss", "ppo_loss", "vf_loss"):
                if k in a:
                    d = abs(a[k] - b[k])
                    assert d <= atol + rtol * abs(a[k]), \\
                        (label, k, a[k], b[k])
"""


@runtime_smoke
@pytest.mark.parametrize("engine", ["separate", "hydra"])
@pytest.mark.parametrize("zero_stage", [0, 3])
def test_tp_allclose_grid(engine, zero_stage):
    """2-step PPO losses allclose between (ndp=1, ntp=1) and
    (ndp=2, ntp=2) at ZeRO off AND ZeRO-3, both engines — the axes
    compose. Every layout must also cut per-device persistent state."""
    _run(_SMOKE_PRELUDE + f"""
    tr1, m1 = run("{engine}", None)
    sc = ShardedContext.create(2, zero_stage={zero_stage}, model=2)
    tr2, m2 = run("{engine}", sc)
    assert_allclose(m1, m2, "{engine}-z{zero_stage}-tp2")
    b1, b2 = tr1.per_device_state_bytes(), tr2.per_device_state_bytes()
    assert b2 < b1, (b2, b1)
    print("OK", b1, b2)
    """)


@runtime_smoke
def test_tp_pure_cut_separate():
    """Pure TP (zero_stage=0, DP replicated) cuts per-device param+opt
    bytes >= 40% at ntp=2 for the separate engine — the acceptance bar
    for the new axis on its own."""
    _run(_SMOKE_PRELUDE + """
    tr1, _ = run("separate", None, steps=1)
    sc = ShardedContext.create(2, zero_stage=0, model=2)
    tr2, _ = run("separate", sc, steps=1)
    b1, b2 = tr1.per_device_state_bytes(), tr2.per_device_state_bytes()
    assert b2 <= 0.60 * b1, (b2, b1)
    print("cut to", 100 * b2 / b1, "%")
    """)


@runtime_smoke
def test_tp_rollout_identity_dense_and_paged():
    """Greedy rollout from the TP-sharded, DP-gathered actor — dense AND
    paged decode, the paged pool itself kv-head-sharded over "model" —
    matches the unsharded tokens exactly (separate engine)."""
    _run(_SMOKE_PRELUDE + """
    from repro.rlhf import Rollout
    tr1, _ = run("separate", None, steps=1)
    sc = ShardedContext.create(2, zero_stage=3, model=2)
    tr2, _ = run("separate", sc, steps=1)
    tok1 = Rollout(tr1.actor, cfg, capacity=P + G, temperature=0.0,
                   top_k=0).generate(tr1.actor_state["params"],
                                     {"tokens": prompts}, G, key).tokens
    p2, owned = tr2.actor_plan.gather_copy(tr2.actor_state["params"])
    assert owned
    for backend in ("dense", "paged"):
        ro = Rollout(tr2.actor, cfg, capacity=P + G, temperature=0.0,
                     top_k=0, backend=backend, mesh=sc.mesh).generate(
            p2, {"tokens": prompts}, G, key)
        assert bool(jnp.array_equal(tok1, ro.tokens)), backend
    print("rollout identical (dense+paged, tp2)")
    """)


@runtime_smoke
def test_tp_hydra_merged_rollout_identity():
    """Hydra under TP: adapters partition consistently with their base
    matmuls (rules.adapter_pspecs), so the shard-local base + A @ B merge
    is exact — the merged rollout reproduces the unsharded tokens."""
    _run(_SMOKE_PRELUDE + """
    from repro.rlhf import Rollout
    tr1, _ = run("hydra", None, steps=1)
    p1 = tr1.actor.merge_adapter(tr1.base_params, tr1.actor_state["params"])
    tok1 = Rollout(tr1.actor, cfg, capacity=P + G, temperature=0.0,
                   top_k=0).generate(p1, {"tokens": prompts}, G, key).tokens
    sc = ShardedContext.create(2, zero_stage=3, model=2)
    tr2, _ = run("hydra", sc, steps=1)
    base2, ob = tr2.engine.base_plan.gather_copy(tr2.base_params)
    ad2, oa = tr2.engine.adapter_plans["actor"].gather_copy(
        tr2.actor_state["params"])
    assert ob and oa
    p2 = tr2.actor.merge_adapter(base2, ad2)
    for backend in ("dense", "paged"):
        ro = Rollout(tr2.actor, cfg, capacity=P + G, temperature=0.0,
                     top_k=0, backend=backend, mesh=sc.mesh).generate(
            p2, {"tokens": prompts}, G, key)
        assert bool(jnp.array_equal(tok1, ro.tokens)), backend
    print("hydra merged rollout identical under tp2")
    """)


# ---------------------------------------------------------------------------
# Spec-level checks: no devices needed (fast lane)
# ---------------------------------------------------------------------------
def _entries(spec, leaf):
    return list(spec) + [None] * (len(leaf.shape) - len(spec))


def _site_specs(specs, shapes):
    """{path: (spec entries, shape)} with stringified paths."""
    import jax
    from jax.sharding import PartitionSpec as P
    flat = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, P))[0]
    leaves = jax.tree_util.tree_flatten_with_path(shapes)[0]
    out = {}
    for (kp, spec), (_, leaf) in zip(flat, leaves):
        path = tuple(str(getattr(k, "key", k)) for k in kp)
        out[path] = (_entries(spec, leaf), leaf.shape)
    return out


def test_param_pspecs_megatron_sites():
    """The Megatron mapping (DESIGN.md §9 table): QKV/up column-parallel
    (output dim over "model"), down/out row-parallel (input dim), embed
    and lm_head vocab-parallel — with the ZeRO-3 DP entry on the OTHER
    dim, so the axes never stack."""
    import jax

    from repro.configs import get_config
    from repro.models import Model
    from repro.sharding import ShardingStrategy, SpecMesh, param_pspecs

    cfg = get_config("llama3_2_3b")
    shapes = jax.eval_shape(Model(cfg).init, jax.random.PRNGKey(0))
    mesh = SpecMesh({"data": 2, "model": 2})
    strat = ShardingStrategy(zero_stage=3, ntp=2)
    sites = _site_specs(param_pspecs(cfg, mesh, strat, shapes), shapes)
    checked = {"col": 0, "row": 0, "vocab": 0}
    for path, (entries, shape) in sites.items():
        name = path[-1]
        # stacked segment trees carry a leading None
        body = entries[1:] if entries and entries[0] is None and \
            any(p.startswith("segment") for p in path) else entries
        if name in ("wq", "wk", "wv", "w_in", "w_gate") and len(body) == 2:
            assert body[-1] == "model", (path, entries)
            assert body[-2] != "model", (path, entries)
            checked["col"] += 1
        if name in ("wo", "w_out") and len(body) == 2:
            assert body[-2] == "model", (path, entries)
            assert body[-1] != "model", (path, entries)
            checked["row"] += 1
        if name == "embed":
            assert entries[0] == "model", (path, entries)
            checked["vocab"] += 1
        if name == "lm_head":
            assert entries[-1] == "model", (path, entries)
            checked["vocab"] += 1
        # TP and DP never share a dim
        for e in entries:
            assert e != ("data", "model"), (path, entries)
    assert all(v > 0 for v in checked.values()), checked


def test_adapter_pspecs_tp_consistency():
    """Adapter factors partition consistently with their base matmul:
    column sites put "model" on b's d_out, row sites on a's d_in — so the
    merge base + A @ B needs no collective and lands in the base layout."""
    import jax
    from jax.sharding import PartitionSpec as P

    from repro.configs import get_config
    from repro.models import Model
    from repro.sharding import (TP_COL_SITES, TP_ROW_SITES,
                                ShardingStrategy, SpecMesh, adapter_pspecs)

    cfg = get_config("llama3_2_3b")
    model = Model(cfg)
    key = jax.random.PRNGKey(0)
    base = jax.eval_shape(model.init, key)
    ad = jax.eval_shape(
        lambda k: model.init_adapter(k, base, 128, with_value=True), key)
    mesh = SpecMesh({"data": 2, "model": 2})
    specs = adapter_pspecs(mesh, ShardingStrategy(zero_stage=0, ntp=2), ad)
    sites = _site_specs(specs, ad)
    n_col = n_row = 0
    for path, (entries, shape) in sites.items():
        name, site = path[-1], (path[-2] if len(path) >= 2 else "")
        if "value_head" in path:
            assert all(e is None for e in entries), (path, entries)
            continue
        if name == "a" and site in TP_ROW_SITES:
            assert entries[-2] == "model", (path, entries)
            n_row += 1
        if name == "b" and site in TP_COL_SITES and shape[-1] % 2 == 0:
            assert entries[-1] == "model", (path, entries)
            n_col += 1
        if name == "a" and site in TP_COL_SITES:
            assert "model" not in entries, (path, entries)
        if name == "b" and site in TP_ROW_SITES:
            assert "model" not in entries, (path, entries)
    assert n_col > 0 and n_row > 0, (n_col, n_row)


def test_validate_tp_divisibility():
    """The eager launch-time validator names every offending dim instead
    of leaving an XLA shape error inside jit."""
    import dataclasses

    import pytest as _pytest

    from repro.configs import get_config
    from repro.sharding import ShardingStrategy, validate_tp

    cfg = dataclasses.replace(
        get_config("llama3_2_3b").smoke(), num_heads=4, num_kv_heads=2,
        d_ff=256, vocab_size=64)
    validate_tp(cfg, 1)
    validate_tp(cfg, 2)
    with _pytest.raises(ValueError, match="num_heads"):
        validate_tp(cfg, 3)
    with _pytest.raises(ValueError, match="ntp"):
        ShardingStrategy(ntp=0)
    with _pytest.raises(ValueError, match="tensor_parallel"):
        ShardingStrategy(ntp=2, tensor_parallel=False)
    with _pytest.raises(ValueError, match="tp_mode"):
        ShardingStrategy(tp_mode="colwise")


def test_tp_mesh_degree_mismatch_rejected():
    """A strategy declaring ntp=2 refuses a mesh whose model axis is a
    different size — specs and devices can never silently diverge."""
    import jax

    import pytest as _pytest

    from repro.configs import get_config
    from repro.models import Model
    from repro.sharding import ShardingStrategy, SpecMesh, param_pspecs

    cfg = get_config("llama3_2_3b")
    shapes = jax.eval_shape(Model(cfg).init, jax.random.PRNGKey(0))
    strat = ShardingStrategy(zero_stage=3, ntp=2)
    with _pytest.raises(AssertionError, match="model"):
        param_pspecs(cfg, SpecMesh({"data": 2, "model": 4}), strat, shapes)
    with _pytest.raises(AssertionError, match="model"):
        param_pspecs(cfg, SpecMesh({"data": 4}), strat, shapes)


def test_strip_dp_preserves_model_entries():
    """The ZeRO-3 gather target layout: DP entries drop, TP entries stay
    — a gather moves ONLY the DP dimension."""
    from jax.sharding import PartitionSpec as P

    from repro.sharding import SpecMesh
    from repro.sharding.context import _strip_dp

    mesh = SpecMesh({"data": 2, "model": 2})
    assert _strip_dp(P("data", "model"), mesh) == P(None, "model")
    assert _strip_dp(P("model", "data"), mesh) == P("model", None)
    assert _strip_dp(P(None, "model"), mesh) == P(None, "model")
    assert _strip_dp(P("data", None), mesh) == P(None, None)


def test_zero_opt_pspecs_keep_tp_entries():
    """ZeRO-1/2 optimizer sharding picks a dim the param spec leaves
    unsharded — under TP that choice must keep every "model" entry, so
    opt state is cut by BOTH axes (1/(ndp*ntp) for 2-D matmul leaves)."""
    import jax
    from jax.sharding import PartitionSpec as P

    from repro.configs import get_config
    from repro.models import Model
    from repro.sharding import (ShardingStrategy, SpecMesh, param_pspecs,
                                zero_opt_pspecs)

    cfg = get_config("llama3_2_3b")
    shapes = jax.eval_shape(Model(cfg).init, jax.random.PRNGKey(0))
    mesh = SpecMesh({"data": 2, "model": 2})
    strat = ShardingStrategy(zero_stage=1, ntp=2)
    pspecs = param_pspecs(cfg, mesh, strat, shapes)
    ospecs = zero_opt_pspecs(pspecs, shapes, mesh, strat)

    def count(tree, want):
        return sum(1 for spec in jax.tree.leaves(
            tree, is_leaf=lambda x: isinstance(x, P))
            for e in spec if e == want)

    assert count(ospecs, "model") == count(pspecs, "model") > 0
    # and the DP entry landed somewhere the params left whole
    assert count(ospecs, "data") > count(pspecs, "data")
    flat_p = jax.tree.leaves(pspecs, is_leaf=lambda x: isinstance(x, P))
    flat_o = jax.tree.leaves(ospecs, is_leaf=lambda x: isinstance(x, P))
    for ps, os_ in zip(flat_p, flat_o):
        for pe, oe in zip(ps, os_):
            if pe is not None:
                assert oe == pe, (ps, os_)   # opt never moves a TP entry


def test_traced_scales_tp_fractions():
    """The traced simulator realizes the axis: param fractions compose to
    ~1/(ndp*ntp) at ZeRO-3, the hydra merged-rollout fraction is exactly
    1.0 at ntp=1 (DP gather restores the full tree) and ~1/ntp under TP
    (the gathered copy stays TP-sharded), and ntp=1 reproduces the
    pre-TP pure-DP numbers byte-for-byte."""
    import dataclasses

    from repro.configs import get_config
    from repro.core import traced_zero_scales

    cfg = dataclasses.replace(
        get_config("llama3_2_3b").smoke(), num_layers=2, d_model=1024,
        d_ff=2048, vocab_size=64, num_heads=8, num_kv_heads=4, head_dim=128)

    t_dp = dict(traced_zero_scales(cfg, cfg, ndp=2, zero_stage=3))
    t_tp = dict(traced_zero_scales(cfg, cfg, ndp=2, zero_stage=3, ntp=2))
    for group in ("actor_params:param", "critic_params:param",
                  "actor_opt:opt", "critic_opt:opt"):
        f_dp, f_tp = t_dp[group], t_tp[group]
        assert 0.5 <= f_dp <= 0.7, (group, f_dp)    # ~1/2 + unshardables
        assert 0.25 <= f_tp <= 0.45, (group, f_tp)  # ~1/4 + unshardables
        assert f_tp < 0.75 * f_dp, (group, f_dp, f_tp)

    # the merged-rollout copy is a DP-gather: exactly full-size at ntp=1
    # (the invariant test_zero_rlhf's accounting grid relies on), ~1/ntp
    # under TP because the gather leaves the model axis sharded
    h_dp = dict(traced_zero_scales(cfg, cfg, ndp=2, zero_stage=3,
                                   engine="hydra", lora_rank=16))
    h_tp = dict(traced_zero_scales(cfg, cfg, ndp=2, zero_stage=3,
                                   engine="hydra", lora_rank=16, ntp=2))
    assert h_dp["merged_rollout:param"] == 1.0
    assert 0.45 <= h_tp["merged_rollout:param"] <= 0.65, \
        h_tp["merged_rollout:param"]
    # the frozen trunk composes both axes too
    assert h_tp["base_params:param"] < 0.75 * h_dp["base_params:param"]
