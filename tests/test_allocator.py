"""Property-based tests for the caching-allocator simulator."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.allocator import (KB, MB, CachingAllocator, _round_size,
                                  _segment_size)


def test_round_size():
    assert _round_size(1) == 512
    assert _round_size(512) == 512
    assert _round_size(513) == 1024
    assert _round_size(0) == 512


def test_segment_size_classes():
    assert _segment_size(512) == 2 * MB              # small pool
    assert _segment_size(MB) == 2 * MB
    assert _segment_size(2 * MB) == 20 * MB          # medium -> 20MB buffer
    assert _segment_size(30 * MB) == 30 * MB         # large: exact 2MB mult


def test_malloc_free_roundtrip():
    a = CachingAllocator()
    h = a.malloc(10 * MB)
    assert a.allocated >= 10 * MB
    assert a.reserved >= a.allocated
    a.free(h)
    assert a.allocated == 0
    assert a.reserved > 0                            # cached, not released
    released = a.empty_cache()
    assert released > 0
    assert a.reserved == 0


def test_reuse_prevents_growth():
    a = CachingAllocator()
    h = a.malloc(8 * MB)
    a.free(h)
    r0 = a.reserved
    for _ in range(10):
        h = a.malloc(8 * MB)
        a.free(h)
    assert a.reserved == r0


def test_ascending_sizes_grow_reserved():
    """The non-reusable ascending pattern (dynamic KV cache growth)."""
    a = CachingAllocator()
    prev = None
    for t in range(1, 30):
        h = a.malloc(21 * MB + t * MB)               # each bigger than cached
        if prev is not None:
            a.free(prev)
        prev = h
    assert a.reserved > a.allocated * 2              # junk accumulates
    a.free(prev)
    a.empty_cache()
    assert a.reserved == 0


def test_capacity_forced_flush():
    a = CachingAllocator(capacity=100 * MB)
    hs = [a.malloc(20 * MB) for _ in range(3)]
    for h in hs:
        a.free(h)
    # next big request exceeds capacity together with cached segments ->
    # forced flush instead of OOM
    h = a.malloc(80 * MB)
    assert a.stats.n_forced_flush == 1
    with pytest.raises(MemoryError):
        a.malloc(90 * MB)


@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(st.booleans(),
                          st.integers(min_value=1, max_value=64 * MB)),
                min_size=1, max_size=120))
def test_invariants_random_traffic(ops):
    """reserved >= allocated always; empty_cache with no live blocks zeroes
    reserved; stats are consistent."""
    a = CachingAllocator()
    live = []
    for is_alloc, size in ops:
        if is_alloc or not live:
            live.append(a.malloc(size))
        else:
            a.free(live.pop())
        assert a.reserved >= a.allocated >= 0
        assert a.stats.peak_reserved >= a.reserved
        assert a.stats.peak_allocated >= a.allocated
    for h in live:
        a.free(h)
    assert a.allocated == 0
    a.empty_cache()
    assert a.reserved == 0


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(min_value=1, max_value=8 * MB),
                min_size=2, max_size=40),
       st.randoms())
def test_coalescing_returns_full_segments(sizes, rnd):
    """After freeing everything, every segment must be one free block
    (perfect coalescing) so empty_cache releases all reserved bytes."""
    a = CachingAllocator()
    hs = [a.malloc(s) for s in sizes]
    rnd.shuffle(hs)
    for h in hs:
        a.free(h)
    for seg in a.segments:
        n_blocks = 0
        b = seg.head
        while b is not None:
            n_blocks += 1
            assert b.free
            b = b.next
        assert n_blocks == 1
    a.empty_cache()
    assert a.reserved == 0
