"""The paper's claims (R1-R3), asserted against the trace-driven allocator
simulation at the paper's own workload scale (OPT-1.3b actor/ref +
OPT-350m critic/reward, DP=4, LoRA-128, naive HF-style generation)."""
import pytest

from repro.configs import get_config
from repro.core import (PAPER_STRATEGIES, build_rlhf_phases,
                        lora_trainable_fraction, run_iteration)

GEN_LENS = [180, 256, 199, 243]


@pytest.fixture(scope="module")
def study():
    actor = get_config("opt_1_3b")
    critic = get_config("opt_350m")
    tf = lora_trainable_fraction(actor, 128)
    plans = {}
    persist = {}
    for ckpt in (False, True):
        ps, pe = [], None
        for gl in GEN_LENS:
            ph, pe = build_rlhf_phases(actor, critic, gen_len=gl,
                                       naive_generation=True, grad_ckpt=ckpt)
            ps.append(ph)
        plans[ckpt], persist[ckpt] = ps, pe
    strat = {s.name: s for s in PAPER_STRATEGIES}

    def run(strategy_name, policy, **kw):
        s = strat[strategy_name]
        return run_iteration(plans[s.grad_ckpt], persist[s.grad_ckpt], s,
                             policy, ndp=4, trainable_fraction=tf, **kw)
    return run


@pytest.mark.slow
def test_r1_fragmentation_overhead_exists(study):
    """R1: peak reserved carries a significant fragmentation overhead."""
    r = study("None", "none")
    overhead = r.frag_at_peak / (r.peak_reserved - r.frag_at_peak)
    assert overhead > 0.15, overhead        # paper: 46% for all-enabled


@pytest.mark.slow
def test_r1_fragmentation_accumulates_from_inference(study):
    """R1: most fragmentation comes from the inference phases — cleaning
    only after inference recovers almost all of it."""
    base = study("None", "none")
    after_inf = study("None", "after_inference")
    assert after_inf.frag_at_peak < 0.3 * base.frag_at_peak


@pytest.mark.slow
def test_r3_empty_cache_reduces_consumption(study):
    """R3: empty_cache after inference cuts peak consumption by >=15%
    (paper: 25% average) at <=8% time overhead (paper: 2%)."""
    base = study("None", "none")
    fixed = study("None", "after_inference")
    reduction = 1 - fixed.peak_reserved / base.peak_reserved
    assert reduction >= 0.15, reduction
    overhead = fixed.time_s / base.time_s - 1
    assert overhead <= 0.08, overhead


@pytest.mark.slow
def test_r3_placement_ablation(study):
    """R3: after_inference ~ after_all; both strictly better than none."""
    none = study("None", "none").peak_reserved
    ai = study("None", "after_inference").peak_reserved
    aa = study("None", "after_all").peak_reserved
    assert ai < none and aa < none
    assert abs(ai - aa) / aa < 0.10


@pytest.mark.slow
def test_r2_zero3_raises_fragmentation(study):
    """R2: ZeRO-3's per-layer gather churn raises fragmentation vs ZeRO-1."""
    z1 = study("ZeRO-1", "none")
    z3 = study("ZeRO-3", "none")
    assert z3.frag_at_peak >= z1.frag_at_peak * 0.9
    # ...but ZeRO-3 still reduces *allocated* (weights sharded)
    assert z3.peak_allocated < z1.peak_allocated


@pytest.mark.slow
def test_r2_offload_and_ckpt_reduce_consumption(study):
    none = study("None", "none")
    off = study("ZeRO-3 + CPU Offloading", "none")
    ck = study("Gradient Checkpointing", "none")
    assert off.peak_reserved < none.peak_reserved
    assert ck.peak_allocated < none.peak_allocated


@pytest.mark.slow
def test_framework_static_cache_removes_decode_churn():
    """Beyond-paper: our fixed-capacity donated KV cache (vs the HF-style
    growing cache the paper studied) removes the decode-phase reserved
    growth entirely."""
    actor = get_config("opt_1_3b")
    critic = get_config("opt_350m")
    tf = lora_trainable_fraction(actor, 128)
    strat = PAPER_STRATEGIES[0]

    def decode_growth(naive):
        ph, persist = build_rlhf_phases(actor, critic, gen_len=256,
                                        naive_generation=naive)
        r = run_iteration([ph], persist, strat, "none", ndp=4,
                          trainable_fraction=tf, capacity=None)
        recs = {p.name: p for p in r.phase_records}
        return (recs["rollout_decode"].reserved_end
                - recs["rollout_prefill"].reserved_end)

    naive = decode_growth(True)
    ours = decode_growth(False)
    assert ours < 0.5 * naive, (ours, naive)
