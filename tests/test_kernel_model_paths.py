"""Model-level kernel wiring: the Pallas decode-attention and SSD paths,
invoked through the model code (interpret mode), must match the default
XLA paths."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import layers as L
from repro.models.mamba import mamba_fwd, init_mamba


def test_attention_decode_kernel_path_matches():
    cfg = dataclasses.replace(get_config("llama3_2_3b").smoke(),
                              param_dtype="float32")
    params = L.init_attention(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, cap = 2, 32
    cache = L.init_kv_cache(cfg, B, cap, jnp.float32)
    key = jax.random.PRNGKey(1)
    for t in range(4):
        x = jax.random.normal(jax.random.fold_in(key, t),
                              (B, 1, cfg.d_model)) * 0.3
        pos = jnp.full((B,), t, jnp.int32)
        o_ref, c_ref = L.attention_decode(params, x, pos, cache, cfg)
        o_ker, c_ker = L.attention_decode(params, x, pos, cache, cfg,
                                          use_kernel=True)
        np.testing.assert_allclose(np.asarray(o_ker), np.asarray(o_ref),
                                   atol=1e-5)
        for a, b in zip(jax.tree.leaves(c_ref), jax.tree.leaves(c_ker)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        cache = c_ref


def test_attention_decode_kernel_path_windowed():
    cfg = dataclasses.replace(get_config("llama3_2_3b").smoke(),
                              param_dtype="float32")
    params = L.init_attention(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, cap, window = 2, 16, 16
    cache = L.init_kv_cache(cfg, B, cap, jnp.float32)
    key = jax.random.PRNGKey(2)
    for t in range(20):   # exceeds capacity: rolling wraparound exercised
        x = jax.random.normal(jax.random.fold_in(key, t),
                              (B, 1, cfg.d_model)) * 0.3
        pos = jnp.full((B,), t, jnp.int32)
        o_ref, cache2 = L.attention_decode(params, x, pos, cache, cfg,
                                           window=window)
        o_ker, _ = L.attention_decode(params, x, pos, cache, cfg,
                                      window=window, use_kernel=True)
        np.testing.assert_allclose(np.asarray(o_ker), np.asarray(o_ref),
                                   atol=1e-5)
        cache = cache2


def test_mamba_fwd_kernel_path_matches():
    cfg = dataclasses.replace(get_config("mamba2_370m").smoke(),
                              param_dtype="float32")
    params = init_mamba(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model)) * 0.3
    y_ref = mamba_fwd(params, x, cfg)
    y_ker = mamba_fwd(params, x, cfg, use_kernel=True)
    np.testing.assert_allclose(np.asarray(y_ker), np.asarray(y_ref),
                               atol=2e-4)


def test_attention_fwd_kernel_path_matches():
    cfg = dataclasses.replace(get_config("llama3_2_3b").smoke(),
                              param_dtype="float32")
    params = L.init_attention(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model)) * 0.3
    pos = jnp.broadcast_to(jnp.arange(64), (2, 64))
    for window in (0, 16):
        y0 = L.attention_fwd(params, x, pos, cfg, window=window)
        y1 = L.attention_fwd(params, x, pos, cfg, window=window,
                             use_kernel=True)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y0), atol=1e-5)
