"""MoE dispatch properties (hypothesis): permutation equivariance when
drop-free, finiteness under aggressive dropping, router top-k validity."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.models import moe as MOE


def _cfg():
    return dataclasses.replace(get_config("granite_moe_3b_a800m").smoke(),
                               param_dtype="float32")


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_moe_permutation_equivariance_dropfree(seed):
    """With capacity high enough that nothing drops, permuting the tokens
    permutes the outputs (routing is per-token)."""
    cfg = _cfg()
    params = MOE.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (1, 16, cfg.d_model)) * 0.5
    perm = jax.random.permutation(jax.random.fold_in(key, 1), 16)
    y, _ = MOE.moe_fwd(params, x, cfg=cfg, capacity_factor=16.0)
    y_p, _ = MOE.moe_fwd(params, x[:, perm], cfg=cfg, capacity_factor=16.0)
    np.testing.assert_allclose(np.asarray(y_p), np.asarray(y[:, perm]),
                               atol=1e-5)


def test_moe_dropping_is_graceful():
    """Tiny capacity: outputs stay finite and dropped tokens fall back to
    (shared-expert + residual-free) contribution only."""
    cfg = _cfg()
    params = MOE.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model)) * 0.5
    y_tight, aux1 = MOE.moe_fwd(params, x, cfg=cfg, capacity_factor=0.25)
    y_free, aux2 = MOE.moe_fwd(params, x, cfg=cfg, capacity_factor=16.0)
    assert bool(jnp.isfinite(y_tight).all())
    # dropping must change the output (some tokens lost their experts)
    assert float(jnp.abs(y_tight - y_free).max()) > 1e-6
    # ...and can only reduce the routed contribution's norm on average
    assert float(jnp.linalg.norm(y_tight)) <= float(jnp.linalg.norm(y_free)) * 1.5


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 64), st.integers(2, 16), st.integers(0, 2**31 - 1))
@pytest.mark.slow
def test_router_topk_properties(T, E, seed):
    k = min(4, E)
    logits = jax.random.normal(jax.random.PRNGKey(seed), (T, E))
    gates, idx, aux = MOE.router_topk(logits, k)
    g = np.asarray(gates)
    i = np.asarray(idx)
    assert g.shape == (T, k) and i.shape == (T, k)
    np.testing.assert_allclose(g.sum(-1), 1.0, atol=1e-5)   # renormalized
    assert (g >= 0).all()
    assert (i >= 0).all() and (i < E).all()
    # chosen experts are distinct per token
    for t in range(T):
        assert len(set(i[t])) == k
    # aux loss bounded: E * sum(me*ce) in [~1 (uniform), E]
    assert 0.5 <= float(aux) <= E + 1e-3
