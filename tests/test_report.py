"""launch/report.py edge cases: rendering a telemetry JSONL with empty
samples, spans missing the simulator prediction, a zero-width timeline
(all samples at one timestamp), and a metrics-only file — plus the PR 8
renderers on synthetic data: the owner x phase attribution table, the
flight-recorder dump summary, and the cross-run trend table."""
import json

from repro.launch.report import (attribution_table, flight_summary, load,
                                 phase_table, render, timeline, trend_table)


def _span(name, dur_us=1000.0, **args):
    return {"type": "span", "name": name, "cat": "phase", "ts_us": 0.0,
            "dur_us": dur_us, "depth": 0, "args": args}


def _write_jsonl(path, records):
    with open(path, "w") as f:
        for r in records:
            f.write(json.dumps(r) + "\n")
    return str(path)


# --------------------------------------------------------------- edge cases
def test_render_empty_file(tmp_path):
    path = _write_jsonl(tmp_path / "empty.jsonl", [])
    out = render(path)
    assert "(no phase spans in file)" in out
    assert "(no 'memory/device_mib' samples in file)" in out


def test_phase_table_span_missing_sim_bytes():
    """A span recorded without the simulator prediction renders '-' in the
    sim/delta columns instead of crashing."""
    out = phase_table([
        _span("rollout", measured_bytes=2 << 20, measured_peak_bytes=3 << 20,
              host_bytes=0, pcie_bytes=0),
        _span("train_actor", measured_bytes=1 << 20,
              measured_peak_bytes=1 << 20, host_bytes=0, pcie_bytes=0,
              sim_peak_bytes=1 << 20, sim_delta_bytes=-(1 << 18)),
    ])
    lines = out.splitlines()
    roll = next(ln for ln in lines if ln.startswith("rollout"))
    assert roll.rstrip().endswith("-")
    actor = next(ln for ln in lines if ln.startswith("train_actor"))
    assert "-0.25" in actor


def test_timeline_zero_width():
    """All samples at the same timestamp: max(t_hi - t_lo, 1) guards the
    bucketing division."""
    samples = [{"type": "sample", "track": "memory", "ts_us": 100.0,
                "values": {"device_mib": float(v)}} for v in (1, 2, 3)]
    out = timeline(samples)
    assert "ZeroDivision" not in out and "█" in out


def test_timeline_too_few_samples():
    assert timeline([]) == "(no 'memory/device_mib' samples in file)"
    one = [{"type": "sample", "track": "memory", "ts_us": 0.0,
            "values": {"device_mib": 1.0}}]
    assert timeline(one).startswith("(no ")


def test_render_metrics_only_file(tmp_path):
    """A file holding only metric records (registry.write_jsonl with no
    tracer output) still renders, and --metrics shows the snapshot."""
    recs = [
        {"type": "metric", "name": "rlhf_phase_total", "kind": "counter",
         "labels": {"phase": "rollout"}, "value": 4.0},
        {"type": "metric", "name": "rlhf_phase_seconds", "kind": "histogram",
         "labels": {}, "count": 4, "sum": 2.0, "min": 0.25, "max": 1.0,
         "buckets": {}},
    ]
    path = _write_jsonl(tmp_path / "metrics.jsonl", recs)
    meta, events, samples, metrics = load(path)
    assert not events and not samples and len(metrics) == 2
    out = render(path, show_metrics=True)
    assert "rlhf_phase_total{phase=rollout}" in out
    assert "n=4 mean=0.5" in out


# -------------------------------------------------------- attribution table
def test_attribution_table_basic():
    events = [
        _span("rollout", attrib={"base_params": 4 << 20},
              attrib_unattributed=1 << 19),
        _span("train_actor", attrib={"actor_opt": 8 << 20, "kv": 1 << 20},
              attrib_unattributed=0),
        # a second rollout span: the LAST one per phase must win
        _span("rollout", attrib={"base_params": 2 << 20},
              attrib_unattributed=1 << 20),
    ]
    out = attribution_table(events)
    lines = out.splitlines()
    # rows sorted by largest cell; residue row last
    owners = [ln.split()[0] for ln in lines[2:]]
    assert owners == ["actor_opt", "base_params", "kv", "(unattributed)"]
    base = next(ln for ln in lines if ln.startswith("base_params"))
    assert "2.00" in base and "4.00" not in base     # last span won
    kv = next(ln for ln in lines if ln.startswith("kv"))
    assert "-" in kv            # kv owns nothing during rollout


def test_attribution_table_empty_and_sim_delta():
    assert attribution_table([]).startswith("(no per-owner")
    assert attribution_table(
        [_span("rollout", attrib={"a": 1})],
        key="attrib_sim_delta").startswith("(no per-owner")
    out = attribution_table(
        [_span("rollout", attrib_sim_delta={"base_params": -(1 << 20),
                                            "actor_opt": 1 << 21})],
        key="attrib_sim_delta")
    assert "-1.00" in out and "+2.00" in out


def test_render_includes_attribution_sections(tmp_path):
    path = _write_jsonl(tmp_path / "run.jsonl", [
        _span("rollout", measured_bytes=1, measured_peak_bytes=1,
              host_bytes=0, pcie_bytes=0, attrib={"base_params": 1 << 20},
              attrib_unattributed=0,
              attrib_sim_delta={"base_params": 1 << 19})])
    out = render(path)
    assert "per-owner attribution" in out
    assert "per-owner sim delta" in out
    # and a file without attrib args omits the sections entirely
    path2 = _write_jsonl(tmp_path / "run2.jsonl", [
        _span("rollout", measured_bytes=1, measured_peak_bytes=1,
              host_bytes=0, pcie_bytes=0)])
    assert "per-owner attribution" not in render(path2)


# ------------------------------------------------------------ flight summary
def test_flight_summary_full_dump():
    dump = {"schema": "flight-recorder/v1", "trigger": "watermark",
            "source": "rlhf", "phase": "rollout_decode",
            "live_bytes": 3 << 20, "capacity_bytes": 4 << 20,
            "watermark": 0.9,
            "owners": {"merged_rollout": 2 << 20, "actor_params": 1 << 19},
            "owners_ranked": ["merged_rollout", "actor_params"],
            "unattributed": 1 << 19,
            "top_buffers": [{"nbytes": 1 << 20, "shape": "(2, 128, 256)",
                             "dtype": "bfloat16", "owner": "merged_rollout",
                             "path": "['w_in']"}],
            "phase_history": [{"phase": "rollout", "live_bytes": 1 << 20,
                               "host_bytes": 2 << 20}],
            "ring": [{"event": "phase"}] * 5}
    out = flight_summary(dump)
    assert "trigger: watermark" in out and "phase: rollout_decode" in out
    assert "merged_rollout" in out and "66.7%" in out
    assert "@['w_in']" in out
    assert "ring: 5 context events" in out


def test_flight_summary_minimal_dump():
    """An OOM dump captured with no snapshot available (owners empty)
    still renders, including the error line."""
    out = flight_summary({"trigger": "resource_exhausted",
                          "error": "XlaRuntimeError('RESOURCE_EXHAUSTED')",
                          "live_bytes": 0})
    assert "resource_exhausted" in out
    assert "RESOURCE_EXHAUSTED" in out
    assert "(unattributed)" in out


# --------------------------------------------------------------- trend table
def test_trend_table(tmp_path):
    path = tmp_path / "HISTORY_obs.jsonl"
    rows = [
        {"t": 1.0, "iso": "2026-08-08T00:00:00", "sha": "abc1234",
         "bench": "obs", "gated": {"telemetry_overhead_pct": 0.08}},
        # a later run gains a metric: column union, '-' for the old row
        {"t": 2.0, "iso": "2026-08-08T01:00:00", "sha": "def5678",
         "bench": "obs", "gated": {"telemetry_overhead_pct": 0.07,
                                   "attrib_unattributed_pct": 0.8}},
    ]
    _write_jsonl(path, rows)
    out = trend_table(str(path))
    assert "bench history: obs (last 2 runs)" in out
    assert "abc1234" in out and "def5678" in out
    assert "telemetry_overhead_pct" in out
    assert "attrib_unattributed_pct" in out
    first = next(ln for ln in out.splitlines() if "abc1234" in ln)
    assert first.rstrip().endswith("-")


def test_trend_table_empty(tmp_path):
    path = tmp_path / "HISTORY_x.jsonl"
    path.write_text("")
    assert trend_table(str(path)) == "(empty history file)"


def test_trend_table_last_window(tmp_path):
    path = tmp_path / "HISTORY_y.jsonl"
    rows = [{"t": float(i), "iso": f"2026-08-08T00:00:{i:02d}",
             "sha": f"s{i}", "bench": "y", "gated": {"m": float(i)}}
            for i in range(30)]
    _write_jsonl(path, rows)
    out = trend_table(str(path), last=5)
    assert "(last 5 runs)" in out and "s29" in out and "s10" not in out
