"""Per-layer ZeRO-3/FSDP gathers in the scan body (DESIGN.md §3.7).

Runtime checks run in subprocesses with 8 forced host devices (the flag
must be set before jax initializes), mirroring tests/test_zero_rlhf.py;
they execute in the CI ``multidevice`` job. The spec-level checks at the
bottom need no devices and always run.

Covers:
  * 2-step PPO losses bit-identical between ``gather_mode="tree"`` and
    ``"layer"`` (and the unsharded ndp=1 run) on BOTH engines;
  * the measured per-device transient peak of the compiled grad program:
    switching tree -> layer frees at least the whole stacked parameter
    tree minus ~2 layer periods (the gathered weights live one layer at a
    time);
  * TreePlan layer-spec structure: stacked leaves keep their sharded
    state specs at the step boundary, sliced specs are DP-stripped, and
    non-stacked leaves gather whole.
"""
import os
import subprocess
import sys
import textwrap

import pytest

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

pytestmark = pytest.mark.multidevice

runtime_smoke = pytest.mark.skipif(
    "host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""),
    reason="layer-gather runtime smokes run in the multidevice CI job (set "
           "XLA_FLAGS=--xla_force_host_platform_device_count=8 to enable)")


def _run(code: str, devices: int = 8, timeout: int = 1200):
    env = dict(os.environ,
               PYTHONPATH=os.path.join(_ROOT, "src"),
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, \
        f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    return r.stdout


_PRELUDE = """
    import dataclasses, jax, jax.numpy as jnp
    from repro.configs import get_config
    from repro.rlhf import RLHFConfig, RLHFTrainer
    from repro.rlhf.reward import make_target_token_reward
    from repro.sharding import ShardedContext

    cfg = dataclasses.replace(
        get_config("llama3_2_3b").smoke(), num_layers=2, d_model=128,
        d_ff=256, vocab_size=64, num_heads=4, num_kv_heads=2, head_dim=32)
    P, G, B = 8, 12, 4
    key = jax.random.PRNGKey(1)
    prompts = jax.random.randint(key, (B, P), 0, cfg.vocab_size)

    def run(engine, shard, steps=2):
        rl = RLHFConfig(prompt_len=P, gen_len=G, lr=1e-3, critic_lr=1e-3,
                        kl_coef=0.0, top_k=0, engine=engine, lora_rank=8)
        tr = RLHFTrainer(cfg, cfg, rl, jax.random.PRNGKey(0),
                         reward_fn=make_target_token_reward(7), shard=shard)
        ms = [tr.train_step(prompts, jax.random.fold_in(key, s))
              for s in range(steps)]
        return tr, ms

    def assert_biteq(m1, m2, label):
        for a, b in zip(m1, m2):
            for k in ("loss", "ppo_loss", "vf_loss"):
                if k in a:
                    assert a[k] == b[k], (label, k, a[k], b[k])
"""


@runtime_smoke
@pytest.mark.slow
@pytest.mark.parametrize("engine", ["separate", "hydra"])
def test_layer_vs_tree_bit_identity(engine):
    """2-step PPO losses bit-identical across ndp=1, whole-tree gather,
    and per-layer gather at ZeRO-3 — the per-layer all-gather is a pure
    schedule change, never an arithmetic one."""
    _run(_PRELUDE + f"""
    tr1, m1 = run("{engine}", None)
    trT, mT = run("{engine}",
                  ShardedContext.create(8, zero_stage=3, gather_mode="tree"))
    trL, mL = run("{engine}",
                  ShardedContext.create(8, zero_stage=3, gather_mode="layer"))
    assert trL.actor_plan.gather_mode == "layer" if "{engine}" == "separate" \\
        else trL.engine.base_plan.gather_mode == "layer"
    assert_biteq(m1, mT, "{engine}-tree")
    assert_biteq(m1, mL, "{engine}-layer")
    print("OK")
    """)


@runtime_smoke
@pytest.mark.slow
def test_layer_gather_transient_peak():
    """The compiled grad program's per-device transient peak (XLA
    memory_analysis temp bytes): tree -> layer must free at least the
    whole stacked parameter tree minus ~2 layer periods — i.e. under
    per-layer gathers at most ~one gathered layer period is resident at
    any instant (needs remat so the backward re-gathers per layer)."""
    _run(_PRELUDE + """
    import numpy as np
    from repro.models import Model
    from repro.optim import make_optimizer
    from repro.steps import init_train_state, make_train_step

    cfg_t = dataclasses.replace(cfg, num_layers=8, d_model=256, d_ff=512,
                                num_heads=8, num_kv_heads=4, head_dim=32,
                                param_dtype="bfloat16", remat="full")
    model = Model(cfg_t)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    stacked = int(sum(int(np.prod(l.shape)) * jnp.dtype(l.dtype).itemsize
                      for k in shapes if k.startswith("segment")
                      for l in jax.tree.leaves(shapes[k])))
    n_slices = sum(seg.n_groups for seg in model.segments)
    slice_b = stacked // n_slices
    S = P + G
    tb = {"tokens": jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                                       cfg_t.vocab_size)}
    for k in ("loss_mask", "advantages", "old_logp", "ref_logp", "returns"):
        tb[k] = jnp.zeros((B, S), jnp.float32)

    def temp_bytes(mode):
        sc = ShardedContext.create(8, zero_stage=3, gather_mode=mode)
        plan = sc.plan_params(cfg_t, shapes, make_optimizer(cfg_t.optimizer))
        step = make_train_step(model, cfg_t, kind="ppo", shard=plan)
        state = plan.place_state(init_train_state(
            model, cfg_t, jax.random.PRNGKey(0), step.optimizer))
        c = step.jit_grads.lower(state, tb).compile()
        return int(c.memory_analysis().temp_size_in_bytes)

    t_tree, t_layer = temp_bytes("tree"), temp_bytes("layer")
    freed = t_tree - t_layer
    eps = 256 * 1024
    assert freed >= stacked - 2 * slice_b - eps, \\
        (t_tree, t_layer, stacked, slice_b)
    print("OK freed", freed, "stacked", stacked, "slice", slice_b)
    """)


@runtime_smoke
@pytest.mark.slow
def test_adafactor_zero_bit_identity():
    """Adafactor under ZeRO is bit-equal to single-device: its update
    declares a fully-replicated layout (Adafactor.update_pspecs), so the
    factored-moment and update-RMS reductions run in single-device order
    (the ROADMAP close-but-not-bit-equal item)."""
    _run(_PRELUDE + """
    cfg = dataclasses.replace(cfg, optimizer="adafactor", d_model=128,
                              d_ff=256)
    tr1, m1 = run("separate", None)
    # d_model/d_ff >= 128 so 2-D leaves really take the factored path
    import repro.optim.adafactor as AF
    assert AF._factored(tr1.actor_state["params"]["segment0"]
                        ["slot0"]["mixer"]["wq"])
    for stage in (1, 3):
        tr8, m8 = run("separate", ShardedContext.create(8, zero_stage=stage))
        assert_biteq(m1, m8, f"adafactor-z{stage}")
    print("OK")
    """)


@runtime_smoke
@pytest.mark.slow
def test_batch_shard_modes():
    """RLHFConfig.batch_shard: 'strict' raises on a non-divisible batch
    instead of silently replicating; 'throughput' shards a divisible
    batch over DP (accepted reduction-order drift) and still trains."""
    _run(_PRELUDE + """
    rl = RLHFConfig(prompt_len=P, gen_len=G, kl_coef=0.0, top_k=0,
                    batch_shard="strict")
    sc = ShardedContext.create(8, zero_stage=3)
    tr = RLHFTrainer(cfg, cfg, rl, jax.random.PRNGKey(0),
                     reward_fn=make_target_token_reward(7), shard=sc)
    try:
        tr.train_step(prompts, key)     # B=4 does not divide ndp=8
        raise SystemExit("strict mode must raise on a non-divisible batch")
    except ValueError as e:
        assert "strict" in str(e), e

    # divisible batch in throughput mode: experience shards over DP
    prompts8 = jax.random.randint(key, (8, P), 0, cfg.vocab_size)
    rl2 = RLHFConfig(prompt_len=P, gen_len=G, kl_coef=0.0, top_k=0,
                     batch_shard="throughput")
    tr2 = RLHFTrainer(cfg, cfg, rl2, jax.random.PRNGKey(0),
                      reward_fn=make_target_token_reward(7), shard=sc)
    exp = tr2.make_experience(prompts8, key)
    shards = exp["advantages"].addressable_shards
    assert len(shards) == 8 and shards[0].data.shape[0] == 1, \\
        [s.data.shape for s in shards]
    m = tr2.train_step(prompts8, jax.random.fold_in(key, 1))
    assert all(bool(jnp.isfinite(v)) for v in m.values()), m
    print("OK", m["loss"])
    """)


# ---------------------------------------------------------------------------
# Spec-level checks: no devices needed
# ---------------------------------------------------------------------------
def test_layer_plan_spec_structure():
    """TreePlan layer specs: stacked segment leaves keep their sharded
    state specs in the full-tree gather target, sliced per-layer specs
    drop the scan dim and every DP axis, and non-stacked leaves (embed,
    lm head, norms) are DP-stripped (gather whole). On the devices-free
    SpecMesh the sliced specs stay bare PartitionSpecs (a real mesh wraps
    them as NamedShardings — exercised by the runtime smokes above)."""
    import jax
    from jax.sharding import PartitionSpec as P

    from repro.configs import get_config
    from repro.models import Model
    from repro.sharding import ShardingStrategy, SpecMesh, param_pspecs
    from repro.sharding.context import _layer_specs

    cfg = get_config("llama3_2_3b")
    model = Model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    mesh = SpecMesh({"data": 8})
    strat = ShardingStrategy(zero_stage=3, tensor_parallel=False,
                             gather_mode="layer")
    pspecs = param_pspecs(cfg, mesh, strat, shapes)
    full, slices = _layer_specs(pspecs, mesh)
    assert full is not None and len(slices) == len(model.segments)

    is_p = lambda x: isinstance(x, P)

    def uses_data(spec):
        for e in tuple(spec):
            axes = e if isinstance(e, tuple) else (e,)
            if "data" in axes:
                return True
        return False

    # stacked leaves keep the (DP-sharded) state specs
    for k in full:
        if k.startswith("segment"):
            assert full[k] is pspecs[k]
    # non-stacked leaves lose every DP axis
    for k in ("embed", "final_norm"):
        for spec in jax.tree.leaves(full[k], is_leaf=is_p):
            assert not uses_data(spec), (k, spec)
    # sliced specs: one fewer dim than the stacked spec, no DP entries
    flat_stacked = jax.tree.leaves(pspecs["segment0"], is_leaf=is_p)
    flat_slice = jax.tree.leaves(slices[0], is_leaf=is_p)
    assert len(flat_stacked) == len(flat_slice)
    n_dp_sharded = 0
    for st, sl in zip(flat_stacked, flat_slice):
        assert len(tuple(sl)) == len(tuple(st)) - 1, (st, sl)
        assert not uses_data(sl), sl
        if uses_data(st):
            n_dp_sharded += 1
    assert n_dp_sharded > 0, "ZeRO-3 must shard some stacked leaves"


def test_tree_mode_plan_has_no_layer_specs():
    import jax

    from repro.configs import get_config
    from repro.models import Model
    from repro.sharding import ShardedContext, ShardingStrategy, SpecMesh

    cfg = get_config("llama3_2_3b").smoke()
    shapes = jax.eval_shape(Model(cfg).init, jax.random.PRNGKey(0))
    for stage, mode, expect in ((3, "tree", False), (1, "layer", False),
                                (3, "layer", True)):
        sc = ShardedContext(SpecMesh({"data": 8}),
                            ShardingStrategy(zero_stage=stage,
                                             tensor_parallel=False,
                                             gather_mode=mode))
        plan = sc.plan_params(cfg, shapes)
        assert (plan.layer_specs is not None) == expect, (stage, mode)
        assert plan.gather_mode == ("layer" if expect else "tree")


def test_encdec_falls_back_to_tree_gather():
    """Encoder-decoder configs must NOT get per-layer gathers: the model
    reads stacked decoder cross-attn weights outside the scan body
    (``Model._cross_kvs``), which under layer specs would all-gather
    in-graph — the bit-identity hazard DESIGN.md §3 rule 2 forbids."""
    import jax

    from repro.configs import get_config
    from repro.models import Model
    from repro.sharding import ShardedContext, ShardingStrategy, SpecMesh

    cfg = get_config("seamless_m4t_large_v2").smoke()
    assert cfg.input_mode == "encdec"
    shapes = jax.eval_shape(Model(cfg).init, jax.random.PRNGKey(0))
    sc = ShardedContext(SpecMesh({"data": 8}),
                        ShardingStrategy(zero_stage=3,
                                         tensor_parallel=False,
                                         gather_mode="layer"))
    plan = sc.plan_params(cfg, shapes)
    assert plan.layer_specs is None and plan.gather_mode == "tree"


def test_traced_layer_slice_distinguishes_modes():
    """traced_zero_scales: the layer_slice transient term is 1x under
    per-layer gathers and the scan length under whole-tree gathers."""
    import dataclasses as dc

    from repro.configs import get_config
    from repro.core import MemoryStrategy, traced_strategy

    cfg = dc.replace(get_config("llama3_2_3b").smoke(), num_layers=8)
    ndp = 8
    sL = traced_strategy(
        MemoryStrategy("Z3", zero_stage=3, gather_mode="layer"),
        cfg, cfg, ndp=ndp)
    sT = traced_strategy(
        MemoryStrategy("Z3", zero_stage=3, gather_mode="tree"),
        cfg, cfg, ndp=ndp)
    assert sL.scale("layer_slice", ndp=ndp) == 1.0
    assert sT.scale("layer_slice", ndp=ndp) == 8.0
    # below ZeRO-3 the slices are views into persistent storage: no cost
    s1 = traced_strategy(
        MemoryStrategy("Z1", zero_stage=1, gather_mode="tree"),
        cfg, cfg, ndp=ndp)
    assert s1.scale("layer_slice", ndp=ndp) == 0.0
