"""TP x ZeRO sharded-RLHF smoke: the acceptance run for tensor parallelism
as a real runtime axis, on forced multi-device CPU.

Run with 8 forced host devices (the CI multidevice topology):

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m benchmarks.tp_smoke

Checks (each asserted, and emitted as one ``TP_METRICS`` JSON line for
``benchmarks/run.py --only tp`` to parse and gate):

  1. 2-step PPO losses are ALLCLOSE between ``ndp=1, ntp=1`` and
     ``ndp=2, ntp=2`` ZeRO-3 on BOTH engines. Allclose, not bit-identical:
     TP splits every matmul's contraction, so partial sums reduce in a
     different order than the single-device program — reduction-order
     drift is ~1 ulp of the accumulation dtype per layer (measured ~1e-7
     relative in f32 here; the pure-DP ZeRO contract in
     benchmarks/zero_smoke.py stays BIT-identical because DP never splits
     a contraction — see DESIGN.md §9 for the policy). The smoke runs f32
     params with greedy rollout so trajectories cannot fork on that drift
     and the comparison is pure numerics, not diverged experience;
  2. greedy rollout tokens from the TP-sharded, DP-gathered (hydra:
     merged) weights are identical to the ndp=1 reference — dense AND
     paged decode, the paged KV pool itself sharded over the kv-head axis;
  3. per-device persistent param+opt bytes at ``ntp=2, zero_stage=0``
     (pure TP — ZeRO off, DP replicated) drop >=40% vs the ndp=1 figure
     for the separate engine, and further at ``zero_stage=3`` (the axes
     compose: params cut by ~ndp*ntp);
  4. the allocator simulator's per-phase curve — the strategy's dp AND tp
     axes traced from the real sharded spec trees
     (``core.strategies.traced_zero_scales(ntp=...)``) — brackets the
     measured per-device live-bytes curve of a bf16 separate-engine
     ``ndp=2, ntp=2`` run (bf16 to match the dtype build_rlhf_phases
     forces, like against like).
"""
from __future__ import annotations

import dataclasses
import gc
import json
import time

MiB = 1 << 20
RTOL, ATOL = 1e-4, 1e-6   # ~1000x the measured f32 reduction-order drift


def main() -> dict:
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.core import (MemoryStrategy, build_rlhf_phases, run_iteration,
                            traced_strategy)
    from repro.rlhf import RLHFConfig, RLHFTrainer, Rollout
    from repro.rlhf.reward import make_target_token_reward
    from repro.rlhf.trainer import per_device_live_bytes
    from repro.sharding import ShardedContext, delete_tree

    assert jax.device_count() >= 8, \
        f"needs 8 forced host devices, got {jax.device_count()} — run under " \
        "XLA_FLAGS=--xla_force_host_platform_device_count=8"
    NDP, NTP = 2, 2
    # f32 params + greedy rollout: drift stays ~1e-7 relative and the
    # trajectories are fork-proof (see module docstring, check 1). All TP
    # divisibility holds at ntp=2: heads=4, kv=2, d_ff=256, vocab=64.
    cfg = dataclasses.replace(
        get_config("llama3_2_3b").smoke(), num_layers=2, d_model=128,
        d_ff=256, vocab_size=64, num_heads=4, num_kv_heads=2, head_dim=32,
        param_dtype="float32")
    P, G, B = 8, 16, 4
    key = jax.random.PRNGKey(1)
    prompts = jax.random.randint(key, (B, P), 0, cfg.vocab_size)
    metrics: dict = {"ndp": NDP, "ntp": NTP}

    def build(engine, shard, model_cfg=cfg):
        rl = RLHFConfig(prompt_len=P, gen_len=G, lr=1e-3, critic_lr=1e-3,
                        kl_coef=0.0, top_k=0, temperature=0.0,
                        engine=engine, lora_rank=16)
        tr = RLHFTrainer(model_cfg, model_cfg, rl, jax.random.PRNGKey(0),
                         reward_fn=make_target_token_reward(7), shard=shard)
        ms = [tr.train_step(prompts, jax.random.fold_in(key, s))
              for s in range(2)]
        return tr, ms

    # ---- simulator bracket: traced (ndp=2, ntp=2) curve vs measured ------
    # Runs FIRST, while the process baseline is clean — the later engine
    # lanes leave compile caches and result buffers that would pollute the
    # base-live subtraction. bf16, matching the dtype build_rlhf_phases
    # forces (like against like).
    cfg_b = dataclasses.replace(cfg, param_dtype="bfloat16")
    gc.collect()
    base_live = per_device_live_bytes()
    trb, _ = build("separate",
                   ShardedContext.create(NDP, zero_stage=3, model=NTP),
                   model_cfg=cfg_b)
    recs = [dict(r, live_pd=r["live_bytes_per_device"] - base_live)
            for r in trb.memory.records[-7:]]
    del trb

    ph, persist = build_rlhf_phases(
        cfg_b, cfg_b, batch=B, prompt_len=P, gen_len=G,
        grad_ckpt=(cfg_b.remat == "full"), min_bytes=2048)
    strat = traced_strategy(
        MemoryStrategy("ZeRO-3", zero_stage=3, ntp=NTP), cfg_b, cfg_b,
        ndp=NDP)
    sr = run_iteration(ph, persist, strat, "none", ndp=NDP, ntp=NTP,
                       trainable_fraction=1.0, capacity=None)
    sim = {rec.name: rec for rec in sr.phase_records}
    name_map = {"rollout": "rollout_decode"}
    # python-side extras the sim doesn't model (rng keys, sampling
    # workspace, jit-cached constants) — 1.5 MiB at this smoke scale: the
    # TP program keeps a little more alive than pure DP (the DP-gathered
    # rollout copy's staging plus per-shard logits workspace)
    slack = 3 << 19
    print(f"per-phase bracket (separate engine, dp{NDP} x tp{NTP}, "
          "per-device bytes):")
    bracket_ok = True
    for r in recs:
        srec = sim[name_map.get(r["phase"], r["phase"])]
        lo, hi = srec.allocated_end, srec.alloc_peak
        ok = lo * 0.8 - slack <= r["live_pd"] <= hi * 1.2 + slack
        bracket_ok &= ok
        print(f"  {r['phase']:16s} sim [{lo/MiB:8.2f}, {hi/MiB:8.2f}] "
              f"MiB  measured {r['live_pd']/MiB:8.2f} MiB  "
              f"{'ok' if ok else 'OUT'}")
        assert ok, (r["phase"], lo, r["live_pd"], hi)
    metrics["sim_bracket_ok"] = bracket_ok
    print()

    for engine in ("separate", "hydra"):
        gc.collect()
        tr1, m1 = build(engine, None)
        b1 = tr1.per_device_state_bytes()
        p1 = tr1.actor_state["params"] if engine == "separate" else \
            tr1.actor.merge_adapter(tr1.base_params,
                                    tr1.actor_state["params"])
        tok1 = Rollout(tr1.actor, cfg, capacity=P + G, temperature=0.0,
                       top_k=0).generate(p1, {"tokens": prompts},
                                         G, key).tokens

        sc = ShardedContext.create(NDP, zero_stage=3, model=NTP)
        tr2, m2 = build(engine, sc)
        drift = 0.0
        for a, b in zip(m1, m2):
            for k in ("loss", "ppo_loss", "vf_loss"):
                if k not in a:
                    continue
                d = abs(a[k] - b[k])
                assert d <= ATOL + RTOL * abs(a[k]), \
                    f"{engine}/{k}: ndp=1 {a[k]} vs dp{NDP}xtp{NTP} " \
                    f"{b[k]} beyond reduction-order tolerance"
                if abs(a[k]) >= 1e-3:     # rel drift on O(1) losses only
                    drift = max(drift, d / abs(a[k]))
        metrics[f"{engine}_tp_allclose"] = True
        metrics[f"{engine}_max_rel_drift"] = float(f"{drift:.3e}")

        # rollout identity from an OWNED DP-gather of the TP-sharded state
        # (hydra: merged shard-locally — the merge-exactness contract of
        # rules.adapter_pspecs) — dense AND paged, pools kv-head-sharded
        owned = []
        if engine == "separate":
            p2, ow = tr2.actor_plan.gather_copy(tr2.actor_state["params"])
            assert ow, "ZeRO-3 gather_copy must return an owned copy"
            owned.append(p2)
        else:
            base2, ob = tr2.engine.base_plan.gather_copy(tr2.base_params)
            ad2, oa = tr2.engine.adapter_plans["actor"].gather_copy(
                tr2.actor_state["params"])
            assert ob and oa
            p2 = tr2.actor.merge_adapter(base2, ad2)
            owned += [base2, ad2, p2]
        for backend in ("dense", "paged"):
            ro2 = Rollout(tr2.actor, cfg, capacity=P + G, temperature=0.0,
                          top_k=0, backend=backend, mesh=sc.mesh).generate(
                p2, {"tokens": prompts}, G, key)
            assert bool(jnp.array_equal(tok1, ro2.tokens)), \
                f"{engine}/{backend}: TP-sharded greedy rollout diverged"
        for t in owned:
            delete_tree(t)
        metrics[f"{engine}_rollout_identical"] = True

        b3 = tr2.per_device_state_bytes()
        metrics[f"{engine}_state_bytes_ndp1"] = int(b1)
        metrics[f"{engine}_state_bytes_tp_zero3"] = int(b3)
        metrics[f"{engine}_tp_zero3_cut_pct"] = round(100 * (1 - b3 / b1), 1)
        line = f"[{engine:9s}] allclose=True (drift {drift:.1e})  " \
               f"per-device state {b1/MiB:7.2f} -> {b3/MiB:7.2f} MiB " \
               f"(-{100*(1-b3/b1):.0f}%, zs3 x tp{NTP})"
        del tr2, m2, p2
        if engine == "separate":
            # pure-TP cut: ZeRO off, DP replicated — the >=40% acceptance
            # bar isolates what the new axis alone buys per device
            sc0 = ShardedContext.create(NDP, zero_stage=0, model=NTP)
            tr0, _ = build(engine, sc0)
            b0 = tr0.per_device_state_bytes()
            cut0 = 100 * (1 - b0 / b1)
            metrics["separate_state_bytes_tp_zero0"] = int(b0)
            metrics["separate_tp_cut_pct"] = round(cut0, 1)
            assert cut0 >= 40.0, \
                f"pure-TP per-device param+opt cut {cut0:.1f}% < 40%"
            line += f"; zs0 x tp{NTP} -{cut0:.0f}%"
            del tr0
        print(line)
        del tr1, m1, p1

    print("TP_METRICS " + json.dumps(metrics))
    return metrics


if __name__ == "__main__":
    main()
