"""Sharded-RLHF smoke: the acceptance run for the mesh-sharded ZeRO
engines, on forced multi-device CPU.

Run with 8 forced host devices (the CI multidevice topology):

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m benchmarks.zero_smoke

Checks (each asserted, and emitted as one ``ZERO_METRICS`` JSON line for
``benchmarks/run.py --only zero`` to parse and gate):

  1. 2-step PPO losses are BIT-IDENTICAL between ``ndp=1`` and ``ndp=8``
     ZeRO-3 on BOTH engines and BOTH gather modes (whole-``tree`` and
     per-``layer`` FSDP gathers — the gather-compute /
     uniform-layout-update contract of ``steps.make_train_step(shard=...)``
     plus the in-scan constraint of DESIGN.md §3.7);
  2. greedy rollout tokens are identical too — including the paged decode
     path running under the same mesh;
  3. per-device live param+opt bytes at ``zero_stage=3`` are <= 30% of the
     ``zero_stage=0`` replicated figure for the separate engine (the
     replicated figure per device equals the ndp=1 total by definition);
  4. the allocator simulator's per-phase ``ndp=8`` curve — run with the
     strategy's ndp axis TRACED from the real sharded spec trees
     (``core.strategies.traced_strategy``) — brackets the measured
     per-device live-bytes curve of the separate-engine run;
  5. the per-device TRANSIENT peak of the compiled grad program (XLA
     ``memory_analysis().temp_size_in_bytes``): switching the ZeRO-3
     gather from ``"tree"`` to ``"layer"`` must free at least the whole
     stacked parameter tree minus ~2 layer periods — i.e. the gathered
     weights resident at any instant drop from every layer to one — and
     the traced simulator transient delta (``layer_slice`` charged at the
     scan length vs at 1x) brackets the measured delta.
"""
from __future__ import annotations

import dataclasses
import gc
import json
import time

GB = 1 << 30
MiB = 1 << 20


def main() -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config
    from repro.core import (MemoryStrategy, build_rlhf_phases, run_iteration,
                            traced_strategy)
    from repro.models import Model
    from repro.optim import make_optimizer
    from repro.rlhf import RLHFConfig, RLHFTrainer, Rollout
    from repro.rlhf.reward import make_target_token_reward
    from repro.rlhf.trainer import per_device_live_bytes
    from repro.sharding import ShardedContext
    from repro.steps import init_train_state, make_train_step

    assert jax.device_count() >= 8, \
        f"needs 8 forced host devices, got {jax.device_count()} — run under " \
        "XLA_FLAGS=--xla_force_host_platform_device_count=8"
    NDP = 8
    # bf16 params to match the dtype build_rlhf_phases forces, so the
    # simulator bracket compares like against like
    cfg = dataclasses.replace(
        get_config("llama3_2_3b").smoke(), num_layers=2, d_model=128,
        d_ff=256, vocab_size=64, num_heads=4, num_kv_heads=2, head_dim=32,
        param_dtype="bfloat16")
    P, G, B = 8, 16, 4     # B not divisible by ndp: the batch replicates,
    # so ZeRO shards *state* only and bit-identity is exact by construction
    key = jax.random.PRNGKey(1)
    prompts = jax.random.randint(key, (B, P), 0, cfg.vocab_size)
    metrics: dict = {"ndp": NDP}

    def build(engine, shard, base_live, telemetry=None):
        rl = RLHFConfig(prompt_len=P, gen_len=G, lr=1e-3, critic_lr=1e-3,
                        kl_coef=0.0, top_k=0, engine=engine, lora_rank=16)
        tr = RLHFTrainer(cfg, cfg, rl, jax.random.PRNGKey(0),
                         reward_fn=make_target_token_reward(7), shard=shard,
                         telemetry=telemetry)
        ms = [tr.train_step(prompts, jax.random.fold_in(key, s))
              for s in range(2)]
        recs = [dict(r, live_pd=r["live_bytes_per_device"] - base_live)
                for r in tr.memory.records[-7:]]
        return tr, ms, recs

    sep_records = None
    for engine in ("separate", "hydra"):
        gc.collect()
        base_live = per_device_live_bytes()
        if engine == "separate":
            # acceptance: enabled telemetry taxes <=2% of wall time on this
            # bench (tracer self-accounting; sim_delta off so the one-time
            # simulator setup isn't conflated with steady-state overhead)
            from repro.obs import RunTelemetry
            tel = RunTelemetry.create(sim_delta=False)
            t0 = time.time()
            tr1, m1, _ = build(engine, None, base_live, telemetry=tel)
            ov_pct = 100 * tel.tracer.overhead_fraction(time.time() - t0)
            metrics["telemetry_overhead_pct"] = round(ov_pct, 4)
            print(f"[telemetry] self-time {tel.tracer.self_time_s*1e3:.2f} "
                  f"ms = {ov_pct:.3f}% of the instrumented run (<=2%)")
            assert ov_pct <= 2.0, f"telemetry overhead {ov_pct:.2f}% > 2%"
        else:
            tr1, m1, _ = build(engine, None, base_live)

        # greedy reference tokens from the ndp=1 (unsharded) state
        p1 = tr1.actor_state["params"] if engine == "separate" else \
            tr1.actor.merge_adapter(tr1.base_params,
                                    tr1.actor_state["params"])
        tok1 = Rollout(tr1.actor, cfg, capacity=P + G, temperature=0.0,
                       top_k=0).generate(p1, {"tokens": prompts},
                                         G, key).tokens

        # both ZeRO-3 gather granularities must reproduce ndp=1 exactly
        # (the tree-mode trainer is dropped right after its check so two
        # full ZeRO-3 trainers are never resident at once)
        tr8 = m8 = recs8 = None
        for mode in ("tree", "layer"):
            del tr8, m8, recs8
            sc = ShardedContext.create(NDP, zero_stage=3, gather_mode=mode)
            gc.collect()
            base_live8 = per_device_live_bytes()
            tr8, m8, recs8 = build(engine, sc, base_live8)
            biteq = True
            for a, b in zip(m1, m8):
                for k in ("loss", "ppo_loss", "vf_loss"):
                    if k in a and a[k] != b.get(k):
                        biteq = False
            assert biteq, f"{engine}/{mode}: ndp=1 vs ndp={NDP} losses " \
                "not bit-identical"
            metrics[f"{engine}_biteq_{mode}"] = biteq
        metrics[f"{engine}_biteq"] = True

        # rollout identity under the mesh: dense AND paged decode, from an
        # OWNED gather copy (deleted below — the ownership-flag contract)
        owned_trees = []
        if engine == "separate":
            p8, owned = tr8.actor_plan.gather_copy(tr8.actor_state["params"])
            assert owned, "ZeRO-3 gather_copy must return an owned copy"
            owned_trees.append(p8)
        else:
            base8, ob = tr8.engine.base_plan.gather_copy(tr8.base_params)
            ad8, oa = tr8.engine.adapter_plans["actor"].gather_copy(
                tr8.actor_state["params"])
            assert ob and oa
            p8 = tr8.actor.merge_adapter(base8, ad8)
            owned_trees += [base8, ad8, p8]
        for backend in ("dense", "paged"):
            ro8 = Rollout(tr8.actor, cfg, capacity=P + G, temperature=0.0,
                          top_k=0, backend=backend).generate(
                p8, {"tokens": prompts}, G, key)
            assert bool(jnp.array_equal(tok1, ro8.tokens)), \
                f"{engine}/{backend}: sharded greedy rollout diverged"
        metrics[f"{engine}_rollout_identical"] = True

        b1 = tr1.per_device_state_bytes()
        b8 = tr8.per_device_state_bytes()
        from repro.sharding import delete_tree
        for t in owned_trees:      # owned copies die at the phase boundary
            delete_tree(t)
        metrics[f"{engine}_state_bytes_ndp1"] = int(b1)
        metrics[f"{engine}_state_bytes_zero3"] = int(b8)
        metrics[f"{engine}_zero3_cut_pct"] = round(100 * (1 - b8 / b1), 1)
        print(f"[{engine:9s}] biteq=True (tree+layer)  per-device state "
              f"{b1/2**20:7.2f} -> {b8/2**20:7.2f} MiB "
              f"(-{100*(1-b8/b1):.0f}%)")
        if engine == "separate":
            # zero_stage=0 keeps every tree replicated: its per-device
            # figure equals the ndp=1 total by definition
            assert b8 <= 0.30 * b1, \
                f"ZeRO-3 per-device state must be <=30% of replicated, " \
                f"got {100*b8/b1:.0f}%"
            sep_records = recs8
        del tr1, tr8, m1, m8, p1, p8, recs8

    # ---- simulator bracket: traced ndp=8 curve vs the measured one -------
    ph, persist = build_rlhf_phases(
        cfg, cfg, batch=B, prompt_len=P, gen_len=G,
        grad_ckpt=(cfg.remat == "full"), min_bytes=2048)
    strat = traced_strategy(MemoryStrategy("ZeRO-3", zero_stage=3),
                            cfg, cfg, ndp=NDP)
    sr = run_iteration(ph, persist, strat, "none", ndp=NDP,
                       trainable_fraction=1.0, capacity=None)
    sim = {rec.name: rec for rec in sr.phase_records}
    name_map = {"rollout": "rollout_decode"}
    # python-side extras the sim doesn't model (rng keys, experience
    # scalars, jit-cached constants) — ~1 MiB at this smoke scale
    slack = 1 << 20
    print("\nper-phase bracket (separate engine, per-device bytes):")
    bracket_ok = True
    for r in sep_records:
        srec = sim[name_map.get(r["phase"], r["phase"])]
        lo, hi = srec.allocated_end, srec.alloc_peak
        ok = lo * 0.8 - slack <= r["live_pd"] <= hi * 1.2 + slack
        bracket_ok &= ok
        print(f"  {r['phase']:16s} sim [{lo/2**20:8.2f}, {hi/2**20:8.2f}] "
              f"MiB  measured {r['live_pd']/2**20:8.2f} MiB  "
              f"{'ok' if ok else 'OUT'}")
        assert ok, (r["phase"], lo, r["live_pd"], hi)
    metrics["sim_bracket_ok"] = bracket_ok

    # ---- per-layer gather transient: compiled-program temp peak ----------
    # A deeper, remat-enabled config so the whole-tree gather dwarfs one
    # layer period (layer mode needs remat to drop the gathered slice —
    # without it the saved residuals hold the gathered weights anyway).
    cfg_t = dataclasses.replace(cfg, num_layers=8, d_model=256, d_ff=512,
                                num_heads=8, num_kv_heads=4, head_dim=32,
                                remat="full")
    model_t = Model(cfg_t)
    shapes = jax.eval_shape(model_t.init, jax.random.PRNGKey(0))
    stacked_bytes = int(sum(
        int(np.prod(l.shape)) * jnp.dtype(l.dtype).itemsize
        for k in shapes if k.startswith("segment")
        for l in jax.tree.leaves(shapes[k])))
    n_slices = sum(seg.n_groups for seg in model_t.segments)
    slice_bytes = stacked_bytes // n_slices
    S = P + G
    tb = {"tokens": jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                                       cfg_t.vocab_size)}
    for k in ("loss_mask", "advantages", "old_logp", "ref_logp", "returns"):
        tb[k] = jnp.zeros((B, S), jnp.float32)

    def grads_temp(zero_stage, mode):
        sc = ShardedContext.create(NDP, zero_stage=zero_stage,
                                   gather_mode=mode)
        plan = sc.plan_params(cfg_t, shapes,
                              make_optimizer(cfg_t.optimizer))
        step = make_train_step(model_t, cfg_t, kind="ppo", shard=plan)
        state = plan.place_state(init_train_state(
            model_t, cfg_t, jax.random.PRNGKey(0), step.optimizer))
        c = step.jit_grads.lower(state, tb).compile()
        return int(c.memory_analysis().temp_size_in_bytes)

    t_tree = grads_temp(3, "tree")
    t_layer = grads_temp(3, "layer")
    delta = t_tree - t_layer
    print("\ntransient peak of the compiled grad program (per-device "
          "temp bytes):")
    print(f"  stacked tree {stacked_bytes/MiB:7.2f} MiB  one layer period "
          f"{slice_bytes/MiB:7.2f} MiB  ({n_slices} scan slices)")
    print(f"  tree  mode   {t_tree/MiB:7.2f} MiB")
    print(f"  layer mode   {t_layer/MiB:7.2f} MiB "
          f"(-{100*(1 - t_layer/max(t_tree, 1)):.0f}%, "
          f"freed {delta/MiB:.2f} MiB)")
    eps = 256 * 1024
    # per-layer gathers must free at least the whole stacked tree minus
    # ~2 layer periods: the gathered weights concurrently live drop from
    # every layer to one (+ scheduling headroom)
    layer_ok = delta >= stacked_bytes - 2 * slice_bytes - eps
    assert layer_ok, (delta, stacked_bytes, slice_bytes)
    # the traced simulator transient term brackets the measured delta:
    # "tree" charges each layer_slice event at the scan length, "layer"
    # at 1x (traced_zero_scales gather_mode axis). The measured delta may
    # exceed the sim term by up to ~2x — layer mode also shards the
    # remat-saved weight slices the tree program keeps replicated.
    scale_of = lambda mode: traced_strategy(
        MemoryStrategy("ZeRO-3", zero_stage=3, gather_mode=mode),
        cfg_t, cfg_t, ndp=NDP).scale("layer_slice", ndp=NDP)
    sim_delta = (scale_of("tree") - scale_of("layer")) * slice_bytes
    sim_ok = 0.5 * sim_delta - eps <= delta <= 2.5 * sim_delta + eps
    print(f"  sim transient delta {sim_delta/MiB:7.2f} MiB  measured "
          f"{delta/MiB:7.2f} MiB  {'ok' if sim_ok else 'OUT'}")
    assert sim_ok, (sim_delta, delta)
    metrics.update(
        layer_slice_bytes=slice_bytes, stacked_param_bytes=stacked_bytes,
        grads_temp_tree=t_tree, grads_temp_layer=t_layer,
        gather_transient_cut_pct=round(
            100 * (1 - t_layer / max(t_tree, 1)), 1),
        layer_transient_ok=bool(layer_ok),
        transient_sim_bracket_ok=bool(sim_ok))
    print("ZERO_METRICS " + json.dumps(metrics))
    return metrics


if __name__ == "__main__":
    main()
