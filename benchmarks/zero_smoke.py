"""Sharded-RLHF smoke: the acceptance run for the mesh-sharded ZeRO
engines, on forced multi-device CPU.

Run with 8 forced host devices (the CI multidevice topology):

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m benchmarks.zero_smoke

Checks (each asserted, and emitted as one ``ZERO_METRICS`` JSON line for
``benchmarks/run.py --only zero`` to parse and gate):

  1. 2-step PPO losses are BIT-IDENTICAL between ``ndp=1`` and ``ndp=8``
     ZeRO-3 on BOTH engines (the gather-compute / uniform-layout-update
     contract of ``steps.make_train_step(shard=...)``);
  2. greedy rollout tokens are identical too — including the paged decode
     path running under the same mesh;
  3. per-device live param+opt bytes at ``zero_stage=3`` are <= 30% of the
     ``zero_stage=0`` replicated figure for the separate engine (the
     replicated figure per device equals the ndp=1 total by definition);
  4. the allocator simulator's per-phase ``ndp=8`` curve — run with the
     strategy's ndp axis TRACED from the real sharded spec trees
     (``core.strategies.traced_strategy``) — brackets the measured
     per-device live-bytes curve of the separate-engine run.
"""
from __future__ import annotations

import dataclasses
import gc
import json

GB = 1 << 30


def main() -> dict:
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.core import (MemoryStrategy, build_rlhf_phases, run_iteration,
                            traced_strategy)
    from repro.rlhf import RLHFConfig, RLHFTrainer, Rollout
    from repro.rlhf.reward import make_target_token_reward
    from repro.rlhf.trainer import per_device_live_bytes
    from repro.sharding import ShardedContext

    assert jax.device_count() >= 8, \
        f"needs 8 forced host devices, got {jax.device_count()} — run under " \
        "XLA_FLAGS=--xla_force_host_platform_device_count=8"
    NDP = 8
    # bf16 params to match the dtype build_rlhf_phases forces, so the
    # simulator bracket compares like against like
    cfg = dataclasses.replace(
        get_config("llama3_2_3b").smoke(), num_layers=2, d_model=128,
        d_ff=256, vocab_size=64, num_heads=4, num_kv_heads=2, head_dim=32,
        param_dtype="bfloat16")
    P, G, B = 8, 16, 4     # B not divisible by ndp: the batch replicates,
    # so ZeRO shards *state* only and bit-identity is exact by construction
    key = jax.random.PRNGKey(1)
    prompts = jax.random.randint(key, (B, P), 0, cfg.vocab_size)
    metrics: dict = {"ndp": NDP}

    def build(engine, shard, base_live):
        rl = RLHFConfig(prompt_len=P, gen_len=G, lr=1e-3, critic_lr=1e-3,
                        kl_coef=0.0, top_k=0, engine=engine, lora_rank=16)
        tr = RLHFTrainer(cfg, cfg, rl, jax.random.PRNGKey(0),
                         reward_fn=make_target_token_reward(7), shard=shard)
        ms = [tr.train_step(prompts, jax.random.fold_in(key, s))
              for s in range(2)]
        recs = [dict(r, live_pd=r["live_bytes_per_device"] - base_live)
                for r in tr.memory.records[-7:]]
        return tr, ms, recs

    sep_records = None
    for engine in ("separate", "hydra"):
        gc.collect()
        base_live = per_device_live_bytes()
        tr1, m1, _ = build(engine, None, base_live)

        # greedy reference tokens from the ndp=1 (unsharded) state
        p1 = tr1.actor_state["params"] if engine == "separate" else \
            tr1.actor.merge_adapter(tr1.base_params,
                                    tr1.actor_state["params"])
        tok1 = Rollout(tr1.actor, cfg, capacity=P + G, temperature=0.0,
                       top_k=0).generate(p1, {"tokens": prompts},
                                         G, key).tokens

        sc = ShardedContext.create(NDP, zero_stage=3)
        gc.collect()
        base_live8 = per_device_live_bytes()
        tr8, m8, recs8 = build(engine, sc, base_live8)

        biteq = True
        for a, b in zip(m1, m8):
            for k in ("loss", "ppo_loss", "vf_loss"):
                if k in a and a[k] != b.get(k):
                    biteq = False
        assert biteq, f"{engine}: ndp=1 vs ndp={NDP} losses not bit-identical"
        metrics[f"{engine}_biteq"] = biteq

        # rollout identity under the mesh: dense AND paged decode
        if engine == "separate":
            p8 = tr8.actor_plan.gather_copy(tr8.actor_state["params"])
        else:
            base8 = tr8.engine.base_plan.gather_copy(tr8.base_params)
            ad8 = tr8.engine.adapter_plans["actor"].gather_copy(
                tr8.actor_state["params"])
            p8 = tr8.actor.merge_adapter(base8, ad8)
        for backend in ("dense", "paged"):
            ro8 = Rollout(tr8.actor, cfg, capacity=P + G, temperature=0.0,
                          top_k=0, backend=backend).generate(
                p8, {"tokens": prompts}, G, key)
            assert bool(jnp.array_equal(tok1, ro8.tokens)), \
                f"{engine}/{backend}: sharded greedy rollout diverged"
        metrics[f"{engine}_rollout_identical"] = True

        b1 = tr1.per_device_state_bytes()
        b8 = tr8.per_device_state_bytes()
        metrics[f"{engine}_state_bytes_ndp1"] = int(b1)
        metrics[f"{engine}_state_bytes_zero3"] = int(b8)
        metrics[f"{engine}_zero3_cut_pct"] = round(100 * (1 - b8 / b1), 1)
        print(f"[{engine:9s}] biteq=True  per-device state "
              f"{b1/2**20:7.2f} -> {b8/2**20:7.2f} MiB "
              f"(-{100*(1-b8/b1):.0f}%)")
        if engine == "separate":
            # zero_stage=0 keeps every tree replicated: its per-device
            # figure equals the ndp=1 total by definition
            assert b8 <= 0.30 * b1, \
                f"ZeRO-3 per-device state must be <=30% of replicated, " \
                f"got {100*b8/b1:.0f}%"
            sep_records = recs8
        del tr1, tr8, m1, m8, p1, p8

    # ---- simulator bracket: traced ndp=8 curve vs the measured one -------
    ph, persist = build_rlhf_phases(
        cfg, cfg, batch=B, prompt_len=P, gen_len=G,
        grad_ckpt=(cfg.remat == "full"), min_bytes=2048)
    strat = traced_strategy(MemoryStrategy("ZeRO-3", zero_stage=3),
                            cfg, cfg, ndp=NDP)
    sr = run_iteration(ph, persist, strat, "none", ndp=NDP,
                       trainable_fraction=1.0, capacity=None)
    sim = {rec.name: rec for rec in sr.phase_records}
    name_map = {"rollout": "rollout_decode"}
    # python-side extras the sim doesn't model (rng keys, experience
    # scalars, jit-cached constants) — ~1 MiB at this smoke scale
    slack = 1 << 20
    print("\nper-phase bracket (separate engine, per-device bytes):")
    bracket_ok = True
    for r in sep_records:
        srec = sim[name_map.get(r["phase"], r["phase"])]
        lo, hi = srec.allocated_end, srec.alloc_peak
        ok = lo * 0.8 - slack <= r["live_pd"] <= hi * 1.2 + slack
        bracket_ok &= ok
        print(f"  {r['phase']:16s} sim [{lo/2**20:8.2f}, {hi/2**20:8.2f}] "
              f"MiB  measured {r['live_pd']/2**20:8.2f} MiB  "
              f"{'ok' if ok else 'OUT'}")
        assert ok, (r["phase"], lo, r["live_pd"], hi)
    metrics["sim_bracket_ok"] = bracket_ok
    print("ZERO_METRICS " + json.dumps(metrics))
    return metrics


if __name__ == "__main__":
    main()
