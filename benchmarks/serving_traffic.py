"""Trace-driven synthetic multi-tenant serving traffic (maxtext
``offline_inference``-style): a deterministic arrival schedule of requests
from N tenants, every prompt sharing one system-prompt prefix and carrying
a short per-request user tail — the workload shape where cross-request
prefix caching pays (thousands of requests, one shared preamble).

The module is driver-only: it builds traces and pushes them through a
``ContinuousBatcher`` step by step, recording per-request admission
latency (in scheduler steps — deterministic) and wall-clock throughput.
``benchmarks.run --only serving`` runs the A/B (prefix cache on vs off)
and gates hit rate, reserved-KV reduction, tokens/s and p99 admission
latency; run this module directly for a quick eyeball summary.

    PYTHONPATH=src python -m benchmarks.serving_traffic
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Dict, List, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class TraceRequest:
    arrival_step: int            # batcher step at which the request arrives
    tenant: str
    prompt: np.ndarray           # [P] int32: system prefix + user tail
    max_new_tokens: int


@dataclasses.dataclass
class TraceResult:
    requests: List                      # batcher Request objects, trace order
    latency_steps: Dict[int, int]       # rid -> submit->first-token steps
    wall_s: float
    n_steps: int
    n_tokens: int

    @property
    def tokens_per_s(self) -> float:
        return self.n_tokens / max(self.wall_s, 1e-9)

    def p99_admission_latency_s(self) -> float:
        """p99 of the (deterministic) step-count latencies, scaled by the
        run's mean step time — stable under CI-runner load in a way raw
        per-request wall timestamps are not."""
        lat = sorted(self.latency_steps.values())
        if not lat:
            return 0.0
        p99_steps = lat[min(len(lat) - 1, int(0.99 * len(lat)))]
        return p99_steps * self.wall_s / max(self.n_steps, 1)


def synthetic_trace(vocab_size: int, *, n_tenants: int = 3,
                    per_tenant: int = 24, sys_len: int = 64,
                    user_len: Tuple[int, int] = (1, 3),
                    gen_len: Tuple[int, int] = (8, 16),
                    arrive_every: int = 2, seed: int = 0,
                    shared_system_prompt: bool = True
                    ) -> List[TraceRequest]:
    """Deterministic multi-tenant trace. One system prompt of ``sys_len``
    tokens shared by every request (per-tenant system prompts with
    ``shared_system_prompt=False``); each request appends a random user
    tail and asks for a ragged completion. Arrivals interleave tenants
    round-robin, one request every ``arrive_every`` steps — enough
    backlog to exercise queueing without drowning the pool."""
    rng = np.random.RandomState(seed)
    shared = rng.randint(0, vocab_size, size=sys_len)
    sys_prompts = {
        f"tenant{t}": shared if shared_system_prompt
        else rng.randint(0, vocab_size, size=sys_len)
        for t in range(n_tenants)}
    trace: List[TraceRequest] = []
    for i in range(n_tenants * per_tenant):
        tenant = f"tenant{i % n_tenants}"
        tail = rng.randint(0, vocab_size,
                           size=rng.randint(user_len[0], user_len[1] + 1))
        trace.append(TraceRequest(
            arrival_step=i * arrive_every // n_tenants,
            tenant=tenant,
            prompt=np.concatenate([sys_prompts[tenant], tail]).astype(
                np.int32),
            max_new_tokens=int(rng.randint(gen_len[0], gen_len[1] + 1))))
    return trace


def run_trace(cb, trace: Sequence[TraceRequest], *,
              max_steps: int = 20_000) -> TraceResult:
    """Drive the batcher through the trace: submit each request at its
    arrival step, record submit->first-token latency in steps, drain."""
    pending = deque(sorted(trace, key=lambda r: r.arrival_step))
    reqs, waiting, lat = [], {}, {}
    t0 = time.time()
    for _ in range(max_steps):
        while pending and pending[0].arrival_step <= cb.steps:
            tr = pending.popleft()
            req = cb.submit(tr.prompt, tr.max_new_tokens, tenant=tr.tenant)
            reqs.append(req)
            waiting[req.rid] = (req, cb.steps)
        cb.step()
        for rid in list(waiting):
            req, s0 = waiting[rid]
            if req.out_tokens:                 # first token => admitted
                lat[rid] = (cb.steps - 1) - s0
                del waiting[rid]
        if not pending and not cb.n_queued \
                and all(r is None for r in cb.active):
            break
    else:
        raise RuntimeError("trace did not drain")
    wall = time.time() - t0
    return TraceResult(requests=reqs, latency_steps=lat, wall_s=wall,
                       n_steps=cb.steps,
                       n_tokens=sum(len(r.out_tokens) for r in reqs))


def main() -> None:      # quick eyeball run, no gating
    import sys
    sys.path.insert(0, "src")
    import dataclasses as dc

    import jax

    from repro.configs import get_config
    from repro.models import Model
    from repro.serving import ContinuousBatcher

    cfg = dc.replace(
        get_config("llama3_2_3b").smoke(), num_layers=2, d_model=128,
        d_ff=256, vocab_size=64, num_heads=4, num_kv_heads=2, head_dim=32)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    trace = synthetic_trace(cfg.vocab_size)
    for prefix_cache in (False, True):
        cb = ContinuousBatcher(
            model, cfg, params, slots=4, capacity=96, temperature=0.0,
            seed=0, cache_backend="paged", page_size=16, num_pages=48,
            capture_buckets=(4, 16, 80), prefix_cache=prefix_cache,
            tenant_weights={"tenant0": 4.0, "tenant1": 2.0, "tenant2": 1.0})
        res = run_trace(cb, trace)
        peak = cb.pm.stats.peak_pages_in_use * cb.pm.page_bytes
        print(f"prefix_cache={prefix_cache}: {len(res.requests)} requests, "
              f"{res.n_tokens} tokens, {res.tokens_per_s:.0f} tok/s, "
              f"hit rate {cb.prefix_hit_rate():.3f}, "
              f"peak reserved {peak} B")


if __name__ == "__main__":
    main()
