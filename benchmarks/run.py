"""Benchmark harness — one benchmark per paper table/figure, plus kernel
microbenchmarks and the roofline summary. Prints ``name,us_per_call,derived``
CSV rows (and the detailed tables beneath).

  figure1    — per-phase memory timeline of one PPO iteration (all-enabled)
  table1     — strategies x {none, empty_cache} for OPT and GPT-2 (24 GB)
  table2     — A100-80GB grid: OPT-1.3b / OPT-6.7b / Llama-2-7b, +-ZeRO-3
  placement  — empty_cache placement ablation (paper §3.3)
  generation — naive (HF-style growing cache) vs framework static cache
  paged      — dense [B, capacity] vs paged KV cache on ragged requests
  decode     — fast decode path: compile-bucket ladder + MTP speculation
  obs        — runtime telemetry: phase spans, sim-vs-measured, overhead,
               per-owner HBM attribution + flight-recorder dump (PR 8)
  zero       — mesh-sharded ZeRO RLHF smoke on 8 forced host devices
  tp         — TP x ZeRO composition smoke: dp x tp allclose + byte cuts
  kernels    — wall-time microbenches of the XLA flash twin vs dense sdpa
  roofline   — summary of roofline_baseline.json if present

Run: PYTHONPATH=src python -m benchmarks.run [--only table1 ...]

Every run writes one ``BENCH_<name>.json`` per benchmark into ``--out-dir``
(default ``benchmarks/results/``; CI uploads them as artifacts). Metrics a
benchmark registers via ``_gate`` are regression-gated: with
``--check-baseline``, any gated metric that regresses >10% against the
committed ``benchmarks/baselines/BENCH_<name>.json`` fails the run —
the perf trajectory is recorded, not just asserted once. Each run also
appends the gated metrics as one git-sha-stamped line to
``benchmarks/history/HISTORY_<name>.jsonl`` (``--history-dir``) — the
cross-run trend ``launch/report.py --trend`` renders.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

GB = 1 << 30

# per-benchmark results registry: name -> {"metrics": {...}, "gated": {...}}
RESULTS: dict = {}
_CURRENT = [None]                   # benchmark currently executing
# with --emit-trace: name -> Chrome-trace dict, written as TRACE_<name>.json
TRACES: dict = {}
_EMIT_TRACE = [False]
# extra JSON artifacts a bench wants preserved next to its BENCH_ file
# (attribution tables, flight-recorder dumps): filename -> obj
ARTIFACTS: dict = {}


def _result(name=None):
    cur = name or _CURRENT[0] or "misc"
    return RESULTS.setdefault(cur, {"name": cur, "metrics": {}, "gated": {}})


def _trace(chrome: dict) -> None:
    """Attach a Chrome-trace dict to the current benchmark (overrides the
    harness's own wall-clock span trace for benches that record a richer
    one, e.g. bench_obs's full per-phase run trace)."""
    if _EMIT_TRACE[0] and _CURRENT[0]:
        TRACES[_CURRENT[0]] = chrome


def _artifact(filename: str, obj) -> None:
    """Register an extra JSON artifact (flight dump, attribution tables)
    for ``write_results`` to persist into ``--out-dir``."""
    ARTIFACTS[filename] = obj


def _csv(name, us, derived=""):
    print(f"CSV,{name},{us:.1f},{derived}")
    _result()["metrics"][name] = {"us_per_call": round(us, 1),
                                  "derived": derived}


def _gate(key, value, better="higher"):
    """Register a regression-gated metric for the current benchmark.
    ``better="higher"`` fails when the value drops >10% below baseline;
    ``"lower"`` fails when it rises >10% above."""
    assert better in ("higher", "lower"), better
    _result()["gated"][key] = {"value": float(value), "better": better}


def write_results(out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    for name, rec in RESULTS.items():
        path = os.path.join(out_dir, f"BENCH_{name}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1, sort_keys=True)
        print(f"[bench] wrote {path}")
    for name, chrome in TRACES.items():
        path = os.path.join(out_dir, f"TRACE_{name}.json")
        with open(path, "w") as f:
            json.dump(chrome, f)
        print(f"[bench] wrote {path}")
    for fname, obj in ARTIFACTS.items():
        path = os.path.join(out_dir, fname)
        with open(path, "w") as f:
            json.dump(obj, f, indent=1, default=str)
        print(f"[bench] wrote {path}")


def _git_sha() -> str:
    try:
        import subprocess
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=10)
        return out.stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def append_history(history_dir: str) -> None:
    """Append one timestamped, git-sha-stamped JSONL line per completed
    benchmark to ``HISTORY_<name>.jsonl`` — the cross-run trajectory that
    ``launch/report.py --trend`` renders. Append-only by design: the
    BENCH_ files are one run's snapshot; the history is the trend."""
    os.makedirs(history_dir, exist_ok=True)
    t = time.time()
    iso = time.strftime("%Y-%m-%dT%H:%M:%S", time.gmtime(t))
    sha = _git_sha()
    for name, rec in RESULTS.items():
        if not rec["gated"]:
            continue            # nothing trend-worthy was registered
        line = {"t": t, "iso": iso, "sha": sha, "bench": name,
                "gated": {k: v["value"] for k, v in rec["gated"].items()}}
        path = os.path.join(history_dir, f"HISTORY_{name}.jsonl")
        with open(path, "a") as f:
            f.write(json.dumps(line) + "\n")
        print(f"[bench] history += {path} ({sha})")


def check_baseline(baseline_dir: str, tol: float = 0.10) -> int:
    """Compare this run's gated metrics against the committed baselines.
    Returns the number of regressions (>tol relative, in the bad
    direction — improvements never fail)."""
    failures = 0
    for name, rec in RESULTS.items():
        path = os.path.join(baseline_dir, f"BENCH_{name}.json")
        if not os.path.exists(path):
            if rec["gated"]:
                print(f"[bench] {name}: no baseline committed "
                      f"({path}) — skipped")
            continue
        base = json.load(open(path)).get("gated", {})
        for key, cur in rec["gated"].items():
            if key not in base:
                print(f"[bench] {name}.{key}: not in baseline — skipped")
                continue
            bv, cv = base[key]["value"], cur["value"]
            if cur["better"] == "higher":
                ok = cv >= bv - abs(bv) * tol
            else:
                ok = cv <= bv + abs(bv) * tol
            status = "ok" if ok else "REGRESSION"
            print(f"[bench] {name}.{key}: {cv:.2f} vs baseline {bv:.2f} "
                  f"({cur['better']} is better) {status}")
            failures += 0 if ok else 1
    return failures


def _study(actor_name, critic_name, gen_lens, naive=True):
    from repro.configs import get_config
    from repro.core import build_rlhf_phases, lora_trainable_fraction
    actor = get_config(actor_name)
    critic = get_config(critic_name)
    # exact trainable fraction from the real adapter tree; the lora_rank
    # axis of the strategy grid threads through here
    tf = lambda rank=128: lora_trainable_fraction(actor, rank)
    cache = {}

    def plans(grad_ckpt):
        if grad_ckpt not in cache:
            out, persist = [], None
            for gl in gen_lens:
                ph, persist = build_rlhf_phases(
                    actor, critic, gen_len=gl, naive_generation=naive,
                    grad_ckpt=grad_ckpt)
                out.append(ph)
            cache[grad_ckpt] = (out, persist)
        return cache[grad_ckpt]
    return plans, tf


GEN_LENS = [180, 256, 199, 243]


def bench_figure1():
    """Figure 1: reserved/allocated timeline across the phases of a PPO
    iteration (all strategies enabled)."""
    from repro.core import PAPER_STRATEGIES, run_iteration
    t0 = time.time()
    plans, tf = _study("opt_1_3b", "opt_350m", GEN_LENS)
    strat = [s for s in PAPER_STRATEGIES if s.name == "All Enabled"][0]
    pl, persist = plans(True)
    r = run_iteration(pl, persist, strat, "none", ndp=4,
                      trainable_fraction=tf(strat.lora_rank), timeline=True)
    print("\n== Figure 1: phase memory timeline (All Enabled, OPT) ==")
    print(f"{'phase':18s} {'reserved_end':>12s} {'alloc_end':>10s} "
          f"{'frag_end':>9s}")
    for rec in r.phase_records[:8]:
        print(f"{rec.name:18s} {rec.reserved_end/GB:11.2f}G "
              f"{rec.allocated_end/GB:9.2f}G {rec.frag_end/GB:8.2f}G")
    ov = 100 * r.frag_at_peak / max(r.peak_reserved - r.frag_at_peak, 1)
    print(f"peak reserved {r.peak_reserved/GB:.2f}G  "
          f"frag@peak {r.frag_at_peak/GB:.2f}G  "
          f"(overhead {ov:.0f}% — paper: 46%)")
    _gate("frag_overhead_pct", ov, "lower")
    _csv("figure1_timeline", (time.time() - t0) * 1e6,
         f"frag_overhead_pct={ov:.0f}")


def _grid(title, actor, critic, capacity,
          policies=("none", "after_inference")):
    from repro.core import PAPER_STRATEGIES, run_iteration
    plans, tf = _study(actor, critic, GEN_LENS)
    print(f"\n== {title} ==")
    print(f"{'strategy':28s} {'policy':16s} {'reserved':>8s} {'frag':>6s} "
          f"{'alloc':>6s} {'time':>7s}")
    rows = []
    for strat in PAPER_STRATEGIES:
        pl, persist = plans(strat.grad_ckpt)
        for policy in policies:
            try:
                r = run_iteration(pl, persist, strat, policy, ndp=4,
                                  trainable_fraction=tf(strat.lora_rank),
                                  capacity=capacity)
                print(f"{strat.name:28s} {policy:16s} "
                      f"{r.peak_reserved/GB:7.2f}G {r.frag_at_peak/GB:5.2f}G "
                      f"{r.peak_allocated/GB:5.2f}G {r.time_s:6.2f}s")
                rows.append((strat.name, policy, r))
            except MemoryError:
                print(f"{strat.name:28s} {policy:16s} OOM")
    red, dt = [], []
    by = {(s, p): r for s, p, r in rows}
    for s in {s for s, _, _ in rows}:
        if (s, "none") in by and (s, "after_inference") in by:
            a, b = by[(s, "none")], by[(s, "after_inference")]
            red.append(1 - b.peak_reserved / a.peak_reserved)
            dt.append(b.time_s / a.time_s - 1)
    if red:
        print(f"-> empty_cache: avg consumption -{100*sum(red)/len(red):.0f}% "
              f"(paper -25%), time +{100*sum(dt)/len(dt):.1f}% (paper +2%)")
    return rows


def bench_table1():
    t0 = time.time()
    rows1 = _grid("Table 1a: DeepSpeed-Chat-style, OPT-1.3b/350m, 24 GB",
                  "opt_1_3b", "opt_350m", 24 * GB)
    rows2 = _grid("Table 1b: ColossalChat-style, GPT2-xl/medium, 24 GB",
                  "gpt2_xl", "gpt2_medium", 24 * GB)
    _csv("table1", (time.time() - t0) * 1e6, f"rows={len(rows1)+len(rows2)}")


def bench_table2():
    """Appendix C, Table 2: A100-80GB node, bigger models, +-ZeRO-3."""
    from repro.core import PAPER_STRATEGIES, run_iteration
    t0 = time.time()
    print("\n== Table 2: A100-80GB grid ==")
    strat_by = {s.name: s for s in PAPER_STRATEGIES}
    print(f"{'model':12s} {'strategy':8s} {'policy':16s} {'reserved':>8s} "
          f"{'frag':>6s} {'alloc':>6s}")
    for actor, critic in [("opt_1_3b", "opt_350m"),
                          ("opt_6_7b", "opt_350m"),
                          ("llama2_7b", "opt_350m")]:
        plans, tf = _study(actor, critic, GEN_LENS[:3])
        for sname in ("None", "ZeRO-3"):
            strat = strat_by[sname]
            pl, persist = plans(False)
            for policy in ("none", "after_inference"):
                try:
                    r = run_iteration(pl, persist, strat, policy,
                                      ndp=4,
                                      trainable_fraction=tf(strat.lora_rank),
                                      capacity=80 * GB)
                    print(f"{actor:12s} {sname:8s} {policy:16s} "
                          f"{r.peak_reserved/GB:7.2f}G "
                          f"{r.frag_at_peak/GB:5.2f}G "
                          f"{r.peak_allocated/GB:5.2f}G")
                except MemoryError:
                    print(f"{actor:12s} {sname:8s} {policy:16s} OOM")
    _csv("table2", (time.time() - t0) * 1e6)


def bench_placement():
    """§3.3: where to call empty_cache."""
    from repro.core import PAPER_STRATEGIES, run_iteration
    t0 = time.time()
    plans, tf = _study("opt_1_3b", "opt_350m", GEN_LENS)
    pl, persist = plans(False)
    print("\n== empty_cache placement ablation (None strategy) ==")
    res = {}
    for policy in ("none", "after_inference", "after_training", "after_all"):
        r = run_iteration(pl, persist, PAPER_STRATEGIES[0], policy, ndp=4,
                          trainable_fraction=tf(PAPER_STRATEGIES[0].lora_rank))
        res[policy] = r
        print(f"{policy:16s} reserved {r.peak_reserved/GB:6.2f}G "
              f"frag {r.frag_at_peak/GB:5.2f}G time {r.time_s:6.2f}s")
    d = res
    print(f"-> after_inference ~ after_all "
          f"({d['after_inference'].peak_reserved/GB:.2f} vs "
          f"{d['after_all'].peak_reserved/GB:.2f}); both << none "
          f"({d['none'].peak_reserved/GB:.2f}) — paper insight §3.3")
    _csv("placement", (time.time() - t0) * 1e6)


def bench_generation():
    """App. B: HF-style growing-cache generation vs our static donated
    cache (the framework's beyond-paper default)."""
    from repro.configs import get_config
    from repro.core import (PAPER_STRATEGIES, build_rlhf_phases,
                            lora_trainable_fraction, run_iteration)
    t0 = time.time()
    actor, critic = get_config("opt_1_3b"), get_config("opt_350m")
    tf = lora_trainable_fraction(actor, 128)
    print("\n== generation memory: naive growing cache vs static cache ==")
    for naive, label in ((True, "naive (HF dynamic cache)"),
                         (False, "framework (static donated)")):
        ph, persist = build_rlhf_phases(actor, critic, gen_len=256,
                                        naive_generation=naive)
        r = run_iteration([ph], persist, PAPER_STRATEGIES[0], "none", ndp=4,
                          trainable_fraction=tf, capacity=None)
        recs = {p.name: p for p in r.phase_records}
        growth = (recs["rollout_decode"].reserved_end
                  - recs["rollout_prefill"].reserved_end)
        print(f"{label:28s} decode reserved growth {growth/GB:6.2f}G "
              f"(cudaMallocs {r.n_cuda_malloc})")
    _csv("generation", (time.time() - t0) * 1e6)


def bench_kernels():
    """Microbench: XLA flash twin vs dense attention (wall time, CPU)."""
    import jax
    import jax.numpy as jnp
    from repro.kernels.ref import attention_ref
    from repro.models.flash import flash_sdpa
    t0 = time.time()
    print("\n== kernel microbench (CPU wall time; Pallas kernels are")
    print("   TPU-targeted, validated in interpret mode in tests/) ==")
    B, S, H, K, D = 1, 2048, 8, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, K, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, K, D), jnp.float32)
    f_dense = jax.jit(lambda q, k, v: attention_ref(q, k, v))
    f_flash = jax.jit(lambda q, k, v: flash_sdpa(q, k, v, True, 0, 512))
    for name, fn in (("attention_dense", f_dense),
                     ("attention_flash_xla", f_flash)):
        fn(q, k, v).block_until_ready()
        t1 = time.time()
        n = 3
        for _ in range(n):
            fn(q, k, v).block_until_ready()
        us = (time.time() - t1) / n * 1e6
        _csv(name, us, f"S={S}")
    _csv("kernels", (time.time() - t0) * 1e6)


def bench_paged():
    """Beyond-paper: dense [B, capacity] vs paged KV cache under ragged
    request lengths — reserved KV bytes and us/token of the serving loop."""
    import dataclasses

    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.models import Model
    from repro.serving import ContinuousBatcher
    t0 = time.time()
    cfg = dataclasses.replace(
        get_config("llama3_2_3b").smoke(), num_layers=2, d_model=128,
        d_ff=256, vocab_size=64, num_heads=4, num_kv_heads=2, head_dim=32)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    slots, capacity = 4, 128
    # ragged workload: short completions against a worst-case capacity
    gens = rng.randint(8, 48, size=10)
    print("\n== paged vs dense KV cache (ragged serving workload) ==")
    rows = {}
    for backend in ("dense", "paged"):
        cb = ContinuousBatcher(model, cfg, params, slots=slots,
                               capacity=capacity, temperature=0.0, seed=0,
                               cache_backend=backend, page_size=16)
        for g in gens:
            cb.submit(rng.randint(0, 64, size=8), int(g))
        t1 = time.time()
        done = cb.run_until_drained()
        dt = time.time() - t1
        toks = sum(len(r.out_tokens) for r in done)
        if backend == "paged":
            reserved = cb.pm.stats.peak_pages_in_use * cb.pm.page_bytes
        else:
            reserved = cb.kv_reserved_bytes()
        rows[backend] = (reserved, dt / toks * 1e6, toks)
        print(f"{backend:6s} reserved_kv {reserved/2**20:7.2f} MiB  "
              f"{dt/toks*1e6:8.1f} us/tok  ({toks} tokens)")
    dense_r, paged_r = rows["dense"][0], rows["paged"][0]
    assert paged_r < dense_r, "paged must reserve less than dense"
    print(f"-> paged reserves {100*(1-paged_r/dense_r):.0f}% less KV than "
          f"the dense [B, capacity] layout")
    _gate("kv_reduction_pct", 100 * (1 - paged_r / dense_r), "higher")
    _gate("paged_reserved_bytes", paged_r, "lower")
    _csv("paged", (time.time() - t0) * 1e6,
         f"dense_bytes={dense_r};paged_bytes={paged_r}")


def bench_decode():
    """Beyond-paper: the DESIGN.md "Fast decode path" — greedy decode
    tokens/s with MTP self-speculative decoding off vs on (bit-identity
    asserted), plus the compile-bucket ladder's cache hit rate on ragged
    serving traffic and paged-KV bytes per generated token.

    The draft heads only help if they predict the trunk, so the bench
    first trains the tiny model on a deterministic cyclic-token task
    (t_{i+1} = (t_i + 1) mod V) with the chained MTP loss at window=1 —
    the identity attention mask is exactly the function ``mtp_draft``
    evaluates at decode time."""
    import dataclasses

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config
    from repro.models import Model
    from repro.optim import make_optimizer
    from repro.rlhf import Rollout
    from repro.serving import ContinuousBatcher
    from repro.steps import lm_loss, mtp_loss

    t0 = time.time()
    V, SPEC_K = 64, 3
    cfg = dataclasses.replace(
        get_config("llama3_2_3b").smoke(), num_layers=2, d_model=128,
        d_ff=256, vocab_size=V, num_heads=4, num_kv_heads=2, head_dim=32,
        mtp_depth=SPEC_K)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = make_optimizer("adamw")
    opt_state = opt.init(params)
    S, TB = 32, 8

    def loss_fn(p, tokens):
        logits, _aux, h = model.forward(p, {"tokens": tokens})
        mask = jnp.ones_like(tokens)
        loss = lm_loss(logits, tokens, mask)
        for d, lg in enumerate(model.mtp_chain_logits(p, h, tokens,
                                                      window=1), start=1):
            loss = loss + mtp_loss(lg, tokens, mask, offset=d + 1) / SPEC_K
        return loss

    @jax.jit
    def train_step(p, st, tokens):
        loss, g = jax.value_and_grad(loss_fn)(p, tokens)
        p, st = opt.update(g, st, p, 3e-3)
        return p, st, loss

    print("\n== decode fast path: bucket ladder + MTP self-speculation ==")
    rng = np.random.RandomState(0)
    for _ in range(300):
        start = rng.randint(0, V, size=(TB, 1))
        params, opt_state, loss = train_step(
            params, opt_state, jnp.asarray((start + np.arange(S)[None]) % V))
    print(f"mini-train: cyclic-token task, 300 steps, "
          f"final loss {float(loss):.4f}")

    # -- greedy rollout tokens/s, speculation off vs on --------------------
    B, P, G = 4, 8, 64
    prompts = jnp.asarray(
        (rng.randint(0, V, size=(B, 1)) + np.arange(P)[None]) % V)
    key = jax.random.PRNGKey(1)
    runs = {}
    for name, kw in (("vanilla", {}),
                     ("spec", {"spec_decode": True, "spec_k": SPEC_K})):
        ro = Rollout(model, cfg, capacity=P + G, temperature=0.0, top_k=0,
                     **kw)
        res = ro.generate(params, {"tokens": prompts}, G, key)   # compile
        best = float("inf")
        for _ in range(7):      # best-of: robust to CI-runner load spikes
            t1 = time.time()
            res = ro.generate(params, {"tokens": prompts}, G, key)
            jax.block_until_ready(res.tokens)
            best = min(best, time.time() - t1)
        tps = B * G / best
        runs[name] = (ro, res, tps)
        print(f"{name:8s} {tps:8.0f} tok/s greedy (B={B}, gen={G})")
    (_, rv, tps_v), (ro_s, rs, tps_s) = runs["vanilla"], runs["spec"]
    assert bool(jnp.array_equal(rv.tokens, rs.tokens)), \
        "speculative greedy tokens diverged from vanilla"
    assert float(jnp.max(jnp.abs(rv.logp - rs.logp))) < 1e-5
    st = ro_s.spec_stats
    accept = st["accepted"] / max(st["drafted"], 1)
    speedup = tps_s / tps_v
    # deterministic companion to the (timing-noisy) speedup: forwards per
    # emitted token — vanilla is G, spec is the verify-step count
    dispatch_red = G / st["steps"]
    print(f"-> spec speedup {speedup:.2f}x wall ({dispatch_red:.2f}x fewer "
          f"decode dispatches), draft accept rate {100*accept:.0f}% "
          f"({st['steps']} verify steps; bit-identical)")
    assert speedup >= 1.2, f"spec decode speedup {speedup:.2f}x < 1.2x"
    assert accept >= 0.90, f"trained draft accept rate {accept:.2f} < 0.90"

    # -- bucketed batcher on ragged traffic: hit rate + bytes/token --------
    cb = ContinuousBatcher(model, cfg, params, slots=4, capacity=64,
                           temperature=0.0, seed=0, cache_backend="paged",
                           page_size=16, capture_buckets=(8, 16, 32),
                           spec_decode=True, spec_k=SPEC_K)
    for _ in range(12):
        plen = int(rng.randint(4, 28))
        cb.submit((int(rng.randint(0, V)) + np.arange(plen)) % V,
                  int(rng.randint(8, 32)))
    done = cb.run_until_drained()
    toks = sum(len(r.out_tokens) for r in done)
    hit = cb.compile_cache.hit_rate
    kv_bpt = cb.pm.stats.peak_pages_in_use * cb.pm.page_bytes / toks
    print(f"ragged traffic: {len(done)} requests, {toks} tokens, "
          f"compile cache {cb.compile_cache.stats()}")
    print(f"-> hit rate {100*hit:.1f}% (acceptance: >=95%), "
          f"paged KV {kv_bpt:.0f} bytes/token")
    assert hit >= 0.95, f"compile-cache hit rate {hit:.2f} < 0.95"
    assert cb.compile_cache.recompiles == 0, "post-warmup recompile"

    _gate("spec_speedup", speedup, "higher")
    _gate("dispatch_reduction", dispatch_red, "higher")
    _gate("draft_accept_rate", accept, "higher")
    _gate("compile_cache_hit_rate", hit, "higher")
    _gate("kv_bytes_per_token", kv_bpt, "lower")
    _result()["metrics"]["tokens_per_s"] = {
        "vanilla": round(tps_v, 1), "spec": round(tps_s, 1)}
    _csv("decode", (time.time() - t0) * 1e6,
         f"speedup={speedup:.2f};accept={accept:.2f};hit_rate={hit:.2f};"
         f"kv_bytes_per_token={kv_bpt:.0f}")


def bench_serving():
    """Beyond-paper: trace-driven multi-tenant serving A/B — the same
    shared-system-prompt traffic (3 tenants, weighted 4:2:1, deterministic
    arrivals) through the continuous batcher with the prefix cache off and
    on. Asserts greedy outputs are bit-identical between the legs, a
    token-level prefix-hit-rate >= 0.9, and >= 40% lower peak reserved KV
    on the cached leg; gates hit rate, KV reduction, tokens/s and p99
    admission latency against the committed baseline."""
    import dataclasses

    import jax

    from benchmarks.serving_traffic import run_trace, synthetic_trace
    from repro.configs import get_config
    from repro.models import Model
    from repro.obs import RunTelemetry
    from repro.serving import ContinuousBatcher

    t0 = time.time()
    cfg = dataclasses.replace(
        get_config("llama3_2_3b").smoke(), num_layers=2, d_model=128,
        d_ff=256, vocab_size=64, num_heads=4, num_kv_heads=2, head_dim=32)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    trace = synthetic_trace(cfg.vocab_size)
    print("\n== multi-tenant serving traffic (prefix cache A/B) ==")
    legs = {}
    tel = None
    # the trace replay is deterministic, so repeats only re-measure wall
    # time — best-of-3 keeps the wall-clock gates out of CI-runner noise
    # (same trick as bench_decode's best-of-7 decode timing)
    for prefix_cache in (False, True):
        repeats = 3 if prefix_cache else 1
        best_tps, best_p99 = 0.0, float("inf")
        for rep in range(repeats):
            # telemetry only on the measured (cached) leg: registry gauges,
            # attribution owner tables and the Chrome trace come from it
            tel = (RunTelemetry.create(sim_delta=False)
                   if prefix_cache else None)
            cb = ContinuousBatcher(
                model, cfg, params, slots=4, capacity=96, temperature=0.0,
                seed=0, cache_backend="paged", page_size=16, num_pages=48,
                capture_buckets=(4, 16, 80), prefix_cache=prefix_cache,
                telemetry=tel,
                tenant_weights={"tenant0": 4.0, "tenant1": 2.0,
                                "tenant2": 1.0})
            res = run_trace(cb, trace)
            best_tps = max(best_tps, res.tokens_per_s)
            best_p99 = min(best_p99, res.p99_admission_latency_s())
        legs[prefix_cache] = (cb, res, best_tps, best_p99)
        reserved = cb.pm.stats.peak_pages_in_use * cb.pm.page_bytes
        print(f"prefix_cache={str(prefix_cache):5s}: {res.n_tokens} tokens "
              f"{best_tps:8.0f} tok/s  hit {cb.prefix_hit_rate():.3f}"
              f"  peak_reserved {reserved} B  "
              f"p99_admit {best_p99*1e3:.1f} ms")

    (cb_off, res_off, _, _) = legs[False]
    (cb_on, res_on, tps_on, p99_on) = legs[True]
    # greedy decoding must not notice the cache: same rid order, same tokens
    for a, b in zip(res_off.requests, res_on.requests):
        assert a.out_tokens == b.out_tokens, \
            f"prefix cache changed rid {a.rid}: {a.out_tokens} vs " \
            f"{b.out_tokens}"
    hit = cb_on.prefix_hit_rate()
    assert hit >= 0.9, f"prefix hit rate {hit:.3f} < 0.9"
    r_off = cb_off.pm.stats.peak_pages_in_use * cb_off.pm.page_bytes
    r_on = cb_on.pm.stats.peak_pages_in_use * cb_on.pm.page_bytes
    kv_red = 100 * (1 - r_on / r_off)
    assert kv_red >= 40, f"reserved-KV reduction {kv_red:.0f}% < 40%"
    # the registry gauge the scheduler emits must agree with the API
    g = tel.registry.get("serving_prefix_hit_rate")
    assert g is not None and abs(g.value() - hit) < 1e-9
    print(f"-> hit rate {hit:.3f}, reserved KV -{kv_red:.0f}% "
          f"({r_off} -> {r_on} B), outputs bit-identical")

    _gate("prefix_hit_rate", hit, "higher")
    _gate("kv_reduction_pct", kv_red, "higher")
    _gate("tokens_per_s", tps_on, "higher")
    _gate("p99_admission_latency_s", p99_on, "lower")
    _result()["metrics"]["reserved_kv_bytes"] = {
        "prefix_cache_off": int(r_off), "prefix_cache_on": int(r_on)}
    _result()["metrics"]["prefix_cache"] = {
        "hits": cb_on.pm.stats.n_prefix_hits,
        "queries": cb_on.pm.stats.n_prefix_queries,
        "evictions": cb_on.pm.stats.n_prefix_evictions}
    _result()["metrics"]["per_tenant_p50_admission_steps"] = {
        t: sorted(ls)[len(ls) // 2] for t, ls in (
            (t, [res_on.latency_steps[r.rid] for r in res_on.requests
                 if r.tenant == t and r.rid in res_on.latency_steps])
            for t in ("tenant0", "tenant1", "tenant2")) if ls}
    _trace(tel.tracer.chrome_trace())
    _artifact("ATTRIB_serving.json",
              {"owners": tel.attribution.snapshot().table(),
               "metrics": tel.registry.snapshot()})
    _csv("serving", (time.time() - t0) * 1e6,
         f"hit_rate={hit:.3f};kv_reduction_pct={kv_red:.0f};"
         f"p99_admit_s={p99_on:.4f}")


def bench_hydra():
    """Beyond-paper: the shared-base hydra engine (one frozen trunk +
    per-role LoRA adapters, rank 128) vs the four-model separate path —
    REAL live device bytes from PhaseMemoryManager, plus the greedy
    merged-rollout == unmerged-argmax identity check."""
    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.rlhf import RLHFConfig, RLHFTrainer, live_device_bytes
    from repro.rlhf.reward import make_target_token_reward

    t0 = time.time()
    cfg = dataclasses.replace(
        get_config("llama3_2_3b").smoke(), num_layers=2, d_model=1024,
        d_ff=2048, vocab_size=64, num_heads=8, num_kv_heads=4, head_dim=128)
    print("\n== hydra engine vs four-model pipeline (live device bytes) ==")
    init_bytes, tr = {}, None
    for engine in ("separate", "hydra"):
        rl = RLHFConfig(prompt_len=8, gen_len=16, lr=1e-3, critic_lr=1e-3,
                        kl_coef=0.0, top_k=0, engine=engine, lora_rank=128)
        before = live_device_bytes()
        tr = RLHFTrainer(cfg, cfg, rl, jax.random.PRNGKey(0),
                         reward_fn=make_target_token_reward(7))
        init_bytes[engine] = live_device_bytes() - before
        print(f"{engine:9s} live after init {init_bytes[engine]/2**20:8.2f} "
              f"MiB")
        if engine == "separate":
            # only measured for the A/B — free the four full models before
            # the hydra trainer allocates. The trainer's engine-bound
            # closures capture self (a reference cycle), so refcounting
            # alone frees nothing: collect explicitly, or the drop lands
            # nondeterministically inside the hydra measurement window.
            del tr
            import gc
            gc.collect()
    acc = tr.engine.memory_accounting()
    for layout in ("separate", "hydra"):
        tot = {k: sum(r[k] for r in acc[layout].values())
               for k in ("params", "opt", "grad")}
        print(f"  accounting[{layout:9s}] params "
              f"{tot['params']/2**20:8.2f} MiB  opt "
              f"{tot['opt']/2**20:8.2f} MiB  grad "
              f"{tot['grad']/2**20:8.2f} MiB")
    red = 1 - init_bytes["hydra"] / init_bytes["separate"]
    print(f"-> hydra holds {100*red:.0f}% less live memory after init "
          f"(acceptance: >=40%)")
    assert red >= 0.40, f"hydra must cut live bytes >=40%, got {100*red:.0f}%"

    # greedy identity: 2 PPO steps to move the adapters off zero-delta, then
    # a greedy merged rollout must equal the unmerged forward's argmax path
    from repro.rlhf import Rollout
    P = tr.rl.prompt_len
    key = jax.random.PRNGKey(1)
    prompts = jax.random.randint(key, (4, P), 0, cfg.vocab_size)
    for s in range(2):
        tr.train_step(prompts, jax.random.fold_in(key, s))
    greedy_ro = Rollout(tr.actor, cfg, capacity=P + tr.rl.gen_len,
                        temperature=0.0, top_k=0)
    ro = greedy_ro.generate(tr.base_params, {"tokens": prompts},
                            tr.rl.gen_len, key,
                            adapter=tr.actor_state["params"])
    logits, _, _ = tr.actor.forward(tr.base_params, {"tokens": ro.tokens},
                                    adapter=tr.actor_state["params"])
    greedy = jnp.argmax(logits[:, P - 1:-1], -1)   # position P-1+t scores t
    gen = ro.tokens[:, P:]
    match = bool(jnp.array_equal(greedy, gen))
    print(f"-> merged-rollout greedy tokens == unmerged argmax: {match}")
    assert match, "merged rollout diverged from unmerged argmax path"
    _gate("reduction_pct", 100 * red, "higher")
    _csv("hydra", (time.time() - t0) * 1e6,
         f"separate_bytes={init_bytes['separate']};"
         f"hydra_bytes={init_bytes['hydra']};reduction_pct={100*red:.0f}")


def bench_offload():
    """Beyond-paper: the phase-aware host-offload subsystem
    (repro.offload). Part 1 replays the paper-scale hydra engine
    (OPT-1.3b trunk + OPT-350m critic slot, rank 128, grad-ckpt — the
    paper's all-enabled remat regime) through the allocator simulator
    across the offload grid and asserts the >=25% peak-live-HBM floor for
    offload="all". Part 2 runs the real trainer A/B at CPU scale:
    bit-identical greedy rollout tokens and exactly equal 2-step PPO
    losses between offload="all" and "none", plus the check that the
    simulator's per-phase live-bytes curve brackets the measured one."""
    import dataclasses
    import gc

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.core import (MemoryStrategy, OFFLOAD_LEVELS,
                            build_rlhf_phases, run_iteration)
    from repro.rlhf import (RLHFConfig, RLHFTrainer, Rollout,
                            live_device_bytes)
    from repro.rlhf.reward import make_target_token_reward

    t0 = time.time()
    # ---- part 1: paper scale through the simulator -----------------------
    actor, critic = get_config("opt_1_3b"), get_config("opt_350m")
    ph, persist = build_rlhf_phases(actor, critic, gen_len=256,
                                    engine="hydra", lora_rank=128,
                                    grad_ckpt=True)
    print("\n== offload grid: paper scale, hydra engine (simulator) ==")
    print(f"{'offload':10s} {'peak_live':>9s} {'peak_host':>9s} "
          f"{'swapped':>8s} {'time':>7s}")
    peaks = {}
    for level in OFFLOAD_LEVELS:
        r = run_iteration(ph, persist,
                          MemoryStrategy("None", grad_ckpt=True,
                                         offload=level),
                          "none", ndp=4, trainable_fraction=1.0,
                          capacity=None)
        peaks[level] = r.peak_allocated
        print(f"{level:10s} {r.peak_allocated/GB:8.2f}G "
              f"{r.peak_host_bytes/GB:8.2f}G {r.swapped_bytes/GB:7.2f}G "
              f"{r.time_s:6.2f}s")
    red = 1 - peaks["all"] / peaks["none"]
    print(f"-> offload=all cuts peak live HBM {100*red:.0f}% "
          f"(acceptance: >=25%)")
    assert red >= 0.25, f"offload=all must cut >=25%, got {100*red:.0f}%"

    # ---- part 2: runtime A/B (tiny hydra config) -------------------------
    # bf16 params to match the dtype build_rlhf_phases forces, so part 3's
    # bracket compares like against like
    cfg = dataclasses.replace(
        get_config("llama3_2_3b").smoke(), num_layers=2, d_model=1024,
        d_ff=2048, vocab_size=64, num_heads=8, num_kv_heads=4, head_dim=128,
        param_dtype="bfloat16")
    P, G, B = 8, 16, 4
    key = jax.random.PRNGKey(1)
    prompts = jax.random.randint(key, (B, P), 0, cfg.vocab_size)
    print("\n== offload runtime A/B (live device bytes per phase) ==")
    metrics, tokens, peak_live = {}, {}, {}
    trainers = {}
    for level in ("none", "all"):
        gc.collect()
        base_live = live_device_bytes()
        rl = RLHFConfig(prompt_len=P, gen_len=G, lr=1e-3, critic_lr=1e-3,
                        kl_coef=0.0, top_k=0, engine="hydra", lora_rank=128,
                        offload=level)
        tr = RLHFTrainer(cfg, cfg, rl, jax.random.PRNGKey(0),
                         reward_fn=make_target_token_reward(7))
        ms = [tr.train_step(prompts, jax.random.fold_in(key, s))
              for s in range(2)]
        metrics[level] = ms
        recs = tr.memory.records[-8:]         # final iteration
        peak_live[level] = max(r["live_bytes"] for r in recs) - base_live
        for r in recs:
            print(f"  [{level:4s}] {r['phase']:16s} live "
                  f"{(r['live_bytes']-base_live)/2**20:8.2f} MiB  host "
                  f"{r['host_bytes']/2**20:8.2f} MiB")
        # greedy rollout from the trained state (merged path)
        ro = Rollout(tr.actor, cfg, capacity=P + G, temperature=0.0,
                     top_k=0).generate(
            tr.base_params, {"tokens": prompts}, G, key,
            adapter=tr.actor_state["params"])
        tokens[level] = ro.tokens
        if level == "none":
            # greedy identity vs the unmerged argmax path
            logits, _, _ = tr.actor.forward(
                tr.base_params, {"tokens": ro.tokens},
                adapter=tr.actor_state["params"])
            greedy = jnp.argmax(logits[:, P - 1:-1], -1)
            assert bool(jnp.array_equal(greedy, ro.tokens[:, P:])), \
                "merged greedy rollout diverged from unmerged argmax"
            del tr, logits
        else:
            trainers[level] = tr
        del ms, recs, ro
    run_red = 1 - peak_live["all"] / peak_live["none"]
    print(f"-> runtime peak live bytes: -{100*run_red:.0f}% "
          f"(offload=all vs none)")
    assert bool(jnp.array_equal(tokens["none"], tokens["all"])), \
        "greedy rollout tokens differ between offload levels"
    for a, b in zip(metrics["none"], metrics["all"]):
        for k in ("loss", "vf_loss", "ppo_loss"):
            assert a[k] == b[k], (k, a[k], b[k])
    print("-> greedy rollout tokens bit-identical; 2-step PPO losses equal")

    # ---- part 3: simulator curve brackets the measured one ---------------
    tr = trainers["all"]
    sph, spersist = build_rlhf_phases(
        cfg, cfg, batch=B, prompt_len=P, gen_len=G, engine="hydra",
        lora_rank=128, grad_ckpt=(cfg.remat == "full"), min_bytes=2048)
    sr = run_iteration(sph, spersist,
                       MemoryStrategy("None", offload="all"), "none",
                       ndp=1, trainable_fraction=1.0, capacity=None)
    sim = {rec.name: rec for rec in sr.phase_records}
    name_map = {"rollout": "rollout_decode"}
    print("\n== simulator brackets runtime (per-phase live bytes) ==")
    gc.collect()
    slack = 4 << 20     # python-side scalars/rng keys the sim doesn't see
    for r in tr.memory.records[-8:]:
        srec = sim[name_map.get(r["phase"], r["phase"])]
        measured = r["live_bytes"]
        # bracket: [post-eviction floor, within-phase allocation peak] —
        # boundary records sit near the floor, the mid-rollout sample
        # (merged weights live) under the peak
        lo, hi = srec.allocated_end, srec.alloc_peak
        ok = lo * 0.8 - slack <= measured <= hi * 1.2 + slack
        print(f"  {r['phase']:16s} sim [{lo/2**20:8.2f}, {hi/2**20:8.2f}] "
              f"MiB  measured {measured/2**20:8.2f} MiB  "
              f"{'ok' if ok else 'OUT'}")
        assert ok, (r["phase"], lo, measured, hi)
    print("-> simulator's predicted live-HBM curve brackets the runtime")
    _gate("sim_reduction_pct", 100 * red, "higher")
    _gate("runtime_reduction_pct", 100 * run_red, "higher")
    _csv("offload", (time.time() - t0) * 1e6,
         f"sim_reduction_pct={100*red:.0f};"
         f"runtime_reduction_pct={100*run_red:.0f}")


def bench_obs():
    """Unified runtime telemetry acceptance: a 2-step PPO run (hydra
    engine, offload=all, zero_stage=3) must produce a Perfetto-loadable
    Chrome trace with >= one span per canonical runtime phase carrying the
    measured peak bytes AND the traced simulator's prediction, a JSONL that
    ``launch/report.py`` renders with zero recomputation, and a telemetry
    tax <= 2% of wall time (tracer self-accounting). PR 8 extends the
    acceptance to the attribution observatory: every phase span's owner
    table must sum (with the unattributed residue) EXACTLY to the
    measured live bytes, the residue must stay <= 10% of live at every
    boundary, and a forced low watermark must produce a valid
    flight-recorder dump naming the top owners."""
    import dataclasses
    import tempfile

    import jax

    from repro.configs import get_config
    from repro.core.phases import RUNTIME_RLHF_PHASE_SEQUENCE
    from repro.launch.report import render
    from repro.obs import FlightRecorder, RunTelemetry
    from repro.rlhf import RLHFConfig, RLHFTrainer
    from repro.rlhf.reward import make_target_token_reward
    from repro.sharding import ShardedContext

    t0 = time.time()
    print("\n== runtime telemetry (hydra, offload=all, zero_stage=3) ==")
    cfg = dataclasses.replace(
        get_config("llama3_2_3b").smoke(), num_layers=2, d_model=128,
        d_ff=256, vocab_size=64, num_heads=4, num_kv_heads=2, head_dim=32,
        param_dtype="bfloat16")
    rl = RLHFConfig(prompt_len=8, gen_len=16, lr=1e-3, critic_lr=1e-3,
                    kl_coef=0.0, top_k=0, engine="hydra", lora_rank=16,
                    offload="all")
    shard = ShardedContext.create(1, zero_stage=3)
    # forced watermark: on CPU the recorder calibrates capacity from its
    # first check (step-1 mid-rollout peak, merged weights live), so 0.9
    # deterministically breaches at step 2's rollout sample — the
    # memory-rich point — after a full iteration of phase history
    fl = FlightRecorder(watermark=0.9, ring=128)
    tel = RunTelemetry.create(engine="hydra", offload="all", zero_stage=3,
                              flight=fl)
    tr = RLHFTrainer(cfg, cfg, rl, jax.random.PRNGKey(0),
                     reward_fn=make_target_token_reward(7), shard=shard,
                     telemetry=tel)
    key = jax.random.PRNGKey(1)
    for s in range(2):
        prompts = jax.random.randint(jax.random.fold_in(key, s),
                                     (4, rl.prompt_len), 0, cfg.vocab_size)
        tr.train_step(prompts, jax.random.fold_in(key, 100 + s))
    wall = time.time() - t0

    # one span per canonical phase, measured AND simulated peaks attached
    by_phase = {}
    for sp in tel.tracer.spans:
        if sp.cat == "phase":
            by_phase.setdefault(sp.name, []).append(sp)
    for ph in RUNTIME_RLHF_PHASE_SEQUENCE:
        name = "rollout" if ph == "rollout" else ph
        assert by_phase.get(name), f"no phase span for {ph}"
        args = by_phase[name][-1].args
        assert "measured_peak_bytes" in args, (name, args)
        assert "sim_peak_bytes" in args, \
            f"{name}: simulator prediction missing from phase span"
    n_phase = sum(len(v) for v in by_phase.values())
    print(f"phase spans: {n_phase} over {len(by_phase)} phases "
          f"(2 iterations x {len(RUNTIME_RLHF_PHASE_SEQUENCE)})")
    assert n_phase == 2 * len(RUNTIME_RLHF_PHASE_SEQUENCE)
    n_off = sum(1 for sp in tel.tracer.spans if sp.cat == "offload")
    assert n_off > 0, "offload=all run emitted no offload spans"

    # -- attribution observatory acceptance --------------------------------
    # exactness: at every boundary, sum(owner table) + residue must equal
    # the measured live bytes EXACTLY (the snapshot walk IS the
    # measurement — one jax.live_arrays() pass classifies and totals)
    phase_spans = [sp for sp in tel.tracer.spans if sp.cat == "phase"]
    worst_resid = 0.0
    attrib_tables = {}
    for sp in phase_spans:
        a = sp.args
        assert "attrib" in a, f"{sp.name}: no owner table on phase span"
        total = sum(a["attrib"].values()) + a["attrib_unattributed"]
        assert total == a["measured_bytes"], \
            (sp.name, total, a["measured_bytes"])
        resid = a["attrib_unattributed"] / max(a["measured_bytes"], 1)
        worst_resid = max(worst_resid, resid)
        attrib_tables[sp.name] = {"owners": a["attrib"],
                                  "unattributed": a["attrib_unattributed"],
                                  "measured_bytes": a["measured_bytes"],
                                  "sim_delta": a.get("attrib_sim_delta")}
    n_sim_owner = sum(1 for sp in phase_spans
                      if "attrib_sim_delta" in sp.args)
    # the mid-phase samples sit at the phase PEAKS (hydra rollout decode:
    # merged weights + ZeRO gather copies live) — exactness and the <=10%
    # residue bound must hold there too, not just at boundary troughs
    n_samples = 0
    for ev in tel.tracer.instants:
        a = ev["args"]
        if ev["cat"] != "phase" or "attrib" not in a:
            continue
        n_samples += 1
        total = sum(a["attrib"].values()) + a["attrib_unattributed"]
        assert total == a["measured_bytes"], (ev["name"], total)
        resid = a["attrib_unattributed"] / max(a["measured_bytes"], 1)
        worst_resid = max(worst_resid, resid)
    assert n_samples > 0, "no mid-phase attribution samples recorded"
    print(f"attribution: {len(phase_spans)} spans + {n_samples} peak "
          f"samples exact (sum owners + residue == measured), worst "
          f"residue {100*worst_resid:.2f}% of live, {n_sim_owner} spans "
          f"carry per-owner sim deltas")
    assert worst_resid <= 0.10, \
        f"unattributed residue {100*worst_resid:.1f}% > 10% of live"
    assert n_sim_owner > 0, "no span joined the sim's per-owner ledger"

    # forced watermark must have produced a valid forensic dump
    assert fl.dumps, "forced watermark=0.25 produced no flight dump"
    dump = fl.dumps[0]
    assert dump["schema"] == "flight-recorder/v1" and \
        dump["trigger"] == "watermark", dump["trigger"]
    top3 = dump["owners_ranked"][:3]
    assert len(top3) >= 3 and all(dump["owners"][o] > 0 for o in top3), top3
    assert dump["top_buffers"] and dump["phase_history"], \
        "dump missing top_buffers/phase_history forensics"
    print(f"flight dump: trigger={dump['trigger']} top owners {top3}")
    _artifact("FLIGHT_obs.json", dump)
    _artifact("ATTRIB_obs.json", attrib_tables)

    # per-jitted-program compiled-memory accounting joined the registry
    n_compiled = sum(
        1 for m in tel.registry.snapshot()
        if m["name"].startswith("compiled_") and m["name"].endswith("_bytes"))
    print(f"compiled-memory gauges: {n_compiled}")
    assert n_compiled > 0, "no compiled_*_bytes program accounting recorded"

    # Chrome-trace schema: loadable JSON, required keys per event type
    chrome = tel.tracer.chrome_trace()
    chrome = json.loads(json.dumps(chrome))        # round-trip
    assert isinstance(chrome["traceEvents"], list) and chrome["traceEvents"]
    for ev in chrome["traceEvents"]:
        assert ev["ph"] in ("M", "X", "i", "C"), ev
        assert isinstance(ev["pid"], int) and isinstance(ev["tid"], int)
        if ev["ph"] == "X":
            assert ev["dur"] >= 0 and isinstance(ev["ts"], (int, float))
    _trace(chrome)

    # report renders the JSONL without recomputation
    with tempfile.NamedTemporaryFile("w", suffix=".jsonl",
                                     delete=False) as f:
        jsonl_path = f.name
    tel.write_jsonl(jsonl_path)
    report = render(jsonl_path)
    for ph in RUNTIME_RLHF_PHASE_SEQUENCE:
        assert ("rollout" if ph == "rollout" else ph) in report
    print(report.split("\n\n")[1])                 # the per-phase table
    os.unlink(jsonl_path)

    ov_pct = 100 * tel.tracer.overhead_fraction(wall)
    print(f"-> telemetry self-time {tel.tracer.self_time_s*1e3:.2f} ms "
          f"of {wall:.2f} s wall = {ov_pct:.3f}% (acceptance: <=2%)")
    assert ov_pct <= 2.0, f"telemetry overhead {ov_pct:.2f}% > 2%"
    # the 2% gate now covers the attribution walk too: snapshot() charges
    # its walk time to tracer.self_time_s
    _gate("telemetry_overhead_pct", ov_pct, "lower")
    _gate("phase_spans_per_iteration", n_phase / 2, "higher")
    _gate("attrib_unattributed_pct", 100 * worst_resid, "lower")
    _csv("obs", (time.time() - t0) * 1e6,
         f"phase_spans={n_phase};offload_spans={n_off};"
         f"overhead_pct={ov_pct:.3f};"
         f"attrib_unattributed_pct={100*worst_resid:.2f}")


def bench_grpo():
    """Beyond-paper: GRPO (2 models) vs PPO (4 models) peak memory."""
    from repro.configs import get_config
    from repro.core import (PAPER_STRATEGIES, build_rlhf_phases,
                            lora_trainable_fraction, run_iteration)
    from repro.core.phases import build_grpo_phases
    t0 = time.time()
    actor, critic = get_config("opt_1_3b"), get_config("opt_350m")
    tf = lora_trainable_fraction(actor, 128)
    strat = PAPER_STRATEGIES[0]
    print("\n== GRPO vs PPO memory (same token budget) ==")
    for name, builder in (
            ("PPO", lambda gl: build_rlhf_phases(
                actor, critic, gen_len=gl, naive_generation=True)),
            ("GRPO", lambda gl: build_grpo_phases(
                actor, batch=2, group_size=1, gen_len=gl,
                naive_generation=True))):
        plans = []
        for gl in (180, 256, 199, 243):
            ph, persist = builder(gl)
            plans.append(ph)
        for policy in ("none", "after_inference"):
            r = run_iteration(plans, persist, strat, policy, ndp=4,
                              trainable_fraction=tf)
            print(f"{name:5s} {policy:16s} reserved {r.peak_reserved/GB:6.2f}G"
                  f" frag {r.frag_at_peak/GB:5.2f}G"
                  f" alloc {r.peak_allocated/GB:6.2f}G")
    _csv("grpo_vs_ppo", (time.time() - t0) * 1e6)


def bench_zero():
    """Beyond-paper: the mesh-sharded ZeRO RLHF engines, validated on 8
    forced host devices (subprocess — the flag must be set before jax
    initializes). Asserts 2-step PPO bit-identity between ndp=1 and ndp=8
    on BOTH engines, dense+paged rollout identity under the mesh, the
    ZeRO-3 per-device param+opt cut (<=30% of replicated for the separate
    engine), and that the simulator's traced ndp=8 curve brackets the
    measured one. See benchmarks/zero_smoke.py."""
    import subprocess
    t0 = time.time()
    root = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    env = dict(os.environ,
               PYTHONPATH=os.path.join(root, "src"),
               JAX_PLATFORMS="cpu",
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    r = subprocess.run([sys.executable, "-m", "benchmarks.zero_smoke"],
                       env=env, cwd=root, capture_output=True, text=True,
                       timeout=3000)
    print("\n== mesh-sharded ZeRO RLHF smoke (8 forced host devices) ==")
    out = r.stdout or ""
    print("\n".join(l for l in out.splitlines()
                    if not l.startswith("ZERO_METRICS")))
    assert r.returncode == 0, f"zero_smoke failed:\n{out}\n{r.stderr[-3000:]}"
    metrics = json.loads(
        [l for l in out.splitlines()
         if l.startswith("ZERO_METRICS ")][-1][len("ZERO_METRICS "):])
    assert metrics["separate_biteq"] and metrics["hydra_biteq"]
    assert metrics["sim_bracket_ok"]
    assert metrics["separate_state_bytes_zero3"] <= \
        0.30 * metrics["separate_state_bytes_ndp1"]
    # per-layer FSDP gathers: the compiled-program transient peak must
    # drop from the whole stacked tree to ~one layer period, and the
    # traced simulator term must bracket the measured delta
    assert metrics["layer_transient_ok"]
    assert metrics["transient_sim_bracket_ok"]
    assert metrics["telemetry_overhead_pct"] <= 2.0
    _gate("telemetry_overhead_pct", metrics["telemetry_overhead_pct"],
          "lower")
    _gate("separate_zero3_cut_pct", metrics["separate_zero3_cut_pct"],
          "higher")
    _gate("hydra_zero3_cut_pct", metrics["hydra_zero3_cut_pct"], "higher")
    _gate("gather_transient_cut_pct", metrics["gather_transient_cut_pct"],
          "higher")
    _csv("zero", (time.time() - t0) * 1e6,
         f"separate_cut_pct={metrics['separate_zero3_cut_pct']};"
         f"hydra_cut_pct={metrics['hydra_zero3_cut_pct']};"
         f"gather_transient_cut_pct={metrics['gather_transient_cut_pct']}")


def bench_tp():
    """Beyond-paper: tensor parallelism as a runtime axis composed with
    ZeRO, validated on 8 forced host devices (subprocess — the flag must
    be set before jax initializes). Asserts 2-step PPO loss ALLCLOSE
    (reduction-order drift only — TP splits contractions, so the pure-DP
    bit-identity bar does not apply; DESIGN.md §9) between ndp=1,ntp=1 and
    ndp=2,ntp=2 on BOTH engines, dense+paged rollout identity from the
    TP-sharded state (paged KV pool kv-head-sharded), the pure-TP
    per-device param+opt cut (>=40% at ntp=2, ZeRO off), and that the
    simulator's traced dp x tp curve brackets the measured one. See
    benchmarks/tp_smoke.py."""
    import subprocess
    t0 = time.time()
    root = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    env = dict(os.environ,
               PYTHONPATH=os.path.join(root, "src"),
               JAX_PLATFORMS="cpu",
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    r = subprocess.run([sys.executable, "-m", "benchmarks.tp_smoke"],
                       env=env, cwd=root, capture_output=True, text=True,
                       timeout=3000)
    print("\n== TP x ZeRO sharded RLHF smoke (8 forced host devices) ==")
    out = r.stdout or ""
    print("\n".join(l for l in out.splitlines()
                    if not l.startswith("TP_METRICS")))
    assert r.returncode == 0, f"tp_smoke failed:\n{out}\n{r.stderr[-3000:]}"
    metrics = json.loads(
        [l for l in out.splitlines()
         if l.startswith("TP_METRICS ")][-1][len("TP_METRICS "):])
    assert metrics["separate_tp_allclose"] and metrics["hydra_tp_allclose"]
    assert metrics["separate_rollout_identical"]
    assert metrics["hydra_rollout_identical"]
    assert metrics["sim_bracket_ok"]
    assert metrics["separate_tp_cut_pct"] >= 40.0
    _gate("separate_tp_cut_pct", metrics["separate_tp_cut_pct"], "higher")
    _gate("separate_tp_zero3_cut_pct",
          metrics["separate_tp_zero3_cut_pct"], "higher")
    _gate("hydra_tp_zero3_cut_pct",
          metrics["hydra_tp_zero3_cut_pct"], "higher")
    _csv("tp", (time.time() - t0) * 1e6,
         f"separate_tp_cut_pct={metrics['separate_tp_cut_pct']};"
         f"separate_tp_zero3_cut_pct={metrics['separate_tp_zero3_cut_pct']};"
         f"hydra_tp_zero3_cut_pct={metrics['hydra_tp_zero3_cut_pct']}")


def bench_zero_tpu():
    """Beyond-paper: the R2 strategy comparison on the real TPU mesh
    (subprocess — needs 512 forced host devices before jax init)."""
    import subprocess
    import sys
    t0 = time.time()
    root = os.path.join(os.path.dirname(__file__), "..")
    path = os.path.join(root, "zero_tpu_study.txt")
    if os.path.exists(path):
        print("\n== R2 on the TPU runtime (cached zero_tpu_study.txt) ==")
        print(open(path).read())
    else:
        env = dict(os.environ,
                   PYTHONPATH=os.path.join(root, "src"),
                   XLA_FLAGS="--xla_force_host_platform_device_count=512")
        code = (
            "from repro.launch.roofline import analyze_one\n"
            "from repro.launch.mesh import make_production_mesh\n"
            "from repro.sharding import ShardingStrategy\n"
            "mesh = make_production_mesh()\n"
            "for z in (1, 2, 3):\n"
            "    r = analyze_one('llama3_2_3b', 'train_4k', mesh,\n"
            "                    strat=ShardingStrategy(zero_stage=z))\n"
            "    print(z, r['device_mem_gib'], r['memory_s'],"
            " r['collective_s'])\n")
        r = subprocess.run([sys.executable, "-c", code], env=env,
                           capture_output=True, text=True, timeout=1200)
        print("\n== R2 on the TPU runtime ==")
        print(r.stdout or r.stderr[-500:])
    _csv("zero_tpu", (time.time() - t0) * 1e6)


def bench_roofline():
    root = os.path.join(os.path.dirname(__file__), "..")
    path = os.path.join(root, "roofline_final.json")
    if not os.path.exists(path):
        path = os.path.join(root, "roofline_baseline.json")
    if not os.path.exists(path):
        print("\n(roofline_baseline.json not present — run "
              "python -m repro.launch.roofline)")
        return
    recs = json.load(open(path))
    print("\n== Roofline baselines (single-pod 16x16; see EXPERIMENTS.md) ==")
    print(f"{'arch':25s} {'shape':12s} {'compute':>8s} {'memory':>8s} "
          f"{'coll':>8s} {'dominant':>10s} {'useful':>7s}")
    for r in recs:
        if "error" in r:
            print(f"{r['arch']:25s} {r['shape']:12s} ERROR")
            continue
        print(f"{r['arch']:25s} {r['shape']:12s} {r['compute_s']:7.3f}s "
              f"{r['memory_s']:7.3f}s {r['collective_s']:7.3f}s "
              f"{r['dominant']:>10s} {r['useful_ratio']:6.3f}")


BENCHES = {
    "figure1": bench_figure1,
    "table1": bench_table1,
    "table2": bench_table2,
    "placement": bench_placement,
    "generation": bench_generation,
    "paged": bench_paged,
    "decode": bench_decode,
    "serving": bench_serving,
    "hydra": bench_hydra,
    "offload": bench_offload,
    "obs": bench_obs,
    "zero": bench_zero,
    "tp": bench_tp,
    "kernels": bench_kernels,
    "grpo": bench_grpo,
    "zero_tpu": bench_zero_tpu,
    "roofline": bench_roofline,
}

_DEFAULT_OUT = os.path.join(os.path.dirname(__file__), "results")
_DEFAULT_BASELINES = os.path.join(os.path.dirname(__file__), "baselines")
_DEFAULT_HISTORY = os.path.join(os.path.dirname(__file__), "history")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", choices=list(BENCHES), default=None)
    ap.add_argument("--out-dir", default=_DEFAULT_OUT,
                    help="where BENCH_<name>.json result files are written")
    ap.add_argument("--check-baseline", action="store_true",
                    help="fail when a gated metric regresses >10%% vs the "
                         "committed benchmarks/baselines/BENCH_*.json")
    ap.add_argument("--baseline-dir", default=_DEFAULT_BASELINES)
    ap.add_argument("--emit-trace", action="store_true",
                    help="write a Chrome-trace TRACE_<name>.json sibling "
                         "next to every BENCH_<name>.json")
    ap.add_argument("--history-dir", default=_DEFAULT_HISTORY,
                    help="append one git-sha-stamped JSONL line per bench "
                         "to HISTORY_<name>.jsonl here (render with "
                         "launch/report.py --trend); '' disables")
    args = ap.parse_args()
    _EMIT_TRACE[0] = args.emit_trace
    print("name,us_per_call,derived")
    try:
        for name, fn in BENCHES.items():
            if args.only and name not in args.only:
                continue
            _CURRENT[0] = name
            try:
                if args.emit_trace:
                    from repro.obs import SpanTracer
                    bench_tr = SpanTracer()
                    with bench_tr.span(name, "bench"):
                        fn()
                    # a bench that recorded its own richer trace wins
                    TRACES.setdefault(name, bench_tr.chrome_trace())
                else:
                    fn()
            finally:
                _CURRENT[0] = None
    finally:
        # a failing bench must not lose the results of the ones that
        # completed — that is exactly when the artifacts matter
        write_results(args.out_dir)
        if args.history_dir:
            append_history(args.history_dir)
    if args.check_baseline:
        failures = check_baseline(args.baseline_dir)
        if failures:
            print(f"[bench] {failures} gated metric(s) regressed >10%")
            sys.exit(1)
        print("[bench] baseline gate passed")


if __name__ == "__main__":
    main()
