"""Continuous-batching serving: a slot pool over one static donated KV
cache; requests of different lengths join and leave between decode steps.

    PYTHONPATH=src python examples/continuous_batching.py
"""
import sys
import time

import jax
import numpy as np

sys.path.insert(0, "src")

from repro.configs import get_config
from repro.models import Model
from repro.rlhf import live_device_bytes
from repro.serving import ContinuousBatcher


def main():
    cfg = get_config("llama3_2_3b").smoke()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cb = ContinuousBatcher(model, cfg, params, slots=4, capacity=96,
                           temperature=0.8, top_k=40)
    rng = np.random.RandomState(0)
    for i in range(10):
        cb.submit(rng.randint(0, cfg.vocab_size, size=16),
                  max_new_tokens=8 + 4 * (i % 4))
    t0 = time.time()
    done = cb.run_until_drained()
    dt = time.time() - t0
    tok = sum(len(r.out_tokens) for r in done)
    print(f"served {len(done)} requests, {tok} tokens in {cb.steps} decode "
          f"steps ({dt:.1f}s, {tok/dt:.0f} tok/s)")
    print(f"live device memory at end: {live_device_bytes()/2**20:.1f} MiB "
          f"(static pool — no growth with request count)")
    for r in done[:3]:
        print(f"  req {r.rid}: {len(r.out_tokens)} tokens ->",
              r.out_tokens[:8], "...")


if __name__ == "__main__":
    main()
