"""Quickstart: build a model from the registry, train it briefly on the
synthetic corpus, and sample from it.

    PYTHONPATH=src python examples/quickstart.py [--arch llama3_2_3b]
"""
import argparse
import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, "src")

from repro.configs import get_config
from repro.data import SyntheticTextDataset
from repro.models import Model
from repro.rlhf import Rollout
from repro.steps import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_2_3b")
    ap.add_argument("--steps", type=int, default=40)
    args = ap.parse_args()

    # every assigned architecture has a CPU-sized smoke variant
    cfg = get_config(args.arch).smoke()
    model = Model(cfg)
    step = make_train_step(model, cfg, kind="lm", lr=3e-4)
    state = init_train_state(model, cfg, jax.random.PRNGKey(0),
                             step.optimizer)
    n = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(state["params"]))
    print(f"{cfg.name}: {n/1e6:.2f}M params, family={cfg.family}")

    data = SyntheticTextDataset(cfg.vocab_size, 128)
    jit_step = jax.jit(step, donate_argnums=(0,))
    for i, toks in zip(range(args.steps), data.batches(8)):
        toks = jnp.asarray(toks)
        state, m = jit_step(state, {
            "tokens": toks, "loss_mask": jnp.ones_like(toks, jnp.float32)})
        if i % 10 == 0 or i == args.steps - 1:
            print(f"  step {i:3d}  lm_loss {float(m['loss']):.4f}")

    # sample with the fixed-capacity donated KV cache
    ro = Rollout(model, cfg, capacity=96, temperature=0.8, top_k=20)
    prompts = jnp.asarray(next(data.batches(2)))[:, :32]
    res = ro.generate(state["params"], {"tokens": prompts}, 32,
                      jax.random.PRNGKey(7))
    print("generated token ids:", np.asarray(res.tokens[0, 32:48]))


if __name__ == "__main__":
    main()
