"""Batched serving example: prefill + decode with the fixed-capacity donated
KV cache, streaming live-memory per request — demonstrating that serving
memory is flat (the framework-level fix for the paper's App-B generate()
pathology).

    PYTHONPATH=src python examples/serving.py [--arch mamba2_370m]
"""
import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, "src")

from repro.configs import get_config
from repro.data import ByteTokenizer, PromptDataset, \
    synthetic_instruction_prompts
from repro.models import Model
from repro.rlhf import Rollout, live_device_bytes


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_2_3b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen", type=int, default=48)
    ap.add_argument("--requests", type=int, default=5)
    args = ap.parse_args()

    cfg = get_config(args.arch).smoke()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tok = ByteTokenizer()
    prompt_len = 24
    ro = Rollout(model, cfg, capacity=prompt_len + args.gen,
                 temperature=0.8, top_k=40)
    ds = PromptDataset(
        synthetic_instruction_prompts(args.batch * args.requests),
        prompt_len)
    it = ds.batches(args.batch)
    key = jax.random.PRNGKey(1)
    print(f"serving {cfg.name} | live {live_device_bytes()/2**20:.1f} MiB")
    for r in range(args.requests):
        key, k = jax.random.split(key)
        batch = jnp.asarray(next(it)) % cfg.vocab_size
        t0 = time.time()
        res = ro.generate(params, {"tokens": batch}, args.gen, k)
        dt = time.time() - t0
        print(f"req {r}: {dt*1e3:7.1f} ms  "
              f"{args.batch*args.gen/dt:7.0f} tok/s  "
              f"live {live_device_bytes()/2**20:7.1f} MiB")
        del res


if __name__ == "__main__":
    main()
