"""Batched serving example: prefill + decode with the fixed-capacity donated
KV cache, streaming live-memory per request — demonstrating that serving
memory is flat (the framework-level fix for the paper's App-B generate()
pathology).

With ``--backend paged`` the same traffic runs through the paged KV cache
(`repro.paged`): a continuous batcher admits ragged-length requests
against a global page pool and the example prints reserved-KV pages as
the pool breathes — the vLLM-style layout where reserved memory tracks
live tokens instead of worst-case capacity.

``--capture-buckets 8,16,32`` pads prompts (and paged live-slot batches)
to a compile-bucket ladder so ragged traffic stops recompiling the jitted
steps; ``--spec-decode`` turns on MTP self-speculative greedy decoding
(bit-identical greedy output, fewer decode dispatches). Both are the
DESIGN.md "Fast decode path" features.

``--prefix-cache`` shares one system prompt across all requests so later
arrivals hash-hit its KV pages instead of re-prefilling them;
``--tenants gold,silver,bronze`` splits traffic across tenants under
weighted round-robin admission with anti-starvation aging.

    PYTHONPATH=src python examples/serving.py [--arch mamba2_370m]
    PYTHONPATH=src python examples/serving.py --backend paged
    PYTHONPATH=src python examples/serving.py --backend paged \
        --spec-decode --capture-buckets 8,16,32
    PYTHONPATH=src python examples/serving.py --backend paged \
        --prefix-cache --tenants gold,silver,bronze
"""
import argparse
import dataclasses
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, "src")

from repro.configs import get_config
from repro.data import ByteTokenizer, PromptDataset, \
    synthetic_instruction_prompts
from repro.models import Model
from repro.rlhf import Rollout, live_device_bytes


def _fast_decode_cfg(args, cfg):
    """Apply the fast-decode CLI flags: parse the bucket list and give the
    smoke config an MTP head when speculation is requested."""
    buckets = tuple(int(b) for b in args.capture_buckets.split(",")) \
        if args.capture_buckets else None
    if args.spec_decode and cfg.mtp_depth == 0:
        cfg = dataclasses.replace(cfg, mtp_depth=args.spec_k)
    return cfg, buckets


def paged_demo(args):
    from repro.serving import ContinuousBatcher
    cfg = get_config(args.arch).smoke()
    cfg, buckets = _fast_decode_cfg(args, cfg)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    capacity = 24 + args.gen + (args.spec_k if args.spec_decode else 0)
    temperature, top_k = (0.0, 0) if args.spec_decode else (0.8, 40)
    telemetry = None
    if args.watermark or args.attrib_out:
        from repro.obs import FlightRecorder, RunTelemetry
        flight = FlightRecorder(watermark=args.watermark) \
            if args.watermark else None
        telemetry = RunTelemetry.create(run="serving", arch=args.arch,
                                        backend="paged", flight=flight)
    tenants = [t for t in args.tenants.split(",") if t] or ["default"]
    weights = {t: float(len(tenants) - i) for i, t in enumerate(tenants)}
    cb = ContinuousBatcher(model, cfg, params, slots=args.batch,
                           capacity=capacity, temperature=temperature,
                           top_k=top_k, cache_backend="paged", page_size=16,
                           capture_buckets=buckets,
                           spec_decode=args.spec_decode, spec_k=args.spec_k,
                           prefix_cache=args.prefix_cache,
                           tenant_weights=weights, telemetry=telemetry)
    rng = np.random.RandomState(0)
    n_req = args.batch * args.requests
    # with --prefix-cache every request shares one 16-token system prompt,
    # so only the first prefills it; the rest hash-hit and ride its pages
    system = rng.randint(0, cfg.vocab_size, size=16)
    for i in range(n_req):
        # ragged: every request decodes a different number of tokens
        tail = rng.randint(0, cfg.vocab_size, size=8)
        prompt = np.concatenate([system, tail]) if args.prefix_cache \
            else rng.randint(0, cfg.vocab_size, size=24)
        cb.submit(prompt, int(rng.randint(args.gen // 4, args.gen)),
                  tenant=tenants[i % len(tenants)])
    print(f"serving {cfg.name} [paged] | pool {cb.pm.num_pages} pages "
          f"x {cb.pm.page_size} tokens")
    done, t0 = 0, time.time()
    while done < n_req:
        done += len(cb.step())
        if cb.steps % 8 == 0 or done == n_req:
            st = cb.pm.stats
            print(f"step {cb.steps:4d}: done {done:3d}/{n_req}  "
                  f"pages {st.pages_in_use:3d}/{st.num_pages}  "
                  f"reserved {cb.pm.reserved_bytes()/2**20:6.2f} MiB  "
                  f"frag {cb.pm.fragmentation_slots():3d} slots")
    if buckets or args.spec_decode:
        print("compile cache:", cb.compile_cache.stats())
    if args.prefix_cache:
        print(f"prefix cache: hit rate {cb.prefix_hit_rate():.3f} "
              f"({cb.pm.stats.n_prefix_hits} page hits, "
              f"{cb.pm.stats.n_prefix_evictions} evictions)")
    dense_bytes = cb.B * capacity * (cb.pm.bytes_per_token or 1)
    print(f"drained in {time.time()-t0:.1f}s | peak "
          f"{st.peak_pages_in_use * cb.pm.page_bytes / 2**20:.2f} MiB paged "
          f"vs {dense_bytes/2**20:.2f} MiB dense [B, capacity]")
    if args.attrib_out and telemetry is not None \
            and telemetry.attribution is not None:
        import json
        fl = telemetry.flight
        bundle = {"schema": "attribution/v1", "source": "serving",
                  "arch": args.arch,
                  "final": telemetry.attribution.snapshot().to_record(),
                  "compiled_memory": {":".join(str(k) for k in key): stats
                                      for key, stats in
                                      cb.compiled_memory.items()},
                  "flight_dumps": list(fl.dumps) if fl is not None else []}
        with open(args.attrib_out, "w") as f:
            json.dump(bundle, f, indent=1, default=str)
        print("attribution:", args.attrib_out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_2_3b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen", type=int, default=48)
    ap.add_argument("--requests", type=int, default=5)
    ap.add_argument("--backend", default="dense",
                    choices=("dense", "paged"))
    ap.add_argument("--spec-decode", action="store_true",
                    help="MTP self-speculative greedy decode (forces "
                         "temperature=0, top_k=0; bit-identical output)")
    ap.add_argument("--spec-k", type=int, default=2,
                    help="draft tokens per speculative step")
    ap.add_argument("--capture-buckets", default="",
                    help="comma list of compile-bucket sizes, e.g. 8,16,32")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="cross-request prefix caching (paged backend): "
                         "requests share one system prompt and hash-hit "
                         "its KV pages instead of re-prefilling")
    ap.add_argument("--tenants", default="",
                    help="comma list of tenant names for weighted "
                         "round-robin admission, e.g. gold,silver,bronze "
                         "(first listed gets the highest weight)")
    ap.add_argument("--watermark", type=float, default=0.0,
                    metavar="FRACTION",
                    help="arm the OOM flight recorder (paged backend): "
                         "dump owners/buffers when live bytes cross this "
                         "fraction of capacity; 0 disables")
    ap.add_argument("--attrib-out", default="", metavar="PATH",
                    help="write the serving attribution snapshot + "
                         "compiled-memory table + flight dumps as JSON "
                         "(paged backend)")
    args = ap.parse_args()
    if (args.watermark or args.attrib_out) and args.backend != "paged":
        print("note: --watermark/--attrib-out instrument the paged "
              "batcher; ignored for --backend dense")
    if args.backend == "paged":
        paged_demo(args)
        return

    cfg = get_config(args.arch).smoke()
    cfg, buckets = _fast_decode_cfg(args, cfg)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tok = ByteTokenizer()
    prompt_len = 24
    temperature, top_k = (0.0, 0) if args.spec_decode else (0.8, 40)
    ro = Rollout(model, cfg, capacity=prompt_len + args.gen,
                 temperature=temperature, top_k=top_k,
                 capture_buckets=buckets, spec_decode=args.spec_decode,
                 spec_k=args.spec_k)
    ds = PromptDataset(
        synthetic_instruction_prompts(args.batch * args.requests),
        prompt_len)
    it = ds.batches(args.batch)
    key = jax.random.PRNGKey(1)
    print(f"serving {cfg.name} | live {live_device_bytes()/2**20:.1f} MiB")
    for r in range(args.requests):
        key, k = jax.random.split(key)
        batch = jnp.asarray(next(it)) % cfg.vocab_size
        t0 = time.time()
        res = ro.generate(params, {"tokens": batch}, args.gen, k)
        dt = time.time() - t0
        print(f"req {r}: {dt*1e3:7.1f} ms  "
              f"{args.batch*args.gen/dt:7.0f} tok/s  "
              f"live {live_device_bytes()/2**20:7.1f} MiB")
        del res
    if buckets or args.spec_decode:
        print("compile cache:", ro.compile_cache.stats())


if __name__ == "__main__":
    main()
